"""Backend throughput record: numpy vs jax vs pallas on the shared numerics.

Three measurements, written to BENCH_backend.json (env knob
REPRO_BENCH_BACKEND_JSON) so the perf trajectory is machine-readable:

(a) Monte-Carlo completion delay (the workload behind every paper figure)
    at large trial counts: the chunked-numpy ``simulate_plan`` loop vs the
    jitted device-resident ``stream.backend.simulate_batch`` kernel
    (active-column gather, rbg float32 draws, sort-free completion rule in
    cache-sized lax.map chunks).  The acceptance bar is >= 5x throughput on
    the jax path at 1e5 trials; CPU measures ~10-15x, accelerators more.
(b) The exactly-L decode: systematic-prefix fast path (permutation scatter,
    bit-identical to the general solve) vs the forced stacked LU solve.
(c) The verification encode: the Pallas ``mds_encode`` kernel vs plain jnp
    matmul at serving-path sizes.  Off-TPU the kernel runs in interpret
    mode — correctness-scale numbers only, recorded with the flag so the
    JSON is honest about what was measured.
(d) The batched shard-execution kernel: one ``coded_shard_matmul_batch``
    pass over a serving step's packed 128-aligned shard tiles vs the
    per-tile loop (numpy einsum reference, jax vmap fallback, Pallas
    one-launch path).
(e) Virtual parity: the generated-parity kernel path (rows derived
    in-kernel from packed threefry counters) vs the materialised gather,
    plus the encoded-cache bytes each storage mode holds at redundancy 2.
    CI floors generated throughput at 0.8x materialised and ceilings the
    virtual/materialised byte ratio at 0.55.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import iterated_greedy, large_scale_scenario, plan_from_assignment
from repro.sim import simulate_plan
from repro.stream.backend import decode_batch, has_jax

from .common import emit


def _best(fn, reps: int = 3) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run_montecarlo(trials: int, seed: int = 0) -> dict:
    sc = large_scale_scenario(seed)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=seed))
    t_np = _best(lambda: simulate_plan(sc, plan, trials=trials, rng=seed + 1),
                 reps=2)
    rec = {
        "trials": trials,
        "numpy_seconds": round(t_np, 4),
        "numpy_trials_per_s": round(trials / t_np),
    }
    if has_jax():
        jx = lambda: simulate_plan(sc, plan, trials=trials, rng=seed + 1,
                                   backend="jax")
        jx()                                   # compile outside the timing
        t_jx = _best(jx, reps=3)
        r_np = simulate_plan(sc, plan, trials=trials, rng=seed + 1)
        r_jx = simulate_plan(sc, plan, trials=trials, rng=seed + 1,
                             backend="jax")
        rec.update({
            "jax_seconds": round(t_jx, 4),
            "jax_trials_per_s": round(trials / t_jx),
            "jax_speedup": round(t_np / t_jx, 2),
            "numpy_mean_ms": round(r_np.overall_mean, 2),
            "jax_mean_ms": round(r_jx.overall_mean, 2),
        })
        emit("backend/montecarlo", t_jx * 1e6,
             f"trials={trials};jax_speedup={rec['jax_speedup']}x;"
             f"numpy_mean={rec['numpy_mean_ms']};jax_mean={rec['jax_mean_ms']}")
    return rec


def run_decode(batch: int = 2048, L: int = 128, seed: int = 0) -> dict:
    """Systematic-prefix scatter vs forced general solve on identical input."""
    rng = np.random.default_rng(seed)
    Lt = 2 * L
    G = np.vstack([np.eye(L), rng.normal(0, 1 / np.sqrt(L), (Lt - L, L))])
    # the no-straggler serving case: every task got the systematic prefix
    rows = np.stack([rng.permutation(L) for _ in range(batch)])
    x_true = rng.normal(size=(batch, L))
    y = np.stack([x_true[i][rows[i]] for i in range(batch)])
    t_fast = _best(lambda: decode_batch(G, rows, y))
    t_solve = _best(lambda: decode_batch(G, rows, y, systematic="never"))
    out_fast = decode_batch(G, rows, y)
    out_solve = decode_batch(G, rows, y, systematic="never")
    rec = {
        "batch": batch, "L": L,
        "fast_path_seconds": round(t_fast, 5),
        "solve_seconds": round(t_solve, 5),
        "fast_path_speedup": round(t_solve / t_fast, 1),
        "bit_identical": bool((out_fast == out_solve).all()),
    }
    emit("backend/decode_fast_path", t_fast * 1e6,
         f"batch={batch};L={L};speedup={rec['fast_path_speedup']}x;"
         f"bit_identical={rec['bit_identical']}")
    return rec


def run_pallas_encode(L: int = 256, S: int = 256, seed: int = 0) -> dict:
    if not has_jax():  # pragma: no cover
        return {}
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    Lt = 2 * L
    G = jnp.asarray(np.vstack([np.eye(L),
                               rng.normal(0, 1 / np.sqrt(L), (L, L))]),
                    jnp.float32)
    A = jnp.asarray(rng.normal(size=(L, S)), jnp.float32)
    interp = ops.default_interpret()
    pal = lambda: np.asarray(ops.mds_encode(G, A))
    ref = lambda: np.asarray(jnp.matmul(G, A))
    pal(), ref()                               # compile outside the timing
    t_pal, t_ref = _best(pal), _best(ref)
    err = float(np.abs(pal() - ref()).max())
    rec = {
        "shape": f"{Lt}x{L}x{S}",
        "pallas_seconds": round(t_pal, 5),
        "jnp_seconds": round(t_ref, 5),
        "interpret_mode": bool(interp),
        "max_err": err,
    }
    emit("backend/pallas_encode", t_pal * 1e6,
         f"shape={rec['shape']};interpret={interp};max_err={err:.2e}")
    return rec


def run_shard_matmul(tiles: int = 12, tile: int = 128, D: int = 128,
                     cols: int = 4, seed: int = 0) -> dict:
    """The batched serving kernel: every packed shard tile of a step in
    one pass (``kernels.ops.coded_shard_matmul_batch``) vs the per-tile
    loop it replaces — numpy einsum loop, jax vmap, Pallas one-launch
    (interpret off-TPU: correctness-scale numbers, flagged)."""
    if not has_jax():  # pragma: no cover
        return {}
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    T = np.asarray(rng.normal(size=(tiles, tile, D)), np.float32)
    x = np.asarray(rng.normal(size=(D, cols)), np.float32)
    Td, xd = jnp.asarray(T), jnp.asarray(x)
    t_np = _best(lambda: [np.einsum("ld,dc->lc", T[i], x)
                          for i in range(tiles)])
    vm = lambda: np.asarray(ops.coded_shard_matmul_batch(Td, xd,
                                                         mode="vmap"))
    pl = lambda: np.asarray(ops.coded_shard_matmul_batch(Td, xd,
                                                         mode="pallas"))
    vm(), pl()                                 # compile outside the timing
    t_vm, t_pl = _best(vm), _best(pl)
    interp = ops.default_interpret()
    err = float(np.abs(vm() - np.stack([T[i] @ x
                                        for i in range(tiles)])).max())
    rec = {
        "tiles": tiles, "tile": tile, "D": D, "cols": cols,
        "numpy_loop_seconds": round(t_np, 5),
        "vmap_seconds": round(t_vm, 5),
        "pallas_seconds": round(t_pl, 5),
        "vmap_speedup_vs_loop": round(t_np / t_vm, 2),
        "interpret_mode": bool(interp),
        "max_err": err,
    }
    emit("backend/shard_matmul_batch", t_vm * 1e6,
         f"tiles={tiles}x{tile}x{D};vmap_speedup={rec['vmap_speedup_vs_loop']}"
         f"x;interpret={interp};max_err={err:.2e}")
    return rec


def run_generated_parity(L: int = 256, D: int = 128, cols: int = 4,
                         seed: int = 0) -> dict:
    """Virtual-parity serving cost: the generated-parity kernel path
    (parity rows derived in-kernel from packed threefry counters,
    contracted as ``R_gen @ (W @ x)``) vs the materialised path (parity
    rows gathered from the host encoded cache into the tiles).  Also
    records the encoded-cache footprint of each storage mode at
    redundancy 2 — the memory the virtual mode exists to reclaim."""
    if not has_jax():  # pragma: no cover
        return {}
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.serve_coded import CodedLinear
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(L, D))
    mat = CodedLinear(W, name="bench", seed=seed, parity_chunk=64)
    virt = CodedLinear(W, name="bench", seed=seed, parity_chunk=64,
                       parity_storage="virtual")
    for lin in (mat, virt):
        lin.ensure_parity(L)                   # redundancy 2
    # one packed tile: a straggler prefix of systematic rows + parity tail
    n_par = 64
    rows = np.concatenate([np.arange(L - n_par), np.arange(L, L + n_par)])
    tiles_mat = jnp.asarray(mat.gather_encoded(rows)[None], jnp.float32)
    zeroed = virt.gather_encoded(rows).astype(np.float32)
    par_pos = np.nonzero(rows >= L)[0]
    zeroed[par_pos] = 0.0
    spec = ops.GeneratedParity(lanes=par_pos,
                               ctrs=virt.parity_ctrs(rows[par_pos] - L),
                               key=virt.pkey, w=virt.device_W())
    tiles_gen = jnp.asarray(zeroed[None])
    x = jnp.asarray(rng.normal(size=(D, cols)), jnp.float32)
    m = lambda: np.asarray(ops.coded_shard_matmul_batch(
        tiles_mat, x, mode="vmap"))
    g = lambda: np.asarray(ops.coded_shard_matmul_batch(
        tiles_gen, x, mode="vmap", parity_mode="generated", parity=[spec]))
    m(), g()                                   # compile outside the timing
    t_m, t_g = _best(m), _best(g)
    err = float(np.abs(g() - m()).max())
    b_mat, b_virt = mat.encoded_cache_bytes(), virt.encoded_cache_bytes()
    rec = {
        "L": L, "D": D, "cols": cols, "parity_rows": n_par,
        "materialized_seconds": round(t_m, 5),
        "generated_seconds": round(t_g, 5),
        "generated_vs_materialized": round(t_m / t_g, 3),
        "encoded_bytes_materialized": int(b_mat),
        "encoded_bytes_virtual": int(b_virt),
        "encoded_bytes_ratio": round(b_virt / b_mat, 3),
        "interpret_mode": bool(ops.default_interpret()),
        "max_err": err,
    }
    emit("backend/generated_parity", t_g * 1e6,
         f"L={L};D={D};gen_vs_mat={rec['generated_vs_materialized']}x;"
         f"bytes_ratio={rec['encoded_bytes_ratio']};max_err={err:.2e}")
    return rec


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--trials", type=int, default=100_000,
                   help="Monte-Carlo trials for the throughput record")
    p.add_argument("--json", default=None,
                   help="output path (default BENCH_backend.json)")
    args = p.parse_args(argv)
    record = {
        "bench": "backend_throughput",
        "montecarlo": run_montecarlo(args.trials),
        "decode": run_decode(),
        "pallas_encode": run_pallas_encode(),
        "shard_matmul": run_shard_matmul(),
        "generated_parity": run_generated_parity(),
    }
    path = args.json or os.environ.get("REPRO_BENCH_BACKEND_JSON",
                                       "BENCH_backend.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}")
    return record


if __name__ == "__main__":
    main()
