"""Build the §Roofline table from the dry-run JSONs + the analytic estimator.

    PYTHONPATH=src python -m benchmarks.roofline_table [--dir results/dryrun]

Emits a markdown table (stdout + results/roofline_table.md): per (arch ×
shape × mesh) the three analytic roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO ratios, per-device memory, and compile times.  Compiled
cost_analysis numbers are shown per-device as a cross-check (they undercount
loop bodies — see launch/analytic.py).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict

from repro.configs import get_config
from repro.launch.analytic import MeshDesc, estimate
from repro.launch.roofline import HW, model_flops
from repro.models import shape_cell

MESHES = {"pod16x16": MeshDesc(dp=16, tp=16),
          "pod2x16x16": MeshDesc(dp=32, tp=16)}


def load_records(d: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def enrich(rec: dict) -> dict:
    if rec.get("status") != "ok":
        return rec
    cfg = get_config(rec["arch"])
    cell = shape_cell(rec["cell"])
    mesh = MESHES[rec["mesh"]]
    est = estimate(cfg, cell, mesh, n_micro=rec.get("microbatches", 1),
                   fsdp=rec.get("fsdp", True),
                   ep_full=rec.get("ep_full", False),
                   acc_dtype=rec.get("acc_dtype", "float32"),
                   remat_policy=rec.get("remat_policy", "full"),
                   a2a_fp8=rec.get("a2a_fp8", False))
    terms = est.terms()
    dominant = max(terms, key=terms.get)
    t_total = sum(terms.values())        # serial upper bound
    t_peak = model_flops(cfg, cell) / (mesh.chips * HW["peak_flops"])
    rec.update(
        a_flops=est.flops, a_hbm=est.hbm_bytes, a_ici=est.ici_bytes,
        a_t_compute=terms["compute"], a_t_memory=terms["memory"],
        a_t_collective=terms["collective"], a_bottleneck=dominant,
        a_roofline_frac=t_peak / max(t_total, 1e-30),
        a_mfu_bound=t_peak / max(max(terms.values()), 1e-30),
    )
    return rec


def fmt_ms(x):
    return f"{x*1e3:.1f}"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline_table.md")
    args = ap.parse_args(argv)

    rows = []
    skips = []
    for rec in load_records(args.dir):
        if rec.get("status") == "skip":
            skips.append(rec)
            continue
        rows.append(enrich(rec))

    rows.sort(key=lambda r: (r["arch"], r["cell"], r["mesh"]))
    hdr = ("| arch | cell | mesh | compute ms | memory ms | collective ms | "
           "bottleneck | roofline frac | useful/HLO | temp GiB | compile s |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        temp = r.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {fmt_ms(r['a_t_compute'])} | {fmt_ms(r['a_t_memory'])} "
            f"| {fmt_ms(r['a_t_collective'])} | {r['a_bottleneck']} "
            f"| {r['a_roofline_frac']:.3f} | {r['useful_ratio']:.2f} "
            f"| {temp:.1f} | {r.get('compile_s', 0):.0f} |")
    for s in skips:
        lines.append(f"| {s['arch']} | {s['cell']} | {s['mesh']} | — | — | — "
                     f"| skipped | — | — | — | — |")

    text = "\n".join(lines)
    print(text)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text + "\n")
    n_ok = len(rows)
    print(f"\n{n_ok} compiled cells, {len(skips)} documented skips "
          f"→ {args.out}")


if __name__ == "__main__":
    main()
