"""Paper Fig. 2 & 3 — validation of the Markov's-inequality approximation.

Computation-delay-dominant setting.  'Exact' = Theorem-2 loads (optimal for
P3), 'Approx' = Theorem-1 loads (P4 optimum), 'Approx, enhanced' = Theorem-2
re-allocation on the Theorem-1-driven worker assignment — all three assigned
by Algorithm 1.  Reports per-master and overall mean completion delay (ms)
plus CDF samples.

Paper claims validated: the enhanced approximation ≈ exact everywhere; the
plain approximation's gap is small and can even *win* at small N (extra
redundancy robustness, Fig. 2a discussion).
"""
from __future__ import annotations

import numpy as np

from repro.core import (comp_dominant_loads, iterated_greedy,
                        plan_from_assignment, small_scale_scenario,
                        large_scale_scenario, Plan)
from repro.sim import simulate_plan

from .common import TRIALS, bench_parser, emit, save_rows, timed


def _plans(sc, rng=0):
    k_exact = iterated_greedy(sc, mode="comp_exact", rng=rng)
    k_approx = iterated_greedy(sc, mode="markov", rng=rng)
    exact = plan_from_assignment(sc, k_exact, mode="comp_exact",
                                 method="exact")
    approx = plan_from_assignment(sc, k_approx, mode="markov",
                                  method="approx")
    enhanced = plan_from_assignment(sc, k_approx, mode="comp_exact",
                                    method="approx-enhanced")
    return exact, approx, enhanced


def run(scale: str = "small", trials: int = TRIALS, seed: int = 0,
        backend: str = "numpy"):
    # computation-dominant: make comms delay negligible
    sc0 = small_scale_scenario(seed) if scale == "small" \
        else large_scale_scenario(seed)
    import dataclasses
    sc = dataclasses.replace(sc0, gamma=np.full_like(sc0.gamma, 1e9))
    plans, t_us = timed(_plans, sc)
    rows = []
    out = {}
    for plan in plans:
        r = simulate_plan(sc, plan, trials=trials, rng=seed + 1,
                          keep_samples=True, backend=backend)
        out[plan.method] = r
        for m in range(sc.M):
            rows.append((plan.method, f"master{m}",
                         round(r.per_master_mean[m], 2)))
        rows.append((plan.method, "overall", round(r.overall_mean, 2)))
    save_rows(f"fig{'2' if scale == 'small' else '3'}_markov_{scale}.csv",
              "method,master,mean_delay_ms", rows)

    gap = out["approx"].overall_mean / out["exact"].overall_mean - 1
    enh_gap = out["approx-enhanced"].overall_mean / out["exact"].overall_mean - 1
    emit(f"fig2_3/markov_{scale}", t_us,
         f"approx_gap={gap:+.3%};enhanced_gap={enh_gap:+.3%}")
    return out


def main(argv=None):
    args = bench_parser(__doc__).parse_args(argv)
    for scale in ("small", "large") if args.scale == "all" else (args.scale,):
        run(scale, trials=args.trials, backend=args.backend)


if __name__ == "__main__":
    main()
