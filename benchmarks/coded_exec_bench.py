"""Beyond-paper benchmark: the end-to-end coded execution engine + kernels.

(a) CodedExecutor numerical round-trip at matrix scale (encode → straggle →
    k-of-n decode) with fault injection;
(b) Pallas kernel throughput (interpret mode on CPU: correctness-scale
    numbers, the real targets are TPU);
(c) coded gradient aggregation k-of-n reconstruction error.
"""
from __future__ import annotations

import numpy as np

from repro.core import (Scenario, iterated_greedy, plan_from_assignment,
                        small_scale_scenario)
from repro.runtime import CodedExecutor
from repro.runtime.coded_grads import coded_grad_aggregate, encode_grad_shards

from .common import emit, timed


def run_executor(seed: int = 0):
    sc = small_scale_scenario(seed)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=seed))
    # shrink loads to a fast matrix size while keeping proportions
    plan.l[:] = plan.l / sc.L[:, None] * 512
    sc = Scenario(a=sc.a, u=sc.u, gamma=sc.gamma, L=np.full(sc.M, 512.0))
    ex = CodedExecutor(sc, plan, rng=seed)
    rng = np.random.default_rng(seed)
    A = [rng.normal(size=(512, 128)) for _ in range(sc.M)]
    x = [rng.normal(size=128) for _ in range(sc.M)]

    def go():
        return ex.run(A, x, dead_workers=(1,))

    (res, report), t_us = timed(go)
    emit("coded_exec/roundtrip", t_us,
         f"decode_ok={bool(report.decode_ok.all())};"
         f"max_err={report.max_err.max():.2e};"
         f"completion_ms={report.overall:.1f};dead_worker_survived=True")


def run_kernels(seed: int = 0):
    import jax.numpy as jnp
    from repro.kernels import coded_matvec, mds_encode, ref
    rng = np.random.default_rng(seed)
    G = jnp.asarray(np.vstack([np.eye(256),
                               rng.normal(0, 1 / 16, size=(256, 256))]),
                    jnp.float32)
    A = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    (enc, t_enc) = timed(lambda: np.asarray(mds_encode(G, A)))
    err = float(np.abs(enc - np.asarray(ref.mds_encode_ref(G, A))).max())
    emit("kernels/mds_encode_interp", t_enc, f"max_err={err:.2e};shape=512x256x512")
    x = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    (y, t_mv) = timed(lambda: np.asarray(coded_matvec(jnp.asarray(enc), x)))
    err2 = float(np.abs(y - np.asarray(ref.coded_matvec_ref(jnp.asarray(enc), x))).max())
    emit("kernels/coded_matvec_interp", t_mv, f"max_err={err2:.2e}")


def run_coded_grads(seed: int = 0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    grads = [{"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
             for _ in range(4)]

    def go():
        coded, ctx = encode_grad_shards(grads, n_coded=6, rng=seed)
        # drop shards 0 and 2 (stragglers) — any 4 of 6 reconstruct
        return coded_grad_aggregate(coded, ctx, arrived=[1, 3, 4, 5])

    agg, t_us = timed(go)
    truth = sum(np.asarray(g["w"]) for g in grads)
    err = float(np.abs(np.asarray(agg["w"]) - truth).max() / np.abs(truth).max())
    emit("coded_grads/4of6", t_us, f"rel_err={err:.2e};stragglers_dropped=2")


def main():
    run_executor()
    run_kernels()
    run_coded_grads()


if __name__ == "__main__":
    main()
