"""Beyond-paper benchmark: the end-to-end coded execution engine + kernels.

(a) CodedExecutor numerical round-trip at matrix scale (encode → straggle →
    k-of-n decode) with fault injection;
(b) Pallas kernel throughput (interpret mode on CPU: correctness-scale
    numbers, the real targets are TPU);
(c) coded gradient aggregation k-of-n reconstruction error;
(d) the streaming engine: a 1000-task, 3-master Poisson stream with mid-run
    churn through the batched backend vs the same tasks run sequentially
    through CodedExecutor — results land in BENCH_stream.json (env knob
    REPRO_BENCH_JSON) so the perf trajectory is machine-readable.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import (Scenario, iterated_greedy, plan_from_assignment,
                        small_scale_scenario)
from repro.runtime import CodedExecutor
from repro.runtime.coded_grads import coded_grad_aggregate, encode_grad_shards
from repro.stream import (BackendConfig, StreamConfig, StreamingExecutor,
                          WorkerEvent, poisson_sources)

from .common import emit, timed


def run_executor(seed: int = 0):
    sc = small_scale_scenario(seed)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=seed))
    # shrink loads to a fast matrix size while keeping proportions
    plan.l[:] = plan.l / sc.L[:, None] * 512
    sc = Scenario(a=sc.a, u=sc.u, gamma=sc.gamma, L=np.full(sc.M, 512.0))
    ex = CodedExecutor(sc, plan, rng=seed)
    rng = np.random.default_rng(seed)
    A = [rng.normal(size=(512, 128)) for _ in range(sc.M)]
    x = [rng.normal(size=128) for _ in range(sc.M)]

    def go():
        return ex.run(A, x, dead_workers=(1,))

    (res, report), t_us = timed(go)
    emit("coded_exec/roundtrip", t_us,
         f"decode_ok={bool(report.decode_ok.all())};"
         f"max_err={report.max_err.max():.2e};"
         f"completion_ms={report.overall:.1f};dead_worker_survived=True")


def run_kernels(seed: int = 0):
    import jax.numpy as jnp
    from repro.kernels import coded_matvec, mds_encode, ref
    rng = np.random.default_rng(seed)
    G = jnp.asarray(np.vstack([np.eye(256),
                               rng.normal(0, 1 / 16, size=(256, 256))]),
                    jnp.float32)
    A = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    (enc, t_enc) = timed(lambda: np.asarray(mds_encode(G, A)))
    err = float(np.abs(enc - np.asarray(ref.mds_encode_ref(G, A))).max())
    emit("kernels/mds_encode_interp", t_enc, f"max_err={err:.2e};shape=512x256x512")
    x = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    (y, t_mv) = timed(lambda: np.asarray(coded_matvec(jnp.asarray(enc), x)))
    err2 = float(np.abs(y - np.asarray(ref.coded_matvec_ref(jnp.asarray(enc), x))).max())
    emit("kernels/coded_matvec_interp", t_mv, f"max_err={err2:.2e}")


def run_coded_grads(seed: int = 0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    grads = [{"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
             for _ in range(4)]

    def go():
        coded, ctx = encode_grad_shards(grads, n_coded=6, rng=seed)
        # drop shards 0 and 2 (stragglers) — any 4 of 6 reconstruct
        return coded_grad_aggregate(coded, ctx, arrived=[1, 3, 4, 5])

    agg, t_us = timed(go)
    truth = sum(np.asarray(g["w"]) for g in grads)
    err = float(np.abs(np.asarray(agg["w"]) - truth).max() / np.abs(truth).max())
    emit("coded_grads/4of6", t_us, f"rel_err={err:.2e};stragglers_dropped=2")


def _stream_scenario(seed: int = 0, M: int = 3, N: int = 8, L: float = 256.0):
    rng = np.random.default_rng(seed)
    a = np.zeros((M, N + 1))
    a[:, 0] = 0.5
    a[:, 1:] = rng.uniform(0.2, 0.4, size=(M, N))
    return Scenario(a=a, u=1 / a, gamma=2 / a, L=np.full(M, L))


def run_stream(seed: int = 0, n_tasks: int = 1000,
               json_path: str | None = None, backend: str = "numpy"):
    """1000-task streaming simulation vs sequential CodedExecutor.run.

    Both sides simulate the same workload class (3 masters, L=256 coded
    rows, heterogeneous workers).  Two stream timings are recorded so the
    comparison is honest about what is skipped vs what is batched:

    * delay-sim (numerics='none'): arrivals + queueing + completion delays
      only — the Monte-Carlo-style use, no linear algebra;
    * verify (numerics='verify'): additionally executes every task's MDS
      encode → partial products → exactly-L decode, but *batched* per
      master (einsum + stacked solve) — like-for-like with the baseline's
      per-task numerics loop.
    """
    sc = _stream_scenario(seed)

    def stream_once(numerics):
        srcs = poisson_sources(sc, utilization=0.6, seed=seed + 1)
        churn = [WorkerEvent(2000.0, 2, "degrade", 3.0),
                 WorkerEvent(5000.0, 5, "leave"),
                 WorkerEvent(9000.0, 5, "join")]
        cfg = StreamConfig(
            policy="fractional", rng=seed,
            backend=BackendConfig(numerics=numerics, backend=backend))
        ex = StreamingExecutor(sc, srcs, config=cfg, churn=churn)
        t0 = time.perf_counter()
        ms = ex.run(max_tasks=n_tasks)
        return ms, time.perf_counter() - t0

    ms, stream_s = stream_once("none")
    ms_v, stream_verify_s = stream_once("verify")
    s = ms.summary()
    decode_rate = ms_v.summary().get("decode_ok_rate", float("nan"))

    # sequential baseline: the per-master Python-loop executor, once per task
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=seed))
    L = int(sc.L[0])
    rng = np.random.default_rng(seed)
    A = [rng.normal(size=(L, 8)) for _ in range(sc.M)]
    x = [rng.normal(size=8) for _ in range(sc.M)]
    seq_runs = max(n_tasks // sc.M, 1)       # each run executes M tasks
    cex = CodedExecutor(sc, plan, rng=seed)
    t0 = time.perf_counter()
    for _ in range(seq_runs):
        cex.run(A, x)
    seq_s = (time.perf_counter() - t0) * (n_tasks / (seq_runs * sc.M))

    speedup = seq_s / max(stream_s, 1e-12)
    speedup_verify = seq_s / max(stream_verify_s, 1e-12)
    record = {
        "bench": "stream_vs_sequential",
        "tasks": n_tasks,
        "masters": sc.M,
        "workers": sc.N,
        "L": L,
        "stream_seconds": round(stream_s, 4),
        "stream_verify_seconds": round(stream_verify_s, 4),
        "sequential_seconds": round(seq_s, 4),
        "speedup": round(speedup, 2),
        "speedup_batched_numerics": round(speedup_verify, 2),
        "decode_ok_rate": decode_rate,
        "throughput_tasks_per_s": round(n_tasks / max(stream_s, 1e-12), 1),
        "p50_sojourn_ms": round(s["sojourn_p50"], 3),
        "p99_sojourn_ms": round(s["sojourn_p99"], 3),
        "queue_wait_mean_ms": round(s["queue_wait_mean"], 3),
        "wasted_fraction": round(s["wasted_fraction"], 4),
        "replans": int(s["replans"]),
        "tasks_completed": int(s["tasks_completed"]),
    }
    path = json_path or os.environ.get("REPRO_BENCH_JSON", "BENCH_stream.json")
    # BENCH_stream.json is shared with stream_fleet_bench: carry its
    # "fleet" section over instead of clobbering it
    try:
        with open(path) as f:
            fleet = json.load(f).get("fleet")
    except (OSError, ValueError):
        fleet = None
    if fleet is not None:
        record["fleet"] = fleet
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    emit("stream/1k_tasks", stream_s * 1e6,
         f"speedup_vs_sequential={speedup:.1f}x;"
         f"speedup_batched_numerics={speedup_verify:.1f}x;"
         f"decode_ok_rate={decode_rate};"
         f"throughput={record['throughput_tasks_per_s']};"
         f"p99_sojourn_ms={record['p99_sojourn_ms']};json={path}")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tasks", type=int, default=1000,
                   help="streaming-bench task count")
    p.add_argument("--backend", default="numpy",
                   choices=("numpy", "jax", "pallas"),
                   help="streaming verification backend")
    args = p.parse_args(argv)
    run_executor()
    run_kernels()
    run_coded_grads()
    run_stream(n_tasks=args.tasks, backend=args.backend)


if __name__ == "__main__":
    main()
