"""Fleet-scale streaming bench: ≥1e6 tasks over ≥1000 masters with churn.

Measures the two mechanisms that make ``mode="incremental"`` + the
vectorised event loop the fleet-scale configuration:

* **event throughput** — the batched drain (``BackendConfig.event_batch``)
  vs the per-event reference loop (``event_batch=1``), same scenario, same
  seeds, on a common churn-free subset of the workload (churn-forced
  planner solves cost both loops the same wall and would mask the loop
  difference).  The two loops produce identical metrics (property-tested
  in ``tests/test_stream_fleet.py``); only the wall clock differs.
* **replan latency** — incremental plan repair (O(affected rows) per churn
  event) vs the full re-solve ``mode="always"`` pays on the same churn
  schedule.  Medians over the per-event planner walls
  (``OnlinePlanner.repair_wall`` / ``solve_wall``).

Results merge into the ``"fleet"`` section of ``BENCH_stream.json`` (env
knob ``REPRO_BENCH_JSON``) next to ``coded_exec_bench``'s stream record;
CI floors the two machine-independent ratios
(``fleet.events_per_s_ratio``, ``fleet.replan_latency_ratio``) via
``check_regression.py --min``.

    PYTHONPATH=src python -m benchmarks.stream_fleet_bench \
        --tasks 1000000 --masters 1000 --workers 128
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.problem import Scenario
from repro.stream import (BackendConfig, ReplanPolicy, StreamConfig,
                          StreamingExecutor, WorkerEvent, poisson_sources)

from .common import emit


def fleet_scenario(M: int, N: int, L: float = 64.0,
                   seed: int = 0) -> Scenario:
    """M-master fleet over N shared heterogeneous workers."""
    rng = np.random.default_rng(seed)
    a = np.zeros((M, N + 1))
    a[:, 0] = 0.5
    a[:, 1:] = rng.uniform(0.2, 0.4, size=(M, N))
    return Scenario(a=a, u=1 / a, gamma=2 / a, L=np.full(M, L))


def churn_schedule(horizon: float, N: int, period: float,
                   seed: int = 0) -> list:
    """Deterministic churn: every ``period`` a perturbation fires, cycling
    degrade → restore → leave → join over a rotating worker so the pool
    always returns to health (and the schedule has both repairable events
    and the joins that force a full re-solve)."""
    rng = np.random.default_rng((seed, 0xC4))
    events, t, i = [], period, 0
    while t < horizon:
        w = 1 + (i // 4) % N
        kind = ("degrade", "restore", "leave", "join")[i % 4]
        factor = float(rng.uniform(1.5, 4.0)) if kind == "degrade" else 1.0
        events.append(WorkerEvent(t, w, kind, factor))
        t += period
        i += 1
    return events


def run_fleet(sc: Scenario, *, tasks: int, utilization: float,
              churn: list, event_batch: int, mode: str,
              seed: int) -> tuple:
    cfg = StreamConfig(
        policy="fractional",
        replan=ReplanPolicy(mode=mode),
        backend=BackendConfig(event_batch=event_batch, keep_records=False),
        rng=seed)
    srcs = poisson_sources(sc, utilization=utilization, seed=seed + 1)
    ex = StreamingExecutor(sc, srcs, config=cfg, churn=list(churn))
    t0 = time.perf_counter()
    ms = ex.run(max_tasks=tasks)
    wall = time.perf_counter() - t0
    return ex, ms, wall


def _q(xs, p):
    return float(np.quantile(np.asarray(xs), p)) if len(xs) else float("nan")


def run_bench(tasks: int = 1_000_000, masters: int = 1000,
              workers: int = 128, utilization: float = 0.15,
              churn_period: float = 20000.0, event_batch: int = 256,
              subset_tasks: int = 0, repeats: int = 3, seed: int = 0,
              json_path: str | None = None) -> dict:
    sc = fleet_scenario(masters, workers, seed=seed)
    # workload horizon estimate sizes the churn schedule; the sim stops at
    # max_tasks regardless, so an over-long schedule only leaves unused
    # events on the heap
    rates = [s.rate for s in poisson_sources(sc, utilization=utilization,
                                             seed=seed + 1)]
    horizon = 1.5 * tasks / max(sum(rates), 1e-12)
    churn = churn_schedule(horizon, workers, churn_period, seed=seed)
    subset = subset_tasks or max(min(tasks // 10, 100_000), 10_000)

    print(f"[fleet] M={masters} N={workers} tasks={tasks} "
          f"util={utilization} churn_events≈{len(churn)} "
          f"event_batch={event_batch} subset={subset}")

    # main run: batched loop + incremental repair, full task count
    ex, ms, wall = run_fleet(sc, tasks=tasks, utilization=utilization,
                             churn=churn, event_batch=event_batch,
                             mode="incremental", seed=seed)
    s = ms.summary()
    pl = ex.planner
    print(f"[fleet] main: {wall:.1f}s, "
          f"{ex.events_processed / wall:,.0f} events/s, "
          f"repairs={pl.repairs} full_solves={pl.full_solves} "
          f"fallbacks={pl.repair_fallbacks}")

    # Loop comparison on a common churn-free subset (identical runs but for
    # the batch).  Churn-free on purpose: both loops would pay the *same*
    # planner wall for every churn-forced solve, a shared constant that
    # compresses the events/s ratio toward 1 no matter how fast either loop
    # drains — planner cost is what replan_latency_ratio measures.  This
    # pair isolates the loop mechanics: heap ops, admission checks, delay
    # sampling, completion math.  Median of ``repeats`` walls.
    walls_b, walls_p = [], []
    for _ in range(max(repeats, 1)):
        exb, _, wall_b = run_fleet(sc, tasks=subset,
                                   utilization=utilization,
                                   churn=[], event_batch=event_batch,
                                   mode="incremental", seed=seed)
        walls_b.append(wall_b)
        exp, _, wall_p = run_fleet(sc, tasks=subset,
                                   utilization=utilization,
                                   churn=[], event_batch=1,
                                   mode="incremental", seed=seed)
        walls_p.append(wall_p)
    assert exb.events_processed == exp.events_processed
    evs_b = exb.events_processed / max(float(np.median(walls_b)), 1e-12)
    evs_p = exp.events_processed / max(float(np.median(walls_p)), 1e-12)

    # replan-latency comparison: full re-solve on the same churn schedule
    exa, _, _ = run_fleet(sc, tasks=subset, utilization=utilization,
                          churn=churn, event_batch=event_batch,
                          mode="always", seed=seed)
    repair_med = _q(pl.repair_wall, 0.5)
    solve_med = _q(exa.planner.solve_wall, 0.5)

    fleet = {
        "tasks": int(s["tasks_completed"]),
        "masters": masters,
        "workers": workers,
        "utilization": utilization,
        "event_batch": event_batch,
        "wall_seconds": round(wall, 2),
        "events_per_s": round(ex.events_processed / max(wall, 1e-12), 1),
        "events_per_s_batched": round(evs_b, 1),
        "events_per_s_per_event": round(evs_p, 1),
        "events_per_s_ratio": round(evs_b / max(evs_p, 1e-12), 2),
        "sojourn_p50_ms": round(s["sojourn_p50"], 3),
        "sojourn_p99_ms": round(s["sojourn_p99"], 3),
        "replan_latency_p50_ms": round(repair_med * 1e3, 3),
        "replan_latency_p99_ms": round(_q(pl.repair_wall, 0.99) * 1e3, 3),
        "full_solve_p50_ms": round(solve_med * 1e3, 3),
        "replan_latency_ratio": round(solve_med / max(repair_med, 1e-12), 2),
        "repairs": pl.repairs,
        "full_solves": pl.full_solves,
        "repair_fallbacks": pl.repair_fallbacks,
    }

    path = json_path or os.environ.get("REPRO_BENCH_JSON",
                                       "BENCH_stream.json")
    # merge: coded_exec_bench owns the top level of this JSON
    try:
        with open(path) as f:
            record = json.load(f)
    except (OSError, ValueError):
        record = {}
    record["fleet"] = fleet
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    emit("stream/fleet", wall * 1e6,
         f"events_per_s={fleet['events_per_s']};"
         f"events_per_s_ratio={fleet['events_per_s_ratio']};"
         f"replan_latency_ratio={fleet['replan_latency_ratio']};"
         f"sojourn_p99_ms={fleet['sojourn_p99_ms']};json={path}")
    return fleet


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tasks", type=int, default=1_000_000)
    p.add_argument("--masters", type=int, default=1000)
    p.add_argument("--workers", type=int, default=128)
    p.add_argument("--utilization", type=float, default=0.15)
    p.add_argument("--churn-period", type=float, default=20000.0,
                   help="sim time between churn events")
    p.add_argument("--event-batch", type=int, default=256)
    p.add_argument("--subset-tasks", type=int, default=0,
                   help="task count of the comparison runs "
                        "(0 = tasks/10 clamped to [1e4, 1e5])")
    p.add_argument("--repeats", type=int, default=3,
                   help="loop-comparison repetitions (median wall)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", dest="json_path", default=None)
    args = p.parse_args(argv)
    run_bench(tasks=args.tasks, masters=args.masters, workers=args.workers,
              utilization=args.utilization, churn_period=args.churn_period,
              event_batch=args.event_batch, subset_tasks=args.subset_tasks,
              repeats=args.repeats, seed=args.seed,
              json_path=args.json_path)
    return 0


if __name__ == "__main__":
    main()
