"""Paper Fig. 7 & 8 — EC2-measured delay distributions and the 4-master /
50-worker evaluation on them.

Fig. 7: we regenerate 'measured' samples from the paper's fitted t2.micro /
c5.large shifted exponentials, then re-fit with our estimator — round-trip
parameter recovery validates the fitting path.  Fig. 8: 40 t2.micro + 10
c5.large workers, computation-delay dominant; paper reports up to 82% / 30%
delay reduction vs uncoded / coded.
"""
from __future__ import annotations

import numpy as np

from repro.core import (coded_uniform, fractional_greedy, iterated_greedy,
                        plan_from_assignment, sca_enhance_plan,
                        uncoded_uniform)
from repro.sim import simulate_plan
from repro.sim.cluster import (EC2_C5_LARGE, EC2_T2_MICRO, ec2_cluster,
                               fit_shifted_exponential,
                               sample_shifted_exponential)

from .common import TRIALS, emit, save_rows, timed


def run_fig7(n: int = 200_000, seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []
    errs = []
    for name, spec in (("t2.micro", EC2_T2_MICRO), ("c5.large", EC2_C5_LARGE)):
        samples = sample_shifted_exponential(rng, n, spec["a"], spec["u"])
        (a_hat, u_hat), t_us = timed(fit_shifted_exponential, samples)
        rows.append((name, spec["a"], round(a_hat, 4), spec["u"],
                     round(u_hat, 4)))
        errs.append(abs(a_hat - spec["a"]) / spec["a"])
        errs.append(abs(u_hat - spec["u"]) / spec["u"])
    save_rows("fig7_ec2_fit.csv", "instance,a_true,a_fit,u_true,u_fit", rows)
    emit("fig7/ec2_fit", t_us, f"max_param_err={max(errs):.3%}")


def run_fig8(trials: int = TRIALS, seed: int = 0):
    profile = ec2_cluster(N=50, n_fast=10, rng=seed)
    sc = profile.scenario(M=4, L=1e4)

    def build():
        k_it = iterated_greedy(sc, mode="comp_exact", rng=seed)
        k_s = None
        from repro.core import simple_greedy
        k_s = simple_greedy(sc, mode="comp_exact")
        dedi_it = plan_from_assignment(sc, k_it, mode="comp_exact",
                                       method="dedi-iter")
        dedi_s = plan_from_assignment(sc, k_s, mode="comp_exact",
                                      method="dedi-simple")
        frac = fractional_greedy(sc, init=k_it, loads="comp_exact")
        return {"uncoded": uncoded_uniform(sc), "coded": coded_uniform(sc),
                "dedi-simple": dedi_s, "dedi-iter": dedi_it, "frac": frac}

    plans, t_us = timed(build)
    means, means_m, rows = {}, {}, []
    for name, plan in plans.items():
        # fitted-distribution world (planning model == simulation model)
        r = simulate_plan(sc, plan, trials=trials, rng=seed + 1)
        # measured-like world: burstable instances throttle ~5% of tasks ×8
        # (the heavy tail the paper's measured traces contain and the fitted
        # shifted exponential misses — see sim.montecarlo docstring)
        rm = simulate_plan(sc, plan, trials=trials, rng=seed + 1,
                           straggle_p=0.05, straggle_factor=8.0)
        means[name], means_m[name] = r.overall_mean, rm.overall_mean
        rows.append((name, round(r.overall_mean, 3), round(rm.overall_mean, 3)))
    save_rows("fig8_ec2_eval.csv", "method,fitted_mc_ms,measured_like_mc_ms",
              rows)
    best = min(means["dedi-iter"], means["frac"])
    best_m = min(means_m["dedi-iter"], means_m["frac"])
    emit("fig8/ec2_eval", t_us,
         f"vs_uncoded={1 - best / means['uncoded']:.1%};"
         f"vs_coded={1 - best / means['coded']:.1%};"
         f"measured_vs_uncoded={1 - best_m / means_m['uncoded']:.1%};"
         f"measured_vs_coded={1 - best_m / means_m['coded']:.1%};"
         f"iter_beats_simple={means['dedi-iter'] <= means['dedi-simple'] * 1.02}")
    return means


def main():
    run_fig7()
    run_fig8()


if __name__ == "__main__":
    main()
