# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (harness contract) and writes the underlying data to
# results/figures/*.csv.
#
#   fig2_3 — Markov-approximation validation (paper Fig. 2 & 3)
#   fig4   — algorithm-vs-benchmark delays (paper Fig. 4)
#   fig5   — completion-delay CDF / rho_s tail (paper Fig. 5)
#   fig6   — communication-rate sweep (paper Fig. 6)
#   fig7_8 — EC2 fits + evaluation (paper Fig. 7 & 8)
#   extras — coded executor / kernels / coded-grads (beyond paper)
#   backend — numpy/jax/pallas throughput record (BENCH_backend.json)
#
# Env knobs: REPRO_TRIALS (Monte-Carlo trials, default 60000; the paper used
# 1e6 — same seeds, just more samples), REPRO_RESULTS (output dir).
# The fig scripts also run standalone with --backend/--trials flags
# (`python -m benchmarks.fig4_delay --backend jax --trials 1000000`).
from __future__ import annotations


def main() -> None:
    print("name,us_per_call,derived")
    from . import (ablation_redundancy, backend_bench, coded_exec_bench,
                   fig2_3_markov, fig4_delay, fig5_cdf, fig6_commrate,
                   fig7_8_ec2)
    fig2_3_markov.main([])
    fig4_delay.main([])
    fig5_cdf.main([])
    fig6_commrate.main([])
    fig7_8_ec2.main()
    coded_exec_bench.main([])
    ablation_redundancy.main()
    backend_bench.main([])


if __name__ == "__main__":
    main()
