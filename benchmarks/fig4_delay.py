"""Paper Fig. 4 — average task completion delay of all algorithms vs the
uncoded / coded benchmarks, small (2×5) and large (4×50) scenarios, γ = 2u.

Paper claims validated here:
  small: SCA-enhanced dedicated ≈ −8.85%, SCA fractional ≈ −17.1% vs their
         plain versions; SCA-fractional ≈ brute-force optimal;
  large: iterated ≥ simple greedy; fractional ≈ iterated; SCA ≥ 4.4% more;
         up to ~79% vs uncoded and ~30% vs coded.
"""
from __future__ import annotations

import numpy as np

from repro.core import (coded_uniform, fractional_greedy, iterated_greedy,
                        near_optimal_fractional, plan_from_assignment,
                        sca_enhance_plan, simple_greedy, small_scale_scenario,
                        large_scale_scenario, uncoded_uniform)
from repro.sim import simulate_plan

from .common import TRIALS, bench_parser, emit, save_rows, timed


def build_plans(sc, *, include_bruteforce: bool, rng=0):
    plans = {}
    plans["uncoded"] = uncoded_uniform(sc)
    plans["coded"] = coded_uniform(sc)
    k_it = iterated_greedy(sc, rng=rng)
    plans["dedi-simple"] = plan_from_assignment(sc, simple_greedy(sc),
                                                method="dedi-simple")
    plans["dedi-iter"] = plan_from_assignment(sc, k_it, method="dedi-iter")
    plans["frac"] = fractional_greedy(sc, init=k_it)
    plans["dedi-iter-sca"] = sca_enhance_plan(sc, plans["dedi-iter"])
    plans["frac-sca"] = sca_enhance_plan(sc, plans["frac"])
    if include_bruteforce:
        bf = near_optimal_fractional(sc, restarts=4, rng=rng)
        plans["bruteforce"] = sca_enhance_plan(sc, bf)
    return plans


def run(scale: str = "small", trials: int = TRIALS, seed: int = 0,
        backend: str = "numpy"):
    sc = small_scale_scenario(seed) if scale == "small" \
        else large_scale_scenario(seed)
    plans, t_us = timed(build_plans, sc,
                        include_bruteforce=(scale == "small"))
    means = {}
    rows = []
    for name, plan in plans.items():
        r = simulate_plan(sc, plan, trials=trials, rng=seed + 1,
                          backend=backend)
        means[name] = r.overall_mean
        rows.append((name, round(r.overall_mean, 2), round(plan.t, 2)))
    save_rows(f"fig4_delay_{scale}.csv", "method,mc_mean_ms,predicted_ms",
              rows)

    sca_gain_d = 1 - means["dedi-iter-sca"] / means["dedi-iter"]
    sca_gain_f = 1 - means["frac-sca"] / means["frac"]
    vs_unc = 1 - means["dedi-iter-sca"] / means["uncoded"]
    vs_cod = 1 - means["dedi-iter-sca"] / means["coded"]
    derived = (f"sca_dedi={sca_gain_d:.1%};sca_frac={sca_gain_f:.1%};"
               f"vs_uncoded={vs_unc:.1%};vs_coded={vs_cod:.1%}")
    if "bruteforce" in means:
        derived += f";fracSCA_vs_opt={means['frac-sca']/means['bruteforce']-1:+.2%}"
    emit(f"fig4/delay_{scale}", t_us, derived)
    return means


def main(argv=None):
    args = bench_parser(__doc__).parse_args(argv)
    for scale in ("small", "large") if args.scale == "all" else (args.scale,):
        run(scale, trials=args.trials, backend=args.backend)


if __name__ == "__main__":
    main()
