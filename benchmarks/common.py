"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import os
import time

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/figures")
TRIALS = int(os.environ.get("REPRO_TRIALS", "60000"))


def emit(name: str, us_per_call: float, derived: str):
    """CSV row per the harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def save_rows(fname: str, header: str, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, fname)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
