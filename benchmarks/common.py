"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/figures")
TRIALS = int(os.environ.get("REPRO_TRIALS", "60000"))


def bench_parser(description: str, *, scales=("small", "large"),
                 default_trials: int | None = None) -> argparse.ArgumentParser:
    """Common CLI for the figure benchmarks: Monte-Carlo backend selection
    (``--backend jax`` = the jitted device-resident ``simulate_batch`` path,
    ~10x throughput at 1e5+ trials on CPU, more on accelerators), trial
    count, and scenario scale."""
    p = argparse.ArgumentParser(description=description)
    p.add_argument("--backend", default="numpy", choices=("numpy", "jax"),
                   help="Monte-Carlo backend (default numpy; jax is the "
                        "jitted large-trial path)")
    p.add_argument("--trials", type=int,
                   default=default_trials if default_trials else TRIALS,
                   help="Monte-Carlo realizations per plan")
    if scales:
        p.add_argument("--scale", default="all", choices=scales + ("all",),
                       help="which paper scenario(s) to run")
    return p


def emit(name: str, us_per_call: float, derived: str):
    """CSV row per the harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


def save_rows(fname: str, header: str, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, fname)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
