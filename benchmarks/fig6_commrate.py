"""Paper Fig. 6 — impact of the communication rate γ/u on (a) the average
completion delay and (b) the local-processing load share l_{m,0}/Σl.

Paper claims validated: delay decreases monotonically in γ/u for the
proposed algorithms and stays above the benchmarks' at every ratio; the
local share *decreases* as comms get faster (benchmarks are flat by
construction).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (coded_uniform, fractional_greedy, iterated_greedy,
                        plan_from_assignment, uncoded_uniform,
                        large_scale_scenario)
from repro.sim import simulate_plan

from .common import TRIALS, bench_parser, emit, save_rows, timed


RATIOS = (0.5, 1.0, 2.0, 4.0, 8.0)


def run(trials: int = TRIALS // 2, seed: int = 0, backend: str = "numpy"):
    base = large_scale_scenario(seed)
    rows = []
    mono_ok = True
    last = None

    def sweep():
        nonlocal mono_ok, last
        for ratio in RATIOS:
            sc = dataclasses.replace(base, gamma=ratio * base.u)
            k_it = iterated_greedy(sc, rng=seed)
            plans = {
                "uncoded": uncoded_uniform(sc),
                "coded": coded_uniform(sc),
                "dedi-iter": plan_from_assignment(sc, k_it, method="dedi-iter"),
                "frac": fractional_greedy(sc, init=k_it),
            }
            for name, plan in plans.items():
                r = simulate_plan(sc, plan, trials=trials, rng=seed + 1,
                                  backend=backend)
                share = float(np.mean(plan.l[:, 0] / plan.l.sum(axis=1)))
                rows.append((ratio, name, round(r.overall_mean, 2),
                             round(share, 4)))
                if name == "dedi-iter":
                    if last is not None and r.overall_mean > last * 1.02:
                        mono_ok = False
                    last = r.overall_mean

    _, t_us = timed(sweep)
    save_rows("fig6_commrate.csv", "gamma_over_u,method,mc_mean_ms,local_share",
              rows)
    shares = [r[3] for r in rows if r[1] == "dedi-iter"]
    emit("fig6/commrate", t_us,
         f"delay_monotone_decreasing={mono_ok};"
         f"local_share_{RATIOS[0]}x={shares[0]:.3f};"
         f"local_share_{RATIOS[-1]}x={shares[-1]:.3f};"
         f"share_decreasing={shares[-1] < shares[0]}")


def main(argv=None):
    args = bench_parser(__doc__, scales=(),
                        default_trials=TRIALS // 2).parse_args(argv)
    run(trials=args.trials, backend=args.backend)


if __name__ == "__main__":
    main()
