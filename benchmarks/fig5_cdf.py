"""Paper Fig. 5 — CDF / tail of the task completion delay (solving P1 via
P2's solution).  Reports the ρ_s = 0.95 quantile per method; the paper reads
0.658 / 0.694 / 0.957 s for SCA-dedicated / dedicated / coded in Fig. 5(b)
(≈30% tail reduction vs the coded benchmark), which we validate in ratio.
"""
from __future__ import annotations

import numpy as np

from repro.core import (coded_uniform, iterated_greedy, plan_from_assignment,
                        sca_enhance_plan, small_scale_scenario,
                        large_scale_scenario, uncoded_uniform)
from repro.sim import simulate_plan

from .common import TRIALS, bench_parser, emit, save_rows, timed


def run(scale: str = "large", trials: int = TRIALS, seed: int = 0,
        rho: float = 0.95, backend: str = "numpy"):
    sc = small_scale_scenario(seed) if scale == "small" \
        else large_scale_scenario(seed)

    def build():
        k_it = iterated_greedy(sc, rng=seed)
        dedi = plan_from_assignment(sc, k_it, method="dedi-iter")
        return {"uncoded": uncoded_uniform(sc), "coded": coded_uniform(sc),
                "dedi-iter": dedi, "dedi-iter-sca": sca_enhance_plan(sc, dedi)}

    plans, t_us = timed(build)
    rows, q = [], {}
    for name, plan in plans.items():
        r = simulate_plan(sc, plan, trials=trials, rng=seed + 1,
                          keep_samples=True, backend=backend)
        q[name] = r.quantile(rho)
        # coarse CDF grid for the figure
        ts = np.quantile(r.overall_samples, np.linspace(0.01, 0.999, 25))
        for t_, p_ in zip(ts, np.linspace(0.01, 0.999, 25)):
            rows.append((name, round(float(t_), 2), round(float(p_), 4)))
    save_rows(f"fig5_cdf_{scale}.csv", "method,delay_ms,cdf", rows)

    tail_red = 1 - q["dedi-iter-sca"] / q["coded"]
    emit(f"fig5/cdf_{scale}", t_us,
         f"q95_sca={q['dedi-iter-sca']:.0f}ms;q95_dedi={q['dedi-iter']:.0f}ms;"
         f"q95_coded={q['coded']:.0f}ms;tail_reduction_vs_coded={tail_red:.1%}")
    return q


def main(argv=None):
    args = bench_parser(__doc__).parse_args(argv)
    for scale in ("large", "small") if args.scale == "all" else (args.scale,):
        run(scale, trials=args.trials, backend=args.backend)


if __name__ == "__main__":
    main()
