"""Bench regression gate: fail CI when a freshly produced bench JSON
regresses more than ``--factor`` against the committed copy.

Compares higher-is-better metrics (dotted paths into the JSON), e.g.:

    python -m benchmarks.check_regression \
        --baseline BENCH_stream.json --fresh fresh_BENCH_stream.json \
        --key throughput_tasks_per_s --factor 2.0

    python -m benchmarks.check_regression \
        --baseline BENCH_backend.json --fresh fresh_BENCH_backend.json \
        --key montecarlo.numpy_trials_per_s --key decode.fast_path_speedup

Exit code 1 (with a table) if any fresh value falls below
``baseline / factor``.  CI runners are slower than the dev machines that
committed the baselines, which is exactly why the gate is a *ratio*: a
genuine 2x throughput regression trips it, runner-to-runner noise does
not.  ``REPRO_REGRESSION_FACTOR`` overrides the factor without a workflow
edit.

``--min KEY=VALUE`` adds an *absolute floor* on a fresh metric —
machine-independent ratios recorded inside one bench JSON (e.g.
``BENCH_serve.json``'s ``trunk_wall_vs_head``: trunk and head wall
throughput come from the same process on the same runner, so their ratio
must hold anywhere) are gated against a constant instead of the committed
copy:

    python -m benchmarks.check_regression \
        --baseline BENCH_serve.json --fresh fresh_BENCH_serve.json \
        --key scopes.trunk.batched.tokens_per_wall_second \
        --min trunk_wall_vs_head=0.4 \
        --min batched_wall_speedup.trunk=1.0

``--max KEY=VALUE`` is the mirror: an *absolute ceiling* on a fresh
lower-is-better metric (again machine-independent, again no baseline
comparison) — e.g. the virtual/materialised encoded-cache byte ratio,
which the virtual-parity mode must keep at or below 0.55 at redundancy 2:

    python -m benchmarks.check_regression \
        --baseline BENCH_backend.json --fresh fresh_BENCH_backend.json \
        --min generated_parity.generated_vs_materialized=0.8 \
        --max generated_parity.encoded_bytes_ratio=0.55
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def get_path(record: dict, dotted: str):
    cur = record
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(f"key {dotted!r} not found (missing {part!r})")
        cur = cur[part]
    return float(cur)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline", required=True,
                   help="committed bench JSON (the reference)")
    p.add_argument("--fresh", required=True,
                   help="freshly produced bench JSON")
    p.add_argument("--key", action="append", default=[], dest="keys",
                   help="dotted path to a higher-is-better metric "
                        "(repeatable)")
    p.add_argument("--min", action="append", default=[], dest="mins",
                   metavar="KEY=VALUE",
                   help="absolute floor on a fresh metric (dotted path "
                        "= number; repeatable; no baseline comparison)")
    p.add_argument("--max", action="append", default=[], dest="maxs",
                   metavar="KEY=VALUE",
                   help="absolute ceiling on a fresh lower-is-better "
                        "metric (dotted path = number; repeatable; no "
                        "baseline comparison)")
    p.add_argument("--factor", type=float,
                   default=float(os.environ.get("REPRO_REGRESSION_FACTOR",
                                                "2.0")),
                   help="maximum tolerated slowdown ratio (default 2.0)")
    args = p.parse_args(argv)
    if not args.keys and not args.mins and not args.maxs:
        p.error("need at least one --key, --min or --max")

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    failed = False
    print(f"{'metric':<44} {'baseline':>12} {'fresh':>12} {'ratio':>7}  gate")
    for key in args.keys:
        b, fval = get_path(base, key), get_path(fresh, key)
        ratio = fval / b if b > 0 else float("inf")
        ok = fval >= b / args.factor
        failed |= not ok
        print(f"{key:<44} {b:12.2f} {fval:12.2f} {ratio:7.2f}  "
              f"{'ok' if ok else f'REGRESSION >{args.factor}x'}")
    for spec in args.mins:
        key, _, floor_s = spec.partition("=")
        if not floor_s:
            p.error(f"--min needs KEY=VALUE, got {spec!r}")
        floor = float(floor_s)
        fval = get_path(fresh, key)
        ok = fval >= floor
        failed |= not ok
        print(f"{key:<44} {floor:>12.2f} {fval:12.2f} {'':>7}  "
              f"{'ok' if ok else 'BELOW FLOOR'}")
    for spec in args.maxs:
        key, _, ceil_s = spec.partition("=")
        if not ceil_s:
            p.error(f"--max needs KEY=VALUE, got {spec!r}")
        ceiling = float(ceil_s)
        fval = get_path(fresh, key)
        ok = fval <= ceiling
        failed |= not ok
        print(f"{key:<44} {ceiling:>12.2f} {fval:12.2f} {'':>7}  "
              f"{'ok' if ok else 'ABOVE CEILING'}")
    if failed:
        print(f"[check_regression] FAILED: fresh metrics regressed more "
              f"than {args.factor}x vs {args.baseline}, fell below a "
              f"--min floor or exceeded a --max ceiling", file=sys.stderr)
        return 1
    print(f"[check_regression] ok (factor {args.factor}x, "
          f"{len(args.keys)} ratio + {len(args.mins)} floor + "
          f"{len(args.maxs)} ceiling metrics)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
