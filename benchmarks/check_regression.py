"""Bench regression gate: fail CI when a freshly produced bench JSON
regresses more than ``--factor`` against the committed copy.

Compares higher-is-better metrics (dotted paths into the JSON), e.g.:

    python -m benchmarks.check_regression \
        --baseline BENCH_stream.json --fresh fresh_BENCH_stream.json \
        --key throughput_tasks_per_s --factor 2.0

    python -m benchmarks.check_regression \
        --baseline BENCH_backend.json --fresh fresh_BENCH_backend.json \
        --key montecarlo.numpy_trials_per_s --key decode.fast_path_speedup

Exit code 1 (with a table) if any fresh value falls below
``baseline / factor``.  CI runners are slower than the dev machines that
committed the baselines, which is exactly why the gate is a *ratio*: a
genuine 2x throughput regression trips it, runner-to-runner noise does
not.  ``REPRO_REGRESSION_FACTOR`` overrides the factor without a workflow
edit.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def get_path(record: dict, dotted: str):
    cur = record
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(f"key {dotted!r} not found (missing {part!r})")
        cur = cur[part]
    return float(cur)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline", required=True,
                   help="committed bench JSON (the reference)")
    p.add_argument("--fresh", required=True,
                   help="freshly produced bench JSON")
    p.add_argument("--key", action="append", required=True, dest="keys",
                   help="dotted path to a higher-is-better metric "
                        "(repeatable)")
    p.add_argument("--factor", type=float,
                   default=float(os.environ.get("REPRO_REGRESSION_FACTOR",
                                                "2.0")),
                   help="maximum tolerated slowdown ratio (default 2.0)")
    args = p.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    failed = False
    print(f"{'metric':<40} {'baseline':>12} {'fresh':>12} {'ratio':>7}  gate")
    for key in args.keys:
        b, fval = get_path(base, key), get_path(fresh, key)
        ratio = fval / b if b > 0 else float("inf")
        ok = fval >= b / args.factor
        failed |= not ok
        print(f"{key:<40} {b:12.2f} {fval:12.2f} {ratio:7.2f}  "
              f"{'ok' if ok else f'REGRESSION >{args.factor}x'}")
    if failed:
        print(f"[check_regression] FAILED: fresh metrics regressed more "
              f"than {args.factor}x vs {args.baseline}", file=sys.stderr)
        return 1
    print(f"[check_regression] ok (factor {args.factor}x, "
          f"{len(args.keys)} metrics)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
