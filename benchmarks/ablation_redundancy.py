"""Beyond-paper ablation: coding-redundancy ratio vs completion-delay tail.

Theorem 1 fixes redundancy at 2× (the Markov optimum).  On TPU the encode
redundancy is MXU compute (DESIGN.md §2), so the right operating point
trades encode FLOPs against the straggler tail.  We rescale the Thm-1 loads
by ρ ∈ [1.05, 3] (keeping proportions ∝ 1/θ) and report mean / p95 / p99
completion and the encode-FLOPs multiplier — under the fitted law and under
the heavy-tail (measured-like) world where redundancy matters most.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (Plan, iterated_greedy, plan_from_assignment,
                        large_scale_scenario)
from repro.sim import simulate_plan

from .common import TRIALS, emit, save_rows, timed

RHOS = (1.05, 1.25, 1.5, 2.0, 2.5, 3.0)


def run(trials: int = TRIALS // 3, seed: int = 0):
    sc = large_scale_scenario(seed)
    base = plan_from_assignment(sc, iterated_greedy(sc, rng=seed))
    rows = []

    def sweep():
        out = {}
        for rho in RHOS:
            l = base.l / base.l.sum(axis=1, keepdims=True) * (rho * sc.L[:, None])
            plan = Plan(k=base.k, b=base.b, l=l,
                        t_per_master=base.t_per_master,
                        method=f"thm1-rho{rho}")
            for world, kw in (("fitted", {}),
                              ("heavy", dict(straggle_p=0.05,
                                             straggle_factor=8.0))):
                r = simulate_plan(sc, plan, trials=trials, rng=seed + 1,
                                  keep_samples=True, **kw)
                rows.append((rho, world, round(r.overall_mean, 1),
                             round(r.quantile(0.95), 1),
                             round(r.quantile(0.99), 1)))
                out[(rho, world)] = r.overall_mean
        return out

    out, t_us = timed(sweep)
    save_rows("ablation_redundancy.csv",
              "rho,world,mean_ms,p95_ms,p99_ms", rows)
    best_fit = min(RHOS, key=lambda r: out[(r, "fitted")])
    best_heavy = min(RHOS, key=lambda r: out[(r, "heavy")])
    emit("ablation/redundancy", t_us,
         f"best_rho_fitted={best_fit};best_rho_heavytail={best_heavy};"
         f"mean_at_2x_fitted={out[(2.0, 'fitted')]:.0f}ms;"
         f"mean_at_2x_heavy={out[(2.0, 'heavy')]:.0f}ms")


def main():
    run()


if __name__ == "__main__":
    main()
