"""Coded serving benchmark: admission policies vs the FIFO baseline, and
coding scopes × shard-execution engines vs head-only.

Serves one seeded contended workload (more requests than batch slots,
mixed tight/loose deadlines, mid-run churn) through the coded serving
bridge under each admission policy and records tokens/s (simulation and
wall clock), p50/p99 request sojourn and the deadline-miss rate into
``BENCH_serve.json`` (env knob ``REPRO_BENCH_SERVE_JSON``), with the
EDF/fair numbers expressed relative to FIFO.

A second sweep serves the same workload once per ``coding_scope``
(head | ffn | trunk, default pool, EDF) × ``execution`` engine
(``serial`` shard-by-shard reference | ``batched`` packed step-barrier
passes).  Each cell reports two wall-clock numbers:

* ``tokens_per_wall_second`` — the *serving configuration* (``verify``
  off: no reference matmuls ride along; distributing the products is the
  point), best of ``--reps`` runs to damp CI-runner noise;
* a verification pass (``verify`` on, same workload) contributing
  ``decode_max_err`` / ``argmax_match_rate`` and asserting every decoded
  matmul matched the uncoded product bit-for-bit at the greedy argmax.

Headline ratios: ``trunk_wall_vs_head`` (batched trunk wall throughput
over batched head — the "Wall-clock shard execution" gap this records),
``batched_wall_speedup`` per scope (batched over serial), and the
sim-time ``trunk_throughput_vs_head``.

A final traced pass on the trunk/batched cell records the per-stage wall
breakdown (plan | pack | kernel | decode | glue), the straggler
attribution table and the disabled-tracer throughput ratio into the
JSON's ``trace`` section (``--trace out.json`` additionally writes the
Chrome/Perfetto trace itself).

A seeded chaos pass (``--faults SPEC``) then serves the same workload on
the trunk/batched cell under fault injection and records the ``faults``
section: corruption detection / localisation rates, quarantine and
readmission counts, the decode-mode histogram, the chaos token-match
rate against the clean serve (asserted 1.0 unless steps explicitly
degraded), plus the fault-free-schedule and LS-tail token-identity
checks CI floors at 1.0.

    PYTHONPATH=src python -m benchmarks.serve_bench \
        [--requests 24] [--gen-len 8] [--slots 2] [--rate 0.02] \
        [--backend numpy] [--steps-per-dispatch 1] [--reps 3] [--seed 0] \
        [--trace out.json] [--faults corrupt=0.25,kind=sign_flip,...]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.serve_coded import (CODING_SCOPES, EXECUTION_MODES,
                               CodedServingBridge, serve_policy_sweep,
                               synthetic_requests)
from repro.stream import AdmissionConfig, StreamConfig, WorkerEvent

from .common import emit

POLICIES = ("fifo", "edf", "fair")


def _report_row(rep) -> dict:
    s = rep.summary()
    return {
        "tokens_per_sim_second": round(s["tokens_per_sim_second"], 2),
        "tokens_per_wall_second": round(s["tokens_per_wall_second"], 1),
        "p50_sojourn_ms": round(s.get("sojourn_p50", float("nan")), 1),
        "p99_sojourn_ms": round(s.get("sojourn_p99", float("nan")), 1),
        "deadline_miss_rate": round(s.get("deadline_miss_rate", 0.0), 4),
        "coded_steps": int(s["coded_steps"]),
        "solve_steps": int(s["solve_steps"]),
        "decode_max_err": rep.max_err,
        "wall_seconds": round(rep.wall_seconds, 3),
    }


def _default_churn():
    return [WorkerEvent(400.0, 2, "degrade", 4.0),
            WorkerEvent(1500.0, 5, "leave"),
            WorkerEvent(6000.0, 5, "join"),
            WorkerEvent(8000.0, 2, "restore")]


def run_serve_bench(requests: int = 24, gen_len: int = 8, masters: int = 2,
                    slots: int = 2, rate: float = 0.02, prompt_len: int = 16,
                    backend: str = "numpy", steps_per_dispatch: int = 1,
                    reps: int = 3, seed: int = 0,
                    trace: str | None = None,
                    faults: str = "corrupt=0.25,kind=sign_flip,crash=0.05,"
                                  "retries=4,seed=5",
                    json_path: str | None = None) -> dict:
    churn = _default_churn()
    per_policy = {}
    bridge = CodedServingBridge(masters=masters, backend=backend,
                                config=StreamConfig(rng=seed),
                                slots_per_master=slots,
                                steps_per_dispatch=steps_per_dispatch)
    bridge._setup_model(prompt_len + gen_len + 8)
    reqs = synthetic_requests(
        requests, masters=masters, vocab=bridge._model["cfg"].vocab,
        prompt_len=prompt_len, gen_len=gen_len, rate=rate, seed=seed)
    # discarded warmup rep: the first serve of the process pays jit
    # compilation, lazy parity encodes and allocator warmup — without it
    # the first timed cell (historically fifo) absorbed all of that and
    # the cross-policy wall ratios were skewed against it
    bridge.serve(reqs, churn=churn)
    reports = serve_policy_sweep(bridge, reqs, POLICIES, churn=churn)
    for policy, rep in reports.items():
        per_policy[policy] = _report_row(rep)

    # scope × execution sweep: same workload, same pool, EDF.  The wall
    # numbers come from the serving configuration (verify off — the
    # reference matmuls exist only for CI assertions); a separate
    # verified run per cell contributes decode_max_err and the argmax
    # assertion, so the JSON carries both honesty and throughput.
    per_scope: dict = {}
    cells = [(scope, execution) for scope in CODING_SCOPES
             for execution in EXECUTION_MODES]
    timers = {}
    for scope, execution in cells:
        vbridge = CodedServingBridge(
            masters=masters, backend=backend,
            config=StreamConfig(admission=AdmissionConfig(policy="edf"),
                                rng=seed),
            slots_per_master=slots, coding_scope=scope,
            steps_per_dispatch=steps_per_dispatch, execution=execution)
        vbridge._setup_model(prompt_len + gen_len + 8)
        vrep = vbridge.serve(reqs, churn=churn)
        assert vrep.decode_ok, (scope, execution, vrep.max_err)
        row = _report_row(vrep)
        row["verified_tokens_per_wall_second"] = \
            row.pop("tokens_per_wall_second")
        row["verified_wall_seconds"] = row.pop("wall_seconds")
        row["execution"] = execution
        row["decode_backend"] = vrep.decode_backend
        row["tasks_per_step"] = \
            int(vrep.steps[0]["n_tasks"]) if vrep.steps else 0
        per_scope.setdefault(scope, {})[execution] = row
        tbridge = CodedServingBridge(
            masters=masters, backend=backend,
            config=StreamConfig(admission=AdmissionConfig(policy="edf"),
                                rng=seed),
            slots_per_master=slots, coding_scope=scope,
            steps_per_dispatch=steps_per_dispatch, execution=execution,
            verify=False)
        tbridge._setup_model(prompt_len + gen_len + 8)
        trep = tbridge.serve(reqs, churn=churn)       # warm the engine
        assert trep.tokens == vrep.tokens    # engines + verify agree
        timers[(scope, execution)] = tbridge
        if (scope, execution) == ("trunk", "batched"):
            clean_tokens = {r: list(t) for r, t in vrep.tokens.items()}
    # serving-configuration timing, reps round-robined across the cells
    # so a noise burst on a shared CI runner degrades every cell alike —
    # the cross-scope wall ratios stay comparable even when absolute
    # throughput wobbles
    for _ in range(max(reps, 1)):
        for cell, tbridge in timers.items():
            trep = tbridge.serve(reqs, churn=churn)
            tps = trep.summary()["tokens_per_wall_second"]
            row = per_scope[cell[0]][cell[1]]
            if tps > row.get("tokens_per_wall_second", 0.0):
                row["tokens_per_wall_second"] = round(tps, 1)
                row["wall_seconds"] = round(trep.wall_seconds, 3)

    # observability: one traced pass on the trunk/batched serving cell
    # yields the per-stage wall breakdown (plan vs pack vs kernel vs
    # decode vs glue) and the straggler attribution table; paired
    # best-of-reps rounds — a *disabled* tracer attached vs no tracer,
    # interleaved so both sides see the same machine conditions — then
    # time the contract that disabled tracing serves on the identical
    # code path.  CI floors the ratio at 0.98 (< 2% disabled-mode
    # overhead); comparing against the earlier timing loop instead would
    # fold half the bench's worth of runner drift into the ratio.
    from repro.obs import Tracer
    json_out = json_path or os.environ.get("REPRO_BENCH_SERVE_JSON",
                                           "BENCH_serve.json")
    # the traced pass always runs — always write its artifact too, so the
    # JSON's trace.trace_path points at a real file instead of null
    # whenever --trace wasn't given
    if trace is None:
        trace = os.path.splitext(json_out)[0] + "_trace.json"
    tbridge = timers[("trunk", "batched")]
    tbridge.tracer = tracer = Tracer(meta={"bench": "coded_serving",
                                           "scope": "trunk",
                                           "execution": "batched"})
    traced_rep = tbridge.serve(reqs, churn=churn, trace_path=trace)
    ts = tracer.summary()
    best_disabled = off_best = 0.0
    for _ in range(max(reps, 1)):
        tbridge.tracer = Tracer(enabled=False)
        r = tbridge.serve(reqs, churn=churn)
        best_disabled = max(best_disabled,
                            r.summary()["tokens_per_wall_second"])
        tbridge.tracer = None
        r = tbridge.serve(reqs, churn=churn)
        off_best = max(off_best, r.summary()["tokens_per_wall_second"])
    cache_hits = ts["counters"].get("plan_cache_hits", 0.0)
    cache_misses = ts["counters"].get("plan_cache_misses", 0.0)
    trace_row = {
        "scope": "trunk", "execution": "batched",
        "per_stage_wall": {k: round(v, 6)
                           for k, v in ts["per_stage_wall"].items()},
        # steady-state step plans come from the StepPlanCache; misses only
        # on cold start and after churn/replan invalidations, so the rate
        # is a direct gauge of whether caching is actually engaged
        "plan_cache_hit_rate": round(
            cache_hits / max(cache_hits + cache_misses, 1.0), 4),
        "stage_coverage": None if ts["stage_coverage"] is None
        else round(ts["stage_coverage"], 4),
        "counters": {k: round(v, 1) for k, v in ts["counters"].items()},
        "stragglers": ts["stragglers"],
        "traced_tokens_per_wall_second": round(
            traced_rep.summary()["tokens_per_wall_second"], 1),
        "disabled_tracer_tokens_per_wall_second": round(best_disabled, 1),
        "tracing_off_throughput_ratio": round(
            best_disabled / max(off_best, 1e-12), 3),
        "trace_path": trace,
    }

    # chaos pass: a seeded fault schedule on the trunk/batched cell must
    # detect every applied corruption, quarantine the culprits and decode
    # back to the fault-free token stream (or explicitly degrade — never
    # silently wrong).  Three sub-checks feed the JSON's ``faults``
    # section: the chaos serve itself, the fault-free-schedule identity
    # (zero rates, detection armed) and the LS-tail decode parity.
    from repro.faults import FaultConfig, parse_fault_spec

    def _fault_bridge(**kw):
        fb = CodedServingBridge(
            masters=masters, backend=backend,
            config=StreamConfig(admission=AdmissionConfig(policy="edf"),
                                rng=seed),
            slots_per_master=slots, coding_scope="trunk",
            steps_per_dispatch=steps_per_dispatch, execution="batched",
            **kw)
        fb._setup_model(prompt_len + gen_len + 8)
        return fb

    def _tokens_match(rep) -> float:
        got = {r: list(t) for r, t in rep.tokens.items()}
        n = max(len(clean_tokens), 1)
        return sum(1 for r, t in clean_tokens.items()
                   if got.get(r) == t) / n

    frep = _fault_bridge(faults=parse_fault_spec(faults)).serve(
        reqs, churn=churn)
    fstat = frep.faults or {}
    fmodes = frep.decode_modes or {}
    degraded = int(fmodes.get("degraded", 0))
    chaos_match = _tokens_match(frep)
    # never silently wrong: every token either matches the clean serve or
    # came from a step explicitly reported as degraded
    assert chaos_match == 1.0 or degraded > 0, (chaos_match, fmodes)
    zrep = _fault_bridge(faults=FaultConfig(seed=seed)).serve(
        reqs, churn=churn)
    lrep = _fault_bridge(ls_tail=True).serve(reqs, churn=churn)
    faults_row = {
        "spec": faults,
        "scope": "trunk", "execution": "batched",
        "fault_free_token_identity": _tokens_match(zrep),
        "ls_tail_token_identity": _tokens_match(lrep),
        "token_match_rate": round(chaos_match, 4),
        "detection_rate": round(fstat.get("detection_rate", 1.0), 4),
        "localization_rate": round(fstat.get("localization_rate", 1.0), 4),
        "injected": int(fstat.get("injected", 0)),
        "corrupt_applied": int(fstat.get("corrupt_applied", 0)),
        "quarantines": int(fstat.get("quarantines", 0)),
        "readmissions": int(fstat.get("readmissions", 0)),
        "retries": int(fstat.get("retries", 0)),
        "rows_rejected": int(fstat.get("rows_rejected", 0)),
        "false_flags": int(fstat.get("false_flags", 0)),
        "degraded_steps": degraded,
        "decode_modes": fmodes,
    }

    base = per_policy["fifo"]
    head_b = per_scope["head"]["batched"]
    trunk_b = per_scope["trunk"]["batched"]
    record = {
        "bench": "coded_serving_policies",
        "requests": requests,
        "gen_len": gen_len,
        "masters": masters,
        "slots_per_master": slots,
        "backend": backend,
        "steps_per_dispatch": steps_per_dispatch,
        "timing_reps": reps,
        "baseline": "fifo",
        "policies": per_policy,
        "edf_miss_vs_fifo": round(
            per_policy["edf"]["deadline_miss_rate"]
            / max(base["deadline_miss_rate"], 1e-12), 3),
        "fair_throughput_vs_fifo": round(
            per_policy["fair"]["tokens_per_sim_second"]
            / max(base["tokens_per_sim_second"], 1e-12), 3),
        "scopes": per_scope,
        "trunk_throughput_vs_head": round(
            trunk_b["tokens_per_sim_second"]
            / max(head_b["tokens_per_sim_second"], 1e-12), 3),
        "trunk_wall_vs_head": round(
            trunk_b["tokens_per_wall_second"]
            / max(head_b["tokens_per_wall_second"], 1e-12), 3),
        "batched_wall_speedup": {
            scope: round(per_scope[scope]["batched"]
                         ["tokens_per_wall_second"]
                         / max(per_scope[scope]["serial"]
                               ["tokens_per_wall_second"], 1e-12), 3)
            for scope in CODING_SCOPES},
        "trace": trace_row,
        "faults": faults_row,
    }
    path = json_out
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    emit("serve/coded_policies", base["wall_seconds"] * 1e6,
         f"fifo_tok_per_sim_s={base['tokens_per_sim_second']};"
         f"edf_miss_vs_fifo={record['edf_miss_vs_fifo']};"
         f"fair_throughput_vs_fifo={record['fair_throughput_vs_fifo']};"
         f"trunk_vs_head={record['trunk_throughput_vs_head']};"
         f"trunk_wall_vs_head={record['trunk_wall_vs_head']};"
         f"batched_speedup_trunk="
         f"{record['batched_wall_speedup']['trunk']};"
         f"plan_cache_hit_rate={trace_row['plan_cache_hit_rate']};"
         f"stage_coverage={trace_row['stage_coverage']};"
         f"tracing_off_ratio="
         f"{trace_row['tracing_off_throughput_ratio']};"
         f"fault_detection={faults_row['detection_rate']};"
         f"fault_token_match={faults_row['token_match_rate']};"
         f"json={path}")
    return record


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--requests", type=int, default=24)
    p.add_argument("--gen-len", type=int, default=8)
    p.add_argument("--masters", type=int, default=2)
    p.add_argument("--slots", type=int, default=2)
    p.add_argument("--rate", type=float, default=0.02)
    p.add_argument("--backend", default="numpy",
                   choices=("numpy", "jax", "pallas"))
    p.add_argument("--steps-per-dispatch", type=int, default=1)
    p.add_argument("--reps", type=int, default=3,
                   help="timing repetitions per cell (best wall wins)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--trace", default=None, metavar="OUT.json",
                   help="write the traced trunk/batched pass's "
                        "Chrome/Perfetto trace here")
    p.add_argument("--faults",
                   default="corrupt=0.25,kind=sign_flip,crash=0.05,"
                           "retries=4,seed=5",
                   metavar="SPEC",
                   help="chaos-pass fault spec (repro.faults."
                        "parse_fault_spec syntax; 'none' = zero rates "
                        "with detection armed)")
    args = p.parse_args(argv)
    run_serve_bench(requests=args.requests, gen_len=args.gen_len,
                    masters=args.masters, slots=args.slots, rate=args.rate,
                    backend=args.backend,
                    steps_per_dispatch=args.steps_per_dispatch,
                    reps=args.reps, seed=args.seed, trace=args.trace,
                    faults=args.faults)


if __name__ == "__main__":
    main()
