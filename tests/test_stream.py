"""Tests for repro.stream: the batched backend, fault injection through both
executors, streaming determinism, queueing constraints and online replanning.
"""
import numpy as np
import pytest

from repro.core import iterated_greedy, plan_from_assignment
from repro.core.problem import Scenario
from repro.runtime import CodedExecutor
from repro.sim.montecarlo import _completion_times
from repro.stream import (AdmissionConfig, BackendConfig, OnlinePlanner,
                          PoissonProcess, ReplanPolicy, SharePool,
                          StreamConfig, StreamingExecutor, TraceProcess,
                          WorkerEvent, completion_times, decode_batch)
from repro.stream.backend import has_jax


def _scenario(M=2, N=10, L=96.0, seed=3):
    rng = np.random.default_rng(seed)
    a = np.zeros((M, N + 1))
    a[:, 0] = 0.5
    a[:, 1:] = rng.uniform(0.2, 0.4, size=(M, N))
    return Scenario(a=a, u=1 / a, gamma=2 / a, L=np.full(M, L))


# ---------------------------------------------------------------------------
# Batched completion backend
# ---------------------------------------------------------------------------

def _reference_completion(T, loads, need, needs_all=False):
    """Straightforward per-row reference implementation."""
    out = np.empty(T.shape[0])
    for i in range(T.shape[0]):
        pairs = [(t, l) for t, l in zip(T[i], loads)
                 if l > 0 and np.isfinite(t)]
        if needs_all:
            alive = all(np.isfinite(t) for t, l in zip(T[i], loads) if l > 0)
            out[i] = (max(t for t, _ in pairs)
                      if pairs and alive else np.inf)
            continue
        pairs.sort()
        acc, done = 0.0, np.inf
        for t, l in pairs:
            acc += l
            if acc >= need - 1e-9:
                done = t
                break
        out[i] = done
    return out


def test_completion_times_matches_reference():
    rng = np.random.default_rng(0)
    T = rng.exponential(1.0, size=(200, 7))
    loads = rng.uniform(0.0, 3.0, size=7)
    loads[2] = 0.0
    # inject dead (inf) and poisoned (NaN) entries
    T[rng.random(T.shape) < 0.1] = np.inf
    T[rng.random(T.shape) < 0.05] = np.nan
    for need in (1.0, 5.0, loads.sum() + 1.0):
        got = completion_times(T, loads, need)
        ref = _reference_completion(np.nan_to_num(T, nan=np.inf, posinf=np.inf), loads, need)
        np.testing.assert_allclose(got, ref)
    got_all = completion_times(T, loads, 0.0, needs_all=True)
    ref_all = _reference_completion(np.nan_to_num(T, nan=np.inf, posinf=np.inf), loads, 0.0,
                                    needs_all=True)
    np.testing.assert_allclose(got_all, ref_all)


def test_completion_times_batches_over_masters():
    """(R, M, K) batching equals the per-master legacy wrapper."""
    rng = np.random.default_rng(1)
    R, M, K = 64, 3, 6
    T = rng.exponential(1.0, size=(R, M, K))
    loads = rng.uniform(0.5, 2.0, size=(M, K))
    loads[1, 3] = 0.0
    need = np.array([3.0, 4.0, 2.0])
    batched = completion_times(T, loads[None], need[None])
    for m in range(M):
        np.testing.assert_allclose(
            batched[:, m], _completion_times(T[:, m], loads[m], need[m]))


def test_nan_delay_does_not_poison_prefix():
    """A NaN-delay worker ranked before live ones must be skipped."""
    T = np.array([[np.nan, 1.0, 2.0, 3.0]])
    loads = np.array([4.0, 4.0, 4.0, 4.0])
    assert completion_times(T, loads, 8.0)[0] == 2.0
    assert completion_times(T, loads, 12.0)[0] == 3.0
    assert completion_times(T, loads, 13.0)[0] == np.inf


@pytest.mark.skipif(not has_jax(), reason="jax not installed")
def test_jax_backend_matches_numpy():
    rng = np.random.default_rng(2)
    T = rng.exponential(1.0, size=(32, 5))
    T[0, 0] = np.inf
    loads = rng.uniform(0.5, 2.0, size=5)
    np.testing.assert_allclose(
        completion_times(T, loads, 3.0, backend="jax"),
        completion_times(T, loads, 3.0), rtol=1e-6)
    # batched decode
    L, Lt, B = 8, 12, 5
    G = np.vstack([np.eye(L), rng.normal(0, 1 / np.sqrt(L), (Lt - L, L))])
    rows = np.stack([rng.permutation(Lt)[:L] for _ in range(B)])
    y = rng.normal(size=(B, L))
    np.testing.assert_allclose(decode_batch(G, rows, y, backend="jax"),
                               decode_batch(G, rows, y), rtol=1e-4)


# ---------------------------------------------------------------------------
# Fault injection through CodedExecutor
# ---------------------------------------------------------------------------

def test_dead_worker_sweep_coded_executor():
    """Any single worker death is covered by Thm-1 redundancy: every master
    still decodes exactly and completes at finite time."""
    sc = _scenario()
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    rng = np.random.default_rng(0)
    A = [rng.normal(size=(96, 8)) for _ in range(sc.M)]
    x = [rng.normal(size=8) for _ in range(sc.M)]
    for w in range(1, sc.N + 1):
        ex = CodedExecutor(sc, plan, rng=w)
        results, rep = ex.run(A, x, dead_workers=(w,))
        assert bool(rep.decode_ok.all()), (w, rep.max_err)
        assert np.isfinite(rep.completion).all(), w
        for m in range(sc.M):
            np.testing.assert_allclose(results[m], A[m] @ x[m], rtol=1e-6)


# ---------------------------------------------------------------------------
# Streaming engine
# ---------------------------------------------------------------------------

def _stream(sc, *, policy="fractional", churn=(), rng=7, n=40, rate=0.01,
            numerics="none", replan=None):
    srcs = [PoissonProcess(m, rate=rate, seed=1) for m in range(sc.M)]
    cfg = StreamConfig(policy=policy, replan=replan, rng=rng,
                       backend=BackendConfig(numerics=numerics))
    ex = StreamingExecutor(sc, srcs, config=cfg, churn=churn)
    return ex.run(max_tasks=n)


def test_streaming_three_masters_churn_all_decode():
    """The acceptance scenario: 3 Poisson masters, mid-run degradation and a
    worker death — every task completes finite and decode-verifies."""
    sc = _scenario(M=3, N=8, L=48.0, seed=5)
    churn = [WorkerEvent(150.0, 2, "degrade", 4.0),
             WorkerEvent(300.0, 5, "leave"),
             WorkerEvent(900.0, 5, "join")]
    ms = _stream(sc, churn=churn, n=60, numerics="verify")
    s = ms.summary()
    assert s["tasks_completed"] == 60
    assert s["tasks_unserved"] == 0
    assert s["decode_ok_rate"] == 1.0
    soj = ms.sojourns()
    assert np.isfinite(soj).all() and (soj > 0).all()


def test_streaming_dead_worker_sweep():
    """Killing any single worker mid-run: redundancy + re-dispatch keep every
    completion finite and decode-verified."""
    sc = _scenario(M=2, N=8, L=48.0, seed=6)
    for w in range(1, sc.N + 1):
        churn = [WorkerEvent(100.0, w, "leave")]
        ms = _stream(sc, churn=churn, n=25, numerics="verify", rng=w)
        s = ms.summary()
        assert s["tasks_completed"] == 25, w
        assert np.isfinite(ms.sojourns()).all(), w
        assert s["decode_ok_rate"] == 1.0, w


def test_same_seed_replay_is_identical():
    sc = _scenario(M=3, N=8, L=48.0, seed=5)
    churn = [WorkerEvent(100.0, 3, "degrade", 3.0),
             WorkerEvent(250.0, 1, "leave")]
    runs = [_stream(sc, churn=churn, n=50, rng=11) for _ in range(2)]
    assert runs[0].summary() == runs[1].summary()
    assert runs[0].to_records() == runs[1].to_records()


def test_different_seed_differs():
    sc = _scenario(M=2, N=8, L=48.0, seed=5)
    a = _stream(sc, n=30, rng=1)
    b = _stream(sc, n=30, rng=2)
    assert a.summary() != b.summary()


def test_share_pool_constraints_held():
    """Concurrent in-flight tasks never oversubscribe a worker: the time-
    integral of held shares is bounded by the horizon (column sums <= 1)."""
    sc = _scenario(M=3, N=6, L=48.0, seed=8)
    ms = _stream(sc, n=60, rate=0.05)    # bursty: forces concurrency
    assert ms.utilization().max() <= 1.0 + 1e-6
    assert ms.summary()["tasks_completed"] == 60


def test_share_pool_unit():
    pool = SharePool(3)
    k = np.array([1.0, 0.6, 0.0, 0.3])
    pool.acquire(k, k)
    assert pool.feasible_fraction(k, k) == pytest.approx(0.4 / 0.6)
    with pytest.raises(ValueError):
        pool.acquire(np.array([1.0, 0.5, 0.0, 0.0]),
                     np.array([1.0, 0.5, 0.0, 0.0]))
    pool.release(k, k)
    assert pool.feasible_fraction(k, k) == 1.0
    pool.set_online(1, False)
    assert pool.feasible_fraction(k, k) == 0.0


def test_backpressure_queue_and_rejection():
    """A burst at t=0 beyond the pool forces queueing; a bounded queue
    rejects the overflow."""
    sc = _scenario(M=1, N=4, L=48.0, seed=9)
    srcs = [TraceProcess(0, [0.0] * 12)]
    ex = StreamingExecutor(
        sc, srcs, config=StreamConfig(
            policy="fractional", rng=3,
            admission=AdmissionConfig(min_fraction=0.9, max_queue=4)))
    ms = ex.run(max_tasks=12)
    s = ms.summary()
    assert s["tasks_rejected"] > 0
    assert s["tasks_completed"] + s["tasks_rejected"] == 12
    assert s["queue_wait_mean"] > 0   # head-of-line tasks waited


def test_straggle_fault_sweep():
    """Heavy-tail throttling (churn-free degradation): in-flight tasks hit
    CPU-credit-exhaustion slowdowns without any WorkerEvent.  Completion
    must survive every throttle probability, replay deterministically, and
    degrade monotonically in p on a fixed seed."""
    sc = _scenario(M=2, N=8, L=48.0, seed=7)
    p50 = {}
    for p in (0.0, 0.2, 0.5):
        srcs = [PoissonProcess(m, rate=0.01, seed=1) for m in range(sc.M)]
        ex = StreamingExecutor(sc, srcs, config=StreamConfig(
            policy="fractional", rng=9,
            backend=BackendConfig(numerics="verify", straggle_p=p,
                                  straggle_factor=8.0)))
        ms = ex.run(max_tasks=30)
        s = ms.summary()
        assert s["tasks_completed"] == 30, p
        assert s["decode_ok_rate"] == 1.0, p
        assert np.isfinite(ms.sojourns()).all(), p
        p50[p] = s["sojourn_p50"]
    assert p50[0.0] < p50[0.2] < p50[0.5]
    # deterministic replay with throttling on
    srcs = [PoissonProcess(m, rate=0.01, seed=1) for m in range(sc.M)]
    ex = StreamingExecutor(sc, srcs, config=StreamConfig(
        policy="fractional", rng=9,
        backend=BackendConfig(straggle_p=0.2, straggle_factor=8.0)))
    assert ex.run(max_tasks=30).summary()["sojourn_p50"] == p50[0.2]


@pytest.mark.skipif(not has_jax(), reason="jax not installed")
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_streaming_verify_backend_equivalence(backend):
    """jax / (interpret-mode) Pallas verification backends: identical delay
    metrics to the numpy run (only the verification numerics move to
    device) and every task decode-verifies."""
    sc = _scenario(M=2, N=8, L=48.0, seed=5)
    churn = [WorkerEvent(150.0, 2, "degrade", 4.0),
             WorkerEvent(300.0, 5, "leave")]

    def go(be):
        srcs = [PoissonProcess(m, rate=0.01, seed=1) for m in range(sc.M)]
        ex = StreamingExecutor(sc, srcs, config=StreamConfig(
            policy="fractional", rng=11,
            backend=BackendConfig(backend=be, numerics="verify")),
            churn=churn)
        return ex.run(max_tasks=30).summary()

    s_np, s_be = go("numpy"), go(backend)
    assert s_be["decode_ok_rate"] == 1.0
    for k in ("tasks_completed", "sojourn_p50", "sojourn_p99",
              "queue_wait_mean", "replans"):
        assert s_np[k] == s_be[k], k


def test_uncoded_needs_all_and_redispatch():
    """Uncoded tasks lose a worker mid-flight: no redundancy, so the task is
    re-dispatched (retries > 0) and still completes."""
    sc = _scenario(M=2, N=6, L=48.0, seed=10)
    churn = [WorkerEvent(60.0, 1, "leave")]
    ms = _stream(sc, policy="uncoded", churn=churn, n=30, rate=0.02, rng=4)
    s = ms.summary()
    assert s["tasks_completed"] == 30
    assert np.isfinite(ms.sojourns()).all()


# ---------------------------------------------------------------------------
# Online replanning
# ---------------------------------------------------------------------------

def test_planner_drops_dead_workers():
    sc = _scenario(M=2, N=6, L=64.0, seed=11)
    pl = OnlinePlanner(sc, policy="fractional")
    online = np.ones(sc.N + 1, dtype=bool)
    scale = np.ones(sc.N + 1)
    p0 = pl.ensure_plan(online, scale)
    online2 = online.copy()
    online2[3] = False
    p1 = pl.ensure_plan(online2, scale)
    assert np.all(p1.k[:, 3] == 0) and np.all(p1.l[:, 3] == 0)
    assert p1.t >= p0.t - 1e-9           # losing capacity cannot help
    assert pl.replans == 2


def test_replan_policy_counts():
    sc = _scenario(M=2, N=6, L=48.0, seed=12)
    churn = [WorkerEvent(50.0, 2, "degrade", 5.0),
             WorkerEvent(120.0, 4, "degrade", 5.0)]
    never = _stream(sc, churn=churn, n=25, rng=5,
                    replan=ReplanPolicy(mode="never"))
    drift = _stream(sc, churn=churn, n=25, rng=5,
                    replan=ReplanPolicy(mode="drift", drift_threshold=0.05))
    always = _stream(sc, churn=churn, n=25, rng=5,
                     replan=ReplanPolicy(mode="always"))
    r = [x.summary()["replans"] for x in (never, drift, always)]
    assert r[0] <= r[1] <= r[2]
    assert r[0] == 1                      # initial solve only
    assert r[1] >= 2                      # degradations crossed the threshold


def test_sca_warm_start_replan_improves_or_matches():
    from repro.core import sca_enhance_plan
    from repro.core.sca import feasible_deadline
    sc = _scenario(M=2, N=8, L=96.0, seed=13)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    t0 = feasible_deadline(sc, 0, plan.k, plan.b, plan.l[0])
    assert np.isfinite(t0) and t0 <= plan.t_per_master[0] * 1.01
    enhanced = sca_enhance_plan(sc, plan, max_iters=8)
    warm = sca_enhance_plan(sc, plan, max_iters=8, warm_l=enhanced.l)
    assert warm.t <= plan.t + 1e-9
    assert warm.t <= enhanced.t * 1.01    # warm start keeps the gains


def test_redispatch_never_finalized_by_stale_completion():
    """A task re-dispatched after losing its workers must not be finalized
    by the COMPLETION event of its *original* admission (version reuse bug):
    every completed task has delivered at least L rows."""
    sc = _scenario(M=1, N=3, L=64.0, seed=20)
    srcs = [TraceProcess(0, [0.0, 1.0, 2.0])]
    churn = [WorkerEvent(5.0, w, "leave") for w in (1, 2, 3)]
    ex = StreamingExecutor(sc, srcs, config=StreamConfig(
        policy="fractional", rng=1), churn=churn)
    ms = ex.run(max_tasks=3)
    recs = ms.to_records()
    assert len(recs) == 3
    assert any(r["retries"] > 0 for r in recs)      # churn actually hit
    for r in recs:
        assert r["rows_delivered"] >= r["rows_needed"] - 1e-6, r
        assert r["t_complete"] >= 5.0               # post-churn finish


def test_periodic_replan_terminates_when_sources_exhaust():
    """An exhausted trace source must not leave the periodic REPLAN timer
    rescheduling itself forever."""
    sc = _scenario(M=1, N=4, L=48.0, seed=21)
    ex = StreamingExecutor(sc, [TraceProcess(0, [0.0, 1.0])],
                           config=StreamConfig(
                               replan=ReplanPolicy(mode="periodic",
                                                   period=10.0),
                               rng=2))
    ms = ex.run(max_tasks=10)       # only 2 arrivals will ever happen
    assert ms.summary()["tasks_completed"] == 2


def test_fifo_admission_order():
    """A newcomer may not slip past queued tasks: admission order follows
    arrival order within a saturated single-master stream."""
    sc = _scenario(M=1, N=4, L=48.0, seed=22)
    srcs = [TraceProcess(0, [float(i) for i in range(10)])]
    ex = StreamingExecutor(
        sc, srcs, config=StreamConfig(
            policy="fractional", rng=3,
            admission=AdmissionConfig(min_fraction=0.9)))
    ms = ex.run(max_tasks=10)
    recs = sorted(ms.to_records(), key=lambda r: r["tid"])
    assert len(recs) == 10
    admits = [r["t_admit"] for r in recs]
    assert admits == sorted(admits)


def test_streaming_deterministic_trace_metrics_shape():
    """Trace-driven arrivals produce exactly the traced tasks with sane
    record fields."""
    sc = _scenario(M=2, N=6, L=48.0, seed=14)
    srcs = [TraceProcess(0, [1.0, 2.0, 3.0]), TraceProcess(1, [1.5, 2.5])]
    ex = StreamingExecutor(sc, srcs, config=StreamConfig(rng=6))
    ms = ex.run(max_tasks=5)
    recs = ms.to_records()
    assert len(recs) == 5
    for r in recs:
        assert r["t_admit"] >= r["t_arrive"]
        assert r["t_complete"] > r["t_admit"]
        assert r["rows_total"] >= r["rows_needed"] - 1e-6
        assert r["wasted_rows"] >= 0
