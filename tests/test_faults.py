"""Fault injection + parity-verified recovery: the chaos layer.

The contract under test, end to end: a seeded :class:`FaultConfig`
schedule injects crashes / drops / stales / duplicates (delivery faults)
and bit-flip / sign-flip / scaled corruptions (Byzantine faults) into
the serving bridge and the streaming engine.  Delivery faults only
change *which rows arrive when* — MDS decode is exact from any covering
prefix, so greedy tokens must stay bit-identical to the fault-free
serve.  Corruptions are detected by residual-checking surplus deliveries
(plus two master-encoded audit rows) against the decoded estimate,
localised by retry-as-re-dispatch exclusion, the culprits quarantined
with exponential backoff, and the step decoded back to the exact
product — or, when the retry budget is exhausted, explicitly degraded
to a stacked-LS decode on the verified row subset.  Never silently
wrong.
"""
import numpy as np
import pytest

from repro.faults import (CORRUPTION_FAULTS, DELIVERY_FAULTS, FaultConfig,
                          FaultEvent, FaultSchedule, QuarantineLedger,
                          corrupt_products, parse_fault_spec)
from repro.serve_coded import CodedServingBridge, synthetic_requests
from repro.stream import (AdmissionConfig, PoissonProcess, StreamConfig,
                          StreamingExecutor)
from repro.core.problem import Scenario


# ---------------------------------------------------------------------------
# Schedule / ledger / spec units
# ---------------------------------------------------------------------------

def test_fault_schedule_is_deterministic_and_stateless():
    cfg = FaultConfig(seed=7, crash_rate=0.1, corrupt_rate=0.2,
                      corrupt_kind="sign_flip")
    a, b = cfg.schedule(), cfg.schedule()
    workers = [1, 2, 3, 4, 5]
    draws = [a.faults_at(d, workers) for d in range(50)]
    # same config -> same draws, in any evaluation order
    assert [b.faults_at(d, workers) for d in reversed(range(50))] \
        == list(reversed(draws))
    assert any(draws), "rates this high must fire somewhere in 50 dispatches"


def test_zero_rate_schedule_is_inactive():
    cfg = FaultConfig(seed=0)
    assert not cfg.active
    sched = FaultSchedule(cfg)
    assert all(sched.faults_at(d, [1, 2, 3]) == {} for d in range(20))


def test_trace_events_override_draws():
    cfg = FaultConfig(seed=0, trace=(FaultEvent(3, 2, "crash"),
                                     FaultEvent(5, 1, "bit_flip")))
    sched = cfg.schedule()
    assert sched.faults_at(3, [1, 2]) == {2: "crash"}
    assert sched.faults_at(5, [1, 2]) == {1: "bit_flip"}
    assert sched.faults_at(4, [1, 2]) == {}


def test_quarantine_ledger_backoff_and_readmission():
    led = QuarantineLedger(backoff_base=100.0, backoff_factor=2.0)
    t1 = led.flag(3, 10.0)
    assert t1 == pytest.approx(110.0)
    assert led.quarantines == 1 and 3 in led.readmit_at
    led.readmit(3)
    assert led.readmissions == 1 and 3 not in led.readmit_at
    # a repeat offender backs off exponentially
    t2 = led.flag(3, 200.0)
    assert t2 == pytest.approx(400.0)
    # critical-path attribution feeds the suspect ordering
    led.note_critical(5)
    led.note_critical(5)
    led.note_critical(2)
    order = led.suspects_first([1, 2, 5])
    assert order.index(5) < order.index(2) < order.index(1)


def test_parse_fault_spec_round_trip():
    cfg = parse_fault_spec("corrupt=0.3,kind=sign_flip,crash=0.05,"
                           "retries=3,seed=11,surplus=6,tol=1e-5")
    assert cfg.corrupt_rate == 0.3 and cfg.corrupt_kind == "sign_flip"
    assert cfg.crash_rate == 0.05 and cfg.retry_budget == 3
    assert cfg.seed == 11 and cfg.surplus_rows == 6
    assert cfg.residual_tol == 1e-5
    assert not parse_fault_spec("none").active
    with pytest.raises(ValueError):
        parse_fault_spec("bogus=1")


def test_corrupt_products_kinds_are_deterministic_and_nontrivial():
    y = np.arange(1.0, 13.0).reshape(3, 4)
    for kind in CORRUPTION_FAULTS:
        a = corrupt_products(y.copy(), kind, eps=1e-3)
        b = corrupt_products(y.copy(), kind, eps=1e-3)
        assert np.array_equal(a, b), kind
        assert not np.array_equal(a, y), kind


# ---------------------------------------------------------------------------
# Streaming engine under faults
# ---------------------------------------------------------------------------

def _scenario(M=2, N=8, L=96.0, seed=3):
    rng = np.random.default_rng(seed)
    a = np.zeros((M, N + 1))
    a[:, 0] = 0.5
    a[:, 1:] = rng.uniform(0.2, 0.4, size=(M, N))
    return Scenario(a=a, u=1 / a, gamma=2 / a, L=np.full(M, L))


def _run_stream(faults, max_tasks=24, seed=5):
    sc = _scenario()
    srcs = [PoissonProcess(m, rate=0.02, seed=1) for m in range(sc.M)]
    ex = StreamingExecutor(sc, srcs,
                           config=StreamConfig(policy="fractional", rng=seed),
                           faults=faults)
    ms = ex.run(max_tasks=max_tasks)
    return ex, ms


def test_engine_zero_rate_faults_is_bit_identical():
    _, base = _run_stream(None)
    _, armed = _run_stream(FaultConfig(seed=0))
    rb, ra = base.to_records(), armed.to_records()
    assert len(rb) == len(ra)
    for x, y in zip(rb, ra):
        assert x == y


def test_engine_survives_crash_and_drop_chaos():
    ex, ms = _run_stream(FaultConfig(seed=9, crash_rate=0.05, drop_rate=0.1,
                                     stale_rate=0.1))
    recs = ms.to_records()
    assert len(recs) == 24                       # every task still completes
    for r in recs:
        assert np.isfinite(r["t_complete"])
        assert r["rows_delivered"] >= r["rows_needed"] - 1e-6
    stats = ex.fault_stats
    assert sum(stats.values()) > 0
    assert ms.utilization().max() <= 1.0 + 1e-6  # ledger survives the churn


# ---------------------------------------------------------------------------
# Serving bridge: chaos matrix
# ---------------------------------------------------------------------------

def _bridge(*, execution="batched", backend="numpy", **kw):
    b = CodedServingBridge(
        masters=2, slots_per_master=2, coding_scope="trunk",
        backend=backend, execution=execution,
        admission=AdmissionConfig(policy="edf"), **kw)
    b._setup_model(16 + 3 + 8)
    return b


def _reqs(b, n=4, gen=3, seed=0):
    return synthetic_requests(n, masters=2, vocab=b._model["cfg"].vocab,
                              prompt_len=16, gen_len=gen, rate=0.02,
                              seed=seed)


_CLEAN = {}


def _clean_tokens(execution, backend="numpy"):
    key = (execution, backend)
    if key not in _CLEAN:
        b = _bridge(execution=execution, backend=backend)
        rep = b.serve(_reqs(b))
        _CLEAN[key] = {r: list(t) for r, t in rep.tokens.items()}
    return _CLEAN[key]


def _serve_faulted(fc, *, execution="batched", backend="numpy", **kw):
    b = _bridge(execution=execution, backend=backend, faults=fc, **kw)
    rep = b.serve(_reqs(b))
    got = {r: list(t) for r, t in rep.tokens.items()}
    return rep, got == _clean_tokens(execution, backend)


@pytest.mark.parametrize("execution", ["serial", "batched"])
@pytest.mark.parametrize("kind", DELIVERY_FAULTS)
def test_delivery_faults_keep_tokens_bit_identical(kind, execution):
    """Crash / drop / stale / duplicate only change which rows arrive
    when; the decode is exact from whatever covers, so tokens match."""
    rates = {"crash": dict(crash_rate=0.1), "drop": dict(drop_rate=0.2),
             "stale": dict(stale_rate=0.3),
             "duplicate": dict(duplicate_rate=0.3)}[kind]
    rep, same = _serve_faulted(FaultConfig(seed=3, **rates),
                               execution=execution)
    assert same and rep.decode_ok
    assert (rep.decode_modes or {}).get("degraded", 0) == 0
    assert rep.faults["injected"] > 0


@pytest.mark.parametrize("execution", ["serial", "batched"])
@pytest.mark.parametrize("kind", CORRUPTION_FAULTS)
def test_corruption_detected_localised_and_recovered(kind, execution):
    """The chaos matrix headline: every applied corruption is detected
    (rate >= 0.99), localised to the marked worker, the culprit
    quarantined, and the decode recovered bit-identically — or the step
    is explicitly degraded.  Never silently wrong."""
    fc = FaultConfig(seed=5, corrupt_rate=0.3, corrupt_kind=kind,
                     retry_budget=4)
    rep, same = _serve_faulted(fc, execution=execution)
    f = rep.faults
    degraded = (rep.decode_modes or {}).get("degraded", 0)
    assert same or degraded > 0                 # never silently wrong
    if f["corrupt_applied"] > 0:
        assert f["detection_rate"] >= 0.99
        assert f["localization_rate"] >= 0.99
        assert f["quarantines"] > 0
        # workers flagged near the end may still be serving their backoff
        assert f["readmissions"] <= f["quarantines"]
    assert f["false_flags"] == 0


def test_corruption_recovers_on_jax_backend():
    fc = FaultConfig(seed=5, corrupt_rate=0.3, corrupt_kind="sign_flip",
                     retry_budget=4)
    rep, same = _serve_faulted(fc, backend="jax")
    assert same and rep.decode_ok
    assert rep.faults["detection_rate"] >= 0.99


def test_fault_free_schedule_with_detection_armed_is_identity():
    """Zero rates + detection on: the residual checks all pass, nothing
    is rejected, tokens stay bit-identical, and the fault report says
    so (rates 1.0 by convention when nothing was applied)."""
    for execution in ("serial", "batched"):
        rep, same = _serve_faulted(FaultConfig(seed=0), execution=execution)
        assert same and rep.decode_ok
        f = rep.faults
        assert f["injected"] == 0 and f["false_flags"] == 0
        assert f["detection_rate"] == 1.0 and f["localization_rate"] == 1.0
        assert set(rep.decode_modes) == {"exact"}


def test_exhausted_retry_budget_degrades_explicitly():
    """retry_budget=0 disables re-dispatch: corrupt steps must be
    *reported* as degraded (LS on the verified row subset), with the
    rejected rows counted — the never-silently-wrong escape hatch."""
    fc = FaultConfig(seed=5, corrupt_rate=0.3, corrupt_kind="sign_flip",
                     retry_budget=0)
    rep, same = _serve_faulted(fc)
    if not same:
        assert (rep.decode_modes or {}).get("degraded", 0) > 0
        assert rep.faults["rows_rejected"] > 0
    assert rep.faults["detection_rate"] >= 0.99


def test_quarantine_and_backoff_readmission_cycle():
    """Crash faults quarantine the worker (synthetic leave churn), the
    backoff timer readmits it, and the serve still matches clean."""
    rep, same = _serve_faulted(FaultConfig(seed=3, crash_rate=0.1,
                                           backoff_base=500.0))
    f = rep.faults
    assert same and f["quarantines"] > 0
    assert f["readmissions"] == f["quarantines"]


def test_ls_tail_is_bit_identical_at_exact_rows():
    """plan_decode_ls at rows == L routes through the same stacked LU as
    plan_decode — forcing every decode down the LS tail must not move a
    single token."""
    for execution in ("serial", "batched"):
        b = _bridge(execution=execution, ls_tail=True)
        rep = b.serve(_reqs(b))
        got = {r: list(t) for r, t in rep.tokens.items()}
        assert got == _clean_tokens(execution)
        assert rep.decode_ok
        assert set(rep.decode_modes) == {"ls"}


def test_fault_report_schema():
    rep, _ = _serve_faulted(FaultConfig(seed=5, corrupt_rate=0.2,
                                        corrupt_kind="bit_flip",
                                        retry_budget=4))
    f = rep.faults
    for key in ("injected", "crashes", "drops", "stales", "duplicates",
                "corrupt_steps", "corrupt_applied", "detected", "localized",
                "retries", "rows_rejected", "false_flags", "detection_rate",
                "localization_rate", "quarantines", "readmissions",
                "degraded_steps", "suspect_replans"):
        assert key in f, key
    per_step = [s for s in rep.steps if "decode_mode" in s]
    assert per_step and all(s["decode_mode"] in ("exact", "ls", "degraded")
                            for s in per_step)
