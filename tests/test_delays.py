"""Delay model (eqs. (1)-(5)) — CDF identities and sampler agreement."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.delays import (cdf_comm, cdf_comp, cdf_local, cdf_total,
                               expected_total, sample_total)


def test_cdf_total_resonant_limit():
    """Eq. (3) → eq. (4) as bγ → ku."""
    l, k, b, a = 100.0, 1.0, 1.0, 0.2
    u = 5.0
    t = 40.0
    exact = cdf_total(t, l, k, b, a, u, u)                  # resonant path
    near = cdf_total(t, l, k, b, a, u * (1 + 1e-7), u)      # general path
    assert abs(float(exact) - float(near)) < 1e-5


def test_cdf_monotone_and_bounded():
    ts = np.linspace(0, 200, 400)
    c = cdf_total(ts, 100.0, 1.0, 1.0, 0.2, 5.0, 8.0)
    assert np.all(np.diff(c) >= -1e-12)
    assert c[0] == 0.0 and c[-1] <= 1.0
    assert np.all((0 <= c) & (c <= 1))


def test_shift_region_zero():
    # P[T <= t] = 0 for t below the deterministic computation shift a·l/k
    assert float(cdf_total(10.0, 100.0, 1.0, 1.0, 0.2, 5.0, 8.0)) == 0.0
    assert float(cdf_comp(19.9, 100.0, 1.0, 0.2, 5.0)) == 0.0
    assert float(cdf_comp(20.1, 100.0, 1.0, 0.2, 5.0)) > 0.0


@settings(max_examples=20, deadline=None)
@given(st.floats(0.05, 0.5), st.floats(1.0, 10.0), st.floats(0.5, 4.0),
       st.integers(0, 100))
def test_sampler_matches_cdf(a, u, g_ratio, seed):
    """Empirical CDF of sample_total ≈ closed-form cdf_total."""
    rng = np.random.default_rng(seed)
    l = 50.0
    gamma = g_ratio * u
    arr_l = np.array([[0.0, l]])      # col 0 local (zero load), col 1 worker
    ones = np.ones((1, 2))
    s = sample_total(rng, (4000,), arr_l, ones, ones,
                     np.array([[0.4, a]]), np.array([[1.0, u]]),
                     np.array([[1.0, gamma]]), local_col0=True)[:, 0, 1]
    for q in (0.25, 0.5, 0.75):
        t_q = np.quantile(s, q)
        c = float(cdf_total(t_q, l, 1.0, 1.0, a, u, gamma))
        assert abs(c - q) < 0.05


def test_expected_total_is_mean_of_samples():
    rng = np.random.default_rng(0)
    l, a, u, gamma = 80.0, 0.3, 3.0, 5.0
    arr_l = np.array([[0.0, l]])
    ones = np.ones((1, 2))
    s = sample_total(rng, (200_000,), arr_l, ones, ones,
                     np.array([[0.4, a]]), np.array([[1.0, u]]),
                     np.array([[1.0, gamma]]))[:, 0, 1]
    want = float(expected_total(l, 1.0, 1.0, a, u, gamma))
    assert abs(s.mean() - want) / want < 0.02
