"""Sharded-vs-single-device consistency: the strongest check that the
sharding rules (TP + FSDP + EP + vocab/embedding shard_maps) don't change
the math.  Runs in a subprocess so the 4-device host platform doesn't leak
into other tests (the dry-run brief forbids a global device-count override).
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_smoke_config
from repro.models import init_model, model_fwd, ModelCtx
from repro.parallel.sharding import param_shardings, batch_sharding
from repro.launch.steps import model_state_shapes

for arch in ["llama3_2_1b", "dbrx_132b", "rwkv6_7b", "jamba_1_5_large_398b"]:
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity competition is dispatch-group-dependent by design; uncap
        # it so local and EP dispatch drop nothing and must agree exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, T = 4, 16
    batch = {"tokens": jnp.arange(B * T).reshape(B, T) % cfg.vocab}
    if cfg.frontend == "vision":
        batch["patch_feats"] = jnp.full(
            (B, cfg.frontend_len, cfg.frontend_dim), 0.1, jnp.float32)
    if cfg.enc_dec:
        batch["enc_feats"] = jnp.full(
            (B, cfg.frontend_len, cfg.frontend_dim), 0.1, jnp.float32)

    ref = model_fwd(params, batch, cfg=cfg)["logits"]

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ctx = ModelCtx(mesh=mesh, model_axis="model")
    p_shard = param_shardings(jax.eval_shape(lambda: params), mesh)
    params_s = jax.device_put(params, p_shard)
    batch_s = {k: jax.device_put(v, batch_sharding(mesh, v.shape))
               for k, v in batch.items()}
    with mesh:
        out = jax.jit(lambda p, b: model_fwd(p, b, cfg=cfg, ctx=ctx)["logits"])(
            params_s, batch_s)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                ref.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-9
    assert err / scale < 5e-3, (arch, err, scale)
    print(f"OK {arch}: sharded == single-device (rel {err/scale:.2e})")

    if cfg.moe is not None:
        # full-mesh EP path (the hillclimb lever) must agree too
        ctx2 = ModelCtx(mesh=mesh, model_axis="model", ep_full=True)
        p_shard2 = param_shardings(jax.eval_shape(lambda: params), mesh,
                                   moe_full_ep=True)
        params_s2 = jax.device_put(params, p_shard2)
        with mesh:
            out2 = jax.jit(lambda p, b: model_fwd(p, b, cfg=cfg,
                                                  ctx=ctx2)["logits"])(
                params_s2, batch_s)
        err2 = float(jnp.max(jnp.abs(out2.astype(jnp.float32) -
                                     ref.astype(jnp.float32))))
        assert err2 / scale < 5e-3, (arch, "ep_full", err2, scale)
        print(f"OK {arch}: full-mesh EP == single-device (rel {err2/scale:.2e})")
print("ALL-OK")
"""


@pytest.mark.slow
def test_sharded_forward_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "ALL-OK" in r.stdout
