"""Analytic roofline estimator vs XLA cost_analysis on unroll-free configs.

XLA counts each while-loop body once, so we validate on configs compiled
with effectively no loop trips to miscount: n_repeats=1, single microbatch,
T small enough for a single flash block.  Within those constraints the
estimator's forward-FLOP census must agree with the compiled module.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.launch.analytic import MeshDesc, estimate
from repro.models import model_fwd
from repro.models.config import ShapeCell


def _compiled_flops(cfg, B, T):
    batch = {"tokens": jnp.zeros((B, T), jnp.int32)}
    if cfg.enc_dec:
        batch["enc_feats"] = jnp.zeros((B, cfg.frontend_len,
                                        cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "vision":
        batch["patch_feats"] = jnp.zeros((B, cfg.frontend_len,
                                          cfg.frontend_dim), jnp.float32)
    fn = jax.jit(lambda p, b: model_fwd(p, b, cfg=cfg)["logits"])
    from repro.models import init_model
    shapes = jax.eval_shape(lambda k: init_model(k, cfg),
                            jax.random.PRNGKey(0))
    lowered = fn.lower(shapes, batch)
    cost = lowered.compile().cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax <= 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    return float(cost.get("flops", 0.0))


@pytest.mark.parametrize("arch", ["llama3_2_1b", "nemotron_4_15b",
                                  "glm4_9b"])
def test_fwd_flops_match_compiled_dense(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), n_repeats=1)
    B, T = 2, 64
    got = _compiled_flops(cfg, B, T)
    cell = ShapeCell("tiny", T, B, "prefill")
    est = estimate(cfg, cell, MeshDesc(dp=1, tp=1)).breakdown[
        "flops_fwd_global"]
    assert got > 0
    assert abs(est - got) / got < 0.35, (arch, est, got)


def test_fwd_flops_match_compiled_moe():
    cfg = dataclasses.replace(get_smoke_config("dbrx_132b"), n_repeats=1)
    B, T = 2, 64
    got = _compiled_flops(cfg, B, T)
    cell = ShapeCell("tiny", T, B, "prefill")
    est = estimate(cfg, cell, MeshDesc(dp=1, tp=1)).breakdown[
        "flops_fwd_global"]
    # MoE dispatch padding makes the compiled count higher; stay in band
    assert 0.3 < est / got < 2.0, (est, got)


def test_estimator_scales_linearly_in_depth_and_tokens():
    cfg = get_smoke_config("llama3_2_1b")
    cell1 = ShapeCell("a", 128, 2, "prefill")
    cell2 = ShapeCell("b", 256, 2, "prefill")
    mesh = MeshDesc(dp=1, tp=1)
    f1 = estimate(cfg, cell1, mesh).flops
    f2 = estimate(cfg, cell2, mesh).flops
    assert 1.8 < f2 / f1 < 2.3                  # ~linear in tokens (small T)
    cfg2 = dataclasses.replace(cfg, n_repeats=cfg.n_repeats * 2)
    f3 = estimate(cfg2, cell1, mesh).flops
    assert f3 > 1.5 * f1


def test_terms_positive_all_cells():
    from repro.configs import ARCH_IDS, get_config
    from repro.models.config import SHAPE_CELLS
    mesh = MeshDesc(dp=16, tp=16)
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in SHAPE_CELLS:
            if cell.name == "long_500k" and not cfg.subquadratic:
                continue
            c = estimate(cfg, cell, mesh, n_micro=8 if cell.kind == "train"
                         else 1)
            assert c.flops > 0 and c.hbm_bytes > 0 and c.ici_bytes > 0, \
                (arch, cell.name)
