"""Cross-backend parity harness for per-layer coded serving.

The headline invariant of the deep coding scopes: because MDS decode is
exact for *any* covering prefix, serving with every in-scope matmul
MDS-coded across the heterogeneous pool produces **bit-identical greedy
tokens** to the identically-scheduled uncoded pipeline — at every
``coding_scope`` (head | ffn | trunk), on every numerics backend
(numpy | jax | pallas-interpret), with multi-token dispatches, and under
worker churn that re-times in-flight per-layer tasks.
"""
import numpy as np
import pytest

from repro.parallel.hetero import coded_row_shards, rescaled_row_shards
from repro.serve_coded import (CODING_SCOPES, CodedLinear,
                               CodedServingBridge, HostTrunk,
                               synthetic_requests, trunk_matmul_keys)
from repro.stream import AdmissionConfig, WorkerEvent
from repro.stream.barrier import BarrierTask, StepBarrier, churn_finish_update

jax = pytest.importorskip("jax")

BACKENDS = ("numpy", "jax", "pallas")


def _serve(scope, *, coded=True, backend="numpy", steps=1, churn=(),
           n=4, gen=3, seed=0, policy="edf", slots=2):
    bridge = CodedServingBridge(
        masters=2, seed=seed, slots_per_master=slots, coding_scope=scope,
        steps_per_dispatch=steps, backend=backend, coded=coded,
        admission=AdmissionConfig(policy=policy))
    bridge._setup_model(16 + gen + 8)
    reqs = synthetic_requests(
        n, masters=2, vocab=bridge._model["cfg"].vocab, prompt_len=16,
        gen_len=gen, rate=0.02, seed=seed)
    return bridge.serve(reqs, churn=churn)


# ---------------------------------------------------------------------------
# The parity matrix: scope × backend, coded vs uncoded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("scope", CODING_SCOPES)
def test_greedy_tokens_bit_identical_across_scopes_and_backends(
        scope, backend):
    """Coded serving and the identically-scheduled uncoded pipeline emit
    bit-identical greedy tokens; every decoded matmul verifies against the
    local product."""
    coded = _serve(scope, coded=True, backend=backend)
    plain = _serve(scope, coded=False, backend=backend)
    assert coded.decode_ok, (scope, backend, coded.max_err)
    assert coded.argmax_match_rate == 1.0
    assert coded.tokens == plain.tokens          # bit-identical token ids
    assert coded.tokens_generated == 4 * 3
    assert plain.decode_ok is None               # baseline doesn't verify
    # identical scheduling: the uncoded twin saw the same steps/timings
    assert len(coded.steps) == len(plain.steps)
    assert [s["t_done"] for s in coded.steps] == \
        [s["t_done"] for s in plain.steps]


def test_scope_task_fanout_and_exactness():
    """ffn codes head+FFN, trunk additionally codes q/k/v/o — visible as
    the per-step task count — and deeper scopes stay exact (numpy
    float64)."""
    by_scope = {s: _serve(s) for s in CODING_SCOPES}
    cfg_layers = 2                               # llama3.2-1b smoke repeats
    expect = {"head": 1, "ffn": 1 + 3 * cfg_layers,
              "trunk": 1 + 7 * cfg_layers}
    for scope, rep in by_scope.items():
        assert rep.decode_ok and rep.max_err < 1e-6, scope
        for s in rep.steps:
            assert s["n_tasks"] == expect[scope], (scope, s)
        assert rep.metrics.utilization().max() <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# Multi-token dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scope", ("head", "trunk"))
def test_steps_per_dispatch_amortizes_and_preserves_tokens(scope):
    one = _serve(scope, steps=1, n=4, gen=4)
    batched = _serve(scope, steps=4, n=4, gen=4)
    assert batched.tokens == one.tokens          # same greedy chains
    assert len(batched.steps) < len(one.steps)   # fewer queue cycles
    assert batched.decode_ok and one.decode_ok
    assert batched.tokens_generated == one.tokens_generated == 16
    # amortization shows up in simulation throughput too
    assert batched.summary()["tokens_per_sim_second"] > \
        one.summary()["tokens_per_sim_second"]
    # and coded == uncoded still holds for batched dispatches
    plain = _serve(scope, coded=False, steps=4, n=4, gen=4)
    assert batched.tokens == plain.tokens


# ---------------------------------------------------------------------------
# Churn: in-flight per-layer re-timing and timing re-dispatch
# ---------------------------------------------------------------------------

def test_churn_retimes_in_flight_steps_tokens_unchanged():
    churn = [WorkerEvent(100.0, 2, "degrade", 6.0),
             WorkerEvent(250.0, 5, "leave"),
             WorkerEvent(2500.0, 5, "join"),
             WorkerEvent(4000.0, 2, "restore")]
    coded = _serve("trunk", churn=churn, n=6)
    plain = _serve("trunk", coded=False, churn=churn, n=6)
    assert coded.decode_ok
    assert coded.tokens == plain.tokens
    assert coded.summary()["tasks_completed"] == 6
    assert coded.metrics.replans >= 2


def test_mass_leave_redispatches_in_flight_step():
    """Killing every shared worker mid-flight strands the step's shard
    deliveries; the bridge re-times it on the local-only plan instead of
    replanning only between steps — tokens (already exactly decoded) are
    unchanged."""
    churn = [WorkerEvent(60.0, w, "leave") for w in range(1, 9)]
    coded = _serve("trunk", churn=churn, n=4)
    plain = _serve("trunk", coded=False, churn=churn, n=4)
    assert coded.summary()["tasks_completed"] == 4
    assert coded.redispatches > 0
    assert coded.tokens == plain.tokens
    assert coded.decode_ok


# ---------------------------------------------------------------------------
# Committed benchmark record: per-scope rows, trunk within 2x of head
# ---------------------------------------------------------------------------

def test_bench_serve_has_per_scope_execution_rows_trunk_within_2x_of_head():
    import json
    import pathlib
    path = pathlib.Path(__file__).parent.parent / "BENCH_serve.json"
    record = json.loads(path.read_text())
    assert set(CODING_SCOPES) <= set(record["scopes"])
    for scope in CODING_SCOPES:
        for execution in ("serial", "batched"):
            row = record["scopes"][scope][execution]
            assert row["execution"] == execution
            assert row["tokens_per_sim_second"] > 0
            assert row["tokens_per_wall_second"] > 0
            assert row["verified_tokens_per_wall_second"] > 0
            assert row["decode_backend"] in ("numpy", "jax")
    head = record["scopes"]["head"]["batched"]["tokens_per_sim_second"]
    trunk = record["scopes"]["trunk"]["batched"]["tokens_per_sim_second"]
    assert trunk >= head / 2.0, (trunk, head)
    assert record["trunk_throughput_vs_head"] >= 0.5
    # the wall-clock story: the batched engine must not lose to the
    # serial reference, and with the step-plan cache + cached LU decode
    # the fully-coded trunk must not lose to the head-only scope either
    # (1.08 recorded — planning is amortised away at steady state)
    assert record["trunk_wall_vs_head"] >= 0.9
    for scope in CODING_SCOPES:
        assert record["batched_wall_speedup"][scope] >= 1.0, scope
    trace = record["trace"]
    assert trace["plan_cache_hit_rate"] >= 0.9
    assert trace["counters"]["plan_cache_hits"] > 0
    assert trace["counters"]["pool_k_used_peak"] > 0
    assert trace["trace_path"]                   # never null: always written


# ---------------------------------------------------------------------------
# HostTrunk vs the jitted model (per-layer return-hidden threading)
# ---------------------------------------------------------------------------

def test_host_trunk_tracks_jitted_model_layer_by_layer():
    import jax.numpy as jnp
    from repro.launch.serve import build_model, head_matrix, zero_caches
    from repro.models import prefill
    cfg, params = build_model("llama3.2-1b", smoke=True, seed=0)
    runner = HostTrunk(cfg, params, head_matrix(cfg, params))
    rng = np.random.default_rng(3)
    P = 12
    prompt = rng.integers(0, cfg.vocab, size=(1, P)).astype(np.int32)
    logits, _, hid, layers = prefill(
        params, {"tokens": jnp.asarray(prompt)}, zero_caches(cfg, 1, P + 2),
        cfg=cfg, return_hidden=True, collect_layers=True)
    assert len(layers) == cfg.n_repeats * len(cfg.block)
    caches = runner.zero_caches(1, P + 2)
    mm_log = {}

    def probe(key, X):
        out = runner.local_matmul(key, X)
        mm_log[key] = out
        return out

    host_layers: list = []
    H = runner.forward(prompt, np.arange(P)[None], np.array([0]), caches,
                       probe, collect=host_layers)
    # every trunk matmul was routed through the hook exactly once
    assert set(mm_log) == set(trunk_matmul_keys(cfg, "trunk"))
    # layer-by-layer: the host float64 re-execution tracks the jitted
    # float32 model to float32 precision
    assert len(host_layers) == len(layers)
    for host_h, jit_h in zip(host_layers, layers):
        np.testing.assert_allclose(
            host_h, np.asarray(jit_h, np.float64), atol=5e-5)
    ref_h = np.asarray(hid, np.float64)[0, 0]
    np.testing.assert_allclose(H[0, -1], ref_h, atol=5e-5)
    host_logits = runner.local_matmul("head", H[:, -1])
    assert int(np.argmax(host_logits[0])) == int(np.argmax(logits[0, -1]))


# ---------------------------------------------------------------------------
# CodedLinear / shard-sizing units
# ---------------------------------------------------------------------------

def _linear(L=48, D=16, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return CodedLinear(rng.normal(size=(L, D)), name=f"t{L}x{D}", seed=seed,
                       **kw), rng


def test_coded_linear_systematic_and_parity_paths_exact():
    lin, rng = _linear()
    X = rng.normal(size=(5, 16))
    l_int = np.array([12, 18, 18, 24, 24])       # Σ=96 ≥ L=48
    res = lin.step(X, l_int, np.array([1.0, 2.0, 3.0, 4.0, 5.0]), 3.0)
    assert not res.used_solve
    np.testing.assert_allclose(res.out, X @ lin.W.T, rtol=1e-10)
    # straggling systematic node → parity rows + mixed-substitution decode
    res2 = lin.step(X, l_int, np.array([99.0, 2.0, 3.0, 1.0, 4.0]), 4.0)
    assert res2.used_solve
    np.testing.assert_allclose(res2.out, X @ lin.W.T, atol=1e-8)
    with pytest.raises(RuntimeError):
        lin.step(X, l_int, np.full(5, np.inf), 10.0)


def test_rescaled_row_shards_proportions_and_coverage():
    l_row = np.array([40.0, 0.0, 140.0, 260.0, 80.0])   # planned for L=512
    for L_mat in (32, 64, 128, 511):
        sh = rescaled_row_shards(l_row, 512.0, L_mat)
        assert sh.sum() >= L_mat
        assert sh[1] == 0                                # offline stays 0
        # redundancy ratio carries over (ceil slack aside)
        assert sh.sum() <= np.ceil(l_row.sum() * L_mat / 512.0) + len(l_row)
    same = rescaled_row_shards(l_row, 512.0, 512)
    np.testing.assert_array_equal(same, coded_row_shards(l_row, 512))


# ---------------------------------------------------------------------------
# StepBarrier / shared churn re-timing units
# ---------------------------------------------------------------------------

def _task(name, l, finish, need):
    return BarrierTask(name=name, l_int=np.asarray(l, dtype=np.int64),
                       finish=np.asarray(finish, dtype=np.float64),
                       need=float(need))


def test_step_barrier_completion_is_max_of_member_prefixes():
    b = StepBarrier([
        _task("a", [4, 4, 4], [1.0, 2.0, 9.0], 8),      # done at t=2
        _task("b", [2, 2, 2], [1.0, 5.0, 7.0], 6),      # needs all → t=7
    ])
    assert b.tasks[0].completion == 2.0
    assert b.tasks[1].completion == 7.0
    assert b.completion == 7.0
    assert b.rows_dispatched() == 18
    assert b.rows_delivered_by(2.0) == 4 + 4 + 2


def test_step_barrier_retime_leave_degrade_restore():
    # need = 12: every node's 4 rows are required (no slack redundancy)
    b = StepBarrier([_task("a", [4, 4, 4], [1.0, 4.0, 6.0], 12)])
    assert b.completion == 6.0
    # degrade node 1 at t=2: remaining 2 → ×3 = 6 ⇒ finish 8, now critical
    assert b.retime(1, "degrade", 2.0, factor=3.0)
    assert b.tasks[0].finish[1] == 8.0 and b.completion == 8.0
    # restore at t=5: remaining 3 → /3 ⇒ finish 6; node 2 critical again
    assert b.retime(1, "restore", 5.0, undo=3.0)
    assert b.tasks[0].finish[1] == 6.0 and b.completion == 6.0
    # node 2 leaves before delivering: coverage lost entirely
    assert b.retime(2, "leave", 5.5)
    assert np.isinf(b.tasks[0].finish[2]) and np.isinf(b.completion)
    # events on already-delivered shards change nothing
    assert not b.retime(0, "degrade", 7.0, factor=2.0)


def test_churn_finish_update_ignores_history_and_idle_nodes():
    finish = np.array([1.0, 3.0, np.inf])
    loads = np.array([2.0, 2.0, 0.0])
    # already-delivered shard (finish <= t) never moves
    assert not churn_finish_update(finish, loads, 0, "degrade", 2.0,
                                   factor=5.0)
    # zero-load node never moves
    assert not churn_finish_update(finish, loads, 2, "leave", 0.0)
    # dead (inf) delivery cannot degrade further
    finish[1] = np.inf
    assert not churn_finish_update(finish, loads, 1, "degrade", 0.0,
                                   factor=2.0)
