"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config, one forward + one train step on CPU; output shapes + no NaNs; decode
path consistency with prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (decode_step, init_cache_shapes, init_model,
                          model_fwd, padded_vocab, prefill)
from repro.optim import adamw_init
from repro.runtime.train_loop import make_train_step


def _batch(cfg, B=2, T=16):
    batch = {"tokens": jnp.arange(B * T).reshape(B, T) % cfg.vocab,
             "labels": (jnp.arange(B * T).reshape(B, T) + 1) % cfg.vocab}
    if cfg.enc_dec:
        batch["enc_feats"] = jnp.full((B, cfg.frontend_len, cfg.frontend_dim),
                                      0.1, jnp.float32)
    if cfg.frontend == "vision":
        batch["patch_feats"] = jnp.full((B, cfg.frontend_len,
                                         cfg.frontend_dim), 0.1, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, T = 2, 16
    out = model_fwd(params, _batch(cfg, B, T), cfg=cfg)
    assert out["logits"].shape == (B, T, padded_vocab(cfg))
    assert not bool(jnp.isnan(out["logits"]).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_loss_finite_and_decreases(arch):
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, n_microbatches=2, lr_peak=5e-3,
                                   warmup=1, total_steps=50))
    batch = _batch(cfg, B=4, T=16)
    losses = []
    for _ in range(4):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses   # same batch → must overfit


@pytest.mark.parametrize("arch", ["llama3_2_1b", "gemma3_12b", "rwkv6_7b",
                                  "jamba_1_5_large_398b", "deepseek_v3_671b"])
def test_prefill_then_decode_matches_full_forward(arch):
    """logits(prefill+decode at position T) ≈ logits(full forward at T).

    MoE archs get an uncapped capacity factor: capacity competition is
    context-dependent by design, so token-dropping must be disabled for the
    incremental-vs-full comparison to be exact."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = init_model(jax.random.PRNGKey(1), cfg)
    B, T = 2, 12
    toks = jnp.arange(B * (T + 1)).reshape(B, T + 1) % cfg.vocab
    full = model_fwd(params, {"tokens": toks}, cfg=cfg)["logits"][:, -1]

    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          init_cache_shapes(cfg, B, 32))
    _, caches = prefill(params, {"tokens": toks[:, :T]}, caches, cfg=cfg)
    lg, _ = decode_step(params, toks[:, T:T + 1],
                        jnp.full((B,), T, jnp.int32), caches, cfg=cfg)
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment_table():
    """The full (published) configs carry the exact assigned dimensions."""
    expect = {
        "deepseek_v3_671b": dict(d_model=7168, n_heads=128, vocab=129280,
                                 n_layers=61),
        "dbrx_132b": dict(d_model=6144, n_heads=48, vocab=100352, n_layers=40),
        "seamless_m4t_large_v2": dict(d_model=1024, n_heads=16, vocab=256206,
                                      n_layers=24),
        "nemotron_4_15b": dict(d_model=6144, n_heads=48, vocab=256000,
                               n_layers=32),
        "gemma3_12b": dict(d_model=3840, n_heads=16, vocab=262144, n_layers=48),
        "glm4_9b": dict(d_model=4096, n_heads=32, vocab=151552, n_layers=40),
        "llama3_2_1b": dict(d_model=2048, n_heads=32, vocab=128256,
                            n_layers=16),
        "jamba_1_5_large_398b": dict(d_model=8192, n_heads=64, vocab=65536,
                                     n_layers=72),
        "internvl2_26b": dict(d_model=6144, n_heads=48, vocab=92553,
                              n_layers=48),
        "rwkv6_7b": dict(d_model=4096, vocab=65536, n_layers=32),
    }
    for arch, spec in expect.items():
        cfg = get_config(arch)
        for key, val in spec.items():
            got = getattr(cfg, key) if key != "n_layers" else cfg.n_layers
            assert got == val, (arch, key, got, val)


def test_param_counts_in_published_ballpark():
    """active/total param counts land near the models' nameplates."""
    cases = {  # (total_low, total_high) in billions
        "deepseek_v3_671b": (550, 760),
        "dbrx_132b": (110, 150),
        "llama3_2_1b": (0.9, 1.6),
        "gemma3_12b": (9, 14),
        "glm4_9b": (8, 12),
        "nemotron_4_15b": (12, 18),
        "rwkv6_7b": (6, 9),
        "jamba_1_5_large_398b": (330, 440),
    }
    for arch, (lo, hi) in cases.items():
        P = get_config(arch).param_count() / 1e9
        assert lo <= P <= hi, (arch, P)
