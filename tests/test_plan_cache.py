"""Persistent step-plan cache: steady-state reuse, invalidation, exactness.

The StepPlanCache freezes per-(plan row, m) shard splits, row assignments,
covering-prefix structures and packed stages across serving steps.  MDS
decode is exact for any covering prefix, so the frozen structures must
change *nothing* observable: greedy tokens have to stay bit-identical to
an uncached serve on both execution engines, through churn and drift
replans.  These tests pin that, the hit/miss/invalidation counters that
make the steady state observable, and the satellite pool_k_used gauge.
"""
import numpy as np
import pytest

from repro.obs import Tracer
from repro.serve_coded import (CodedServingBridge, StepPlan, StepPlanCache,
                               synthetic_requests)
from repro.stream import AdmissionConfig, ReplanPolicy, WorkerEvent

CHURN = [WorkerEvent(400.0, 2, "degrade", 4.0),
         WorkerEvent(1500.0, 5, "leave"),
         WorkerEvent(6000.0, 5, "join"),
         WorkerEvent(8000.0, 2, "restore")]
# degrade-only: pool membership never changes, but the 4x slowdown trips
# the planner's drift threshold (0.15) and forces a replan mid-generation
DRIFT = [WorkerEvent(500.0, 1, "degrade", 6.0)]


def _bridge(scope="trunk", *, execution="batched", plan_cache=True,
            seed=0, gen=3, **kw):
    b = CodedServingBridge(
        masters=2, seed=seed, slots_per_master=2, coding_scope=scope,
        backend="numpy", execution=execution, plan_cache=plan_cache,
        admission=AdmissionConfig(policy="edf"), **kw)
    b._setup_model(16 + gen + 8)
    return b


def _reqs(b, n=4, gen=3, seed=0):
    return synthetic_requests(n, masters=2, vocab=b._model["cfg"].vocab,
                              prompt_len=16, gen_len=gen, rate=0.02,
                              seed=seed)


# ---------------------------------------------------------------------------
# Steady state is cache-hit-only
# ---------------------------------------------------------------------------

def test_churn_free_serve_is_all_hits_after_first_step_per_width():
    b = _bridge()
    reqs = _reqs(b)
    first = b.serve(reqs)
    assert first.plan_cache_misses > 0            # cold start must plan
    assert first.plan_cache_invalidations == 0
    again = b.serve(reqs)                         # same rows, warm cache
    assert again.plan_cache_misses == 0
    assert again.plan_cache_hits == len(again.steps)
    assert again.summary()["plan_cache_hit_rate"] == 1.0


def test_cache_hit_rate_stays_high_under_churn():
    # the bench workload (24 requests x gen 8): misses are fixed by the
    # churn schedule (one per active width per invalidation), so the hit
    # rate only clears the CI floor once steps amortise them — shorter
    # workloads deterministically under-read it
    b = _bridge(gen=8)
    rep = b.serve(_reqs(b, n=24, gen=8), churn=CHURN)
    s = rep.summary()
    assert rep.plan_cache_invalidations > 0
    assert s["plan_cache_hit_rate"] >= 0.9        # the CI floor


# ---------------------------------------------------------------------------
# Invalidation events drop the frozen plans and re-plan on the fresh row
# ---------------------------------------------------------------------------

def test_churn_invalidates_and_tokens_match_uncached_serial():
    want = _bridge(execution="serial", plan_cache=False).serve(
        _reqs(_bridge(execution="serial", plan_cache=False)), churn=CHURN)
    for execution in ("serial", "batched"):
        b = _bridge(execution=execution)
        rep = b.serve(_reqs(b), churn=CHURN)
        assert rep.plan_cache_invalidations > 0
        assert rep.plan_cache_misses > 1          # re-planned after churn
        assert rep.tokens == want.tokens          # bit-identical greedy ids


def test_drift_replan_invalidates_mid_generation():
    b = _bridge()
    rep = b.serve(_reqs(b, n=6, gen=4), churn=DRIFT)
    assert rep.plan_cache_invalidations > 0       # replan subscriber fired
    b2 = _bridge(plan_cache=False)
    want = b2.serve(_reqs(b2, n=6, gen=4), churn=DRIFT)
    assert rep.tokens == want.tokens


def test_incremental_repair_serves_identical_tokens():
    # MDS decode is exact for any covering prefix, so the planner's repair
    # mode (incremental row repair vs full re-solve per pool change) must
    # be invisible in the served tokens — on both execution engines,
    # through a schedule with repairable events *and* a join
    for execution in ("serial", "batched"):
        inc = _bridge(execution=execution,
                      replan=ReplanPolicy(mode="incremental"))
        always = _bridge(execution=execution,
                         replan=ReplanPolicy(mode="always"))
        r_inc = inc.serve(_reqs(inc), churn=CHURN)
        r_alw = always.serve(_reqs(always), churn=CHURN)
        assert r_inc.tokens == r_alw.tokens


def test_disabled_cache_reports_zero_counters_and_same_tokens():
    on = _bridge()
    off = _bridge(plan_cache=False)
    r_on = on.serve(_reqs(on), churn=CHURN)
    r_off = off.serve(_reqs(off), churn=CHURN)
    assert (r_off.plan_cache_hits == r_off.plan_cache_misses
            == r_off.plan_cache_invalidations == 0)
    assert r_on.tokens == r_off.tokens


# ---------------------------------------------------------------------------
# Cache mechanics
# ---------------------------------------------------------------------------

def test_cache_epoch_invalidation_and_context_keying():
    c = StepPlanCache(maxsize=2)
    k = np.array([2, 2]); bb = np.array([1.0, 1.0])
    entry = StepPlan(keys=["w"], l_ints=np.ones((1, 3), np.int64),
                     assign=np.zeros((1, 3)), epoch=c.epoch)
    c.set_context(b"scenario-a")
    assert c.lookup(1, k, bb) is None             # miss
    c.store(1, k, bb, entry)
    assert c.lookup(1, k, bb) is entry            # hit
    c.set_context(b"scenario-b")                  # degrade changes loads
    assert c.lookup(1, k, bb) is None             # same row, new context
    c.set_context(b"scenario-a")
    assert c.is_current(entry)
    c.invalidate("churn")
    assert c.lookup(1, k, bb) is None             # table cleared
    assert not c.is_current(entry)                # epoch moved on
    assert (c.hits, c.misses, c.invalidations) == (1, 3, 1)


def test_cache_lru_evicts_oldest_width():
    c = StepPlanCache(maxsize=2)
    rows = [(m, np.array([m]), np.array([float(m)])) for m in (1, 2, 3)]
    for m, k, bb in rows:
        c.store(m, k, bb, StepPlan(keys=[], l_ints=np.empty((0, 2), np.int64),
                                   assign=np.empty((0, 2)), epoch=0))
    assert c.lookup(*rows[0]) is None              # evicted
    assert c.lookup(*rows[1]) is not None
    assert c.lookup(*rows[2]) is not None


# ---------------------------------------------------------------------------
# Satellite: the pool_k_used gauge must actually move
# ---------------------------------------------------------------------------

def test_pool_k_used_gauge_peak_is_wired():
    b = _bridge()
    b.tracer = tr = Tracer(meta={"test": "pool_k_used"})
    b.serve(_reqs(b))
    s = tr.summary()
    assert s["counters"]["pool_k_used_peak"] > 0.0
    # last-value semantics of the plain gauge are unchanged: after the
    # final release the pool is empty again
    assert s["counters"]["pool_k_used"] == 0.0
    assert s["counters"]["plan_cache_hits"] > 0


# ---------------------------------------------------------------------------
# Quarantine / readmission epoch invalidation
# ---------------------------------------------------------------------------

def _chaos():
    from repro.faults import FaultConfig
    return FaultConfig(seed=5, corrupt_rate=0.3, corrupt_kind="sign_flip",
                       retry_budget=4)


def test_quarantine_and_readmission_invalidate_cache_epoch():
    """A localised corruption quarantines the culprit (synthetic crash
    churn) and later readmits it — both events must bump the cache epoch
    under their own reason so in-flight steps rebuild from the retimed
    barrier instead of trusting plans frozen for the old pool."""
    b = _bridge(faults=_chaos())
    rep = b.serve(_reqs(b))
    assert rep.faults["quarantines"] > 0
    by_reason = b._plan_cache.invalidations_by_reason
    assert by_reason.get("quarantine", 0) > 0
    assert by_reason.get("readmit", 0) > 0
    assert rep.plan_cache_invalidations == sum(by_reason.values())


def test_cached_serve_matches_uncached_through_quarantine():
    """Epoch invalidation keeps the cache exact under chaos: greedy
    tokens through a quarantine/readmission cycle are bit-identical with
    and without the StepPlanCache, on both engines."""
    for execution in ("batched", "serial"):
        bc = _bridge(execution=execution, faults=_chaos())
        bu = _bridge(execution=execution, faults=_chaos(), plan_cache=False)
        tc = bc.serve(_reqs(bc)).tokens
        tu = bu.serve(_reqs(bu)).tokens
        assert {r: list(t) for r, t in tc.items()} \
            == {r: list(t) for r, t in tu.items()}
