import importlib.util
import os
import pathlib
import re
import sys

# Make src/ importable without installation (pytest's `pythonpath` ini option
# also does this; the explicit insert keeps `python tests/...` working too).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see the real (single) device; only the dry-run
# pins 512 devices, inside its own process.

# Graceful degradation for optional test-only deps: when `hypothesis` (or any
# other optional import) is absent, skip collecting the modules that need it
# instead of erroring the whole session.
_OPTIONAL = ("hypothesis",)
collect_ignore = []
_here = pathlib.Path(__file__).parent
for _dep in _OPTIONAL:
    if importlib.util.find_spec(_dep) is not None:
        continue
    _pat = re.compile(rf"^\s*(?:from|import)\s+{_dep}\b", re.MULTILINE)
    for _p in sorted(_here.glob("test_*.py")):
        if _pat.search(_p.read_text()) and _p.name not in collect_ignore:
            collect_ignore.append(_p.name)
