import os
import sys

# Make src/ importable without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see the real (single) device; only the dry-run
# pins 512 devices, inside its own process.
