"""Algorithms 1, 2, 4 — assignment invariants and orderings."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Scenario, fractional_greedy, iterated_greedy,
                        plan_from_assignment, simple_greedy,
                        small_scale_scenario, large_scale_scenario,
                        theta_fractional, validate_plan, value_matrix)


def _min_V(sc, k):
    v = value_matrix(sc)
    V = v[:, 0] + (k[:, 1:] * v[:, 1:]).sum(1)
    return V.min()


def test_simple_greedy_assigns_every_worker():
    sc = large_scale_scenario(0)
    k = simple_greedy(sc)
    assert np.all(k[:, 1:].sum(0) == 1)          # each worker exactly once
    validate_plan(sc, plan_from_assignment(sc, k), fractional=False)


def test_iterated_at_least_as_good_as_simple():
    """Both are heuristics for an NP-hard problem; iterated greedy must win
    or tie (within noise) on the clear majority of seeds and never lose by
    more than 1% (paper Fig. 4(b) shows it ahead at large scale)."""
    wins = 0
    for seed in range(5):
        sc = large_scale_scenario(seed)
        vi, vs = _min_V(sc, iterated_greedy(sc, rng=seed)), \
            _min_V(sc, simple_greedy(sc))
        assert vi >= vs * 0.99
        wins += vi >= vs - 1e-12
    assert wins >= 3


def test_iterated_greedy_deterministic_given_rng():
    sc = large_scale_scenario(3)
    k1 = iterated_greedy(sc, rng=7)
    k2 = iterated_greedy(sc, rng=7)
    np.testing.assert_array_equal(k1, k2)


def test_fractional_respects_constraints_and_balances():
    sc = small_scale_scenario(0)
    init = iterated_greedy(sc, rng=0)
    p_ded = plan_from_assignment(sc, init)
    p = fractional_greedy(sc, init=init)
    validate_plan(sc, p, fractional=True)
    # fractional min-max objective is never worse than the dedicated one
    assert p.t <= p_ded.t + 1e-9
    # resource sums per worker stay within [0, 1]
    assert np.all(p.k[:, 1:].sum(0) <= 1 + 1e-9)
    assert np.all(p.b[:, 1:].sum(0) <= 1 + 1e-9)


def test_fractional_narrows_master_gap():
    sc = small_scale_scenario(0)
    init = iterated_greedy(sc, rng=0)
    ded = plan_from_assignment(sc, init)
    frac = fractional_greedy(sc, init=init)
    gap_ded = ded.t_per_master.max() - ded.t_per_master.min()
    gap_frac = frac.t_per_master.max() - frac.t_per_master.min()
    assert gap_frac <= gap_ded + 1e-9


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.integers(2, 4), st.integers(4, 12))
def test_assignment_random_scenarios(seed, M, N):
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.05, 0.5, size=(M, N + 1))
    u = 1.0 / a
    sc = Scenario(a=a, u=u, gamma=2 * u, L=rng.uniform(1e3, 1e4, M))
    k = iterated_greedy(sc, rng=seed)
    # binary, exclusive
    assert set(np.unique(k[:, 1:])).issubset({0.0, 1.0})
    assert np.all(k[:, 1:].sum(0) <= 1)
    p = fractional_greedy(sc, init=k, rng=seed)
    validate_plan(sc, p, fractional=True)
    assert np.isfinite(p.t)
