"""Data pipeline, checkpointing, optimizers, hetero planner, coded grads."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, cosine_warmup)
from repro.parallel.hetero import (coded_batch_plan, hetero_split,
                                   replan_on_failure)
from repro.runtime.coded_grads import coded_grad_aggregate, encode_grad_shards
from repro.sim.cluster import ec2_cluster, tpu_pod_cluster


# -- data --------------------------------------------------------------------

def test_stream_deterministic_and_resumable():
    s = TokenStream(vocab=1000, seq_len=32, global_batch=4, seed=7)
    b1 = s.batch(5)
    b2 = TokenStream(vocab=1000, seq_len=32, global_batch=4, seed=7).batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert not np.array_equal(s.batch(5)["tokens"], s.batch(6)["tokens"])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(0, 20))
def test_stream_resharding_partitions_global_batch(n_hosts_pow, step):
    n_hosts = 2 ** (n_hosts_pow % 3)
    full = TokenStream(vocab=500, seq_len=8, global_batch=8, seed=1)
    parts = [full.reshard(n_hosts, h).batch(step) for h in range(n_hosts)]
    merged = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(merged, full.batch(step)["tokens"])


# -- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"data_state": {"step": step}})
    assert mgr.latest_step() == 3
    assert mgr._steps() == [2, 3]            # keep-2 GC
    restored, step, extra = mgr.restore(tree)
    assert step == 3 and extra["data_state"]["step"] == 3
    np.testing.assert_array_equal(restored["w"], np.asarray(tree["w"]))


def test_checkpoint_structure_drift_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones(3)})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.ones(3), "extra": jnp.ones(2)})


# -- optimizers ---------------------------------------------------------------

def _quadratic_losses(update_fn, init_fn, steps=60):
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = init_fn(params)
    losses = []
    for _ in range(steps):
        grads = {"w": 2 * params["w"]}
        losses.append(float(jnp.sum(params["w"] ** 2)))
        params, state = update_fn(params, grads, state, lr=0.05)
    return losses


def test_adamw_converges_quadratic():
    losses = _quadratic_losses(
        lambda p, g, s, lr: adamw_update(p, g, s, lr=lr, weight_decay=0.0),
        adamw_init)
    assert losses[-1] < losses[0] * 0.05


def test_adamw_bf16_states():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st_ = adamw_init(params, state_dtype="bfloat16")
    assert st_.mu["w"].dtype == jnp.bfloat16
    p2, st2 = adamw_update(params, {"w": jnp.ones((4, 4), jnp.bfloat16)}, st_,
                           lr=1e-2)
    assert p2["w"].dtype == jnp.bfloat16
    assert int(st2.step) == 1


def test_adafactor_converges_and_is_factored():
    losses = _quadratic_losses(
        lambda p, g, s, lr: adafactor_update(p, g, s, lr=lr), adafactor_init)
    assert losses[-1] < losses[0] * 0.2
    st_ = adafactor_init({"w": jnp.ones((8, 16))})
    leaf = st_.second["w"]
    assert leaf.row.shape == (8,) and leaf.col.shape == (16,)


def test_cosine_warmup_shape():
    lr = cosine_warmup(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < 0.2


# -- hetero planner (paper Thm-1 tie-in) ---------------------------------------

def test_hetero_split_proportional_and_exact():
    prof = ec2_cluster(N=10, n_fast=5, rng=0)
    split = hetero_split(prof, 256)
    assert split.sum() == 256
    theta = np.array([prof.classes[c].unit_delay for c in prof.members])
    fast, slow = split[theta == theta.min()], split[theta == theta.max()]
    assert fast.min() >= slow.max()          # faster groups get more work


def test_coded_batch_plan_redundancy():
    prof = tpu_pod_cluster(n_pods=8, degraded=(3,))
    loads, t = coded_batch_plan(prof, 1024)
    assert loads.sum() >= 2 * 1024 - len(loads)     # Thm-1 2× redundancy
    assert t > 0
    # any prefix covering >= 1024 rows reconstructs: sorted-by-θ prefix check
    assert loads.sum() - loads.max() >= 1024        # lose the biggest, still ok


def test_replan_on_failure_drops_and_resolves():
    prof = tpu_pod_cluster(n_pods=8, degraded=(3,))
    new_prof, split = replan_on_failure(prof, 512, failed=[0, 3])
    assert new_prof.N == 6 and split.sum() == 512


# -- coded gradient aggregation -------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_coded_grads_any_k_of_n(seed):
    rng = np.random.default_rng(seed)
    k, n = 4, 7
    grads = [{"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
             for _ in range(k)]
    coded, ctx = encode_grad_shards(grads, n_coded=n, rng=seed)
    arrived = rng.choice(n, size=k, replace=False)
    agg = coded_grad_aggregate(coded, ctx, arrived)
    truth = np.sum([np.asarray(g["w"]) for g in grads], axis=0)
    np.testing.assert_allclose(np.asarray(agg["w"]), truth, rtol=1e-3,
                               atol=1e-3)
