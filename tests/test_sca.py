"""Algorithm 3 (SCA) — feasibility and monotone improvement."""
import numpy as np

from repro.core import (iterated_greedy, plan_from_assignment,
                        sca_enhance_plan, small_scale_scenario,
                        large_scale_scenario, fractional_greedy)
from repro.core.delays import expected_received


def _exact_feasible(sc, plan, slack=1e-3):
    for m in range(sc.M):
        ex = expected_received(float(plan.t_per_master[m]),
                               plan.l[m][None], plan.k[m][None],
                               plan.b[m][None], sc.a[m][None], sc.u[m][None],
                               sc.gamma[m][None])
        assert ex[0] >= sc.L[m] * (1 - slack), (m, ex[0], sc.L[m])


def test_sca_improves_dedicated_and_stays_feasible():
    sc = small_scale_scenario(0)
    base = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    enh = sca_enhance_plan(sc, base)
    assert enh.t <= base.t + 1e-9
    # the paper reports ~8.85% predicted-delay reduction at small scale;
    # accept anything ≥ 3% for robustness across draws
    assert enh.t < base.t * 0.97
    _exact_feasible(sc, enh)


def test_sca_improves_fractional():
    sc = small_scale_scenario(1)
    frac = fractional_greedy(sc)
    enh = sca_enhance_plan(sc, frac)
    assert enh.t <= frac.t + 1e-9
    _exact_feasible(sc, enh)


def test_sca_large_scale_feasible():
    sc = large_scale_scenario(0, M=2, N=20)   # trimmed for CI time
    base = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    enh = sca_enhance_plan(sc, base)
    assert enh.t <= base.t
    _exact_feasible(sc, enh)
