"""Tests for the coded serving bridge: plan-scheduled real token
generation with exact coded-head decode."""
import numpy as np
import pytest

from repro.parallel.hetero import coded_row_shards
from repro.serve_coded import (CodedLMHead, CodedServingBridge, ServeRequest,
                               synthetic_requests)
from repro.stream import AdmissionConfig, WorkerEvent

jax = pytest.importorskip("jax")


# ---------------------------------------------------------------------------
# Shard sizing (parallel.hetero)
# ---------------------------------------------------------------------------

def test_coded_row_shards_covers_and_preserves_zeros():
    l_row = np.array([10.4, 0.0, 7.2, 3.0, 0.0])
    shards = coded_row_shards(l_row, 16)
    assert shards.sum() >= 16
    assert shards[1] == 0 and shards[4] == 0
    assert (shards >= np.floor(l_row)).all()
    # down-scaled loads below L get topped up over the participants
    small = np.array([3.0, 2.0, 2.0])
    top = coded_row_shards(small, 16)
    assert top.sum() >= 16 and (top[small == 0] == 0).all()
    with pytest.raises(ValueError):
        coded_row_shards(np.zeros(3), 8)


# ---------------------------------------------------------------------------
# Coded head unit
# ---------------------------------------------------------------------------

def _head(L=32, D=8, seed=0):
    rng = np.random.default_rng(seed)
    return CodedLMHead(rng.normal(size=(L, D)), seed=seed), rng


def test_coded_head_systematic_prefix_exact():
    head, rng = _head()
    H = rng.normal(size=(3, 8))
    l_int = np.array([8, 12, 12, 16, 16])       # Σ=64 ≥ L=32
    finish = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
    res = head.step(H, l_int, finish, t_complete=3.0)
    # nodes 0..2 hold the systematic rows 0..31 → scatter path, no solve
    assert not res.used_solve
    np.testing.assert_allclose(res.logits, H @ head.W.T, rtol=1e-10)


def test_coded_head_parity_solve_exact():
    head, rng = _head()
    H = rng.normal(size=(2, 8))
    l_int = np.array([8, 12, 12, 16, 16])
    # the first systematic node is a straggler: parity rows fill the prefix
    finish = np.array([99.0, 2.0, 3.0, 1.0, 4.0])
    res = head.step(H, l_int, finish, t_complete=4.0)
    assert res.used_solve
    assert 0 not in res.workers_used
    np.testing.assert_allclose(res.logits, H @ head.W.T, atol=1e-8)


def test_coded_head_needs_coverage():
    head, rng = _head()
    H = rng.normal(size=(1, 8))
    l_int = np.array([8, 12, 12, 16, 16])
    finish = np.full(5, np.inf)
    finish[0] = 1.0                              # only 8 of 32 rows arrive
    with pytest.raises(RuntimeError):
        head.step(H, l_int, finish, t_complete=10.0)


# ---------------------------------------------------------------------------
# The bridge end-to-end
# ---------------------------------------------------------------------------

def _serve(policy="edf", n=6, gen=3, seed=0, churn=(), slots=2):
    bridge = CodedServingBridge(
        masters=2, seed=seed, slots_per_master=slots,
        admission=AdmissionConfig(policy=policy))
    bridge._setup_model(16 + gen + 8)
    reqs = synthetic_requests(
        n, masters=2, vocab=bridge._model["cfg"].vocab, prompt_len=16,
        gen_len=gen, rate=0.02, seed=seed)
    return bridge.serve(reqs, churn=churn)


def test_bridge_smoke_all_policies_decode_exact():
    """Every policy serves the workload; every token batch decodes to the
    uncoded forward pass; every request finishes with all tokens."""
    for policy in ("fifo", "edf", "fair"):
        rep = _serve(policy=policy)
        assert rep.decode_ok, (policy, rep.max_err, rep.argmax_match_rate)
        assert rep.argmax_match_rate == 1.0
        assert rep.max_err < 1e-6
        s = rep.summary()
        assert s["tasks_completed"] == 6
        assert s["tasks_unserved"] == 0
        assert rep.tokens_generated == 6 * 3
        assert len(rep.steps) > 0                  # plan-scheduled batches
        for toks in rep.tokens.values():
            assert len(toks) == 3
        # the share ledger held across concurrent tenant steps
        assert rep.metrics.utilization().max() <= 1.0 + 1e-6


def test_bridge_survives_churn():
    churn = [WorkerEvent(100.0, 2, "degrade", 4.0),
             WorkerEvent(300.0, 5, "leave"),
             WorkerEvent(2500.0, 5, "join")]
    rep = _serve(policy="fair", n=8, gen=3, churn=churn)
    assert rep.decode_ok
    assert rep.summary()["tasks_completed"] == 8
    assert rep.summary()["replans"] >= 2


def test_bridge_deterministic_replay():
    a = _serve(policy="edf", n=6, gen=3, seed=4)
    b = _serve(policy="edf", n=6, gen=3, seed=4)
    assert a.tokens == b.tokens
    assert a.metrics.summary() == b.metrics.summary()
    assert a.steps == b.steps


def test_bridge_reuse_grows_caches_for_longer_requests():
    """A second serve() with longer prompts/generations must regrow the KV
    caches (sized by the first call) instead of silently clamping writes."""
    bridge = CodedServingBridge(masters=2, seed=0, slots_per_master=2,
                                admission=AdmissionConfig(policy="fifo"))
    bridge._setup_model(16 + 2 + 8)
    vocab = bridge._model["cfg"].vocab
    short = synthetic_requests(4, masters=2, vocab=vocab, prompt_len=16,
                               gen_len=2, rate=0.02, seed=0)
    rep1 = bridge.serve(short)
    assert rep1.decode_ok
    longer = synthetic_requests(4, masters=2, vocab=vocab, prompt_len=40,
                                gen_len=6, rate=0.02, seed=1)
    rep2 = bridge.serve(longer)
    assert rep2.decode_ok
    assert rep2.tokens_generated == 4 * 6
    # and the regrown run matches a fresh bridge with the same workload
    fresh = CodedServingBridge(masters=2, seed=0, slots_per_master=2,
                               admission=AdmissionConfig(policy="fifo"))
    fresh._setup_model(40 + 6 + 8)
    rep3 = fresh.serve(synthetic_requests(4, masters=2, vocab=vocab,
                                          prompt_len=40, gen_len=6,
                                          rate=0.02, seed=1))
    assert rep2.tokens == rep3.tokens


def test_bridge_verify_off_still_generates():
    bridge = CodedServingBridge(masters=2, seed=0, slots_per_master=2,
                                verify=False,
                                admission=AdmissionConfig(policy="edf"))
    bridge._setup_model(16 + 3 + 8)
    reqs = synthetic_requests(4, masters=2, vocab=bridge._model["cfg"].vocab,
                              prompt_len=16, gen_len=3, rate=0.02, seed=0)
    rep = bridge.serve(reqs)
    assert rep.decode_ok is None and np.isnan(rep.max_err)
    assert rep.tokens_generated == 4 * 3
    # tokens come from the decoded coded logits either way: same seed with
    # verification on produces the identical sequences
    on = CodedServingBridge(masters=2, seed=0, slots_per_master=2,
                            admission=AdmissionConfig(policy="edf"))
    on._setup_model(16 + 3 + 8)
    rep_on = on.serve(synthetic_requests(
        4, masters=2, vocab=bridge._model["cfg"].vocab, prompt_len=16,
        gen_len=3, rate=0.02, seed=0))
    assert rep.tokens == rep_on.tokens


def test_bridge_deadlines_feed_edf():
    """Requests carry deadlines derived from the plan's per-token time;
    the summary reports a miss rate when deadlines are finite."""
    rep = _serve(policy="edf", n=8, gen=3, slots=1)
    s = rep.summary()
    assert "deadline_miss_rate" in s
    for rec in rep.metrics.completed:
        assert np.isfinite(rec.deadline)
