"""End-to-end behaviour tests for the paper's system.

The full pipeline: scenario → assignment (Alg 1/2/4) → loads (Thm 1/2/3,
SCA) → MDS encode → straggling workers → k-of-n decode → verified numerics,
plus Monte-Carlo agreement with the paper's qualitative claims and the
fault-tolerance story (dead workers, elastic replan).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (Scenario, coded_uniform, fractional_greedy,
                        iterated_greedy, plan_from_assignment,
                        sca_enhance_plan, simple_greedy, small_scale_scenario,
                        large_scale_scenario, uncoded_uniform, validate_plan)
from repro.runtime import CodedExecutor
from repro.runtime.straggler import BackupTaskPolicy, DeadlinePolicy
from repro.sim import simulate_plan


def test_end_to_end_coded_pipeline_exact_result():
    """Numerical round-trip with a dead worker — the core paper workflow."""
    sc = small_scale_scenario(0)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    plan.l[:] = plan.l / sc.L[:, None] * 256        # shrink to test size
    sc = Scenario(a=sc.a, u=sc.u, gamma=sc.gamma, L=np.full(sc.M, 256.0))
    rng = np.random.default_rng(0)
    A = [rng.normal(size=(256, 32)) for _ in range(sc.M)]
    x = [rng.normal(size=32) for _ in range(sc.M)]
    ex = CodedExecutor(sc, plan, rng=1)
    results, report = ex.run(A, x, dead_workers=(2,))
    assert bool(report.decode_ok.all()), report.max_err
    for m in range(sc.M):
        np.testing.assert_allclose(results[m], A[m] @ x[m], rtol=1e-6)
    assert np.isfinite(report.overall)


def test_proposed_beats_benchmarks_in_monte_carlo():
    """The paper's headline ordering: proposed < coded < uncoded."""
    sc = large_scale_scenario(0)
    k_it = iterated_greedy(sc, rng=0)
    dedi = plan_from_assignment(sc, k_it, method="dedi-iter")
    r_dedi = simulate_plan(sc, dedi, trials=8000, rng=1)
    r_cod = simulate_plan(sc, coded_uniform(sc), trials=8000, rng=1)
    r_unc = simulate_plan(sc, uncoded_uniform(sc), trials=8000, rng=1)
    assert r_dedi.overall_mean < r_cod.overall_mean < r_unc.overall_mean
    # and SCA strictly improves the dedicated plan
    sca = sca_enhance_plan(sc, dedi)
    r_sca = simulate_plan(sc, sca, trials=8000, rng=1)
    assert r_sca.overall_mean < r_dedi.overall_mean


def test_fractional_equals_iterated_at_large_scale():
    """Paper Fig. 4(b): frac ≈ dedi-iter when workers are plentiful."""
    sc = large_scale_scenario(1)
    k_it = iterated_greedy(sc, rng=1)
    dedi = plan_from_assignment(sc, k_it)
    frac = fractional_greedy(sc, init=k_it)
    r_d = simulate_plan(sc, dedi, trials=6000, rng=2)
    r_f = simulate_plan(sc, frac, trials=6000, rng=2)
    assert abs(r_f.overall_mean - r_d.overall_mean) / r_d.overall_mean < 0.05


def test_plans_validate_constraints():
    sc = small_scale_scenario(3)
    validate_plan(sc, plan_from_assignment(sc, simple_greedy(sc)),
                  fractional=False)
    validate_plan(sc, fractional_greedy(sc, rng=3), fractional=True)


def test_coding_beats_replication_baselines():
    """Coded k-of-n vs the replication policies the paper cites ([7],[8])."""
    sc = large_scale_scenario(2, M=1, N=20)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=2))
    r_coded = simulate_plan(sc, plan, trials=4000, rng=3)

    rng = np.random.default_rng(3)
    n_tasks, d = 10, 2
    loads = sc.L[0] / n_tasks
    theta = 1 / sc.gamma[0, 1:21] + 1 / sc.u[0, 1:21] + sc.a[0, 1:21]
    backup = BackupTaskPolicy(d=d)
    comp = []
    for _ in range(500):
        delays = loads * theta[rng.permutation(20)[:n_tasks * d]].reshape(
            n_tasks, d) * rng.exponential(1.0, (n_tasks, d))
        comp.append(backup.completion(delays))
    assert r_coded.overall_mean < np.mean(comp)


def test_elastic_replan_after_worker_loss():
    """Losing workers triggers a feasible re-plan with higher delay."""
    sc = large_scale_scenario(4)
    k = iterated_greedy(sc, rng=4)
    base = plan_from_assignment(sc, k)
    theta = 1 / sc.gamma + 1 / sc.u + sc.a
    order = np.argsort(theta[0, 1:])[:5] + 1      # the 5 fastest workers
    k2 = k.copy()
    k2[:, order] = 0.0
    degraded = plan_from_assignment(sc, k2)
    validate_plan(sc, degraded, fractional=False)
    assert degraded.t >= base.t                   # losing capacity can't help
    assert np.isfinite(degraded.t)


def test_deadline_policy_counts_waste():
    delays = np.array([1.0, 2.0, 3.0, 10.0])
    loads = np.array([4.0, 4.0, 4.0, 4.0])
    t, wasted = DeadlinePolicy().completion(delays, loads, need=8.0)
    assert t == 2.0 and wasted == 8.0
