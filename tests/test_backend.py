"""Backend equivalence at the new seams: numpy vs jax vs (interpret-mode)
Pallas must agree on ``completion_times``, ``decode_batch`` (dense and the
systematic fast path), ``simulate_batch`` statistics, and full
``CodedExecutor.run`` reports on fixed seeds.
"""
import numpy as np
import pytest

from repro.core import iterated_greedy, plan_from_assignment, uncoded_uniform
from repro.core.problem import Scenario
from repro.runtime import CodedExecutor
from repro.sim import simulate_plan
from repro.stream.backend import (ExponentialBlock, completion_times,
                                  decode_batch, has_jax, simulate_batch)

needs_jax = pytest.mark.skipif(not has_jax(), reason="jax not installed")


def _scenario(M=3, N=10, L=96.0, seed=3):
    rng = np.random.default_rng(seed)
    a = np.zeros((M, N + 1))
    a[:, 0] = 0.5
    a[:, 1:] = rng.uniform(0.2, 0.4, size=(M, N))
    return Scenario(a=a, u=1 / a, gamma=2 / a, L=np.full(M, L))


# ---------------------------------------------------------------------------
# completion_times
# ---------------------------------------------------------------------------

@needs_jax
def test_completion_jax_matches_numpy_with_dead_and_poisoned():
    rng = np.random.default_rng(0)
    T = rng.exponential(1.0, size=(300, 7))
    T[rng.random(T.shape) < 0.10] = np.inf
    T[rng.random(T.shape) < 0.05] = np.nan
    loads = rng.uniform(0.0, 3.0, size=7)
    loads[2] = 0.0
    for need in (1.0, 5.0, loads.sum() + 1.0):
        np.testing.assert_allclose(
            completion_times(T, loads, need, backend="jax"),
            completion_times(T, loads, need), rtol=1e-6)
    np.testing.assert_allclose(
        completion_times(T, loads, 2.0, needs_all=True, backend="jax"),
        completion_times(T, loads, 2.0, needs_all=True), rtol=1e-6)


@needs_jax
def test_completion_jax_batched_leading_axes():
    rng = np.random.default_rng(1)
    T = rng.exponential(1.0, size=(40, 3, 6))
    loads = rng.uniform(0.5, 2.0, size=(3, 6))
    need = np.array([3.0, 4.0, 2.0])
    np.testing.assert_allclose(
        completion_times(T, loads[None], need[None], backend="jax"),
        completion_times(T, loads[None], need[None]), rtol=1e-6)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        completion_times(np.ones((2, 3)), np.ones(3), 1.0, backend="tpu")


# ---------------------------------------------------------------------------
# decode_batch: systematic fast path + stacked/ragged generators
# ---------------------------------------------------------------------------

def _decode_case(seed=0, L=16, B=14):
    rng = np.random.default_rng(seed)
    Lt = 2 * L
    G = np.vstack([np.eye(L), rng.normal(0, 1 / np.sqrt(L), (Lt - L, L))])
    x_true = rng.normal(size=(B, L))
    # even tasks: pure systematic prefix (permutation); odd: mixed rows
    rows = np.stack([rng.permutation(L if i % 2 == 0 else Lt)[:L]
                     for i in range(B)])
    y = np.einsum("bij,bj->bi", G[rows], x_true)
    return G, rows, y, x_true


def test_decode_fast_path_bitwise_equals_solve():
    G, rows, y, x_true = _decode_case()
    out_auto = decode_batch(G, rows, y)
    out_solve = decode_batch(G, rows, y, systematic="never")
    out_prefix = decode_batch(G, rows, y, systematic="prefix")
    np.testing.assert_allclose(out_auto, x_true, atol=1e-8)
    pure = (rows < G.shape[1]).all(axis=1)
    assert pure.any() and not pure.all()
    # LU of a permutation matrix is exact, so scatter == solve bit-for-bit
    assert (out_auto[pure] == out_solve[pure]).all()
    # "prefix" keeps the pre-substitution behaviour: mixed tasks take the
    # full L×L solve, bit-for-bit
    assert (out_prefix[~pure] == out_solve[~pure]).all()
    # "auto" substitutes; it still agrees with the full solve to solver
    # precision on the mixed tasks
    np.testing.assert_allclose(out_auto[~pure], out_solve[~pure],
                               rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# decode_batch: mixed-row substitution (s < L systematic rows)
# ---------------------------------------------------------------------------

def _mixed_case(seed, L, counts):
    """One task per entry of ``counts``: s systematic + (L-s) parity rows."""
    rng = np.random.default_rng(seed)
    Lt = 2 * L + 3
    G = np.vstack([np.eye(L), rng.normal(0, 1 / np.sqrt(L), (Lt - L, L))])
    x_true = rng.normal(size=(len(counts), L))
    rows = np.stack([
        np.concatenate([rng.permutation(L)[:s],
                        L + rng.permutation(Lt - L)[:L - s]])
        for s in counts])
    for r in rows:                      # interleave systematic/parity order
        rng.shuffle(r)
    y = np.einsum("bij,bj->bi", G[rows], x_true)
    return G, rows, y, x_true


@pytest.mark.parametrize("counts", [
    (3,), (0, 5, 5, 12), (1, 1, 7, 0, 16, 7, 3), tuple(range(17))])
def test_decode_mixed_substitution_group_shapes(counts):
    """Substitution solves only the (L-s)-sized parity block, grouped by s:
    every group shape decodes to the truth, the pinned systematic
    coordinates are bit-identical to the received values, and the result
    agrees with the full solve to solver precision."""
    L = 16
    G, rows, y, x_true = _mixed_case(seed=7 + len(counts), L=L, counts=counts)
    out = decode_batch(G, rows, y)
    out_full = decode_batch(G, rows, y, systematic="prefix")
    np.testing.assert_allclose(out, x_true, atol=1e-9)
    np.testing.assert_allclose(out, out_full, rtol=1e-9, atol=1e-9)
    for b in range(rows.shape[0]):
        sys_m = rows[b] < L
        # each received systematic row pins x[row] = y exactly (scatter)
        assert (out[b, rows[b][sys_m]] == y[b, sys_m]).all()
    # matrix right-hand sides ride the same substitution path
    y3 = np.stack([y, -0.5 * y], axis=-1)
    out3 = decode_batch(G, rows, y3)
    np.testing.assert_allclose(out3[..., 0], x_true, atol=1e-9)
    np.testing.assert_allclose(out3[..., 1], -0.5 * x_true, atol=1e-9)


def test_decode_mixed_substitution_generator_forms_and_jax():
    counts = (0, 2, 9, 9, 15, 16)
    L = 16
    G, rows, y, x_true = _mixed_case(seed=3, L=L, counts=counts)
    B = rows.shape[0]
    base = decode_batch(G, rows, y)
    assert (decode_batch(np.stack([G] * B), rows, y) == base).all()
    assert (decode_batch([G] * B, rows, y) == base).all()
    if has_jax():
        np.testing.assert_allclose(decode_batch(G, rows, y, backend="jax"),
                                   x_true, rtol=1e-4, atol=1e-4)


def test_decode_batch_matrix_rhs_and_stacked_generators():
    G, rows, y, x_true = _decode_case(seed=2)
    B = rows.shape[0]
    # (B, L, C) right-hand sides
    y3 = np.stack([y, 2 * y], axis=-1)
    out3 = decode_batch(G, rows, y3)
    np.testing.assert_allclose(out3[..., 0], x_true, atol=1e-8)
    np.testing.assert_allclose(out3[..., 1], 2 * x_true, atol=1e-8)
    # per-task generators: 3-D stack and list forms match the shared-G path
    base = decode_batch(G, rows, y)
    assert (decode_batch(np.stack([G] * B), rows, y) == base).all()
    assert (decode_batch([G] * B, rows, y) == base).all()


@needs_jax
def test_decode_jax_matches_numpy():
    G, rows, y, x_true = _decode_case(seed=3)
    np.testing.assert_allclose(decode_batch(G, rows, y, backend="jax"),
                               x_true, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# simulate_batch / simulate_plan(backend="jax")
# ---------------------------------------------------------------------------

@needs_jax
def test_simulate_jax_statistically_matches_numpy():
    sc = _scenario()
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    r_np = simulate_plan(sc, plan, trials=20_000, rng=1)
    r_jx = simulate_plan(sc, plan, trials=20_000, rng=1, backend="jax")
    # independent RNG streams: agree to Monte-Carlo precision
    np.testing.assert_allclose(r_jx.per_master_mean, r_np.per_master_mean,
                               rtol=0.03)
    assert abs(r_jx.overall_mean / r_np.overall_mean - 1) < 0.02


@needs_jax
def test_simulate_jax_uncoded_needs_all():
    sc = _scenario()
    plan = uncoded_uniform(sc)
    r_np = simulate_plan(sc, plan, trials=20_000, rng=2)
    r_jx = simulate_plan(sc, plan, trials=20_000, rng=2, backend="jax")
    assert abs(r_jx.overall_mean / r_np.overall_mean - 1) < 0.03


@needs_jax
def test_simulate_jax_straggle_and_determinism():
    sc = _scenario()
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    kw = dict(straggle_p=0.25, straggle_factor=8.0, backend="jax")
    r1 = simulate_plan(sc, plan, trials=10_000, rng=3, keep_samples=True, **kw)
    r2 = simulate_plan(sc, plan, trials=10_000, rng=3, keep_samples=True, **kw)
    assert (r1.overall_samples == r2.overall_samples).all()
    base = simulate_plan(sc, plan, trials=10_000, rng=3, backend="jax")
    assert r1.overall_mean > base.overall_mean      # throttling hurts
    r_np = simulate_plan(sc, plan, trials=20_000, rng=3,
                         straggle_p=0.25, straggle_factor=8.0)
    r_jx = simulate_plan(sc, plan, trials=20_000, rng=3,
                         straggle_p=0.25, straggle_factor=8.0, backend="jax")
    assert abs(r_jx.overall_mean / r_np.overall_mean - 1) < 0.05


@needs_jax
def test_simulate_batch_trials_not_multiple_of_chunk():
    sc = _scenario(M=2, N=6)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    comp = simulate_batch(plan.l, plan.k, plan.b, sc.a, sc.u, sc.gamma,
                          sc.L, 1000, seed=5, chunk=256)
    assert comp.shape == (1000, sc.M)
    assert np.isfinite(comp).all()


def test_exponential_block_uniform_rows():
    blk = ExponentialBlock(np.random.default_rng(0), width=5, block=4,
                           uniform_rows=1)
    rows = [blk.draw() for _ in range(10)]          # spans a refill
    for r in rows:
        assert r.shape == (3, 5)
        assert (r[2] >= 0).all() and (r[2] < 1).all()     # uniform row
    # deterministic replay
    blk2 = ExponentialBlock(np.random.default_rng(0), width=5, block=4,
                            uniform_rows=1)
    assert all((a == blk2.draw()).all() for a in rows)


# ---------------------------------------------------------------------------
# CodedExecutor: stacked run vs the legacy per-master loop
# ---------------------------------------------------------------------------

def _exec_case(seed=0):
    sc = _scenario()
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    rng = np.random.default_rng(seed)
    A = [rng.normal(size=(96, 8)) for _ in range(sc.M)]
    x = [rng.normal(size=8) for _ in range(sc.M)]
    return sc, plan, A, x


@pytest.mark.parametrize("dead", [(), (1,), (2, 5)])
def test_coded_executor_batched_bit_for_bit(dead):
    sc, plan, A, x = _exec_case()
    for seed in range(4):
        res_n, rep_n = CodedExecutor(sc, plan, rng=seed).run(
            A, x, dead_workers=dead)
        res_o, rep_o = CodedExecutor(sc, plan, rng=seed)._run_loop(
            A, x, dead_workers=dead)
        assert np.array_equal(rep_n.completion, rep_o.completion)
        assert np.array_equal(rep_n.decode_ok, rep_o.decode_ok)
        assert np.array_equal(rep_n.max_err, rep_o.max_err)
        for u, v in zip(rep_n.used_nodes, rep_o.used_nodes):
            assert np.array_equal(u, v)
        for a, b in zip(res_n, res_o):
            assert np.array_equal(np.nan_to_num(a, nan=-1.0),
                                  np.nan_to_num(b, nan=-1.0))


def test_coded_executor_matrix_rhs_and_mixed_shapes():
    """Matrix right-hand sides (x (S, C)) and heterogeneous RHS shapes in
    one run() — the legacy loop accepted both, the stacked path must too."""
    sc, plan, A, _ = _exec_case()
    rng = np.random.default_rng(9)
    x = [rng.normal(size=8), rng.normal(size=(8, 3)), rng.normal(size=(8, 2))]
    res_n, rep_n = CodedExecutor(sc, plan, rng=0).run(A, x)
    res_o, rep_o = CodedExecutor(sc, plan, rng=0)._run_loop(A, x)
    assert np.array_equal(rep_n.completion, rep_o.completion)
    assert np.array_equal(rep_n.max_err, rep_o.max_err)
    for a, b in zip(res_n, res_o):
        assert np.array_equal(a, b)
    if has_jax():
        _, rep_j = CodedExecutor(sc, plan, rng=0, backend="jax").run(A, x)
        assert rep_j.decode_ok.all() and \
            np.array_equal(rep_j.completion, rep_o.completion)


def test_simulate_plan_numpy_bit_equals_simulate_batch_numpy():
    """One shared Generator-chunk implementation: same seed + chunk give the
    same samples through both entry points."""
    sc = _scenario(M=2, N=6)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    r = simulate_plan(sc, plan, trials=5000, rng=11, keep_samples=True)
    comp = simulate_batch(plan.l, plan.k, plan.b, sc.a, sc.u, sc.gamma,
                          sc.L, 5000, seed=np.random.default_rng(11),
                          backend="numpy", chunk=20_000)
    assert (r.per_master_samples == comp).all()


def test_coded_executor_gaussian_generator_still_equivalent():
    sc, plan, A, x = _exec_case(seed=1)
    kw = dict(generator_kind="gaussian", rng=2)
    _, rep_n = CodedExecutor(sc, plan, **kw).run(A, x)
    _, rep_o = CodedExecutor(sc, plan, **kw)._run_loop(A, x)
    assert np.array_equal(rep_n.completion, rep_o.completion)
    assert np.array_equal(rep_n.max_err, rep_o.max_err)
    assert rep_n.decode_ok.all()


@needs_jax
@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("kind", ["systematic", "gaussian"])
def test_coded_executor_accelerator_backends(backend, kind):
    sc, plan, A, x = _exec_case()
    _, rep_b = CodedExecutor(sc, plan, rng=0, backend=backend,
                             generator_kind=kind).run(
        A, x, dead_workers=(1,))
    _, rep_r = CodedExecutor(sc, plan, rng=0,
                             generator_kind=kind)._run_loop(
        A, x, dead_workers=(1,))
    # randomness and the completion rule stay on the host: identical
    assert np.array_equal(rep_b.completion, rep_r.completion)
    # float32 linear algebra: verified decode, looser error floor
    assert rep_b.decode_ok.all(), rep_b.max_err
    assert rep_b.max_err.max() < 1e-3
