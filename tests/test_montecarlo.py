"""Monte-Carlo simulator vs closed-form expectations."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (Plan, Scenario, iterated_greedy,
                        plan_from_assignment, small_scale_scenario)
from repro.core.delays import cdf_total
from repro.sim import simulate_plan
from repro.sim.montecarlo import _completion_times


def test_completion_times_manual_case():
    T = np.array([[5.0, 1.0, 3.0], [2.0, 9.0, 4.0]])
    loads = np.array([4.0, 4.0, 4.0])
    # need 8 rows: first row arrivals sorted (1,3,5) → done at 3
    out = _completion_times(T, loads, need=8.0)
    np.testing.assert_allclose(out, [3.0, 4.0])
    # unreachable
    out2 = _completion_times(T, loads, need=20.0)
    assert np.isinf(out2).all()


def test_markov_bound_holds_empirically():
    """P[node finishes by t*] ≥ 1/2 at the Thm-1 point (Markov tightness)."""
    sc = small_scale_scenario(0)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=0))
    r = simulate_plan(sc, plan, trials=20_000, rng=5, keep_samples=True)
    # E[X(t*)] >= L ⇒ empirical completion should usually beat t*
    frac_on_time = np.mean(r.overall_samples <= plan.t)
    assert frac_on_time > 0.5


def test_simulator_seed_reproducible():
    sc = small_scale_scenario(1)
    plan = plan_from_assignment(sc, iterated_greedy(sc, rng=1))
    r1 = simulate_plan(sc, plan, trials=2000, rng=9)
    r2 = simulate_plan(sc, plan, trials=2000, rng=9)
    assert r1.overall_mean == r2.overall_mean


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50))
def test_single_node_completion_matches_cdf(seed):
    """One worker, whole task: empirical CDF at median ≈ closed form."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.1, 0.4)
    u = 1.0 / a
    sc = Scenario(a=np.array([[0.4, a]]), u=np.array([[2.5, u]]),
                  gamma=np.array([[1.0, 2 * u]]), L=np.array([100.0]))
    k = np.ones((1, 2))
    l = np.array([[0.0, 100.0]])
    plan = Plan(k=k, b=k.copy(), l=l, t_per_master=np.array([1.0]))
    r = simulate_plan(sc, plan, trials=6000, rng=seed, keep_samples=True)
    med = float(np.median(r.overall_samples))
    c = float(cdf_total(med, 100.0, 1.0, 1.0, a, u, 2 * u))
    assert abs(c - 0.5) < 0.06
