"""Tests for the pluggable admission policies, deadline-aware scheduling
and speculative re-dispatch in the streaming engine."""
import numpy as np
import pytest

from repro.core.problem import Scenario
from repro.stream import (AdmissionConfig, EDFAdmission, FairShareAdmission,
                          FIFOAdmission, PoissonProcess, StreamConfig,
                          StreamingExecutor, TraceProcess, WorkerEvent,
                          make_admission_policy, maxmin_share)


def _scenario(M=2, N=8, L=96.0, seed=3):
    rng = np.random.default_rng(seed)
    a = np.zeros((M, N + 1))
    a[:, 0] = 0.5
    a[:, 1:] = rng.uniform(0.2, 0.4, size=(M, N))
    return Scenario(a=a, u=1 / a, gamma=2 / a, L=np.full(M, L))


# ---------------------------------------------------------------------------
# Policy units
# ---------------------------------------------------------------------------

def test_policy_factory_and_ordering():
    fifo = make_admission_policy("fifo")
    assert isinstance(fifo, FIFOAdmission) and fifo.head_of_line
    edf = make_admission_policy("edf")
    assert isinstance(edf, EDFAdmission) and edf.reorders
    fair = make_admission_policy("fair")
    assert isinstance(fair, FairShareAdmission) and not fair.head_of_line
    with pytest.raises(ValueError):
        make_admission_policy("lifo")

    # FIFO: insertion order regardless of deadline
    fifo.offer(1, master=0, deadline=5.0)
    fifo.offer(2, master=0, deadline=1.0)
    assert fifo.candidates() == [1, 2]

    # EDF: deadline order, arrival breaks ties, inf sorts last
    edf.offer(1, master=0, deadline=50.0)
    edf.offer(2, master=0, deadline=10.0)
    edf.offer(3, master=1)                      # no deadline
    edf.offer(4, master=1, deadline=10.0)
    assert edf.candidates() == [2, 4, 1, 3]
    edf.remove(2)
    assert edf.candidates() == [4, 1, 3]

    # fair: round-robin across masters' FIFO heads (least-admitted first)
    fair.offer(10, master=0)
    fair.offer(11, master=0)
    fair.offer(20, master=1)
    assert fair.candidates() == [10, 20, 11]
    fair.remove(10)
    fair.note_admitted(0)                       # master 0 got one admission
    fair.offer(12, master=0)
    assert fair.candidates()[0] == 20           # master 1 now least-admitted
    # direct (queue-bypass) admissions count too
    fair.note_admitted(1)
    fair.note_admitted(1)
    assert fair.candidates()[0] == 11           # master 0 least-admitted again


def test_policy_backpressure_counts():
    edf = make_admission_policy("edf", max_queue=2)
    assert edf.offer(1, master=0) and edf.offer(2, master=0)
    assert not edf.offer(3, master=0)
    assert edf.rejected == 1
    assert edf.offer(4, master=0, force=True)   # re-queued in-flight work
    assert len(edf) == 3


def test_maxmin_share_waterfill():
    # two equal claimants split the column evenly
    assert maxmin_share(1.0, 0.6, [0.6]) == pytest.approx(0.5)
    # a small claimant releases its leftover to the big one
    assert maxmin_share(1.0, 0.6, [0.2]) == pytest.approx(0.6)
    # three claimants: fair line is 1/3
    assert maxmin_share(1.0, 0.6, [0.6, 0.6]) == pytest.approx(1 / 3)
    # never more than the demand
    assert maxmin_share(1.0, 0.1, [0.5]) == pytest.approx(0.1)


def test_fair_fraction_caps_contended_columns():
    fair = FairShareAdmission()
    k_req = np.array([1.0, 0.6, 0.0])
    held = np.zeros(3)
    other = np.array([0.0, 0.6, 0.0])
    f = fair.fair_fraction(0, k_req, k_req, held=held, demands=[other])
    assert f == pytest.approx(0.5 / 0.6)        # capped at the 0.5 fair share
    # column 0 (the master's own processor) is never contended
    k_local = np.array([1.0, 0.0, 0.0])
    assert fair.fair_fraction(0, k_local, k_local, held=held,
                              demands=[other]) == 1.0


# ---------------------------------------------------------------------------
# EDF vs FIFO on deadline misses
# ---------------------------------------------------------------------------

def _deadline_run(policy: str, seed: int):
    """Saturated single master, mixed tight/loose deadlines, churn."""
    sc = _scenario(M=1, N=4, L=64.0, seed=9)
    rng = np.random.default_rng(seed)
    n = 24
    times = np.sort(rng.uniform(0.0, 120.0, size=n))
    slack = rng.choice([160.0, 1200.0], size=n)   # tight vs loose
    srcs = [TraceProcess(0, times, deadlines=list(times + slack))]
    churn = [WorkerEvent(80.0, 2, "degrade", 3.0)]
    ex = StreamingExecutor(
        sc, srcs, config=StreamConfig(
            policy="fractional", rng=seed,
            admission=AdmissionConfig(min_fraction=0.9, policy=policy)),
        churn=churn)
    s = ex.run(max_tasks=n).summary()
    assert s["tasks_completed"] == n, (policy, seed)
    return s["deadline_miss_rate"]


def test_edf_beats_fifo_on_deadline_miss_rate():
    """Seeded churn sweep: EDF never loses to FIFO on miss rate and wins
    in aggregate."""
    miss_fifo, miss_edf = [], []
    for seed in (1, 2, 3, 4, 5):
        miss_fifo.append(_deadline_run("fifo", seed))
        miss_edf.append(_deadline_run("edf", seed))
    assert all(e <= f + 1e-9 for e, f in zip(miss_edf, miss_fifo)), \
        (miss_edf, miss_fifo)
    assert sum(miss_edf) < sum(miss_fifo), (miss_edf, miss_fifo)


def test_unserved_expired_deadline_counts_as_miss():
    """A starving run cannot look deadline-perfect: tasks still queued at
    the end with finite deadlines count as misses."""
    from repro.stream import StreamMetrics, TaskRecord
    ms = StreamMetrics(1, 2)
    done = TaskRecord(tid=0, master=0, t_arrive=0.0, deadline=100.0)
    done.t_admit, done.t_complete = 1.0, 50.0
    ms.record_task(done)
    ms.record_unserved(TaskRecord(tid=1, master=0, t_arrive=0.0,
                                  deadline=100.0))
    assert ms.summary()["deadline_miss_rate"] == pytest.approx(0.5)


def test_deadline_metric_plumbing():
    """deadline_slack on a Poisson source lands in the records and the
    summary; without deadlines the summary key is absent."""
    sc = _scenario(M=2, N=8, L=48.0, seed=5)
    srcs = [PoissonProcess(m, rate=0.01, seed=1, deadline_slack=3.0)
            for m in range(sc.M)]
    ex = StreamingExecutor(sc, srcs, config=StreamConfig(rng=7))
    ms = ex.run(max_tasks=30)
    s = ms.summary()
    assert "deadline_miss_rate" in s
    for r in ms.to_records():
        assert np.isfinite(r["deadline"]) and r["deadline"] > r["t_arrive"]
    srcs2 = [PoissonProcess(m, rate=0.01, seed=1) for m in range(sc.M)]
    s2 = StreamingExecutor(sc, srcs2, config=StreamConfig(rng=7)) \
        .run(max_tasks=30).summary()
    assert "deadline_miss_rate" not in s2


# ---------------------------------------------------------------------------
# Max-min fair share policy through the engine
# ---------------------------------------------------------------------------

def test_fair_policy_respects_share_ledger():
    """Bursty multi-master load under the fair policy: the column-sum ≤ 1
    ledger constraint holds (utilization never exceeds 1, SharePool.acquire
    never raised) and everything completes."""
    sc = _scenario(M=3, N=6, L=48.0, seed=8)
    srcs = [PoissonProcess(m, rate=0.05, seed=1) for m in range(sc.M)]
    ex = StreamingExecutor(sc, srcs, config=StreamConfig(
        policy="fractional", rng=2,
        admission=AdmissionConfig(policy="fair")))
    ms = ex.run(max_tasks=60)
    assert ms.summary()["tasks_completed"] == 60
    assert ms.utilization().max() <= 1.0 + 1e-6
    assert np.isfinite(ms.sojourns()).all()


def test_fair_policy_avoids_cross_master_blocking():
    """Master 0 floods the system; master 1's lone task must not wait for
    the whole backlog under the fair policy (it does under FIFO)."""
    sc = _scenario(M=2, N=4, L=64.0, seed=11)
    times0 = [0.0] * 10
    srcs = [TraceProcess(0, times0), TraceProcess(1, [1.0])]

    def wait_of_master1(policy):
        ex = StreamingExecutor(
            sc, srcs_for(policy), config=StreamConfig(
                policy="fractional", rng=3,
                admission=AdmissionConfig(min_fraction=0.9, policy=policy)))
        ms = ex.run(max_tasks=11)
        recs = [r for r in ms.to_records() if r["master"] == 1]
        assert len(recs) == 1
        return recs[0]["queue_wait"]

    def srcs_for(policy):
        return [TraceProcess(0, times0), TraceProcess(1, [1.0])]

    w_fifo = wait_of_master1("fifo")
    w_fair = wait_of_master1("fair")
    assert w_fair < w_fifo, (w_fair, w_fifo)


# ---------------------------------------------------------------------------
# Speculative re-dispatch
# ---------------------------------------------------------------------------

def test_speculation_triggers_and_never_double_counts():
    """Heavy degradation makes in-flight tasks slip; speculation races a
    twin before any leave proves the original lost.  Every task completes
    exactly once, with delivered ≥ needed rows."""
    sc = _scenario(M=2, N=6, L=64.0, seed=13)
    churn = [WorkerEvent(t, w, "degrade", 25.0)
             for t in (40.0, 80.0, 120.0) for w in (1, 2, 3)]
    srcs = [PoissonProcess(m, rate=0.02, seed=1) for m in range(sc.M)]
    ex = StreamingExecutor(
        sc, srcs, config=StreamConfig(
            policy="fractional", rng=5,
            admission=AdmissionConfig(speculate_factor=1.2)),
        churn=churn)
    ms = ex.run(max_tasks=30)
    s = ms.summary()
    assert s["tasks_completed"] == 30
    assert s["speculations"] > 0
    recs = ms.to_records()
    tids = [r["tid"] for r in recs]
    assert len(tids) == len(set(tids))          # one completion per task
    assert any(r["speculated"] for r in recs)
    for r in recs:
        assert r["rows_delivered"] >= r["rows_needed"] - 1e-6, r
    assert ms.utilization().max() <= 1.0 + 1e-6


def test_speculation_improves_p99_under_degradation():
    """The insurance pays: with heavy mid-flight slowdowns, racing a twin
    lowers (or matches) tail sojourn on a fixed seed."""
    sc = _scenario(M=2, N=6, L=64.0, seed=13)
    churn = [WorkerEvent(t, w, "degrade", 25.0)
             for t in (40.0, 80.0, 120.0) for w in (1, 2, 3)]

    def p99(spec):
        srcs = [PoissonProcess(m, rate=0.02, seed=1) for m in range(sc.M)]
        ex = StreamingExecutor(
            sc, srcs, config=StreamConfig(
                policy="fractional", rng=5,
                admission=AdmissionConfig(speculate_factor=spec)),
            churn=churn)
        return ex.run(max_tasks=30).summary()["sojourn_p99"]

    assert p99(1.2) <= p99(None) * 1.01


def test_speculation_with_leave_churn_survives():
    """Speculation + worker death: whichever attempt survives finishes the
    task; stale completions of cancelled attempts never finalize."""
    sc = _scenario(M=1, N=4, L=64.0, seed=20)
    srcs = [TraceProcess(0, [0.0, 1.0, 2.0, 3.0])]
    churn = [WorkerEvent(10.0, 1, "degrade", 30.0),
             WorkerEvent(30.0, 2, "leave"),
             WorkerEvent(40.0, 1, "leave")]
    ex = StreamingExecutor(
        sc, srcs, config=StreamConfig(
            policy="fractional", rng=1,
            admission=AdmissionConfig(speculate_factor=1.1)),
        churn=churn)
    ms = ex.run(max_tasks=4)
    recs = ms.to_records()
    assert len(recs) == 4
    for r in recs:
        assert r["rows_delivered"] >= r["rows_needed"] - 1e-6, r
        assert np.isfinite(r["t_complete"])


def test_twin_losing_after_original_completion_never_double_counts():
    """Regression pin for the COMPLETION version check: a speculative twin
    whose completion event fires *after* the original already finalized
    must be a no-op — no second completion record, no share-ledger
    underflow, no extra throughput or deadline-miss accounting."""
    sc = _scenario(M=1, N=4, L=64.0, seed=20)
    srcs = [TraceProcess(0, [0.0], deadlines=[5000.0])]
    ex = StreamingExecutor(
        sc, srcs, config=StreamConfig(
            policy="fractional", rng=1,
            admission=AdmissionConfig(speculate_factor=1.1)))
    ex._ran = True
    ex.max_tasks = 1
    ex._on_arrival(0, 0.0)
    assert 0 in ex.inflight and not ex.twins
    fl = ex.inflight[0]
    # race a twin on the spare columns (what _maybe_speculate dispatches)
    tw = ex._dispatch(0, 1.0, min_fraction=1e-3)
    assert tw is not None and tw.version != fl.version
    tw.speculative = True
    ex.twins[0] = tw
    k_used = ex.pool.k_used.copy()
    assert (k_used[1:] > 0).any()
    # the original completes first: twin must be cancelled and released
    ex._on_completion((0, fl.version), fl.completion)
    assert ex.metrics.summary()["tasks_completed"] == 1
    assert 0 not in ex.twins and 0 not in ex.inflight
    assert (ex.pool.k_used == 0).all() and (ex.pool.b_used == 0).all()
    # the loser's stale completion event fires later: pure no-op
    before = (len(ex.metrics.completed), ex.pool.k_used.copy())
    ex._on_completion((0, tw.version), tw.completion)
    ex._on_completion((0, fl.version), fl.completion + 1.0)   # double-fire
    assert len(ex.metrics.completed) == before[0]
    assert (ex.pool.k_used == before[1]).all()
    s = ex.metrics.summary()
    assert s["tasks_completed"] == 1
    assert s["deadline_miss_rate"] == 0.0        # one verdict, not two
    recs = ex.metrics.to_records()
    assert [r["tid"] for r in recs] == [0]
    assert recs[0]["rows_delivered"] >= recs[0]["rows_needed"] - 1e-6


def test_policy_runs_replay_deterministically():
    """EDF + fair + speculation: same seed → identical records."""
    sc = _scenario(M=2, N=6, L=48.0, seed=5)
    churn = [WorkerEvent(100.0, 3, "degrade", 6.0)]

    def run(policy):
        srcs = [PoissonProcess(m, rate=0.02, seed=1, deadline_slack=2.0)
                for m in range(sc.M)]
        ex = StreamingExecutor(
            sc, srcs, config=StreamConfig(
                policy="fractional", rng=11,
                admission=AdmissionConfig(policy=policy,
                                          speculate_factor=1.3)),
            churn=churn)
        return ex.run(max_tasks=40)

    for policy in ("edf", "fair"):
        a, b = run(policy), run(policy)
        assert a.summary() == b.summary()
        assert a.to_records() == b.to_records()


def test_crashed_original_promotes_twin_as_primary():
    """Regression pin for twin promotion: when the original attempt dies
    (a crash fault or churn drives its completion to +inf) while a
    speculative twin races, the twin is promoted to the task's primary
    attempt — ``speculative`` must flip back to False so a *later*
    straggle can legitimately race a fresh twin against it — and the
    dropped attempt's shares are released without touching the twin's."""
    sc = _scenario(M=1, N=4, L=64.0, seed=20)
    srcs = [TraceProcess(0, [0.0])]
    ex = StreamingExecutor(
        sc, srcs, config=StreamConfig(
            policy="fractional", rng=1,
            admission=AdmissionConfig(speculate_factor=1.1)))
    ex._ran = True
    ex.max_tasks = 1
    ex._on_arrival(0, 0.0)
    fl = ex.inflight[0]
    tw = ex._dispatch(0, 1.0, min_fraction=1e-3)
    assert tw is not None
    tw.speculative = True
    ex.twins[0] = tw
    held = ex.pool.k_used.copy()
    # every delivery of the original is lost (what a crash fault does to
    # its finish times): the retime must drop it and promote the twin
    fl.finish[:] = np.inf
    ex._retime(fl, 2.0)
    assert ex.inflight[0] is tw and 0 not in ex.twins
    assert tw.speculative is False            # promoted = primary again
    # the original's worker shares are released; only the twin's remain
    # (column 0 is the master's own compute and is never ledgered)
    np.testing.assert_allclose(ex.pool.k_used[1:], tw.k_row[1:], atol=1e-12)
    # the survivor completes the task exactly once, ledger drains to zero
    ex._on_completion((0, tw.version), tw.completion)
    assert ex.metrics.summary()["tasks_completed"] == 1
    assert (ex.pool.k_used == 0).all() and (ex.pool.b_used == 0).all()
    recs = ex.metrics.to_records()
    assert [r["tid"] for r in recs] == [0]
    assert recs[0]["rows_delivered"] >= recs[0]["rows_needed"] - 1e-6
