"""Observability: tracer core, exporters, engine/bridge integration, and
the NaN-safe metrics guard that rides along.

The tracer's contracts under test:

* span nesting/ordering is deterministic — children append before their
  parent (exit order), sequence numbers strictly increase, parents cover
  their children's intervals;
* a *disabled* tracer is indistinguishable from no tracer (normalises to
  None at every entry point) and costs < 2% on a 1k-task stream run;
* the Chrome-trace export round-trips through ``json.loads`` and every
  event carries the required ``name/ph/ts/pid/tid`` keys (``dur`` on
  complete events), with wall and sim time as separate pid groups.
"""
import json
import time

import numpy as np
import pytest

from repro.core.problem import Scenario
from repro.obs import (STAGE_CATS, Tracer, check_trace, current_tracer,
                       device_span, use_tracer)
from repro.stream import (BackendConfig, PoissonProcess, StreamConfig,
                          StreamingExecutor, WorkerEvent)
from repro.stream.metrics import StreamMetrics, TaskRecord


def _scenario(M=2, N=8, L=96.0, seed=3):
    rng = np.random.default_rng(seed)
    a = np.zeros((M, N + 1))
    a[:, 0] = 0.5
    a[:, 1:] = rng.uniform(0.2, 0.4, size=(M, N))
    return Scenario(a=a, u=1 / a, gamma=2 / a, L=np.full(M, L))


def _run_stream(tracer, max_tasks=40, churn=(), numerics="none"):
    sc = _scenario()
    srcs = [PoissonProcess(m, rate=0.05, seed=1) for m in range(sc.M)]
    cfg = StreamConfig(rng=7, backend=BackendConfig(numerics=numerics))
    ex = StreamingExecutor(sc, srcs, config=cfg, churn=churn, tracer=tracer)
    return ex.run(max_tasks=max_tasks)


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering_deterministic():
    tr = Tracer()
    with tr.span("outer", cat="step"):
        with tr.span("inner_a", cat="plan"):
            pass
        with tr.span("inner_b", cat="decode") as a:
            a["note"] = 1
    assert [s.name for s in tr.spans] == ["inner_a", "inner_b", "outer"]
    seqs = [s.seq for s in tr.spans]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    outer = tr.spans[-1]
    for child in tr.spans[:-1]:
        assert outer.t0 <= child.t0 <= child.t1 <= outer.t1
    assert tr.spans[1].args == {"note": 1}
    # same structure twice -> same names/cats/ordering (timestamps differ)
    tr2 = Tracer()
    with tr2.span("outer", cat="step"):
        with tr2.span("inner_a", cat="plan"):
            pass
        with tr2.span("inner_b", cat="decode"):
            pass
    assert [(s.name, s.cat) for s in tr.spans] == \
        [(s.name, s.cat) for s in tr2.spans]


def test_add_span_sanitizes_endpoints():
    tr = Tracer()
    assert tr.add_span("nan", float("nan"), 1.0) is None
    assert tr.add_span("inf", 0.0, float("inf")) is None
    sp = tr.add_span("rev", 2.0, 1.0)            # reversed endpoints swap
    assert (sp.t0, sp.t1) == (1.0, 2.0) and sp.dur == 1.0
    assert len(tr.spans) == 1


def test_disabled_tracer_records_nothing_and_normalizes_to_none():
    tr = Tracer(enabled=False)
    with tr.span("s", cat="plan"):
        tr.count("c")
        tr.gauge("g", 3.0)
        tr.instant("i")
        tr.add_span("a", 0.0, 1.0)
    assert not tr.spans and not tr.instants and not tr.counters
    with use_tracer(tr) as t:
        assert t is None and current_tracer() is None
    with use_tracer(Tracer()) as t:
        assert current_tracer() is t
    assert current_tracer() is None              # restored on exit


def test_device_span_no_tracer_passthrough():
    x = object()
    with device_span("k", cat="kernel") as fence:
        assert fence(x) is x                     # untouched when off


def test_counters_and_gauges_accumulate():
    tr = Tracer()
    tr.count("hits")
    tr.count("hits", 2)
    tr.gauge("depth", 5.0, t=10.0, track="sim")
    tr.gauge("depth", 2.0, t=20.0, track="sim")
    assert tr.counters["hits"] == 3.0
    assert tr.counters["depth"] == 2.0           # gauge = last level
    assert len(tr.counter_samples) == 4


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_roundtrips_with_required_keys(tmp_path):
    tr = Tracer(meta={"case": "roundtrip"})
    _run_stream(tr, max_tasks=20, numerics="verify",
                churn=[WorkerEvent(50.0, 2, "degrade", 3.0)])
    path = tmp_path / "trace.json"
    tr.write(str(path))
    obj = json.loads(path.read_text())           # round-trips through JSON
    events = obj["traceEvents"]
    assert events
    for ev in events:
        for key in ("name", "ph", "ts", "pid", "tid"):
            assert key in ev, ev
        if ev["ph"] == "X":
            assert "dur" in ev and ev["dur"] >= 0.0
    # both clocks present as distinct pid groups (1 = wall, 2 = sim)
    pids = {ev["pid"] for ev in events if ev["ph"] != "M"}
    assert pids >= {1, 2}
    # per-worker sim lanes became threads with metadata names
    names = {ev["args"]["name"] for ev in events
             if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert any(n.startswith("worker") for n in names)
    ok, problems = check_trace(obj)
    assert ok, problems


def test_check_trace_flags_broken_files():
    ok, problems = check_trace({"traceEvents": []})
    assert not ok and problems
    ok, problems = check_trace(
        {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0}]})
    assert not ok                                # missing pid/tid/dur


def test_summary_rolls_stages_counters_and_stragglers():
    tr = Tracer()
    ms = _run_stream(tr, max_tasks=30, numerics="verify",
                     churn=[WorkerEvent(40.0, 1, "leave")])
    s = tr.summary(top_k=3)
    assert set(s["per_stage_wall"]) == set(STAGE_CATS)
    assert s["span_count"] == len(tr.spans)
    assert tr.counters.get("churn_retimes", 0) >= 0
    assert s["stragglers"], "delivery spans should yield a straggler table"
    top = s["stragglers"][0]
    assert {"worker", "task", "sim_duration", "critical"} <= set(top)
    durs = [row["sim_duration"] for row in s["stragglers"]]
    assert durs == sorted(durs, reverse=True)
    assert ms.summary()["tasks_completed"] == 30


# ---------------------------------------------------------------------------
# Disabled-mode overhead (the contract the whole design hangs on)
# ---------------------------------------------------------------------------

def test_disabled_tracer_overhead_under_2pct_on_1k_task_stream():
    """An attached-but-disabled tracer must serve the identical code path:
    best-of-3 wall time within 2% (plus a small absolute slack for timer
    granularity) of the no-tracer run on a 1k-task stream."""
    def best(tracer_factory, reps=3):
        b = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            _run_stream(tracer_factory(), max_tasks=1000)
            b = min(b, time.perf_counter() - t0)
        return b

    best(lambda: None, reps=1)                   # warm caches/jit once
    t_none = best(lambda: None)
    t_disabled = best(lambda: Tracer(enabled=False))
    assert t_disabled <= t_none * 1.02 + 0.05, (t_disabled, t_none)


# ---------------------------------------------------------------------------
# Engine integration: spans in both time domains
# ---------------------------------------------------------------------------

def test_engine_emits_sim_and_wall_spans_side_by_side():
    tr = Tracer()
    _run_stream(tr, max_tasks=25, numerics="verify",
                churn=[WorkerEvent(60.0, 2, "degrade", 2.0)])
    cats = {s.cat for s in tr.spans}
    assert {"run", "task", "delivery", "verify"} <= cats
    tracks = {s.track for s in tr.spans}
    assert "wall" in tracks
    assert any(t.startswith("sim:worker") for t in tracks)
    # every task's service span contains its per-worker delivery spans
    service = {s.args["task"]: s for s in tr.spans if s.cat == "task"}
    for d in (s for s in tr.spans if s.cat == "delivery"):
        sv = service[d.args["task"]]
        assert sv.t0 <= d.t0 and (not d.args["delivered"]
                                  or d.t1 <= sv.t1 + 1e-9)
    # a critical (prefix-closing) delivery is attributed per completed task
    # (>= 1: simultaneous finishes can tie on the closing timestamp)
    for tid in service:
        crit = [d for d in tr.spans if d.cat == "delivery"
                and d.args["task"] == tid and d.args["critical"]]
        assert len(crit) >= 1, tid


def test_flat_records_export_is_pandas_ready():
    tr = Tracer()
    _run_stream(tr, max_tasks=10)
    rows = tr.to_records()
    assert rows and all(isinstance(r, dict) for r in rows)
    base_keys = {"seq", "kind", "name", "cat", "track", "t0", "t1", "dur"}
    assert all(base_keys <= set(r) for r in rows
               if r["kind"] in ("span", "instant"))
    seqs = [r["seq"] for r in rows if "seq" in r]
    assert seqs == sorted(seqs)


# ---------------------------------------------------------------------------
# StreamMetrics NaN-safety (satellite regression)
# ---------------------------------------------------------------------------

def test_metrics_summary_empty_pool_is_nan_free():
    ms = StreamMetrics(2, 4)
    s = ms.summary()
    for key, val in s.items():
        assert np.isfinite(val), (key, val)
    assert s["tasks_completed"] == 0.0
    assert s["utilization_mean"] == 0.0 and s["utilization_max"] == 0.0
    assert (ms.utilization() == 0.0).all()


def test_metrics_summary_partial_records_omit_unfinished_keys():
    ms = StreamMetrics(1, 2)
    # a record that never completed: NaN completion, no admit time
    r = TaskRecord(tid=0, master=0, t_arrive=1.0)
    ms.record_unserved(r)
    # one real completion with no queue wait recorded
    done = TaskRecord(tid=1, master=0, t_arrive=0.0)
    done.t_admit = np.nan
    done.t_complete = 5.0
    ms.record_task(done)
    s = ms.summary()
    for key, val in s.items():
        assert np.isfinite(val), (key, val)
    assert "queue_wait_mean" not in s            # omitted, not NaN
    assert s["tasks_completed"] == 1.0
