"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import coded_matvec, matmul, mds_encode, ref, wkv6

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(128, 128, 128), (200, 300, 170),
                                   (64, 257, 33), (512, 128, 256)])
def test_matmul_sweep(shape, dtype):
    M, K, N = shape
    a = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    b = jnp.asarray(RNG.normal(size=(K, N)), dtype)
    got = matmul(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("L,Lt,S", [(100, 250, 333), (128, 256, 128),
                                    (60, 60, 70)])
def test_mds_encode_sweep(L, Lt, S):
    G = np.asarray(RNG.normal(0, 1 / np.sqrt(L), size=(Lt, L)), np.float32)
    G[:L] = np.eye(L)
    G = jnp.asarray(G)
    A = jnp.asarray(RNG.normal(size=(L, S)), jnp.float32)
    got = mds_encode(G, A, interpret=True)
    np.testing.assert_allclose(got, ref.mds_encode_ref(G, A),
                               rtol=2e-3, atol=2e-3)
    # systematic prefix passes through bit-exact
    np.testing.assert_array_equal(np.asarray(got[:L]), np.asarray(A))


@pytest.mark.parametrize("L,S,B", [(300, 333, 1), (128, 512, 4), (77, 65, 8)])
def test_coded_matvec_sweep(L, S, B):
    A = jnp.asarray(RNG.normal(size=(L, S)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(S,) if B == 1 else (S, B)), jnp.float32)
    got = coded_matvec(A, x, interpret=True)
    np.testing.assert_allclose(got, ref.coded_matvec_ref(A, x),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("T,K,V,chunk", [(64, 8, 8, 16), (80, 16, 24, 32),
                                         (128, 32, 32, 64)])
def test_wkv6_sweep(T, K, V, chunk):
    BH = 2
    r = jnp.asarray(RNG.normal(size=(BH, T, K)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(BH, T, K)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(BH, T, V)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.85, 0.999, size=(BH, T, K)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(K,)), jnp.float32)
    got = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    want = jnp.stack([ref.wkv6_chunk_ref(r[i], k[i], v[i], w[i], u)
                      for i in range(BH)])
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


def test_model_wkv_matches_kernel():
    """The model-side chunked jnp WKV equals the Pallas kernel (shared u)."""
    from repro.models.rwkv import wkv6_chunked
    B, H, T, K = 1, 2, 96, 16
    r = jnp.asarray(RNG.normal(size=(B, H, T, K)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, H, T, K)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, H, T, K)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.9, 0.999, size=(B, H, T, K)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(K,)), jnp.float32)
    out_model, _ = wkv6_chunked(r, k, v, w,
                                jnp.broadcast_to(u, (H, K)), chunk=32)
    out_kernel = wkv6(r.reshape(B * H, T, K), k.reshape(B * H, T, K),
                      v.reshape(B * H, T, K), w.reshape(B * H, T, K), u,
                      chunk=32, interpret=True)
    np.testing.assert_allclose(out_model.reshape(B * H, T, K), out_kernel,
                               rtol=2e-3, atol=2e-3)
