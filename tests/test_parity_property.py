"""Property test: parity bits are invariant to cache growth history.

Requires `hypothesis` (skipped via ``conftest.collect_ignore`` where it is
not installed; the fixed-schedule cases in ``test_virtual_parity.py``
cover the same invariant deterministically).

For an arbitrary growth schedule — any sequence of ``ensure_parity``
targets — and any gather order, the counter-derived parity stream must
produce bit-identical rows whether the cache was materialised first and
grown incrementally, grown in one shot, or never materialised at all
(``parity_storage="virtual"``), on every backend whose decode the repo
ships (numpy | jax | pallas-interpret).
"""
from hypothesis import given, settings
from hypothesis import strategies as st

import numpy as np
import pytest

from repro.serve_coded import CodedLinear

jax = pytest.importorskip("jax")

BACKENDS = ("numpy", "jax", "pallas")


def _linear(storage, backend, *, L=32, D=8, chunk=8, seed=0):
    rng = np.random.default_rng(seed)
    return CodedLinear(rng.normal(size=(L, D)), name="prop", seed=seed,
                       parity_chunk=chunk, backend=backend,
                       parity_storage=storage)


@settings(max_examples=30, deadline=None)
@given(schedule=st.lists(st.integers(min_value=1, max_value=40),
                         min_size=1, max_size=6),
       gather=st.lists(st.integers(min_value=0, max_value=39),
                       min_size=1, max_size=12),
       backend=st.sampled_from(BACKENDS))
def test_growth_schedule_invariance(schedule, gather, backend):
    grown = _linear("materialized", backend)
    for n in schedule:                       # arbitrary incremental growth
        grown.ensure_parity(n)
    grown.ensure_parity(40)

    oneshot = _linear("materialized", backend)
    oneshot.ensure_parity(40)                # same rows, one append

    virtual = _linear("virtual", backend)    # never materialised

    ids = np.asarray(gather)
    assert np.array_equal(grown.R, oneshot.R)
    assert np.array_equal(grown.parity_rows(ids), oneshot.parity_rows(ids))
    assert np.array_equal(virtual.parity_rows(ids), grown.R[ids])
    assert np.array_equal(virtual.parity_ctrs(ids), grown.parity_ctrs(ids))

    rows = np.concatenate([ids % grown.L, ids + grown.L])
    assert np.array_equal(virtual.gather_encoded(rows),
                          grown.gather_encoded(rows))
