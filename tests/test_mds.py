"""Real-MDS codec: any-L-subset decodability (the MDS property)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import decode, decode_ls, encode, make_generator, split_loads
from repro.core.mds import integer_loads
from repro.stream.backend import BACKENDS, decode_batch, has_jax


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 24), st.integers(0, 16), st.integers(0, 1000))
def test_any_subset_decodes(L, extra, seed):
    rng = np.random.default_rng(seed)
    Lt = L + extra
    G = make_generator(L, Lt, kind="gaussian", rng=rng, dtype=np.float64)
    A = rng.normal(size=(L, 7))
    x = rng.normal(size=7)
    y = encode(G, A) @ x
    rows = rng.choice(Lt, size=L, replace=False)
    np.testing.assert_allclose(decode(G, rows, y[rows]), A @ x,
                               rtol=1e-6, atol=1e-8)


def test_systematic_fast_path():
    rng = np.random.default_rng(0)
    L, Lt = 16, 40
    G = make_generator(L, Lt, kind="systematic", rng=rng)
    np.testing.assert_array_equal(np.asarray(G[:L]), np.eye(L, dtype=G.dtype))
    A = rng.normal(size=(L, 5)).astype(np.float32)
    enc = encode(G, A)
    np.testing.assert_allclose(enc[:L], A, rtol=1e-6)


def test_ls_decode_overdetermined_beats_noise():
    rng = np.random.default_rng(1)
    L, Lt = 32, 96
    G = make_generator(L, Lt, kind="gaussian", rng=rng, dtype=np.float64)
    A = rng.normal(size=(L, 3))
    x = rng.normal(size=3)
    y = encode(G, A) @ x + rng.normal(scale=1e-6, size=Lt)
    rows = np.arange(Lt)
    err_ls = np.abs(decode_ls(G, rows, y) - A @ x).max()
    err_sq = np.abs(decode(G, rows[:L], y[:L]) - A @ x).max()
    assert err_ls <= err_sq * 1.5


# ---------------------------------------------------------------------------
# Property: encode→receive→decode round-trip with partial systematic
# prefixes, across all backends
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(4, 20),            # L: task size
       st.integers(1, 14),            # n_parity: redundancy rows available
       st.data())
def test_roundtrip_partial_systematic_prefix_all_backends(L, n_parity, data):
    """decode(encode(A)·x received rows) == A·x for random (L, n, s): a
    systematic generator with n parity rows, a task that received s
    systematic rows (0 ≤ s ≤ L) and L−s parity rows — the exact shape of a
    partially-straggled serving prefix — on every backend."""
    seed = data.draw(st.integers(0, 10_000))
    s = data.draw(st.integers(max(L - n_parity, 0), L))
    rng = np.random.default_rng(seed)
    Lt = L + n_parity
    G = make_generator(L, Lt, kind="systematic", rng=rng, dtype=np.float64)
    G = np.asarray(G, dtype=np.float64)
    A = rng.normal(size=(L, 5))
    x = rng.normal(size=5)
    y_full = encode(G, A) @ x                      # every coded row's result
    rows = np.concatenate([
        rng.permutation(L)[:s],                    # received systematic rows
        L + rng.permutation(Lt - L)[:L - s],       # received parity rows
    ]).astype(np.int64)
    rng.shuffle(rows)                              # interleaved arrivals
    truth = A @ x
    for backend in BACKENDS:
        if backend != "numpy" and not has_jax():
            continue
        out = decode_batch(G, rows[None], np.asarray(y_full)[rows][None],
                           backend=backend)[0]
        # jax/pallas solve in float32 (no x64): looser tolerance, as in
        # the streaming engine's verification
        tol = dict(rtol=1e-6, atol=1e-7) if backend == "numpy" \
            else dict(rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(out, truth, **tol,
                                   err_msg=f"{backend} (L={L}, s={s})")
        # received systematic rows pin their coordinates bit-exactly
        sys_m = rows < L
        assert (out[rows[sys_m]] == np.asarray(y_full)[rows[sys_m]]).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 12), st.integers(2, 6), st.integers(0, 1000))
def test_roundtrip_batched_mixed_groups(L, B, seed):
    """A batch of tasks with different systematic counts s decodes each
    task independently (grouped substitution must not cross-contaminate)."""
    rng = np.random.default_rng(seed)
    Lt = 2 * L
    G = np.asarray(make_generator(L, Lt, kind="systematic", rng=rng,
                                  dtype=np.float64), dtype=np.float64)
    A = rng.normal(size=(B, L, 3))
    x = rng.normal(size=(B, 3))
    truth = np.einsum("bls,bs->bl", A, x)
    rows = np.empty((B, L), dtype=np.int64)
    y = np.empty((B, L))
    for b in range(B):
        s = int(rng.integers(0, L + 1))
        r = np.concatenate([rng.permutation(L)[:s],
                            L + rng.permutation(Lt - L)[:L - s]])
        rng.shuffle(r)
        rows[b] = r
        y[b] = (G[r] @ A[b]) @ x[b]
    out = decode_batch(G, rows, y)
    np.testing.assert_allclose(out, truth, rtol=1e-6, atol=1e-7)


def test_integer_loads_and_split():
    l = np.array([3.2, 0.0, 4.7, 1.0])
    li = integer_loads(l, 0)
    assert li.tolist() == [4, 0, 5, 1]
    parts = split_loads(10, [4, 0, 5, 1])
    assert [p.size for p in parts] == [4, 0, 5, 1]
    assert np.concatenate([p for p in parts if p.size]).tolist() == list(range(10))
