"""Real-MDS codec: any-L-subset decodability (the MDS property)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import decode, decode_ls, encode, make_generator, split_loads
from repro.core.mds import integer_loads


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 24), st.integers(0, 16), st.integers(0, 1000))
def test_any_subset_decodes(L, extra, seed):
    rng = np.random.default_rng(seed)
    Lt = L + extra
    G = make_generator(L, Lt, kind="gaussian", rng=rng, dtype=np.float64)
    A = rng.normal(size=(L, 7))
    x = rng.normal(size=7)
    y = encode(G, A) @ x
    rows = rng.choice(Lt, size=L, replace=False)
    np.testing.assert_allclose(decode(G, rows, y[rows]), A @ x,
                               rtol=1e-6, atol=1e-8)


def test_systematic_fast_path():
    rng = np.random.default_rng(0)
    L, Lt = 16, 40
    G = make_generator(L, Lt, kind="systematic", rng=rng)
    np.testing.assert_array_equal(np.asarray(G[:L]), np.eye(L, dtype=G.dtype))
    A = rng.normal(size=(L, 5)).astype(np.float32)
    enc = encode(G, A)
    np.testing.assert_allclose(enc[:L], A, rtol=1e-6)


def test_ls_decode_overdetermined_beats_noise():
    rng = np.random.default_rng(1)
    L, Lt = 32, 96
    G = make_generator(L, Lt, kind="gaussian", rng=rng, dtype=np.float64)
    A = rng.normal(size=(L, 3))
    x = rng.normal(size=3)
    y = encode(G, A) @ x + rng.normal(scale=1e-6, size=Lt)
    rows = np.arange(Lt)
    err_ls = np.abs(decode_ls(G, rows, y) - A @ x).max()
    err_sq = np.abs(decode(G, rows[:L], y[:L]) - A @ x).max()
    assert err_ls <= err_sq * 1.5


def test_integer_loads_and_split():
    l = np.array([3.2, 0.0, 4.7, 1.0])
    li = integer_loads(l, 0)
    assert li.tolist() == [4, 0, 5, 1]
    parts = split_loads(10, [4, 0, 5, 1])
    assert [p.size for p in parts] == [4, 0, 5, 1]
    assert np.concatenate([p for p in parts if p.size]).tolist() == list(range(10))
