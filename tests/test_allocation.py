"""Theorems 1/2/3 and the Lambert-W implementation."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (comp_dominant_loads, fractional_loads, lambertw_m1,
                        markov_loads, phi_comp_dominant, small_scale_scenario,
                        theta_dedicated)
from repro.core.delays import expected_received


def test_lambertw_identity():
    ys = -np.exp(np.linspace(np.log(1e-14), -1.0000001, 200))
    w = lambertw_m1(ys)
    np.testing.assert_allclose(w * np.exp(w), ys, rtol=1e-10)
    assert np.all(w <= -1.0)


def test_lambertw_against_scipy():
    sp = pytest.importorskip("scipy.special")
    ys = -np.exp(np.linspace(np.log(1e-12), np.log(np.exp(-1) * 0.9999), 100))
    np.testing.assert_allclose(lambertw_m1(ys), sp.lambertw(ys, k=-1).real,
                               rtol=1e-10)


def test_thm1_constraint_tight_and_redundancy_2x():
    sc = small_scale_scenario(0)
    th = theta_dedicated(sc, np.ones((sc.M, sc.N + 1)))
    l, t = markov_loads(sc.L, th)
    # P4 constraint is tight at the optimum
    lhs = (l * (1 - th * l / t[:, None])).sum(1)
    np.testing.assert_allclose(lhs, sc.L, rtol=1e-10)
    # Markov optimum always provisions 2× redundancy
    np.testing.assert_allclose(l.sum(1), 2 * sc.L, rtol=1e-10)
    # loads are inversely proportional to θ
    ratio = l * th
    np.testing.assert_allclose(ratio, np.broadcast_to(ratio[:, :1],
                                                      ratio.shape),
                               rtol=1e-10)


def test_thm2_exact_feasibility_and_optimality():
    sc = small_scale_scenario(1)
    part = np.ones((sc.M, sc.N + 1))
    l, t = comp_dominant_loads(sc.L, sc.a, sc.u, part)
    # E[X(t*)] == L exactly (constraint active at the optimum)
    huge_gamma = np.full_like(sc.gamma, 1e12)
    ex = expected_received(float(t[0]), l, part, part, sc.a, sc.u, huge_gamma)
    np.testing.assert_allclose(ex[0], sc.L[0], rtol=1e-6)
    # perturbing loads (same total) cannot beat t*: check a few directions
    rng = np.random.default_rng(0)
    m = 0
    for _ in range(20):
        d = rng.normal(size=sc.N + 1)
        d -= d.mean()
        l2 = np.maximum(l[m] + 0.01 * sc.L[m] * d / np.abs(d).max(), 1e-3)
        ex2 = expected_received(float(t[m]), l2[None], part[:1], part[:1],
                                sc.a[:1], sc.u[:1], huge_gamma[:1])
        # feasible perturbations deliver no more than the optimum needs
        assert ex2[0] <= sc.L[m] * (1 + 5e-2)


def test_phi_positive_decreasing_in_u():
    a = 0.3
    us = np.linspace(0.5, 50, 20)
    phi = phi_comp_dominant(a, us)
    assert np.all(phi > 0)
    assert np.all(np.diff(phi) < 0)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.integers(1, 4), st.integers(0, 10_000))
def test_thm1_properties_random(n_workers, m_masters, seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.05, 0.5, size=(m_masters, n_workers + 1))
    u = 1.0 / a
    gamma = rng.uniform(0.5, 4.0, size=a.shape) * u
    L = rng.uniform(1e3, 1e5, size=m_masters)
    from repro.core import Scenario
    sc = Scenario(a=a, u=u, gamma=gamma, L=L)
    th = theta_dedicated(sc, np.ones_like(a))
    l, t = markov_loads(sc.L, th)
    assert np.all(l >= 0) and np.all(t > 0)
    # adding a worker (finite θ) can only reduce t*: drop one and compare
    th_drop = th.copy()
    th_drop[:, -1] = np.inf
    _, t_drop = markov_loads(sc.L, th_drop)
    assert np.all(t <= t_drop + 1e-9)


def test_thm3_matches_markov_form():
    sc = small_scale_scenario(2)
    th = theta_dedicated(sc, np.ones((sc.M, sc.N + 1)))
    l1, t1 = markov_loads(sc.L, th)
    l3, t3 = fractional_loads(sc.L, th)
    np.testing.assert_allclose(l1, l3)
    np.testing.assert_allclose(t1, t3)
    # KKT condition: l* = t*/(2θ)
    np.testing.assert_allclose(l3, t3[:, None] / (2 * th), rtol=1e-10)
