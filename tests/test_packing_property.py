"""Property test: packed/padded batched shard execution ≡ the serial path.

Hypothesis drives ragged shard-size distributions, straggler/delivery
patterns (including 0 < s < L mixed-row substitution groups and
coverage-boundary truncation) and matrix shapes; for every draw the
packed stage execution must be *bit-identical* to the serial
shard-by-shard reference on numpy — products and decoded outputs — and
agree to float32 tolerance on the jax / pallas-interpret device tile
path (the decode-feeding products are float64 host-side on every
backend, so greedy-token parity is backend-independent).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve_coded import CodedLinear, PackedStage, ShardProblem
from repro.serve_coded.coded_linear import shard_products

jax = pytest.importorskip("jax")


@st.composite
def ragged_dispatch(draw):
    L = draw(st.sampled_from([8, 24, 48]))
    D = draw(st.sampled_from([4, 16]))
    n_nodes = draw(st.integers(2, 6))
    # shard sizes: ragged, Σ ≥ L (zero-load nodes allowed)
    sizes = draw(st.lists(st.integers(0, L), min_size=n_nodes,
                          max_size=n_nodes))
    deficit = L + draw(st.integers(0, L)) - sum(sizes)
    if deficit > 0:
        sizes[draw(st.integers(0, n_nodes - 1))] += deficit
    # delivery times: permuted ranks with some nodes never arriving
    ranks = draw(st.permutations(list(range(n_nodes))))
    dead = draw(st.lists(st.integers(0, n_nodes - 1), max_size=2))
    finish = np.array([float(r + 1) for r in ranks])
    l_int = np.array(sizes, dtype=np.int64)
    for i in dead:
        if l_int.sum() - l_int[i] >= L:
            finish[i] = np.inf
    t_complete = float(draw(st.integers(n_nodes // 2, n_nodes + 1)))
    use_assign = draw(st.booleans())
    assign = (np.asarray(draw(st.permutations(list(range(n_nodes)))),
                         dtype=float) if use_assign else None)
    seed = draw(st.integers(0, 2**16))
    return L, D, l_int, finish, t_complete, assign, seed


@settings(max_examples=40, deadline=None)
@given(ragged_dispatch(), st.integers(1, 3))
def test_packed_execution_bit_identical_to_serial(dispatch, n_problems):
    L, D, l_int, finish, t_complete, assign, seed = dispatch
    rng = np.random.default_rng(seed)
    problems, linears, steps = [], [], []
    for i in range(n_problems):
        lin = CodedLinear(rng.normal(size=(L, D)), name=f"p{i}",
                          seed=seed + i, parity_chunk=32)
        try:
            plan = lin.prefix_plan(l_int, finish, t_complete,
                                   assign=assign)
        except (ValueError, RuntimeError):
            return                              # uncoverable draw: skip
        X = rng.normal(size=(2, D))
        res = lin.step(X, l_int, finish, t_complete, assign=assign)
        problems.append(ShardProblem(key=f"p{i}", linear=lin,
                                     rows=plan.rows,
                                     used_solve=plan.used_solve))
        linears.append(lin)
        steps.append((X, res, plan))

    for p, lin, (X, res, plan) in zip(problems, linears, steps):
        one = PackedStage([p], backend="numpy")
        # packed products == serial per-worker products, bitwise
        enc = lin._enc[:lin._n_enc]
        serial_y = np.concatenate(
            [shard_products(enc[sl], X) for sl in plan.slices])
        assert (one.pack.products(X)[0] == serial_y).all()
        # packed decode == serial decode, bitwise (numpy engine)
        out = one.execute(X)[p.key]
        assert (out == res.out).all()
        np.testing.assert_allclose(out, X @ lin.W.T, atol=1e-7)

    # multi-problem stage: same X for all members (stacked decode groups,
    # incl. same-(L, s) members solved in one launch) stays bitwise equal
    X = rng.normal(size=(2, D))
    stage = PackedStage(problems, backend="numpy")
    outs = stage.execute(X)
    for p, lin in zip(problems, linears):
        res = lin.step(X, l_int, finish, t_complete, assign=assign)
        assert (outs[p.key] == res.out).all()


@settings(max_examples=10, deadline=None)
@given(ragged_dispatch())
def test_device_tile_path_matches_host_products(dispatch):
    L, D, l_int, finish, t_complete, assign, seed = dispatch
    rng = np.random.default_rng(seed)
    lin = CodedLinear(rng.normal(size=(L, D)), name="dev", seed=seed,
                      parity_chunk=32, backend="jax")
    try:
        plan = lin.prefix_plan(l_int, finish, t_complete, assign=assign)
    except (ValueError, RuntimeError):
        return
    p = ShardProblem(key="dev", linear=lin, rows=plan.rows,
                     used_solve=plan.used_solve)
    X = rng.normal(size=(2, D))
    for backend in ("jax", "pallas"):
        stage = PackedStage([p], backend=backend)
        host = stage.pack.products(X)[0]
        dev = stage.pack.products_device(X, backend=backend)[0]
        # float32 gather+dot/kernel over the padded tiles; padding must
        # wash out exactly
        assert dev.shape == host.shape
        assert np.abs(dev - host).max() <= 1e-3 * (1 + np.abs(host).max())
