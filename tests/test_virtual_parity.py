"""Virtual parity rows: counter-generated MDS parity, cross-mode parity.

The tentpole invariant: serving with ``parity_storage="virtual"`` — parity
generator rows derived in-kernel (or per host block) from packed threefry
counters, never materialised as a ``[W; WR]`` cache — produces
**bit-identical greedy tokens** to the materialised mode, at every
``coding_scope`` and on every backend (numpy | jax | pallas-interpret).
Underneath it, the replay fix: every parity row is a pure function of
``(seed, name, row index)``, independent of the cache's growth history.
"""
import warnings

import numpy as np
import pytest

from repro.core import mds
from repro.serve_coded import (CODING_SCOPES, CodedLinear,
                               CodedServingBridge, synthetic_requests)
from repro.serve_coded.packing import PackedStage, ShardProblem
from repro.stream import AdmissionConfig
from repro.stream import backend as bk

jax = pytest.importorskip("jax")

BACKENDS = ("numpy", "jax", "pallas")


def _serve(scope, parity_storage, *, backend="numpy", n=3, gen=2, seed=0):
    bridge = CodedServingBridge(
        masters=2, seed=seed, slots_per_master=2, coding_scope=scope,
        backend=backend, parity_storage=parity_storage,
        admission=AdmissionConfig(policy="edf"))
    bridge._setup_model(16 + gen + 8)
    reqs = synthetic_requests(
        n, masters=2, vocab=bridge._model["cfg"].vocab, prompt_len=16,
        gen_len=gen, rate=0.02, seed=seed)
    return bridge.serve(reqs)


def _linear(storage, *, L=48, D=16, seed=0, chunk=8, backend="numpy"):
    rng = np.random.default_rng(seed)
    return CodedLinear(rng.normal(size=(L, D)), name=f"v{L}x{D}", seed=seed,
                       parity_chunk=chunk, backend=backend,
                       parity_storage=storage)


# ---------------------------------------------------------------------------
# The acceptance matrix: scope × backend, virtual vs materialised serving
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("scope", CODING_SCOPES)
def test_virtual_serving_token_identical(scope, backend):
    mat = _serve(scope, "materialized", backend=backend)
    virt = _serve(scope, "virtual", backend=backend)
    assert virt.tokens == mat.tokens             # bit-identical token ids
    assert virt.decode_ok and mat.decode_ok, (scope, backend, virt.max_err)
    assert virt.max_err == mat.max_err           # same decoded values
    assert virt.parity_storage == "virtual"
    assert mat.parity_storage == "materialized"
    # satellite: the report says which backend actually ran
    assert virt.backend == backend
    assert virt.backend_effective == (backend if bk.has_jax() else "numpy")
    for s in virt.steps:
        assert s["parity_storage"] == "virtual"
        assert s["backend"] == virt.backend_effective


# ---------------------------------------------------------------------------
# Replay fix: rows are growth-history independent, cross-mode bit-equal
# ---------------------------------------------------------------------------

def test_parity_rows_independent_of_growth_history():
    a = _linear("materialized")
    b = _linear("materialized")
    a.ensure_parity(10)       # grows by 8-row chunks: two appends
    a.ensure_parity(40)
    b.ensure_parity(40)       # one append of the same blocks
    assert np.array_equal(a.R, b.R)
    assert np.array_equal(a._enc[:a._n_enc], b._enc[:b._n_enc])
    # virtual twin, gathered in arbitrary order, carries identical bits
    v = _linear("virtual")
    ids = np.array([37, 2, 19, 5])
    assert np.array_equal(v.parity_rows(ids), a.R[ids])
    rows = np.array([0, 47, 48, 50, 85, 3])
    assert np.array_equal(v.gather_encoded(rows),
                          a.gather_encoded(rows))
    assert np.array_equal(v.parity_ctrs(ids), a.parity_ctrs(ids))


def test_serial_step_bit_identical_across_modes():
    X = np.random.default_rng(1).normal(size=(4, 16))
    l_int = np.array([12, 18, 18, 24, 24])
    finish = np.array([99.0, 2.0, 3.0, 1.0, 4.0])    # straggler → solve
    outs = {}
    for storage in ("materialized", "virtual"):
        lin = _linear(storage)
        res = lin.step(X, l_int, finish, 4.0)
        assert res.used_solve
        outs[storage] = res.out
        np.testing.assert_allclose(res.out, X @ lin.W.T, atol=1e-8)
    assert np.array_equal(outs["materialized"], outs["virtual"])


def test_prefix_plan_carries_packed_counters():
    lin = _linear("virtual")
    plan = lin.prefix_plan(np.array([12, 18, 18, 24, 24]),
                           np.array([99.0, 2.0, 3.0, 1.0, 4.0]), 4.0)
    assert plan.used_solve and plan.parity_ctrs is not None
    par = plan.rows[plan.rows >= lin.L] - lin.L
    assert np.array_equal(plan.parity_ctrs, lin.parity_ctrs(par))
    # counters alone reproduce the rows (the frozen-plan replay contract)
    assert np.array_equal(
        mds.counter_parity_rows(lin.pkey, plan.parity_ctrs, lin.L),
        lin.parity_rows(par))


# ---------------------------------------------------------------------------
# Packed execution: host bit-identity, device generated-parity kernel
# ---------------------------------------------------------------------------

def _stage_pair(backend="numpy", D=24, Ls=(48, 48, 96)):
    stages = {}
    for storage in ("materialized", "virtual"):
        rng = np.random.default_rng(0)
        problems = []
        for i, L in enumerate(Ls):
            lin = CodedLinear(rng.normal(size=(L, D)), name=f"m{i}", seed=i,
                              backend=backend, parity_storage=storage)
            l_int = np.array([0, L // 3, L // 2, L // 2, L])
            finish = rng.permutation(np.arange(5).astype(float) + 1.0)
            finish[0] = np.inf
            plan = lin.prefix_plan(l_int, finish, t_complete=5.0)
            problems.append(ShardProblem(key=f"m{i}", linear=lin,
                                         rows=plan.rows,
                                         used_solve=plan.used_solve))
        stages[storage] = PackedStage(problems, backend=backend)
    return stages


def test_packed_stage_host_bit_identical_across_modes():
    stages = _stage_pair()
    X = np.random.default_rng(2).normal(size=(5, 24))
    assert np.array_equal(stages["materialized"].pack.W_packed,
                          stages["virtual"].pack.W_packed)
    mat = stages["materialized"].execute(X)
    virt = stages["virtual"].execute(X)
    assert set(mat) == set(virt)
    for k in mat:
        assert np.array_equal(mat[k], virt[k])


@pytest.mark.parametrize("backend", ("jax", "pallas"))
def test_packed_stage_device_generated_parity_matches(backend):
    stages = _stage_pair(backend=backend)
    X = np.random.default_rng(3).normal(size=(5, 24))
    host = stages["materialized"].execute(X, device_products=False)
    mat = stages["materialized"].execute(X, device_products=True)
    virt = stages["virtual"].execute(X, device_products=True)
    for k in host:
        # float32 device products (materialised gather vs in-kernel
        # generation) both track the float64 host decode
        assert np.abs(mat[k] - host[k]).max() < 1e-3, (backend, k)
        assert np.abs(virt[k] - host[k]).max() < 1e-3, (backend, k)


def test_kernel_generator_bit_equals_host_derivation():
    from repro.kernels import ops
    key = (0xDEADBEEF, 41)
    L = 200                                   # non-multiple of the block
    ctrs = mds.parity_counters(np.array([0, 3, 129, 500]), [0, 1, 0, 2])
    host = mds.counter_parity_rows(key, ctrs, L, dtype=np.float32)
    dev = np.asarray(ops.counter_parity_rows(key, L, ctrs))
    assert np.array_equal(host, dev)


def test_fused_generation_kernel_matches_xla_twin():
    """The TPU-path fused kernel (R derived in-VMEM, tile contraction)
    agrees with the XLA twin `gen_parity_products` routes to off-TPU —
    same rows, reduction order differs, so float32 tolerance."""
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.mds_encode import gen_parity_matvec_pallas
    rng = np.random.default_rng(5)
    L, D, C = 96, 40, 3
    key = (123, 456)
    ctrs = mds.parity_counters(np.arange(7), 0)
    w = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(D, C)), jnp.float32)
    xla = np.asarray(ops.gen_parity_products(key, ctrs, w, x,
                                             interpret=True))
    key_arr = jnp.asarray(np.asarray(key, np.uint32)[None, :])
    scale = jnp.full((1, 1), np.float32(np.sqrt(3.0 / L)), jnp.float32)
    ctrs_p = jnp.zeros((128, 1), jnp.uint32).at[:7, 0].set(
        jnp.asarray(ctrs))
    wp = jnp.zeros((128, 128), jnp.float32).at[:L, :D].set(w)
    xp = jnp.zeros((128, C), jnp.float32).at[:D].set(x)
    fused = np.asarray(gen_parity_matvec_pallas(
        key_arr, scale, ctrs_p, wp, xp, block_rows=128, block_k=128,
        interpret=True))[:7]
    exact = mds.counter_parity_rows(key, ctrs, L) @ (
        np.asarray(w, np.float64) @ np.asarray(x, np.float64))
    assert np.abs(fused - xla).max() < 1e-4
    assert np.abs(xla - exact).max() < 1e-3


# ---------------------------------------------------------------------------
# Satellite: the silent backend downgrade now warns and is recorded
# ---------------------------------------------------------------------------

def test_backend_fallback_warns_and_records(monkeypatch):
    monkeypatch.setattr(bk, "has_jax", lambda: False)
    with pytest.warns(RuntimeWarning, match="falling back"):
        lin = CodedLinear(np.eye(8), name="nb", backend="pallas")
    assert lin.backend == "numpy"
    assert lin.requested_backend == "pallas"
    assert lin.decode_backend == "numpy"


def test_backend_kept_when_jax_present():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        lin = CodedLinear(np.eye(8), name="ok", backend="jax")
    assert lin.backend == "jax" and lin.requested_backend == "jax"
    with pytest.raises(ValueError):
        CodedLinear(np.eye(8), parity_storage="sparse")


# ---------------------------------------------------------------------------
# Memory: virtual keeps ≤ 0.55× the encoded bytes at redundancy 2
# ---------------------------------------------------------------------------

def test_virtual_encoded_cache_bytes_under_055x():
    L, D, chunk = 256, 64, 64
    mat = CodedLinear(np.random.default_rng(4).normal(size=(L, D)),
                      name="mem", parity_chunk=chunk)
    virt = CodedLinear(mat.W, name="mem", parity_chunk=chunk,
                       parity_storage="virtual")
    for lin in (mat, virt):
        lin.ensure_parity(L)                  # redundancy 2
    # steady-state gather footprint: one frozen prefix touching parity
    rows = np.concatenate([np.arange(L - 40), np.arange(L, L + 48)])
    for lin in (mat, virt):
        lin.gather_encoded(rows)
    assert virt.encoded_cache_bytes() <= 0.55 * mat.encoded_cache_bytes()


def test_virtual_mode_refuses_materialised_surfaces():
    v = _linear("virtual")
    with pytest.raises(RuntimeError):
        v.R
    with pytest.raises(RuntimeError):
        v.WR
    with pytest.raises(RuntimeError):
        v.device_rows(50)


# ---------------------------------------------------------------------------
# Satellite: stacked least-squares decode over extra parity rows
# ---------------------------------------------------------------------------

def _ls_fixture(B=6, L=24, R=32, C=3, seed=7):
    lin = _linear("virtual", L=L, D=16, seed=seed)
    rng = np.random.default_rng(seed)
    rows = np.stack([np.sort(rng.choice(L + L, size=R, replace=False))
                     for _ in range(B)])
    x = rng.normal(size=(B, L, C))
    G = bk.SystematicRows(L, 2 * L, lin.parity_rows)
    y = np.stack([G.take(rows[b]) @ x[b] for b in range(B)])
    return lin, G, rows, x, y


def test_ls_decode_bit_parity_with_lstsq_loop():
    lin, G, rows, x, y = _ls_fixture()
    plan = bk.plan_decode_ls(G, rows)
    out = plan.apply(y)
    ref = np.empty_like(x)
    for b in range(rows.shape[0]):                 # the reference, literally
        ref[b], *_ = np.linalg.lstsq(G.take(rows[b]), y[b], rcond=None)
    assert np.array_equal(out, ref)
    np.testing.assert_allclose(out, x, atol=1e-9)
    # dense-G input plans the same systems
    Gd = np.concatenate([np.eye(lin.L), lin.parity_rows(np.arange(lin.L))])
    assert np.array_equal(bk.plan_decode_ls(Gd, rows).Gs, plan.Gs)


def test_ls_decode_matches_exact_decode_on_exactly_L_rows():
    lin, G, _, _, _ = _ls_fixture()
    rng = np.random.default_rng(8)
    L = lin.L
    rows = np.stack([np.sort(rng.choice(L + L, size=L, replace=False))
                     for _ in range(4)])
    x = rng.normal(size=(4, L, 2))
    y = np.stack([G.take(rows[b]) @ x[b] for b in range(4)])
    ls = bk.decode_ls_batch(G, rows, y)
    exact = bk.decode_batch(Gd := np.concatenate(
        [np.eye(L), lin.parity_rows(np.arange(L))]), rows, y)
    np.testing.assert_allclose(ls, exact, atol=1e-8)
    np.testing.assert_allclose(ls, x, atol=1e-8)


def test_ls_decode_jax_path_and_validation():
    lin, G, rows, x, y = _ls_fixture()
    out_np = bk.decode_ls_batch(G, rows, y, backend="numpy")
    out_jx = bk.decode_ls_batch(G, rows, y, backend="jax")
    np.testing.assert_allclose(out_jx, out_np, atol=1e-8)
    with pytest.raises(ValueError, match="needs >= L"):
        bk.plan_decode_ls(G, rows[:, :lin.L - 1])
    # 2-D y (one column squeezed) round-trips shape; bit-parity only holds
    # per identical lstsq call (LAPACK treats 1- and C-column RHS blocks
    # differently at the last bit), so compare to the 1-column reference
    out2 = bk.decode_ls_batch(G, rows, y[..., 0])
    assert out2.shape == x[..., 0].shape
    ref = np.stack([np.linalg.lstsq(G.take(rows[b]), y[b, :, 0],
                                    rcond=None)[0]
                    for b in range(rows.shape[0])])
    assert np.array_equal(out2, ref)
