"""Fleet-scale streaming contracts: the vectorised event loop and
incremental plan repair.

Two exactness guarantees back the fleet bench's speedups:

* the batched drain (``BackendConfig.event_batch > 1``) is *semantically
  equal* to the per-event reference loop (``event_batch=1``): same event
  count, same per-task records (a permutation at most), same summaries —
  through churn, in both record-keeping modes;
* incremental plan repair (``ReplanPolicy.mode="incremental"``) returns to
  a **bit-identical** plan when the pool returns to the solved-on state
  (degrade → restore), and falls back to a full solve when the KKT
  residual check demands it.

Plus the smaller API contracts of this redesign: ``StreamConfig`` as the
only construction path (legacy kwargs warn), ``ReplanMode`` coercion, the
``SharePool.has_headroom`` fast path, and the ``coded_head`` shim.
"""
import warnings

import numpy as np
import pytest

from repro.core.problem import Scenario, validate_plan
from repro.stream import (BackendConfig, OnlinePlanner, ReplanMode,
                          ReplanPolicy, SharePool, StreamConfig,
                          StreamingExecutor, WorkerEvent, poisson_sources)


def _scenario(M=6, N=10, L=64.0, seed=3):
    rng = np.random.default_rng(seed)
    a = np.zeros((M, N + 1))
    a[:, 0] = 0.5
    a[:, 1:] = rng.uniform(0.2, 0.4, size=(M, N))
    return Scenario(a=a, u=1 / a, gamma=2 / a, L=np.full(M, L))


CHURN = [WorkerEvent(50.0, 2, "degrade", 3.0),
         WorkerEvent(120.0, 5, "leave"),
         WorkerEvent(200.0, 5, "join"),
         WorkerEvent(260.0, 2, "restore")]


def _run(event_batch, *, utilization=0.5, tasks=400, keep_records=True,
         mode="incremental", churn=CHURN):
    sc = _scenario()
    cfg = StreamConfig(
        policy="fractional", replan=ReplanPolicy(mode=mode),
        backend=BackendConfig(event_batch=event_batch,
                              keep_records=keep_records),
        rng=0)
    ex = StreamingExecutor(
        sc, poisson_sources(sc, utilization=utilization, seed=1),
        config=cfg, churn=list(churn))
    ms = ex.run(max_tasks=tasks)
    return ex, ms


def _assert_summaries_equal(sa, sb, ctx=""):
    assert set(sa) == set(sb), ctx
    for key in sa:
        va, vb = sa[key], sb[key]
        if isinstance(va, float) and np.isnan(va) and np.isnan(vb):
            continue
        assert np.isclose(va, vb, rtol=1e-9, atol=1e-12), (ctx, key, va, vb)


# ---------------------------------------------------------------------------
# Batched drain ≡ per-event reference loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("event_batch", [64, 256])
@pytest.mark.parametrize("utilization", [0.2, 0.5, 0.8])
def test_batched_drain_matches_per_event(event_batch, utilization):
    exb, msb = _run(event_batch, utilization=utilization)
    exp, msp = _run(1, utilization=utilization)
    assert exb.events_processed == exp.events_processed
    _assert_summaries_equal(msb.summary(), msp.summary(),
                            f"util={utilization}")
    # record-level: identical task set and per-task values (completion
    # *finalisation* order inside a drained run may permute the lists)
    rb = sorted(msb.to_records(), key=lambda r: r["tid"])
    rp = sorted(msp.to_records(), key=lambda r: r["tid"])
    assert len(rb) == len(rp)
    for a, b in zip(rb, rp):
        assert set(a) == set(b)
        for key in a:
            va, vb = a[key], b[key]
            if isinstance(va, float):
                if np.isnan(va) and np.isnan(vb):
                    continue
                assert np.isclose(va, vb, rtol=1e-9, atol=1e-12), \
                    (a["tid"], key, va, vb)
            else:
                assert va == vb, (a["tid"], key, va, vb)


def test_batched_drain_matches_per_event_compact():
    # keep_records=False is the fleet-scale mode: summaries must still agree
    exb, msb = _run(64, keep_records=False)
    exp, msp = _run(1, keep_records=False)
    assert exb.events_processed == exp.events_processed
    _assert_summaries_equal(msb.summary(), msp.summary())


def test_compact_metrics_match_kept_records():
    _, msk = _run(64, keep_records=True)
    _, msc = _run(64, keep_records=False)
    sk, sc = msk.summary(), msc.summary()
    for key in sc:
        if key not in sk:
            continue
        vk, vc = sk[key], sc[key]
        if isinstance(vk, float) and np.isnan(vk) and np.isnan(vc):
            continue
        assert np.isclose(vk, vc, rtol=1e-9, atol=1e-12), (key, vk, vc)


# ---------------------------------------------------------------------------
# Incremental repair vs full re-solve
# ---------------------------------------------------------------------------

def _pool_state(sc):
    online = np.ones(sc.N + 1, dtype=bool)
    scale = np.ones(sc.N + 1)
    return online, scale


def test_repair_bit_identical_after_degrade_restore():
    # degrade then restore brings the pool back to the solved-on θ; the two
    # repairs must land on exactly the plan a fresh full solve produces
    sc = _scenario(M=5, N=8, seed=7)
    online, scale = _pool_state(sc)
    pl = OnlinePlanner(sc, policy="fractional",
                       replan=ReplanPolicy(mode="incremental"))
    pl.ensure_plan(online, scale, event=True)
    s2 = scale.copy()
    s2[3] = 2.5
    pl.ensure_plan(online, s2, event=True)
    p_rep = pl.ensure_plan(online, scale.copy(), event=True)
    assert pl.repairs == 2 and pl.full_solves == 1
    assert pl.repair_fallbacks == 0
    assert p_rep.method.endswith("+repair")

    pf = OnlinePlanner(sc, policy="fractional",
                       replan=ReplanPolicy(mode="always"))
    p_full = pf.ensure_plan(online, scale, event=True)
    for field in ("k", "b", "l", "t_per_master"):
        assert np.array_equal(getattr(p_rep, field), getattr(p_full, field)), \
            field


def test_repair_on_perturbed_pool_is_valid_and_cheap():
    sc = _scenario(M=5, N=8, seed=7)
    online, scale = _pool_state(sc)
    pl = OnlinePlanner(sc, policy="fractional",
                       replan=ReplanPolicy(mode="incremental"))
    pl.ensure_plan(online, scale, event=True)
    s2 = scale.copy()
    s2[4] = 3.0
    plan = pl.ensure_plan(online, s2, event=True)
    assert pl.repairs == 1 and pl.full_solves == 1
    assert pl.repair_fallbacks == 0
    sc_eff = pl.effective_scenario(online, s2)
    validate_plan(sc_eff, plan, fractional=True)
    assert np.all(np.isfinite(plan.t_per_master))
    # Thm-3 loads carry Σl = 2L redundancy per master
    np.testing.assert_allclose(plan.l.sum(axis=1), 2 * sc.L, rtol=1e-9)


def test_repair_fallback_forced_by_negative_tolerance():
    # repair_tol=-1 makes any nonzero residual delta trip the fallback: the
    # planner must adopt a fresh full solve instead of the repaired plan
    sc = _scenario(M=5, N=8, seed=7)
    online, scale = _pool_state(sc)
    pl = OnlinePlanner(sc, policy="fractional",
                       replan=ReplanPolicy(mode="incremental",
                                           repair_tol=-1.0))
    pl.ensure_plan(online, scale, event=True)
    s2 = scale.copy()
    s2[2] = 4.0
    plan = pl.ensure_plan(online, s2, event=True)
    assert pl.repair_fallbacks >= 1
    assert pl.full_solves >= 2
    assert not plan.method.endswith("+repair")


def test_join_forces_full_solve():
    sc = _scenario(M=4, N=6, seed=1)
    online, scale = _pool_state(sc)
    off = online.copy()
    off[3] = False
    pl = OnlinePlanner(sc, policy="fractional",
                       replan=ReplanPolicy(mode="incremental"))
    pl.ensure_plan(off, scale, event=True)
    pl.ensure_plan(online, scale, event=True)   # worker 3 joins
    assert pl.full_solves == 2 and pl.repairs == 0


def test_replan_mode_coercion():
    assert ReplanPolicy(mode="periodic").mode is ReplanMode.PERIODIC
    assert ReplanPolicy().mode is ReplanMode.INCREMENTAL
    assert ReplanMode("incremental") is ReplanMode.INCREMENTAL
    with pytest.raises(ValueError):
        ReplanPolicy(mode="sometimes")


# ---------------------------------------------------------------------------
# SharePool fast-path admission check
# ---------------------------------------------------------------------------

def test_has_headroom_implies_full_feasible_fraction():
    rng = np.random.default_rng(0)
    pool = SharePool(8)
    hits = 0
    for _ in range(200):
        k = np.zeros(9)
        b = np.zeros(9)
        k[1:] = rng.uniform(0.0, 0.5, size=8) * (rng.random(8) < 0.7)
        b[1:] = rng.uniform(0.0, 0.5, size=8) * (rng.random(8) < 0.7)
        if pool.has_headroom(k, b):
            hits += 1
            assert pool.feasible_fraction(k, b) == 1.0
            pool.acquire(k, b)   # validated acquire must accept it too
            pool.release(k, b)
    assert hits > 0   # the property was actually exercised


# ---------------------------------------------------------------------------
# StreamConfig construction surface
# ---------------------------------------------------------------------------

def test_legacy_kwargs_warn_and_match_config_path():
    sc = _scenario(M=3, N=6)
    srcs = lambda: poisson_sources(sc, utilization=0.4, seed=2)  # noqa: E731
    with pytest.warns(DeprecationWarning):
        ex_legacy = StreamingExecutor(sc, srcs(), policy="fractional", rng=5)
    cfg = StreamConfig(policy="fractional", rng=5)
    with warnings.catch_warnings():
        warnings.simplefilter("error")           # config path must not warn
        ex_cfg = StreamingExecutor(sc, srcs(), config=cfg)
    s_legacy = ex_legacy.run(max_tasks=100).summary()
    s_cfg = ex_cfg.run(max_tasks=100).summary()
    _assert_summaries_equal(s_legacy, s_cfg)


def test_config_plus_legacy_kwarg_is_an_error():
    sc = _scenario(M=2, N=4)
    with pytest.raises(TypeError):
        StreamingExecutor(sc, config=StreamConfig(), policy="fractional")


def test_unknown_legacy_kwarg_is_an_error():
    sc = _scenario(M=2, N=4)
    with pytest.raises(TypeError), warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        StreamingExecutor(sc, polcy="fractional")


def test_backend_config_validation():
    with pytest.raises(ValueError):
        BackendConfig(event_batch=0)
    with pytest.raises(ValueError):
        BackendConfig(numerics="verify", keep_records=False)
    with pytest.raises(ValueError):
        StreamConfig(policy="quantum")


# ---------------------------------------------------------------------------
# coded_head retirement shim
# ---------------------------------------------------------------------------

def test_coded_head_shim_warns_and_reexports():
    import importlib
    import repro.serve_coded.coded_head as stub
    with pytest.warns(DeprecationWarning):
        stub = importlib.reload(stub)
    from repro.serve_coded.coded_linear import CodedLMHead, HeadStep
    assert stub.CodedLMHead is CodedLMHead
    assert stub.HeadStep is HeadStep
