"""Device-resident batched shard execution: bit-identity and structure.

The batched engine (persistent encoded caches → ragged-shard packing →
one pass per dependency stage → stacked grouped decode, executed once at
barrier completion) must be *bit-identical* to the serial shard-by-shard
reference on numpy — same shard products, same decoded outputs, same
greedy tokens — and token-identical on every backend.  These tests pin
that, plus the satellite fixes that ride along: explicit decode-backend
routing, the parity-generator conditioning guard, the per-scope decode
error bound, and the per-execution-mode bench schema.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import mds
from repro.serve_coded import (CODING_SCOPES, CodedLinear,
                               CodedServingBridge, PackedStage,
                               ShardProblem, synthetic_requests)
from repro.serve_coded.coded_linear import shard_products
from repro.stream import AdmissionConfig, WorkerEvent
from repro.stream import backend as bk

jax = pytest.importorskip("jax")


def _serve(scope, *, execution="batched", coded=True, backend="numpy",
           steps=1, churn=(), n=4, gen=3, seed=0, **kw):
    bridge = CodedServingBridge(
        masters=2, seed=seed, slots_per_master=2, coding_scope=scope,
        steps_per_dispatch=steps, backend=backend, coded=coded,
        execution=execution, admission=AdmissionConfig(policy="edf"), **kw)
    bridge._setup_model(16 + gen + 8)
    reqs = synthetic_requests(
        n, masters=2, vocab=bridge._model["cfg"].vocab, prompt_len=16,
        gen_len=gen, rate=0.02, seed=seed)
    return bridge.serve(reqs, churn=churn)


def _ragged_problems(rng, D=24, Ls=(48, 48, 96)):
    """Linears + prefix plans across ragged shard splits, mixed
    systematic/parity prefixes (incl. 0 < s < L substitution groups)."""
    problems, linears, plans = [], [], []
    for i, L in enumerate(Ls):
        lin = CodedLinear(rng.normal(size=(L, D)), name=f"m{i}", seed=i)
        l_int = np.array([0, L // 3, L // 2, L // 2, L])       # Σ > L
        finish = rng.permutation(np.arange(5).astype(float) + 1.0)
        finish[0] = np.inf
        plan = lin.prefix_plan(l_int, finish, t_complete=5.0)
        problems.append(ShardProblem(key=f"m{i}", linear=lin,
                                     rows=plan.rows,
                                     used_solve=plan.used_solve))
        linears.append(lin)
        plans.append((l_int, finish, plan))
    return problems, linears, plans


# ---------------------------------------------------------------------------
# Packed execution == serial execution, bit for bit (numpy)
# ---------------------------------------------------------------------------

def test_packed_shard_products_bit_identical_to_serial():
    rng = np.random.default_rng(0)
    problems, linears, plans = _ragged_problems(rng)
    X = rng.normal(size=(5, 24))
    stage = PackedStage(problems)
    packed = {p.key: y for p, y in zip(
        stage.problems, stage.pack.products(X))}
    for p, lin, (l_int, finish, plan) in zip(problems, linears, plans):
        enc = lin._enc[:lin._n_enc]
        serial = np.concatenate([shard_products(enc[sl], X)
                                 for sl in plan.slices])
        assert (packed[p.key] == serial).all()          # exact, not close


def test_packed_stage_decode_bit_identical_to_serial_step():
    rng = np.random.default_rng(1)
    problems, linears, plans = _ragged_problems(rng)
    X = rng.normal(size=(3, 24))
    outs = PackedStage(problems).execute(X)
    any_solve = False
    for p, lin, (l_int, finish, plan) in zip(problems, linears, plans):
        res = lin.step(X, l_int, finish, 5.0)
        assert (outs[p.key] == res.out).all()           # exact, not close
        np.testing.assert_allclose(outs[p.key], X @ lin.W.T, atol=1e-8)
        any_solve |= res.used_solve
    assert any_solve                       # prefixes did hit the solve path


def test_bridge_batched_vs_serial_bit_identical_per_scope():
    for scope in CODING_SCOPES:
        ser = _serve(scope, execution="serial")
        bat = _serve(scope, execution="batched")
        assert bat.tokens == ser.tokens
        assert bat.max_err == ser.max_err, scope     # decodes match in bits
        assert [s["t_done"] for s in bat.steps] == \
            [s["t_done"] for s in ser.steps]         # identical scheduling
        assert bat.execution == "batched" and ser.execution == "serial"
        assert all(s["execution"] == "batched" for s in bat.steps)


@pytest.mark.parametrize("backend", ("jax", "pallas"))
def test_bridge_batched_tokens_match_uncoded_on_device_backends(backend):
    bat = _serve("trunk", backend=backend)
    plain = _serve("trunk", coded=False, backend=backend)
    assert bat.tokens == plain.tokens
    assert bat.decode_ok, bat.max_err


def test_batched_churn_mass_leave_redispatch_matches_serial():
    churn = [WorkerEvent(60.0, w, "leave") for w in range(1, 9)]
    bat = _serve("trunk", churn=churn)
    ser = _serve("trunk", execution="serial", churn=churn)
    plain = _serve("trunk", coded=False, churn=churn)
    assert bat.redispatches > 0
    assert bat.tokens == ser.tokens == plain.tokens
    assert bat.decode_ok


def test_batched_multi_token_dispatch_reuses_plans():
    b4 = _serve("trunk", steps=4, n=4, gen=4)
    s4 = _serve("trunk", execution="serial", steps=4, n=4, gen=4)
    assert b4.tokens == s4.tokens
    assert b4.tokens_generated == 16


def test_batched_slots_admitted_mid_flight_wait_for_next_dispatch():
    """Deferred execution freezes the dispatch's slot set: a request
    admitted between dispatch and completion must ride the *next* step —
    exactly the eager engine's token set (asserted via bit-equality on a
    workload with more requests than slots)."""
    ser = _serve("ffn", execution="serial", n=8, gen=3)
    bat = _serve("ffn", execution="batched", n=8, gen=3)
    assert bat.tokens == ser.tokens


# ---------------------------------------------------------------------------
# Decode-backend routing (satellite: no silent pallas→jax fallthrough)
# ---------------------------------------------------------------------------

def test_decode_backend_recorded_explicitly():
    rng = np.random.default_rng(2)
    W = rng.normal(size=(32, 8))
    l_int = np.array([16, 16, 16])
    finish = np.array([1.0, 2.0, 3.0])
    for backend, engine in (("numpy", "numpy"), ("jax", "jax"),
                            ("pallas", "jax")):
        lin = CodedLinear(W, name="t", seed=0, backend=backend)
        res = lin.step(rng.normal(size=(2, 8)), l_int, finish, 3.0)
        assert lin.decode_backend == engine
        assert res.decode_backend == engine
    rep = _serve("head", backend="pallas")
    assert rep.decode_backend == "jax"
    assert all(s["decode_backend"] == "jax" for s in rep.steps)
    rep = _serve("head", coded=False)
    assert rep.decode_backend == "local"


def test_step_log_schema_parity_serial_vs_batched():
    """Both execution engines must emit the *same* step_log schema — every
    key present in one appears in the other, per-step decode backends agree
    with the report-level routing, and the covering-prefix attribution
    (critical_task/critical_worker) is populated, not defaulted."""
    ser = _serve("trunk", execution="serial")
    bat = _serve("trunk", execution="batched")
    assert ser.steps and bat.steps
    keys_ser = {k for s in ser.steps for k in s}
    keys_bat = {k for s in bat.steps for k in s}
    assert keys_ser == keys_bat
    assert {"decode_backend", "critical_task", "critical_worker",
            "execution", "t_done"} <= keys_ser
    for rep in (ser, bat):
        assert all(s["decode_backend"] == rep.decode_backend
                   for s in rep.steps)
        crit_tasks = [s["critical_task"] for s in rep.steps]
        assert any(t is not None for t in crit_tasks)
        assert any(s["critical_worker"] >= 0 for s in rep.steps)
    # the two engines attribute the same critical tasks: identical
    # scheduling (asserted above via t_done) implies identical attribution
    assert [s["critical_task"] for s in ser.steps] == \
        [s["critical_task"] for s in bat.steps]
    assert [s["critical_worker"] for s in ser.steps] == \
        [s["critical_worker"] for s in bat.steps]


# ---------------------------------------------------------------------------
# Conditioning guard + per-scope decode error bound (satellite)
# ---------------------------------------------------------------------------

def test_parity_cond_flags_degenerate_blocks():
    rng = np.random.default_rng(3)
    good = rng.normal(0, 1 / np.sqrt(64), size=(128, 64))
    assert mds.parity_cond(good) < mds.PARITY_COND_LIMIT
    bad = np.ones((64, 64)) * 0.1                     # rank-1: cond = inf
    assert mds.parity_cond(bad) == np.inf
    assert mds.parity_cond(np.zeros((0, 8))) == 1.0


def test_ensure_parity_redraws_degenerate_chunk(monkeypatch):
    # rig the counter derivation: draw 0 of every block is rank-1, so the
    # conditioning guard must bump the redraw byte deterministically
    real = mds.counter_parity_rows

    def rigged(key, ctrs, L, **kw):
        if not (np.asarray(ctrs, dtype=np.uint32) >> 24).any():
            return np.ones((np.asarray(ctrs).size, L)) * 0.1   # draw 0
        return real(key, ctrs, L, **kw)

    monkeypatch.setattr(mds, "counter_parity_rows", rigged)
    lin = CodedLinear(np.eye(16), name="guard", seed=0, parity_chunk=16)
    lin.ensure_parity(16)
    assert lin.parity_redraws >= 1
    assert mds.parity_cond(lin.R) < mds.PARITY_COND_LIMIT
    # the redraw index is part of the packed counter (high byte), so the
    # frozen plan metadata replays the *redrawn* rows
    assert (lin.parity_ctrs(np.arange(16)) >> 24 >= 1).all()
    # decode through the redrawn parity block stays exact
    X = np.random.default_rng(8).normal(size=(2, 16))
    res = lin.step(X, np.array([8, 24]), np.array([5.0, 1.0]), 6.0)
    assert res.used_solve
    np.testing.assert_allclose(res.out, X @ lin.W.T, atol=1e-9)
    # a virtual-mode twin walks the identical deterministic guard and
    # derives bit-identical rows despite never materialising the cache
    vlin = CodedLinear(np.eye(16), name="guard", seed=0, parity_chunk=16,
                      parity_storage="virtual")
    assert np.array_equal(vlin.parity_rows(np.arange(16)), lin.R)
    assert vlin.parity_redraws >= 1


def test_per_scope_decode_error_stays_bounded():
    """The trunk scope's many small mixed-row solves have a fatter
    conditioning tail than the head's (2.6e-11 vs 1.2e-12 in the seed
    BENCH_serve.json); the parity conditioning guard keeps every scope's
    worst per-matmul relative error under 1e-9 on float64."""
    for scope in CODING_SCOPES:
        rep = _serve(scope, n=6, gen=4)
        assert rep.decode_ok
        assert rep.max_err < 1e-9, (scope, rep.max_err)


# ---------------------------------------------------------------------------
# Backend plumbing: solve bypass, draw_n, batched kernel, device cache
# ---------------------------------------------------------------------------

def test_solve_stacked_bit_identical_to_public_solve():
    rng = np.random.default_rng(4)
    for g, n, c in ((1, 3, 1), (4, 22, 2), (2, 96, 3)):
        A = rng.normal(size=(g, n, n))
        b = rng.normal(size=(g, n, c))
        assert (bk.solve_stacked(A, b) == np.linalg.solve(A, b)).all()


def test_draw_n_matches_successive_draws():
    mk = lambda: bk.ExponentialBlock(np.random.default_rng(5), width=6,
                                     block=8, uniform_rows=1)
    a, b = mk(), mk()
    singles = np.stack([a.draw() for _ in range(64)])
    # spans: within-buffer, across one refill, and n > block (multiple
    # refills — a deep trunk's 1 + 7·n_layers tasks per dispatch)
    batched = np.concatenate([b.draw_n(5), b.draw_n(6), b.draw_n(29),
                              b.draw_n(24)])
    assert (singles == batched).all()      # stream-identical across refills
    assert b.block == 8                    # block size never mutates
    with pytest.raises(ValueError):
        b.draw_n(0)


def test_solve_stacked_raises_on_singular():
    with pytest.raises(np.linalg.LinAlgError):
        bk.solve_stacked(np.zeros((1, 3, 3)), np.ones((1, 3, 2)))


def test_coded_shard_matmul_batch_modes_agree():
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(6)
    tiles = jnp.asarray(rng.normal(size=(3, 128, 128)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(128, 4)), jnp.float32)
    vm = np.asarray(ops.coded_shard_matmul_batch(tiles, x, mode="vmap"))
    pl = np.asarray(ops.coded_shard_matmul_batch(tiles, x, mode="pallas"))
    ref = np.stack([np.asarray(tiles[i]) @ np.asarray(x) for i in range(3)])
    assert np.abs(vm - ref).max() < 1e-4
    assert np.abs(pl - ref).max() < 1e-4
    with pytest.raises(ValueError):
        ops.coded_shard_matmul_batch(tiles, x, mode="nope")
    with pytest.raises(ValueError):
        ops.coded_shard_matmul_batch(tiles[:, :100], x, mode="pallas")


def test_device_cache_grows_incrementally():
    rng = np.random.default_rng(9)
    lin = CodedLinear(rng.normal(size=(32, 16)), name="dev", seed=0,
                      backend="jax", parity_chunk=8)
    d1 = lin.device_rows(40)                          # 8 parity rows
    assert d1.shape == (40, 16)
    n_dev_first = lin._n_dev
    d2 = lin.device_rows(56)                          # grows by 16 more
    assert d2.shape == (56, 16) and lin._n_dev >= 56
    assert n_dev_first < lin._n_dev
    np.testing.assert_allclose(
        np.asarray(d2, dtype=np.float64),
        lin._enc[:56].astype(np.float32).astype(np.float64))


def test_packed_stage_device_products_match_host():
    rng = np.random.default_rng(10)
    problems, _, _ = _ragged_problems(rng)
    for backend in ("jax", "pallas"):
        stage = PackedStage(problems, backend=backend)
        X = rng.normal(size=(4, 24))
        host = stage.pack.products(X)
        dev = stage.pack.products_device(X, backend=backend)
        for h, d in zip(host, dev):
            assert np.abs(h - d).max() < 1e-3          # float32 device path
    prob, row = stage.pack.gather_index()
    assert (prob >= 0).sum() == stage.pack.total
    assert stage.pack.n_tiles == -(-stage.pack.total // 128)


# ---------------------------------------------------------------------------
# Expected-delay row assignment (systematic rows on the fast nodes)
# ---------------------------------------------------------------------------

def test_prefix_plan_small_matrix_parity_first_delivery():
    """L below MIN_PARITY_BLOCK with a parity shard delivering first: the
    parity-fill budget must cap at L (regression: an uncapped floor drove
    the systematic quota negative and emitted > L rows)."""
    rng = np.random.default_rng(12)
    lin = CodedLinear(rng.normal(size=(4, 6)), name="tiny", seed=0)
    l_int = np.array([4, 8])
    finish = np.array([5.0, 1.0])                # parity shard lands first
    plan = lin.prefix_plan(l_int, finish, 2.0)
    assert plan.rows.size == 4
    X = rng.normal(size=(3, 6))
    res = lin.step(X, l_int, finish, 2.0)
    np.testing.assert_allclose(res.out, X @ lin.W.T, atol=1e-9)
    outs = PackedStage([ShardProblem(key="tiny", linear=lin,
                                     rows=plan.rows,
                                     used_solve=plan.used_solve)]).execute(X)
    assert (outs["tiny"] == res.out).all()


def test_prefix_assign_places_systematic_rows_on_expected_fast_nodes():
    rng = np.random.default_rng(11)
    lin = CodedLinear(rng.normal(size=(32, 8)), name="as", seed=0)
    l_int = np.array([16, 16, 16])
    finish = np.array([1.0, 2.0, 3.0])
    # node order: node 0 holds [0,16) — but expected delays say node 2
    # is fastest, so with assign node 2 holds the systematic start
    assign = np.array([2.0, 3.0, 1.0])
    plain = lin.prefix_plan(l_int, finish, 3.0)
    ranked = lin.prefix_plan(l_int, finish, 3.0, assign=assign)
    assert (plain.slices[0] == np.arange(0, 16)).all()
    # delivery order is still by finish (node 0 first), but node 0 now
    # holds the *second* range in expected-delay order: rows [16, 32)
    assert (ranked.slices[0] == np.arange(16, 32)).all()
    X = rng.normal(size=(2, 8))
    for assign_key in (None, assign):
        res = lin.step(X, l_int, finish, 3.0, assign=assign_key)
        np.testing.assert_allclose(res.out, X @ lin.W.T, atol=1e-9)


# ---------------------------------------------------------------------------
# Bench schema (satellite: per-execution-mode rows + gates)
# ---------------------------------------------------------------------------

def test_bench_serve_schema_has_execution_modes_and_wall_ratios():
    record = json.loads(
        (pathlib.Path(__file__).parent.parent / "BENCH_serve.json")
        .read_text())
    for scope in CODING_SCOPES:
        assert set(record["scopes"][scope]) == {"serial", "batched"}
    assert record["trunk_wall_vs_head"] > 0
    assert set(record["batched_wall_speedup"]) == set(CODING_SCOPES)
    assert record["timing_reps"] >= 1


def test_check_regression_min_floor_gate(tmp_path, capsys):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_regression",
        pathlib.Path(__file__).parent.parent / "benchmarks"
        / "check_regression.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    gate = mod.main
    rec = {"scopes": {"trunk": {"batched": {"tokens_per_wall_second": 10}}},
           "trunk_wall_vs_head": 0.9}
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(rec))
    fresh.write_text(json.dumps(rec))
    ok = gate(["--baseline", str(base), "--fresh", str(fresh),
               "--key", "scopes.trunk.batched.tokens_per_wall_second",
               "--min", "trunk_wall_vs_head=0.4"])
    assert ok == 0
    bad = dict(rec, trunk_wall_vs_head=0.2)
    fresh.write_text(json.dumps(bad))
    assert gate(["--baseline", str(base), "--fresh", str(fresh),
                 "--min", "trunk_wall_vs_head=0.4"]) == 1
