"""Vectorised Monte-Carlo estimation of task completion delay.

For each realization, every active (master, node) pair draws
T = T_tr + T_cp from the paper's delay model; master m completes at the
earliest time its cumulative received coded rows reach L_m ("all-or-nothing"
per node, paper §II-C).  The uncoded benchmark instead needs *all* its
workers (no redundancy → max).

The overall system delay of one realization is max_m (completion of m);
the paper's Fig. 2-6/8 plot its mean and CDF.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.problem import Plan, Scenario
from ..stream.backend import (check_backend, completion_times, has_jax,
                              simulate_batch, simulate_chunks_np)

__all__ = ["SimResult", "simulate_plan"]


@dataclasses.dataclass
class SimResult:
    per_master_mean: np.ndarray          # (M,) mean completion delay
    overall_mean: float                  # mean of max_m completion
    overall_samples: Optional[np.ndarray]  # (trials,) if keep_samples
    per_master_samples: Optional[np.ndarray]  # (trials, M) if keep_samples

    def quantile(self, q: float) -> float:
        if self.overall_samples is None:
            raise ValueError("run with keep_samples=True")
        return float(np.quantile(self.overall_samples, q))

    def cdf(self, ts: np.ndarray) -> np.ndarray:
        if self.overall_samples is None:
            raise ValueError("run with keep_samples=True")
        return np.searchsorted(np.sort(self.overall_samples), ts) / self.overall_samples.size


def _completion_times(T: np.ndarray, loads: np.ndarray, need: float) -> np.ndarray:
    """Earliest t with Σ_{n: T_n <= t} l_n >= need, per realization row.

    T: (R, K) delays, loads: (K,).  Returns (R,) (inf if unreachable).
    Thin wrapper over the shared batched backend (repro.stream.backend),
    kept for API compatibility."""
    return completion_times(T, loads, float(need))


def simulate_plan(sc: Scenario, plan: Plan, trials: int = 100_000,
                  rng: np.random.Generator | int = 0, *,
                  needs_all: Optional[bool] = None,
                  keep_samples: bool = False,
                  straggle_p: float = 0.0, straggle_factor: float = 8.0,
                  chunk: Optional[int] = None,
                  backend: str = "numpy") -> SimResult:
    """Monte-Carlo the completion delay of a plan.

    needs_all: force the uncoded "wait for every worker" rule; defaults to
    auto-detect from ``plan.method`` containing "uncoded".

    straggle_p / straggle_factor: per-(trial, node) probability that a node
    is in a degraded state (its whole delay × factor).  Models the
    heavy-tailed *measured* behaviour of burstable cloud instances
    (CPU-credit throttling) that the paper's fitted shifted exponential
    underestimates — the planner still plans with the fitted parameters,
    exactly as the paper's §V-C does with its measured traces.

    backend: "numpy" (authoritative, bit-stable Generator stream) or "jax"
    — the jitted device-resident ``stream.backend.simulate_batch`` kernel,
    ~an order of magnitude faster at 1e5+ trials.  The jax path is seeded
    from ``rng`` but uses a counter-based key, so its samples are
    reproducible yet not bit-equal to numpy's; means/CDFs agree to Monte-
    Carlo precision.

    chunk: realizations per batch.  Defaults per backend (20k host rows on
    numpy; cache-sized 4k device chunks on jax) and is honored on both.
    """
    check_backend(backend)
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    if needs_all is None:
        needs_all = "uncoded" in plan.method
    M = sc.M

    if backend != "numpy" and has_jax():
        comp = simulate_batch(plan.l, plan.k, plan.b, sc.a, sc.u, sc.gamma,
                              sc.L, trials, seed=rng, needs_all=needs_all,
                              straggle_p=straggle_p,
                              straggle_factor=straggle_factor,
                              backend=backend,
                              **({"chunk": chunk} if chunk else {}))
        overall = comp.max(axis=1)
        return SimResult(
            per_master_mean=comp.mean(axis=0),
            overall_mean=float(overall.mean()),
            overall_samples=overall if keep_samples else None,
            per_master_samples=comp if keep_samples else None,
        )

    sums = np.zeros(M)
    overall_sum = 0.0
    samples = [] if keep_samples else None
    pm_samples = [] if keep_samples else None

    # streaming aggregation over the shared Generator-based chunk sampler
    # (one implementation with simulate_batch's numpy fallback)
    for comp in simulate_chunks_np(rng, plan.l, plan.k, plan.b, sc.a, sc.u,
                                   sc.gamma, sc.L, trials,
                                   needs_all=needs_all, straggle_p=straggle_p,
                                   straggle_factor=straggle_factor,
                                   chunk=chunk or 20_000):
        sums += comp.sum(axis=0)
        overall = comp.max(axis=1)
        overall_sum += overall.sum()
        if keep_samples:
            samples.append(overall)
            pm_samples.append(comp)

    return SimResult(
        per_master_mean=sums / trials,
        overall_mean=overall_sum / trials,
        overall_samples=np.concatenate(samples) if keep_samples else None,
        per_master_samples=np.concatenate(pm_samples) if keep_samples else None,
    )
