"""Vectorised Monte-Carlo estimation of task completion delay.

For each realization, every active (master, node) pair draws
T = T_tr + T_cp from the paper's delay model; master m completes at the
earliest time its cumulative received coded rows reach L_m ("all-or-nothing"
per node, paper §II-C).  The uncoded benchmark instead needs *all* its
workers (no redundancy → max).

The overall system delay of one realization is max_m (completion of m);
the paper's Fig. 2-6/8 plot its mean and CDF.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.delays import sample_total
from ..core.problem import Plan, Scenario
from ..stream.backend import completion_times

__all__ = ["SimResult", "simulate_plan"]


@dataclasses.dataclass
class SimResult:
    per_master_mean: np.ndarray          # (M,) mean completion delay
    overall_mean: float                  # mean of max_m completion
    overall_samples: Optional[np.ndarray]  # (trials,) if keep_samples
    per_master_samples: Optional[np.ndarray]  # (trials, M) if keep_samples

    def quantile(self, q: float) -> float:
        if self.overall_samples is None:
            raise ValueError("run with keep_samples=True")
        return float(np.quantile(self.overall_samples, q))

    def cdf(self, ts: np.ndarray) -> np.ndarray:
        if self.overall_samples is None:
            raise ValueError("run with keep_samples=True")
        return np.searchsorted(np.sort(self.overall_samples), ts) / self.overall_samples.size


def _completion_times(T: np.ndarray, loads: np.ndarray, need: float) -> np.ndarray:
    """Earliest t with Σ_{n: T_n <= t} l_n >= need, per realization row.

    T: (R, K) delays, loads: (K,).  Returns (R,) (inf if unreachable).
    Thin wrapper over the shared batched backend (repro.stream.backend),
    kept for API compatibility."""
    return completion_times(T, loads, float(need))


def simulate_plan(sc: Scenario, plan: Plan, trials: int = 100_000,
                  rng: np.random.Generator | int = 0, *,
                  needs_all: Optional[bool] = None,
                  keep_samples: bool = False,
                  straggle_p: float = 0.0, straggle_factor: float = 8.0,
                  chunk: int = 20_000) -> SimResult:
    """Monte-Carlo the completion delay of a plan.

    needs_all: force the uncoded "wait for every worker" rule; defaults to
    auto-detect from ``plan.method`` containing "uncoded".

    straggle_p / straggle_factor: per-(trial, node) probability that a node
    is in a degraded state (its whole delay × factor).  Models the
    heavy-tailed *measured* behaviour of burstable cloud instances
    (CPU-credit throttling) that the paper's fitted shifted exponential
    underestimates — the planner still plans with the fitted parameters,
    exactly as the paper's §V-C does with its measured traces.
    """
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    if needs_all is None:
        needs_all = "uncoded" in plan.method
    M = sc.M
    sums = np.zeros(M)
    overall_sum = 0.0
    samples = [] if keep_samples else None
    pm_samples = [] if keep_samples else None

    done = 0
    while done < trials:
        r = min(chunk, trials - done)
        # (r, M, N+1) delays for every active pair
        T = sample_total(rng, (r,), plan.l, plan.k, plan.b,
                         sc.a, sc.u, sc.gamma, local_col0=True)
        if straggle_p > 0:
            throttled = rng.random(T.shape) < straggle_p
            T = np.where(throttled, T * straggle_factor, T)
        # one batched call over (realization, master) — no per-master loop
        comp = completion_times(T, plan.l[None, :, :], sc.L[None, :],
                                needs_all=needs_all)
        sums += comp.sum(axis=0)
        overall = comp.max(axis=1)
        overall_sum += overall.sum()
        if keep_samples:
            samples.append(overall)
            pm_samples.append(comp)
        done += r

    return SimResult(
        per_master_mean=sums / trials,
        overall_mean=overall_sum / trials,
        overall_samples=np.concatenate(samples) if keep_samples else None,
        per_master_samples=np.concatenate(pm_samples) if keep_samples else None,
    )
