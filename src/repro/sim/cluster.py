"""Cluster / worker-pool profiles and delay-distribution fitting (Fig. 7).

Includes:
* the paper's Amazon EC2 fits (t2.micro / c5.large, §V-C),
* synthetic TPU-pod-group profiles used by the framework's heterogeneous
  shard planner (DESIGN.md §2.3): pods are near-deterministic per-step with a
  small shifted-exponential tail from host jitter / DCN incast,
* ``fit_shifted_exponential`` — the method-of-moments/MLE hybrid the paper
  uses to fit measured delays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from ..core.problem import EC2_C5_LARGE, EC2_T2_MICRO, Scenario

__all__ = [
    "WorkerClass", "ClusterProfile", "fit_shifted_exponential",
    "sample_shifted_exponential", "ec2_cluster", "tpu_pod_cluster",
]


@dataclasses.dataclass(frozen=True)
class WorkerClass:
    """One hardware class: shifted-exponential compute, exponential comms."""
    name: str
    a: float          # compute shift per unit row (ms)
    u: float          # compute rate (1/ms)
    gamma: float      # comms rate at full bandwidth (1/ms); inf → negligible

    @property
    def unit_delay(self) -> float:
        comm = 0.0 if not np.isfinite(self.gamma) else 1.0 / self.gamma
        return comm + 1.0 / self.u + self.a


@dataclasses.dataclass(frozen=True)
class ClusterProfile:
    """A pool of workers with per-class membership."""
    classes: Tuple[WorkerClass, ...]
    members: Tuple[int, ...]           # index into classes, one per worker
    master_class: WorkerClass

    @property
    def N(self) -> int:
        return len(self.members)

    def scenario(self, M: int, L: float = 1e4) -> Scenario:
        """Materialize an (M, N+1) Scenario from the profile."""
        N = self.N
        a = np.zeros((M, N + 1))
        u = np.zeros((M, N + 1))
        g = np.full((M, N + 1), 1e9)
        a[:, 0], u[:, 0] = self.master_class.a, self.master_class.u
        for j, ci in enumerate(self.members):
            c = self.classes[ci]
            a[:, j + 1], u[:, j + 1] = c.a, c.u
            g[:, j + 1] = c.gamma if np.isfinite(c.gamma) else 1e9
        return Scenario(a=a, u=u, gamma=g, L=np.full(M, L))


def sample_shifted_exponential(rng: np.random.Generator, n: int,
                               a: float, u: float) -> np.ndarray:
    """n unit-row delays ~ a + Exp(u)."""
    return a + rng.exponential(1.0 / u, size=n)


def fit_shifted_exponential(samples: np.ndarray) -> Tuple[float, float]:
    """Fit (a, u) of a shifted exponential, as the paper does for Fig. 7.

    MLE of the shift is min(samples); the textbook bias-corrected rate
    follows from the mean excess:  û = (n-1)/n / mean(x - â).
    """
    x = np.asarray(samples, dtype=np.float64)
    n = x.size
    a_hat = float(np.min(x))
    excess = float(np.mean(x - a_hat))
    u_hat = (n - 1) / n / max(excess, 1e-300)
    return a_hat, float(u_hat)


def ec2_cluster(N: int = 50, n_fast: int = 10,
                rng: np.random.Generator | int = 0,
                gamma_over_u: float | None = None) -> ClusterProfile:
    """The paper's §V-C pool: (N - n_fast) t2.micro + n_fast c5.large."""
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    g = (lambda u: gamma_over_u * u) if gamma_over_u else (lambda u: np.inf)
    t2 = WorkerClass("t2.micro", EC2_T2_MICRO["a"], EC2_T2_MICRO["u"],
                     g(EC2_T2_MICRO["u"]))
    c5 = WorkerClass("c5.large", EC2_C5_LARGE["a"], EC2_C5_LARGE["u"],
                     g(EC2_C5_LARGE["u"]))
    members = np.array([1] * n_fast + [0] * (N - n_fast))
    rng.shuffle(members)
    return ClusterProfile(classes=(t2, c5), members=tuple(int(x) for x in members),
                          master_class=t2)


def tpu_pod_cluster(n_pods: int = 8, degraded: Tuple[int, ...] = (3,),
                    base_ms_per_unit: float = 0.05,
                    dcn_gbps: float = 25.0) -> ClusterProfile:
    """Synthetic multi-pod profile for the framework's hetero shard planner.

    Each "worker" is a pod-group; a healthy pod computes a unit shard in
    ``base_ms_per_unit`` with a tight exponential tail, a degraded pod is 2×
    slower with a fat tail (models a failing host dragging its pod).  The
    DCN link rate sets γ.
    """
    healthy = WorkerClass("pod-healthy", a=base_ms_per_unit,
                          u=20.0 / base_ms_per_unit, gamma=dcn_gbps)
    slow = WorkerClass("pod-degraded", a=2.0 * base_ms_per_unit,
                       u=2.0 / base_ms_per_unit, gamma=dcn_gbps / 2)
    members = tuple(1 if i in degraded else 0 for i in range(n_pods))
    return ClusterProfile(classes=(healthy, slow), members=members,
                          master_class=healthy)
