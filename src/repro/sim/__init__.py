"""Monte-Carlo simulation of the coded-computation system (paper §V)."""
from .montecarlo import SimResult, simulate_plan  # noqa: F401
