"""Deterministic, resumable synthetic token pipeline.

Every batch is a pure function of ``(seed, step, host_set)``:
* exact resume after checkpoint restore — restore the step counter and the
  stream regenerates the identical remaining sequence;
* elastic re-sharding — when the host set changes, each surviving host's
  shard is recomputed from the same global sequence, so no examples are
  duplicated or dropped (DESIGN.md §6).

The synthetic distribution is a skewed Zipf-ish mixture with a Markov
bigram kick so that losses actually decrease during the example runs (a
uniform stream would pin CE at log V).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence

import numpy as np

__all__ = ["TokenStream", "make_batch_iterator"]


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.local_batch = self.global_batch // self.n_hosts

    def _rng_for(self, step: int, row: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row]))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Local shard of the global batch for ``step``."""
        rows = range(self.host_id * self.local_batch,
                     (self.host_id + 1) * self.local_batch)
        toks = np.empty((self.local_batch, self.seq_len + 1), np.int32)
        for i, r in enumerate(rows):
            rng = self._rng_for(step, r)
            # Zipf-skewed unigram base
            base = rng.zipf(1.3, size=self.seq_len + 1) % self.vocab
            # bigram kick: even positions follow (prev*7 + 11) mod V
            follow = (np.roll(base, 1) * 7 + 11) % self.vocab
            mask = rng.random(self.seq_len + 1) < 0.5
            toks[i] = np.where(mask, follow, base)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def state(self, step: int) -> dict:
        return {"seed": self.seed, "step": step,
                "n_hosts": self.n_hosts, "host_id": self.host_id}

    @classmethod
    def from_state(cls, state: dict, vocab: int, seq_len: int,
                   global_batch: int) -> "TokenStream":
        return cls(vocab=vocab, seq_len=seq_len, global_batch=global_batch,
                   seed=state["seed"], n_hosts=state["n_hosts"],
                   host_id=state["host_id"])

    def reshard(self, n_hosts: int, host_id: int) -> "TokenStream":
        """Elastic re-shard: same global stream, new host split."""
        return dataclasses.replace(self, n_hosts=n_hosts, host_id=host_id)


def make_batch_iterator(stream: TokenStream, start_step: int = 0,
                        extra_feats: Optional[dict] = None,
                        ) -> Iterator[Dict[str, np.ndarray]]:
    """Iterator of batches from ``start_step``; optionally attaches static
    modality-stub features (audio frames / vision patches)."""
    step = start_step
    while True:
        b = stream.batch(step)
        if extra_feats:
            b = {**b, **extra_feats}
        yield b
        step += 1
