"""Deterministic, resumable data pipeline."""
from .pipeline import TokenStream, make_batch_iterator  # noqa: F401
