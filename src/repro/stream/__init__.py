"""repro.stream — event-driven streaming scheduler over the paper's planner.

The static stack (assignment → loads → SCA) optimises one batch; this
package turns it into a traffic-serving runtime: per-master arrival
processes, per-worker share tracking for concurrent in-flight tasks, online
replanning with SCA warm starts, a batched completion/decode backend shared
with the Monte-Carlo simulator, and structured sojourn/queueing/waste
metrics.  See ``src/repro/stream/README.md`` for the event model.

Canonical construction surface::

    from repro.stream import StreamConfig, StreamingExecutor
    ex = StreamingExecutor(sc, config=StreamConfig(...))
"""
from .backend import (ExponentialBlock, completion_times, decode_batch,
                      delivered_by, sample_delays)
from .barrier import BarrierTask, StepBarrier, churn_finish_update
from .config import BackendConfig, StreamConfig
from .engine import StreamingExecutor, poisson_sources
from .events import (ARRIVAL, CHURN, COMPLETION, REPLAN, Event, EventLoop,
                     PoissonProcess, TraceProcess, WorkerEvent)
from .metrics import StreamMetrics, TaskRecord
from .queueing import (AdmissionConfig, AdmissionPolicy, EDFAdmission,
                       FairShareAdmission, FIFOAdmission, SharePool,
                       WaitQueue, make_admission_policy, maxmin_share)
from .replan import OnlinePlanner, ReplanMode, ReplanPolicy, scaled_row_loads

__all__ = [
    "StreamingExecutor", "poisson_sources",
    "StreamConfig", "BackendConfig", "ReplanMode",
    "EventLoop", "Event", "PoissonProcess", "TraceProcess", "WorkerEvent",
    "ARRIVAL", "COMPLETION", "CHURN", "REPLAN",
    "AdmissionConfig", "SharePool", "WaitQueue",
    "AdmissionPolicy", "FIFOAdmission", "EDFAdmission", "FairShareAdmission",
    "make_admission_policy", "maxmin_share",
    "OnlinePlanner", "ReplanPolicy", "scaled_row_loads",
    "StreamMetrics", "TaskRecord",
    "completion_times", "delivered_by", "sample_delays", "decode_batch",
    "ExponentialBlock",
    "BarrierTask", "StepBarrier", "churn_finish_update",
]
