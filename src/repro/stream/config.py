"""Unified construction surface for the streaming subsystem.

``StreamingExecutor`` historically grew one keyword argument per feature
(admission, replan, backend, numerics, straggler injection, ...).  The
canonical construction path is now a single frozen :class:`StreamConfig`
composed of the three policy objects that already existed —
``AdmissionConfig`` (who gets in), ``ReplanPolicy`` (when to re-optimise)
— plus a new :class:`BackendConfig` bundling the numerics/runtime knobs:

    from repro.stream import StreamConfig, StreamingExecutor
    ex = StreamingExecutor(sc, config=StreamConfig(policy="fractional"))

The legacy kwargs still work (``StreamingExecutor(sc, policy=...,
backend=...)``) but emit a ``DeprecationWarning``; passing both ``config``
and a legacy kwarg is an error.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .queueing import AdmissionConfig
from .replan import ReplanPolicy

__all__ = ["BackendConfig", "StreamConfig"]

_PLAN_POLICIES = ("dedicated", "fractional", "uncoded")
_NUMERICS = ("none", "verify")
_BACKENDS = ("numpy", "jax", "pallas")


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    """Numerics/runtime knobs of the streaming engine.

    backend:          array backend for batched completion/decode numerics
                      ("numpy" | "jax" | "pallas").
    numerics:         "none" (timing only) or "verify" (encode/decode a
                      real matrix for ``verify_cols`` columns per task).
    verify_cols:      columns checked per task when numerics="verify".
    straggle_p:       per-(task, worker) probability of a heavy-tail
                      delivery (delay × straggle_factor).
    straggle_factor:  the heavy-tail multiplier.
    event_batch:      max events drained per heap inspection in the
                      vectorised loop; 1 reproduces the historical
                      one-pop-at-a-time loop exactly (it *is* that loop).
    keep_records:     keep per-task ``TaskRecord`` objects (needed by
                      ``to_records``/verification).  False switches
                      ``StreamMetrics`` to compact scalar arrays — required
                      at fleet scale (1e6 records ≈ 1 GB of dataclasses).
    """
    backend: str = "numpy"
    numerics: str = "none"
    verify_cols: int = 4
    straggle_p: float = 0.0
    straggle_factor: float = 8.0
    event_batch: int = 64
    keep_records: bool = True

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.numerics not in _NUMERICS:
            raise ValueError(f"unknown numerics mode {self.numerics!r}")
        if self.event_batch < 1:
            raise ValueError("event_batch must be >= 1")
        if self.numerics == "verify" and not self.keep_records:
            raise ValueError("numerics='verify' requires keep_records=True")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Everything ``StreamingExecutor`` needs beyond the scenario + sources.

    policy:     planning stack ("dedicated" | "fractional" | "uncoded").
    replan:     :class:`~repro.stream.replan.ReplanPolicy` (None = default,
                i.e. incremental repair on every pool change).
    admission:  :class:`~repro.stream.queueing.AdmissionConfig`.
    backend:    :class:`BackendConfig`.
    rng:        integer seed for the planner + delay streams.
    """
    policy: str = "fractional"
    replan: Optional[ReplanPolicy] = None
    admission: Optional[AdmissionConfig] = None
    backend: BackendConfig = dataclasses.field(default_factory=BackendConfig)
    rng: int = 0

    def __post_init__(self):
        if self.policy not in _PLAN_POLICIES:
            raise ValueError(f"unknown planning policy {self.policy!r}")

    # -- legacy kwargs bridge -------------------------------------------------

    _LEGACY_KEYS = ("policy", "replan", "admission", "numerics",
                    "verify_cols", "rng", "backend", "straggle_p",
                    "straggle_factor")

    @classmethod
    def from_legacy_kwargs(cls, **kw) -> "StreamConfig":
        """Build a config from ``StreamingExecutor``'s historical kwargs."""
        unknown = set(kw) - set(cls._LEGACY_KEYS)
        if unknown:
            raise TypeError(
                f"unexpected keyword argument(s) {sorted(unknown)}")
        backend = BackendConfig(
            backend=kw.get("backend", "numpy"),
            numerics=kw.get("numerics", "none"),
            verify_cols=kw.get("verify_cols", 4),
            straggle_p=kw.get("straggle_p", 0.0),
            straggle_factor=kw.get("straggle_factor", 8.0))
        return cls(policy=kw.get("policy", "fractional"),
                   replan=kw.get("replan"),
                   admission=kw.get("admission"),
                   backend=backend,
                   rng=kw.get("rng", 0))
