"""``StreamingExecutor`` — the event-driven runtime over the paper's planner.

Where ``repro.runtime.coded_exec.CodedExecutor`` executes *one* static batch
with a per-master Python loop, this engine serves a *stream*: per-master
arrival processes feed a discrete-event loop; each arriving task acquires
fractional (k, b) shares from the live worker pool (column sums of
concurrent in-flight tasks stay ≤ 1, paper (6c)/(25c)), gets Theorem-1/3
closed-form loads at its admitted shares, and completes at the earliest
prefix of worker deliveries covering L_m coded rows.  Worker churn (leave /
join / degrade / restore) retimes in-flight deliveries and triggers online
replanning per the configured :class:`~repro.stream.replan.ReplanPolicy`.

All per-task math routes through :mod:`repro.stream.backend` — the same
batched sort+cumsum completion rule the Monte-Carlo simulator uses, block-
amortised exponential sampling, and (in verification mode) one batched MDS
encode + ``vmap``'d decode per master instead of a per-task Python pipeline.

A run is a pure function of its seeds: event ties break by insertion order,
arrival processes own per-master generators, and delay randomness is
consumed from a pre-sampled block — same-seed replays produce identical
metrics, which the tier-1 tests assert.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import mds
from ..core.problem import Scenario
from ..faults import FaultConfig, corrupt_products
from ..obs import Tracer, use_tracer
from . import backend as bk
from .barrier import churn_finish_update
from .config import StreamConfig
from .events import (ARRIVAL, CHURN, COMPLETION, REPLAN, ArrivalProcess,
                     Event, EventLoop, PoissonProcess, WorkerEvent)
from .metrics import StreamMetrics, TaskRecord
from .queueing import (AdmissionConfig, SharePool, fair_demand_rows,
                       make_admission_policy, scale_shares)
from .replan import OnlinePlanner, ReplanPolicy, scaled_row_loads

__all__ = ["StreamingExecutor", "poisson_sources"]


def poisson_sources(sc: Scenario, utilization: float = 0.5,
                    seed: int = 0) -> List[PoissonProcess]:
    """One Poisson source per master, sized to a target utilization.

    Rate_m = utilization / t*_m with t*_m the Theorem-1 predicted completion
    of the full pool split evenly — a convenient default that loads the
    system without saturating it."""
    from ..core.assignment import plan_from_assignment, simple_greedy
    plan = plan_from_assignment(sc, simple_greedy(sc))
    rates = utilization / np.maximum(plan.t_per_master, 1e-300)
    return [PoissonProcess(m, float(rates[m]), seed=seed)
            for m in range(sc.M)]


@dataclasses.dataclass
class _InFlight:
    tid: int
    master: int
    k_row: np.ndarray
    b_row: np.ndarray
    l_row: np.ndarray
    finish: np.ndarray            # absolute per-node delivery times
    need: float
    t_admit: float
    completion: float
    version: int = 0
    service_pred: float = 0.0     # predicted service time at dispatch
    speculative: bool = False     # a racing twin of an existing dispatch
    fraction: float = 1.0         # admitted share scale (1 = full plan row)


class StreamingExecutor:
    """Serves per-master task streams through the coded pipeline.

    Parameters
    ----------
    sc:        base Scenario (M masters, N shared workers).
    sources:   arrival processes (defaults to ``poisson_sources(sc)``).
    config:    a frozen :class:`~repro.stream.config.StreamConfig` — the
               canonical construction surface.  It bundles the planning
               ``policy`` ("fractional" | "dedicated" | "uncoded"), the
               :class:`ReplanPolicy`, the :class:`AdmissionConfig`
               (share-scaling / backpressure / waiting-order; deadlines
               come from the arrival processes and feed EDF ordering and
               ``deadline_miss_rate``), a
               :class:`~repro.stream.config.BackendConfig` (numerics
               backend, verification, straggler injection, the event-batch
               size of the vectorised loop, record retention) and the
               ``rng`` master seed.
    churn:     scheduled :class:`WorkerEvent`s (join/leave/degrade/restore).
    tracer:    optional :class:`repro.obs.Tracer`.  Records sim-time spans
               (queue wait / service per master lane, per-worker shard
               deliveries with critical-delivery attribution, churn
               instants) and wall-time spans (the run itself, replan
               solves, verification products/decodes) side by side.  A
               disabled tracer costs nothing: it is normalised to None.
               Tracing forces the reference per-event drain (the span
               streams are defined per event).

    The historical kwarg surface (``policy=``, ``replan=``, ``admission=``,
    ``numerics=``, ``verify_cols=``, ``rng=``, ``backend=``,
    ``straggle_p=``, ``straggle_factor=``) still works and is folded into a
    ``StreamConfig`` internally, but emits a ``DeprecationWarning``;
    passing both ``config`` and legacy kwargs is a ``TypeError``.

    One executor = one run.  Build a fresh instance to replay.
    """

    def __init__(self, sc: Scenario,
                 sources: Optional[Sequence[ArrivalProcess]] = None,
                 config: Optional[StreamConfig] = None, *,
                 churn: Sequence[WorkerEvent] = (),
                 tracer: Optional[Tracer] = None,
                 faults: Optional[FaultConfig] = None,
                 **legacy):
        if legacy:
            if config is not None:
                raise TypeError(
                    "pass either config=StreamConfig(...) or the legacy "
                    f"kwargs, not both: {sorted(legacy)}")
            warnings.warn(
                "StreamingExecutor's per-feature kwargs (policy=, replan=, "
                "admission=, numerics=, verify_cols=, rng=, backend=, "
                "straggle_p=, straggle_factor=) are deprecated; pass "
                "config=StreamConfig(...) instead",
                DeprecationWarning, stacklevel=2)
            config = StreamConfig.from_legacy_kwargs(**legacy)
        elif config is None:
            config = StreamConfig()
        bcfg = config.backend
        backend = bcfg.backend
        bk.check_backend(backend)
        if backend != "numpy" and not bk.has_jax():
            backend = "numpy"        # graceful, like the backend layer
        self.config = config
        self.sc = sc
        policy = config.policy
        self.sources = list(sources) if sources is not None else \
            poisson_sources(sc, seed=config.rng)
        self.admission = config.admission or AdmissionConfig(
            allow_scaling=(policy == "fractional"))
        if policy != "fractional":
            self.admission = dataclasses.replace(self.admission,
                                                 allow_scaling=False)
        self.churn = sorted(churn, key=lambda e: e.time)
        self.numerics = bcfg.numerics
        self.verify_cols = int(bcfg.verify_cols)
        self.seed = int(config.rng)
        self.backend = backend
        self.straggle_p = float(bcfg.straggle_p)
        self.straggle_factor = float(bcfg.straggle_factor)
        self._event_batch = int(bcfg.event_batch)
        self._keep_records = bool(bcfg.keep_records)
        # Disabled tracers normalise to None so the off path is exactly the
        # no-tracer path (the < 2% disabled-overhead contract).
        self.tracer = tracer if (tracer is not None
                                 and tracer.enabled) else None
        # fault injection: draws come from stateless hash-seeded
        # generators (repro.faults), never the delay block — a zero-rate
        # schedule leaves every delay bit identical to faults=None
        self.faults = faults
        self._fault_sched = faults.schedule() \
            if faults is not None and faults.active else None
        self._dispatch_seq = itertools.count()
        self._corrupt_marks: Dict[int, Tuple[int, str]] = {}
        self.fault_stats = {"crashes": 0, "drops": 0, "stales": 0,
                            "duplicates": 0, "corruptions": 0,
                            "corruptions_applied": 0, "detected": 0,
                            "false_flags": 0}

        self.planner = OnlinePlanner(sc, policy=policy,
                                     replan=config.replan, rng=self.seed)
        self.loop = EventLoop()
        self.pool = SharePool(sc.N)
        self.queue = make_admission_policy(self.admission.policy,
                                           self.admission.max_queue)
        self.metrics = StreamMetrics(sc.M, sc.N,
                                     keep_records=self._keep_records)

        self.scale = np.ones(sc.N + 1)
        self._sc_eff = sc
        self._exp = bk.ExponentialBlock(
            np.random.default_rng((self.seed, 0xD31A)), sc.N + 1,
            uniform_rows=1 if self.straggle_p > 0 else 0)
        self.tasks: Dict[int, TaskRecord] = {}
        self.inflight: Dict[int, _InFlight] = {}
        self.twins: Dict[int, _InFlight] = {}   # speculative racing dispatches
        self._verify_buf: List[_InFlight] = []
        self._next_tid = 0
        self._emitted = 0
        self._ran = False
        self.events_processed = 0
        # (plan, sc_eff)-keyed per-master full-share admission rows for the
        # vectorised arrival drain; cleared whenever either identity changes.
        self._row_cache: Dict = {}
        # Monotone completion-event versions: a stale COMPLETION (pushed
        # before churn retimed or re-dispatched its task) must never match.
        self._version_seq = itertools.count()

    @property
    def online(self) -> np.ndarray:
        """Worker-online mask — single source of truth is the share pool."""
        return self.pool.online

    # ------------------------------------------------------------------ run

    def run(self, max_tasks: int = 1000, until: float = np.inf) -> StreamMetrics:
        """Simulate ``max_tasks`` arrivals (drained to completion) or until
        sim time ``until``, whichever first.  Returns the metrics.

        If a :class:`~repro.obs.Tracer` was passed, it is installed as the
        process-global tracer for the duration of the run (deep call sites
        — replan solves, backend decodes — record through it)."""
        if self._ran:
            raise RuntimeError("StreamingExecutor is single-shot; build a "
                               "fresh instance to replay")
        self._ran = True
        self.max_tasks = int(max_tasks)
        if self.tracer is None:
            return self._run_loop(until)
        with use_tracer(self.tracer) as tr:
            with tr.span("stream_run", cat="run",
                         args={"backend": self.backend,
                               "max_tasks": self.max_tasks}):
                return self._run_loop(until)

    def _run_loop(self, until: float) -> StreamMetrics:
        for i, src in enumerate(self.sources):
            t0 = src.next_after(0.0)
            if np.isfinite(t0):
                self.loop.push(t0, ARRIVAL, i)
        for ev in self.churn:
            self.loop.push(ev.time, CHURN, ev)
        if self._fault_sched is not None and self.faults.crash_rate > 0:
            horizon = until
            if not np.isfinite(horizon):
                # arrival-driven runs have no wall clock: bound the chaos
                # window by the expected span of max_tasks arrivals
                rate = sum(getattr(s, "rate", 0.0) for s in self.sources)
                horizon = 4.0 * self.max_tasks / rate if rate > 0 else 0.0
            plan = self.planner.ensure_plan(self.online, self.scale)
            mean_iv = float(np.mean(plan.t_per_master))
            for ev in self._fault_sched.crash_events(
                    range(1, self.sc.N + 1), horizon, mean_iv):
                self.loop.push(ev.time, CHURN, ev)
        pol = self.planner.replan
        if pol.mode == "periodic":
            self.loop.push(pol.period, REPLAN, None)

        # Tracing pins the reference per-event drain: the span/instant
        # streams are defined per event, and the batched fast paths skip
        # exactly the call sites that emit them.
        batched = self._event_batch > 1 and self.tracer is None
        while not self.loop.empty():
            if self.loop.peek_time() > until:
                break
            if batched:
                kind = self.loop.peek_kind()
                if kind == ARRIVAL or kind == COMPLETION:
                    self._drain_run(until)
                    continue
            ev = self.loop.pop()
            self.events_processed += 1
            if ev.kind == ARRIVAL:
                self._on_arrival(ev.payload, ev.time)
            elif ev.kind == COMPLETION:
                self._on_completion(ev.payload, ev.time)
            elif ev.kind == CHURN:
                self._on_churn(ev.payload, ev.time)
            elif ev.kind == REPLAN:
                self.planner.ensure_plan(self.online, self.scale, force=True)
                # Reschedule only while something else can still happen: a
                # pending arrival/completion/churn event (at most one REPLAN
                # exists and it was just popped) or an in-flight task.  A
                # bare unservable queue must not keep the loop alive forever.
                if self.inflight or self.twins or len(self.loop):
                    self.loop.push(ev.time + pol.period, REPLAN, None)

        if self.numerics == "verify":
            self._run_verification()
        self.metrics.replans = self.planner.replans
        self.metrics.rejected = self.queue.rejected
        self.metrics.unserved = len(self.queue) + len(self.inflight)
        # an `until` cutoff censors deadlines that had not yet expired when
        # observation stopped; a naturally-drained run leaves no censoring
        # (nothing more can ever happen, so an unserved deadline is a miss)
        censor = until if np.isfinite(until) else np.inf
        for tid in self.queue.candidates():
            self.metrics.record_unserved(self.tasks[tid], censor_after=censor)
        # stranded in-flight work is unserved too, and its held shares are
        # accounted up to the cutoff
        t_stop = until if np.isfinite(until) else self.loop.now
        for fl in self._attempts():
            self.metrics.record_share_interval(
                fl.k_row, fl.b_row, max(t_stop - fl.t_admit, 0.0))
        for tid in self.inflight:
            self.metrics.record_unserved(self.tasks[tid], censor_after=censor)
        return self.metrics

    # ------------------------------------------------------------- handlers

    def _on_arrival(self, src_idx: int, t: float) -> None:
        if self._emitted >= self.max_tasks:
            return
        src = self.sources[src_idx]
        tid = self._next_tid
        self._next_tid += 1
        self._emitted += 1
        rec = TaskRecord(tid=tid, master=src.master, t_arrive=t,
                         rows_needed=float(self.sc.L[src.master]))
        self.tasks[tid] = rec
        if self.tracer is not None:
            self.tracer.instant(f"arrive:t{tid}", t, cat="arrival",
                                track=f"sim:m{src.master}",
                                args={"task": tid, "master": src.master})
        plan = self.planner.ensure_plan(self.online, self.scale, event=True)
        rec.deadline = float(src.deadline_for(
            t, float(plan.t_per_master[src.master])))
        if self._emitted < self.max_tasks:
            t_next = src.next_after(t)
            if np.isfinite(t_next):
                self.loop.push(t_next, ARRIVAL, src_idx)
        # Fairness: earlier-queued tasks get first claim on the pool — a
        # newcomer may not slip past a waiting candidate the policy ranks
        # ahead of it.
        self._drain_queue(t)
        if len(self.queue) == 0 and self._try_admit(tid, t):
            return
        if not self.queue.offer(tid, master=rec.master, deadline=rec.deadline):
            del self.tasks[tid]              # backpressure: rejected outright
            return
        if self.queue.reorders and len(self.queue) > 1:
            # deadline/fairness policies may rank the newcomer ahead of the
            # previously-blocked head — give it one admission attempt now
            self._drain_queue(t)

    def _on_completion(self, payload: Tuple[int, int], t: float) -> None:
        tid, version = payload
        fl = self.inflight.get(tid)
        tw = self.twins.get(tid)
        if fl is not None and fl.version == version:
            win, lose = fl, tw
        elif tw is not None and tw.version == version:
            win, lose = tw, fl
        else:
            return                            # stale (churn retimed the task)
        if lose is not None:                  # cancel the slower racing twin
            self.pool.release(lose.k_row, lose.b_row)
            self.metrics.record_share_interval(lose.k_row, lose.b_row,
                                               t - lose.t_admit)
        self.twins.pop(tid, None)
        self.inflight[tid] = win
        self._finalize(win, t)
        self._drain_queue(t)

    def _attempts(self) -> List[_InFlight]:
        return list(self.inflight.values()) + list(self.twins.values())

    def _alive(self, fl: _InFlight) -> bool:
        return self.inflight.get(fl.tid) is fl or self.twins.get(fl.tid) is fl

    def _on_churn(self, ev: WorkerEvent, t: float) -> None:
        w = ev.worker
        undo = self.scale[w]
        if self.tracer is not None:
            self.tracer.instant(f"churn:{ev.kind}:w{w}", t, cat="churn",
                                track=f"sim:worker{w}",
                                args={"worker": w, "kind": ev.kind,
                                      "factor": ev.factor})
        if ev.kind == "leave" or ev.kind == "crash":
            self.pool.set_online(w, False)
            if ev.kind == "crash":
                self.fault_stats["crashes"] += 1
        elif ev.kind == "join":
            self.pool.set_online(w, True)
        elif ev.kind == "degrade":
            self.scale[w] *= ev.factor
        elif ev.kind == "restore":
            self.scale[w] = 1.0
        # the effective scenario must reflect THIS event before any retime:
        # re-dispatches and speculative twins triggered below sample their
        # delays from it
        self._sc_eff = self.planner.effective_scenario(self.online, self.scale)
        # pool membership/speed changed: consumers holding plan-derived
        # state (the serving bridge's step-plan cache subscribes through
        # the planner) must drop it even when the replan policy decides
        # the drift is too small to re-solve
        self.planner.notify_pool_change()
        if ev.kind in ("leave", "crash", "degrade", "restore"):
            for fl in self._attempts():
                if self._alive(fl) and churn_finish_update(
                        fl.finish, fl.l_row, w, ev.kind, t,
                        factor=ev.factor, undo=undo):
                    if self.tracer is not None:
                        self.tracer.count("churn_retimes", t=t, track="sim")
                    self._retime(fl, t)
        self.planner.ensure_plan(self.online, self.scale, event=True)
        self._drain_queue(t)

    # ----------------------------------------------------- vectorised drains
    #
    # The batched loop (BackendConfig.event_batch > 1) pops *mixed runs* of
    # arrival + completion events instead of one heap entry at a time — at
    # steady state the two kinds alternate, so homogeneous runs would be
    # near-singletons — and pushes their math through the batched backend
    # primitives.  Correctness contract: every *ledger* mutation (SharePool
    # acquire/release) happens in the exact (time, seq) order the per-event
    # loop would produce; the pure math (delay sampling, delivered-row
    # counts, completion times) and the metric finalisation defer to one
    # batched call per run.  Observable divergences: (a) generated events
    # get different seq numbers (matters only on exact time ties — measure
    # zero under continuous arrival/delay distributions), (b) ledger /
    # busy-time accumulators are summed with array ops (float associativity
    # at the ulp level), and (c) completions finalise in run order, so a
    # deferred completion landing inside the run's span records *after* the
    # run's own completions — the metrics lists are a permutation of the
    # per-event ones and every summary statistic is order-invariant.
    # Anything the fast path cannot handle exactly — a backlogged queue,
    # racing twins, fairness or partial-fraction admission, verification
    # numerics (whose probe RNG pairs with buffer order) — drops to the
    # unchanged per-event handlers.

    def _drain_run(self, until: float) -> None:
        fast = (len(self.queue) == 0 and not self.twins
                and self.tracer is None
                and self._fault_sched is None
                and self.numerics != "verify"
                and not self.planner.needs_all
                and not self.queue.uses_fairness
                and self.admission.min_fraction <= 1.0)
        if not fast:
            ev = self.loop.pop()
            self.events_processed += 1
            if ev.kind == ARRIVAL:
                self._on_arrival(ev.payload, ev.time)
            else:
                self._on_completion(ev.payload, ev.time)
            return
        # Lazy walk: peek-then-pop one head event at a time, so arrivals
        # pushed mid-walk (a processed arrival schedules its source's next
        # one) join the same window in true heap order — nothing is popped
        # optimistically, so nothing ever needs re-queueing.
        loop = self.loop
        pend: List[Tuple] = []      # admitted arrivals awaiting delay math
        done: List[Tuple] = []      # live completions awaiting finalise
        n = 0
        while n < self._event_batch:
            ev = loop.head()
            if ev is None or ev.time > until or \
                    (ev.kind != ARRIVAL and ev.kind != COMPLETION):
                break
            if ev.kind == COMPLETION:
                loop.pop()
                tid, version = ev.payload
                fl = self.inflight.get(tid)
                if fl is not None and fl.version == version:
                    # release in walk order: later arrivals' headroom
                    # checks must see these shares, exactly as per-event
                    self.pool.release(fl.k_row, fl.b_row)
                    done.append((fl, ev.time))
                n += 1
                continue
            if self._emitted >= self.max_tasks:
                loop.pop()
                n += 1
                continue
            src = self.sources[ev.payload]
            m = src.master
            row = self._fast_row(m)
            if row is None or not self.pool.has_headroom(row[0], row[1]):
                # uncoverable row, or shares that would need scaling: the
                # reference handler decides queue-vs-scale-vs-reject.  With
                # no progress yet it must run *now* (stalling without
                # popping would respin this method forever); otherwise end
                # the window first so the flushed completions below land on
                # the heap ahead of it.
                if n == 0:
                    loop.pop()
                    self.events_processed += 1
                    self._on_arrival(ev.payload, ev.time)
                    return
                break
            loop.pop()
            t = ev.time
            k_row, b_row, l_row, t_pred, l_sum = row
            tid = self._next_tid
            self._next_tid += 1
            self._emitted += 1
            rec = TaskRecord(tid=tid, master=m, t_arrive=t,
                             rows_needed=float(self.sc.L[m]))
            self.tasks[tid] = rec
            rec.deadline = float(src.deadline_for(t, t_pred))
            if self._emitted < self.max_tasks:
                t_next = src.next_after(t)
                if np.isfinite(t_next):
                    loop.push(t_next, ARRIVAL, ev.payload)
            # The ledger mutates per item (sequential, bitwise the
            # per-event order); only the delay/completion math defers.
            # Unchecked: has_headroom above already proved the acquire
            # cannot violate the column-sum invariant.
            self.pool.acquire_unchecked(k_row, b_row)
            rec.rows_total += l_sum
            rec.t_admit = t
            rec.fraction = 1.0
            self.queue.note_admitted(m)
            pend.append((tid, m, t, k_row, b_row, l_row))
            n += 1
        self.events_processed += n
        self._flush_completions(done)
        self._flush_pending(pend)

    def _flush_completions(self, done: List[Tuple]) -> None:
        """Finalise a run's live completions in one batched pass.

        Their shares were already released item-by-item during the walk
        (ledger order is part of the exactness contract); what remains —
        delivered-row counts, busy-time accounting, task records — is pure
        math over per-task state frozen at release time, batched here."""
        if not done:
            return
        F = np.stack([fl.finish for fl, _ in done])
        Lr = np.stack([fl.l_row for fl, _ in done])
        ts = np.asarray([t for _, t in done])
        delivered = bk.delivered_by(F, Lr, ts)
        Kr = np.stack([fl.k_row for fl, _ in done])
        Br = np.stack([fl.b_row for fl, _ in done])
        self.metrics.record_share_interval_many(
            Kr, Br, ts - np.asarray([fl.t_admit for fl, _ in done]))
        self.metrics.record_tasks_many(
            [self.tasks[fl.tid] for fl, _ in done], ts, delivered)
        for fl, _ in done:
            del self.inflight[fl.tid]
            if not self._keep_records:
                del self.tasks[fl.tid]

    def _fast_row(self, m: int):
        """Cached full-share admission row of master ``m``, or None.

        Returns ``(k_row, b_row, l_row, t_pred, l_sum)`` — bitwise what
        ``scale_shares`` + ``scaled_row_loads`` produce at f = 1 — valid
        while neither the active plan nor the effective scenario object has
        been replaced (both are swapped wholesale on churn/replan, never
        mutated).  None when the row's loads cannot *strictly* cover L_m
        (the guarantee that makes a dispatch's completion finite without
        evaluating it)."""
        plan = self.planner._plan
        if plan is None:
            plan = self.planner.ensure_plan(self.online, self.scale,
                                            event=True)
        cache = self._row_cache
        ctx = cache.get("_ctx")
        if ctx is None or ctx[0] is not plan or ctx[1] is not self._sc_eff:
            cache.clear()
            cache["_ctx"] = (plan, self._sc_eff)
        row = cache.get(m)
        if row is None:
            k_row = np.where(self.online, plan.k[m], 0.0)
            b_row = np.where(self.online, plan.b[m], 0.0)
            k_row[0] = b_row[0] = 1.0
            l_row, _ = scaled_row_loads(self._sc_eff, m, k_row, b_row)
            l_sum = float(l_row.sum())
            ok = l_sum >= float(self.sc.L[m]) + 1e-9
            row = (k_row, b_row, l_row, float(plan.t_per_master[m]), l_sum,
                   ok)
            cache[m] = row
        return row[:5] if row[5] else None

    def _flush_pending(self, pend: List[Tuple]) -> None:
        """Sample delays + completion times for a run's admitted arrivals in
        one batched backend call each, then push their completion events.

        Deferral is sound because every pending task was admitted at full
        shares with strict coverage: its dispatch cannot fail, consumes
        exactly one delay draw (in admission order — ``draw_n`` is defined
        as n successive draws), and its completion event cannot influence
        any arrival accepted later in the same run (an empty queue means a
        completion only releases shares, and the fast path admits without
        needing them)."""
        if not pend:
            return
        B = len(pend)
        E = self._exp.draw_n(B)
        ms = np.asarray([p[1] for p in pend])
        Kr = np.stack([p[3] for p in pend])
        Br = np.stack([p[4] for p in pend])
        Lr = np.stack([p[5] for p in pend])
        d = bk.sample_delays(E[:, 0], E[:, 1], Lr, Kr, Br,
                             self._sc_eff.a[ms], self._sc_eff.u[ms],
                             self._sc_eff.gamma[ms],
                             straggle_p=self.straggle_p,
                             straggle_factor=self.straggle_factor,
                             straggle_u=E[:, 2] if self.straggle_p > 0
                             else None)
        ts = np.asarray([p[2] for p in pend])
        finish = np.where(Lr > 0, ts[:, None] + d, np.inf)
        need = self.sc.L[ms]
        comp = bk.completion_times(finish, Lr, need, needs_all=False,
                                   backend="numpy")
        deferred: List[Event] = []
        for i, (tid, m, t, k_row, b_row, l_row) in enumerate(pend):
            fl = _InFlight(tid=tid, master=int(m), k_row=k_row, b_row=b_row,
                           l_row=l_row, finish=finish[i],
                           need=float(need[i]), t_admit=t,
                           completion=float(comp[i]),
                           version=next(self._version_seq),
                           service_pred=float(comp[i]) - t, fraction=1.0)
            self.inflight[tid] = fl
            deferred.append(Event(float(comp[i]), next(self.loop._seq),
                                  COMPLETION, (tid, fl.version)))
        # requeue, not push: a completion earlier than the run's last
        # arrival is legitimately "in the past" of loop.now by design.
        self.loop.requeue(deferred)

    # ------------------------------------------------------------ admission

    def _fair_cap(self, m: int, k_req: np.ndarray,
                  b_req: np.ndarray) -> float:
        """Max-min fair share cap for master ``m`` (fair policy only).

        Claimants are masters with in-flight shares or waiting tasks; a
        waiting master's demand is its current plan row on the online
        workers."""
        held_rows: Dict[int, np.ndarray] = {}
        for fl in self._attempts():
            acc = held_rows.setdefault(fl.master, np.zeros_like(k_req))
            acc += fl.k_row
        held, demands = fair_demand_rows(
            m, self.planner.plan.k, self.online,
            self.queue.waiting_masters(), held_rows)
        return self.queue.fair_fraction(m, k_req, b_req, held=held,
                                        demands=demands)

    def _dispatch(self, tid: int, t: float,
                  min_fraction: Optional[float] = None
                  ) -> Optional[_InFlight]:
        """Admit ``tid``'s work onto the pool: scale shares to what fits
        (and to the fair-share cap), derive Thm-1/3 loads, sample delivery
        times, and acquire the ledger.  Returns the attempt, or None if the
        task cannot run now (insufficient shares / cannot cover L_m).

        ``min_fraction`` overrides the admission floor and additionally
        masks the request to workers with *spare* shares (speculative twins
        race on whatever capacity the pool has left — their original
        attempt still holds its own columns)."""
        rec = self.tasks[tid]
        m = rec.master
        plan = self.planner.ensure_plan(self.online, self.scale)
        fair_fn = (lambda kq, bq: self._fair_cap(m, kq, bq)) \
            if self.queue.uses_fairness else None
        scaled = scale_shares(
            self.pool, plan.k[m], plan.b[m], self.online,
            allow_scaling=self.admission.allow_scaling,
            floor=self.admission.min_fraction if min_fraction is None
            else min_fraction,
            fair_fn=fair_fn, spare_only=min_fraction is not None)
        if scaled is None:
            return None
        k_row, b_row, f = scaled

        if self.planner.needs_all:
            # uncoded: equal re-split over the plan's surviving workers
            l_row = np.zeros_like(k_row)
            w = np.nonzero(k_row[1:] > 0)[0] + 1
            if w.size == 0:
                return None
            l_row[w] = self.sc.L[m] / w.size
        else:
            l_row, _ = scaled_row_loads(self._sc_eff, m, k_row, b_row)
        if l_row.sum() < self.sc.L[m] - 1e-6 and not self.planner.needs_all:
            return None                      # cannot cover L_m: wait

        e = self._exp.draw()
        d = bk.sample_delays(e[0], e[1], l_row, k_row, b_row,
                             self._sc_eff.a[m], self._sc_eff.u[m],
                             self._sc_eff.gamma[m],
                             straggle_p=self.straggle_p,
                             straggle_factor=self.straggle_factor,
                             straggle_u=e[2] if self.straggle_p > 0 else None)
        finish = np.where(l_row > 0, t + d, np.inf)
        if self._fault_sched is not None:
            disp = next(self._dispatch_seq)
            loaded = np.nonzero(l_row[1:] > 0)[0] + 1
            for w, kind in self._fault_sched.faults_at(disp, loaded).items():
                if kind == "drop" or kind == "crash":
                    # a crash drawn at dispatch granularity loses this
                    # shard; the worker-level death/readmission process is
                    # the pre-generated crash churn stream in _run_loop
                    finish[w] = np.inf
                    self.fault_stats[
                        "crashes" if kind == "crash" else "drops"] += 1
                elif kind == "stale":
                    finish[w] = t + (finish[w] - t) * self.faults.stale_factor
                    self.fault_stats["stales"] += 1
                elif kind == "duplicate":
                    # the receiver keys deliveries by (task, worker): a
                    # replayed shard overwrites itself — counted, inert
                    self.fault_stats["duplicates"] += 1
                else:                                # corruption kinds
                    self._corrupt_marks[tid] = (int(w), kind)
                    self.fault_stats["corruptions"] += 1
        comp = float(bk.completion_times(
            finish[None], l_row[None], np.array([self.sc.L[m]]),
            needs_all=self.planner.needs_all, backend="numpy")[0])
        if not np.isfinite(comp):
            return None

        self.pool.acquire(k_row, b_row)
        rec.rows_total += float(l_row.sum())
        fl = _InFlight(tid=tid, master=m, k_row=k_row, b_row=b_row,
                       l_row=l_row, finish=finish, need=float(self.sc.L[m]),
                       t_admit=t, completion=comp,
                       version=next(self._version_seq),
                       service_pred=comp - t, fraction=f)
        self.loop.push(comp, COMPLETION, (tid, fl.version))
        return fl

    def _try_admit(self, tid: int, t: float) -> bool:
        fl = self._dispatch(tid, t)
        if fl is None:
            return False
        rec = self.tasks[tid]
        rec.t_admit = t
        rec.fraction = fl.fraction
        self.inflight[tid] = fl
        self.queue.note_admitted(rec.master)
        if self.tracer is not None and t > rec.t_arrive:
            self.tracer.add_span(f"queue:t{tid}", rec.t_arrive, t,
                                 cat="queue", track=f"sim:m{rec.master}",
                                 args={"task": tid})
        return True

    def _maybe_speculate(self, fl: _InFlight, t: float) -> None:
        """Race a twin dispatch against a straggling in-flight task.

        Triggered when churn re-timing pushed the predicted completion past
        ``speculate_factor ×`` the service time predicted at dispatch —
        *before* a ``leave`` event proves the original attempt lost.  The
        twin runs on whatever shares the pool has spare; first attempt to
        cover L_m wins and the loser is cancelled (its rows are the waste
        this insurance costs)."""
        sf = self.admission.speculate_factor
        if sf is None or fl.speculative or fl.tid in self.twins:
            return
        if self.inflight.get(fl.tid) is not fl:
            return
        if (fl.completion - fl.t_admit) <= sf * fl.service_pred:
            return
        tw = self._dispatch(fl.tid, t, min_fraction=1e-3)
        if tw is not None:
            tw.speculative = True
            self.twins[fl.tid] = tw
            self.tasks[fl.tid].speculated = True
            self.metrics.speculations += 1

    def _drain_queue(self, t: float) -> None:
        self._drain_queue_inner(t)
        if self.tracer is not None:
            self.tracer.gauge("queue_depth", len(self.queue), t=t,
                              track="sim")

    def _drain_queue_inner(self, t: float) -> None:
        while len(self.queue):
            if self.queue.head_of_line:
                # only the head can go: O(1)/O(log Q), no full reorder
                tid = self.queue.head()
                if tid is None or not self._try_admit(tid, t):
                    return                    # head-of-line blocking
                self.queue.remove(tid)
                continue
            admitted = False
            for tid in self.queue.candidates():
                if self._try_admit(tid, t):
                    self.queue.remove(tid)
                    admitted = True
                    break
            if not admitted:
                return

    # ----------------------------------------------------------- completion

    def _retime(self, fl: _InFlight, t: float) -> None:
        comp = float(bk.completion_times(
            fl.finish[None], fl.l_row[None], np.array([fl.need]),
            needs_all=self.planner.needs_all, backend="numpy")[0])
        if comp == fl.completion:
            return
        fl.version = next(self._version_seq)
        if np.isfinite(comp):
            fl.completion = comp
            self.loop.push(max(comp, t), COMPLETION, (fl.tid, fl.version))
            self._maybe_speculate(fl, t)
        else:
            self._drop_attempt(fl, t)

    def _drop_attempt(self, fl: _InFlight, t: float) -> None:
        """An attempt lost too many deliveries to ever cover L: release its
        shares; keep the surviving twin, or re-dispatch from scratch."""
        self.pool.release(fl.k_row, fl.b_row)
        self.metrics.record_share_interval(fl.k_row, fl.b_row, t - fl.t_admit)
        if self.twins.get(fl.tid) is fl:
            del self.twins[fl.tid]            # twin lost; original continues
            return
        del self.inflight[fl.tid]
        tw = self.twins.pop(fl.tid, None)
        if tw is not None:
            self.inflight[fl.tid] = tw        # promote the surviving twin
            # it is the task's primary attempt now — a later straggle may
            # legitimately speculate a fresh twin against it
            tw.speculative = False
            return
        rec = self.tasks[fl.tid]
        rec.retries += 1
        if not self._try_admit(fl.tid, t):
            # already-admitted work re-queues past the backpressure
            # bound — it must not be silently dropped mid-service
            self.queue.offer(fl.tid, master=rec.master,
                             deadline=rec.deadline, force=True)

    def _finalize(self, fl: _InFlight, t: float) -> None:
        rec = self.tasks[fl.tid]
        rec.t_complete = t
        rec.rows_delivered = float(bk.delivered_by(
            fl.finish[None], fl.l_row[None], np.array([t]))[0])
        if self.tracer is not None:
            self._trace_task(fl, rec, t)
        self.pool.release(fl.k_row, fl.b_row)
        self.metrics.record_share_interval(fl.k_row, fl.b_row, t - fl.t_admit)
        self.metrics.record_task(rec)
        del self.inflight[fl.tid]
        if self.numerics == "verify" and not self.planner.needs_all:
            self._verify_buf.append(fl)
        elif not self._keep_records:
            del self.tasks[fl.tid]

    def _trace_task(self, fl: _InFlight, rec: TaskRecord, t: float) -> None:
        """Sim-time spans for a completed attempt: the service interval on
        the master's lane, one delivery span per contributing worker on the
        worker's lane.  The *critical* delivery (finish == completion) is
        the covering-prefix row that closed the task — the paper's slowest-
        task objective, made visible per task."""
        tr = self.tracer
        tr.add_span(f"service:t{fl.tid}", fl.t_admit, t, cat="task",
                    track=f"sim:m{fl.master}",
                    args={"task": fl.tid, "fraction": fl.fraction,
                          "retries": rec.retries,
                          "speculative": fl.speculative})
        eps = 1e-9 * max(1.0, abs(t))
        for n in np.nonzero(fl.l_row > 0)[0]:
            fin = float(fl.finish[n])
            if not np.isfinite(fin):
                continue
            tr.add_span(f"t{fl.tid}/w{int(n)}", fl.t_admit, fin,
                        cat="delivery", track=f"sim:worker{int(n)}",
                        args={"worker": int(n), "task": fl.tid,
                              "rows": float(fl.l_row[n]),
                              "delivered": bool(fin <= t + eps),
                              "critical": bool(abs(fin - t) <= eps)})

    # --------------------------------------------------- batched verification

    def _verify_products(self, G: np.ndarray, A: np.ndarray, x: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-task true products Z_b = A_b x_b and coded results G @ Z_b.

        numpy: two einsums.  jax: the same contraction jitted on device.
        pallas: the ``repro.kernels`` serving path — ``coded_matvec`` for
        the per-task products (one kernel call per task via vmap) and
        ``mds_encode`` for the generator application, which skips the
        identity prefix of the systematic generator entirely.  Returns
        (Z (B, L), y_full (B, L̃)) as host arrays."""
        if self.backend == "numpy":
            Z = np.einsum("bls,bs->bl", A, x)
            return Z, Z @ G.T
        import jax.numpy as jnp
        if self.backend == "pallas":
            from ..kernels import ops
            Z = ops.coded_matvec_batch(jnp.asarray(A), jnp.asarray(x))
            y_full = ops.mds_encode(jnp.asarray(G), Z.T).T
        else:
            Z = jnp.einsum("bls,bs->bl", jnp.asarray(A), jnp.asarray(x))
            y_full = Z @ jnp.asarray(G).T
        return np.asarray(Z, dtype=np.float64), \
            np.asarray(y_full, dtype=np.float64)

    def _run_verification(self) -> None:
        """Execute the completed tasks' numerics in per-master batches.

        One generator, one batched encode and one batched exactly-L decode
        per master — instead of ``CodedExecutor``'s per-task pipeline.  The
        decode takes the systematic-prefix fast path (a scatter, no solve)
        whenever a task's prefix contains only identity rows."""
        verify_tol = 1e-6 if self.backend == "numpy" else 5e-4
        by_master: Dict[int, List[_InFlight]] = {}
        for fl in self._verify_buf:
            by_master.setdefault(fl.master, []).append(fl)
        for m, fls in by_master.items():
            L = int(round(float(self.sc.L[m])))
            li = [mds.integer_loads(fl.l_row, 0) for fl in fls]
            Lt = max(max(int(x.sum()) for x in li), L)
            vrng = np.random.default_rng((self.seed, 0x7E51, m))
            G = mds.make_generator(L, Lt, kind="systematic", rng=vrng,
                                   dtype=np.float64)
            B, S = len(fls), self.verify_cols
            A = vrng.normal(size=(B, L, S))
            x = vrng.normal(size=(B, S))
            tr = self.tracer
            # cat "verify", not the stage cats: the wrapped calls (pallas /
            # jitted products, decode_batch -> plan_decode + apply) emit
            # their own kernel/plan/decode spans, and stage categories must
            # not double count nested work
            ctx = tr.span(f"verify:m{m}:products", cat="verify",
                          args={"tasks": B, "backend": self.backend}) \
                if tr is not None else contextlib.nullcontext()
            with ctx:
                Z, y_full = self._verify_products(G, A, x)  # (B, L), (B, Lt)
            detect = self.faults is not None and self.faults.detect
            cap = int(self.faults.surplus_rows) if detect else 0
            rows = np.empty((B, L), dtype=np.int64)
            valid = np.ones(B, dtype=bool)
            # per-task delivered rows beyond the prefix + row→worker
            # attribution: the fault detector's parity-check budget
            extras: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
            for i, (fl, lint) in enumerate(zip(fls, li)):
                active = np.nonzero(lint > 0)[0]
                slices = mds.split_loads(int(lint[active].sum()), lint[active])
                order = np.argsort(np.where(np.isfinite(fl.finish[active]),
                                            fl.finish[active], np.inf),
                                   kind="stable")
                got: List[np.ndarray] = []
                gotw: List[np.ndarray] = []
                acc = 0
                for j in order:
                    if not np.isfinite(fl.finish[active[j]]) or \
                            fl.finish[active[j]] > fl.completion + 1e-9:
                        continue
                    got.append(slices[j])
                    gotw.append(np.full(slices[j].size, active[j],
                                        dtype=np.int64))
                    acc += slices[j].size
                    if acc >= L + cap:
                        break
                if acc < L:
                    valid[i] = False
                    continue
                allr = np.concatenate(got)[:L + cap]
                rows[i] = allr[:L]
                if self.faults is not None:
                    extras[i] = (allr, np.concatenate(gotw)[:L + cap])
            idx = np.nonzero(valid)[0]
            if idx.size:
                y_rows = np.take_along_axis(y_full[idx], rows[idx], axis=1)
                if self._corrupt_marks:
                    for pos, i in enumerate(idx):
                        mark = self._corrupt_marks.get(fls[i].tid)
                        if mark is None:
                            continue
                        w, kind = mark
                        msk = extras[i][1][:L] == w
                        if msk.any():
                            y_rows[pos, msk] = corrupt_products(
                                y_rows[pos, msk], kind,
                                eps=self.faults.corrupt_eps)
                ctx = tr.span(f"verify:m{m}:decode", cat="verify",
                              args={"tasks": int(idx.size)}) \
                    if tr is not None else contextlib.nullcontext()
                with ctx:
                    y_hat = bk.decode_batch(
                        G, rows[idx], y_rows,
                        backend="numpy" if self.backend == "numpy" else "jax")
                truth = Z[idx]
                err = np.abs(y_hat - truth).max(axis=1)
                tol = verify_tol * (1.0 + np.abs(truth).max(axis=1))
                for j, i in enumerate(idx):
                    rec = self.tasks[fls[i].tid]
                    rec.max_err = float(err[j])
                    rec.decode_ok = bool(err[j] <= tol[j])
                if detect:
                    self._detect_corruptions(G, fls, idx, extras, y_full,
                                             y_hat, L)
            for i in np.nonzero(~valid)[0]:
                self.tasks[fls[i].tid].decode_ok = False

    def _detect_corruptions(self, G: np.ndarray, fls: List[_InFlight],
                            idx: np.ndarray, extras: Dict, y_full: np.ndarray,
                            y_hat: np.ndarray, L: int) -> None:
        """Residual-check each task's surplus deliveries against its decode.

        A corrupted delivery either fed the decode (honest surplus rows
        then disagree with the skewed x̂) or sits in the surplus itself
        (its own residual blows up) — either way the task flags without
        ever consulting the ground truth.  Tasks whose marked worker
        delivered nothing in the covering window injected nothing; a flag
        there (or on an unmarked task) counts as a false positive."""
        tolr = float(self.faults.residual_tol)
        for pos, i in enumerate(idx):
            allr, allw = extras[i]
            sr, sw = allr[L:], allw[L:]
            if sr.size == 0:
                continue
            mark = self._corrupt_marks.get(fls[i].tid)
            y_sur = y_full[i, sr].copy()
            applied = False
            if mark is not None:
                w, kind = mark
                applied = bool((allw == w).any())
                msk = sw == w
                if msk.any():
                    y_sur[msk] = corrupt_products(
                        y_sur[msk], kind, eps=self.faults.corrupt_eps)
            resid = np.abs(y_sur - G[sr] @ y_hat[pos]) / (1.0 + np.abs(y_sur))
            flagged = bool((resid > tolr).any())
            if mark is not None and applied:
                self.fault_stats["corruptions_applied"] += 1
                if flagged:
                    self.fault_stats["detected"] += 1
            elif flagged:
                self.fault_stats["false_flags"] += 1
