"""Discrete-event core of the streaming scheduler.

A heap-based event loop with a strict total order (time, then insertion
sequence) so that same-seed runs replay identically, plus the arrival /
churn processes that feed it:

* ``PoissonProcess`` — per-master memoryless task arrivals.
* ``TraceProcess``  — replay recorded arrival instants.
* ``WorkerEvent``   — worker churn: ``leave`` / ``join`` / ``degrade`` /
  ``restore`` at a given time, with a slowdown ``factor`` for degradation.

Event kinds are plain strings; payloads are opaque to the loop.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ARRIVAL", "COMPLETION", "CHURN", "REPLAN",
    "Event", "EventLoop",
    "ArrivalProcess", "PoissonProcess", "TraceProcess",
    "WorkerEvent",
]

ARRIVAL = "arrival"
COMPLETION = "completion"
CHURN = "churn"
REPLAN = "replan"


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    seq: int
    kind: str
    payload: Any = None


class EventLoop:
    """Min-heap of events keyed by (time, seq).

    ``seq`` is a global insertion counter: ties in time resolve in push
    order, which makes the whole simulation a pure function of its seeds.
    """

    def __init__(self):
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = itertools.count()
        self.now = 0.0

    def push(self, time: float, kind: str, payload: Any = None) -> Event:
        if time < self.now - 1e-12:
            raise ValueError(f"event at t={time} is in the past (now={self.now})")
        ev = Event(float(time), next(self._seq), kind, payload)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def pop(self) -> Event:
        time, _, ev = heapq.heappop(self._heap)
        self.now = time
        return ev

    def peek_time(self) -> float:
        return self._heap[0][0] if self._heap else np.inf

    def peek_key(self) -> Tuple[float, int]:
        """(time, seq) of the head event without popping; (inf, -1) empty."""
        if not self._heap:
            return (np.inf, -1)
        t, s, _ = self._heap[0]
        return (t, s)

    def peek_kind(self) -> Optional[str]:
        return self._heap[0][2].kind if self._heap else None

    def head(self) -> Optional[Event]:
        """The head event without popping (None when empty)."""
        return self._heap[0][2] if self._heap else None

    def pop_run(self, max_events: int, max_time: float = np.inf,
                kinds: Optional[Tuple[str, ...]] = None) -> List[Event]:
        """Pop up to ``max_events`` consecutive events of the head's kind —
        or, with ``kinds``, of any kind in that set (a *mixed* run).

        The run stops at the first event of another kind (or whose time
        exceeds ``max_time``) — events come off the heap in exactly the
        (time, seq) order ``pop`` would yield, so a caller that processes
        the run items left to right (and re-queues any suffix it cannot
        handle via :meth:`requeue`) observes the identical total order.
        ``now`` advances to the last popped event's time; callers stepping
        through the run item by item may assign ``now`` per item (it only
        moves forward).
        """
        run: List[Event] = []
        if not self._heap:
            return run
        allowed = kinds if kinds is not None else (self._heap[0][2].kind,)
        while self._heap and len(run) < max_events:
            t, _, ev = self._heap[0]
            if ev.kind not in allowed or t > max_time:
                break
            heapq.heappop(self._heap)
            self.now = t
            run.append(ev)
        return run

    def requeue(self, events: Iterable[Event]) -> None:
        """Push already-popped events back, keeping their original seq.

        Used by batched processors that popped a run optimistically and then
        discovered a generated event (e.g. a completion) lands *inside* the
        run: the unprocessed suffix goes back with its (time, seq) keys
        intact, so the total order is exactly the per-event one.  ``now``
        rolls back to the earliest requeued event (the caller has not
        processed anything at or past it).
        """
        for ev in events:
            heapq.heappush(self._heap, (ev.time, ev.seq, ev))
            if ev.time < self.now:
                self.now = ev.time

    def __len__(self) -> int:
        return len(self._heap)

    def empty(self) -> bool:
        return not self._heap


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

class ArrivalProcess:
    """One task source bound to a master; yields successive arrival times.

    ``deadline_slack`` optionally attaches a completion deadline to every
    arrival: ``deadline = t_arrive + slack × t_pred`` with ``t_pred`` the
    plan-predicted completion of the master at arrival time (so "slack 2"
    means *twice the unloaded service time* regardless of master speed).
    ``None`` (default) means no deadline (inf) — deadline-aware admission
    policies then degenerate to FIFO.
    """

    master: int
    deadline_slack: Optional[float] = None

    def next_after(self, t: float) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def deadline_for(self, t: float, t_pred: float) -> float:
        """Absolute deadline of the arrival at ``t`` (inf = none)."""
        if self.deadline_slack is None or not np.isfinite(t_pred):
            return np.inf
        return t + self.deadline_slack * t_pred


class PoissonProcess(ArrivalProcess):
    """Poisson arrivals of rate ``rate`` (tasks per unit time) at ``master``.

    Each process owns an independent Generator seeded from (seed, master) so
    the arrival sequence is independent of event interleaving.
    """

    def __init__(self, master: int, rate: float, seed: int = 0,
                 deadline_slack: Optional[float] = None):
        if rate <= 0:
            raise ValueError("rate must be > 0")
        self.master = int(master)
        self.rate = float(rate)
        self.deadline_slack = deadline_slack
        self.rng = np.random.default_rng((int(seed), int(master), 0xA221))

    def next_after(self, t: float) -> float:
        return t + self.rng.exponential(1.0 / self.rate)


class TraceProcess(ArrivalProcess):
    """Replays a fixed sequence of arrival instants (trace-driven mode).

    ``deadlines`` optionally gives an *absolute* deadline per traced
    arrival (aligned with ``times`` after sorting); otherwise
    ``deadline_slack`` applies as in :class:`ArrivalProcess`.
    """

    def __init__(self, master: int, times: Sequence[float],
                 deadlines: Optional[Sequence[float]] = None,
                 deadline_slack: Optional[float] = None):
        self.master = int(master)
        self.deadline_slack = deadline_slack
        order = np.argsort(np.asarray([float(t) for t in times]),
                           kind="stable")
        self.times = [float(times[i]) for i in order]
        self.deadlines = None
        if deadlines is not None:
            if len(deadlines) != len(self.times):
                raise ValueError("deadlines must align with times")
            self.deadlines = [float(deadlines[i]) for i in order]
        self._i = 0

    def next_after(self, t: float) -> float:
        while self._i < len(self.times) and self.times[self._i] < t - 1e-12:
            self._i += 1
        if self._i >= len(self.times):
            return np.inf
        out = self.times[self._i]
        self._i += 1
        return out

    def deadline_for(self, t: float, t_pred: float) -> float:
        if self.deadlines is not None:
            # the arrival being handled is the one next_after last yielded
            return self.deadlines[max(self._i - 1, 0)]
        return super().deadline_for(t, t_pred)


# ---------------------------------------------------------------------------
# Worker churn
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkerEvent:
    """A scheduled change to worker ``worker`` (1-based column) at ``time``.

    kind:
      ``leave``    worker goes offline *gracefully* (scheduled departure);
                   undelivered in-flight rows are lost (redundancy or
                   re-dispatch covers them).
      ``crash``    worker dies mid-task: same delivery loss as ``leave``
                   but unscheduled — typically produced by a fault
                   schedule (:mod:`repro.faults`), paired with a later
                   backoff ``join`` for recovery, and counted separately.
      ``join``     worker (re)joins the pool for new tasks.
      ``degrade``  worker slows down by ``factor`` (a×f, u/f, γ/f), applied
                   to new tasks and to the *remaining* time of in-flight
                   deliveries.
      ``restore``  degradation factor reset to 1.
    """
    time: float
    worker: int
    kind: str
    factor: float = 2.0

    def __post_init__(self):
        if self.kind not in ("leave", "crash", "join", "degrade", "restore"):
            raise ValueError(f"unknown churn kind {self.kind!r}")
        if self.kind == "degrade" and self.factor <= 0:
            raise ValueError("degrade factor must be > 0")
