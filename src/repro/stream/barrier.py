"""Churn re-timing of in-flight coded tasks, and multi-task step barriers.

Two consumers share this module so their delivery semantics cannot drift:

* the :class:`~repro.stream.engine.StreamingExecutor` re-times each
  in-flight task's per-node delivery vector when a worker leaves, degrades
  or restores (``churn_finish_update`` is the single implementation of that
  arithmetic, factored out of the engine's ``_on_churn``);
* the coded serving bridge (:mod:`repro.serve_coded`), whose one "step" is
  now *several* concurrent coded tasks — one per trunk matmul per the
  configured coding scope — joined by a :class:`StepBarrier`: the step
  completes when every member task's earliest covering prefix has landed,
  and churn re-times every member through the same
  ``churn_finish_update`` path the engine uses.

Semantics (identical to the engine's historical in-line behaviour):

* ``leave``    — undelivered rows on that worker are lost (delivery → ∞);
* ``crash``    — identical delivery arithmetic to ``leave`` (a shard is
  delivered whole or not at all, so an unscheduled death loses exactly the
  pending deliveries); the *scheduling* difference — quarantine, backoff
  readmission, twin promotion — lives in the engine/bridge churn handlers;
* ``degrade``  — the *remaining* time of undelivered rows stretches by the
  event factor (work already under way is slowed, not restarted);
* ``restore``  — the remaining time shrinks by the accumulated slowdown
  being cleared (``undo``);
* ``join``     — no effect on in-flight deliveries (new capacity only
  helps future dispatches).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from . import backend as bk

__all__ = ["churn_finish_update", "BarrierTask", "StepBarrier"]


def churn_finish_update(finish: np.ndarray, loads: np.ndarray, worker: int,
                        kind: str, t: float, *, factor: float = 1.0,
                        undo: float = 1.0) -> bool:
    """Apply one churn event to an absolute delivery vector, in place.

    ``finish``/``loads`` are (N+1,) per-node arrays (column 0 = the
    master's local processor, which churn never touches by construction —
    worker events carry n ≥ 1).  Only *pending* deliveries move: a shard
    that already landed (``finish <= t``) is history.  Returns True when
    the vector changed (the caller should re-derive the completion time).
    """
    w = int(worker)
    if loads[w] <= 0 or finish[w] <= t:
        return False
    if kind == "leave" or kind == "crash":
        if not np.isfinite(finish[w]):
            return False
        finish[w] = np.inf
        return True
    if not np.isfinite(finish[w]):
        return False
    if kind == "degrade":
        finish[w] = t + (finish[w] - t) * factor
        return True
    if kind == "restore":
        if undo <= 0:
            return False
        finish[w] = t + (finish[w] - t) / undo
        return True
    return False                                  # "join": in-flight unmoved


@dataclasses.dataclass
class BarrierTask:
    """One coded matmul of a serving dispatch, delivery-timed per node.

    name:   log label ("head", "blk1.wq", ...).
    l_int:  (N+1,) integer shard sizes dispatched per node.
    finish: (N+1,) absolute delivery times (inf = never arrives).
    need:   rows whose earliest covering prefix completes this task
            (the coded matrix's own L, not the plan scenario's).
    assign: optional (N+1,) expected-delay sort key fixing which node
            holds which contiguous coded-row range (None = node order);
            dispatch-time information only — see
            ``CodedLinear.prefix_plan``.
    """
    name: str
    l_int: np.ndarray
    finish: np.ndarray
    need: float
    completion: float = np.inf
    assign: "np.ndarray | None" = None


class StepBarrier:
    """Completion barrier over the coded tasks of one serving dispatch.

    All member tasks are dispatched together (the workers hold the encoded
    weight shards; the step's activations stream to them as one admission),
    and the step's result is usable only when *every* task has decoded —
    so the barrier completes at the max of the per-task earliest-prefix
    completion times.  ``retime`` runs the engine's churn arithmetic over
    every member and re-derives the completions in one batched
    ``completion_times`` call.
    """

    def __init__(self, tasks: Sequence[BarrierTask], *,
                 F: "np.ndarray | None" = None,
                 l: "np.ndarray | None" = None,
                 need: "np.ndarray | None" = None):
        if not tasks:
            raise ValueError("a StepBarrier needs at least one task")
        self.tasks: List[BarrierTask] = list(tasks)
        if F is None:
            self.recompute()
            return
        # fast path for the serving dispatch: the caller already holds the
        # stacked (T, N+1) finish/load arrays the member tasks view into,
        # so skip recompute()'s per-task re-stacking
        comp = bk.completion_times(F, l, need)
        for task, c in zip(self.tasks, comp):
            task.completion = float(c)

    @property
    def completion(self) -> float:
        """Absolute step completion: max over member tasks (inf when any
        member can no longer cover its rows)."""
        return max(task.completion for task in self.tasks)

    def recompute(self) -> float:
        F = np.stack([task.finish for task in self.tasks])
        l = np.stack([task.l_int.astype(np.float64) for task in self.tasks])
        need = np.array([task.need for task in self.tasks])
        comp = bk.completion_times(F, l, need)
        for task, c in zip(self.tasks, comp):
            task.completion = float(c)
        return self.completion

    def retime(self, worker: int, kind: str, t: float, *,
               factor: float = 1.0, undo: float = 1.0) -> bool:
        """Apply a churn event to every member's pending deliveries.

        Returns True when any delivery moved (completions were re-derived
        and the caller must reschedule its step event)."""
        changed = [churn_finish_update(task.finish, task.l_int, worker, kind,
                                       t, factor=factor, undo=undo)
                   for task in self.tasks]
        if any(changed):
            self.recompute()
            return True
        return False

    def delivery_orders(self) -> List[np.ndarray]:
        """Stable delivery-order argsort of every member task's *active*
        nodes, in one batched call — the planning input of the batched
        shard-execution engine (each array indexes that task's active-node
        subarray, exactly what ``CodedLinear.prefix_plan`` consumes).

        All member tasks of one dispatch normally share the plan row's
        active set (``coded_row_shards`` keeps the zero pattern), so the
        common case is a single stacked argsort; heterogeneous active sets
        fall back to per-task sorts.
        """
        F = np.stack([task.finish for task in self.tasks])
        act = np.stack([task.l_int > 0 for task in self.tasks])
        if (act == act[0]).all():
            sub = F[:, act[0]]
            sub = np.where(np.isfinite(sub), sub, np.inf)
            return list(np.argsort(sub, axis=1, kind="stable"))
        return [np.argsort(np.where(np.isfinite(f[a]), f[a], np.inf),
                           kind="stable")
                for f, a in zip(F, act)]

    def covering_selections(self) -> List[tuple]:
        """Every member task's delivered covering prefix, one stacked pass.

        For each task: which active nodes delivered within its completion
        window (delivery order), and the contiguous coded-row range each
        holds under the task's ``assign`` layout.  This is the selection
        half of ``CodedLinear.prefix_plan`` — orders, coverage cumsums and
        row-range edges computed for the whole barrier as stacked array
        ops instead of ~15 per-matmul Python passes.

        Returns ``[(workers, starts, stops), ...]`` per task, where
        ``workers`` are node columns in delivery order and
        ``[starts[i], stops[i])`` is the coded-row range worker i holds.
        Raises RuntimeError when any task's deliveries do not cover its
        ``need`` rows by its completion (same contract as
        ``prefix_plan``).
        """
        act = np.stack([task.l_int > 0 for task in self.tasks])
        homogeneous = bool((act == act[0]).all())
        if not homogeneous:
            return [self._covering_one(task) for task in self.tasks]
        A = np.nonzero(act[0])[0]
        F = np.stack([task.finish for task in self.tasks])[:, A]
        l_act = np.stack([task.l_int for task in self.tasks])[:, A]
        need = np.array([task.need for task in self.tasks])
        comp = np.array([task.completion for task in self.tasks])
        f_inf = np.where(np.isfinite(F), F, np.inf)
        orders = np.argsort(f_inf, axis=1, kind="stable")
        f_ord = np.take_along_axis(f_inf, orders, axis=1)
        l_ord = np.take_along_axis(l_act, orders, axis=1)
        ok = np.isfinite(f_ord) & (f_ord <= comp[:, None] + 1e-9)
        cum = np.cumsum(np.where(ok, l_ord, 0), axis=1)
        stop = (cum < need[:, None]).sum(axis=1)
        if (stop >= cum.shape[1]).any() or \
                (cum[np.arange(len(self.tasks)), np.minimum(
                    stop, cum.shape[1] - 1)] < need).any():
            raise RuntimeError("deliveries do not cover L by t_complete")
        # row-range edges under each task's assign layout (all-None =
        # node order; all tasks of one dispatch share the layout source)
        if all(task.assign is None for task in self.tasks):
            starts_all = np.concatenate(
                [np.zeros((len(self.tasks), 1), dtype=np.int64),
                 np.cumsum(l_act, axis=1)[:, :-1]], axis=1)
        else:
            asg = np.stack([task.assign for task in self.tasks])[:, A]
            aorder = np.argsort(asg, axis=1, kind="stable")
            l_sorted = np.take_along_axis(l_act, aorder, axis=1)
            starts_sorted = np.concatenate(
                [np.zeros((len(self.tasks), 1), dtype=np.int64),
                 np.cumsum(l_sorted, axis=1)[:, :-1]], axis=1)
            starts_all = np.empty_like(starts_sorted)
            np.put_along_axis(starts_all, aorder, starts_sorted, axis=1)
        out = []
        for i in range(len(self.tasks)):
            sel = np.nonzero(ok[i, :stop[i] + 1])[0]
            picked = orders[i, sel]
            starts = starts_all[i, picked]
            out.append((A[picked], starts, starts + l_act[i, picked]))
        return out

    def _covering_one(self, task: BarrierTask) -> tuple:
        """Scalar fallback mirroring ``prefix_plan``'s selection math."""
        l_int = np.asarray(task.l_int, dtype=np.int64)
        active = np.nonzero(l_int > 0)[0]
        l_act = l_int[active]
        if task.assign is None:
            starts_act = np.concatenate(
                [[0], np.cumsum(l_act)[:-1]]).astype(np.int64)
        else:
            aorder = np.argsort(task.assign[active], kind="stable")
            starts_act = np.empty(active.size, dtype=np.int64)
            starts_act[aorder] = np.concatenate(
                [[0], np.cumsum(l_act[aorder])[:-1]])
        f_act = task.finish[active]
        order = np.argsort(np.where(np.isfinite(f_act), f_act, np.inf),
                           kind="stable")
        f_ord = f_act[order]
        ok = np.isfinite(f_ord) & (f_ord <= task.completion + 1e-9)
        cum = np.cumsum(np.where(ok, l_act[order], 0))
        stop = int(np.searchsorted(cum, task.need))
        if stop >= cum.size or cum[stop] < task.need:
            raise RuntimeError("deliveries do not cover L by t_complete")
        sel = np.nonzero(ok[:stop + 1])[0]
        picked = order[sel]
        starts = starts_act[picked]
        return active[picked], starts, starts + l_act[picked]

    def rows_dispatched(self) -> int:
        return int(sum(int(task.l_int.sum()) for task in self.tasks))

    def rows_delivered_by(self, t: float) -> float:
        F = np.stack([task.finish for task in self.tasks])
        l = np.stack([task.l_int.astype(np.float64) for task in self.tasks])
        return float(bk.delivered_by(F, l, np.full(len(self.tasks), t)).sum())
