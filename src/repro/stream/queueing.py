"""Per-worker share tracking and pluggable admission control.

The paper's constraints (6c)/(25c) bound the *column sums* of the
computing-power and bandwidth fractions: Σ_m k_{m,n} ≤ 1 and
Σ_m b_{m,n} ≤ 1 for every shared worker n.  A static plan satisfies them
across masters; a streaming system must additionally satisfy them across
*concurrent in-flight tasks*.  ``SharePool`` is that ledger: tasks acquire
(k, b) rows on admission and release them on completion, and the engine
queues (backpressure) whatever does not fit.

Admission supports proportional down-scaling (fractional policies only): if
a task wants shares k_req but only f·k_req fits, it can run with f·k_req —
its loads are re-derived from the Theorem-3 closed form at the scaled
shares, trading a longer predicted completion for no queueing delay.

Which waiting task gets the next free shares is a pluggable
:class:`AdmissionPolicy`:

* ``fifo`` — arrival order with head-of-line blocking (the original
  behaviour; a newcomer may not slip past a waiting queue head);
* ``edf``  — earliest-deadline-first: candidates are ordered by task
  deadline (ties by arrival), the deadline-aware rule of Amiri & Gündüz
  (2018) for straggling workers;
* ``fair`` — per-master FIFO queues served round-robin (least-admitted
  master first, no cross-master head-of-line blocking) with **max-min fair
  share scaling**: a master's admitted column shares are capped at its
  water-filled max-min fair fraction of each contended worker, so one hot
  master cannot starve the rest even when it arrives first.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..obs import current_tracer

__all__ = [
    "AdmissionConfig", "SharePool", "WaitQueue",
    "AdmissionPolicy", "FIFOAdmission", "EDFAdmission", "FairShareAdmission",
    "make_admission_policy", "maxmin_share", "scale_shares",
    "fair_demand_rows",
]

_ATOL = 1e-9


@dataclasses.dataclass
class AdmissionConfig:
    """Admission / backpressure policy of the streaming engine.

    min_fraction: smallest acceptable down-scaling of the desired shares;
                  below it the task waits instead of running starved.
    allow_scaling: fractional policies may shrink shares; dedicated and
                  uncoded plans are all-or-nothing (whole workers).
    max_queue:    backpressure bound — arrivals beyond it are *rejected*
                  (counted, not simulated).  None = unbounded queue.
    policy:       waiting-task ordering: "fifo" | "edf" | "fair"
                  (see :func:`make_admission_policy`).
    speculate_factor: if set, an in-flight task whose re-timed completion
                  slips beyond ``factor ×`` its originally predicted service
                  time is speculatively re-dispatched on the spare pool
                  *before* a ``leave`` event proves the first attempt lost;
                  whichever attempt covers L first wins, the other is
                  cancelled.  None disables speculation.
    """
    min_fraction: float = 0.25
    allow_scaling: bool = True
    max_queue: Optional[int] = None
    policy: str = "fifo"
    speculate_factor: Optional[float] = None


class SharePool:
    """Ledger of in-flight (k, b) column sums over the N shared workers.

    Column 0 (the master's local processor) is never pooled: each master is
    always fully dedicated to itself (paper §II-A), so only columns 1..N are
    tracked.  Offline workers admit no new shares.
    """

    def __init__(self, N: int):
        self.N = int(N)
        self.k_used = np.zeros(N + 1)
        self.b_used = np.zeros(N + 1)
        self.online = np.ones(N + 1, dtype=bool)

    # -- capacity queries ---------------------------------------------------

    def available_k(self) -> np.ndarray:
        out = np.where(self.online, 1.0 - self.k_used, 0.0)
        out[0] = 1.0
        return np.maximum(out, 0.0)

    def available_b(self) -> np.ndarray:
        out = np.where(self.online, 1.0 - self.b_used, 0.0)
        out[0] = 1.0
        return np.maximum(out, 0.0)

    def feasible_fraction(self, k_req: np.ndarray, b_req: np.ndarray) -> float:
        """Largest f ∈ [0, 1] with f·k_req ≤ avail_k and f·b_req ≤ avail_b.

        Requests on offline workers force f = 0 (the caller should mask them
        out first if partial service is acceptable)."""
        need = (k_req[1:] > _ATOL) | (b_req[1:] > _ATOL)
        if not need.any():
            return 1.0
        ak, ab = self.available_k()[1:], self.available_b()[1:]
        with np.errstate(divide="ignore", invalid="ignore"):
            fk = np.where(k_req[1:] > _ATOL, ak / np.maximum(k_req[1:], _ATOL), np.inf)
            fb = np.where(b_req[1:] > _ATOL, ab / np.maximum(b_req[1:], _ATOL), np.inf)
        f = float(np.min(np.where(need, np.minimum(fk, fb), np.inf)))
        return float(np.clip(f, 0.0, 1.0))

    def has_headroom(self, k_req: np.ndarray, b_req: np.ndarray) -> bool:
        """Division-free check that implies ``feasible_fraction(...) == 1.0``.

        Conservative: it compares availability to the request elementwise,
        so within one ulp of the boundary it may say False where the
        division in :meth:`feasible_fraction` rounds up to exactly 1 —
        callers on a fast path then fall back to the exact computation.
        True always implies f ≥ 1 (each per-column quotient is ≥ 1 when
        availability ≥ request)."""
        kr, br = k_req[1:], b_req[1:]
        on = self.online[1:]
        ok = (((1.0 - self.k_used[1:] >= kr) & on) | (kr <= _ATOL)) \
            & (((1.0 - self.b_used[1:] >= br) & on) | (br <= _ATOL))
        return bool(ok.all())

    # -- mutation -----------------------------------------------------------

    def acquire(self, k_row: np.ndarray, b_row: np.ndarray) -> None:
        if np.any(self.k_used[1:] + k_row[1:] > 1.0 + 1e-6) or \
           np.any(self.b_used[1:] + b_row[1:] > 1.0 + 1e-6):
            raise ValueError("share acquisition violates column-sum <= 1")
        self.k_used[1:] += k_row[1:]
        self.b_used[1:] += b_row[1:]
        tr = current_tracer()
        if tr is not None:
            tr.gauge("pool_k_used", float(self.k_used[1:].sum()))

    def acquire_unchecked(self, k_row: np.ndarray, b_row: np.ndarray) -> None:
        """:meth:`acquire` minus the column-sum validation — for callers
        that have just proven :meth:`has_headroom` (availability ≥ request
        on every column implies the post-acquire sums stay ≤ 1)."""
        self.k_used[1:] += k_row[1:]
        self.b_used[1:] += b_row[1:]
        tr = current_tracer()
        if tr is not None:
            tr.gauge("pool_k_used", float(self.k_used[1:].sum()))

    def release(self, k_row: np.ndarray, b_row: np.ndarray) -> None:
        self.k_used[1:] = np.maximum(self.k_used[1:] - k_row[1:], 0.0)
        self.b_used[1:] = np.maximum(self.b_used[1:] - b_row[1:], 0.0)
        tr = current_tracer()
        if tr is not None:
            tr.gauge("pool_k_used", float(self.k_used[1:].sum()))

    def set_online(self, worker: int, online: bool) -> None:
        self.online[worker] = online


# ---------------------------------------------------------------------------
# Shared admission math
# ---------------------------------------------------------------------------

def scale_shares(pool: "SharePool", plan_k_row: np.ndarray,
                 plan_b_row: np.ndarray, online: np.ndarray, *,
                 allow_scaling: bool, floor: float,
                 fair_fn=None, spare_only: bool = False):
    """Mask one master's plan row to the online workers and scale it to
    what the pool (and the fairness cap) grants.

    This is *the* share-admission rule, used by both the streaming engine's
    dispatch and the serving bridge's step admission so the simulator and
    the real server cannot drift:

    * offline workers are masked out (column 0, the master's own
      processor, always stays);
    * ``spare_only`` additionally masks columns with no spare capacity
      (speculative twins race on leftovers while the original attempt
      keeps its own columns);
    * with ``allow_scaling``, the row is shrunk to the pool's feasible
      fraction, capped by ``fair_fn(k_req, b_req)`` when given, and
      rejected below ``floor``; without it, admission is all-or-nothing.

    Returns ``(k_row, b_row, f)`` with ``k_row[0] = b_row[0] = 1``, or
    ``None`` when the request does not fit.
    """
    k_req = np.where(online, plan_k_row, 0.0)
    b_req = np.where(online, plan_b_row, 0.0)
    k_req[0], b_req[0] = plan_k_row[0], plan_b_row[0]
    if spare_only:
        spare = (pool.available_k() > 1e-6) & (pool.available_b() > 1e-6)
        spare[0] = True
        k_req = np.where(spare, k_req, 0.0)
        b_req = np.where(spare, b_req, 0.0)
    f = pool.feasible_fraction(k_req, b_req)
    if allow_scaling:
        if fair_fn is not None:
            f = min(f, fair_fn(k_req, b_req))
        if f < floor:
            return None
        f = min(f, 1.0)
    elif f < 1.0 - 1e-9:
        return None
    else:
        f = 1.0
    k_row = f * k_req
    b_row = f * b_req
    k_row[0] = b_row[0] = 1.0            # the master's own processor
    return k_row, b_row, f


def fair_demand_rows(requester: int, plan_k: np.ndarray, online: np.ndarray,
                     waiting_masters: Set[int],
                     held_rows: Dict[int, np.ndarray]):
    """Assemble the (held, demands) inputs of ``fair_fraction``.

    ``held_rows`` maps each master to the sum of its currently-held k rows
    (in-flight tasks / running steps).  Masters that are merely *waiting*
    (queued work, no shares yet) demand their plan row on the online
    workers.  Shared by the streaming engine and the serving bridge so the
    fair-entitlement accounting cannot drift between them.

    Returns ``(held, demands)``: the requester's held row and the other
    claimants' demand rows."""
    width = plan_k.shape[1]
    held = held_rows.get(requester, np.zeros(width))
    others: Dict[int, np.ndarray] = {}
    for m2, row in held_rows.items():
        if m2 != requester:
            others[m2] = row.copy()
    for m2 in waiting_masters:
        if m2 == requester:
            continue
        row = np.where(online, plan_k[m2], 0.0)
        others[m2] = others.get(m2, np.zeros(width)) + row
    return held, list(others.values())


# ---------------------------------------------------------------------------
# Max-min fair water-filling
# ---------------------------------------------------------------------------

def maxmin_share(capacity: float, want: float,
                 others: Sequence[float]) -> float:
    """Max-min fair allocation to a claimant demanding ``want`` against
    ``others``' demands under a shared ``capacity`` (water-filling).

    Claimants below the fair line keep their full demand and release the
    rest; the remainder is split evenly among the still-unsatisfied.  The
    returned value is what the ``want`` claimant is entitled to."""
    demands = sorted(float(d) for d in others)
    cap = float(capacity)
    n = len(demands) + 1
    for d in demands:
        fair = cap / n
        if d <= fair + _ATOL:
            cap -= d
            n -= 1
        else:
            return min(want, cap / n)
    return min(want, cap)


# ---------------------------------------------------------------------------
# Pluggable admission policies
# ---------------------------------------------------------------------------

class AdmissionPolicy:
    """Ordering (and optional share-scaling) policy over waiting tasks.

    The engine ``offer``s each task with its master and deadline, asks for
    ``candidates()`` — task ids in the order admission should be attempted —
    and ``remove``s a task once admitted.  Two class flags shape the drain
    loop:

    * ``head_of_line``: a blocked candidate blocks everything behind it
      (strict global ordering).  ``False`` lets later candidates bypass a
      blocked one (per-master fairness).
    * ``reorders``: candidate order differs from arrival order, so a
      newcomer may outrank already-waiting tasks and the engine re-drains
      after enqueueing it.

    ``fair_fraction`` lets a policy cap a task's share scaling below what
    the pool has free; the default caps nothing.
    """

    name = "base"
    head_of_line = True
    reorders = False
    uses_fairness = False

    def __init__(self, max_queue: Optional[int] = None):
        self.max_queue = max_queue
        self.rejected = 0
        self._seq = itertools.count()
        # tid -> (master, deadline, seq)
        self._entries: Dict[int, Tuple[int, float, int]] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, tid: int) -> bool:
        return tid in self._entries

    def offer(self, tid: int, *, master: int = 0,
              deadline: float = math.inf, force: bool = False) -> bool:
        """Enqueue; False (rejected) when the backpressure bound is hit.
        ``force`` bypasses the bound (re-queued in-flight work is never
        silently dropped)."""
        if not force and self.max_queue is not None \
                and len(self._entries) >= self.max_queue:
            self.rejected += 1
            tr = current_tracer()
            if tr is not None:
                tr.count("admission_rejected")
            return False
        self._entries[tid] = (int(master), float(deadline), next(self._seq))
        return True

    def remove(self, tid: int) -> None:
        del self._entries[tid]

    def note_admitted(self, master: int) -> None:
        """Called by the engine on *every* successful admission — including
        direct ones that never queued — so fairness counters see the true
        per-master grant history, not just the contended subset."""

    def waiting_masters(self) -> Set[int]:
        return {m for (m, _, _) in self._entries.values()}

    def candidates(self) -> List[int]:  # pragma: no cover - interface
        raise NotImplementedError

    def head(self) -> Optional[int]:
        """First candidate only — the hot path for head-of-line policies
        (the drain loop never looks past a blocked head), kept cheaper
        than materialising the full ``candidates()`` order."""
        cands = self.candidates()
        return cands[0] if cands else None

    def fair_fraction(self, master: int, k_req: np.ndarray,
                      b_req: np.ndarray, *, held: np.ndarray,
                      demands: Sequence[np.ndarray]) -> float:
        return 1.0


class FIFOAdmission(AdmissionPolicy):
    """Arrival order, head-of-line blocking — the original engine policy."""

    name = "fifo"

    def candidates(self) -> List[int]:
        # dict preserves insertion order == seq order: no sort needed
        return list(self._entries)

    def head(self) -> Optional[int]:
        return next(iter(self._entries), None)


class WaitQueue(FIFOAdmission):
    """Back-compat FIFO facade (``peek``/``take``) over FIFOAdmission —
    one queue implementation, two APIs."""

    def peek(self) -> Optional[int]:
        return self.head()

    def take(self) -> int:
        tid = self.head()
        self.remove(tid)
        return tid


class EDFAdmission(AdmissionPolicy):
    """Earliest-deadline-first: candidates ordered by (deadline, arrival).

    Tasks without deadlines (inf) sort last, in arrival order — with no
    deadlines anywhere this degenerates to FIFO.  The head lookup is a
    lazy-deletion heap, so the per-admission cost stays O(log Q) instead
    of re-sorting the whole backlog."""

    name = "edf"
    reorders = True

    def __init__(self, max_queue: Optional[int] = None):
        super().__init__(max_queue)
        self._heap: List[Tuple[float, int, int]] = []   # (deadline, seq, tid)

    def offer(self, tid: int, *, master: int = 0,
              deadline: float = math.inf, force: bool = False) -> bool:
        if not super().offer(tid, master=master, deadline=deadline,
                             force=force):
            return False
        _, dl, seq = self._entries[tid]
        heapq.heappush(self._heap, (dl, seq, tid))
        return True

    def head(self) -> Optional[int]:
        while self._heap:
            _, seq, tid = self._heap[0]
            entry = self._entries.get(tid)
            if entry is not None and entry[2] == seq:
                return tid
            heapq.heappop(self._heap)            # stale (admitted/re-offered)
        return None

    def candidates(self) -> List[int]:
        return sorted(self._entries,
                      key=lambda t: (self._entries[t][1],
                                     self._entries[t][2]))


class FairShareAdmission(AdmissionPolicy):
    """Per-master FIFO queues, round-robin across masters, max-min shares.

    Candidate order interleaves the per-master queue heads, least-admitted
    master first, so a burst from one master cannot head-of-line block the
    others.  ``fair_fraction`` additionally caps the admitted share scaling
    at the water-filled max-min fair entitlement per contended worker
    column, still subject to the pool's column-sum ≤ 1 ledger."""

    name = "fair"
    head_of_line = False
    reorders = True
    uses_fairness = True

    def __init__(self, max_queue: Optional[int] = None):
        super().__init__(max_queue)
        self._admitted: Dict[int, int] = {}

    def note_admitted(self, master: int) -> None:
        self._admitted[master] = self._admitted.get(master, 0) + 1

    def candidates(self) -> List[int]:
        by_master: Dict[int, List[int]] = {}
        for tid, (m, _, seq) in self._entries.items():
            by_master.setdefault(m, []).append(tid)   # insertion == seq order
        masters = sorted(by_master,
                         key=lambda m: (self._admitted.get(m, 0), m))
        out: List[int] = []
        depth = 0
        while True:
            row = [by_master[m][depth] for m in masters
                   if depth < len(by_master[m])]
            if not row:
                return out
            out.extend(row)
            depth += 1

    def fair_fraction(self, master: int, k_req: np.ndarray,
                      b_req: np.ndarray, *, held: np.ndarray,
                      demands: Sequence[np.ndarray]) -> float:
        """Largest f with held + f·k_req within the max-min fair share of
        every contended worker column (column 0, the master's own
        processor, is never contended)."""
        if not demands:
            return 1.0
        f = 1.0
        for n in np.nonzero(k_req[1:] > _ATOL)[0] + 1:
            dem = [float(d[n]) for d in demands if d[n] > _ATOL]
            if not dem:
                continue
            cap = maxmin_share(1.0, float(held[n] + k_req[n]), dem)
            allowed = max(cap - float(held[n]), 0.0)
            f = min(f, allowed / float(k_req[n]))
        return max(f, 0.0)


_POLICIES = {
    "fifo": FIFOAdmission,
    "edf": EDFAdmission,
    "fair": FairShareAdmission,
}


def make_admission_policy(name: str,
                          max_queue: Optional[int] = None) -> AdmissionPolicy:
    """Build the named waiting-task policy ("fifo" | "edf" | "fair")."""
    try:
        cls = _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown admission policy {name!r}; "
                         f"known: {sorted(_POLICIES)}") from None
    return cls(max_queue)
