"""Per-worker share tracking and admission control.

The paper's constraints (6c)/(25c) bound the *column sums* of the
computing-power and bandwidth fractions: Σ_m k_{m,n} ≤ 1 and
Σ_m b_{m,n} ≤ 1 for every shared worker n.  A static plan satisfies them
across masters; a streaming system must additionally satisfy them across
*concurrent in-flight tasks*.  ``SharePool`` is that ledger: tasks acquire
(k, b) rows on admission and release them on completion, and the engine
queues (backpressure) whatever does not fit.

Admission supports proportional down-scaling (fractional policies only): if
a task wants shares k_req but only f·k_req fits, it can run with f·k_req —
its loads are re-derived from the Theorem-3 closed form at the scaled
shares, trading a longer predicted completion for no queueing delay.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Optional, Tuple

import numpy as np

__all__ = ["AdmissionConfig", "SharePool", "WaitQueue"]

_ATOL = 1e-9


@dataclasses.dataclass
class AdmissionConfig:
    """Admission / backpressure policy of the streaming engine.

    min_fraction: smallest acceptable down-scaling of the desired shares;
                  below it the task waits instead of running starved.
    allow_scaling: fractional policies may shrink shares; dedicated and
                  uncoded plans are all-or-nothing (whole workers).
    max_queue:    backpressure bound — arrivals beyond it are *rejected*
                  (counted, not simulated).  None = unbounded queue.
    """
    min_fraction: float = 0.25
    allow_scaling: bool = True
    max_queue: Optional[int] = None


class SharePool:
    """Ledger of in-flight (k, b) column sums over the N shared workers.

    Column 0 (the master's local processor) is never pooled: each master is
    always fully dedicated to itself (paper §II-A), so only columns 1..N are
    tracked.  Offline workers admit no new shares.
    """

    def __init__(self, N: int):
        self.N = int(N)
        self.k_used = np.zeros(N + 1)
        self.b_used = np.zeros(N + 1)
        self.online = np.ones(N + 1, dtype=bool)

    # -- capacity queries ---------------------------------------------------

    def available_k(self) -> np.ndarray:
        out = np.where(self.online, 1.0 - self.k_used, 0.0)
        out[0] = 1.0
        return np.maximum(out, 0.0)

    def available_b(self) -> np.ndarray:
        out = np.where(self.online, 1.0 - self.b_used, 0.0)
        out[0] = 1.0
        return np.maximum(out, 0.0)

    def feasible_fraction(self, k_req: np.ndarray, b_req: np.ndarray) -> float:
        """Largest f ∈ [0, 1] with f·k_req ≤ avail_k and f·b_req ≤ avail_b.

        Requests on offline workers force f = 0 (the caller should mask them
        out first if partial service is acceptable)."""
        need = (k_req[1:] > _ATOL) | (b_req[1:] > _ATOL)
        if not need.any():
            return 1.0
        ak, ab = self.available_k()[1:], self.available_b()[1:]
        with np.errstate(divide="ignore", invalid="ignore"):
            fk = np.where(k_req[1:] > _ATOL, ak / np.maximum(k_req[1:], _ATOL), np.inf)
            fb = np.where(b_req[1:] > _ATOL, ab / np.maximum(b_req[1:], _ATOL), np.inf)
        f = float(np.min(np.where(need, np.minimum(fk, fb), np.inf)))
        return float(np.clip(f, 0.0, 1.0))

    # -- mutation -----------------------------------------------------------

    def acquire(self, k_row: np.ndarray, b_row: np.ndarray) -> None:
        if np.any(self.k_used[1:] + k_row[1:] > 1.0 + 1e-6) or \
           np.any(self.b_used[1:] + b_row[1:] > 1.0 + 1e-6):
            raise ValueError("share acquisition violates column-sum <= 1")
        self.k_used[1:] += k_row[1:]
        self.b_used[1:] += b_row[1:]

    def release(self, k_row: np.ndarray, b_row: np.ndarray) -> None:
        self.k_used[1:] = np.maximum(self.k_used[1:] - k_row[1:], 0.0)
        self.b_used[1:] = np.maximum(self.b_used[1:] - b_row[1:], 0.0)

    def set_online(self, worker: int, online: bool) -> None:
        self.online[worker] = online


class WaitQueue:
    """FIFO backpressure queue of task ids awaiting admission."""

    def __init__(self, max_queue: Optional[int] = None):
        self.max_queue = max_queue
        self._q: Deque[int] = deque()
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    def offer(self, tid: int, *, force: bool = False) -> bool:
        """Enqueue; False (rejected) when the backpressure bound is hit.

        ``force`` bypasses the bound: backpressure is an *admission* policy,
        so a task that was already admitted and must re-queue (its in-flight
        deliveries were lost to churn) is never silently dropped."""
        if not force and self.max_queue is not None \
                and len(self._q) >= self.max_queue:
            self.rejected += 1
            return False
        self._q.append(tid)
        return True

    def peek(self) -> Optional[int]:
        return self._q[0] if self._q else None

    def take(self) -> int:
        return self._q.popleft()
