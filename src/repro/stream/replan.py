"""Online (re)planning for the streaming engine.

The static stack solves one batch: assignment (Alg. 1/2/4) → loads
(Thm. 1/2/3) → SCA enhancement (Alg. 3).  A streaming system must re-solve
as the pool drifts — workers leave, join, degrade — without paying the full
optimisation on every arrival.  ``OnlinePlanner`` wraps the static stack
with:

* **replan policies** — ``always`` (every arrival/churn event), ``periodic``
  (timer-driven), ``drift`` (re-solve when the per-master capacity vector
  V_m = Σ_n 1/θ_{m,n} moved more than a threshold), ``never``;
* **warm starting** — Algorithm 3 is seeded from the previous plan's loads
  (``sca_enhance_plan(warm_l=...)``), so a mildly-perturbed pool converges
  in a few SCA iterations instead of a cold solve;
* **a cheap closed-form fallback** — admission-time decisions (scaling a
  task's shares to what the pool has left) use the Theorem-1/3 closed form
  ``l* = t*/(2θ)`` directly; no iterative solve sits on the latency-critical
  path.

Pool changes are communicated as an ``online`` mask plus a per-worker
slowdown ``scale`` (1 = healthy); plans are always recomputed when the mask
changes (a plan placing load on a dead worker is never served).
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Optional, Tuple

import numpy as np

from ..core.assignment import fractional_greedy, iterated_greedy, plan_from_assignment
from ..core.allocation import markov_loads
from ..core.benchmarks import uncoded_uniform
from ..core.problem import Plan, Scenario, theta_dedicated
from ..core.sca import kkt_residual, sca_enhance_master, sca_enhance_plan
from ..obs import current_tracer

__all__ = ["ReplanMode", "ReplanPolicy", "OnlinePlanner",
           "theta_row_fractional", "scaled_row_loads"]


class ReplanMode(str, enum.Enum):
    """Replan trigger policy.

    A ``str`` enum: members compare equal to their literal values, so both
    ``ReplanPolicy(mode=ReplanMode.DRIFT)`` and the historical
    ``ReplanPolicy(mode="drift")`` construct the same policy.

    INCREMENTAL re-plans at ``ALWAYS`` frequency (every pool change) but
    first attempts an O(affected-rows) *repair* of the incumbent plan —
    see ``OnlinePlanner`` — falling back to the full solve on worker joins
    or when the repaired plan's KKT residual drifts past ``repair_tol``.
    """
    ALWAYS = "always"
    PERIODIC = "periodic"
    DRIFT = "drift"
    NEVER = "never"
    INCREMENTAL = "incremental"


@dataclasses.dataclass
class ReplanPolicy:
    """When and how hard to re-optimise.

    mode:            a ``ReplanMode`` (or its string value).
    period:          timer interval for "periodic" (sim time units).
    drift_threshold: relative capacity change triggering a re-solve in
                     "drift" mode (max_m |V_m/V_m_prev - 1|).
    use_sca:         run Algorithm 3 on each re-solve (warm-started).
    sca_iters:       SCA iteration budget per re-solve.
    repair_tol:      "incremental" fallback tolerance: a repaired plan is
                     kept while kkt_residual(repaired) - kkt_residual(last
                     full solve) <= repair_tol.  Set to -1.0 to force the
                     fallback on every repair attempt (testing hook).
    suspect_after:   online straggler signal — once a worker has been the
                     *critical* delivery (tracer attribution: its shard
                     closed the covering prefix) this many times, the
                     planner treats it as slowed by ``suspect_penalty``
                     when solving (load shifts off the binding worker)
                     and counts a ``suspect_replans``.  0 disables.
    suspect_penalty: pessimism factor applied to a suspected worker's
                     effective speed inside the solve (planning belief
                     only — the simulated delays are untouched).
    """
    mode: ReplanMode = ReplanMode.INCREMENTAL
    period: float = 50.0
    drift_threshold: float = 0.15
    use_sca: bool = False
    sca_iters: int = 6
    repair_tol: float = 0.25
    suspect_after: int = 0
    suspect_penalty: float = 1.5

    def __post_init__(self):
        try:
            self.mode = ReplanMode(self.mode)
        except ValueError:
            raise ValueError(f"unknown replan mode {self.mode!r}") from None


def theta_row_fractional(a_row, u_row, g_row, k_row, b_row) -> np.ndarray:
    """θ_{m,·} of eq. (24) for a single master row (admission fast path)."""
    th = np.full_like(np.asarray(a_row, dtype=np.float64), np.inf)
    th[0] = 1.0 / u_row[0] + a_row[0]
    kk, bb = k_row[1:], b_row[1:]
    act = (kk > 0) & (bb > 0)
    with np.errstate(divide="ignore"):
        val = (1.0 / np.where(act, bb * g_row[1:], 1.0)
               + 1.0 / np.where(act, kk * u_row[1:], 1.0)
               + a_row[1:] / np.where(act, kk, 1.0))
    th[1:] = np.where(act, val, np.inf)
    return th


def scaled_row_loads(sc: Scenario, m: int, k_row: np.ndarray,
                     b_row: np.ndarray) -> Tuple[np.ndarray, float]:
    """Theorem-1/3 closed-form loads for one master at given shares.

    This is the latency-critical fallback: O(N) closed form, no iteration.
    Returns (l_row, t_pred)."""
    th = theta_row_fractional(sc.a[m], sc.u[m], sc.gamma[m], k_row, b_row)
    l, t = markov_loads(sc.L[m:m + 1], th[None, :])
    return l[0], float(t[0])


class OnlinePlanner:
    """Maintains the active Plan for the current pool state.

    ``policy`` picks the static planning stack:
      "dedicated"  — Alg. 1 iterated greedy + Thm-1 loads,
      "fractional" — Alg. 4 fractional greedy + Thm-3 loads,
      "uncoded"    — uniform uncoded benchmark (needs-all rule).
    """

    def __init__(self, sc: Scenario, *, policy: str = "fractional",
                 replan: Optional[ReplanPolicy] = None,
                 rng: np.random.Generator | int = 0):
        if policy not in ("dedicated", "fractional", "uncoded"):
            raise ValueError(f"unknown planning policy {policy!r}")
        self.base = sc
        self.policy = policy
        self.replan = replan or ReplanPolicy()
        self._seed = rng if isinstance(rng, int) else 0
        self._plan: Optional[Plan] = None
        self._key: Optional[bytes] = None
        self._capacity_at_plan: Optional[np.ndarray] = None
        self._online_at_plan: Optional[np.ndarray] = None
        self._scale_at_plan: Optional[np.ndarray] = None
        self._kkt_at_plan: Optional[float] = None
        self.replans = 0            # plan replacements (full solves + repairs)
        self.full_solves = 0
        self.repairs = 0
        self.repair_fallbacks = 0   # repairs rejected by the KKT criterion
        self.solve_wall: list = []  # seconds per full solve (perf_counter)
        self.repair_wall: list = []  # seconds per accepted repair
        self._subscribers: list = []
        # online suspect/straggler signal (critical-worker attribution)
        self.crit_counts: dict = {}        # worker -> critical attributions
        self.suspect_replans = 0           # plan replacements it caused
        self._suspect_scale: Optional[np.ndarray] = None
        self._suspect_pending = False

    # -- invalidation hooks --------------------------------------------------

    def subscribe(self, fn) -> None:
        """Register a callback fired whenever the active plan is *replaced*.

        Listener contract (stable; ``StepPlanCache`` and any future consumer
        may rely on it):

        * fires exactly once per plan replacement — a full re-solve *or* an
          accepted incremental repair while a previous plan existed;
        * fires *after* ``self.plan`` already points at the new plan, so a
          listener may inspect the fresh rows;
        * the first solve of a planner's life does not fire (no prior plan,
          hence no derived state to drop);
        * ``notify_pool_change`` additionally fires all listeners even when
          no replacement happens (membership changed but the policy absorbed
          it) — listeners must treat every callback as "drop derived state",
          not "a solve happened";
        * callbacks run synchronously, in subscription order, inside
          ``ensure_plan`` / ``notify_pool_change``; they must not call back
          into the planner.
        """
        self._subscribers.append(fn)

    def notify_pool_change(self) -> None:
        """Explicitly fire the subscribers (pool membership changed in a
        way the next ``ensure_plan`` may absorb without re-solving, e.g. a
        drift below threshold)."""
        for fn in self._subscribers:
            fn()

    # -- online suspect signal (critical-worker attribution) -----------------

    def note_critical(self, worker: int) -> None:
        """Feed one critical-delivery attribution (the tracer's per-task /
        per-step ``critical_worker``): the shard that closed the covering
        prefix came from ``worker``.  A repeatedly-critical worker is the
        binding constraint of the paper's min-max objective; once it has
        been critical ``ReplanPolicy.suspect_after`` times, the next
        ``ensure_plan`` treats it as ``suspect_penalty``× slower — a pure
        planning belief that shifts load off it — and the resulting plan
        replacement is counted in ``suspect_replans``."""
        w = int(worker)
        after = int(self.replan.suspect_after)
        if w <= 0 or after <= 0:
            return
        self.crit_counts[w] = self.crit_counts.get(w, 0) + 1
        if self.crit_counts[w] != after:
            return                       # fires once per worker per run
        if self._suspect_scale is None:
            self._suspect_scale = np.ones(self.base.N + 1)
        self._suspect_scale[w] = self.replan.suspect_penalty
        self._suspect_pending = True

    # -- pool state → effective scenario ------------------------------------

    def effective_scenario(self, online: np.ndarray,
                           scale: np.ndarray) -> Scenario:
        """Degradation-adjusted Scenario over the full node axis.

        ``scale[n] = f`` slows worker n by f: shift a×f, rates u/f and γ/f.
        Offline workers keep their parameters (exclusion happens in the
        restricted solve, not by parameter surgery)."""
        s = np.asarray(scale, dtype=np.float64)[None, :]
        return Scenario(a=self.base.a * s, u=self.base.u / s,
                        gamma=self.base.gamma / s, L=self.base.L)

    def capacity(self, online: np.ndarray, scale: np.ndarray) -> np.ndarray:
        """V_m = Σ_{n online} 1/θ_{m,n}: the drift statistic (1/t* scale)."""
        sc_eff = self.effective_scenario(online, scale)
        part = np.broadcast_to(online[None, :], (sc_eff.M, sc_eff.N + 1))
        th = theta_dedicated(sc_eff, part.astype(float))
        inv = np.where(np.isfinite(th), 1.0 / th, 0.0)
        return inv.sum(axis=1)

    # -- plan lifecycle ------------------------------------------------------

    @property
    def plan(self) -> Plan:
        if self._plan is None:
            raise RuntimeError("no plan yet — call ensure_plan first")
        return self._plan

    @property
    def needs_all(self) -> bool:
        return self.policy == "uncoded"

    def ensure_plan(self, online: np.ndarray, scale: np.ndarray, *,
                    force: bool = False, event: bool = False) -> Plan:
        """Return the active plan, re-solving per the replan policy.

        force: timer fired (periodic mode) or caller demands a re-solve.
        event: an arrival/churn happened ("always" mode re-solves on these).
        """
        online = np.asarray(online, dtype=bool)
        scale = np.asarray(scale, dtype=np.float64)
        if self._suspect_scale is not None:
            # the suspect belief changes the key too, so crossing the
            # threshold naturally invalidates the short-circuit below
            scale = scale * self._suspect_scale
        key = online.tobytes() + scale.tobytes()
        if self._plan is not None and key == self._key:
            return self._plan
        mode = self.replan.mode
        # Incremental: any pool-state change replans ("always" frequency),
        # but via O(affected-rows) repair when possible.  Full solve on
        # force, first plan, or repair rejection (joins / KKT fallback).
        if (mode == ReplanMode.INCREMENTAL and not force
                and self._plan is not None):
            t0 = time.perf_counter()
            repaired = self._repair(online, scale)
            if repaired is not None:
                if repaired is not self._plan:
                    self._adopt(repaired, online, scale, key,
                                full_solve=False)
                    self.repair_wall.append(time.perf_counter() - t0)
                else:
                    # Nothing moved: keep the incumbent bit-identical, just
                    # refresh the key so the next call short-circuits.
                    self._key = key
                return self._plan
        mask_changed = (self._key is None
                        or self._key[:online.nbytes] != online.tobytes())
        solve = (force or self._plan is None or mask_changed
                 or mode == ReplanMode.INCREMENTAL)
        if not solve:
            if mode == ReplanMode.ALWAYS and event:
                solve = True
            elif mode == ReplanMode.DRIFT:
                V = self.capacity(online, scale)
                drift = np.max(np.abs(V / np.maximum(
                    self._capacity_at_plan, 1e-300) - 1.0))
                solve = drift > self.replan.drift_threshold
        if solve:
            t0 = time.perf_counter()
            tr = current_tracer()
            if tr is None:
                new_plan = self._solve(online, scale)
            else:
                # cat "replan" (not the "plan" stage cat): a re-solve can
                # fire *inside* a serving step's plan stage, and stage
                # categories must tile the step without double counting.
                with tr.span("replan_solve", cat="replan",
                             args={"policy": self.policy,
                                   "mode": str(self.replan.mode.value),
                                   "replans": self.replans}):
                    new_plan = self._solve(online, scale)
            self._adopt(new_plan, online, scale, key, full_solve=True)
            self.solve_wall.append(time.perf_counter() - t0)
        return self._plan

    def _adopt(self, plan: Plan, online: np.ndarray, scale: np.ndarray,
               key: bytes, *, full_solve: bool) -> None:
        """Install ``plan`` as the active plan and fire the listeners."""
        had_plan = self._plan is not None
        self._plan = plan
        self._key = key
        self._online_at_plan = online.copy()
        self._scale_at_plan = scale.copy()
        self._capacity_at_plan = self.capacity(online, scale)
        if full_solve:
            self.full_solves += 1
            if self.policy != "uncoded":
                sc_eff = self.effective_scenario(online, scale)
                self._kkt_at_plan = kkt_residual(
                    sc_eff, plan.k, plan.b, plan.l, plan.t_per_master)
        else:
            self.repairs += 1
        self.replans += 1
        if self._suspect_pending:
            self.suspect_replans += 1
            self._suspect_pending = False
        if had_plan:
            for fn in self._subscribers:
                fn()

    # -- incremental repair ---------------------------------------------------

    def _repair(self, online: np.ndarray,
                scale: np.ndarray) -> Optional[Plan]:
        """Repair the incumbent plan for a perturbed pool, or ``None``.

        Only workers whose θ changed are touched (paper's per-worker θ
        structure: a worker's parameters enter other masters' rows only
        through the shares it already donated — which a leave zeroes and a
        degrade keeps).  The repair:

        * rejects **joins** (a new worker must be assigned shares — that is
          the full Algorithm 1/4 problem, not a row update);
        * zeroes departed workers' share/load columns;
        * recomputes the Theorem-1/3 closed-form load row (optionally
          SCA-polished) for every master holding shares on a moved worker;
        * falls back (returns ``None``) when the repaired plan's
          ``kkt_residual`` exceeds the residual recorded at the last full
          solve by more than ``ReplanPolicy.repair_tol`` — anchoring to the
          full-solve baseline lets single cheap repairs through while
          ratcheting accumulated drift back to a real solve.

        Returns the incumbent itself (``is``-identical) when nothing moved.
        """
        if self.policy == "uncoded":
            return None     # uniform re-solve is already O(M·N)
        old_online, old_scale = self._online_at_plan, self._scale_at_plan
        if old_online is None or old_scale is None:
            return None
        if bool(np.any(online & ~old_online)):
            return None     # join: requires a fresh assignment
        if online[0] != old_online[0] or scale[0] != old_scale[0]:
            return None     # local processors never churn; be safe if they do
        moved = (online != old_online) | (scale != old_scale)
        moved[0] = False
        if not bool(np.any(moved)):
            return self._plan
        inc = self._plan
        k = inc.k.copy(); b = inc.b.copy(); l = inc.l.copy()
        t = inc.t_per_master.copy()
        affected = np.nonzero(
            ((inc.k[:, moved] > 0) | (inc.l[:, moved] > 0)).any(axis=1))[0]
        gone = moved & ~online
        k[:, gone] = 0.0; b[:, gone] = 0.0; l[:, gone] = 0.0
        sc_eff = self.effective_scenario(online, scale)
        for m in affected:
            l_row, t_m = scaled_row_loads(sc_eff, int(m), k[m], b[m])
            if self.replan.use_sca:
                l_row, t_m = sca_enhance_master(
                    sc_eff, int(m), k, b, l_row, t_m,
                    max_iters=self.replan.sca_iters)
            l[m] = l_row
            t[m] = t_m
        if affected.size and self._kkt_at_plan is not None:
            r_new = kkt_residual(sc_eff, k, b, l, t)
            if r_new - self._kkt_at_plan > self.replan.repair_tol:
                self.repair_fallbacks += 1
                return None
        method = inc.method
        if not method.endswith("+repair"):
            method = method + "+repair"
        return Plan(k=k, b=b, l=l, t_per_master=t, method=method)

    # -- the restricted static solve ----------------------------------------

    def _solve(self, online: np.ndarray, scale: np.ndarray) -> Plan:
        sc_eff = self.effective_scenario(online, scale)
        cols = np.concatenate([[0], np.nonzero(online[1:])[0] + 1])
        if cols.size == 1:
            return self._local_only_plan(sc_eff)
        # ascontiguousarray: fancy indexing on axis 1 yields Fortran-ordered
        # copies, and axis=-1 reductions walk F-ordered memory in a different
        # order than C rows — a 1-ulp divergence between the solver's loads
        # and the repair path's row recomputation (scaled_row_loads works on
        # C rows).  Forcing C order keeps repair ≡ re-solve bit-identical.
        sub = Scenario(a=np.ascontiguousarray(sc_eff.a[:, cols]),
                       u=np.ascontiguousarray(sc_eff.u[:, cols]),
                       gamma=np.ascontiguousarray(sc_eff.gamma[:, cols]),
                       L=sc_eff.L)
        if self.policy == "uncoded":
            sub_plan = uncoded_uniform(sub)
        elif self.policy == "dedicated":
            k = iterated_greedy(sub, rng=self._seed)
            sub_plan = plan_from_assignment(sub, k, method="stream-dedicated")
        else:
            k = iterated_greedy(sub, rng=self._seed)
            sub_plan = fractional_greedy(sub, init=k, rng=self._seed)
        if self.replan.use_sca and self.policy != "uncoded":
            warm = None
            if self._plan is not None:
                warm = self._plan.l[:, cols]
            sub_plan = sca_enhance_plan(sub, sub_plan,
                                        max_iters=self.replan.sca_iters,
                                        warm_l=warm)
        return self._expand(sub_plan, cols)

    def _local_only_plan(self, sc_eff: Scenario) -> Plan:
        """Every shared worker is offline: each master computes alone.

        A single node needs no redundancy — load exactly L_m locally.  The
        uncoded benchmark has no local-compute path, so it cannot serve
        (t = inf; arrivals queue until a worker rejoins)."""
        M, W = self.base.M, self.base.N + 1
        k = np.zeros((M, W))
        k[:, 0] = 1.0
        l = np.zeros((M, W))
        if self.policy == "uncoded":
            t = np.full(M, np.inf)
        else:
            l[:, 0] = sc_eff.L
            theta0 = 1.0 / sc_eff.u[:, 0] + sc_eff.a[:, 0]
            t = sc_eff.L * theta0
        return Plan(k=k, b=k.copy(), l=l, t_per_master=t,
                    method=f"stream-{self.policy}-local-only")

    def _expand(self, sub_plan: Plan, cols: np.ndarray) -> Plan:
        M, W = self.base.M, self.base.N + 1
        k = np.zeros((M, W)); b = np.zeros((M, W)); l = np.zeros((M, W))
        k[:, cols] = sub_plan.k
        b[:, cols] = sub_plan.b
        l[:, cols] = sub_plan.l
        return Plan(k=k, b=b, l=l, t_per_master=sub_plan.t_per_master.copy(),
                    method=sub_plan.method)
