"""Multi-backend batched numerics shared by the streaming engine,
``repro.sim.montecarlo`` and ``repro.runtime.coded_exec``.

The paper's delay pipeline — encode → per-worker partial products → prefix
completion → exactly-L decode — used to exist in two and a half
implementations (a per-master Python loop in the Monte-Carlo simulator, a
per-arrival loop in ``CodedExecutor``, and implicitly in the straggler
policies).  This module is the single implementation, with three backends:

* ``numpy`` — the authoritative reference.  Batched sort + cumsum over the
  node axis, stacked ``np.linalg.solve`` decode.  Bit-for-bit equal to the
  legacy per-master loops (asserted by tests).
* ``jax`` — jitted and device-resident.  ``completion_times`` /
  ``decode_batch`` run as cached jitted functions; ``simulate_batch`` is a
  full Monte-Carlo kernel (delay sampling + completion) that gathers each
  master's *active* worker columns, draws float32 exponentials with the
  fast ``rbg`` generator, and evaluates the completion rule sort-free in
  cache-sized ``lax.map`` chunks — nothing round-trips to the host until
  the final sample array.
* ``pallas`` — the encode / coded-product kernels from ``repro.kernels``
  (real lowering on TPU, ``interpret=True`` everywhere else), consumed by
  ``CodedExecutor`` and the streaming verification path; decode reuses the
  jitted jax solve.

Public entry points:

* ``completion_times`` — earliest time the cumulative received coded rows
  reach L, batched over any leading axes (realizations, masters, tasks).
  NaN and ±inf delays are "never arrives" instead of poisoning the prefix.
* ``sample_delays`` — turn pre-drawn Exp(1) variates into T = T_tr + T_cp
  delays, with optional heavy-tail ``straggle_p``/``straggle_factor``
  throttling (burstable-instance CPU-credit exhaustion).
* ``simulate_batch`` — (trials, M) Monte-Carlo completion delays for a full
  plan in one call; the jitted path behind ``simulate_plan(backend="jax")``.
* ``decode_batch`` — batched exactly-L MDS decode with a systematic-prefix
  fast path: when the generator's top L rows are the identity and a task
  received only those rows, the "solve" is a row permutation and is applied
  by a scatter (bit-identical to LAPACK on a permutation matrix, no O(L^3)
  factorization).
* ``ExponentialBlock`` — block-amortised standard-exponential (and
  optionally uniform) draws so the event loop consumes pre-sampled
  randomness (deterministic replay, no per-event RNG overhead).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import numpy as np

from ..obs import current_tracer, device_span

__all__ = [
    "has_jax",
    "completion_times",
    "delivered_by",
    "sample_delays",
    "simulate_batch",
    "simulate_chunks_np",
    "decode_batch",
    "plan_decode",
    "DecodePlan",
    "SystematicRows",
    "plan_decode_ls",
    "LSDecodePlan",
    "decode_ls_batch",
    "plan_verify",
    "VerifyPlan",
    "verify_decode",
    "localize_faulty_worker",
    "solve_stacked",
    "solve_jax",
    "StackedLU",
    "ExponentialBlock",
]

_EPS = 1e-12
BACKENDS = ("numpy", "jax", "pallas")


def check_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{BACKENDS}")
    return backend


@functools.lru_cache(maxsize=1)
def has_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:  # pragma: no cover - jax is baked into the image
        return False


def _use_jax(backend: str) -> bool:
    return backend in ("jax", "pallas") and has_jax()


@functools.lru_cache(maxsize=1)
def _rng_key_impl() -> Optional[str]:
    """Fastest counter-based PRNG available ("rbg" beats threefry ~2x on
    CPU and lowers to the hardware RNG on TPU); None → jax default."""
    import jax.random as jr
    try:
        jr.key(0, impl="rbg")
        return "rbg"
    except Exception:  # pragma: no cover - rbg exists on all supported jax
        return None


def _make_key(seed: int):
    import jax.random as jr
    impl = _rng_key_impl()
    return jr.key(seed, impl=impl) if impl else jr.key(seed)


# ---------------------------------------------------------------------------
# Completion times
# ---------------------------------------------------------------------------

def _completion_np(T: np.ndarray, loads: np.ndarray, need: np.ndarray,
                   needs_all: bool) -> np.ndarray:
    active = loads > 0
    # NaN (poisoned sample) and inf (dead worker) both mean "never arrives".
    Ti = np.where(active & np.isfinite(T), T, np.inf)
    if needs_all:
        out = np.where(active, Ti, -np.inf).max(axis=-1)
        out = np.where(active.any(axis=-1), out, np.inf)
        return np.where(np.isfinite(out), out, np.inf)
    order = np.argsort(Ti, axis=-1, kind="stable")
    T_s = np.take_along_axis(Ti, order, axis=-1)
    l_s = np.take_along_axis(np.where(active, loads, 0.0), order, axis=-1)
    cum = np.cumsum(l_s, axis=-1)
    hit = cum >= need[..., None] - 1e-9
    first = np.argmax(hit, axis=-1)
    reachable = np.take_along_axis(hit, first[..., None], axis=-1)[..., 0]
    out = np.take_along_axis(T_s, first[..., None], axis=-1)[..., 0]
    return np.where(reachable & np.isfinite(out), out, np.inf)


@functools.lru_cache(maxsize=None)
def _completion_jit(needs_all: bool):
    """Cached jitted batched completion kernel (device arrays in and out)."""
    import jax
    import jax.numpy as jnp

    def core(T, loads, need):
        active = loads > 0
        Ti = jnp.where(active & jnp.isfinite(T), T, jnp.inf)
        if needs_all:
            out = jnp.where(active, Ti, -jnp.inf).max(axis=-1)
            out = jnp.where(active.any(axis=-1), out, jnp.inf)
            return jnp.where(jnp.isfinite(out), out, jnp.inf)
        T_s, l_s = jax.lax.sort(
            [Ti, jnp.where(active, loads, 0.0)], num_keys=1, is_stable=True)
        cum = jnp.cumsum(l_s, axis=-1)
        hit = cum >= need[..., None] - 1e-9
        first = jnp.argmax(hit, axis=-1)
        ok = jnp.take_along_axis(hit, first[..., None], axis=-1)[..., 0]
        out = jnp.take_along_axis(T_s, first[..., None], axis=-1)[..., 0]
        return jnp.where(ok & jnp.isfinite(out), out, jnp.inf)

    return jax.jit(core)


def completion_times(T, loads, need, *, needs_all: bool = False,
                     backend: str = "numpy") -> np.ndarray:
    """Earliest t per batch row with Σ_{n: T_n <= t} l_n >= need.

    T:     (..., K) arrival times (absolute or relative — any monotone scale).
    loads: broadcastable to T; zero-load nodes are ignored.
    need:  broadcastable to T's leading axes.
    needs_all: the uncoded rule — wait for *every* positive-load node.

    Non-finite delays (inf dead workers, NaN poisoned samples) never arrive:
    they are skipped by the prefix, and the result is inf only if the
    remaining live nodes cannot cover ``need``.

    The jax backend runs one cached jitted kernel over the whole batch; the
    host boundary is a single transfer each way.
    """
    check_backend(backend)
    T = np.asarray(T, dtype=np.float64)
    loads = np.broadcast_to(np.asarray(loads, dtype=np.float64), T.shape)
    need = np.broadcast_to(np.asarray(need, dtype=np.float64), T.shape[:-1])
    if _use_jax(backend):
        return np.asarray(_completion_jit(bool(needs_all))(T, loads, need))
    return _completion_np(T, loads, need, needs_all)


def delivered_by(T, loads, t) -> np.ndarray:
    """Rows delivered by time ``t``: Σ_{n: T_n <= t} l_n (batched)."""
    T = np.asarray(T, dtype=np.float64)
    loads = np.broadcast_to(np.asarray(loads, dtype=np.float64), T.shape)
    t = np.asarray(t, dtype=np.float64)
    arrived = np.isfinite(T) & (T <= t[..., None]) & (loads > 0)
    return np.where(arrived, loads, 0.0).sum(axis=-1)


# ---------------------------------------------------------------------------
# Delay sampling
# ---------------------------------------------------------------------------

def sample_delays(e_tr: np.ndarray, e_cp: np.ndarray, l, k, b, a, u, gamma,
                  *, local_col0: bool = True,
                  straggle_p: float = 0.0, straggle_factor: float = 8.0,
                  straggle_u: Optional[np.ndarray] = None) -> np.ndarray:
    """Turn standard-exponential draws into T = T_tr + T_cp delays.

    ``e_tr``/``e_cp`` are ~Exp(1) draws of the same (batched) shape as ``l``;
    the transformation matches ``repro.core.delays.sample_total`` exactly, so
    an ``ExponentialBlock`` + ``sample_delays`` pipeline is distributionally
    identical to the legacy per-call sampler while being batchable and
    replayable.

    ``straggle_p`` / ``straggle_factor``: per-node probability that the node
    is in a degraded state for this task, multiplying its whole delay by
    ``factor`` — the heavy-tailed *measured* behaviour of burstable cloud
    instances (CPU-credit throttling) that the fitted shifted exponential
    underestimates.  ``straggle_u`` supplies the uniform draws (same shape
    as ``l``; see ``ExponentialBlock(uniform_rows=1)``) so replay stays
    deterministic.
    """
    l = np.asarray(l, dtype=np.float64)
    lsafe = np.maximum(l, _EPS)
    ksafe = np.maximum(k, _EPS)
    bsafe = np.maximum(b, _EPS)
    t_tr = e_tr * lsafe / (bsafe * gamma)
    if local_col0:
        t_tr = t_tr.copy()
        t_tr[..., 0] = 0.0
    t_cp = a * l / ksafe + e_cp * lsafe / (ksafe * u)
    total = t_tr + t_cp
    if straggle_p > 0.0:
        if straggle_u is None:
            raise ValueError("straggle_p > 0 requires straggle_u draws "
                             "(use ExponentialBlock(uniform_rows=1))")
        total = np.where(np.asarray(straggle_u) < straggle_p,
                         total * straggle_factor, total)
    return np.where(l > 0, total, 0.0)


class ExponentialBlock:
    """Pre-sampled Exp(1) (+ optional Uniform(0,1)) draws consumed row-by-row.

    The event loop needs one (2, N+1) standard-exponential row per admitted
    task (plus one uniform row when heavy-tail throttling is on); drawing
    them one event at a time costs a Generator call per event.  This draws
    ``block`` tasks' worth at once and hands out views — deterministic
    replay at block-amortised cost.
    """

    def __init__(self, rng: np.random.Generator, width: int,
                 block: int = 512, uniform_rows: int = 0):
        self.rng = rng
        self.width = int(width)
        self.block = int(block)
        self.uniform_rows = int(uniform_rows)
        self.rows = 2 + self.uniform_rows
        self._buf = np.empty((0, self.rows, self.width))
        self._pos = 0

    def _refill(self) -> None:
        exp = self.rng.exponential(
            1.0, size=(self.block, 2, self.width))
        if self.uniform_rows:
            uni = self.rng.random(
                size=(self.block, self.uniform_rows, self.width))
            self._buf = np.concatenate([exp, uni], axis=1)
        else:
            self._buf = exp
        self._pos = 0

    def draw(self) -> np.ndarray:
        if self._pos >= self._buf.shape[0]:
            self._refill()
        row = self._buf[self._pos]
        self._pos += 1
        return row

    def draw_n(self, n: int) -> np.ndarray:
        """``n`` consecutive draws as one (n, rows, width) view — the
        multi-task serving dispatch consumes one row per coded matmul and
        samples all of a step barrier's delays in a single batched
        :func:`sample_delays` call.  The stream is identical to ``n``
        successive :meth:`draw` calls."""
        if n <= 0:
            raise ValueError("draw_n needs n >= 1")
        if self._pos + n <= self._buf.shape[0]:
            out = self._buf[self._pos:self._pos + n]
            self._pos += n
            return out
        # keep the stream identical to n draw() calls: consume the tail,
        # then refill block-by-block for the remainder (n may exceed one
        # block — e.g. a deep trunk's 1 + 7·n_layers tasks per dispatch)
        parts = [self._buf[self._pos:]]
        need = n - parts[0].shape[0]
        while need > 0:
            self._refill()
            take = min(need, self._buf.shape[0])
            parts.append(self._buf[:take])
            self._pos = take
            need -= take
        return np.concatenate([p for p in parts if p.size])


# ---------------------------------------------------------------------------
# Jitted Monte-Carlo (sample + complete, device-resident)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _simulate_jit(needs_all: bool, straggle: bool, n_nodes: int):
    """Cached jitted Monte-Carlo kernel over active-node arrays.

    Works on per-master *gathered* parameter rows (M, A) where A is the
    max active-node count — a 3-4x cut in RNG and completion work versus
    the dense (M, N+1) layout when workers are partitioned across masters.

    The completion rule is evaluated sort-free: for each candidate arrival
    i, S_i = Σ_n l_n·[T_n <= T_i]; the completion is min{T_i : S_i >= L}.
    XLA's CPU sort is ~5x slower than this O(A²) unrolled reduction at the
    A ≤ 64 widths that occur in practice, and the ``lax.map`` chunking
    keeps every temporary cache-resident, so the whole kernel runs at
    memory speed of the (trials, M) output.
    """
    import jax
    import jax.numpy as jnp
    import jax.random as jr

    def run(key, c_tr, shift, c_cp, loads, need, p, factor, nch, chunk):
        dt = c_tr.dtype
        # need-1e-9 matches numpy; the relative term absorbs float32 cumsum
        # rounding when coverage is exact (never larger than a fraction of
        # one coded row at L ~ 1e4).
        rel = 1e-6 if dt == jnp.float32 else 0.0
        thresh = need[None, :] - 1e-9 - rel * need[None, :]
        keys = jr.split(key, (nch, 2))

        def one(kk):
            e = jr.exponential(kk[0], (2, chunk) + c_tr.shape, dt)
            T = c_tr * e[0] + shift + c_cp * e[1]      # padded nodes: +inf
            if straggle:
                u01 = jr.uniform(kk[1], (chunk,) + c_tr.shape, dt)
                T = jnp.where(u01 < p, T * factor, T)
            if needs_all:
                act = loads > 0
                out = jnp.where(act, T, -jnp.inf).max(axis=-1)
                out = jnp.where(act.any(axis=-1), out, jnp.inf)
                return jnp.where(jnp.isfinite(out), out, jnp.inf)
            comp = jnp.full(T.shape[:-1], jnp.inf, dt)
            for i in range(n_nodes):
                Ti = T[..., i]
                S = jnp.where(T <= Ti[..., None], loads, 0.0).sum(axis=-1)
                comp = jnp.minimum(
                    comp, jnp.where(S >= thresh, Ti, jnp.inf))
            return comp

        return jax.lax.map(one, keys).reshape(nch * chunk, -1)

    return jax.jit(run, static_argnames=("nch", "chunk"))


def _gather_active(l, k, b, a, u, gamma, dtype):
    """Per-master active-column gather → (idx, loads, c_tr, shift, c_cp).

    Returns (M, A) coefficient arrays with T = c_tr·e1 + shift + c_cp·e2;
    padded slots have shift = +inf (never arrive) and zero load.  Column 0
    (the master's local processor) gets c_tr = 0 — no communication.
    """
    M = l.shape[0]
    counts = (l > 0).sum(axis=1)
    A = max(int(counts.max()), 1)
    idx = np.zeros((M, A), dtype=np.int64)
    pad = np.ones((M, A), dtype=bool)
    for m in range(M):
        nz = np.nonzero(l[m] > 0)[0]
        idx[m, :nz.size] = nz
        pad[m, nz.size:] = False
    act = pad          # True where a real node sits
    ga = lambda arr: np.take_along_axis(np.asarray(arr, np.float64), idx, 1)
    l_a = np.where(act, ga(l), 0.0)
    k_a, b_a = ga(k), ga(b)
    a_a, u_a, g_a = ga(a), ga(u), ga(gamma)
    c_tr = np.where(act, l_a / np.maximum(b_a * g_a, _EPS), 0.0)
    c_tr[idx == 0] = 0.0                       # local node: no comm delay
    shift = np.where(act, a_a * l_a / np.maximum(k_a, _EPS), np.inf)
    c_cp = np.where(act, l_a / np.maximum(k_a * u_a, _EPS), 0.0)
    return (idx, l_a.astype(dtype), c_tr.astype(dtype),
            shift.astype(dtype), c_cp.astype(dtype))


def simulate_chunks_np(rng: np.random.Generator, l, k, b, a, u, gamma, L,
                       trials: int, *, needs_all: bool = False,
                       straggle_p: float = 0.0, straggle_factor: float = 8.0,
                       chunk: int = 20_000):
    """Yield (r, M) completion-delay chunks from the Generator-based
    sampler — the single numpy Monte-Carlo loop behind both
    ``simulate_batch(backend="numpy")`` and ``sim.montecarlo``'s
    streaming aggregation (bit-stable for a given Generator + chunk)."""
    from ..core.delays import sample_total
    l = np.asarray(l, dtype=np.float64)
    L = np.atleast_1d(np.asarray(L, dtype=np.float64))
    chunk = max(int(chunk), 1)
    done = 0
    while done < trials:
        r = min(chunk, trials - done)
        T = sample_total(rng, (r,), l, k, b, a, u, gamma, local_col0=True)
        if straggle_p > 0:
            throttled = rng.random(T.shape) < straggle_p
            T = np.where(throttled, T * straggle_factor, T)
        yield completion_times(T, l[None], L[None], needs_all=needs_all)
        done += r


def simulate_batch(l, k, b, a, u, gamma, L, trials: int, *,
                   seed: "int | np.random.Generator" = 0,
                   needs_all: bool = False,
                   straggle_p: float = 0.0, straggle_factor: float = 8.0,
                   backend: str = "jax", dtype=np.float32,
                   chunk: int = 4096) -> np.ndarray:
    """(trials, M) Monte-Carlo completion delays for a full plan, one call.

    All inputs are the dense (M, N+1) plan/scenario arrays (column 0 = the
    master's local processor, communication-free).  The jax path is the
    jitted device-resident kernel described in :func:`_simulate_jit`;
    float32 by default — delay-model rounding is orders of magnitude below
    Monte-Carlo noise at any trial count this path exists for.  Seeding is
    by integer ``seed`` (counter-based key), so results are reproducible
    but *not* bit-equal to the numpy Generator stream — the two backends
    agree statistically, which is what the tests assert.

    The numpy fallback runs :func:`simulate_chunks_np` (a Generator is
    also accepted as ``seed`` there, for bit-stable shared streams).
    """
    check_backend(backend)
    l = np.asarray(l, dtype=np.float64)
    trials = int(trials)
    if backend == "numpy" or not has_jax():
        rng = (seed if isinstance(seed, np.random.Generator)
               else np.random.default_rng(seed))
        return np.concatenate(list(simulate_chunks_np(
            rng, l, k, b, a, u, gamma, L, trials, needs_all=needs_all,
            straggle_p=straggle_p, straggle_factor=straggle_factor,
            chunk=chunk)))
    if isinstance(seed, np.random.Generator):
        seed = int(seed.integers(np.iinfo(np.int64).max))
    L = np.atleast_1d(np.asarray(L, dtype=np.float64))

    import jax.numpy as jnp
    dtype = jnp.dtype(dtype)
    _, l_a, c_tr, shift, c_cp = _gather_active(l, k, b, a, u, gamma, dtype)
    chunk = max(min(int(chunk), trials), 1)
    nch = math.ceil(trials / chunk)
    fn = _simulate_jit(bool(needs_all), straggle_p > 0.0, l_a.shape[1])
    # device_span fences with block_until_ready only while a tracer records,
    # so the async dispatch pipeline is untouched when tracing is off
    with device_span("simulate_batch", cat="kernel",
                     args={"trials": trials, "M": int(l.shape[0]),
                           "chunks": nch}) as fence:
        comp = fence(fn(_make_key(int(seed)), jnp.asarray(c_tr),
                        jnp.asarray(shift), jnp.asarray(c_cp),
                        jnp.asarray(l_a), jnp.asarray(L.astype(dtype)),
                        dtype.type(straggle_p), dtype.type(straggle_factor),
                        nch, chunk))
    return np.asarray(comp[:trials], dtype=np.float64)


# ---------------------------------------------------------------------------
# Batched MDS decode
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _solve_jit():
    import jax
    import jax.numpy as jnp
    return jax.jit(lambda Gs, y: jnp.linalg.solve(Gs, y))


@functools.lru_cache(maxsize=1)
def _solve_jit_x64():
    """Jitted float64 stacked solve, or None when x64 jit is unavailable.

    Probed once: under ``jax.experimental.enable_x64`` the jit traces
    float64 avals, so the decode solve keeps full precision on the jax
    path instead of silently truncating to float32.  Builds where the
    context manager is missing or the output still canonicalises to f32
    fall back to the f32 jit (the historical behaviour).
    """
    import jax
    import jax.numpy as jnp
    try:
        fn = jax.jit(lambda Gs, y: jnp.linalg.solve(Gs, y))
        with jax.experimental.enable_x64():
            out = fn(jnp.eye(2, dtype=jnp.float64)[None],
                     jnp.ones((1, 2, 1), jnp.float64))
            if out.dtype != jnp.float64:
                return None
        return fn
    except Exception:  # pragma: no cover - older jax without enable_x64
        return None


def solve_jax(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Stacked solve on the jitted jax path, float64 when the build allows.

    The call must re-enter ``enable_x64`` every time: jit avals
    canonicalise by the flag's state at trace *and* call time.
    """
    fn = _solve_jit_x64()
    if fn is None:
        return np.asarray(_solve_jit()(A, b))
    import jax
    with jax.experimental.enable_x64():
        return np.asarray(fn(A, b))


try:                                   # the gufunc behind np.linalg.solve
    from numpy.linalg import _umath_linalg as _gu
    _gu.solve(np.eye(2)[None], np.ones((1, 2, 1)), signature="dd->d")
except Exception:  # pragma: no cover - exotic numpy builds
    _gu = None


try:
    from scipy.linalg import lu_factor as _lu_factor, lu_solve as _lu_solve
    from scipy.linalg.lapack import dgetrs as _dgetrs
except Exception:  # pragma: no cover - no-scipy builds
    _lu_factor = _lu_solve = _dgetrs = None


class StackedLU:
    """Lazily cached LU factorization of stacked (g, n, n) systems.

    ``np.linalg.solve`` (LAPACK ``gesv``) re-factorizes on every call.  A
    *frozen* decode plan solves the same parity sub-blocks for every step
    of a serve with only the right-hand side changing, so the ``getrf``
    is paid once and each step replays the O(n²) ``getrs``.  Solutions
    are bit-identical to :func:`solve_stacked` — ``gesv`` *is*
    ``getrf`` + ``getrs`` — and both decode engines route through this,
    so they cannot drift from each other.  Falls back to the one-shot
    solve when scipy is unavailable.
    """

    __slots__ = ("A", "_fac", "_checked")

    def __init__(self, A: np.ndarray):
        self.A = A
        self._fac = None
        self._checked = False

    def solve(self, b: np.ndarray) -> np.ndarray:
        if _lu_factor is None:
            return solve_stacked(self.A, b)
        if self._fac is None:
            self._fac = [_lu_factor(a, check_finite=False) for a in self.A]
        # raw getrs: same triangular sweeps as lu_solve minus its per-call
        # argument validation (thousands of tiny serving solves per run)
        if len(self._fac) == 1:
            lu, piv = self._fac[0]
            out = _dgetrs(lu, piv, b[0])[0][None]
        else:
            out = np.empty(self.A.shape[:1] + b.shape[1:])
            for i, (lu, piv) in enumerate(self._fac):
                out[i] = _dgetrs(lu, piv, b[i])[0]
        # singularity is a property of the frozen matrices, not the RHS —
        # one finiteness pass on the first solve is enough
        if not self._checked:
            if not np.isfinite(out).all():
                raise np.linalg.LinAlgError("Singular matrix")
            self._checked = True
        return out


def solve_stacked(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    """``np.linalg.solve(A, b)`` for stacked (g, n, n) · (g, n, C) systems,
    minus the per-call wrapper overhead.

    The serving decode issues thousands of tiny (n ≲ 50) solves per run;
    ``np.linalg.solve``'s Python wrapper (shape juggling, errstate, extobj
    plumbing) costs more than LAPACK ``gesv`` itself at those sizes.  This
    calls the same gufunc directly — results are bit-identical — and falls
    back to the public API when the private entry point is unavailable.
    Singular inputs still raise ``LinAlgError`` (the gufunc emits
    non-finite rows; the finiteness check costs one cheap pass, and a
    silent NaN would otherwise reach ``argmax`` as token 0 in the
    verify-off serving configuration).
    """
    if _gu is not None and A.dtype == np.float64 and b.dtype == np.float64:
        with np.errstate(invalid="ignore", over="ignore", divide="ignore"):
            out = _gu.solve(A, b, signature="dd->d")
        if not np.isfinite(out).all():
            raise np.linalg.LinAlgError("Singular matrix")
        return out
    return np.linalg.solve(A, b)


class SystematicRows:
    """Lazy row-view of a systematic generator ``[I; R]`` — no dense G.

    Virtual parity storage keeps no materialised generator; what decode
    planning actually consumes is *rows* of G (the mixed groups' square
    minors, the full-solve gathers).  This adapter satisfies exactly that:
    ``take(rows)`` synthesises identity rows for indices < L and asks
    ``parity_rows_fn(ids)`` (e.g. :meth:`CodedLinear.parity_rows`, the
    counter derivation) for the rest.  ``plan_decode`` accepts it wherever
    a shared 2-D generator is accepted; the identity prefix holds by
    construction.
    """

    __slots__ = ("L", "total", "parity_rows_fn")
    ndim = 2

    def __init__(self, L: int, total: int, parity_rows_fn):
        self.L = int(L)
        self.total = int(total)
        self.parity_rows_fn = parity_rows_fn

    @property
    def shape(self):
        return (self.total, self.L)

    def take(self, rows: np.ndarray) -> np.ndarray:
        """Gather G[rows] (float64) for any integer index array — the
        result has shape ``rows.shape + (L,)``."""
        rows = np.asarray(rows)
        flat = rows.ravel()
        out = np.zeros((flat.size, self.L))
        sys_m = flat < self.L
        out[np.nonzero(sys_m)[0], flat[sys_m]] = 1.0
        if (~sys_m).any():
            out[~sys_m] = np.asarray(
                self.parity_rows_fn(flat[~sys_m] - self.L), dtype=np.float64)
        return out.reshape(rows.shape + (self.L,))

    def __getitem__(self, rows):
        return self.take(rows)


def _identity_prefix(G: np.ndarray) -> bool:
    """True iff the generator's (shared) top L rows are exactly I_L."""
    L = G.shape[-1]
    if G.shape[-2] < L:
        return False
    top = G[..., :L, :]
    eye = np.eye(L, dtype=G.dtype)
    return bool((top == eye).all())


def _gather_generator_rows(G, glist: bool, idx: np.ndarray,
                           rows: np.ndarray) -> np.ndarray:
    """Stack G[rows[i]] for the selected task indices → (len(idx), R, L)."""
    if glist:
        return np.stack([np.asarray(G[i], dtype=np.float64)[rows[j]]
                         for j, i in enumerate(idx)])
    if G.ndim == 2:
        return G[rows]
    return G[idx[:, None], rows]


class _MixedGroup:
    """One mixed-row substitution group of a :class:`DecodePlan`: every
    task that received exactly ``s`` systematic rows (0 < s < L)."""

    __slots__ = ("grp", "sys_rows", "unk", "lu", "Gk", "sys_pos", "par_pos")

    def __init__(self, grp, sys_rows, unk, A, Gk, sys_pos, par_pos):
        self.grp = grp                # (g,) task indices in the batch
        self.sys_rows = sys_rows      # (g, s) pinned coordinate ids
        self.unk = unk                # (g, L-s) coordinates to solve for
        self.lu = StackedLU(A)        # (g, L-s, L-s) parity sub-blocks
        self.Gk = Gk                  # (g, L-s, s) known-coordinate columns
        self.sys_pos = sys_pos        # (g, s) receive positions of sys rows
        self.par_pos = par_pos        # (g, L-s) receive positions of parity

    @property
    def A(self) -> np.ndarray:
        return self.lu.A


class DecodePlan:
    """The X-independent structure of one stacked exactly-L decode.

    Everything :func:`decode_batch` derives from ``(G, rows)`` alone — the
    systematic/mixed/full partition of the batch, the per-``s`` substitution
    groups, the gathered generator sub-blocks — is computed once here, so a
    caller that decodes many right-hand sides against the *same* received
    rows (the serving bridge's step barrier: one delivery prefix, one
    decode problem per coded matmul, re-applied for every token of a
    multi-token dispatch) pays the planning overhead once.  ``apply(y)``
    runs the solves; ``decode_batch(G, rows, y)`` is literally
    ``plan_decode(G, rows).apply(y)``, so the two can never drift.
    """

    __slots__ = ("B", "L", "fast_idx", "fast_rows", "full_idx", "full_G",
                 "full_lu", "mixed_groups")

    def __init__(self, B: int, L: int, fast_idx, fast_rows, full_idx,
                 full_G, mixed_groups):
        self.B = B
        self.L = L
        self.fast_idx = fast_idx          # (f,) tasks decoded by scatter
        self.fast_rows = fast_rows        # (f, L) their received row ids
        self.full_idx = full_idx          # (n,) tasks needing the full solve
        self.full_G = full_G              # (n, L, L) gathered generators
        self.full_lu = StackedLU(full_G)  # factor cached across applies
        # list of (grp_idx, sys_rows, unk, A, Gk) per distinct s count
        self.mixed_groups = mixed_groups

    def apply(self, y: np.ndarray, *, backend: str = "numpy") -> np.ndarray:
        """Solve the planned systems for one stacked right-hand side
        ``y`` (B, L) or (B, L, C)."""
        check_backend(backend)
        tr = current_tracer()
        t0 = tr.now() if tr is not None else 0.0
        y = np.asarray(y, dtype=np.float64)
        squeeze = y.ndim == 2
        if squeeze:
            y = y[..., None]
        out = np.empty((self.B, self.L, y.shape[-1]))

        use_jax = _use_jax(backend)

        def solve(lu: StackedLU, b: np.ndarray) -> np.ndarray:
            # jax path: the jitted batched solve; numpy path: the cached
            # getrf + per-step getrs (bit-identical to gesv)
            if use_jax:
                return solve_jax(lu.A, b)
            return lu.solve(b)

        if self.fast_idx.size:
            # permutation decode: out[b, rows[b, i]] = y[b, i]
            out[self.fast_idx[:, None], self.fast_rows] = y[self.fast_idx]
        if self.full_idx.size:
            out[self.full_idx] = solve(self.full_lu, y[self.full_idx])
        for mg in self.mixed_groups:
            # receive-order partitions were frozen at plan time as position
            # index arrays; partition y the same row-major way
            yg = y[mg.grp]
            sys_y = np.take_along_axis(yg, mg.sys_pos[:, :, None], axis=1)
            par_y = np.take_along_axis(yg, mg.par_pos[:, :, None], axis=1)
            sol = solve(mg.lu, par_y - mg.Gk @ sys_y)
            out[mg.grp[:, None], mg.sys_rows] = sys_y        # exact pins
            out[mg.grp[:, None], mg.unk] = sol
        if tr is not None:
            tr.add_span("decode_apply", t0, tr.now(), cat="decode",
                        track="wall",
                        args={"tasks": self.B, "backend": backend,
                              "scatter": int(self.fast_idx.size),
                              "solved": int(self.full_idx.size),
                              "mixed": sum(int(mg.grp.size)
                                           for mg in self.mixed_groups)})
        return out[..., 0] if squeeze else out


def plan_decode(G, rows: np.ndarray, *, systematic: str = "auto",
                identity_prefix: Optional[bool] = None) -> DecodePlan:
    """Build the :class:`DecodePlan` for stacked received rows.

    ``identity_prefix`` short-circuits the O(L²) top-rows-are-identity
    check when the caller constructed G as a systematic [I; R] generator
    (``CodedLinear`` always does) — pass ``True``/``False`` to assert the
    structure, ``None`` (default) to detect it.
    """
    if systematic not in ("auto", "prefix", "never"):
        raise ValueError(f"systematic must be 'auto', 'prefix' or 'never', "
                         f"got {systematic!r}")
    tr = current_tracer()
    t0 = tr.now() if tr is not None else 0.0
    rows = np.asarray(rows)
    glist = isinstance(G, (list, tuple))
    if not glist and not isinstance(G, SystematicRows):
        G = np.asarray(G, dtype=np.float64)
    B, L = rows.shape

    sys_ok = False
    if systematic != "never" and B:
        if identity_prefix is not None:
            sys_ok = bool(identity_prefix)
        elif isinstance(G, SystematicRows):
            sys_ok = True            # systematic by construction
        else:
            sys_ok = (all(_identity_prefix(np.asarray(g)) for g in G)
                      if glist else _identity_prefix(G))
    sys_counts = (rows < L).sum(axis=1) if sys_ok else np.zeros(B, dtype=int)
    fast = sys_counts == L
    fast_idx = np.nonzero(fast)[0]

    if systematic == "auto" and sys_ok:
        full_idx = np.nonzero(sys_counts == 0)[0]
    else:
        full_idx = np.nonzero(~fast)[0]
    full_G = (np.empty((0, L, L)) if not full_idx.size else
              _gather_generator_rows(G, glist, full_idx, rows[full_idx]))

    mixed_groups = []
    if systematic == "auto" and sys_ok:
        mixed = (sys_counts > 0) & (sys_counts < L)
        for s in np.unique(sys_counts[mixed]):
            grp = np.nonzero(sys_counts == s)[0]
            g = grp.size
            m_sys = rows[grp] < L                            # (g, L)
            # boolean indexing is row-major, so per-task receive order is
            # preserved inside both partitions
            sys_pos = np.nonzero(m_sys)[1].reshape(g, s)
            par_pos = np.nonzero(~m_sys)[1].reshape(g, L - s)
            sys_rows = np.take_along_axis(rows[grp], sys_pos, axis=1)
            par_rows = np.take_along_axis(rows[grp], par_pos, axis=1)
            # unknown coordinates: per-task complement of the pinned ones
            known = np.zeros((g, L), dtype=bool)
            known[np.arange(g)[:, None], sys_rows] = True
            unk = np.nonzero(~known)[1].reshape(g, L - s)
            Gp = _gather_generator_rows(G, glist, grp, par_rows)
            Gk = np.take_along_axis(Gp, sys_rows[:, None, :], axis=2)
            A = np.take_along_axis(Gp, unk[:, None, :], axis=2)
            mixed_groups.append(
                _MixedGroup(grp, sys_rows, unk, A, Gk, sys_pos, par_pos))
    if tr is not None:
        tr.add_span("plan_decode", t0, tr.now(), cat="plan", track="wall",
                    args={"tasks": B, "L": L, "scatter": int(fast_idx.size),
                          "solved": int(full_idx.size),
                          "mixed_groups": len(mixed_groups)})
    return DecodePlan(B, L, fast_idx, rows[fast_idx], full_idx, full_G,
                      mixed_groups)


def decode_batch(G: np.ndarray, rows: np.ndarray, y: np.ndarray,
                 *, backend: str = "numpy", systematic: str = "auto",
                 identity_prefix: Optional[bool] = None) -> np.ndarray:
    """Recover B systems A_t x_t from exactly-L received coded results each.

    G:    (L̃, L) shared generator, (B, L̃, L) per-task generators, or a
          length-B list of (L̃_b, L) generators (avoids stacking the full
          generators when only the received rows are needed).
    rows: (B, L) int — received coded-row indices per task.
    y:    (B, L) or (B, L, C) received results.

    systematic="auto" (default) exploits an identity prefix (G's top L rows
    are exactly I_L) at every straggler pattern:

    * a task that received *only* systematic rows is a permutation decode —
      ``out[rows] = y``, a scatter, bit-identical to the general solve (LU
      of a permutation matrix is exact) at O(L) instead of O(L³);
    * a task with ``0 < s < L`` systematic rows *substitutes* the known
      coordinates (each received systematic row pins one entry of x
      exactly) and solves only the (L−s)-sized parity block for the rest —
      tasks are grouped by s so each group is one stacked solve.  The
      pinned coordinates are bit-identical to the received values; the
      parity block agrees with the full L×L solve to solver precision.

    "prefix" keeps only the pure-systematic scatter and sends every mixed
    task through the full solve (the pre-substitution behaviour; the
    benchmark baseline for the substitution speedup).  "never" forces the
    general solve for everything.

    ``identity_prefix=True`` skips the O(L²) identity-prefix scan when the
    caller built G systematically (see :func:`plan_decode`).

    Solves run as ``np.linalg.solve`` on the numpy backend and a cached
    jitted ``jnp.linalg.solve`` on jax/pallas.  This function is the
    composition ``plan_decode(G, rows).apply(y)``; callers re-decoding
    against fixed received rows should hold the plan and call ``apply``.
    """
    check_backend(backend)
    return plan_decode(G, rows, systematic=systematic,
                       identity_prefix=identity_prefix).apply(
                           y, backend=backend)


# ---------------------------------------------------------------------------
# Batched least-squares decode (> L received rows)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _lstsq_jit():
    import jax
    import jax.numpy as jnp
    return jax.jit(jax.vmap(lambda A, y: jnp.linalg.lstsq(A, y)[0]))


class LSDecodePlan:
    """X-independent structure of a stacked *least-squares* decode.

    The exact :class:`DecodePlan` consumes exactly L rows per task; when a
    prefix delivered R > L rows (extra parity arrived before the cut), the
    overdetermined solve averages out the float32 encode noise of the
    jax/pallas product path instead of discarding the surplus — the
    streaming analogue of :func:`repro.core.mds.decode_ls`.  Gathered
    generator blocks are frozen at plan time; ``apply`` re-solves per
    right-hand side.  The numpy engine is *literally* a per-task
    ``np.linalg.lstsq`` sweep, so it is bit-identical to the reference by
    construction; jax runs a vmapped jitted ``jnp.linalg.lstsq``.
    """

    __slots__ = ("B", "L", "Gs", "_lu")

    def __init__(self, B: int, L: int, Gs: np.ndarray):
        self.B = B
        self.L = L
        self.Gs = Gs                     # (B, R, L) gathered generator rows
        # R == L is a square system: route it through the same cached-LU
        # solve the exact decode uses, so "least squares with no surplus"
        # is bit-identical to the square decode (tested) instead of
        # merely close via the QR in lstsq
        self._lu = StackedLU(Gs) if Gs.shape[1] == L else None

    def apply(self, y: np.ndarray, *, backend: str = "numpy") -> np.ndarray:
        """Least-squares solve for stacked received results ``y`` of shape
        (B, R) or (B, R, C) → (B, L[, C])."""
        check_backend(backend)
        tr = current_tracer()
        t0 = tr.now() if tr is not None else 0.0
        y = np.asarray(y, dtype=np.float64)
        squeeze = y.ndim == 2
        if squeeze:
            y = y[..., None]
        if self._lu is not None:
            out = self._lu.solve(y)
        elif _use_jax(backend):
            import jax
            try:
                with jax.experimental.enable_x64():
                    out = np.asarray(_lstsq_jit()(self.Gs, y),
                                     dtype=np.float64)
            except Exception:     # pragma: no cover - lstsq not vmappable
                out = self._apply_np(y)
        else:
            out = self._apply_np(y)
        if tr is not None:
            tr.add_span("decode_ls_apply", t0, tr.now(), cat="decode",
                        track="wall",
                        args={"tasks": self.B, "L": self.L,
                              "rows": int(self.Gs.shape[1]),
                              "backend": backend})
        return out[..., 0] if squeeze else out

    def _apply_np(self, y: np.ndarray) -> np.ndarray:
        out = np.empty((self.B, self.L, y.shape[-1]))
        for b in range(self.B):
            out[b], *_ = np.linalg.lstsq(self.Gs[b], y[b], rcond=None)
        return out


def plan_decode_ls(G, rows: np.ndarray, *,
                   allow_underdetermined: bool = False) -> LSDecodePlan:
    """Build the :class:`LSDecodePlan` for stacked received rows (B, R),
    R ≥ L.  ``G`` accepts the same forms as :func:`plan_decode` —
    including :class:`SystematicRows` for virtual parity.

    ``allow_underdetermined`` admits R < L for the *degraded* recovery
    path (fault verification rejected rows below coverage): ``lstsq``
    then returns the minimum-norm solution — explicitly reported as
    degraded by the caller, never silently exact."""
    rows = np.asarray(rows)
    glist = isinstance(G, (list, tuple))
    B, R = rows.shape
    if glist:
        L = np.asarray(G[0]).shape[-1]
    else:
        L = G.shape[-1]
    if R < L and not allow_underdetermined:
        raise ValueError(f"least-squares decode needs >= L={L} rows per "
                         f"task, got {R}")
    if not glist and not isinstance(G, SystematicRows):
        G = np.asarray(G, dtype=np.float64)
    Gs = _gather_generator_rows(G, glist, np.arange(B), rows)
    return LSDecodePlan(B, int(L), np.asarray(Gs, dtype=np.float64))


def decode_ls_batch(G, rows: np.ndarray, y: np.ndarray,
                    *, backend: str = "numpy") -> np.ndarray:
    """Least-squares decode of B tasks from ≥ L received rows each —
    the composition ``plan_decode_ls(G, rows).apply(y)``."""
    return plan_decode_ls(G, rows).apply(y, backend=backend)


# ---------------------------------------------------------------------------
# Parity-residual verification (fault detection over surplus rows)
# ---------------------------------------------------------------------------

class VerifyPlan:
    """X-independent structure of a batched parity-residual check.

    A decode consumes exactly L delivered rows; every row delivered
    *beyond* the covering prefix is a free integrity check on the result:
    for surplus row r with generator row G[r],

        resid_r = | y_r − G[r] · x̂ | / (1 + |y_r|)

    is ≈ 0 (float noise) when worker deliveries are honest and O(1) when
    any consumed or surplus row was corrupted.  The gathered surplus
    generator block is frozen at plan time (cached alongside the decode's
    :class:`StackedLU` in the serving step-plan cache); ``residuals``
    re-checks per right-hand side.
    """

    __slots__ = ("B", "L", "Gs")

    def __init__(self, B: int, L: int, Gs: np.ndarray):
        self.B = B
        self.L = L
        self.Gs = Gs                     # (B, S, L) surplus generator rows

    def residuals(self, x_hat: np.ndarray,
                  y_surplus: np.ndarray) -> np.ndarray:
        """Relative parity residual per surplus row.

        ``x_hat`` (B, L) or (B, L, C); ``y_surplus`` (B, S) or (B, S, C)
        → (B, S), the max over C of the relative residuals."""
        x_hat = np.asarray(x_hat, dtype=np.float64)
        y_surplus = np.asarray(y_surplus, dtype=np.float64)
        pred = np.einsum("bsl,bl...->bs...", self.Gs, x_hat)
        r = np.abs(y_surplus - pred) / (1.0 + np.abs(y_surplus))
        if r.ndim == 3:
            r = r.max(axis=-1)
        return r


def plan_verify(G, surplus_rows: np.ndarray) -> VerifyPlan:
    """Build the :class:`VerifyPlan` for stacked surplus rows (B, S).
    ``G`` accepts the same forms as :func:`plan_decode`."""
    surplus_rows = np.asarray(surplus_rows)
    glist = isinstance(G, (list, tuple))
    B = surplus_rows.shape[0]
    if glist:
        L = np.asarray(G[0]).shape[-1]
    else:
        L = G.shape[-1]
    if not glist and not isinstance(G, SystematicRows):
        G = np.asarray(G, dtype=np.float64)
    Gs = _gather_generator_rows(G, glist, np.arange(B), surplus_rows)
    return VerifyPlan(B, int(L), np.asarray(Gs, dtype=np.float64))


def verify_decode(G, rows: np.ndarray, y: np.ndarray,
                  surplus_rows: np.ndarray, y_surplus: np.ndarray, *,
                  tol: float = 1e-6, backend: str = "numpy"):
    """Decode from the earliest covering prefix and parity-check every
    surplus delivered row.

    ``rows`` (B, L) and ``y`` (B, L[, C]) feed the exact decode;
    ``surplus_rows`` (B, S) and ``y_surplus`` (B, S[, C]) are the extra
    deliveries to check.  Returns ``(x_hat, resid, bad)``: the decoded
    (B, L[, C]) result, the (B, S) relative residuals, and the boolean
    flag mask ``resid > tol``.  A flagged row means the system is
    inconsistent — either that surplus row or a row *inside* the decoded
    prefix is corrupt; :func:`localize_faulty_worker` disambiguates.
    """
    x_hat = plan_decode(G, np.asarray(rows)).apply(y, backend=backend)
    resid = plan_verify(G, surplus_rows).residuals(x_hat, y_surplus)
    return x_hat, resid, resid > tol


def localize_faulty_worker(G, rows: np.ndarray, y: np.ndarray,
                           row_workers: np.ndarray, *, tol: float = 1e-6,
                           candidates=None, backend: str = "numpy"):
    """Leave-one-worker-out sweep over ONE task's delivered rows.

    ``rows`` (R,) delivered coded-row ids (prefix + surplus, R > L),
    ``y`` (R,) or (R, C) their products, ``row_workers`` (R,) the worker
    that delivered each row.  For each candidate worker w (most-suspect
    first when ``candidates`` orders them): exclude w's rows; if ≥ L
    remain, decode from the earliest L and residual-check the rest — the
    first exclusion that restores consistency names the culprit.

    Returns ``(worker, x_hat, keep)``: the localised worker (or None
    when no exclusion is consistent), the clean decode over the kept
    rows, and the boolean keep-mask.  Guaranteed to localise when the
    corrupt worker's rows number ≤ R − L − 1 (enough surplus remains to
    re-check after exclusion); with exactly R − L the sweep still
    localises unless the corruption hides in an uncheckable exact-L
    remainder, which candidate ordering makes vanishingly rare.
    """
    rows = np.asarray(rows)
    y = np.asarray(y, dtype=np.float64)
    row_workers = np.asarray(row_workers)
    L = G.shape[-1] if not isinstance(G, (list, tuple)) \
        else np.asarray(G[0]).shape[-1]
    if candidates is None:
        candidates = sorted(set(int(w) for w in row_workers))
    for w in candidates:
        keep = row_workers != w
        if not (~keep).any() or int(keep.sum()) < L:
            continue
        kept_rows = rows[keep]
        kept_y = y[keep]
        x_hat = plan_decode(G, kept_rows[:L][None]).apply(
            kept_y[:L][None], backend=backend)[0]
        if kept_rows.size > L:
            resid = plan_verify(G, kept_rows[L:][None]).residuals(
                x_hat[None], kept_y[L:][None])[0]
            if (resid > tol).any():
                continue
        return int(w), x_hat, keep
    return None, None, None
