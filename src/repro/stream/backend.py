"""Batched completion / delay-sampling / decode backend shared by the
streaming engine, ``repro.sim.montecarlo`` and ``repro.runtime.coded_exec``.

The paper's completion rule — master m finishes at the earliest time its
cumulative received coded rows reach L_m — used to be implemented three
times (a per-master Python loop in the Monte-Carlo simulator, a per-arrival
Python loop in ``CodedExecutor``, and implicitly in the straggler policies).
This module is the single vectorised implementation:

* ``completion_times`` — sort + cumsum over the node axis, batched over any
  leading axes (realizations, masters, in-flight tasks).  NaN and ±inf
  delays are treated as "never arrives" instead of poisoning the prefix.
* ``sample_delays`` — one-call delay sampling for a batch of heterogeneous
  tasks (stacked (B, N+1) parameter rows).
* ``decode_batch`` — batched exactly-L MDS decode: ``np.linalg.solve`` on a
  stacked (B, L, L) system, or ``jax.vmap(jnp.linalg.solve)`` on the jax
  backend.
* ``ExponentialBlock`` — block-amortised standard-exponential draws so the
  event loop consumes pre-sampled randomness (deterministic replay, no
  per-event RNG overhead).

Everything accepts ``backend="numpy" | "jax"``; jax is optional and the
NumPy path is authoritative (tested bit-for-bit against the legacy loops).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

__all__ = [
    "has_jax",
    "completion_times",
    "delivered_by",
    "sample_delays",
    "decode_batch",
    "ExponentialBlock",
]

_EPS = 1e-12


@functools.lru_cache(maxsize=1)
def has_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:  # pragma: no cover - jax is baked into the image
        return False


# ---------------------------------------------------------------------------
# Completion times
# ---------------------------------------------------------------------------

def _completion_np(T: np.ndarray, loads: np.ndarray, need: np.ndarray,
                   needs_all: bool) -> np.ndarray:
    active = loads > 0
    # NaN (poisoned sample) and inf (dead worker) both mean "never arrives".
    Ti = np.where(active & np.isfinite(T), T, np.inf)
    if needs_all:
        out = np.where(active, Ti, -np.inf).max(axis=-1)
        out = np.where(active.any(axis=-1), out, np.inf)
        return np.where(np.isfinite(out), out, np.inf)
    order = np.argsort(Ti, axis=-1, kind="stable")
    T_s = np.take_along_axis(Ti, order, axis=-1)
    l_s = np.take_along_axis(np.where(active, loads, 0.0), order, axis=-1)
    cum = np.cumsum(l_s, axis=-1)
    hit = cum >= need[..., None] - 1e-9
    first = np.argmax(hit, axis=-1)
    reachable = np.take_along_axis(hit, first[..., None], axis=-1)[..., 0]
    out = np.take_along_axis(T_s, first[..., None], axis=-1)[..., 0]
    return np.where(reachable & np.isfinite(out), out, np.inf)


def _completion_jax(T, loads, need, needs_all: bool):
    import jax
    import jax.numpy as jnp

    def one(Trow, lrow, nd):
        active = lrow > 0
        Ti = jnp.where(active & jnp.isfinite(Trow), Trow, jnp.inf)
        if needs_all:
            out = jnp.where(active, Ti, -jnp.inf).max()
            out = jnp.where(active.any(), out, jnp.inf)
            return jnp.where(jnp.isfinite(out), out, jnp.inf)
        order = jnp.argsort(Ti)
        T_s = Ti[order]
        l_s = jnp.where(active, lrow, 0.0)[order]
        cum = jnp.cumsum(l_s)
        hit = cum >= nd - 1e-9
        first = jnp.argmax(hit)
        ok = hit[first] & jnp.isfinite(T_s[first])
        return jnp.where(ok, T_s[first], jnp.inf)

    lead = T.shape[:-1]
    Tf = T.reshape((-1, T.shape[-1]))
    lf = jnp.broadcast_to(loads, T.shape).reshape((-1, T.shape[-1]))
    nf = jnp.broadcast_to(need, lead).reshape((-1,))
    out = jax.vmap(one)(jnp.asarray(Tf), jnp.asarray(lf), jnp.asarray(nf))
    return np.asarray(out).reshape(lead)


def completion_times(T, loads, need, *, needs_all: bool = False,
                     backend: str = "numpy") -> np.ndarray:
    """Earliest t per batch row with Σ_{n: T_n <= t} l_n >= need.

    T:     (..., K) arrival times (absolute or relative — any monotone scale).
    loads: broadcastable to T; zero-load nodes are ignored.
    need:  broadcastable to T's leading axes.
    needs_all: the uncoded rule — wait for *every* positive-load node.

    Non-finite delays (inf dead workers, NaN poisoned samples) never arrive:
    they are skipped by the prefix, and the result is inf only if the
    remaining live nodes cannot cover ``need``.
    """
    T = np.asarray(T, dtype=np.float64)
    loads = np.broadcast_to(np.asarray(loads, dtype=np.float64), T.shape)
    need = np.broadcast_to(np.asarray(need, dtype=np.float64), T.shape[:-1])
    if backend == "jax" and has_jax():
        return _completion_jax(T, loads, need, needs_all)
    return _completion_np(T, loads, need, needs_all)


def delivered_by(T, loads, t) -> np.ndarray:
    """Rows delivered by time ``t``: Σ_{n: T_n <= t} l_n (batched)."""
    T = np.asarray(T, dtype=np.float64)
    loads = np.broadcast_to(np.asarray(loads, dtype=np.float64), T.shape)
    t = np.asarray(t, dtype=np.float64)
    arrived = np.isfinite(T) & (T <= t[..., None]) & (loads > 0)
    return np.where(arrived, loads, 0.0).sum(axis=-1)


# ---------------------------------------------------------------------------
# Delay sampling
# ---------------------------------------------------------------------------

def sample_delays(e_tr: np.ndarray, e_cp: np.ndarray, l, k, b, a, u, gamma,
                  *, local_col0: bool = True) -> np.ndarray:
    """Turn standard-exponential draws into T = T_tr + T_cp delays.

    ``e_tr``/``e_cp`` are ~Exp(1) draws of the same (batched) shape as ``l``;
    the transformation matches ``repro.core.delays.sample_total`` exactly, so
    an ``ExponentialBlock`` + ``sample_delays`` pipeline is distributionally
    identical to the legacy per-call sampler while being batchable and
    replayable.
    """
    l = np.asarray(l, dtype=np.float64)
    lsafe = np.maximum(l, _EPS)
    ksafe = np.maximum(k, _EPS)
    bsafe = np.maximum(b, _EPS)
    t_tr = e_tr * lsafe / (bsafe * gamma)
    if local_col0:
        t_tr = t_tr.copy()
        t_tr[..., 0] = 0.0
    t_cp = a * l / ksafe + e_cp * lsafe / (ksafe * u)
    return np.where(l > 0, t_tr + t_cp, 0.0)


class ExponentialBlock:
    """Pre-sampled Exp(1) draws consumed row-by-row (deterministic replay).

    The event loop needs one (2, N+1) standard-exponential row per admitted
    task; drawing them one event at a time costs a Generator call per event.
    This draws ``block`` rows at once and hands out views.
    """

    def __init__(self, rng: np.random.Generator, width: int,
                 block: int = 512):
        self.rng = rng
        self.width = int(width)
        self.block = int(block)
        self._buf = np.empty((0, 2, self.width))
        self._pos = 0

    def draw(self) -> np.ndarray:
        if self._pos >= self._buf.shape[0]:
            self._buf = self.rng.exponential(
                1.0, size=(self.block, 2, self.width))
            self._pos = 0
        row = self._buf[self._pos]
        self._pos += 1
        return row


# ---------------------------------------------------------------------------
# Batched MDS decode
# ---------------------------------------------------------------------------

def decode_batch(G: np.ndarray, rows: np.ndarray, y: np.ndarray,
                 *, backend: str = "numpy") -> np.ndarray:
    """Recover B systems A_t x_t from exactly-L received coded results each.

    G:    (L̃, L) shared generator.
    rows: (B, L) int — received coded-row indices per task.
    y:    (B, L) or (B, L, C) received results.

    numpy path: one batched ``np.linalg.solve``; jax path: ``jax.vmap`` of
    ``jnp.linalg.solve`` (the vmap execution backend of the streaming
    engine's verification mode).
    """
    rows = np.asarray(rows)
    Gs = np.asarray(G, dtype=np.float64)[rows]          # (B, L, L)
    y = np.asarray(y, dtype=np.float64)
    squeeze = y.ndim == 2
    if squeeze:
        y = y[..., None]
    if backend == "jax" and has_jax():
        import jax
        import jax.numpy as jnp
        out = np.asarray(jax.vmap(jnp.linalg.solve)(
            jnp.asarray(Gs), jnp.asarray(y)))
    else:
        out = np.linalg.solve(Gs, y)
    return out[..., 0] if squeeze else out
