"""Structured metrics of the streaming engine.

Per-task records plus pool-level aggregates, all in simulation time units
(the paper's scenarios are milliseconds):

* **sojourn**        t_complete − t_arrive  (what a user of the system sees)
* **queue_wait**     t_admit − t_arrive     (admission backpressure)
* **service**        t_complete − t_admit   (coded completion delay — the
                     quantity the paper's Theorems bound)
* **wasted_rows**    coded rows dispatched but cancelled at completion
                     (Σl − rows delivered by t_complete): the price of
                     redundancy, cf. the deadline policy's waste counter
* **overshoot_rows** delivered − L_m: rows received but not needed
* **utilization**    per-worker ∫ k_inflight dt / horizon — how much of each
                     worker's computing power the stream actually held

``summary()`` flattens everything into one dict of floats (JSON-ready);
``to_records()`` returns the raw per-task dicts for trace analysis.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

__all__ = ["TaskRecord", "StreamMetrics"]


@dataclasses.dataclass
class TaskRecord:
    tid: int
    master: int
    t_arrive: float
    t_admit: float = math.nan
    t_complete: float = math.nan
    fraction: float = 1.0          # admitted share scale (1 = full plan shares)
    rows_total: float = 0.0        # Σ l dispatched
    rows_needed: float = 0.0       # L_m
    rows_delivered: float = 0.0    # delivered by completion
    retries: int = 0               # re-dispatches after losing too many workers
    deadline: float = math.inf     # absolute completion deadline (inf = none)
    speculated: bool = False       # a twin dispatch raced the original
    decode_ok: Optional[bool] = None
    max_err: float = math.nan

    @property
    def sojourn(self) -> float:
        return self.t_complete - self.t_arrive

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.t_arrive

    @property
    def service(self) -> float:
        return self.t_complete - self.t_admit

    @property
    def wasted_rows(self) -> float:
        return max(self.rows_total - self.rows_delivered, 0.0)

    @property
    def overshoot_rows(self) -> float:
        return max(self.rows_delivered - self.rows_needed, 0.0)

    @property
    def deadline_miss(self) -> bool:
        """Finite deadline not met (never-completed counts as a miss)."""
        return math.isfinite(self.deadline) and \
            not (self.t_complete <= self.deadline)

    def to_dict(self) -> Dict[str, float]:
        return {
            "tid": self.tid, "master": self.master,
            "t_arrive": self.t_arrive, "t_admit": self.t_admit,
            "t_complete": self.t_complete, "sojourn": self.sojourn,
            "queue_wait": self.queue_wait, "service": self.service,
            "fraction": self.fraction, "rows_total": self.rows_total,
            "rows_needed": self.rows_needed,
            "rows_delivered": self.rows_delivered,
            "wasted_rows": self.wasted_rows,
            "overshoot_rows": self.overshoot_rows,
            "retries": self.retries,
            "deadline": self.deadline,
            "deadline_miss": self.deadline_miss,
            "speculated": self.speculated,
            "decode_ok": self.decode_ok, "max_err": self.max_err,
        }


class StreamMetrics:
    """Accumulates task records and worker share-time integrals.

    ``keep_records=False`` switches to compact accumulation: completed
    tasks fold into scalar columns (sojourn / queue wait / waste / master
    / deadline counters) instead of retaining ``TaskRecord`` objects —
    ``summary()`` is unchanged, ``to_records()`` becomes unavailable.
    Required at fleet scale: 1e6 retained records cost ~1 GB.  Unserved
    tasks keep their records either way (there are few, and censoring
    needs their deadlines).
    """

    def __init__(self, M: int, N: int, keep_records: bool = True):
        self.M, self.N = int(M), int(N)
        self.keep_records = bool(keep_records)
        self.completed: List[TaskRecord] = []
        self.unserved_tasks: List[TaskRecord] = []   # never completed
        self.rejected = 0
        self.unserved = 0          # still queued when the run ended
        self.replans = 0
        self.speculations = 0      # twin dispatches raced against stragglers
        self.busy_k = np.zeros(N + 1)      # ∫ k dt per worker column
        self.busy_b = np.zeros(N + 1)
        self.t_end = 0.0
        self._n_completed = 0
        # compact columns (populated only when keep_records=False)
        self._c_master: List[int] = []
        self._c_sojourn: List[float] = []
        self._c_queue_wait: List[float] = []
        self._c_wasted: List[float] = []
        self._c_needed: List[float] = []
        self._dl_total = 0
        self._dl_miss = 0

    # -- accumulation --------------------------------------------------------

    def record_task(self, rec: TaskRecord) -> None:
        self._n_completed += 1
        if np.isfinite(rec.t_complete):
            self.t_end = max(self.t_end, rec.t_complete)
        if self.keep_records:
            self.completed.append(rec)
            return
        self._c_master.append(rec.master)
        self._c_sojourn.append(rec.sojourn)
        self._c_queue_wait.append(rec.queue_wait)
        self._c_wasted.append(rec.wasted_rows)
        self._c_needed.append(rec.rows_needed)
        if math.isfinite(rec.deadline):
            self._dl_total += 1
            self._dl_miss += int(rec.deadline_miss)

    def record_tasks_many(self, recs: List[TaskRecord],
                          t_completes: np.ndarray,
                          rows_delivered: np.ndarray) -> None:
        """Batched :meth:`record_task` for B completions finalised together.

        Writes ``t_complete`` / ``rows_delivered`` onto the records and
        folds them in with array ops.  Every derived column is the same
        IEEE expression elementwise, so the values equal B sequential
        :meth:`record_task` calls exactly (the compact lists come out in
        the caller's batch order — a permutation never visible through the
        order-invariant summary statistics)."""
        tc = np.asarray(t_completes, dtype=np.float64)
        rd = np.asarray(rows_delivered, dtype=np.float64)
        self._n_completed += len(recs)
        fin = tc[np.isfinite(tc)]
        if fin.size:
            self.t_end = max(self.t_end, float(fin.max()))
        if self.keep_records:
            for i, rec in enumerate(recs):
                rec.t_complete = float(tc[i])
                rec.rows_delivered = float(rd[i])
                self.completed.append(rec)
            return
        t_arrive = np.asarray([r.t_arrive for r in recs])
        t_admit = np.asarray([r.t_admit for r in recs])
        rows_total = np.asarray([r.rows_total for r in recs])
        dl = np.asarray([r.deadline for r in recs])
        for i, rec in enumerate(recs):
            rec.t_complete = float(tc[i])
            rec.rows_delivered = float(rd[i])
        self._c_master.extend(r.master for r in recs)
        self._c_sojourn.extend((tc - t_arrive).tolist())
        self._c_queue_wait.extend((t_admit - t_arrive).tolist())
        self._c_wasted.extend(np.maximum(rows_total - rd, 0.0).tolist())
        self._c_needed.extend(r.rows_needed for r in recs)
        fin_dl = np.isfinite(dl)
        self._dl_total += int(fin_dl.sum())
        self._dl_miss += int((fin_dl & ~(tc <= dl)).sum())

    def record_unserved(self, rec: TaskRecord,
                        censor_after: float = math.inf) -> None:
        """A task the run ended without serving — its expired deadline must
        count as a miss, or a starving policy would look deadline-perfect.

        ``censor_after``: observation horizon of a truncated run (engine
        ``until=``).  A deadline beyond it is *censored* — the simulation
        stopped before the verdict — and is excluded from the miss
        statistic rather than counted against the policy."""
        if math.isfinite(rec.deadline) and rec.deadline > censor_after:
            return
        self.unserved_tasks.append(rec)

    def record_share_interval(self, k_row: np.ndarray, b_row: np.ndarray,
                              dt: float) -> None:
        self.busy_k += k_row * dt
        self.busy_b += b_row * dt

    def record_share_interval_many(self, k_rows: np.ndarray,
                                   b_rows: np.ndarray,
                                   dts: np.ndarray) -> None:
        """Fold (B, N+1) share rows held for (B,) durations into the busy-
        time integrals in one pass (sum-associativity aside, B sequential
        :meth:`record_share_interval` calls)."""
        self.busy_k += (k_rows * dts[:, None]).sum(axis=0)
        self.busy_b += (b_rows * dts[:, None]).sum(axis=0)

    # -- views ---------------------------------------------------------------

    _COMPACT_COLS = {"sojourn": "_c_sojourn", "queue_wait": "_c_queue_wait",
                     "wasted_rows": "_c_wasted", "rows_needed": "_c_needed"}

    def _arr(self, attr: str, master: Optional[int] = None) -> np.ndarray:
        if not self.keep_records:
            a = np.asarray(getattr(self, self._COMPACT_COLS[attr]),
                           dtype=np.float64)
            if master is not None:
                a = a[np.asarray(self._c_master, dtype=np.int64) == master]
            return a
        recs = self.completed if master is None else [
            r for r in self.completed if r.master == master]
        return np.array([getattr(r, attr) for r in recs], dtype=np.float64)

    def sojourns(self, master: Optional[int] = None) -> np.ndarray:
        return self._arr("sojourn", master)

    def utilization(self) -> np.ndarray:
        """Mean in-flight computing-power share per worker (cols 1..N).

        With no observed horizon (nothing completed, ``t_end == 0``) the
        integral has no denominator — report zeros instead of the 1e300-
        scale garbage a tiny epsilon horizon would produce (shares can be
        recorded at a cutoff even when no task ever finished)."""
        if self.t_end <= 0.0:
            return np.zeros(self.N)
        return self.busy_k[1:] / self.t_end

    def to_records(self) -> List[Dict[str, float]]:
        if not self.keep_records:
            raise RuntimeError(
                "per-task records were not retained "
                "(BackendConfig.keep_records=False)")
        return [r.to_dict() for r in self.completed]

    def summary(self) -> Dict[str, float]:
        """One flat dict of floats.  NaN-safe by construction: statistics
        over partially-populated pools (tasks whose ``t_admit`` /
        ``t_complete`` are still NaN, or an entirely empty run) are computed
        over the *finite* samples only, and a key with no finite sample is
        omitted rather than emitted as NaN — downstream JSON/gating code
        never sees a NaN."""
        s = self.sojourns()
        q = self._arr("queue_wait")
        w = self._arr("wasted_rows")
        need = self._arr("rows_needed")
        ok = [r.decode_ok for r in self.completed if r.decode_ok is not None]
        out: Dict[str, float] = {
            "tasks_completed": float(self._n_completed),
            "tasks_rejected": float(self.rejected),
            "tasks_unserved": float(self.unserved),
            "replans": float(self.replans),
            "speculations": float(self.speculations),
            "horizon": float(self.t_end),
            "utilization_mean": float(self.utilization().mean()),
            "utilization_max": float(self.utilization().max()),
        }
        with_dl = [r for r in self.completed + self.unserved_tasks
                   if math.isfinite(r.deadline)]
        dl_total = len(with_dl) + self._dl_total
        if dl_total:
            dl_miss = sum(r.deadline_miss for r in with_dl) + self._dl_miss
            out["deadline_miss_rate"] = float(dl_miss / dl_total)
        if s.size:
            fin = s[np.isfinite(s)]
            fq = q[np.isfinite(q)]
            fw = w[np.isfinite(w)]
            out["throughput_per_time"] = \
                (self._n_completed / self.t_end) if self.t_end > 0 else 0.0
            out.update({
                "sojourn_mean": float(fin.mean()) if fin.size else math.inf,
                "sojourn_p50": float(np.quantile(fin, 0.50)) if fin.size else math.inf,
                "sojourn_p95": float(np.quantile(fin, 0.95)) if fin.size else math.inf,
                "sojourn_p99": float(np.quantile(fin, 0.99)) if fin.size else math.inf,
            })
            if fq.size:
                out["queue_wait_mean"] = float(fq.mean())
                out["queue_wait_p99"] = float(np.quantile(fq, 0.99))
            if fw.size:
                out["wasted_rows_per_task"] = float(fw.mean())
                need_sum = need[np.isfinite(need)].sum()
                out["wasted_fraction"] = float(
                    fw.sum() / max(need_sum, 1e-300))
        if ok:
            out["decode_ok_rate"] = float(np.mean([bool(v) for v in ok]))
        return out
