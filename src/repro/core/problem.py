"""Problem definitions for the multi-master / heterogeneous-worker coded
computation system (paper §II).

Conventions used throughout ``repro.core``:

* Node axis has length ``N + 1``; **column 0 is the master's local processor**
  (the paper's index ``n = 0``), columns ``1..N`` are the shared workers.
* All per-(master, node) parameters are dense ``(M, N + 1)`` arrays.
* ``k`` (computing-power fraction) and ``b`` (bandwidth fraction) are
  ``(M, N + 1)`` with column 0 pinned to 1 (a master is always dedicated to
  itself, paper §II-A).  Dedicated assignment means ``k ∈ {0,1}`` and
  ``b == k``; fractional means ``k, b ∈ [0,1]`` with per-worker column sums
  ``≤ 1`` (excluding column 0).
* Loads ``l`` are non-negative reals (the paper relaxes integrality in (7c)).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Scenario",
    "Plan",
    "theta_dedicated",
    "theta_fractional",
    "validate_plan",
    "small_scale_scenario",
    "large_scale_scenario",
    "ec2_scenario",
]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """System parameters for one problem instance.

    Attributes
    ----------
    a:      (M, N+1) shift parameter of the shifted-exponential computation
            delay per coded row (paper eq. (2)); column 0 is local compute.
    u:      (M, N+1) rate parameter of the computation delay.
    gamma:  (M, N+1) rate parameter of the exponential communication delay
            per coded row at full bandwidth (paper eq. (1)).  Column 0 is
            ignored (local compute has no communication, eq. (5)).
    L:      (M,) number of *useful* inner products master m must recover.
    """

    a: np.ndarray
    u: np.ndarray
    gamma: np.ndarray
    L: np.ndarray

    def __post_init__(self):
        a = np.asarray(self.a, dtype=np.float64)
        u = np.asarray(self.u, dtype=np.float64)
        g = np.asarray(self.gamma, dtype=np.float64)
        L = np.asarray(self.L, dtype=np.float64)
        object.__setattr__(self, "a", a)
        object.__setattr__(self, "u", u)
        object.__setattr__(self, "gamma", g)
        object.__setattr__(self, "L", L)
        if a.shape != u.shape or a.shape != g.shape:
            raise ValueError("a, u, gamma must share shape (M, N+1)")
        if a.ndim != 2 or L.shape != (a.shape[0],):
            raise ValueError("bad scenario shapes")
        if np.any(u <= 0) or np.any(a < 0) or np.any(L <= 0):
            raise ValueError("u must be > 0, a >= 0, L > 0")
        if np.any(g[:, 1:] <= 0):
            raise ValueError("worker gamma must be > 0")

    @property
    def M(self) -> int:
        return self.a.shape[0]

    @property
    def N(self) -> int:
        return self.a.shape[1] - 1


@dataclasses.dataclass
class Plan:
    """A full solution: worker assignment + resource split + load allocation.

    ``t_per_master`` is the *predicted* (model-based) completion delay of each
    master under the allocation model that produced the plan; ``t`` is the
    min-max objective ``max_m t_per_master``.  Empirical delays come from
    ``repro.sim.montecarlo``.
    """

    k: np.ndarray                    # (M, N+1) computing-power fractions
    b: np.ndarray                    # (M, N+1) bandwidth fractions
    l: np.ndarray                    # (M, N+1) loads (coded rows)
    t_per_master: np.ndarray         # (M,)
    method: str = ""

    @property
    def t(self) -> float:
        return float(np.max(self.t_per_master))

    @property
    def redundancy(self) -> np.ndarray:
        """Per-master coding redundancy  Σ_n l_{m,n} / L_m  (≥ 1)."""
        return self.l.sum(axis=1)

    def workers_of(self, m: int) -> np.ndarray:
        """Worker indices (1-based columns) serving master m (paper Ω_m)."""
        return np.nonzero(self.l[m, 1:] > 0)[0] + 1


# ---------------------------------------------------------------------------
# Expected unit-delay θ (paper eqs. (10) and (24))
# ---------------------------------------------------------------------------

def theta_dedicated(sc: Scenario, assign: np.ndarray) -> np.ndarray:
    """θ_{m,n} for a dedicated assignment (paper eq. (10)).

    ``assign`` is a boolean/binary ``(M, N+1)`` participation mask (column 0
    should be 1).  Non-participating entries get ``inf`` so that ``1/θ = 0``.
    """
    th = np.full_like(sc.a, np.inf)
    th[:, 0] = 1.0 / sc.u[:, 0] + sc.a[:, 0]
    w = assign[:, 1:] > 0
    inv = 1.0 / sc.gamma[:, 1:] + 1.0 / sc.u[:, 1:] + sc.a[:, 1:]
    th[:, 1:] = np.where(w, inv, np.inf)
    return th


def theta_fractional(sc: Scenario, k: np.ndarray, b: np.ndarray) -> np.ndarray:
    """θ_{m,n} under fractional resource split (paper eq. (24))."""
    th = np.full_like(sc.a, np.inf)
    th[:, 0] = 1.0 / sc.u[:, 0] + sc.a[:, 0]
    kk, bb = k[:, 1:], b[:, 1:]
    act = (kk > 0) & (bb > 0)
    with np.errstate(divide="ignore"):
        val = (
            1.0 / np.where(act, bb * sc.gamma[:, 1:], 1.0)
            + 1.0 / np.where(act, kk * sc.u[:, 1:], 1.0)
            + sc.a[:, 1:] / np.where(act, kk, 1.0)
        )
    th[:, 1:] = np.where(act, val, np.inf)
    return th


def validate_plan(sc: Scenario, plan: Plan, *, fractional: bool,
                  atol: float = 1e-9) -> None:
    """Raise if a plan violates the paper's constraints (6c)-(6e)/(25c-d)."""
    k, b, l = plan.k, plan.b, plan.l
    if k.shape != (sc.M, sc.N + 1):
        raise ValueError("plan shape mismatch")
    if np.any(l < -atol):
        raise ValueError("negative load")
    if not np.allclose(k[:, 0], 1.0) or not np.allclose(b[:, 0], 1.0):
        raise ValueError("masters must be dedicated to themselves (k_{m,0}=1)")
    sums_k = k[:, 1:].sum(axis=0)
    sums_b = b[:, 1:].sum(axis=0)
    if np.any(sums_k > 1 + atol) or np.any(sums_b > 1 + atol):
        raise ValueError("per-worker resource constraint (6c)/(25c) violated")
    if not fractional:
        vals = np.unique(np.round(k[:, 1:], 12))
        if not np.all(np.isin(vals, (0.0, 1.0))):
            raise ValueError("dedicated plan requires binary k")
        if not np.allclose(k[:, 1:], b[:, 1:]):
            raise ValueError("dedicated plan requires b == k")
    # A node either gets everything (k,b,l > 0) or nothing (paper §IV-A).
    for m in range(sc.M):
        on = plan.l[m, 1:] > atol
        if np.any(on & ~((k[m, 1:] > 0) & (b[m, 1:] > 0))):
            raise ValueError("load assigned to a node with zero resources")


# ---------------------------------------------------------------------------
# Canonical scenarios from the paper's §V
# ---------------------------------------------------------------------------

def small_scale_scenario(rng: np.random.Generator | int = 0) -> Scenario:
    """M=2, N=5; a_{m,n} ∈ {0.2,0.25,0.3} ms, a_{m,0} ∈ {0.4,0.5} ms,
    u = 1/a, L = 1e4, γ = 2u (paper §V-A/V-B).  Times are in ms."""
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    M, N = 2, 5
    a = np.zeros((M, N + 1))
    a[:, 0] = rng.choice([0.4, 0.5], size=M)
    a[:, 1:] = rng.choice([0.2, 0.25, 0.3], size=(M, N))
    u = 1.0 / a
    gamma = 2.0 * u
    L = np.full(M, 1e4)
    return Scenario(a=a, u=u, gamma=gamma, L=L)


def large_scale_scenario(rng: np.random.Generator | int = 0,
                         M: int = 4, N: int = 50) -> Scenario:
    """M=4, N=50; a_{m,n} ~ U[0.05, 0.5] ms, a_{m,0} ∈ {0.4,0.5} ms,
    u = 1/a, L = 1e4, γ = 2u (paper §V-A/V-B)."""
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    a = np.zeros((M, N + 1))
    a[:, 0] = rng.choice([0.4, 0.5], size=M)
    a[:, 1:] = rng.uniform(0.05, 0.5, size=(M, N))
    u = 1.0 / a
    gamma = 2.0 * u
    L = np.full(M, 1e4)
    return Scenario(a=a, u=u, gamma=gamma, L=L)


# Fitted EC2 instance parameters from the paper's Fig. 7 (times in ms).
EC2_T2_MICRO = dict(a=1.36, u=4.976)
EC2_C5_LARGE = dict(a=0.97, u=19.29)


def ec2_scenario(rng: np.random.Generator | int = 0, M: int = 4, N: int = 50,
                 n_fast: int = 10, gamma_over_u: Optional[float] = None) -> Scenario:
    """Paper §V-C: 4 masters + 40 t2.micro + 10 c5.large workers; masters are
    t2.micro.  Computation-delay dominant unless ``gamma_over_u`` is given."""
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    a = np.zeros((M, N + 1))
    u = np.zeros((M, N + 1))
    a[:, 0], u[:, 0] = EC2_T2_MICRO["a"], EC2_T2_MICRO["u"]
    kinds = np.array([1] * n_fast + [0] * (N - n_fast))
    rng.shuffle(kinds)
    for n in range(N):
        spec = EC2_C5_LARGE if kinds[n] else EC2_T2_MICRO
        a[:, n + 1], u[:, n + 1] = spec["a"], spec["u"]
    if gamma_over_u is None:
        gamma = np.full_like(u, 1e9)  # computation-delay dominant
        gamma[:, 0] = 1e9
    else:
        gamma = gamma_over_u * u
    return Scenario(a=a, u=u, gamma=np.maximum(gamma, 1e-12), L=np.full(M, 1e4))
