"""Load-allocation theorems of the paper (Thm 1, Thm 2, Thm 3).

* Theorem 1 — Markov's-inequality convex surrogate (problem P4), any delay
  distribution with known mean:  l* = L/(θ Σ 1/(2θ)),  t* = L/Σ 1/(4θ).
* Theorem 2 — exact optimum of P3 when computation delay dominates, via the
  lower branch of the Lambert-W function.
* Theorem 3 — fractional-assignment KKT condition  l* = t*/(2θ).

θ values come from ``repro.core.problem.theta_*``; entries with θ = inf are
non-participating nodes and receive zero load.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "lambertw_m1",
    "phi_comp_dominant",
    "markov_loads",
    "comp_dominant_loads",
    "fractional_loads",
]


# ---------------------------------------------------------------------------
# Lambert W, lower branch  W_{-1}: [-1/e, 0) -> (-inf, -1]
# ---------------------------------------------------------------------------

def lambertw_m1(y):
    """Lower branch of the Lambert-W function, vectorised.

    Solves w·e^w = y for y ∈ [-1/e, 0), returning w ≤ -1.  Uses the
    asymptotic seed w0 = ln(-y) - ln(-ln(-y)) followed by Halley iterations
    (quadratic-plus convergence; 6 iterations reach ~1e-15 everywhere on the
    branch, including the awkward region near -1/e where we seed with the
    square-root expansion instead).
    """
    y = np.asarray(y, dtype=np.float64)
    if np.any(y >= 0) or np.any(y < -np.exp(-1.0) * (1 + 1e-12)):
        raise ValueError("lambertw_m1 domain is [-1/e, 0)")
    y = np.minimum(y, -1e-300)

    # Seeds.  Near the branch point -1/e use the series w ≈ -1 - s - s²/3,
    # s = sqrt(2(1 + e·y)); elsewhere use the log-log asymptote.
    s = np.sqrt(np.maximum(2.0 * (1.0 + np.e * y), 0.0))
    w_branch = -1.0 - s - s * s / 3.0
    ly = np.log(-y)
    with np.errstate(invalid="ignore"):
        w_asym = ly - np.log(-ly)
    w = np.where(y > -0.25 / np.e, w_asym, w_branch)
    w = np.minimum(w, -1.0 - 1e-12)

    for _ in range(20):
        ew = np.exp(w)
        f = w * ew - y
        # Halley step.
        denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0)
        step = f / np.where(np.abs(denom) < 1e-300, 1e-300, denom)
        w_new = w - step
        w_new = np.minimum(w_new, -1.0)       # stay on the lower branch
        if np.all(np.abs(w_new - w) <= 1e-14 * (1 + np.abs(w_new))):
            w = w_new
            break
        w = w_new
    return w


def phi_comp_dominant(a, u):
    """φ_{m,n} = [ -W_{-1}(-e^{-u·a-1}) - 1 ] / u  (paper Thm 2).

    φ is the optimal per-row deadline-to-load ratio t*/l* for a
    shifted-exponential server; a > 0 required (a = 0 degenerates to the
    memoryless case where φ solves (1+uφ)e^{-uφ}=1 → φ→0; we clamp a).
    """
    a = np.maximum(np.asarray(a, dtype=np.float64), 1e-9)
    u = np.asarray(u, dtype=np.float64)
    y = -np.exp(-u * a - 1.0)
    return (-lambertw_m1(y) - 1.0) / u


# ---------------------------------------------------------------------------
# Theorem 1 — Markov-approximation loads (problem P4)
# ---------------------------------------------------------------------------

def markov_loads(L, theta) -> Tuple[np.ndarray, np.ndarray]:
    """Optimal loads/delay of the convex surrogate P4 (paper Thm 1).

    Parameters
    ----------
    L:      (M,) required useful rows per master.
    theta:  (M, N+1) expected unit delays; inf → node not participating.

    Returns ``(l, t)`` with ``l`` (M, N+1) and ``t`` (M,).
    Each participating node is expected to deliver exactly half its load by
    t* (the Markov bound is tight at 1/2), giving redundancy Σl = 2L.
    """
    L = np.asarray(L, dtype=np.float64)
    theta = np.asarray(theta, dtype=np.float64)
    inv = np.where(np.isfinite(theta), 1.0 / theta, 0.0)
    half = 0.5 * inv.sum(axis=-1)            # Σ 1/(2θ)
    quarter = 0.25 * inv.sum(axis=-1)        # Σ 1/(4θ)
    t = L / quarter
    l = (L / half)[..., None] * inv
    return l, t


# ---------------------------------------------------------------------------
# Theorem 2 — exact loads when computation delay dominates (problem P3(1))
# ---------------------------------------------------------------------------

def comp_dominant_loads(L, a, u, participate) -> Tuple[np.ndarray, np.ndarray]:
    """Exact optimum of P3 with T = T_cp only (paper Thm 2).

    l* = L/(φ Σ' u/(1+uφ)),  t* = L/Σ' u/(1+uφ)  over participating nodes.
    """
    L = np.asarray(L, dtype=np.float64)
    a = np.asarray(a, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    part = np.asarray(participate) > 0
    phi = phi_comp_dominant(a, u)
    w = np.where(part, u / (1.0 + u * phi), 0.0)   # per-node effective rate
    denom = w.sum(axis=-1)
    t = L / denom
    l = t[..., None] / phi * (part.astype(np.float64))
    # zero the non-participants exactly
    l = np.where(part, l, 0.0)
    return l, t


# ---------------------------------------------------------------------------
# Theorem 3 — fractional KKT loads
# ---------------------------------------------------------------------------

def fractional_loads(L, theta) -> Tuple[np.ndarray, np.ndarray]:
    """Loads satisfying the fractional KKT condition l* = t*/(2θ) (Thm 3).

    Identical in form to Theorem 1 — the KKT condition pins l θ / t = 1/2 —
    but θ here is the *fractional* θ_{m,n}(k, b) of eq. (24).
    """
    return markov_loads(L, theta)
