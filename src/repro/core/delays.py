"""Delay distributions of the paper (§II-B, eqs. (1)-(5)).

Communication delay of shipping ``l`` coded rows from master m to worker n
with bandwidth fraction ``b``:      T_tr ~ Exp(rate = b·γ / l).
Computation delay of ``l`` coded rows with computing-power fraction ``k``:
    T_cp ~ a·l/k + Exp(rate = k·u / l)    (shifted exponential).

All CDFs and expectations below are closed-form and vectorised; they are the
oracles the Monte-Carlo simulator and the optimization layers are tested
against.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "cdf_comm", "cdf_comp", "cdf_total", "cdf_local",
    "expected_total", "expected_received", "sample_total",
]

_EPS = 1e-12


def cdf_comm(t, l, b, gamma):
    """Eq. (1): P[T_tr <= t] for shipping l coded rows at bandwidth b·γ."""
    t, l = np.asarray(t, dtype=np.float64), np.asarray(l, dtype=np.float64)
    rate = np.where(l > 0, b * gamma / np.maximum(l, _EPS), np.inf)
    return np.where(t >= 0, 1.0 - np.exp(-rate * np.maximum(t, 0.0)), 0.0)


def cdf_comp(t, l, k, a, u):
    """Eq. (2): P[T_cp <= t], shifted exponential with shift a·l/k."""
    t, l = np.asarray(t, dtype=np.float64), np.asarray(l, dtype=np.float64)
    shift = a * l / np.maximum(k, _EPS)
    rate = k * u / np.maximum(l, _EPS)
    z = np.maximum(t - shift, 0.0)
    out = 1.0 - np.exp(-rate * z)
    return np.where((t >= shift) & (l > 0), out, np.where(l > 0, 0.0, 1.0))


def cdf_total(t, l, k, b, a, u, gamma):
    """Eqs. (3)/(4): CDF of T = T_tr + T_cp for a worker node.

    Handles the resonant case b·γ == k·u via eq. (4); fully vectorised.
    Zero-load entries return CDF 1 (an empty shipment completes at t=0).
    """
    t = np.asarray(t, dtype=np.float64)
    l = np.asarray(l, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    a = np.asarray(a, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    gamma = np.asarray(gamma, dtype=np.float64)
    lpos = l > 0
    lsafe = np.maximum(l, _EPS)
    ksafe = np.maximum(k, _EPS)
    cu = k * u          # computation rate numerator
    cg = b * gamma      # communication rate numerator
    shift = a * l / ksafe
    z = np.maximum(t - shift, 0.0)           # time past the deterministic shift
    ru = cu / lsafe     # computation exp rate
    rg = cg / lsafe     # communication exp rate
    same = np.isclose(cg, cu, rtol=1e-9, atol=1e-15)
    denom = np.where(same, 1.0, cg - cu)
    # Eq. (3): 1 - [bγ e^{-ru z} - ku e^{-rg z}] / (bγ - ku)
    general = 1.0 - (cg * np.exp(-ru * z) - cu * np.exp(-rg * z)) / denom
    # Eq. (4): 1 - (1 + ru z) e^{-ru z}
    resonant = 1.0 - (1.0 + ru * z) * np.exp(-ru * z)
    out = np.where(same, resonant, general)
    out = np.where(t >= shift, out, 0.0)
    return np.where(lpos, out, 1.0)


def cdf_local(t, l, a0, u0):
    """Eq. (5): local computation at the master (no communication)."""
    return cdf_comp(t, l, 1.0, a0, u0)


def expected_total(l, k, b, a, u, gamma):
    """E[T] = l·(1/(bγ) + 1/(ku) + a/k) — the Markov-inequality numerator (9)/(23)."""
    l = np.asarray(l, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        theta = 1.0 / (b * gamma) + 1.0 / (k * u) + a / np.maximum(k, _EPS)
    return l * theta


def expected_received(t, l, k, b, a, u, gamma):
    """E[X_m(t)] = Σ_n l_n · P[T_n <= t]  (paper eq. below (7)).

    Inputs are (M, N+1) arrays with column 0 = the master's local node
    (no communication, eq. (5)).
    """
    l = np.asarray(l, dtype=np.float64)
    p = np.empty_like(l)
    p[:, 0] = cdf_local(t, l[:, 0], a[:, 0], u[:, 0])
    p[:, 1:] = cdf_total(t, l[:, 1:], k[:, 1:], b[:, 1:],
                         a[:, 1:], u[:, 1:], gamma[:, 1:])
    return (l * p).sum(axis=-1)


def sample_total(rng: np.random.Generator, shape, l, k, b, a, u, gamma,
                 *, local_col0: bool = True):
    """Sample T = T_tr + T_cp.  ``shape`` prepends realization axes.

    With ``local_col0`` (the default for (M, N+1) plan arrays), column 0 is
    the master's local processor: its communication delay is identically 0.
    Zero-load nodes return 0 delay (they contribute nothing anyway).
    """
    l = np.asarray(l, dtype=np.float64)
    lsafe = np.maximum(l, _EPS)
    ksafe = np.maximum(k, _EPS)
    bsafe = np.maximum(b, _EPS)
    t_tr = rng.exponential(1.0, size=shape + l.shape) * lsafe / (bsafe * gamma)
    if local_col0:
        t_tr[..., 0] = 0.0
    t_cp = (a * l / ksafe
            + rng.exponential(1.0, size=shape + l.shape) * lsafe / (ksafe * u))
    total = t_tr + t_cp
    return np.where(l > 0, total, 0.0)
