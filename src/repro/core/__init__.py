"""The paper's primary contribution: joint worker assignment, resource
allocation and MDS-coded load allocation for multi-master heterogeneous
distributed computing with communication delay (Sun et al., IEEE TSP 2022).

Public surface:
  Scenario / Plan              problem containers (problem.py)
  theta_dedicated/fractional   expected unit delays, eqs. (10)/(24)
  markov_loads                 Theorem 1 (P4 optimum)
  comp_dominant_loads          Theorem 2 (Lambert-W exact optimum)
  fractional_loads             Theorem 3 (KKT loads)
  simple_greedy / iterated_greedy / fractional_greedy   Algorithms 2 / 1 / 4
  sca_enhance_plan             Algorithm 3 (SCA load enhancement)
  uncoded_uniform / coded_uniform / near_optimal_fractional   §V benchmarks
  make_generator / encode / decode / decode_ls            real-MDS codec
"""
from .allocation import (comp_dominant_loads, fractional_loads, lambertw_m1,
                         markov_loads, phi_comp_dominant)
from .assignment import (fractional_greedy, iterated_greedy,
                         plan_from_assignment, simple_greedy, value_matrix)
from .benchmarks import (coded_uniform, near_optimal_fractional,
                         uncoded_uniform, uniform_assignment)
from .mds import decode, decode_ls, encode, integer_loads, make_generator, split_loads
from .problem import (Plan, Scenario, ec2_scenario, large_scale_scenario,
                      small_scale_scenario, theta_dedicated, theta_fractional,
                      validate_plan)
from .sca import sca_enhance_master, sca_enhance_plan

__all__ = [
    "Plan", "Scenario",
    "ec2_scenario", "large_scale_scenario", "small_scale_scenario",
    "theta_dedicated", "theta_fractional", "validate_plan",
    "lambertw_m1", "phi_comp_dominant",
    "markov_loads", "comp_dominant_loads", "fractional_loads",
    "simple_greedy", "iterated_greedy", "fractional_greedy",
    "plan_from_assignment", "value_matrix",
    "sca_enhance_master", "sca_enhance_plan",
    "uncoded_uniform", "coded_uniform", "near_optimal_fractional",
    "uniform_assignment",
    "make_generator", "encode", "decode", "decode_ls", "integer_loads",
    "split_loads",
]
