"""Worker-assignment algorithms of the paper (§III-C, §IV-B).

* Algorithm 1 — iterated greedy (insertion / interchange / exploration) for
  the NP-hard max-min allocation problem P5.
* Algorithm 2 — simple greedy (largest-value-first to the poorest master).
* Algorithm 4 — fractional greedy: balance ``V_max`` vs ``V_min`` by moving
  (part of) a worker's computing power & bandwidth between masters.

Values are ``v_{m,n} = 1/(4 L_m θ_{m,n})`` (Markov mode, Thm 1) or
``v_{m,n} = u_{m,n} / (L_m (1 + u_{m,n} φ_{m,n}))`` (computation-dominant
mode, Thm 2); the sum ``V_m = Σ v`` is exactly ``1/t*_m``.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

import numpy as np

from .allocation import (comp_dominant_loads, fractional_loads, markov_loads,
                         phi_comp_dominant)
from .problem import Plan, Scenario, theta_dedicated, theta_fractional

ValueMode = Literal["markov", "comp_exact"]

__all__ = [
    "value_matrix",
    "simple_greedy",
    "iterated_greedy",
    "fractional_greedy",
    "plan_from_assignment",
]


def value_matrix(sc: Scenario, mode: ValueMode = "markov") -> np.ndarray:
    """v_{m,n} for all (m, n) incl. the local column 0 (paper eq. (17))."""
    full = np.ones((sc.M, sc.N + 1))
    if mode == "markov":
        theta = theta_dedicated(sc, full)
        return 1.0 / (4.0 * sc.L[:, None] * theta)
    elif mode == "comp_exact":
        phi = phi_comp_dominant(sc.a, sc.u)
        return sc.u / (sc.L[:, None] * (1.0 + sc.u * phi))
    raise ValueError(f"unknown value mode {mode!r}")


def _assignment_to_k(sc: Scenario, owner: np.ndarray) -> np.ndarray:
    """owner: (N,) int array of the master owning each worker → k (M, N+1)."""
    k = np.zeros((sc.M, sc.N + 1))
    k[:, 0] = 1.0
    for n in range(sc.N):
        if owner[n] >= 0:
            k[owner[n], n + 1] = 1.0
    return k


# ---------------------------------------------------------------------------
# Algorithm 2 — simple greedy
# ---------------------------------------------------------------------------

def simple_greedy(sc: Scenario, mode: ValueMode = "markov") -> np.ndarray:
    """Largest-value-first assignment (paper Alg. 2).  Returns k (M, N+1)."""
    v = value_matrix(sc, mode)
    V = v[:, 0].copy()
    owner = np.full(sc.N, -1, dtype=int)
    remaining = list(range(1, sc.N + 1))
    while remaining:
        m_star = int(np.argmin(V))
        n_star = max(remaining, key=lambda n: v[m_star, n])
        V[m_star] += v[m_star, n_star]
        owner[n_star - 1] = m_star
        remaining.remove(n_star)
    return _assignment_to_k(sc, owner)


# ---------------------------------------------------------------------------
# Algorithm 1 — iterated greedy
# ---------------------------------------------------------------------------

def iterated_greedy(sc: Scenario, mode: ValueMode = "markov",
                    max_iters: int = 30, explore_frac: float = 0.3,
                    patience: int = 5,
                    rng: np.random.Generator | int = 0) -> np.ndarray:
    """Iterated greedy with insertion / interchange / exploration (Alg. 1).

    The reported assignment is the best post-interchange snapshot (the
    paper's "final output is the worker assignment after the interchange
    phase").
    """
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    v = value_matrix(sc, mode)
    M, N = sc.M, sc.N
    if M == 1:                 # single master: every worker helps it
        return _assignment_to_k(sc, np.zeros(N, dtype=int))

    # --- initialization: each worker to the master valuing it most -------
    owner = np.argmax(v[:, 1:], axis=0).astype(int)      # (N,)
    V = v[:, 0].copy()
    for n in range(N):
        V[owner[n]] += v[owner[n], n + 1]

    def lex_better(V_new, V_old, tol=1e-15):
        """Lexicographic improvement of the sorted value vector.

        The paper's insertion accepts only strict global-min improvements;
        with symmetric masters (e.g. the EC2 scenario, where every master
        values a worker identically) several masters tie at the minimum and
        no single move can raise it — the literal rule deadlocks with all
        workers on one master.  Sorted-vector lexicographic acceptance is
        the standard max-min plateau fix and strictly generalizes the
        paper's condition."""
        a, b = np.sort(V_new), np.sort(V_old)
        for x, y in zip(a, b):
            if x > y + tol:
                return True
            if x < y - tol:
                return False
        return False

    best_owner, best_min = owner.copy(), float(np.min(V))
    stall = 0
    for _ in range(max_iters):
        # --- insertion phase ---------------------------------------------
        for n in range(N):
            m1 = owner[n]
            others = [m for m in range(M) if m != m1]
            m2 = min(others, key=lambda m: V[m])
            V_new = V.copy()
            V_new[m1] -= v[m1, n + 1]
            V_new[m2] += v[m2, n + 1]
            if lex_better(V_new, V):
                V = V_new
                owner[n] = m2

        # --- interchange phase -------------------------------------------
        for n1 in range(N):
            for n2 in range(n1 + 1, N):
                m1, m2 = owner[n1], owner[n2]
                if m1 == m2:
                    continue
                if v[m1, n1 + 1] + v[m2, n2 + 1] >= v[m1, n2 + 1] + v[m2, n1 + 1]:
                    continue
                Vmin = np.min(V)
                V1 = V[m1] - v[m1, n1 + 1] + v[m1, n2 + 1]
                V2 = V[m2] - v[m2, n2 + 1] + v[m2, n1 + 1]
                if V1 > Vmin and V2 > Vmin:
                    V[m1], V[m2] = V1, V2
                    owner[n1], owner[n2] = m2, m1

        # snapshot after interchange (the paper's reporting point)
        cur_min = float(np.min(V))
        if cur_min > best_min + 1e-15:
            best_min, best_owner = cur_min, owner.copy()
            stall = 0
        else:
            stall += 1
            if stall >= patience:
                break

        # --- exploration phase -------------------------------------------
        n_remove = max(1, int(round(explore_frac * N)))
        removed = rng.choice(N, size=n_remove, replace=False)
        for n in removed:
            V[owner[n]] -= v[owner[n], n + 1]
            owner[n] = -1
        pool = list(removed)
        while pool:
            # jointly pick (m*, n*) with max value among removed workers
            sub = v[:, [n + 1 for n in pool]]
            m_star, j = np.unravel_index(np.argmax(sub), sub.shape)
            n_star = pool[j]
            owner[n_star] = int(m_star)
            V[m_star] += v[m_star, n_star + 1]
            pool.remove(n_star)

    return _assignment_to_k(sc, best_owner)


# ---------------------------------------------------------------------------
# Plans from dedicated assignments
# ---------------------------------------------------------------------------

def plan_from_assignment(sc: Scenario, k: np.ndarray,
                         mode: ValueMode = "markov",
                         method: str = "") -> Plan:
    """Attach Thm-1 (or Thm-2) loads to a dedicated assignment."""
    if mode == "markov":
        theta = theta_dedicated(sc, k)
        l, t = markov_loads(sc.L, theta)
    else:
        part = k.copy()
        part[:, 0] = 1.0
        l, t = comp_dominant_loads(sc.L, sc.a, sc.u, part)
    return Plan(k=k, b=k.copy(), l=l, t_per_master=t, method=method or f"dedicated-{mode}")


# ---------------------------------------------------------------------------
# Algorithm 4 — fractional greedy
# ---------------------------------------------------------------------------

def fractional_greedy(sc: Scenario, init: Optional[np.ndarray] = None,
                      mode: ValueMode = "markov", max_iters: int = 500,
                      tol: float = 1e-7, loads: ValueMode = "markov",
                      rng: np.random.Generator | int = 0) -> Plan:
    """Fractional worker assignment by V_max / V_min balancing (Alg. 4).

    ``loads``: how to allocate loads on the final (k, b).  "markov" = Thm-3
    KKT loads; "comp_exact" = Thm-2 with the paper's effective-parameter
    substitution (û = k·u, â = a/k) — the right choice when computation
    delay dominates (§V-C)."""
    if init is None:
        init = iterated_greedy(sc, mode=mode, rng=rng)
    k = init.astype(np.float64).copy()
    b = k.copy()

    def V_of(k_, b_):
        theta = theta_fractional(sc, k_, b_)
        inv = np.where(np.isfinite(theta), 1.0 / theta, 0.0)
        return (0.25 * inv.sum(axis=1)) / sc.L, theta

    V, theta = V_of(k, b)
    for _ in range(max_iters):
        m1, m2 = int(np.argmax(V)), int(np.argmin(V))
        if V[m1] - V[m2] <= tol * max(V[m2], 1e-300):
            break
        cand = np.nonzero((k[m1, 1:] > 0) & (k[m2, 1:] == 0))[0] + 1
        if cand.size == 0:
            break
        # Potential θ'_{m2,n}: m2 gets *all* of n's current m1 resources.
        kk, bb = k[m1, cand], b[m1, cand]
        theta_p = (1.0 / (bb * sc.gamma[m2, cand])
                   + 1.0 / (kk * sc.u[m2, cand])
                   + sc.a[m2, cand] / kk)
        j = int(np.argmin(theta_p))
        n1 = int(cand[j])
        gain_full = 1.0 / (4.0 * theta_p[j] * sc.L[m2])
        loss_full = 1.0 / (4.0 * theta[m1, n1] * sc.L[m1])
        k_tot, b_tot = k[m1, n1], b[m1, n1]

        if V[m1] - loss_full <= V[m2] + gain_full:
            # Partial transfer: keep fraction f at m1, bisect V_m1(f)=V_m2(1-f).
            base1, base2 = V[m1] - loss_full, V[m2]

            def diff(f):
                th1 = (1.0 / (f * b_tot * sc.gamma[m1, n1])
                       + 1.0 / (f * k_tot * sc.u[m1, n1])
                       + sc.a[m1, n1] / (f * k_tot)) if f > 0 else np.inf
                g = 1.0 - f
                th2 = (1.0 / (g * b_tot * sc.gamma[m2, n1])
                       + 1.0 / (g * k_tot * sc.u[m2, n1])
                       + sc.a[m2, n1] / (g * k_tot)) if g > 0 else np.inf
                v1 = base1 + (1.0 / (4.0 * th1 * sc.L[m1]) if np.isfinite(th1) else 0.0)
                v2 = base2 + (1.0 / (4.0 * th2 * sc.L[m2]) if np.isfinite(th2) else 0.0)
                return v1 - v2

            lo, hi = 0.0, 1.0
            for _ in range(60):
                mid = 0.5 * (lo + hi)
                if diff(mid) > 0:
                    hi = mid
                else:
                    lo = mid
            f = 0.5 * (lo + hi)
            k[m1, n1], b[m1, n1] = f * k_tot, f * b_tot
            k[m2, n1], b[m2, n1] = (1 - f) * k_tot, (1 - f) * b_tot
        else:
            # Full transfer of worker n1's m1 share to m2.
            k[m2, n1], b[m2, n1] = k_tot, b_tot
            k[m1, n1], b[m1, n1] = 0.0, 0.0

        V, theta = V_of(k, b)

    if loads == "comp_exact":
        ksafe = np.maximum(k, 1e-12)
        a_eff = sc.a / ksafe
        u_eff = k * sc.u
        a_eff[:, 0], u_eff[:, 0] = sc.a[:, 0], sc.u[:, 0]
        part = (k > 0)
        part[:, 0] = True
        l, t = comp_dominant_loads(sc.L, a_eff, np.maximum(u_eff, 1e-12),
                                   part)
    else:
        theta = theta_fractional(sc, k, b)
        l, t = fractional_loads(sc.L, theta)
    return Plan(k=k, b=b, l=l, t_per_master=t,
                method=f"fractional-greedy-{loads}")
