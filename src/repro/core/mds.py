"""Real-valued MDS coding for distributed matrix multiplication (paper §II).

The paper encodes A_m row-wise with an (L̃, L) MDS code; the master recovers
A_m x_m from the inner products of **any** L coded rows.  Over the reals a
random Gaussian generator is MDS with probability 1; we default to the
*systematic* variant [I; R] so the fast path (no stragglers) is decode-free.

Shapes:  A (L, S),  G (L̃, L),  Ã = G A (L̃, S),  y = Ã x (L̃,),
recover A x from any L entries of y via the corresponding rows of G.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

__all__ = [
    "make_generator",
    "encode",
    "split_loads",
    "decode",
    "decode_ls",
    "integer_loads",
    "parity_cond",
    "PARITY_COND_LIMIT",
]

#: Redraw threshold for :func:`parity_cond`.  A fresh N(0, 1/L) parity
#: block has singular values in ≈ [1−√(r/L), 1+√(r/L)] w.h.p.
#: (Marchenko–Pastur), so its 2-norm condition sits in the tens; per-scope
#: serving measures decode error ≈ cond · ε_machine per solve (the trunk
#: scope's 2.6e-11 vs the head's 1.2e-12 in BENCH_serve.json is exactly
#: this: many small mixed-row solves whose random square sub-blocks have a
#: fatter conditioning tail than the head's near-complete prefixes).  1e6
#: keeps worst-case decode error ≲ 1e-10 ≪ the 1e-9 per-scope bound the
#: tests assert, while firing only on genuinely degenerate draws.
PARITY_COND_LIMIT = 1e6


def parity_cond(R: np.ndarray) -> float:
    """2-norm condition diagnostic of a parity-generator block.

    ``R`` is an (r, L) block of parity rows (any slice of the generator
    below the identity prefix).  Mixed-row substitution decodes solve
    square minors of ``R``; their conditioning is not cheaply boundable
    minor-by-minor, but a collapsed spectrum of the block itself is the
    necessary symptom of every degenerate minor, so σ_max/σ_min of the
    block is the cheap guard: ``CodedLinear`` redraws any parity chunk
    whose diagnostic exceeds :data:`PARITY_COND_LIMIT` before encoding it.
    Returns +inf for a rank-deficient block.
    """
    R = np.asarray(R, dtype=np.float64)
    if R.size == 0:
        return 1.0
    s = np.linalg.svd(R, compute_uv=False)
    if s[-1] <= 0.0:
        return float("inf")
    return float(s[0] / s[-1])


def make_generator(L: int, L_tilde: int, *, kind: str = "systematic",
                   rng: np.random.Generator | int = 0,
                   dtype=np.float32) -> np.ndarray:
    """Build an (L̃, L) real MDS generator matrix.

    kind="systematic": G = [I; R], R ~ N(0, 1/L) — decode-free when the first
    L rows arrive.  kind="gaussian": fully random (used by property tests).
    """
    if L_tilde < L:
        raise ValueError("L_tilde must be >= L")
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    if kind == "systematic":
        R = rng.normal(0.0, 1.0 / np.sqrt(L), size=(L_tilde - L, L))
        G = np.concatenate([np.eye(L), R], axis=0)
    elif kind == "gaussian":
        G = rng.normal(0.0, 1.0 / np.sqrt(L), size=(L_tilde, L))
    else:
        raise ValueError(f"unknown generator kind {kind!r}")
    return G.astype(dtype)


def encode(G: np.ndarray, A: np.ndarray) -> np.ndarray:
    """Ã = G A  (row-wise MDS encoding)."""
    return G @ A


def integer_loads(l: np.ndarray, L: float) -> np.ndarray:
    """Round real loads to integers, preserving Σl ≥ ceil(required).

    The paper drops integrality (7c); real deployments need integers.  We
    ceil every positive load — the redundancy only grows, recovery is safe.
    """
    l = np.asarray(l, dtype=np.float64)
    return np.where(l > 0, np.ceil(l - 1e-9), 0.0).astype(np.int64)


def split_loads(L_tilde: int, loads: Sequence[int]) -> Tuple[np.ndarray, ...]:
    """Partition row indices 0..L̃-1 into per-node contiguous slices."""
    loads = np.asarray(loads, dtype=np.int64)
    if loads.sum() != L_tilde:
        raise ValueError("loads must sum to L_tilde")
    edges = np.concatenate([[0], np.cumsum(loads)])
    return tuple(np.arange(edges[i], edges[i + 1]) for i in range(len(loads)))


def decode(G: np.ndarray, rows: np.ndarray, y_rows: np.ndarray) -> np.ndarray:
    """Recover A x (or A B) from exactly-L received coded results.

    ``rows`` are the indices of the received coded rows (len == L),
    ``y_rows`` the received results, shape (L,) or (L, C).
    """
    L = G.shape[1]
    rows = np.asarray(rows)
    if rows.size != L:
        raise ValueError(f"decode needs exactly L={L} rows, got {rows.size}")
    Gs = G[rows].astype(np.float64)
    return np.linalg.solve(Gs, np.asarray(y_rows, dtype=np.float64))


def decode_ls(G: np.ndarray, rows: np.ndarray, y_rows: np.ndarray) -> np.ndarray:
    """Least-squares decode from ≥ L received rows (overdetermined: averages
    out numerical noise; the robust path for float32 pipelines)."""
    L = G.shape[1]
    rows = np.asarray(rows)
    if rows.size < L:
        raise ValueError(f"need >= L={L} rows, got {rows.size}")
    Gs = G[rows].astype(np.float64)
    sol, *_ = np.linalg.lstsq(Gs, np.asarray(y_rows, dtype=np.float64), rcond=None)
    return sol
