"""Real-valued MDS coding for distributed matrix multiplication (paper §II).

The paper encodes A_m row-wise with an (L̃, L) MDS code; the master recovers
A_m x_m from the inner products of **any** L coded rows.  Over the reals a
random Gaussian generator is MDS with probability 1; we default to the
*systematic* variant [I; R] so the fast path (no stragglers) is decode-free.

Shapes:  A (L, S),  G (L̃, L),  Ã = G A (L̃, S),  y = Ã x (L̃,),
recover A x from any L entries of y via the corresponding rows of G.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

__all__ = [
    "make_generator",
    "encode",
    "split_loads",
    "decode",
    "decode_ls",
    "integer_loads",
    "parity_cond",
    "PARITY_COND_LIMIT",
    "threefry2x32",
    "parity_counters",
    "counter_gaussian_tile",
    "counter_parity_rows",
    "PARITY_ROW_LIMIT",
    "PARITY_DRAW_LIMIT",
]

#: Redraw threshold for :func:`parity_cond`.  A fresh N(0, 1/L) parity
#: block has singular values in ≈ [1−√(r/L), 1+√(r/L)] w.h.p.
#: (Marchenko–Pastur), so its 2-norm condition sits in the tens; per-scope
#: serving measures decode error ≈ cond · ε_machine per solve (the trunk
#: scope's 2.6e-11 vs the head's 1.2e-12 in BENCH_serve.json is exactly
#: this: many small mixed-row solves whose random square sub-blocks have a
#: fatter conditioning tail than the head's near-complete prefixes).  1e6
#: keeps worst-case decode error ≲ 1e-10 ≪ the 1e-9 per-scope bound the
#: tests assert, while firing only on genuinely degenerate draws.
PARITY_COND_LIMIT = 1e6


def parity_cond(R: np.ndarray) -> float:
    """2-norm condition diagnostic of a parity-generator block.

    ``R`` is an (r, L) block of parity rows (any slice of the generator
    below the identity prefix).  Mixed-row substitution decodes solve
    square minors of ``R``; their conditioning is not cheaply boundable
    minor-by-minor, but a collapsed spectrum of the block itself is the
    necessary symptom of every degenerate minor, so σ_max/σ_min of the
    block is the cheap guard: ``CodedLinear`` redraws any parity chunk
    whose diagnostic exceeds :data:`PARITY_COND_LIMIT` before encoding it.
    Returns +inf for a rank-deficient block.
    """
    R = np.asarray(R, dtype=np.float64)
    if R.size == 0:
        return 1.0
    s = np.linalg.svd(R, compute_uv=False)
    if s[-1] <= 0.0:
        return float("inf")
    return float(s[0] / s[-1])


# ---------------------------------------------------------------------------
# Counter-based parity derivation (virtual parity rows)
# ---------------------------------------------------------------------------

#: parity row index must fit in the low 24 bits of the threefry counter
#: (the high 8 bits carry the conditioning-guard redraw index)
PARITY_ROW_LIMIT = 1 << 24
#: conditioning-guard redraws per block fit in the counter's high byte
PARITY_DRAW_LIMIT = 1 << 8

_TF_ROT = ((13, 15, 26, 6), (17, 29, 16, 24))
_TF_PARITY = 0x1BD11BDA


def threefry2x32(k0, k1, c0, c1):
    """20-round Threefry-2x32 block cipher over uint32 counters.

    ``k0``/``k1`` are uint32 key words, ``c0``/``c1`` broadcastable uint32
    counter arrays.  Returns the two output words.  Written against the
    operator set numpy and jax.numpy share, so the *same* code runs on the
    host (parity replay, decode plans) and inside the Pallas generated-
    parity kernels — bit-equality between the two paths is by construction,
    not by test luck.  All arithmetic wraps mod 2^32 (uint32 dtype).
    """
    u32 = np.uint32          # numpy scalar: both backends absorb it
    x0 = c0 + k0
    x1 = c1 + k1
    ks2 = k0 ^ k1 ^ u32(_TF_PARITY)
    sched = (k1, ks2, k0)      # injected after rounds 4, 8, 12, 16, 20
    for d in range(5):
        for r in _TF_ROT[d % 2]:
            x0 = x0 + x1
            x1 = (x1 << u32(r)) | (x1 >> u32(32 - r))
            x1 = x1 ^ x0
        x0 = x0 + sched[d % 3]
        x1 = x1 + sched[(d + 1) % 3] + u32(d + 1)
    return x0, x1


def parity_counters(row_ids, draws) -> np.ndarray:
    """Pack absolute parity-row ids + redraw indices into uint32 counters.

    ``row_ids`` (n,) int parity-row indices (0-based within the parity
    region, < 2^24); ``draws`` scalar or (n,) conditioning-guard redraw
    index per row (< 256, the high counter byte).  The packed counter is
    the *only* state a parity row needs — a frozen plan carries these
    through packed stages instead of encoded-row indices.
    """
    row_ids = np.asarray(row_ids, dtype=np.int64)
    draws = np.broadcast_to(np.asarray(draws, dtype=np.int64), row_ids.shape)
    if row_ids.size and (row_ids.min() < 0
                         or row_ids.max() >= PARITY_ROW_LIMIT):
        raise ValueError(f"parity row ids must be in [0, {PARITY_ROW_LIMIT})")
    if draws.size and (draws.min() < 0 or draws.max() >= PARITY_DRAW_LIMIT):
        raise ValueError(f"parity redraw index must be < {PARITY_DRAW_LIMIT}")
    return (row_ids | (draws << 24)).astype(np.uint32)


def _uniform24(bits):
    """uint32 → float32 uniform in [0, 1) from the top 24 bits (exact)."""
    return (bits >> np.uint32(8)).astype("float32") * np.float32(2.0 ** -24)


def counter_gaussian_tile(k0, k1, ctrs, cols, scale):
    """One tile of counter-derived parity values — numpy *and* jnp.

    ``ctrs`` (r, 1) packed row counters (:func:`parity_counters`), ``cols``
    (1, c) uint32 column indices, ``scale`` = float32(sqrt(3/L)).  Each
    value draws four 24-bit uniforms through two threefry calls and maps
    them to a zero-mean Gaussian approximant (Irwin–Hall order 4, variance
    1/3 before scaling) — a continuous iid entry distribution, so the MDS
    any-L-rows property holds with probability 1 exactly as for the
    Gaussian draw it replaces, while every arithmetic step (integer ops,
    exact 24-bit-to-float32 conversion, fixed-order float32 adds) is
    bit-reproducible across numpy and the XLA/Pallas backends.
    """
    two = np.uint32(2)
    one = np.uint32(1)
    a0, a1 = threefry2x32(k0, k1, ctrs, cols * two)
    b0, b1 = threefry2x32(k0, k1, ctrs, cols * two + one)
    u = _uniform24
    g = (u(a0) + u(a1)) + (u(b0) + u(b1)) - np.float32(2.0)
    return g * scale


def counter_parity_rows(key, ctrs, L: int, *,
                        dtype=np.float64) -> np.ndarray:
    """Parity generator rows R[ctrs] derived from counters alone (host).

    ``key`` is the per-layer (k0, k1) uint32 pair, ``ctrs`` (n,) packed
    row counters, ``L`` the row width.  Row r is a pure function of
    (key, counter) — independent of any growth history or draw order,
    which is the replay contract virtual parity storage rests on.  Values
    are float32-exact (the kernel twin generates identical bits) returned
    in ``dtype`` for the float64 host decode path.
    """
    k0 = np.uint32(key[0])
    k1 = np.uint32(key[1])
    ctrs = np.asarray(ctrs, dtype=np.uint32)[:, None]
    cols = np.arange(L, dtype=np.uint32)[None, :]
    scale = np.float32(np.sqrt(3.0 / L))
    return counter_gaussian_tile(k0, k1, ctrs, cols, scale).astype(dtype)


def make_generator(L: int, L_tilde: int, *, kind: str = "systematic",
                   rng: np.random.Generator | int = 0,
                   dtype=np.float32) -> np.ndarray:
    """Build an (L̃, L) real MDS generator matrix.

    kind="systematic": G = [I; R], R ~ N(0, 1/L) — decode-free when the first
    L rows arrive.  kind="gaussian": fully random (used by property tests).
    """
    if L_tilde < L:
        raise ValueError("L_tilde must be >= L")
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    if kind == "systematic":
        R = rng.normal(0.0, 1.0 / np.sqrt(L), size=(L_tilde - L, L))
        G = np.concatenate([np.eye(L), R], axis=0)
    elif kind == "gaussian":
        G = rng.normal(0.0, 1.0 / np.sqrt(L), size=(L_tilde, L))
    else:
        raise ValueError(f"unknown generator kind {kind!r}")
    return G.astype(dtype)


def encode(G: np.ndarray, A: np.ndarray) -> np.ndarray:
    """Ã = G A  (row-wise MDS encoding)."""
    return G @ A


def integer_loads(l: np.ndarray, L: float) -> np.ndarray:
    """Round real loads to integers, preserving Σl ≥ ceil(required).

    The paper drops integrality (7c); real deployments need integers.  We
    ceil every positive load — the redundancy only grows, recovery is safe.
    """
    l = np.asarray(l, dtype=np.float64)
    return np.where(l > 0, np.ceil(l - 1e-9), 0.0).astype(np.int64)


def split_loads(L_tilde: int, loads: Sequence[int]) -> Tuple[np.ndarray, ...]:
    """Partition row indices 0..L̃-1 into per-node contiguous slices."""
    loads = np.asarray(loads, dtype=np.int64)
    if loads.sum() != L_tilde:
        raise ValueError("loads must sum to L_tilde")
    edges = np.concatenate([[0], np.cumsum(loads)])
    return tuple(np.arange(edges[i], edges[i + 1]) for i in range(len(loads)))


def decode(G: np.ndarray, rows: np.ndarray, y_rows: np.ndarray) -> np.ndarray:
    """Recover A x (or A B) from exactly-L received coded results.

    ``rows`` are the indices of the received coded rows (len == L),
    ``y_rows`` the received results, shape (L,) or (L, C).
    """
    L = G.shape[1]
    rows = np.asarray(rows)
    if rows.size != L:
        raise ValueError(f"decode needs exactly L={L} rows, got {rows.size}")
    Gs = G[rows].astype(np.float64)
    return np.linalg.solve(Gs, np.asarray(y_rows, dtype=np.float64))


def decode_ls(G: np.ndarray, rows: np.ndarray, y_rows: np.ndarray) -> np.ndarray:
    """Least-squares decode from ≥ L received rows (overdetermined: averages
    out numerical noise; the robust path for float32 pipelines)."""
    L = G.shape[1]
    rows = np.asarray(rows)
    if rows.size < L:
        raise ValueError(f"need >= L={L} rows, got {rows.size}")
    Gs = G[rows].astype(np.float64)
    sol, *_ = np.linalg.lstsq(Gs, np.asarray(y_rows, dtype=np.float64), rcond=None)
    return sol
