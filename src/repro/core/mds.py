"""Real-valued MDS coding for distributed matrix multiplication (paper §II).

The paper encodes A_m row-wise with an (L̃, L) MDS code; the master recovers
A_m x_m from the inner products of **any** L coded rows.  Over the reals a
random Gaussian generator is MDS with probability 1; we default to the
*systematic* variant [I; R] so the fast path (no stragglers) is decode-free.

Shapes:  A (L, S),  G (L̃, L),  Ã = G A (L̃, S),  y = Ã x (L̃,),
recover A x from any L entries of y via the corresponding rows of G.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

__all__ = [
    "make_generator",
    "encode",
    "split_loads",
    "decode",
    "decode_ls",
    "integer_loads",
]


def make_generator(L: int, L_tilde: int, *, kind: str = "systematic",
                   rng: np.random.Generator | int = 0,
                   dtype=np.float32) -> np.ndarray:
    """Build an (L̃, L) real MDS generator matrix.

    kind="systematic": G = [I; R], R ~ N(0, 1/L) — decode-free when the first
    L rows arrive.  kind="gaussian": fully random (used by property tests).
    """
    if L_tilde < L:
        raise ValueError("L_tilde must be >= L")
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    if kind == "systematic":
        R = rng.normal(0.0, 1.0 / np.sqrt(L), size=(L_tilde - L, L))
        G = np.concatenate([np.eye(L), R], axis=0)
    elif kind == "gaussian":
        G = rng.normal(0.0, 1.0 / np.sqrt(L), size=(L_tilde, L))
    else:
        raise ValueError(f"unknown generator kind {kind!r}")
    return G.astype(dtype)


def encode(G: np.ndarray, A: np.ndarray) -> np.ndarray:
    """Ã = G A  (row-wise MDS encoding)."""
    return G @ A


def integer_loads(l: np.ndarray, L: float) -> np.ndarray:
    """Round real loads to integers, preserving Σl ≥ ceil(required).

    The paper drops integrality (7c); real deployments need integers.  We
    ceil every positive load — the redundancy only grows, recovery is safe.
    """
    l = np.asarray(l, dtype=np.float64)
    return np.where(l > 0, np.ceil(l - 1e-9), 0.0).astype(np.int64)


def split_loads(L_tilde: int, loads: Sequence[int]) -> Tuple[np.ndarray, ...]:
    """Partition row indices 0..L̃-1 into per-node contiguous slices."""
    loads = np.asarray(loads, dtype=np.int64)
    if loads.sum() != L_tilde:
        raise ValueError("loads must sum to L_tilde")
    edges = np.concatenate([[0], np.cumsum(loads)])
    return tuple(np.arange(edges[i], edges[i + 1]) for i in range(len(loads)))


def decode(G: np.ndarray, rows: np.ndarray, y_rows: np.ndarray) -> np.ndarray:
    """Recover A x (or A B) from exactly-L received coded results.

    ``rows`` are the indices of the received coded rows (len == L),
    ``y_rows`` the received results, shape (L,) or (L, C).
    """
    L = G.shape[1]
    rows = np.asarray(rows)
    if rows.size != L:
        raise ValueError(f"decode needs exactly L={L} rows, got {rows.size}")
    Gs = G[rows].astype(np.float64)
    return np.linalg.solve(Gs, np.asarray(y_rows, dtype=np.float64))


def decode_ls(G: np.ndarray, rows: np.ndarray, y_rows: np.ndarray) -> np.ndarray:
    """Least-squares decode from ≥ L received rows (overdetermined: averages
    out numerical noise; the robust path for float32 pipelines)."""
    L = G.shape[1]
    rows = np.asarray(rows)
    if rows.size < L:
        raise ValueError(f"need >= L={L} rows, got {rows.size}")
    Gs = G[rows].astype(np.float64)
    sol, *_ = np.linalg.lstsq(Gs, np.asarray(y_rows, dtype=np.float64), rcond=None)
    return sol
