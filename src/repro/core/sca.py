"""SCA-enhanced load allocation (paper §III-D, Algorithm 3).

The non-convex recovery constraint of P3,

    L_m - E[X_m(t)] <= 0,
    E[X_m(t)] = Σ_n l_n · P[T_n <= t],

has a difference-of-convex structure (paper eq. (20)):

    L_m - E[X_m] = L_m - Σ_{n∈Ω} l_n + h0(l_0,t) + Σ_{n∈Ω} (h+_n - h-_n),

with, for p = max(γ̂, û), q = min(γ̂, û), d = p - q and effective rates
γ̂ = b·γ, û = k·u, â = a/k (dedicated: k = b = 1):

    h+_n(l,t) = p·l·e^{-q(t/l - â)} / d      (convex)
    h-_n(l,t) = q·l·e^{-p(t/l - â)} / d      (convex)
    h0(l,t)   = -l·(1 - e^{-u0(t/l - a0)})   (convex; paper Appendix B)

Linearizing h- at the current point z gives the convex restriction P(z)
(eq. (22)); Algorithm 3 iterates  z ← z + γ_r (w* - z),
γ_{r+1} = γ_r(1 - α γ_r), from the Theorem-1 feasible point.

P(z) is solved exactly by bisection on t; for fixed t the constraint
residual is *separable* in the per-node loads, and each 1-D convex piece is
minimized by golden-section search.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from . import delays
from .allocation import markov_loads
from .problem import Plan, Scenario, theta_dedicated, theta_fractional

__all__ = ["sca_enhance_master", "sca_enhance_plan", "feasible_deadline",
           "kkt_residual"]

_GOLD = 0.5 * (3.0 - np.sqrt(5.0))  # 0.381966...


@dataclasses.dataclass
class _MasterInst:
    """Effective single-master instance: local node + participating workers."""
    L: float
    a0: float
    u0: float
    a_hat: np.ndarray    # (W,) effective shifts of the workers
    p: np.ndarray        # (W,) max(γ̂, û)
    q: np.ndarray        # (W,) min(γ̂, û)

    @property
    def d(self) -> np.ndarray:
        return self.p - self.q


def _build_instance(sc: Scenario, m: int, k: np.ndarray, b: np.ndarray,
                    workers: np.ndarray) -> _MasterInst:
    g_hat = b[m, workers] * sc.gamma[m, workers]
    u_hat = k[m, workers] * sc.u[m, workers]
    a_hat = sc.a[m, workers] / k[m, workers]
    # Perturb the resonant case γ̂ == û (paper handles it by eq. (4); an
    # ε-perturbation keeps the DC decomposition well-defined).
    same = np.isclose(g_hat, u_hat, rtol=1e-9)
    g_hat = np.where(same, g_hat * (1.0 + 1e-6), g_hat)
    return _MasterInst(
        L=float(sc.L[m]), a0=float(sc.a[m, 0]), u0=float(sc.u[m, 0]),
        a_hat=a_hat, p=np.maximum(g_hat, u_hat), q=np.minimum(g_hat, u_hat))


# -- convex pieces and gradients -------------------------------------------

def _h_plus(inst: _MasterInst, l, t):
    l = np.maximum(l, 1e-300)
    return inst.p * l * np.exp(-inst.q * (t / l - inst.a_hat)) / inst.d


def _h_minus(inst: _MasterInst, l, t):
    l = np.maximum(l, 1e-300)
    return inst.q * l * np.exp(-inst.p * (t / l - inst.a_hat)) / inst.d


def _h_minus_grad(inst: _MasterInst, l, t) -> Tuple[np.ndarray, np.ndarray]:
    """(∂h-/∂l, ∂h-/∂t) at (l, t), elementwise over workers."""
    l = np.maximum(l, 1e-300)
    e = np.exp(-inst.p * (t / l - inst.a_hat))
    gl = inst.q / inst.d * e * (1.0 + inst.p * t / l)
    gt = -inst.q * inst.p / inst.d * e
    return gl, gt


def _h0(inst: _MasterInst, l0, t):
    l0 = np.maximum(l0, 1e-300)
    return -l0 * (1.0 - np.exp(-inst.u0 * (t / l0 - inst.a0)))


def _true_EX(inst: _MasterInst, l0, l, t):
    """Exact E[X_m(t)] for the instance (oracle for feasibility checks)."""
    return (-_h0(inst, l0, t)
            + np.sum(l - (_h_plus(inst, l, t) - _h_minus(inst, l, t))))


# -- P(z) subproblem ---------------------------------------------------------

def _golden_min(f, lo: np.ndarray, hi: np.ndarray, iters: int = 52):
    """Vectorised golden-section minimization of elementwise-convex f."""
    lo = lo.astype(np.float64).copy()
    hi = hi.astype(np.float64).copy()
    x1 = lo + _GOLD * (hi - lo)
    x2 = hi - _GOLD * (hi - lo)
    f1, f2 = f(x1), f(x2)
    for _ in range(iters):
        take_left = f1 < f2
        hi = np.where(take_left, x2, hi)
        lo = np.where(take_left, lo, x1)
        x1n = lo + _GOLD * (hi - lo)
        x2n = hi - _GOLD * (hi - lo)
        # recompute both (cheap, keeps the vectorised logic branch-free)
        x1, x2 = x1n, x2n
        f1, f2 = f(x1), f(x2)
    x = 0.5 * (lo + hi)
    return x, f(x)


def _solve_subproblem(inst: _MasterInst, z_l0: float, z_l: np.ndarray,
                      z_t: float, *, bisect_iters: int = 44,
                      l_cap_scale: float = 8.0):
    """Solve P(z): min t s.t. the linearized constraint holds, l >= 0.

    Returns (l0, l, t).  Assumes (z_l0, z_l, z_t) is P3-feasible, hence
    P(z)-feasible (the linearization is exact at z).
    """
    gl, gt = _h_minus_grad(inst, z_l, z_t)
    # Constant of the linearization: -Σ[h-(z) - gl·z_l] - (Σ gt)·(t - z_t)
    c_lin = np.sum(_h_minus(inst, z_l, z_t) - gl * z_l)
    gts = np.sum(gt)
    l_cap = l_cap_scale * inst.L

    def min_residual(t: float):
        """min over l >= 0 of the constraint residual G(l, t)."""
        # local node: minimize h0(l0, t)
        l0, h0v = _golden_min(lambda x: _h0(inst, x, t),
                              np.array([0.0]), np.array([l_cap]))
        # worker nodes: minimize h+(l,t) - (1 + gl)·l
        def psi(l):
            return _h_plus(inst, l, t) - (1.0 + gl) * l
        lw, psiv = _golden_min(psi, np.zeros_like(inst.p),
                               np.full_like(inst.p, l_cap))
        resid = (inst.L + h0v[0] + np.sum(psiv)
                 - c_lin - gts * (t - z_t))
        return resid, float(l0[0]), lw

    # Bisection on t over [0, z_t]; predicate = feasible (residual <= 0).
    t_hi = z_t
    r_hi, l0_hi, lw_hi = min_residual(t_hi)
    if r_hi > 1e-9 * inst.L:
        # z not recognized feasible under numerics; return z unchanged.
        return z_l0, z_l.copy(), z_t
    t_lo = 0.0
    best = (l0_hi, lw_hi, t_hi)
    for _ in range(bisect_iters):
        t_mid = 0.5 * (t_lo + t_hi)
        r, l0m, lwm = min_residual(t_mid)
        if r <= 0.0:
            t_hi = t_mid
            best = (l0m, lwm, t_mid)
        else:
            t_lo = t_mid
    return best[0], best[1], best[2]


# -- Algorithm 3 -------------------------------------------------------------

def sca_enhance_master(sc: Scenario, m: int, k: np.ndarray, b: np.ndarray,
                       l_init: np.ndarray, t_init: float, *,
                       alpha: float = 0.995, gamma0: float = 1.0,
                       max_iters: int = 12, rtol: float = 1e-7,
                       ) -> Tuple[np.ndarray, float]:
    """Run Algorithm 3 for one master.  Returns (l_row, t) with l_row of
    length N+1 (column 0 local)."""
    workers = np.nonzero(l_init[1:] > 0)[0] + 1
    if workers.size == 0:
        return l_init.copy(), t_init
    inst = _build_instance(sc, m, k, b, workers)

    z_l0 = float(l_init[0])
    z_l = l_init[workers].astype(np.float64).copy()
    z_t = float(t_init)

    gam = gamma0
    for _ in range(max_iters):
        w_l0, w_l, w_t = _solve_subproblem(inst, z_l0, z_l, z_t)
        new_l0 = z_l0 + gam * (w_l0 - z_l0)
        new_l = z_l + gam * (w_l - z_l)
        new_t = z_t + gam * (w_t - z_t)
        moved = abs(new_t - z_t) > rtol * max(z_t, 1e-300)
        z_l0, z_l, z_t = new_l0, new_l, new_t
        gam = gam * (1.0 - alpha * gam)
        gam = max(gam, 1e-4)
        if not moved:
            break

    # Tighten t to the exact feasibility boundary at the final loads.
    lo, hi = 0.0, z_t * 2.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if _true_EX(inst, z_l0, z_l, mid) >= inst.L:
            hi = mid
        else:
            lo = mid
    z_t = hi

    out = np.zeros_like(l_init)
    out[0] = z_l0
    out[workers] = z_l
    return out, float(z_t)


def feasible_deadline(sc: Scenario, m: int, k: np.ndarray, b: np.ndarray,
                      l_row: np.ndarray, *, t_hi: Optional[float] = None,
                      iters: int = 60) -> float:
    """Smallest t with E[X_m(t)] >= L_m at *fixed* loads (exact CDFs).

    The online replanner warm-starts Algorithm 3 from the previous plan's
    loads; Algorithm 3 requires a feasible (l, t) pair, so this bisection
    recovers the matching deadline.  Returns inf when Σl < L_m (the loads
    can never recover L_m useful rows)."""
    l_row = np.asarray(l_row, dtype=np.float64)
    if l_row.sum() < float(sc.L[m]) - 1e-9:
        return np.inf

    def ex(t: float) -> float:
        return float(delays.expected_received(
            t, l_row[None, :], k[m][None, :], b[m][None, :],
            sc.a[m][None, :], sc.u[m][None, :], sc.gamma[m][None, :])[0])

    if t_hi is None:
        t_hi = 1.0
        for _ in range(200):
            if ex(t_hi) >= sc.L[m]:
                break
            t_hi *= 2.0
        else:
            return np.inf
    lo, hi = 0.0, float(t_hi)
    if ex(hi) < sc.L[m]:
        return np.inf
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if ex(mid) >= sc.L[m]:
            hi = mid
        else:
            lo = mid
    return hi


def kkt_residual(sc: Scenario, k: np.ndarray, b: np.ndarray,
                 l: np.ndarray, t: np.ndarray) -> float:
    """First-order (KKT) optimality residual of a fractional plan.

    Two stationarity systems govern the planning stack, and the residual is
    the larger normalised violation of the two:

    * **loads** (P3/P4, Theorems 1 & 3): at fixed shares the Markov-bound
      optimum has ``2 l_n θ_n = t`` on every active node and meets the
      recovery bound ``Σ_n l_n (1 - l_n θ_n / t) = L`` with equality.
    * **shares** (P4', Algorithm 4): no fractional transfer of a worker's
      shares to the minimum-value master can improve ``min_m V_m`` — the
      fractional-greedy stopping rule.  The residual term is the best
      achievable normalised improvement from a single full transfer,
      capped by the value headroom of the donating master (transferring
      more than half the V gap would overshoot the min).

    A freshly solved plan scores near zero on both.  The incremental
    repairer (``stream.replan.OnlinePlanner``) records this residual at
    every full solve and falls back to a full re-solve whenever a repaired
    plan's residual exceeds that baseline by ``ReplanPolicy.repair_tol`` —
    an *anchored* criterion: successive repairs may drift, but only until
    the accumulated first-order error crosses the tolerance.

    Vectorised O(M·N); never calls the exact-CDF oracle.
    """
    th = theta_fractional(sc, k, b)
    l = np.asarray(l, dtype=np.float64)
    tt = np.maximum(np.asarray(t, dtype=np.float64), 1e-300)[:, None]
    fin = np.isfinite(th)
    th0 = np.where(fin, th, 0.0)
    active = (l > 0) & fin

    # Load-level stationarity: |2 l θ / t - 1| on active nodes.
    stat = np.where(active, np.abs(2.0 * l * th0 / tt - 1.0), 0.0)
    r_load = float(stat.max()) if stat.size else 0.0

    # Recovery-bound tightness (Markov form): Σ l (1 - lθ/t) = L.
    recv = (l * np.maximum(1.0 - l * th0 / tt, 0.0) * fin).sum(axis=1)
    r_cover = float(np.max(np.abs(recv - sc.L) / np.maximum(sc.L, 1e-300)))

    # Share-level stationarity: best single-transfer gain toward min-V.
    r_share = 0.0
    W = th.shape[1]
    if sc.M >= 2 and W > 1:
        inv = np.where(fin, 1.0 / np.where(fin, th, 1.0), 0.0)
        V = 0.25 * inv.sum(axis=1) / np.maximum(sc.L, 1e-300)
        m2 = int(np.argmin(V))
        kk, bb = k[:, 1:], b[:, 1:]
        held = (kk > 0) & (bb > 0)
        th_p = np.where(
            held,
            1.0 / np.where(held, bb * sc.gamma[m2, 1:][None, :], 1.0)
            + 1.0 / np.where(held, kk * sc.u[m2, 1:][None, :], 1.0)
            + sc.a[m2, 1:][None, :] / np.where(held, kk, 1.0),
            np.inf)
        gain = 0.25 / (th_p * np.maximum(sc.L[m2], 1e-300))  # 0 where inf
        headroom = np.maximum(V[:, None] - V[m2], 0.0)
        gain = np.minimum(gain, 0.5 * headroom)
        gain[m2, :] = 0.0
        r_share = float(gain.max() / np.maximum(V[m2], 1e-300))
    return max(r_load, r_cover, r_share)


def sca_enhance_plan(sc: Scenario, plan: Plan, *, alpha: float = 0.995,
                     max_iters: int = 60,
                     warm_l: Optional[np.ndarray] = None) -> Plan:
    """Apply Algorithm 3 to every master of a plan (dedicated or fractional).

    Fractional plans are handled by the paper's remark at the end of §IV-B:
    substitute γ → bγ, u → ku, a → a/k inside the DC pieces (done by
    ``_build_instance``).

    ``warm_l`` (optional, (M, N+1)) warm-starts each master's SCA iteration
    from previous loads instead of the plan's Theorem-1/3 point — the online
    replanner passes the previous plan here so few SCA iterations suffice
    when the worker pool changed only slightly.  Warm rows that put load on
    nodes the plan assigns no resources to, or whose total cannot cover
    L_m, fall back to the plan's own loads.
    """
    l_new = plan.l.copy()
    t_new = plan.t_per_master.copy()
    for m in range(sc.M):
        l_init, t_init = plan.l[m], float(plan.t_per_master[m])
        if warm_l is not None:
            cand = np.where((plan.k[m] > 0) & (plan.b[m] > 0), warm_l[m], 0.0)
            cand[0] = warm_l[m][0]
            t_cand = feasible_deadline(sc, m, plan.k, plan.b, cand)
            if np.isfinite(t_cand) and t_cand <= t_init:
                l_init, t_init = cand, t_cand
        l_row, t_m = sca_enhance_master(
            sc, m, plan.k, plan.b, l_init, t_init,
            alpha=alpha, max_iters=max_iters)
        if t_m <= t_new[m]:
            l_new[m] = l_row
            t_new[m] = t_m
    return Plan(k=plan.k.copy(), b=plan.b.copy(), l=l_new,
                t_per_master=t_new, method=plan.method + "+sca")
