"""Benchmark policies from the paper's §V-B.

1. Uncoded computation with uniform worker assignment — each master gets
   ⌊N/M⌋ workers (contiguous blocks; remainder spread round-robin), A_m is
   split *evenly and without coding*, so the master must wait for **all** of
   its workers (no local compute, no redundancy).
2. Coded computation with uniform worker assignment — same worker split, but
   MDS-coded loads from Theorem 2 (the single-master scheme of [5], which
   ignores communication delay).
3. Near-optimal fractional benchmark — the paper brute-forces (k, b) on a
   0.01 grid for the 2×5 scenario.  A raw 0.01 grid over all 2·M·N fractions
   is ~1e10 points even there, so we implement the practical equivalent:
   multi-start coordinate ascent on the true max-min objective, sweeping each
   worker's (κ, β) split on the same 0.01 grid until a fixed point — followed
   by the same SCA load enhancement the paper applies.  On the small scenario
   this matches/beats Algorithm 4 everywhere we checked, which is the role
   the "optimal" curve plays in Fig. 4(a).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .allocation import comp_dominant_loads, fractional_loads, markov_loads
from .problem import Plan, Scenario, theta_dedicated, theta_fractional

__all__ = [
    "uniform_assignment",
    "uncoded_uniform",
    "coded_uniform",
    "near_optimal_fractional",
]


def uniform_assignment(sc: Scenario) -> np.ndarray:
    """Contiguous equal split of workers across masters → k (M, N+1)."""
    k = np.zeros((sc.M, sc.N + 1))
    k[:, 0] = 1.0
    owners = np.array([m % sc.M for m in range(sc.N)])
    owners = np.sort(owners)  # contiguous blocks, remainder round-robin
    for n, m in enumerate(owners):
        k[m, n + 1] = 1.0
    return k


def uncoded_uniform(sc: Scenario) -> Plan:
    """Benchmark 1: equal uncoded partition; needs *all* workers to finish.

    The predicted t_per_master is the expected max of the workers' delays
    (computed by the simulator; here we store the Markov point estimate of a
    single worker as a placeholder — empirical delay is what the paper
    plots)."""
    k = uniform_assignment(sc)
    l = np.zeros_like(k)
    for m in range(sc.M):
        w = np.nonzero(k[m, 1:] > 0)[0] + 1
        if w.size:
            l[m, w] = sc.L[m] / w.size
    theta = theta_dedicated(sc, k)
    # crude deterministic estimate: slowest worker's expected finish time;
    # a master with no workers at all (tiny pools) cannot finish uncoded.
    with np.errstate(invalid="ignore"):
        vals = np.where(l > 0, l * theta, -np.inf).max(axis=1)
    est = np.where((l > 0).any(axis=1), vals, np.inf)
    return Plan(k=k, b=k.copy(), l=l, t_per_master=est, method="uncoded-uniform")


def coded_uniform(sc: Scenario) -> Plan:
    """Benchmark 2: uniform assignment + Theorem-2 loads (scheme of [5])."""
    k = uniform_assignment(sc)
    part = k.copy()
    part[:, 0] = 1.0
    l, t = comp_dominant_loads(sc.L, sc.a, sc.u, part)
    return Plan(k=k, b=k.copy(), l=l, t_per_master=t, method="coded-uniform")


# ---------------------------------------------------------------------------
# Near-optimal fractional benchmark (paper's brute-force curve)
# ---------------------------------------------------------------------------

def _minV(sc: Scenario, k: np.ndarray, b: np.ndarray) -> float:
    theta = theta_fractional(sc, k, b)
    inv = np.where(np.isfinite(theta), 1.0 / theta, 0.0)
    V = 0.25 * inv.sum(axis=1) / sc.L
    return float(np.min(V))


def near_optimal_fractional(sc: Scenario, step: float = 0.01,
                            restarts: int = 8, max_sweeps: int = 50,
                            rng: np.random.Generator | int = 0) -> Plan:
    """Multi-start coordinate-ascent grid search on max-min V (paper's
    brute-force benchmark, small scenarios only)."""
    rng = np.random.default_rng(rng) if not isinstance(rng, np.random.Generator) else rng
    if sc.M != 2:
        raise NotImplementedError("the paper's brute-force benchmark is M=2 only")
    grid = np.round(np.arange(0.0, 1.0 + step / 2, step), 10)

    best_kb: Optional[Tuple[np.ndarray, np.ndarray]] = None
    best_val = -np.inf
    for r in range(restarts):
        if r == 0:
            kappa = np.full(sc.N, 0.5)
            beta = np.full(sc.N, 0.5)
        else:
            kappa = rng.choice(grid, size=sc.N)
            beta = rng.choice(grid, size=sc.N)

        def kb_of(kpa, bta):
            k = np.zeros((2, sc.N + 1))
            b = np.zeros((2, sc.N + 1))
            k[:, 0] = b[:, 0] = 1.0
            k[0, 1:], k[1, 1:] = kpa, 1.0 - kpa
            b[0, 1:], b[1, 1:] = bta, 1.0 - bta
            return k, b

        cur = _minV(sc, *kb_of(kappa, beta))
        for _ in range(max_sweeps):
            improved = False
            for n in range(sc.N):
                # joint sweep of (κ_n, β_n) over the grid
                vals = np.empty((grid.size, grid.size))
                for i, kv in enumerate(grid):
                    kappa_n = kappa.copy(); kappa_n[n] = kv
                    for j, bv in enumerate(grid):
                        beta_n = beta.copy(); beta_n[n] = bv
                        vals[i, j] = _minV(sc, *kb_of(kappa_n, beta_n))
                i, j = np.unravel_index(np.argmax(vals), vals.shape)
                if vals[i, j] > cur + 1e-12:
                    kappa[n], beta[n] = grid[i], grid[j]
                    cur = vals[i, j]
                    improved = True
            if not improved:
                break
        if cur > best_val:
            best_val = cur
            best_kb = kb_of(kappa, beta)

    k, b = best_kb
    theta = theta_fractional(sc, k, b)
    l, t = fractional_loads(sc.L, theta)
    return Plan(k=k, b=b, l=l, t_per_master=t, method="bruteforce-fractional")
