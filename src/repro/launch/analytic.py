"""Analytic roofline estimator — trip-count-correct FLOPs / HBM bytes / ICI
collective bytes per device for every (arch × cell × mesh) combination.

Why this exists: XLA's ``cost_analysis()`` counts each ``while``-loop body
**once** (no trip-count multiplication), so any scan-based model (layer scan,
microbatch scan, flash-attention KV scan, SSM scan) under-reports FLOPs by
the product of trip counts.  The dry-run keeps cost_analysis for
cross-checking, and uses these closed-form counts for the §Roofline terms.
``tests/test_analytic.py`` validates the estimator against cost_analysis on
small *fully-unrolled* configs (within tolerance), which pins the formulas
to the compiled truth.

Conventions: everything is *per device*; the model axis (TP) and data axes
(DP) divide work evenly (KV-head replication under-division is ignored —
<2% on these configs).  bf16 activations/weights, fp32 accumulators.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from ..models import ArchConfig, ShapeCell
from ..models.config import LayerSpec, MambaConfig
from ..models.moe import moe_capacity

__all__ = ["AnalyticCosts", "estimate", "MeshDesc"]

BF16 = 2
F32 = 4


@dataclasses.dataclass(frozen=True)
class MeshDesc:
    dp: int                     # product of data axes (pod × data)
    tp: int                     # model axis

    @property
    def chips(self) -> int:
        return self.dp * self.tp


@dataclasses.dataclass
class AnalyticCosts:
    flops: float                # per device
    hbm_bytes: float            # per device
    ici_bytes: float            # per device
    breakdown: Dict[str, float]

    def terms(self, peak=197e12, hbm=819e9, ici=50e9) -> Dict[str, float]:
        return {"compute": self.flops / peak,
                "memory": self.hbm_bytes / hbm,
                "collective": self.ici_bytes / ici}


def _layer_list(cfg: ArchConfig):
    layers = list(cfg.prefix)
    layers += list(cfg.block) * cfg.n_repeats
    return layers


def _attn_matmul_flops(cfg: ArchConfig, D: float, T_ctx: float,
                       spec: LayerSpec, decode: bool) -> Tuple[float, float]:
    """(projection flops, score/value flops) for D query tokens with average
    context T_ctx."""
    d = cfg.d_model
    if cfg.mla is not None:
        m = cfg.mla
        H = cfg.n_heads
        qk = m.nope_dim + m.rope_dim
        proj = 2 * D * (d * m.q_lora + m.q_lora * H * qk
                        + d * (m.kv_lora + m.rope_dim))
        if decode:
            # absorbed: q→latent per head, scores/values over latent cache
            proj += 2 * D * H * (m.nope_dim * m.kv_lora + m.kv_lora * m.v_dim)
            sv = 2 * D * H * T_ctx * (m.kv_lora + m.rope_dim + m.kv_lora)
        else:
            proj += 2 * D * m.kv_lora * H * (m.nope_dim + m.v_dim)
            sv = 2 * D * H * T_ctx * (qk + m.v_dim)
        proj += 2 * D * H * m.v_dim * d
        return proj, sv
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    proj = 2 * D * (d * Hq * Dh + 2 * d * Hkv * Dh + Hq * Dh * d)
    sv = 2 * D * Hq * T_ctx * 2 * Dh
    return proj, sv


def _ctx_len(cell: ShapeCell, spec: LayerSpec) -> float:
    """Average context length per query token."""
    if cell.kind == "decode":
        S = cell.seq_len
        return min(S, spec.sliding_window) if spec.sliding_window else S
    T = cell.seq_len
    if spec.sliding_window:
        return min(spec.sliding_window, T)
    return (T + 1) / 2.0                      # causal average


def _layer_fwd_flops(cfg: ArchConfig, spec: LayerSpec, D: float,
                     cell: ShapeCell) -> float:
    d = cfg.d_model
    decode = cell.kind == "decode"
    f = 0.0
    if spec.mixer == "attn":
        proj, sv = _attn_matmul_flops(cfg, D, _ctx_len(cell, spec), spec,
                                      decode)
        f += proj + sv
    elif spec.mixer == "mamba":
        mc = cfg.mamba or MambaConfig()
        di = mc.expand * d
        f += 2 * D * (2 * d * di + di * d)                  # in/out proj
        f += 2 * D * di * mc.d_conv                         # conv
        f += 2 * D * di * (2 * mc.d_state + 1)              # B,C,dt proj
        f += 6 * D * di * mc.d_state                        # scan update+mix
    elif spec.mixer == "rwkv":
        hs = cfg.rwkv_head_size
        C = 64.0 if not decode else 1.0                      # chunk length
        f += 2 * D * 5 * d * d                               # r,k,v,g,o
        f += 2 * D * (d * 64 + 64 * d)                       # decay lora
        if decode:
            f += 4 * D * d * hs                              # state update
        else:
            f += 2 * D * C * d * 2                           # intra-chunk P,PV
            f += 6 * D * d * hs                              # carry + state
    if spec.ffn == "moe":
        m = cfg.moe
        routed_tokens = D * m.top_k * m.capacity_factor
        f += 2 * D * d * m.num_experts                       # router
        f += 2 * routed_tokens * 3 * d * m.d_expert          # experts (SwiGLU)
        f += 2 * D * 3 * d * (m.n_shared * m.d_expert)       # shared experts
    elif spec.mixer == "rwkv":
        f += 2 * D * (d * cfg.d_ff + cfg.d_ff * d + d * d)   # cmix (k,v,r)
    elif spec.ffn == "swiglu":
        f += 2 * D * 3 * d * cfg.d_ff
    else:
        f += 2 * D * 2 * d * cfg.d_ff
    return f


def _cross_attn_flops(cfg: ArchConfig, D: float, T_enc: float) -> float:
    d, H, Dh = cfg.d_model, cfg.n_heads, cfg.d_head
    proj = 2 * D * (d * H * Dh + H * Dh * d) + 2 * (T_enc) * 2 * d * H * Dh
    sv = 2 * D * H * T_enc * 2 * Dh
    return proj + sv


def expert_param_count(cfg: ArchConfig) -> int:
    """Parameters held in routed-expert weights (the full-mesh-EP target)."""
    if cfg.moe is None:
        return 0
    m = cfg.moe
    n_moe = sum(1 for s in _layer_list(cfg) if s.ffn == "moe")
    return n_moe * 3 * cfg.d_model * m.d_expert * m.num_experts


def estimate(cfg: ArchConfig, cell: ShapeCell, mesh: MeshDesc, *,
             n_micro: int = 1, fsdp: bool = True,
             remat: bool = True, ep_full: bool = False,
             acc_dtype: str = "float32",
             remat_policy: str = "full",
             a2a_fp8: bool = False) -> AnalyticCosts:
    # remat_policy "dots": matmul outputs saved — the bwd pass re-runs only
    # elementwise ops, so weight re-gathers and MoE dispatch drop from 3
    # events (fwd + bwd + remat-recompute) to 2, and the recompute FLOPs
    # shrink from ~1 extra fwd to ~0.3.
    B, T = cell.global_batch, cell.seq_len
    kind = cell.kind
    d, V = cfg.d_model, cfg.vocab
    P = cfg.param_count()
    chips = mesh.chips

    if kind == "train":
        D = float(B) * T                    # query tokens per step
    elif kind == "prefill":
        D = float(B) * T
    else:
        D = float(B)                        # one token per sequence

    if cfg.frontend == "vision" and kind != "decode":
        D = float(B) * (T - cfg.frontend_len) + float(B) * cfg.frontend_len
        # (text + patch positions both flow through the trunk)

    # ---- forward FLOPs (whole system) -----------------------------------
    fwd = 0.0
    br: Dict[str, float] = {}
    for spec in _layer_list(cfg):
        fwd += _layer_fwd_flops(cfg, spec, D, cell)
    if cfg.enc_dec:
        D_enc = float(B) * cfg.frontend_len
        enc_cell = dataclasses.replace(cell, kind="prefill",
                                       seq_len=cfg.frontend_len)
        for spec in list(cfg.enc_block) * cfg.n_enc_repeats:
            fwd += _layer_fwd_flops(cfg, spec, D_enc, enc_cell)
        fwd += len(_layer_list(cfg)) * _cross_attn_flops(cfg, D, cfg.frontend_len)
    # logits (+MTP)
    fwd += 2 * D * d * V * (2 if cfg.mtp and kind == "train" else 1)
    if cfg.mtp and kind == "train":
        fwd += 2 * D * (2 * d) * d

    n_events = 2 if remat_policy == "dots" else (3 if remat else 2)
    if kind == "train":
        remat_extra = 0.3 if remat_policy == "dots" else (1.0 if remat else 0.0)
        total_flops = fwd * (3.0 + remat_extra)
    else:
        total_flops = fwd
    flops_dev = total_flops / chips
    br["flops_fwd_global"] = fwd

    # ---- HBM bytes per device -------------------------------------------
    # with full-mesh EP the expert weights never leave their home shard
    P_ep = expert_param_count(cfg) if ep_full else 0
    P_gath = P - P_ep                     # weights that FSDP gathers
    acc_bytes = F32 if acc_dtype == "float32" else BF16
    P_dev = P * BF16 / chips if fsdp else P * BF16 / mesh.tp
    act_unit = (D / mesh.dp) * d * BF16          # one activation tensor/device
    n_layers = len(_layer_list(cfg)) + (cfg.n_enc_repeats
                                        * len(cfg.enc_block) if cfg.enc_dec else 0)
    hbm = 0.0
    if kind == "train":
        # weights: gather-write + read, fwd + bwd (+ remat re-run), per micro
        w_events = n_events
        hbm += (n_micro * w_events * 2 * (P_gath * BF16 / mesh.tp)
                + n_micro * w_events * 2 * P_ep * BF16 / chips) \
            if fsdp else n_micro * w_events * P_dev
        # optimizer: read p,m,v + write p,m,v (bf16 states) + grad acc rw
        hbm += 6 * P * BF16 / chips + 2 * P * acc_bytes / chips
        # activations: ~18 tensor read/writes per layer fwd, ×3 with bwd+remat
        hbm += n_layers * 18 * 3 * act_unit
        # logits fp32 softmax (+bwd)
        hbm += 3 * (D / mesh.dp) * (V / mesh.tp) * F32
        br["hbm_weights"] = n_micro * 3 * 2 * P_gath * BF16 / mesh.tp
        br["hbm_opt"] = 6 * P * BF16 / chips + 2 * P * acc_bytes / chips
        br["hbm_acts"] = n_layers * 18 * 3 * act_unit
    else:
        hbm += 2 * P_dev if fsdp else P_dev     # stream weights once
        hbm += n_layers * 12 * act_unit
        hbm += (D / mesh.dp) * (V / mesh.tp) * BF16
        if kind == "decode":
            hbm += _kv_cache_bytes(cfg, cell) / chips   # read the cache
            br["hbm_kv_cache"] = _kv_cache_bytes(cfg, cell) / chips

    # ---- ICI collective bytes per device ---------------------------------
    ici = 0.0
    if kind == "train":
        if fsdp:
            gather_events = n_events * n_micro
            ici += gather_events * (P_gath * BF16 / mesh.tp) \
                * (mesh.dp - 1) / mesh.dp
            br["ici_fsdp_gather"] = gather_events * (P_gath * BF16 / mesh.tp)
        # grad reduce-scatter once per micro (the accumulator is sharded);
        # full-EP expert grads are already fully sharded — no DP reduction
        ici += n_micro * (P_gath * BF16 / mesh.tp) * (mesh.dp - 1) / mesh.dp
        # TP all-reduces: 2 per layer, fwd+bwd(+remat) (ring ⇒ 2× payload);
        # act_unit already covers the *whole* step's tokens, so the microbatch
        # factor cancels (n_micro × tokens/n_micro).
        tp_events = 2 * n_layers * n_events
        ici += tp_events * 2 * act_unit * (mesh.tp - 1) / mesh.tp
        br["ici_tp_allreduce"] = tp_events * 2 * act_unit
    else:
        tp_events = 2 * n_layers
        ici += tp_events * 2 * act_unit * (mesh.tp - 1) / mesh.tp
    # MoE all-to-alls
    if cfg.moe is not None:
        n_moe = sum(1 for s in _layer_list(cfg) if s.ffn == "moe")
        tok_dev = D / mesh.dp
        dir_bytes = (0.5 + 1.0) if a2a_fp8 else 2.0   # dispatch + return
        a2a = dir_bytes * min(cfg.moe.top_k * cfg.moe.capacity_factor,
                              mesh.tp) * tok_dev * d * BF16
        events = n_events if kind == "train" else 1
        ici += n_moe * events * a2a
        br["ici_moe_a2a"] = n_moe * events * a2a
    # vocab-psum for the sharded embed (psum of (D/dp, d) per micro)
    ici += (3 if kind == "train" else 1) * 2 * act_unit

    return AnalyticCosts(flops=flops_dev, hbm_bytes=hbm, ici_bytes=ici,
                         breakdown=br)


def _kv_cache_bytes(cfg: ArchConfig, cell: ShapeCell) -> float:
    B, S = cell.global_batch, cell.seq_len
    total = 0.0
    for spec in _layer_list(cfg):
        if spec.mixer == "attn":
            if cfg.mla is not None:
                total += B * S * (cfg.mla.kv_lora + cfg.mla.rope_dim) * BF16
            else:
                w = min(S, spec.sliding_window) if spec.sliding_window else S
                total += B * w * 2 * cfg.n_kv_heads * cfg.d_head * BF16
        elif spec.mixer == "mamba":
            mc = cfg.mamba or MambaConfig()
            total += B * mc.expand * cfg.d_model * mc.d_state * F32
        elif spec.mixer == "rwkv":
            total += B * cfg.d_model * cfg.rwkv_head_size * F32
    return total
