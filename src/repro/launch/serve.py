"""Serving launcher: batched prefill + decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 16 --prompt-len 32 --gen-len 24

Runs a small request pool through prefill → token-by-token decode with a
shared jitted decode step and per-request completion, reporting throughput
and verifying the decode path against the full forward pass.

With ``--coded`` the same model is served through the coded-computation
bridge (:mod:`repro.serve_coded`): per ``--coding-scope`` the output-head
matmul (``head``), the FFN up/down projections too (``ffn``), or the whole
trunk including attention q/k/v/o (``trunk``) of every token batch is
MDS-encoded and executed as per-worker shards scheduled by the
``StreamingExecutor`` plan, with ``--policy fifo|edf|fair`` picking the
admission policy and ``--steps-per-dispatch`` batching several decode
tokens per admission:

    PYTHONPATH=src python -m repro.launch.serve --coded --policy edf \
        --coding-scope trunk --requests 12 --gen-len 8

The building blocks (``build_model`` / ``serving_fns`` / ``zero_caches`` /
``head_matrix``) are shared with the bridge so both paths serve the exact
same model.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

__all__ = ["build_model", "serving_fns", "zero_caches", "head_matrix",
           "main"]


_MODEL_CACHE: dict = {}


def build_model(arch: str, *, smoke: bool = True, seed: int = 0):
    """Config + initialised parameters for ``arch`` (smoke-sized or full).

    Memoised per (arch, smoke, seed): init is deterministic and params are
    treated as read-only everywhere, so repeated bridge/test construction
    shares one copy instead of re-initialising the model."""
    key = (arch, bool(smoke), int(seed))
    if key not in _MODEL_CACHE:
        import jax
        from repro.configs import get_config, get_smoke_config
        from repro.models import init_model
        cfg = get_smoke_config(arch) if smoke else get_config(arch)
        params = init_model(jax.random.PRNGKey(seed), cfg)
        _MODEL_CACHE[key] = (cfg, params)
    return _MODEL_CACHE[key]


def serving_fns(cfg, *, return_hidden: bool = False):
    """Jitted (prefill_fn, decode_fn) closures over ``cfg``.

    ``return_hidden`` threads the final-norm hidden states out of both —
    the input the coded output head distributes across workers.  Memoised
    per (cfg, return_hidden): ArchConfig is a frozen dataclass, so repeated
    bridge construction reuses the compiled functions instead of
    re-tracing."""
    key = (cfg, bool(return_hidden))
    if key not in _FNS_CACHE:
        import jax
        from repro.models import decode_step, prefill
        prefill_fn = jax.jit(lambda p, b, c: prefill(
            p, b, c, cfg=cfg, return_hidden=return_hidden))
        decode_fn = jax.jit(lambda p, t, pos, c: decode_step(
            p, t, pos, c, cfg=cfg, return_hidden=return_hidden))
        _FNS_CACHE[key] = (prefill_fn, decode_fn)
    return _FNS_CACHE[key]


_FNS_CACHE: dict = {}


def zero_caches(cfg, batch: int, max_len: int):
    """Zero-initialised decode caches for ``batch`` slots."""
    import jax
    import jax.numpy as jnp
    from repro.models import init_cache_shapes
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache_shapes(cfg, batch, max_len))


def head_matrix(cfg, params) -> np.ndarray:
    """The output-head weight W (padded_vocab, d_model) as float64.

    ``logits = hidden @ W.T`` — exactly the paper's A·x task per request,
    with L = padded_vocab useful rows."""
    if cfg.tie_embeddings:
        W = np.asarray(params["embed"]["tok"])
    else:
        W = np.asarray(params["embed"]["out"]).T
    return W.astype(np.float64)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--coded", action="store_true",
                    help="serve through the coded-computation bridge "
                         "(StreamingExecutor-planned shards)")
    ap.add_argument("--policy", default="edf",
                    choices=("fifo", "edf", "fair"),
                    help="admission policy for --coded serving")
    ap.add_argument("--coding-scope", default="head",
                    choices=("head", "ffn", "trunk"),
                    help="which matmuls run coded: the output head only, "
                         "+FFN up/down, or the full trunk incl. attention "
                         "q/k/v/o (--coded serving)")
    ap.add_argument("--steps-per-dispatch", type=int, default=1,
                    help="decode tokens generated per coded admission "
                         "(--coded serving)")
    ap.add_argument("--execution", default="batched",
                    choices=("serial", "batched"),
                    help="shard-execution engine: packed per-stage passes "
                         "(batched) or the shard-by-shard reference "
                         "(serial) (--coded serving)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record per-step spans (plan/pack/kernel/decode "
                         "stages, sim deliveries, cache counters) and "
                         "write a Chrome/Perfetto trace here "
                         "(--coded serving)")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="arm the chaos layer: comma-separated fault "
                         "spec, e.g. 'corrupt=0.25,kind=sign_flip,"
                         "crash=0.05,retries=4,seed=5' — injected faults "
                         "are detected, localised and recovered during "
                         "the serve; 'none' = zero rates with detection "
                         "armed (--coded serving)")
    ap.add_argument("--ls-tail", action="store_true",
                    help="route every coded decode through the "
                         "stacked-LS tail (bit-identical at exactly L "
                         "rows) (--coded serving)")
    args = ap.parse_args(argv)

    if args.coded:
        from repro.serve_coded import run_coded_smoke
        return run_coded_smoke(arch=args.arch, smoke=args.smoke,
                               policies=(args.policy,),
                               n_requests=args.requests,
                               prompt_len=args.prompt_len,
                               gen_len=args.gen_len, seed=args.seed,
                               coding_scope=args.coding_scope,
                               steps_per_dispatch=args.steps_per_dispatch,
                               execution=args.execution,
                               trace=args.trace, faults=args.faults,
                               ls_tail=args.ls_tail)

    import jax
    import jax.numpy as jnp

    cfg, params = build_model(args.arch, smoke=args.smoke, seed=args.seed)
    B, P, G = args.requests, args.prompt_len, args.gen_len
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, P)), jnp.int32)

    batch = {"tokens": prompts}
    if cfg.enc_dec:
        batch["enc_feats"] = jnp.full((B, cfg.frontend_len, cfg.frontend_dim),
                                      0.1, jnp.float32)

    caches = zero_caches(cfg, B, P + G + 8)
    prefill_fn, decode_fn = serving_fns(cfg)

    t0 = time.time()
    logits, caches = prefill_fn(params, batch, caches)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t1 = time.time()
    for i in range(G - 1):
        pos = jnp.full((B,), P + i, jnp.int32)
        logits, caches = decode_fn(params, tok, pos, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[serve] {B} requests, prompt {P}, generated {gen.shape[1]} toks")
    print(f"[serve] prefill {t_prefill*1e3:.0f}ms  decode "
          f"{t_decode*1e3:.0f}ms  ({B*(G-1)/max(t_decode,1e-9):.0f} tok/s)")
    assert not np.any(np.isnan(gen)), "NaN tokens"
    print(f"[serve] sample continuation: {gen[0][:12].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
