"""Serving launcher: batched prefill + decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 16 --prompt-len 32 --gen-len 24

Runs a small request pool through prefill → token-by-token decode with a
shared jitted decode step and per-request completion, reporting throughput
and verifying the decode path against the full forward pass.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config, get_config
    from repro.models import (decode_step, init_cache_shapes, init_model,
                              prefill)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    B, P, G = args.requests, args.prompt_len, args.gen_len
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, P)), jnp.int32)

    batch = {"tokens": prompts}
    if cfg.enc_dec:
        batch["enc_feats"] = jnp.full((B, cfg.frontend_len, cfg.frontend_dim),
                                      0.1, jnp.float32)

    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          init_cache_shapes(cfg, B, P + G + 8))

    prefill_fn = jax.jit(lambda p, b, c: prefill(p, b, c, cfg=cfg))
    decode_fn = jax.jit(lambda p, t, pos, c: decode_step(p, t, pos, c,
                                                         cfg=cfg))

    t0 = time.time()
    logits, caches = prefill_fn(params, batch, caches)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t1 = time.time()
    for i in range(G - 1):
        pos = jnp.full((B,), P + i, jnp.int32)
        logits, caches = decode_fn(params, tok, pos, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[serve] {B} requests, prompt {P}, generated {gen.shape[1]} toks")
    print(f"[serve] prefill {t_prefill*1e3:.0f}ms  decode "
          f"{t_decode*1e3:.0f}ms  ({B*(G-1)/max(t_decode,1e-9):.0f} tok/s)")
    assert not np.any(np.isnan(gen)), "NaN tokens"
    print(f"[serve] sample continuation: {gen[0][:12].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
