"""Production mesh construction.

A *function*, not a module-level constant — importing this module must never
touch jax device state (the dry-run pins the device count before first use).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 chips per pod ("data","model"); 2 pods adds a leading "pod"
    axis.  v5e-256 pod topology; DCN spans the "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Whatever devices exist, as (data, model) — for tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
