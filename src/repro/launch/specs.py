"""input_specs(): ShapeDtypeStruct stand-ins for every model input of every
(arch × shape) cell — weak-type-correct, shardable, zero allocation.

Modality frontends are stubs per the brief: ``enc_feats`` (audio frames) and
``patch_feats`` (vision patches) arrive as precomputed embeddings.  For the
VLM the text length is reduced so patches + text == the cell's seq_len.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import ArchConfig, ShapeCell, init_cache_shapes
from ..parallel.sharding import batch_sharding, cache_shardings, data_axes_of

__all__ = ["input_specs", "input_shardings", "microbatches_for"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Model inputs for one cell.  Keys depend on cell.kind:

    train:   tokens, labels (+ modality feats)
    prefill: tokens (+ modality feats), caches
    decode:  tokens (B,1), pos (B,), caches (+ enc_out for enc-dec)
    """
    B, T = cell.global_batch, cell.seq_len
    out: Dict[str, Any] = {}
    text_T = T
    if cfg.frontend == "vision":
        text_T = T - cfg.frontend_len
    dt = jnp.dtype(cfg.dtype)

    if cell.kind == "train":
        out["tokens"] = _sds((B, text_T), jnp.int32)
        out["labels"] = _sds((B, text_T), jnp.int32)
        if cfg.enc_dec:
            out["enc_feats"] = _sds((B, cfg.frontend_len, cfg.frontend_dim), dt)
        if cfg.frontend == "vision":
            out["patch_feats"] = _sds((B, cfg.frontend_len, cfg.frontend_dim), dt)
    elif cell.kind == "prefill":
        out["tokens"] = _sds((B, text_T), jnp.int32)
        if cfg.enc_dec:
            out["enc_feats"] = _sds((B, cfg.frontend_len, cfg.frontend_dim), dt)
        if cfg.frontend == "vision":
            out["patch_feats"] = _sds((B, cfg.frontend_len, cfg.frontend_dim), dt)
        out["caches"] = init_cache_shapes(cfg, B, T)
    elif cell.kind == "decode":
        out["tokens"] = _sds((B, 1), jnp.int32)
        out["pos"] = _sds((B,), jnp.int32)
        out["caches"] = init_cache_shapes(cfg, B, T)
        if cfg.enc_dec:
            out["enc_out"] = _sds((B, cfg.frontend_len, cfg.d_model), dt)
    else:
        raise ValueError(cell.kind)
    return out


def input_shardings(specs: Dict[str, Any], mesh: Mesh, cell: ShapeCell,
                    ) -> Dict[str, Any]:
    """NamedSharding tree matching ``input_specs`` output."""
    B = cell.global_batch
    out: Dict[str, Any] = {}
    for k, v in specs.items():
        if k == "caches":
            out[k] = cache_shardings(v, mesh, B)
        else:
            out[k] = batch_sharding(mesh, v.shape)
    return out


# Per-arch microbatch counts for the train cells (memory-term lever; the
# global batch must stay divisible by dp × n_micro).
_BIG = {"deepseek-v3-671b", "jamba-1.5-large-398b", "dbrx-132b"}


def microbatches_for(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh,
                     override: Optional[int] = None) -> int:
    if cell.kind != "train":
        return 1
    if override is not None:
        return override
    daxes = data_axes_of(mesh)
    dp = int(np.prod([mesh.shape[a] for a in daxes]))
    cap = max(1, cell.global_batch // dp)      # ≥1 sequence per shard
    want = 16 if cfg.name in _BIG else 8
    n = min(want, cap)
    while cell.global_batch % (dp * n):
        n -= 1
    return max(n, 1)
