"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute   = per_device_FLOPs / 197e12         (bf16 MXU peak)
    memory    = per_device_bytes / 819e9           (HBM bandwidth)
    collective= per_device_collective_bytes / 50e9 (ICI per-link)

``cost_analysis()`` yields per-device FLOPs / bytes of the SPMD-partitioned
module.  Collective bytes are NOT in cost_analysis: we parse the compiled
HLO text and sum the output-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction (output shapes
in post-partitioning HLO are per-device, which is the unit the term wants;
all-reduce is counted 2× for the ring's reduce+broadcast phases).

MODEL_FLOPS uses 6·N·D (dense) or 6·N_active·D (MoE) with D = tokens per
step; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch overheads.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

from ..models import ArchConfig, ShapeCell

__all__ = ["HW", "roofline_from_compiled", "model_flops", "RooflineReport"]

# TPU v5e
HW = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|[suf](?:8|16|32|64)|bf16|f16|c64|c128)"
                       r"\[([0-9,]*)\]")


def _bytes_of_shape(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (output-shape sum)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # instruction lines look like: "%x = bf16[8,128]{1,0} all-gather(..."
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        opm = re.search(r"\b([a-z0-9-]+)\(", rhs)
        if not opm or opm.group(1) not in _COLLECTIVES:
            continue
        kind = opm.group(1)
        # output shape(s) appear before the op name
        head = rhs[:opm.start()]
        total = sum(_bytes_of_shape(m) for m in _SHAPE_RE.finditer(head))
        if kind == "all-reduce":
            total *= 2                     # ring: reduce-scatter + all-gather
        out[kind] += total
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    cell: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_total: float
    useful_ratio: float               # MODEL_FLOPS / (HLO_FLOPs × chips)
    bottleneck: str
    memory_analysis: Dict[str, float]

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg: ArchConfig, cell: ShapeCell) -> float:
    """6·N·D with N = active params, D = tokens processed by the step."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens          # forward only
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def roofline_from_compiled(compiled, cfg: ArchConfig, cell: ShapeCell,
                           mesh_desc: str, n_chips: int) -> RooflineReport:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    coll_total = float(sum(coll.values()))

    t_c = flops / HW["peak_flops"]
    t_m = byts / HW["hbm_bw"]
    t_x = coll_total / HW["ici_bw"]
    mf = model_flops(cfg, cell)
    useful = mf / max(flops * n_chips, 1.0)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for key in ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes"):
            mem[key] = float(getattr(ma, key, 0))
    except Exception:
        pass

    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    return RooflineReport(
        arch=cfg.name, cell=cell.name, mesh=mesh_desc,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=coll_total, coll_breakdown=coll,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        model_flops_total=mf, useful_ratio=useful,
        bottleneck=max(terms, key=terms.get),
        memory_analysis=mem)
