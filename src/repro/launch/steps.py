"""Jittable step factories shared by the dry-run and the real launchers."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models import ArchConfig, ModelCtx, decode_step, init_model, prefill
from ..optim import adamw_init
from ..runtime.train_loop import make_train_step
from ..parallel.sharding import param_shardings, opt_state_shardings

__all__ = ["build_train_fn", "build_prefill_fn", "build_decode_fn",
           "model_state_shapes"]


def model_state_shapes(cfg: ArchConfig, *, opt_state_dtype: Optional[str],
                       optimizer: str = "adamw"):
    """(params, opt_state) as ShapeDtypeStructs — no allocation."""
    from ..optim import adafactor_init
    p_shapes = jax.eval_shape(lambda k: init_model(k, cfg),
                              jax.random.PRNGKey(0))
    if optimizer == "adafactor":
        o_shapes = jax.eval_shape(lambda: adafactor_init(p_shapes))
    else:
        o_shapes = jax.eval_shape(
            lambda: adamw_init(p_shapes, state_dtype=opt_state_dtype))
    return p_shapes, o_shapes


def build_train_fn(cfg: ArchConfig, ctx: ModelCtx, n_microbatches: int,
                   opt_state_dtype: Optional[str] = "bfloat16",
                   acc_dtype: str = "float32",
                   optimizer: str = "adamw") -> Callable:
    step = make_train_step(cfg, ctx=ctx, n_microbatches=n_microbatches,
                           opt_state_dtype=opt_state_dtype,
                           acc_dtype=acc_dtype, optimizer=optimizer)

    def train_fn(params, opt_state, batch):
        return step(params, opt_state, batch)
    return train_fn


def build_prefill_fn(cfg: ArchConfig, ctx: ModelCtx) -> Callable:
    def prefill_fn(params, batch, caches):
        return prefill(params, batch, caches, cfg=cfg, ctx=ctx)
    return prefill_fn


def build_decode_fn(cfg: ArchConfig, ctx: ModelCtx) -> Callable:
    def decode_fn(params, tokens, pos, caches, enc_out=None):
        return decode_step(params, tokens, pos, caches, cfg=cfg, ctx=ctx,
                           enc_out=enc_out)
    return decode_fn
