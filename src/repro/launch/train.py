"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck

Runs the full operational loop (data pipeline → jitted train step →
checkpoint/restart) on whatever devices exist.  ``--smoke`` selects the
reduced config (the full configs need a pod).  ``--resume`` restores the
latest checkpoint and continues — kill it mid-run and relaunch to see the
fault-tolerance path.  ``--hetero-profile`` demonstrates the paper-driven
unequal shard planner."""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hetero-profile", default=None,
                    help="'ec2' or 'tpu' — print the Thm-1 shard plan")
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke_config
    from repro.data import TokenStream
    from repro.runtime.train_loop import TrainLoop, TrainLoopConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"[train] arch={cfg.name} params={cfg.param_count():,} "
          f"layers={cfg.n_layers}")

    if args.hetero_profile:
        from repro.parallel.hetero import coded_batch_plan, hetero_split
        from repro.sim.cluster import ec2_cluster, tpu_pod_cluster
        prof = (ec2_cluster(N=8, n_fast=3) if args.hetero_profile == "ec2"
                else tpu_pod_cluster(n_pods=8, degraded=(3,)))
        split = hetero_split(prof, args.batch * 8)
        coded, t = coded_batch_plan(prof, args.batch * 8)
        print(f"[hetero] Thm-1 split over {prof.N} groups: {split.tolist()}")
        print(f"[hetero] coded loads (k-of-n tolerant): {coded.tolist()}, "
              f"predicted completion {t:.2f}ms")

    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)
    extra = {}
    if cfg.enc_dec:
        extra["enc_feats"] = np.full(
            (args.batch, cfg.frontend_len, cfg.frontend_dim), 0.1, np.float32)
    if cfg.frontend == "vision":
        extra["patch_feats"] = np.full(
            (args.batch, cfg.frontend_len, cfg.frontend_dim), 0.1, np.float32)

    loop = TrainLoop(cfg, TrainLoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, n_microbatches=args.microbatches,
        lr_peak=args.lr, warmup=max(args.steps // 10, 5)),
        stream, rng_seed=args.seed, extra_feats=extra)

    if args.resume and loop.try_restore():
        print(f"[train] resumed from step {loop.step}")

    hist = loop.run(callback=lambda s, m: print(
        f"[train] step {s:5d} loss {m['loss']:.4f} lr {m['lr']:.2e} "
        f"({m['wall_s']:.0f}s)"))
    first, last = hist[0][1]["loss"], hist[-1][1]["loss"]
    print(f"[train] done: loss {first:.4f} → {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    sys.exit(main())
