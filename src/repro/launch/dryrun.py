import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first init.

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
# extract memory / cost / roofline terms — no device buffers are ever
# allocated (ShapeDtypeStruct in, compiled artifact out).
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
#   python -m repro.launch.dryrun --arch all --shape all --mesh both \
#       --out results/dryrun
# Each invocation compiles in-process; --subprocess isolates every cell in a
# fresh interpreter (recommended for the full sweep on small hosts).
# (no `from __future__ import annotations` here: os.environ must be line 2.)
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_from_compiled
from repro.launch.specs import input_specs, input_shardings, microbatches_for
from repro.launch.steps import (build_decode_fn, build_prefill_fn,
                                build_train_fn, model_state_shapes)
from repro.models import ModelCtx, SHAPE_CELLS, shape_cell
from repro.parallel.sharding import (batch_sharding, opt_state_shardings,
                                     param_shardings)

SKIP = "skip"


def should_skip(cfg, cell) -> Optional[str]:
    if cell.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: 500k dense KV per layer is not "
                "sub-quadratic; skipped per brief (DESIGN.md §4)")
    return None


def run_cell(arch: str, shape: str, multi_pod: bool, *,
             fsdp: bool = True, microbatches: Optional[int] = None,
             opt_state_dtype: str = "bfloat16",
             ep_full: bool = False, acc_dtype: str = "float32",
             a2a_fp8: bool = False, optimizer: str = "adamw",
             remat_policy: str = "full",
             save_dir: Optional[str] = None, verbose: bool = True,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    cell = shape_cell(shape)
    mesh_desc = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()

    reason = should_skip(cfg, cell)
    if reason:
        rec = {"arch": cfg.name, "cell": cell.name, "mesh": mesh_desc,
               "status": SKIP, "reason": reason}
        _save(rec, save_dir, cfg.name, cell.name, mesh_desc)
        if verbose:
            print(f"[dryrun] SKIP {cfg.name} × {cell.name} × {mesh_desc}: "
                  f"{reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    ctx = ModelCtx(mesh=mesh, model_axis="model", ep_full=ep_full,
                   remat_policy=remat_policy, a2a_fp8=a2a_fp8)

    specs = input_specs(cfg, cell)
    in_shard = input_shardings(specs, mesh, cell)
    p_shapes, o_shapes = model_state_shapes(
        cfg, opt_state_dtype=opt_state_dtype, optimizer=optimizer)
    p_shard = param_shardings(p_shapes, mesh, fsdp=fsdp,
                              moe_full_ep=ep_full)
    o_shard = opt_state_shardings(o_shapes, p_shard)

    with mesh:
        if cell.kind == "train":
            n_micro = microbatches_for(cfg, cell, mesh, microbatches)
            fn = build_train_fn(cfg, ctx, n_micro,
                                opt_state_dtype=opt_state_dtype,
                                acc_dtype=acc_dtype, optimizer=optimizer)
            batch = {k: v for k, v in specs.items()}
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, o_shard,
                              {k: in_shard[k] for k in batch}),
                donate_argnums=(0, 1))
            lowered = jitted.lower(p_shapes, o_shapes, batch)
        elif cell.kind == "prefill":
            fn = build_prefill_fn(cfg, ctx)
            batch = {k: v for k, v in specs.items() if k != "caches"}
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard,
                              {k: in_shard[k] for k in batch},
                              in_shard["caches"]),
                donate_argnums=(2,))
            lowered = jitted.lower(p_shapes, batch, specs["caches"])
        else:  # decode
            fn = build_decode_fn(cfg, ctx)
            args = [p_shapes, specs["tokens"], specs["pos"], specs["caches"]]
            shards = [p_shard, in_shard["tokens"], in_shard["pos"],
                      in_shard["caches"]]
            if "enc_out" in specs:
                args.append(specs["enc_out"])
                shards.append(in_shard["enc_out"])
            jitted = jax.jit(fn, in_shardings=tuple(shards),
                             donate_argnums=(3,))
            lowered = jitted.lower(*args)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rep = roofline_from_compiled(compiled, cfg, cell, mesh_desc, n_chips)
    rec = rep.to_json()
    rec.update(status="ok", tag=tag, ep_full=ep_full, a2a_fp8=a2a_fp8,
               optimizer=optimizer,
               acc_dtype=acc_dtype, remat_policy=remat_policy,
               lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1),
               n_chips=n_chips, fsdp=fsdp,
               microbatches=microbatches_for(cfg, cell, mesh, microbatches)
               if cell.kind == "train" else 1,
               param_count=cfg.param_count(),
               active_param_count=cfg.active_param_count())
    if verbose:
        ma = rec["memory_analysis"]
        print(f"[dryrun] OK {cfg.name} × {cell.name} × {mesh_desc} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: "
              + ", ".join(f"{k.split('_')[0]}={v/2**30:.2f}GiB"
                          for k, v in ma.items() if v))
        print(f"  cost: {rec['flops_per_device']:.3e} FLOPs/dev, "
              f"{rec['bytes_per_device']:.3e} B/dev, "
              f"coll {rec['coll_bytes_per_device']:.3e} B/dev")
        print(f"  roofline: compute {rec['t_compute']*1e3:.2f}ms, memory "
              f"{rec['t_memory']*1e3:.2f}ms, collective "
              f"{rec['t_collective']*1e3:.2f}ms → {rec['bottleneck']}-bound; "
              f"useful-FLOP ratio {rec['useful_ratio']:.3f}")
    _save(rec, save_dir, cfg.name, cell.name, mesh_desc)
    return rec


def _save(rec: dict, save_dir: Optional[str], arch: str, cell: str,
          mesh: str):
    if not save_dir:
        return
    os.makedirs(save_dir, exist_ok=True)
    safe = arch.replace("/", "_").replace(".", "_")
    tag = rec.get("tag") or ""
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(save_dir, f"{safe}__{cell}__{mesh}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--opt-state-dtype", default="bfloat16")
    ap.add_argument("--ep-full", action="store_true")
    ap.add_argument("--acc-dtype", default="float32")
    ap.add_argument("--remat-policy", default="full")
    ap.add_argument("--a2a-fp8", action="store_true")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--tag", default="")
    ap.add_argument("--subprocess", action="store_true",
                    help="isolate each cell in a fresh interpreter")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = [c.name for c in SHAPE_CELLS] if args.shape == "all" \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.subprocess:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", "multi" if mp else "single",
                           "--out", args.out]
                    if args.no_fsdp:
                        cmd.append("--no-fsdp")
                    if args.microbatches:
                        cmd += ["--microbatches", str(args.microbatches)]
                    r = subprocess.run(cmd)
                    if r.returncode:
                        failures.append((arch, shape, mp))
                    continue
                try:
                    run_cell(arch, shape, mp, fsdp=not args.no_fsdp,
                             microbatches=args.microbatches,
                             opt_state_dtype=args.opt_state_dtype,
                             ep_full=args.ep_full, acc_dtype=args.acc_dtype,
                             a2a_fp8=args.a2a_fp8, optimizer=args.optimizer,
                             remat_policy=args.remat_policy, tag=args.tag,
                             save_dir=args.out)
                except Exception:
                    traceback.print_exc()
                    failures.append((arch, shape, mp))
    if failures:
        print("FAILED cells:", failures)
        return 1
    print("all requested cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
