"""AdamW with optional low-precision moments.

Moments inherit the parameter sharding automatically under pjit (they are
tree_map images of the params).  ``state_dtype="bfloat16"`` halves optimizer
memory for the ≥100B configs (recorded as a §Perf memory-term lever).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["OptState", "adamw_init", "adamw_update"]


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params, state_dtype: Optional[str] = None) -> OptState:
    def zeros_like(p):
        dt = jnp.dtype(state_dtype) if state_dtype else p.dtype
        return jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros_like, params),
                    nu=jax.tree.map(zeros_like, params))


def adamw_update(params, grads, state: OptState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: Optional[float] = 1.0):
    """Returns (new_params, new_state).  ``lr`` may be a scalar or a
    step-indexed callable."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    if grad_clip is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)) + 1e-16)
        scale = jnp.minimum(1.0, grad_clip / gnorm)
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr_t * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu)
