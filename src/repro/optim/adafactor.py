"""Adafactor (factored second moment) — the memory-lean optimizer option for
the ≥300B configs: O(n+m) state per (n, m) matrix instead of O(nm)."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["adafactor_init", "adafactor_update"]


class _Factored(NamedTuple):
    row: jnp.ndarray
    col: jnp.ndarray


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    second: Any          # per-leaf: _Factored for >=2D, full array otherwise


def _is_factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    def init(p):
        if _is_factored(p):
            return _Factored(row=jnp.zeros(p.shape[:-1], jnp.float32),
                             col=jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                           jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)
    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          second=jax.tree.map(init, params,
                                              is_leaf=None))


def adafactor_update(params, grads, state: AdafactorState, *, lr,
                     decay: float = 0.8, eps: float = 1e-30,
                     clip_threshold: float = 1.0):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    beta = 1.0 - step.astype(jnp.float32) ** (-decay)

    def upd(p, g, s):
        gf = g.astype(jnp.float32)
        g2 = jnp.square(gf) + eps
        if isinstance(s, _Factored):
            row = beta * s.row + (1 - beta) * g2.mean(axis=-1)
            col = beta * s.col + (1 - beta) * g2.mean(axis=-2)
            row_mean = row.mean(axis=-1, keepdims=True)
            v = (row / jnp.maximum(row_mean, eps))[..., None] * col[..., None, :]
            new_s = _Factored(row=row, col=col)
        else:
            v = beta * s + (1 - beta) * g2
            new_s = v
        u = gf / jnp.sqrt(jnp.maximum(v, eps))
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        p_new = p.astype(jnp.float32) - lr_t * u
        return p_new.astype(p.dtype), new_s

    is_leaf = lambda t: isinstance(t, _Factored)
    out = jax.tree.map(upd, params, grads, state.second, is_leaf=is_leaf)
    two = lambda t: isinstance(t, tuple) and len(t) == 2 and not isinstance(t, _Factored)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=two)
    new_second = jax.tree.map(lambda t: t[1], out, is_leaf=two)
    return new_params, AdafactorState(step=step, second=new_second)
