"""``repro.faults`` — deterministic fault injection + quarantine ledger.

The paper's redundancy argument is usually read as straggler tolerance:
any L of the L̃ coded rows reconstruct the product.  The same surplus is
an *integrity* budget — decode x̂ from a covering prefix of L delivered
rows, and every extra delivered row r is a parity check

    resid_r = y_r − G[r] · x̂            (≈ 0 for an honest worker)

whose violation localises the faulty worker.  This module supplies the
chaos half of that story; the detection/recovery half lives in
:func:`repro.stream.backend.verify_decode` and the serving bridge.

Determinism.  Fault draws must not perturb the simulator's delay
randomness (the fault-free-schedule serve must stay bit-identical to a
``faults=None`` serve), so every draw comes from its own hash-seeded
generator keyed on ``(seed, salt, dispatch, worker)`` — stateless,
order-independent, replayable.  ``FaultSchedule`` resolves a
:class:`FaultConfig` into per-(dispatch, worker) fault kinds; the
injectors (bridge / engine) apply them at the timing or product layer.

Fault taxonomy
--------------

==============  ==========================================================
kind            effect at injection site
==============  ==========================================================
``crash``       worker dies mid-task: undelivered shards lost, worker
                offline until backoff readmission (vs. a *graceful*
                ``leave``, which is scheduled and permanent)
``drop``        one dispatch's shard delivery is lost in transit
                (worker stays up; timing-only, data never corrupted)
``duplicate``   shard delivered twice; receiver-side dedupe ignores the
                copy (counted, numerically inert)
``stale``       delivery delayed by ``stale_factor`` × the remaining
                transit time — correct bytes, reordered arrival
``bit_flip``    Byzantine: one mantissa bit of every returned product
                value flips (large relative error)
``scaled``      Byzantine: returned products scaled by ``1 + eps``
                (small relative error — the adversarial detection case)
``sign_flip``   Byzantine: returned products negated
==============  ==========================================================
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS", "DELIVERY_FAULTS", "CORRUPTION_FAULTS",
    "FaultEvent", "FaultConfig", "FaultSchedule", "QuarantineLedger",
    "corrupt_products", "parse_fault_spec",
]

DELIVERY_FAULTS = ("crash", "drop", "duplicate", "stale")
CORRUPTION_FAULTS = ("bit_flip", "scaled", "sign_flip")
FAULT_KINDS = DELIVERY_FAULTS + CORRUPTION_FAULTS

_SALT = 0xFA017


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One explicit injection: worker ``worker`` misbehaves as ``kind``
    on dispatch number ``dispatch`` (the injector's monotone counter)."""
    dispatch: int
    worker: int
    kind: str

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {sorted(FAULT_KINDS)}")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded chaos policy + the detect/quarantine/retry knobs.

    Rates are per-(dispatch, worker) Bernoulli probabilities, resolved
    deterministically by :class:`FaultSchedule`; an explicit ``trace`` of
    :class:`FaultEvent`\\ s is injected unconditionally on top.

    ``corrupt_eps`` drives the ``scaled`` kind (relative perturbation).
    ``surplus_rows`` is how many delivered-beyond-the-prefix rows the
    detector residual-checks per task; ``residual_tol`` is the relative
    residual above which a row is flagged (it must sit above the float32
    encode noise of the jax tail — see the bridge's verify tolerances).
    ``retry_budget`` bounds per-step re-dispatches after an
    unrecoverable detection; past it the step degrades to an LS decode
    on the verified row subset instead of silently wrong logits.
    Quarantine readmission backs off exponentially:
    ``backoff_base × backoff_factor**(offenses − 1)`` sim-time units.
    """
    seed: int = 0
    crash_rate: float = 0.0
    drop_rate: float = 0.0
    duplicate_rate: float = 0.0
    stale_rate: float = 0.0
    corrupt_rate: float = 0.0
    corrupt_kind: str = "bit_flip"
    corrupt_eps: float = 1e-3
    stale_factor: float = 4.0
    trace: Tuple[FaultEvent, ...] = ()
    detect: bool = True
    surplus_rows: int = 8
    residual_tol: float = 1e-4
    retry_budget: int = 2
    backoff_base: float = 2000.0
    backoff_factor: float = 2.0

    def __post_init__(self):
        if self.corrupt_kind not in CORRUPTION_FAULTS:
            raise ValueError(f"corrupt_kind must be one of "
                             f"{CORRUPTION_FAULTS}, got {self.corrupt_kind!r}")
        for name in ("crash_rate", "drop_rate", "duplicate_rate",
                     "stale_rate", "corrupt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")

    @property
    def active(self) -> bool:
        """Whether any injection can ever fire (detection may still run)."""
        return bool(self.trace) or any(
            getattr(self, f"{k}_rate") > 0
            for k in ("crash", "drop", "duplicate", "stale", "corrupt"))

    def schedule(self) -> "FaultSchedule":
        return FaultSchedule(self)


class FaultSchedule:
    """Resolved, stateless fault draws for a :class:`FaultConfig`.

    ``faults_at(dispatch, workers)`` maps each worker to at most one
    fault kind for that dispatch.  Draws are independent per
    (dispatch, worker) and never consume shared RNG state, so two runs
    with the same config agree regardless of event interleaving, and a
    zero-rate schedule is observationally identical to no schedule.
    Precedence when several rates fire on one draw:
    crash > corruption > drop > stale > duplicate.
    """

    def __init__(self, config: FaultConfig):
        self.config = config
        self._trace: Dict[Tuple[int, int], str] = {
            (ev.dispatch, ev.worker): ev.kind for ev in config.trace}
        # (kind, rate) checks in precedence order, zero rates pre-dropped
        self._checks: List[Tuple[str, float]] = [
            (k, r) for k, r in (
                ("crash", config.crash_rate),
                (config.corrupt_kind, config.corrupt_rate),
                ("drop", config.drop_rate),
                ("stale", config.stale_rate),
                ("duplicate", config.duplicate_rate),
            ) if r > 0.0]

    def fault_at(self, dispatch: int, worker: int) -> Optional[str]:
        kind = self._trace.get((int(dispatch), int(worker)))
        if kind is not None:
            return kind
        if not self._checks:
            return None
        u = np.random.default_rng(
            (self.config.seed, _SALT, int(dispatch), int(worker))
        ).random(len(self._checks))
        for i, (kind, rate) in enumerate(self._checks):
            if u[i] < rate:
                return kind
        return None

    def faults_at(self, dispatch: int,
                  workers: Iterable[int]) -> Dict[int, str]:
        out: Dict[int, str] = {}
        for w in workers:
            kind = self.fault_at(dispatch, w)
            if kind is not None:
                out[int(w)] = kind
        return out

    def crash_events(self, workers: Sequence[int], horizon: float,
                     mean_interval: float):
        """Pre-generated crash :class:`~repro.stream.events.WorkerEvent`\\ s
        for the streaming engine: per worker, a hash-seeded Poisson clock
        of rate ``crash_rate / mean_interval`` over ``[0, horizon)``.
        Each crash carries its backoff readmission as a paired ``join``
        so the engine's churn loop replays recovery deterministically."""
        from ..stream.events import WorkerEvent
        cfg = self.config
        out: List[WorkerEvent] = []
        if cfg.crash_rate <= 0 or not math.isfinite(horizon):
            return out
        rate = cfg.crash_rate / max(mean_interval, 1e-300)
        for w in workers:
            rng = np.random.default_rng((cfg.seed, _SALT, 0xC4A5, int(w)))
            t, offenses = 0.0, 0
            while True:
                t += rng.exponential(1.0 / rate)
                if t >= horizon:
                    break
                offenses += 1
                back = cfg.backoff_base * cfg.backoff_factor ** (offenses - 1)
                out.append(WorkerEvent(time=t, worker=int(w), kind="crash"))
                out.append(WorkerEvent(time=t + back, worker=int(w),
                                       kind="join"))
                t += back
        out.sort(key=lambda e: e.time)
        return out


def corrupt_products(y: np.ndarray, kind: str, *,
                     eps: float = 1e-3) -> np.ndarray:
    """Apply a Byzantine corruption to a worker's returned products.

    Deterministic and elementwise — the same rows corrupt the same way
    wherever they are recomputed (the localisation sweep re-derives a
    suspect's products and must see identical bytes).
    """
    y = np.asarray(y)
    if kind == "bit_flip":
        u = y.view(np.uint64) if y.dtype == np.float64 else y
        if y.dtype == np.float64:
            out = (u ^ np.uint64(1 << 51)).view(np.float64)
        else:                                   # pragma: no cover - float32
            out = (y.view(np.uint32) ^ np.uint32(1 << 22)).view(np.float32)
        return out.copy()
    if kind == "scaled":
        return y * (1.0 + eps)
    if kind == "sign_flip":
        return -y
    raise ValueError(f"unknown corruption kind {kind!r}")


class QuarantineLedger:
    """Flagged-worker ledger with exponential-backoff readmission.

    A detection flags a worker: it is quarantined (the caller masks it
    from the share pool exactly like a ``leave``) until
    ``t + backoff_base × backoff_factor**(offenses−1)``; repeat
    offenders back off geometrically.  ``note_critical`` accumulates
    the tracer's critical-worker attribution as a *suspect score* —
    detection's localisation sweep tries high-suspicion workers first,
    so a straggling-and-corrupt worker is confirmed in one decode.
    """

    def __init__(self, *, backoff_base: float = 2000.0,
                 backoff_factor: float = 2.0):
        self.backoff_base = float(backoff_base)
        self.backoff_factor = float(backoff_factor)
        self.offenses: Dict[int, int] = {}
        self.readmit_at: Dict[int, float] = {}
        self.suspect: Dict[int, float] = {}
        self.quarantines = 0
        self.readmissions = 0

    def flag(self, worker: int, t: float) -> float:
        """Quarantine ``worker`` at sim time ``t``; returns the
        readmission time."""
        w = int(worker)
        self.offenses[w] = self.offenses.get(w, 0) + 1
        back = self.backoff_base * \
            self.backoff_factor ** (self.offenses[w] - 1)
        self.readmit_at[w] = t + back
        self.suspect[w] = self.suspect.get(w, 0.0) + 1.0
        self.quarantines += 1
        return self.readmit_at[w]

    def readmit(self, worker: int) -> None:
        self.readmit_at.pop(int(worker), None)
        self.readmissions += 1

    def is_quarantined(self, worker: int, t: float) -> bool:
        until = self.readmit_at.get(int(worker))
        return until is not None and t < until

    def quarantined(self, t: float) -> List[int]:
        return sorted(w for w, until in self.readmit_at.items()
                      if t < until)

    def note_critical(self, worker: int, weight: float = 0.1) -> None:
        """Straggler-attribution prior: a repeatedly-critical worker is
        suspicious before it is ever caught corrupting."""
        w = int(worker)
        if w > 0:
            self.suspect[w] = self.suspect.get(w, 0.0) + float(weight)

    def suspects_first(self, workers: Iterable[int]) -> List[int]:
        """Candidate ordering for the localisation sweep: most-suspect
        first, ties by worker id (deterministic)."""
        return sorted((int(w) for w in workers),
                      key=lambda w: (-self.suspect.get(w, 0.0), w))


def parse_fault_spec(spec: str) -> FaultConfig:
    """Build a :class:`FaultConfig` from a CLI spec string.

    ``"corrupt=0.3,kind=sign_flip,seed=3"`` →
    ``FaultConfig(corrupt_rate=0.3, corrupt_kind="sign_flip", seed=3)``.
    Keys: crash / drop / duplicate / stale / corrupt (rates), kind,
    seed, surplus, retries, tol, backoff.  An empty spec ("" or
    "none") means a zero-rate config with detection on — the
    fault-free-schedule identity case.
    """
    cfg: Dict[str, object] = {}
    spec = (spec or "").strip()
    if spec and spec != "none":
        for part in spec.split(","):
            key, _, val = part.partition("=")
            key = key.strip()
            val = val.strip()
            if key in ("crash", "drop", "duplicate", "stale", "corrupt"):
                cfg[f"{key}_rate"] = float(val)
            elif key == "kind":
                cfg["corrupt_kind"] = val
            elif key == "seed":
                cfg["seed"] = int(val)
            elif key == "surplus":
                cfg["surplus_rows"] = int(val)
            elif key == "retries":
                cfg["retry_budget"] = int(val)
            elif key == "tol":
                cfg["residual_tol"] = float(val)
            elif key == "backoff":
                cfg["backoff_base"] = float(val)
            else:
                raise ValueError(f"unknown fault spec key {key!r} in {spec!r}")
    return FaultConfig(**cfg)
