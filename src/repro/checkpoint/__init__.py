"""Sharded, atomic checkpointing."""
from .manager import CheckpointManager  # noqa: F401
