"""Checkpoint manager: per-leaf .npy shards + JSON manifest, atomic rename,
keep-k retention, exact resume (params, optimizer state, data-stream state).

Layout:
    <dir>/step_000123.tmp/...   (write)
    <dir>/step_000123/          (atomic rename on completion)
        manifest.json           {step, leaf index, tree structure, extra}
        leaf_00000.npy ...

On a multi-host deployment each host writes only the leaves (or leaf shards)
it owns — here the host count is 1, but the manifest format carries a
``host`` field per leaf so the layout is forward-compatible.  A half-written
checkpoint is never visible (tmp rename), satisfying the crash-consistency
requirement for preemptible fleets.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        leaves, treedef = jax.tree.flatten(tree)
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            index.append({"file": fn, "shape": list(arr.shape),
                          "dtype": str(arr.dtype), "host": self.host_id})
        manifest = {"step": step, "leaves": index,
                    "treedef": str(treedef), "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):           # re-save of same step: replace
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                ) -> Tuple[Any, int, dict]:
        """Restore into the structure of ``template`` (shapes validated).
        Returns (tree, step, extra)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        t_leaves, treedef = jax.tree.flatten(template)
        if len(t_leaves) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, template "
                f"has {len(t_leaves)} — structure drift")
        leaves = []
        for tmpl, meta in zip(t_leaves, manifest["leaves"]):
            arr = np.load(os.path.join(path, meta["file"]))
            if list(getattr(tmpl, "shape", arr.shape)) != meta["shape"]:
                raise ValueError(f"shape mismatch for {meta['file']}: "
                                 f"{meta['shape']} vs {tmpl.shape}")
            leaves.append(arr)
        return jax.tree.unflatten(treedef, leaves), step, manifest["extra"]

    # -- retention ------------------------------------------------------------

    def _steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d{8})", d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def _gc(self):
        steps = self._steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
