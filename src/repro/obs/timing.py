"""Honest wall timing around jitted / device work.

An unfenced ``perf_counter`` pair around a jax call times *dispatch*, not
compute — results are futures.  :func:`device_span` fences the exit with
``jax.block_until_ready`` on whatever the body registered, so the recorded
wall span covers the device work.  The fence only happens when a tracer is
actually recording: with tracing off the async dispatch pipeline is
untouched (that's the < 2% disabled-overhead contract).

:func:`profiler_annotation` optionally nests a
``jax.profiler.TraceAnnotation`` so spans line up with a concurrently
captured device profile (``Tracer(jax_profiler=True)``); it is a no-op
without jax or when the tracer doesn't ask for it.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Iterator, Optional

from .tracer import Tracer, current_tracer

__all__ = ["device_fence", "device_span", "profiler_annotation"]


def device_fence(x: Any) -> Any:
    """``jax.block_until_ready`` when jax is importable, else identity."""
    try:
        import jax
    except Exception:
        return x
    try:
        return jax.block_until_ready(x)
    except Exception:  # host-side objects jax refuses to traverse
        return x


class _Fence:
    """Mutable holder the ``device_span`` body loads its result into."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Any = None

    def __call__(self, x: Any) -> Any:
        self.value = x
        return x


@contextlib.contextmanager
def profiler_annotation(name: str,
                        tr: Optional[Tracer] = None) -> Iterator[None]:
    tr = tr if tr is not None else current_tracer()
    if tr is None or not tr.jax_profiler:
        yield
        return
    try:
        from jax.profiler import TraceAnnotation
    except Exception:
        yield
        return
    with TraceAnnotation(name):
        yield


@contextlib.contextmanager
def device_span(name: str, *, cat: str = "kernel", track: str = "wall",
                args: Optional[Dict[str, Any]] = None,
                tr: Optional[Tracer] = None) -> Iterator[_Fence]:
    """Fenced wall span.  Usage::

        with device_span("coded_shard_matmul_batch", cat="kernel") as fence:
            out = fence(jitted(...))   # blocked on at span exit

    With no active tracer the body runs untouched (no fence, no timing).
    """
    tr = tr if tr is not None else current_tracer()
    fence = _Fence()
    if tr is None:
        yield fence
        return
    with profiler_annotation(name, tr):
        with tr.span(name, cat=cat, track=track, args=args) as a:
            yield fence
            if fence.value is not None:
                device_fence(fence.value)
            a.setdefault("fenced", fence.value is not None)
