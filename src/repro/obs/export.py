"""Exporters: Chrome/Perfetto trace JSON, flat records, BENCH-schema summary.

Chrome ``trace_event`` mapping (the JSON Array Format with a top-level
object, which Perfetto loads directly):

* every span is a complete event ``ph:"X"`` with ``ts``/``dur`` in
  microseconds;
* the two time domains become two *processes*: pid 1 = wall clock
  (``ts = seconds × 1e6``), pid 2 = sim time (``ts = sim-ms × 1e3``), so
  the sim timeline is readable in the same UI without pretending the two
  clocks are comparable;
* tracks (``"wall"``, ``"sim:worker3"``) become named threads via ``"M"``
  metadata events;
* counters are ``ph:"C"`` events on their domain's pid.

The exported object also carries ``repro_summary`` (the :func:`summary`
rollup) and ``repro_meta`` — Perfetto ignores unknown top-level keys, and
``repro.obs.validate`` / CI read them back.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

from .tracer import STAGE_CATS, Span, Tracer

__all__ = ["to_chrome_trace", "to_records", "summary", "write_trace"]

_PIDS = {"wall": 1, "sim": 2}
_PID_NAMES = {1: "wall-clock (s)", 2: "sim-time (ms)"}
# µs per unit of the domain's native clock (wall: s, sim: ms).
_TS_SCALE = {1: 1e6, 2: 1e3}


def _split_track(track: str) -> Tuple[int, str]:
    domain, _, lane = track.partition(":")
    return _PIDS.get(domain, 1), lane or "main"


class _TidMap:
    """Stable thread ids per (pid, lane), in first-appearance order."""

    def __init__(self) -> None:
        self._tids: Dict[Tuple[int, str], int] = {}

    def tid(self, pid: int, lane: str) -> int:
        key = (pid, lane)
        if key not in self._tids:
            self._tids[key] = 1 + sum(1 for p, _ in self._tids if p == pid)
        return self._tids[key]

    def metadata(self) -> List[Dict[str, Any]]:
        ev: List[Dict[str, Any]] = []
        for pid in sorted(set(p for p, _ in self._tids)):
            ev.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "ts": 0,
                       "args": {"name": _PID_NAMES.get(pid, f"pid{pid}")}})
        for (pid, lane), tid in self._tids.items():
            ev.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "ts": 0, "args": {"name": lane}})
        return ev


def to_chrome_trace(tr: Tracer) -> Dict[str, Any]:
    tids = _TidMap()
    events: List[Dict[str, Any]] = []
    for sp in tr.spans:
        pid, lane = _split_track(sp.track)
        scale = _TS_SCALE[pid]
        ev: Dict[str, Any] = {
            "name": sp.name, "cat": sp.cat, "ph": "X",
            "ts": sp.t0 * scale, "dur": sp.dur * scale,
            "pid": pid, "tid": tids.tid(pid, lane),
        }
        if sp.args:
            ev["args"] = sp.args
        events.append(ev)
    for sp in tr.instants:
        pid, lane = _split_track(sp.track)
        ev = {"name": sp.name, "cat": sp.cat, "ph": "i", "s": "t",
              "ts": sp.t0 * _TS_SCALE[pid], "pid": pid,
              "tid": tids.tid(pid, lane)}
        if sp.args:
            ev["args"] = sp.args
        events.append(ev)
    for track, name, t, value in tr.counter_samples:
        pid, lane = _split_track(track)
        events.append({"name": name, "cat": "counter", "ph": "C",
                       "ts": t * _TS_SCALE[pid], "pid": pid,
                       "tid": tids.tid(pid, lane), "args": {name: value}})
    return {
        "traceEvents": tids.metadata() + events,
        "displayTimeUnit": "ms",
        "repro_meta": dict(tr.meta),
        "repro_summary": summary(tr),
    }


def to_records(tr: Tracer) -> List[Dict[str, Any]]:
    """Flat rows (one per span/instant) for ``pandas.DataFrame(records)``."""
    rows: List[Dict[str, Any]] = []
    for kind, pool in (("span", tr.spans), ("instant", tr.instants)):
        for sp in pool:
            row: Dict[str, Any] = {
                "kind": kind, "seq": sp.seq, "name": sp.name, "cat": sp.cat,
                "track": sp.track, "t0": sp.t0, "t1": sp.t1, "dur": sp.dur,
            }
            for k, v in (sp.args or {}).items():
                row[f"arg_{k}"] = v
            rows.append(row)
    rows.sort(key=lambda r: r["seq"])
    return rows


def _is_wall(sp: Span) -> bool:
    return _split_track(sp.track)[0] == 1


def summary(tr: Tracer, top_k: int = 5) -> Dict[str, Any]:
    """Roll spans into the BENCH schema.

    * ``per_stage_wall`` — wall seconds per leaf stage category
      (plan / pack / kernel / decode / glue);
    * ``step_wall_total`` / ``stage_coverage`` — parent "step" span total and
      the fraction of it the leaf stages account for (the acceptance
      criterion wants ≥ 0.9);
    * ``stragglers`` — top-k slowest sim-time delivery spans as
      (worker, task) attribution rows.
    """
    per_stage = {cat: 0.0 for cat in STAGE_CATS}
    step_total = 0.0
    deliveries: List[Span] = []
    for sp in tr.spans:
        if _is_wall(sp):
            if sp.cat in per_stage:
                per_stage[sp.cat] += sp.dur
            elif sp.cat == "step":
                step_total += sp.dur
        elif sp.cat == "delivery":
            deliveries.append(sp)
    stage_sum = sum(per_stage.values())
    deliveries.sort(key=lambda s: (-s.dur, s.seq))
    stragglers = []
    for sp in deliveries[:top_k]:
        a = sp.args or {}
        stragglers.append({
            "worker": a.get("worker"), "task": a.get("task"),
            "sim_duration": sp.dur, "t_finish": sp.t1,
            "critical": bool(a.get("critical", False)),
        })
    # gauges report last level in counters; surface the observed max as
    # `{name}_peak` so gauges that return to zero (pool shares after the
    # final release) still carry signal in the rollup
    counters = dict(tr.counters)
    counters.update({f"{k}_peak": v for k, v in tr.gauge_peaks.items()})
    return {
        "per_stage_wall": per_stage,
        "step_wall_total": step_total,
        "stage_wall_total": stage_sum,
        "stage_coverage": (stage_sum / step_total) if step_total > 0 else None,
        "counters": counters,
        "stragglers": stragglers,
        "span_count": len(tr.spans),
    }


def write_trace(tr: Tracer, path: str) -> str:
    with open(path, "w") as fh:
        json.dump(to_chrome_trace(tr), fh)
    return path
