"""repro.obs — zero-dependency span tracing for the coded-computation stack.

See ``stream/README.md`` ("Observability") for the span taxonomy and the
Perfetto workflow.  Core pieces:

* :class:`Tracer` / :class:`Span` — spans, instants, counters on wall and
  sim-time tracks; ``to_chrome_trace()`` / ``to_records()`` / ``summary()``.
* :func:`current_tracer` / :func:`use_tracer` — process-global registry so
  deep hot paths (kernels, stacked solves) can record without plumbing a
  tracer argument through every signature.
* :func:`device_span` / :func:`profiler_annotation` — ``block_until_ready``
  -fenced wall timing and optional ``jax.profiler`` trace contexts.
* ``python -m repro.obs.validate out.json`` — trace schema checker (CI).
"""
from .tracer import STAGE_CATS, Span, Tracer, current_tracer, use_tracer
from .timing import device_fence, device_span, profiler_annotation
from .export import summary as trace_summary
from .validate import check_trace

__all__ = [
    "STAGE_CATS", "Span", "Tracer", "current_tracer", "use_tracer",
    "device_fence", "device_span", "profiler_annotation",
    "trace_summary", "check_trace",
]
