"""Zero-dependency span tracer for the streaming / serving stack.

The paper's objective is the delay of the *slowest* task; aggregates
(``StreamMetrics.summary()``, ``ServeReport``) say how slow, not *why*.
``Tracer`` records the why: spans (named intervals with a category and a
track), instants, and counters, in **two time domains side by side**:

* ``wall`` tracks — seconds from ``time.perf_counter``, relative to the
  tracer's epoch.  Real planning / packing / kernel / decode cost.
* ``sim`` tracks — the engine's simulated time units (milliseconds in the
  default delay model).  Queue waits, per-worker shard deliveries, barrier
  completions.

Tracks are strings ``"wall"``, ``"sim"``, or ``"<domain>:<lane>"``
(``"sim:worker3"``) — lanes become Chrome-trace threads inside the domain's
process, so Perfetto shows the two clocks as two process groups.

Overhead contract: a *disabled* tracer (``enabled=False``) must be
indistinguishable from no tracer.  Instrumented code normalises
``tracer if tracer is not None and tracer.enabled else None`` once at entry
and guards every record with ``if tr is not None`` — the disabled path is
exactly the no-tracer path (one predicate at entry).  Deep call sites
(kernels, backend solves) consult the process-global :func:`current_tracer`,
which is ``None`` unless a caller installed an enabled tracer via
:func:`use_tracer` — again one global read + ``is None`` check when off.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Span", "Tracer", "current_tracer", "use_tracer", "STAGE_CATS",
]

# Leaf stage categories whose wall durations are expected to tile a serving
# step ("step" spans are their parents; coverage = sum(stages)/sum(steps)).
STAGE_CATS = ("plan", "pack", "kernel", "decode", "glue")


@dataclasses.dataclass
class Span:
    """One named interval.  ``t0``/``t1`` are in the track's time domain
    (wall: seconds since tracer epoch; sim: simulated time units)."""
    seq: int
    name: str
    cat: str
    track: str
    t0: float
    t1: float
    args: Optional[Dict[str, Any]] = None

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Collects spans / instants / counters; exports Chrome traces,
    flat records and a BENCH-schema summary (see ``repro.obs.export``)."""

    def __init__(self, *, enabled: bool = True, jax_profiler: bool = False,
                 meta: Optional[Dict[str, Any]] = None):
        self.enabled = bool(enabled)
        # Annotate jitted regions with jax.profiler.TraceAnnotation so a
        # concurrently-captured device profile lines up with our spans.
        self.jax_profiler = bool(jax_profiler)
        self.meta: Dict[str, Any] = dict(meta or {})
        self.epoch = time.perf_counter()
        self.spans: List[Span] = []
        self.instants: List[Span] = []          # t1 == t0
        self.counters: Dict[str, float] = {}    # running totals
        self.gauge_peaks: Dict[str, float] = {}  # max level per gauge
        self.counter_samples: List[Tuple[str, str, float, float]] = []
        self._seq = 0

    # -- clock ---------------------------------------------------------------

    def now(self) -> float:
        """Wall seconds since this tracer's epoch."""
        return time.perf_counter() - self.epoch

    # -- recording -----------------------------------------------------------

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def add_span(self, name: str, t0: float, t1: float, *, cat: str = "misc",
                 track: str = "sim",
                 args: Optional[Dict[str, Any]] = None) -> Optional[Span]:
        """Record an interval with explicit endpoints (sim-time spans, or
        wall spans measured externally).  Non-finite endpoints are dropped —
        a lost delivery (finish = inf) has no extent to draw."""
        if not self.enabled:
            return None
        if not (t0 == t0 and t1 == t1 and t0 != float("inf")
                and t1 != float("inf") and t0 != float("-inf")
                and t1 != float("-inf")):
            return None
        if t1 < t0:
            t0, t1 = t1, t0
        sp = Span(self._next_seq(), name, cat, track, t0, t1, args)
        self.spans.append(sp)
        return sp

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "misc", track: str = "wall",
             args: Optional[Dict[str, Any]] = None) -> Iterator[Dict[str, Any]]:
        """Wall-clock span context.  Yields the (mutable) args dict so the
        body can attach results discovered mid-span."""
        if not self.enabled:
            yield {}
            return
        a: Dict[str, Any] = dict(args) if args else {}
        t0 = self.now()
        try:
            yield a
        finally:
            t1 = self.now()
            self.spans.append(Span(self._next_seq(), name, cat, track,
                                   t0, t1, a or None))

    def instant(self, name: str, t: Optional[float] = None, *,
                cat: str = "event", track: str = "wall",
                args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        tt = self.now() if t is None else float(t)
        if tt != tt or tt in (float("inf"), float("-inf")):
            return
        self.instants.append(Span(self._next_seq(), name, cat, track,
                                  tt, tt, args))

    def count(self, name: str, delta: float = 1, *,
              t: Optional[float] = None, track: str = "wall") -> None:
        """Increment a running counter and record a sample of the new total
        (rendered as a Chrome ``"C"`` counter track)."""
        if not self.enabled:
            return
        total = self.counters.get(name, 0.0) + delta
        self.counters[name] = total
        tt = self.now() if t is None else float(t)
        if tt == tt and tt not in (float("inf"), float("-inf")):
            self.counter_samples.append((track, name, tt, total))

    def gauge(self, name: str, value: float, *,
              t: Optional[float] = None, track: str = "wall") -> None:
        """Record an instantaneous level (queue depth, pool shares).

        ``counters[name]`` holds the *last* level (the historical
        semantics); ``gauge_peaks[name]`` tracks the max — the summary
        surfaces it as ``{name}_peak`` so a gauge that naturally returns
        to zero (pool shares after the final release) is still visible
        in the rollup."""
        if not self.enabled:
            return
        self.counters[name] = float(value)
        prev = self.gauge_peaks.get(name)
        if prev is None or value > prev:
            self.gauge_peaks[name] = float(value)
        tt = self.now() if t is None else float(t)
        if tt == tt and tt not in (float("inf"), float("-inf")):
            self.counter_samples.append((track, name, tt, float(value)))

    # -- export (implemented in repro.obs.export) ----------------------------

    def to_chrome_trace(self) -> Dict[str, Any]:
        from .export import to_chrome_trace
        return to_chrome_trace(self)

    def to_records(self) -> List[Dict[str, Any]]:
        from .export import to_records
        return to_records(self)

    def summary(self, top_k: int = 5) -> Dict[str, Any]:
        from .export import summary
        return summary(self, top_k=top_k)

    def write(self, path: str) -> str:
        from .export import write_trace
        return write_trace(self, path)


# -- process-global tracer (deep call sites: kernels, backend solves) --------

_ACTIVE: Optional[Tracer] = None


def current_tracer() -> Optional[Tracer]:
    """The installed *enabled* tracer, or None.  Deep hot paths guard on
    ``tr = current_tracer(); if tr is not None: ...`` — one global read."""
    return _ACTIVE


@contextlib.contextmanager
def use_tracer(tracer: Optional[Tracer]) -> Iterator[Optional[Tracer]]:
    """Install ``tracer`` as the process-global tracer for the block.
    Disabled tracers normalise to None so the off path stays no-op."""
    global _ACTIVE
    tr = tracer if (tracer is not None and tracer.enabled) else None
    prev = _ACTIVE
    _ACTIVE = tr
    try:
        yield tr
    finally:
        _ACTIVE = prev
