"""Schema checker for exported Chrome traces (CI gate).

``python -m repro.obs.validate out.json [--min-coverage 0.9]`` exits 0 iff
the file is a loadable Chrome/Perfetto trace whose events carry the
required keys and whose embedded ``repro_summary`` shows the per-stage
spans covering the step wall time.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Tuple

from .tracer import STAGE_CATS

__all__ = ["check_trace", "main"]

_REQUIRED = ("name", "ph", "ts", "pid", "tid")


def check_trace(obj: Dict[str, Any], *, min_coverage: float = 0.0,
                require_stages: bool = True) -> Tuple[bool, List[str]]:
    problems: List[str] = []
    events = obj.get("traceEvents")
    if not isinstance(events, list) or not events:
        return False, ["traceEvents missing or empty"]
    cats = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i} is not an object")
            continue
        for key in _REQUIRED:
            if key not in ev:
                problems.append(f"event {i} ({ev.get('name')!r}) missing {key!r}")
        if ev.get("ph") == "X":
            cats.add(ev.get("cat"))
            if "dur" not in ev:
                problems.append(f"span {i} ({ev.get('name')!r}) missing dur")
    if require_stages and not (cats & set(STAGE_CATS)):
        problems.append(
            f"no span with a stage category {STAGE_CATS}; saw {sorted(map(str, cats))}")
    summ = obj.get("repro_summary")
    if min_coverage > 0:
        cov = (summ or {}).get("stage_coverage")
        if cov is None:
            problems.append("repro_summary.stage_coverage missing "
                            "(no step spans recorded?)")
        elif cov < min_coverage:
            problems.append(f"stage_coverage {cov:.3f} < {min_coverage}")
    return not problems, problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="trace JSON written by Tracer.write()")
    ap.add_argument("--min-coverage", type=float, default=0.0,
                    help="require stage spans to cover this fraction of "
                         "step wall time (acceptance criterion: 0.9)")
    ap.add_argument("--no-stages", action="store_true",
                    help="don't require plan/pack/kernel/decode spans")
    args = ap.parse_args(argv)
    with open(args.path) as fh:
        obj = json.load(fh)
    ok, problems = check_trace(obj, min_coverage=args.min_coverage,
                               require_stages=not args.no_stages)
    if ok:
        n = len(obj["traceEvents"])
        cov = (obj.get("repro_summary") or {}).get("stage_coverage")
        cov_s = f", stage_coverage={cov:.3f}" if cov is not None else ""
        print(f"OK: {args.path} ({n} events{cov_s})")
        return 0
    for p in problems:
        print(f"FAIL: {p}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
