"""Sharding-aware primitive ops used inside the model.

* ``sharded_embed`` — token embedding against a vocab-sharded table via
  shard_map masked-gather + psum (the standard TP embedding; avoids XLA's
  involuntary full-remat fallback for gathers over a sharded dim).
* ``token_nll`` — cross-entropy against vocab-sharded logits without
  ``take_along_axis`` over the sharded axis (iota-compare trick; the
  softmax's max/sum reductions lower to small all-reduces).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["shard_map_compat", "sharded_embed", "token_nll"]


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions.

    ``jax.shard_map`` (with ``check_vma``) only exists on newer jax; 0.4.x
    ships it as ``jax.experimental.shard_map.shard_map`` with the equivalent
    knob spelled ``check_rep``.  Replication checking is disabled either way
    (the psum/all_to_all bodies here are not closed under it)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def sharded_embed(table: jnp.ndarray, tokens: jnp.ndarray,
                  mesh: Optional[Mesh], model_axis: str = "model",
                  data_axes: Optional[tuple] = None) -> jnp.ndarray:
    """tokens (B, T) → (B, T, d) with table (V, d) sharded on V."""
    if mesh is None or model_axis not in mesh.axis_names \
            or table.shape[0] % mesh.shape[model_axis]:
        return jnp.take(table, tokens, axis=0)
    daxes = data_axes or tuple(a for a in mesh.axis_names if a != model_axis)
    S = mesh.shape[model_axis]
    rows = table.shape[0] // S
    import numpy as np
    dp = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    shardable = tokens.shape[0] % dp == 0 and dp > 1
    tok_spec = P(daxes) if shardable else P()

    def emb(tab, tok):
        r = jax.lax.axis_index(model_axis)
        lo = r * rows
        idx = jnp.clip(tok - lo, 0, rows - 1)
        out = jnp.take(tab, idx, axis=0)
        ok = (tok >= lo) & (tok < lo + rows)
        out = jnp.where(ok[..., None], out, 0)
        return jax.lax.psum(out, model_axis)

    out_spec = P(daxes, None, None) if shardable else P(None, None, None)
    return shard_map_compat(
        emb, mesh=mesh,
        in_specs=(P(model_axis, None), tok_spec),
        out_specs=out_spec,
    )(table, tokens)


def token_nll(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """-log p(labels) per token; safe when the vocab axis is sharded.

    logits (B, T, V) any dtype; labels (B, T) int32 → (B, T) float32."""
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lg.max(axis=-1, keepdims=True))
    shifted = lg - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, dimension=2)
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], shifted, 0.0),
                     axis=-1)
    return lse - picked
