"""Heterogeneous shard planning — the paper's Theorem 1 applied to device
groups (DESIGN.md §2.3).

A TPU fleet is rarely uniform: mixed generations across pods, degraded
hosts, or DCN-distant pod groups.  Given per-group throughput profiles
(exactly the paper's (a, u, γ) triples at pod granularity), the planner:

* ``hetero_split`` — unequal data-parallel shard sizes ∝ 1/θ (Theorem 1),
  rounded to whole examples while preserving the global batch;
* ``replan_on_failure`` — elastic re-plan over the surviving groups (the
  paper's load re-allocation when Ω changes);
* ``coded_batch_plan`` — with MDS-coded gradient aggregation enabled, adds
  the Theorem-1 redundancy so the step completes from any prefix of groups
  whose loads sum to the required batch (straggler tolerance without
  re-execution).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from ..core.allocation import markov_loads
from ..sim.cluster import ClusterProfile

__all__ = ["hetero_split", "replan_on_failure", "coded_batch_plan",
           "coded_row_shards", "rescaled_row_shards"]


def _theta_of_profile(profile: ClusterProfile) -> np.ndarray:
    return np.array([profile.classes[c].unit_delay for c in profile.members])


def _largest_remainder_round(loads: np.ndarray, total: int) -> np.ndarray:
    """Round non-negative loads to integers summing to ``total``."""
    scaled = loads / loads.sum() * total
    base = np.floor(scaled).astype(int)
    rem = total - base.sum()
    order = np.argsort(-(scaled - base))
    base[order[:rem]] += 1
    return base


def coded_row_shards(l_row: np.ndarray, L: int) -> np.ndarray:
    """Integer per-node coded-row shard sizes from a fractional load row.

    This is the heterogeneous split applied to one master's Theorem-1/3
    load allocation ``l_row`` (node axis, column 0 = local): every positive
    load is ceiled (the paper drops integrality in (7c); rounding up only
    grows the redundancy, so recovery from any prefix covering ``L`` stays
    safe), and if share down-scaling left the rounded total below ``L`` the
    deficit is topped up by largest remainder over the participating nodes.
    """
    l_row = np.asarray(l_row, dtype=np.float64)
    shards = np.where(l_row > 0, np.ceil(l_row - 1e-9), 0.0).astype(np.int64)
    deficit = int(L) - int(shards.sum())
    if deficit > 0:
        active = np.nonzero(shards > 0)[0]
        if active.size == 0:
            raise ValueError("no participating nodes to cover L")
        top_up = _largest_remainder_round(l_row[active], deficit)
        shards[active] += top_up
    return shards


def rescaled_row_shards(l_row: np.ndarray, L_plan: float,
                        L_mat: int) -> np.ndarray:
    """Shard an ``L_mat``-row coded matrix by a load row planned for
    ``L_plan`` rows.

    The serving planner solves one Scenario (L = the padded vocabulary,
    the output head's row count), but per-layer coding distributes many
    weight matrices of different heights (d_ff, d_model, n_heads×d_head).
    The Theorem-1/3 load row fixes the per-worker *proportions* and the
    redundancy ratio — both scale-free (Kim et al. 2019's heterogeneous
    allocation is per unit row) — so a matrix of ``L_mat`` rows reuses the
    row scaled by ``L_mat / L_plan`` and integerised the usual way.
    """
    l_row = np.asarray(l_row, dtype=np.float64)
    if L_plan <= 0:
        raise ValueError("L_plan must be positive")
    return coded_row_shards(l_row * (float(L_mat) / float(L_plan)),
                            int(L_mat))


def hetero_split(profile: ClusterProfile, global_batch: int) -> np.ndarray:
    """Per-group batch shard sizes ∝ 1/θ (Theorem 1 without redundancy)."""
    theta = _theta_of_profile(profile)
    inv = 1.0 / theta
    return _largest_remainder_round(inv, global_batch)


def coded_batch_plan(profile: ClusterProfile, global_batch: int,
                     ) -> Tuple[np.ndarray, float]:
    """Theorem-1 loads *with* redundancy for coded gradient aggregation.

    Returns (integer per-group loads summing to ≈2×global_batch, predicted
    completion t* in the profile's time unit).  Any subset of groups whose
    loads reach ``global_batch`` reconstructs the full-batch gradient
    (k-of-n MDS property)."""
    theta = _theta_of_profile(profile)[None, :]   # single "master"
    l, t = markov_loads(np.array([float(global_batch)]), theta)
    total = int(round(l.sum()))
    return _largest_remainder_round(l[0], total), float(t[0])


def replan_on_failure(profile: ClusterProfile, global_batch: int,
                      failed: Sequence[int]) -> Tuple[ClusterProfile, np.ndarray]:
    """Drop failed groups, re-solve the split over survivors."""
    keep = [i for i in range(profile.N) if i not in set(failed)]
    if not keep:
        raise RuntimeError("no surviving worker groups")
    new_profile = dataclasses.replace(
        profile, members=tuple(profile.members[i] for i in keep))
    return new_profile, hetero_split(new_profile, global_batch)
