"""Heterogeneous shard planning — the paper's Theorem 1 applied to device
groups (DESIGN.md §2.3).

A TPU fleet is rarely uniform: mixed generations across pods, degraded
hosts, or DCN-distant pod groups.  Given per-group throughput profiles
(exactly the paper's (a, u, γ) triples at pod granularity), the planner:

* ``hetero_split`` — unequal data-parallel shard sizes ∝ 1/θ (Theorem 1),
  rounded to whole examples while preserving the global batch;
* ``replan_on_failure`` — elastic re-plan over the surviving groups (the
  paper's load re-allocation when Ω changes);
* ``coded_batch_plan`` — with MDS-coded gradient aggregation enabled, adds
  the Theorem-1 redundancy so the step completes from any prefix of groups
  whose loads sum to the required batch (straggler tolerance without
  re-execution).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from ..core.allocation import markov_loads
from ..sim.cluster import ClusterProfile

__all__ = ["hetero_split", "replan_on_failure", "coded_batch_plan"]


def _theta_of_profile(profile: ClusterProfile) -> np.ndarray:
    return np.array([profile.classes[c].unit_delay for c in profile.members])


def _largest_remainder_round(loads: np.ndarray, total: int) -> np.ndarray:
    """Round non-negative loads to integers summing to ``total``."""
    scaled = loads / loads.sum() * total
    base = np.floor(scaled).astype(int)
    rem = total - base.sum()
    order = np.argsort(-(scaled - base))
    base[order[:rem]] += 1
    return base


def hetero_split(profile: ClusterProfile, global_batch: int) -> np.ndarray:
    """Per-group batch shard sizes ∝ 1/θ (Theorem 1 without redundancy)."""
    theta = _theta_of_profile(profile)
    inv = 1.0 / theta
    return _largest_remainder_round(inv, global_batch)


def coded_batch_plan(profile: ClusterProfile, global_batch: int,
                     ) -> Tuple[np.ndarray, float]:
    """Theorem-1 loads *with* redundancy for coded gradient aggregation.

    Returns (integer per-group loads summing to ≈2×global_batch, predicted
    completion t* in the profile's time unit).  Any subset of groups whose
    loads reach ``global_batch`` reconstructs the full-batch gradient
    (k-of-n MDS property)."""
    theta = _theta_of_profile(profile)[None, :]   # single "master"
    l, t = markov_loads(np.array([float(global_batch)]), theta)
    total = int(round(l.sum()))
    return _largest_remainder_round(l[0], total), float(t[0])


def replan_on_failure(profile: ClusterProfile, global_batch: int,
                      failed: Sequence[int]) -> Tuple[ClusterProfile, np.ndarray]:
    """Drop failed groups, re-solve the split over survivors."""
    keep = [i for i in range(profile.N) if i not in set(failed)]
    if not keep:
        raise RuntimeError("no surviving worker groups")
    new_profile = dataclasses.replace(
        profile, members=tuple(profile.members[i] for i in keep))
    return new_profile, hetero_split(new_profile, global_batch)
