"""Distribution layer: sharding rules, heterogeneous shard planning."""
from .sharding import (param_shardings, batch_sharding, cache_shardings,
                       opt_state_shardings)  # noqa: F401
from .hetero import hetero_split, replan_on_failure  # noqa: F401
