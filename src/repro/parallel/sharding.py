"""Sharding rules: parameter / activation / cache PartitionSpecs.

Strategy (DESIGN.md §5):
* tensor-parallel ("model" axis): attention heads, FFN hidden, MoE experts,
  vocab — classic Megatron splits;
* fully-sharded data-parallel (("pod","data") axes): the largest remaining
  dim of every ≥2D weight is sharded across the DP axes (ZeRO-3 equivalent —
  XLA all-gathers weights on use, reduce-scatters grads);
* KV heads replicate when ``n_kv_heads`` doesn't divide the model axis (the
  standard GQA-under-TP fallback);
* 1D params (norm gains, biases) replicate.

Rules are *path+shape* driven so they apply to every architecture in the zoo
without per-arch tables.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_shardings", "batch_sharding", "cache_shardings",
           "opt_state_shardings", "data_axes_of"]


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _tp_dim(path: str, shape: Tuple[int, ...]) -> Optional[int]:
    """Which dim gets the 'model' axis for this leaf, or None."""
    nd = len(shape)
    # embeddings
    if path.endswith("embed.tok"):
        return 0                       # vocab rows
    if path.endswith("embed.out"):
        return 1                       # vocab cols
    # attention
    if path.endswith(".wq") or path.endswith("wq_b"):
        return 1                       # heads
    if path.endswith(".wk") or path.endswith(".wv"):
        return 1                       # kv heads (checked divisible by caller)
    if path.endswith(".wo") and nd == 3:
        return 0                       # heads
    if path.endswith("wk_b") or path.endswith("wv_b"):
        return 1                       # MLA heads
    # dense / shared FFN
    if path.endswith("w_in") and nd == 2:
        return 1
    if path.endswith("w_gate") and nd == 2:
        return 1
    if path.endswith("w_out") and nd == 2:
        return 0
    if "shared_in" in path or "shared_gate" in path:
        return 1
    if "shared_out" in path:
        return 0
    # MoE experts (E, d, f) / (E, f, d)
    if nd == 3 and (path.endswith("ffn.w_in") or path.endswith("ffn.w_gate")
                    or path.endswith("ffn.w_out")):
        return 0                       # expert axis
    # mamba
    if path.endswith("mixer.w_in") and nd == 2:
        return 1
    if path.endswith("mixer.w_out") and nd == 2:
        return 0
    if path.endswith("w_bcdt") or path.endswith("a_log"):
        return 0
    if path.endswith("mixer.conv"):
        return 1
    # rwkv
    if any(path.endswith(s) for s in (".wr", ".wk", ".wv", ".wg")) and nd == 2:
        return 1
    if path.endswith(".u") and nd == 2:
        return 0                       # heads
    return None


def _spec_for(path: str, shape: Tuple[int, ...], mesh: Mesh,
              *, fsdp: bool = True, stacked: bool = False,
              moe_full_ep: bool = False) -> P:
    """Build the PartitionSpec for one leaf.  ``stacked`` marks a leading
    n_repeats axis (from the block scan) that must stay unsharded."""
    model = mesh.shape.get("model", 1)
    daxes = data_axes_of(mesh)
    dp = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    off = 1 if stacked else 0
    body = shape[off:]
    spec: list = [None] * len(shape)

    # full-mesh expert parallelism: (E, d, f) → (E/dp, d, f/tp)
    if moe_full_ep and len(body) == 3 and (
            path.endswith("ffn.w_in") or path.endswith("ffn.w_gate")
            or path.endswith("ffn.w_out")) and body[0] % dp == 0:
        spec[off + 0] = daxes if len(daxes) > 1 else daxes[0]
        hid = 2 if path.endswith("ffn.w_in") or path.endswith("ffn.w_gate") \
            else 1
        if body[hid] % model == 0 and model > 1:
            spec[off + hid] = "model"
        return P(*spec)

    td = _tp_dim(path, body)
    if td is not None and body[td] % model == 0 and model > 1:
        spec[off + td] = "model"

    if fsdp and dp > 1 and len(body) >= 2:
        # shard the largest remaining dim over the DP axes
        cands = [i for i in range(len(body)) if spec[off + i] is None
                 and body[i] % dp == 0]
        if cands:
            big = max(cands, key=lambda i: body[i])
            if body[big] >= 2 * dp:     # don't shred small dims
                spec[off + big] = daxes if len(daxes) > 1 else daxes[0]
    return P(*spec)


def _paths(tree: Any, prefix: str = ""):
    """(path, leaf) pairs with dict keys joined by '.'."""
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            out += _paths(v, f"{prefix}.{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _paths(v, f"{prefix}[{i}]")
    else:
        out.append((prefix, tree))
    return out


def param_shardings(params_shapes: Any, mesh: Mesh, *, fsdp: bool = True,
                    moe_full_ep: bool = False):
    """NamedSharding tree matching a params (shape) tree.

    Leaves under 'blocks'/'enc_blocks' have a leading stacked n_repeats axis.
    """
    flat = _paths(params_shapes)
    specs = {}
    for path, leaf in flat:
        stacked = ("blocks" in path.split(".")[0] or ".blocks." in path
                   or path.startswith("enc_blocks"))
        specs[path] = _spec_for(path, tuple(leaf.shape), mesh, fsdp=fsdp,
                                stacked=stacked, moe_full_ep=moe_full_ep)

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}.{k}" if prefix else str(k))
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [rebuild(v, f"{prefix}[{i}]") for i, v in enumerate(tree)]
            return type(tree)(t)
        return NamedSharding(mesh, specs[prefix])

    return rebuild(params_shapes)


def batch_sharding(mesh: Mesh, batch_shape: Tuple[int, ...],
                   *, batch_dim: int = 0):
    """Shard the batch dim over the DP axes when divisible, else replicate
    (e.g. long_500k's global_batch=1)."""
    daxes = data_axes_of(mesh)
    dp = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    spec: list = [None] * len(batch_shape)
    if dp > 1 and batch_shape[batch_dim] % dp == 0:
        spec[batch_dim] = daxes if len(daxes) > 1 else daxes[0]
    return NamedSharding(mesh, P(*spec))


def cache_shardings(cache_shapes: Any, mesh: Mesh, batch: int):
    """KV caches: batch over DP axes when divisible; otherwise shard the
    sequence axis (long-context single-request decode); head-ish dims on
    'model' when divisible."""
    daxes = data_axes_of(mesh)
    dp = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    model = mesh.shape.get("model", 1)
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def spec_of(leaf):
        shp = leaf.shape
        spec: list = [None] * len(shp)
        # layout: (n_repeats, batch, seq, heads/dims...) or (batch, ...)
        bdim = 1 if len(shp) >= 2 and shp[0] != batch else 0
        if bdim < len(shp) and shp[bdim] == batch and batch % dp == 0 and dp > 1:
            spec[bdim] = dspec
        elif len(shp) > bdim + 1 and shp[bdim + 1] % dp == 0 and dp > 1 \
                and shp[bdim + 1] >= 4 * dp:
            spec[bdim + 1] = dspec      # sequence sharding fallback
        # try the model axis on a heads-like trailing dim
        for dim in range(len(shp) - 1, bdim + 1, -1):
            if spec[dim] is None and shp[dim] % model == 0 and model > 1 \
                    and shp[dim] >= model:
                spec[dim] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(spec_of, cache_shapes)


def opt_state_shardings(opt_shapes: Any, params_shardings: Any):
    """Optimizer-state shardings.

    AdamW moments mirror the parameter shardings exactly.  Adafactor's
    factored second moment inherits the parent spec with the reduced dim
    dropped (row = spec[:-1], col = spec[:-2] + spec[-1:]).  Scalars
    replicate.
    """
    flat_p, _ = jax.tree.flatten(params_shardings)
    mesh = flat_p[0].mesh
    rep = NamedSharding(mesh, P())

    if hasattr(opt_shapes, "mu"):          # AdamW OptState
        return type(opt_shapes)(step=rep, mu=params_shardings,
                                nu=params_shardings)

    if hasattr(opt_shapes, "second"):      # AdafactorState
        from ..optim.adafactor import _Factored

        def factored(ps):
            spec = list(ps.spec) + [None] * 8
            nd = len(ps.spec)
            row = P(*spec[:max(nd - 1, 0)])
            col = P(*(list(spec[:max(nd - 2, 0)]) + [spec[nd - 1]]
                      if nd >= 2 else []))
            return _Factored(row=NamedSharding(mesh, row),
                             col=NamedSharding(mesh, col))

        second = jax.tree.map(
            lambda leaf, ps: factored(ps) if isinstance(leaf, _Factored)
            else ps,
            opt_shapes.second, params_shardings,
            is_leaf=lambda t: isinstance(t, _Factored))
        return type(opt_shapes)(step=rep, second=second)

    raise TypeError(f"unknown optimizer state {type(opt_shapes)}")
