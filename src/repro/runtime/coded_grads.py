"""MDS-coded gradient aggregation (DESIGN.md §2.4).

The data-parallel gradient sum  g = Σ_n g_n  is itself a row-separable
linear map of the per-shard gradients, so the paper's row-coding applies
verbatim: stack the per-group microbatch gradients as rows of a matrix,
encode with the same systematic generator, and the master reconstructs the
full-batch gradient from **any** k of n group contributions.

On a real fleet the encode runs where the gradients live and the decode is a
small (k × k) solve on the aggregator; here both paths are jnp and the
straggler behaviour is simulated by the caller choosing the arrival subset.

``coded_grad_aggregate`` also supports int8 compression of the coded shards
(stochastic-rounding-free symmetric quantization) — the gradient-compression
hook from the brief's distributed-optimization list.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import mds

__all__ = ["encode_grad_shards", "coded_grad_aggregate"]


def _flatten(tree) -> Tuple[jnp.ndarray, list, list]:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, treedef, shapes


def _unflatten(flat, treedef, shapes):
    out, off = [], 0
    for s in shapes:
        n = int(np.prod(s)) if s else 1
        out.append(flat[off:off + n].reshape(s))
        off += n
    return jax.tree.unflatten(treedef, out)


def encode_grad_shards(grad_trees: Sequence, n_coded: int,
                       rng: np.random.Generator | int = 0):
    """Encode k per-group gradients into n_coded ≥ k shards.

    Returns (coded (n_coded, D) matrix, decode context).  The first k rows
    are systematic (the originals) — zero extra work on the fast path."""
    k = len(grad_trees)
    flat_list = []
    treedef = shapes = None
    for g in grad_trees:
        f, treedef, shapes = _flatten(g)
        flat_list.append(f)
    X = jnp.stack(flat_list)                       # (k, D)
    G = jnp.asarray(mds.make_generator(k, n_coded, kind="systematic",
                                       rng=rng, dtype=np.float32))
    coded = G @ X                                   # (n, D)
    return coded, {"G": G, "treedef": treedef, "shapes": shapes, "k": k}


def coded_grad_aggregate(coded: jnp.ndarray, ctx: dict,
                         arrived: Sequence[int],
                         *, compress_int8: bool = False):
    """Reconstruct the *sum* of the k group gradients from any k arrived
    coded shards.  Returns the aggregated gradient tree."""
    k = ctx["k"]
    arrived = list(arrived)[:k]
    if len(arrived) < k:
        raise ValueError(f"need {k} shards, got {len(arrived)}")
    rows = jnp.asarray(arrived)
    Y = coded[rows]                                  # (k, D)
    if compress_int8:
        scale = jnp.max(jnp.abs(Y), axis=1, keepdims=True) / 127.0
        Y = jnp.round(Y / jnp.maximum(scale, 1e-30)).astype(jnp.int8)
        Y = Y.astype(jnp.float32) * scale
    Gs = ctx["G"][rows]                              # (k, k)
    X_hat = jnp.linalg.solve(Gs, Y)                  # (k, D) recovered shards
    total = X_hat.sum(axis=0)
    return _unflatten(total, ctx["treedef"], ctx["shapes"])
