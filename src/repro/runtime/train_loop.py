"""Training loop + the jitted train_step factory.

``make_train_step`` builds the (params, opt, batch) → (params, opt, metrics)
function used by both the real trainer and the multi-pod dry-run:

* cross-entropy over the padded-vocab logits (labels never hit pad ids);
* optional MTP auxiliary loss (DeepSeek);
* gradient accumulation: the global batch is split into ``n_microbatches``
  scanned microbatches (grads accumulated in fp32) — the memory-term lever;
* AdamW or Adafactor update with cosine schedule.

``TrainLoop`` adds the operational shell: checkpoint/restore, preemption-
safe saves, straggler-aware coded gradient aggregation (optional), and
elastic re-sharding callbacks wired to ``parallel.hetero``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import TokenStream
from ..models import ArchConfig, ModelCtx, model_fwd, padded_vocab
from ..optim import (adafactor_init, adafactor_update, adamw_init,
                     adamw_update, cosine_warmup)

__all__ = ["TrainLoopConfig", "TrainLoop", "make_train_step", "loss_fn"]


def loss_fn(params, batch: Dict[str, jnp.ndarray], *, cfg: ArchConfig,
            ctx: ModelCtx = ModelCtx()) -> jnp.ndarray:
    from ..parallel.ops import token_nll
    out = model_fwd(params, batch, cfg=cfg, ctx=ctx)
    labels = batch["labels"]
    nll = token_nll(out["logits"], labels)     # vocab-shard-safe CE
    mask = batch.get("loss_mask")
    if mask is None:
        loss = nll.mean()
    else:
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.mtp and "mtp_logits" in out:
        # predict t+2: shift labels one extra step
        l2 = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
        loss = loss + 0.1 * token_nll(out["mtp_logits"], l2).mean()
    return loss


def make_train_step(cfg: ArchConfig, *, ctx: ModelCtx = ModelCtx(),
                    n_microbatches: int = 1,
                    lr_peak: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000,
                    opt_state_dtype: Optional[str] = None,
                    acc_dtype: str = "float32",
                    optimizer: str = "adamw",
                    ) -> Callable:
    """Build train_step(params, opt_state, batch) → (params, opt, metrics).

    With ``n_microbatches > 1`` the leading batch dim of every array in
    ``batch`` is reshaped to (n_micro, B/n_micro, ...) and scanned, grads
    accumulated in ``acc_dtype`` (fp32 default; bf16 halves the accumulator
    footprint — a §Perf memory-term lever for the ≥300B configs)."""
    schedule = cosine_warmup(lr_peak, warmup, total_steps)
    acc_dt = jnp.dtype(acc_dtype)

    def single(params, mb):
        return loss_fn(params, mb, cfg=cfg, ctx=ctx)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(single)(params, batch)
        else:
            def resh(x):
                return x.reshape((n_microbatches, x.shape[0] // n_microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(resh, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(single)(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(acc_dt),
                                     g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(acc_step, (0.0, zero), mbs)
            loss = loss / n_microbatches
            grads = jax.tree.map(
                lambda g, p: (g / n_microbatches).astype(p.dtype),
                grads, params)
        if optimizer == "adafactor":
            new_params, new_opt = adafactor_update(params, grads, opt_state,
                                                   lr=schedule)
        else:
            new_params, new_opt = adamw_update(params, grads, opt_state,
                                               lr=schedule)
        metrics = {"loss": loss, "step": new_opt.step,
                   "lr": schedule(new_opt.step)}
        return new_params, new_opt, metrics

    return train_step


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 300
    log_every: int = 10
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 2
    n_microbatches: int = 1
    lr_peak: float = 3e-4
    warmup: int = 50


class TrainLoop:
    """Operational training shell with checkpoint/restart and fault hooks."""

    def __init__(self, cfg: ArchConfig, loop_cfg: TrainLoopConfig,
                 stream: TokenStream, *, ctx: ModelCtx = ModelCtx(),
                 rng_seed: int = 0,
                 extra_feats: Optional[dict] = None):
        self.cfg = cfg
        self.loop_cfg = loop_cfg
        self.stream = stream
        self.ctx = ctx
        self.extra_feats = extra_feats or {}
        from ..models import init_model
        self.params = init_model(jax.random.PRNGKey(rng_seed), cfg)
        self.opt_state = adamw_init(self.params)
        self.step = 0
        self.ckpt = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
        self._train_step = jax.jit(make_train_step(
            cfg, ctx=ctx, n_microbatches=loop_cfg.n_microbatches,
            lr_peak=loop_cfg.lr_peak, warmup=loop_cfg.warmup,
            total_steps=loop_cfg.total_steps))

    # -- fault tolerance -----------------------------------------------------

    def try_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        (self.params, self.opt_state), _, extra = self.ckpt.restore(
            (self.params, self.opt_state), step=latest)
        self.step = extra["data_state"]["step"]
        self.stream = TokenStream.from_state(
            extra["data_state"], self.stream.vocab, self.stream.seq_len,
            self.stream.global_batch)
        return True

    def save(self):
        self.ckpt.save(self.step, (self.params, self.opt_state),
                       extra={"data_state": self.stream.state(self.step)})

    # -- main loop -------------------------------------------------------------

    def run(self, callback: Optional[Callable[[int, dict], None]] = None,
            ) -> list:
        history = []
        t0 = time.time()
        while self.step < self.loop_cfg.total_steps:
            raw = self.stream.batch(self.step)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            batch.update({k: jnp.asarray(v)
                          for k, v in self.extra_feats.items()})
            self.params, self.opt_state, metrics = self._train_step(
                self.params, self.opt_state, batch)
            self.step += 1
            if self.step % self.loop_cfg.log_every == 0 or \
                    self.step == self.loop_cfg.total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["wall_s"] = time.time() - t0
                history.append((self.step, m))
                if callback:
                    callback(self.step, m)
            if self.step % self.loop_cfg.ckpt_every == 0:
                self.save()
        self.save()
        return history
