"""Straggler-aware coded matmul executor — the paper's full workflow as an
executable engine.

Pipeline per master m (paper §II): Theorem-1/2 loads → MDS encode (Pallas
kernel on TPU, jnp elsewhere) → per-worker partial products → workers
"arrive" at sampled (comm + comp) delays → the master decodes from the
earliest prefix reaching L_m rows → completion time = that prefix's last
arrival.

This is simultaneously (a) the simulation backend for the paper's Fig. 2-6/8
(numerically exact completion delays), and (b) the fault-tolerance engine:
``run`` simply never waits for workers outside the decoding prefix, so a
dead worker (delay = inf) costs nothing once redundancy covers its load.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import mds
from ..core.delays import sample_total
from ..core.problem import Plan, Scenario

__all__ = ["CodedExecutor", "ExecutionReport"]


@dataclasses.dataclass
class ExecutionReport:
    completion: np.ndarray           # (M,) completion time of each master
    used_nodes: List[np.ndarray]     # per-master node ids in the decode prefix
    decode_ok: np.ndarray            # (M,) bool — result verified vs A x
    max_err: np.ndarray              # (M,) max |ŷ - A x|
    redundancy: np.ndarray           # (M,) Σl / L

    @property
    def overall(self) -> float:
        return float(self.completion.max())


class CodedExecutor:
    """Executes one realization of the coded multi-master computation."""

    def __init__(self, sc: Scenario, plan: Plan, *,
                 generator_kind: str = "systematic",
                 rng: np.random.Generator | int = 0):
        self.sc = sc
        self.plan = plan
        self.rng = (np.random.default_rng(rng)
                    if not isinstance(rng, np.random.Generator) else rng)
        self.generator_kind = generator_kind

    def run(self, A_list: Sequence[np.ndarray], x_list: Sequence[np.ndarray],
            dead_workers: Sequence[int] = (),
            ) -> Tuple[List[np.ndarray], ExecutionReport]:
        """Compute A_m x_m for every master through the coded pipeline.

        ``dead_workers`` are 1-based worker columns that never respond
        (fault injection)."""
        sc, plan = self.sc, self.plan
        loads = mds.integer_loads(plan.l, 0)
        results: List[np.ndarray] = []
        completion = np.zeros(sc.M)
        used, ok, errs = [], np.zeros(sc.M, bool), np.zeros(sc.M)

        delays = sample_total(self.rng, (), plan.l, plan.k, plan.b,
                              sc.a, sc.u, sc.gamma, local_col0=True)
        for w in dead_workers:
            delays[:, w] = np.inf
        # A NaN delay (poisoned sample) means "never arrives", same as a dead
        # worker — fold both into inf so ordering and prefix logic are exact.
        delays = np.where(np.isnan(delays), np.inf, delays)

        for m in range(sc.M):
            A, x = np.asarray(A_list[m]), np.asarray(x_list[m])
            L = A.shape[0]
            lm = loads[m]
            active = np.nonzero(lm > 0)[0]
            L_tilde = int(lm[active].sum())
            G = mds.make_generator(L, max(L_tilde, L),
                                   kind=self.generator_kind,
                                   rng=self.rng, dtype=np.float64)
            slices = mds.split_loads(L_tilde, lm[active])
            # per-node partial products  y_n = Ã_n x
            A_tilde = mds.encode(G[:L_tilde], A)
            y_parts = {int(n): A_tilde[rows] @ x
                       for n, rows in zip(active, slices)}

            # completion: earliest prefix of arrivals covering >= L rows.
            # Explicit finite mask BEFORE ordering: a dead/NaN worker ranked
            # anywhere in the sort must be *skipped* (it never arrives), not
            # terminate decoding — the live workers behind it still count.
            d_act = delays[m, active]
            finite = np.isfinite(d_act)
            order_j = np.argsort(np.where(finite, d_act, np.inf),
                                 kind="stable")
            got_rows: List[np.ndarray] = []
            got_y: List[np.ndarray] = []
            acc = 0
            t_done = np.inf
            prefix = []
            for j in order_j:
                if not finite[j]:
                    break           # only non-arrivals remain past this point
                n = int(active[j])
                idx = slices[j]
                got_rows.append(idx)
                got_y.append(y_parts[n])
                prefix.append(n)
                acc += idx.size
                if acc >= L:
                    t_done = d_act[j]
                    break
            completion[m] = t_done
            used.append(np.array(prefix))
            if acc >= L:
                rows = np.concatenate(got_rows)[:max(L, 0)]
                ys = np.concatenate(got_y)[:rows.size]
                # exactly-L decode (solve); redundancy beyond L is discarded
                rows_L, ys_L = rows[:L], ys[:L]
                y_hat = mds.decode(G[:L_tilde], rows_L, ys_L)
                truth = A @ x
                errs[m] = float(np.max(np.abs(y_hat - truth)))
                ok[m] = errs[m] <= 1e-6 * (1 + float(np.max(np.abs(truth))))
                results.append(y_hat)
            else:
                results.append(np.full(L, np.nan))

        report = ExecutionReport(
            completion=completion, used_nodes=used, decode_ok=ok,
            max_err=errs, redundancy=plan.l.sum(axis=1) / sc.L)
        return results, report
