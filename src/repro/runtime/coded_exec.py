"""Straggler-aware coded matmul executor — the paper's full workflow as an
executable engine.

Pipeline per master m (paper §II): Theorem-1/2 loads → MDS encode (Pallas
kernel on TPU, jnp elsewhere) → per-worker partial products → workers
"arrive" at sampled (comm + comp) delays → the master decodes from the
earliest prefix reaching L_m rows → completion time = that prefix's last
arrival.

This is simultaneously (a) the simulation backend for the paper's Fig. 2-6/8
(numerically exact completion delays), and (b) the fault-tolerance engine:
``run`` simply never waits for workers outside the decoding prefix, so a
dead worker (delay = inf) costs nothing once redundancy covers its load.

``run`` builds **one stacked problem over the master axis** and calls the
shared :mod:`repro.stream.backend` once per stage: a batched encode, a
single ``completion_times`` call over all masters, and a single
``decode_batch`` (with its systematic-prefix fast path) for every master
that completes.  On the default numpy backend this is bit-for-bit equal to
the legacy per-master loop — kept as :meth:`CodedExecutor._run_loop` and
asserted by the equivalence tests.  ``backend="jax"`` moves the linear
algebra onto the jitted jax path, ``backend="pallas"`` runs the encode /
coded-product Pallas kernels (real lowering on TPU, interpret elsewhere);
both compute in float32, so decode verification uses a looser tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import mds
from ..core.delays import sample_total
from ..core.problem import Plan, Scenario
from ..stream.backend import (check_backend, completion_times, decode_batch,
                              has_jax)

__all__ = ["CodedExecutor", "ExecutionReport"]


@dataclasses.dataclass
class ExecutionReport:
    completion: np.ndarray           # (M,) completion time of each master
    used_nodes: List[np.ndarray]     # per-master node ids in the decode prefix
    decode_ok: np.ndarray            # (M,) bool — result verified vs A x
    max_err: np.ndarray              # (M,) max |ŷ - A x|
    redundancy: np.ndarray           # (M,) Σl / L

    @property
    def overall(self) -> float:
        return float(self.completion.max())


@dataclasses.dataclass
class _MasterProblem:
    """One master's prepared (encoded-side) problem, pre-numerics."""
    m: int
    A: np.ndarray
    x: np.ndarray
    L: int
    L_tilde: int
    G: np.ndarray                    # (max(L_tilde, L), L)
    rows_L: Optional[np.ndarray]     # (L,) received row ids, None if DNF
    prefix: np.ndarray               # node ids in the decode prefix
    node_rows: List[Tuple[int, np.ndarray]]  # (node, its row slice) in order


class CodedExecutor:
    """Executes one realization of the coded multi-master computation.

    backend: "numpy" (default; bit-for-bit with the legacy per-master
    loop), "jax" (jitted stacked linear algebra) or "pallas" (encode /
    product kernels from ``repro.kernels``; interpret mode off-TPU).
    ``verify_tol`` is the relative decode-verification tolerance; the
    default is 1e-6 on numpy and 5e-4 on the float32 jax/pallas paths.
    """

    def __init__(self, sc: Scenario, plan: Plan, *,
                 generator_kind: str = "systematic",
                 rng: np.random.Generator | int = 0,
                 backend: str = "numpy",
                 verify_tol: Optional[float] = None):
        self.sc = sc
        self.plan = plan
        self.rng = (np.random.default_rng(rng)
                    if not isinstance(rng, np.random.Generator) else rng)
        self.generator_kind = generator_kind
        self.backend = check_backend(backend)
        if self.backend != "numpy" and not has_jax():
            self.backend = "numpy"   # graceful, like the backend layer
        self.verify_tol = (verify_tol if verify_tol is not None
                           else (1e-6 if self.backend == "numpy" else 5e-4))

    # ------------------------------------------------------------- staging

    def _prepare(self, A_list, x_list, dead_workers
                 ) -> Tuple[np.ndarray, List[_MasterProblem]]:
        """Sample delays, draw generators, and resolve every master's decode
        prefix — all randomness happens here, in the legacy draw order."""
        sc, plan = self.sc, self.plan
        loads = mds.integer_loads(plan.l, 0)

        delays = sample_total(self.rng, (), plan.l, plan.k, plan.b,
                              sc.a, sc.u, sc.gamma, local_col0=True)
        for w in dead_workers:
            delays[:, w] = np.inf
        # A NaN delay (poisoned sample) means "never arrives", same as a dead
        # worker — fold both into inf so ordering and prefix logic are exact.
        delays = np.where(np.isnan(delays), np.inf, delays)

        need = np.array([np.asarray(A).shape[0] for A in A_list],
                        dtype=np.float64)
        # one batched completion call over the master axis
        completion = completion_times(delays, loads.astype(np.float64), need)

        problems: List[_MasterProblem] = []
        for m in range(sc.M):
            A, x = np.asarray(A_list[m]), np.asarray(x_list[m])
            L = A.shape[0]
            lm = loads[m]
            active = np.nonzero(lm > 0)[0]
            L_tilde = int(lm[active].sum())
            G = mds.make_generator(L, max(L_tilde, L),
                                   kind=self.generator_kind,
                                   rng=self.rng, dtype=np.float64)
            slices = mds.split_loads(L_tilde, lm[active])
            # prefix bookkeeping: earliest arrivals until >= L rows.  A dead
            # or NaN worker ranked anywhere in the sort is *skipped* (it
            # never arrives); the live workers behind it still count.
            d_act = delays[m, active]
            finite = np.isfinite(d_act)
            order_j = np.argsort(np.where(finite, d_act, np.inf),
                                 kind="stable")
            got_rows: List[np.ndarray] = []
            node_rows: List[Tuple[int, np.ndarray]] = []
            prefix: List[int] = []
            acc = 0
            for j in order_j:
                if not finite[j]:
                    break           # only non-arrivals remain past this point
                n = int(active[j])
                got_rows.append(slices[j])
                node_rows.append((n, slices[j]))
                prefix.append(n)
                acc += slices[j].size
                if acc >= L:
                    break
            rows_L = (np.concatenate(got_rows)[:L] if acc >= L else None)
            problems.append(_MasterProblem(
                m=m, A=A, x=x, L=L, L_tilde=L_tilde, G=G, rows_L=rows_L,
                prefix=np.array(prefix), node_rows=node_rows))
        return completion, problems

    # ------------------------------------------------------------ numerics

    def _encode_products_np(self, p: _MasterProblem) -> np.ndarray:
        """(L,) received results for one master — legacy-exact numerics.

        Encode and per-node partial products run at the legacy loop's exact
        shapes (``G[:L̃] @ A`` then one gemv per prefix node), so the numpy
        path stays bit-for-bit; only nodes inside the decode prefix are
        computed (the legacy loop also multiplied never-used nodes)."""
        A_tilde = mds.encode(p.G[:p.L_tilde], p.A)
        parts = [A_tilde[idx] @ p.x for _, idx in p.node_rows]
        return np.concatenate(parts)[:p.L]

    def _encode_products_dev(self, group: List[_MasterProblem]) -> np.ndarray:
        """(B, L) received results for one same-shape group of masters, all
        stacked on device: one batched encode (Pallas ``mds_encode`` kernel
        on the pallas backend — real lowering on TPU, interpret elsewhere —
        plain jnp matmul on jax), one batched coded product, one gather of
        the received rows, one host transfer out (float32)."""
        import jax.numpy as jnp
        Lt = group[0].L_tilde
        G_stack = jnp.asarray(np.stack([p.G[:Lt] for p in group]))
        A_stack = jnp.asarray(np.stack([p.A for p in group]))
        x_stack = jnp.asarray(np.stack([p.x for p in group]))
        if self.backend == "pallas":
            from ..kernels import ops
            A_tilde = ops.mds_encode_batch(
                G_stack, A_stack,
                systematic=self.generator_kind == "systematic")
            y_full = ops.coded_matvec_batch(A_tilde, x_stack)
        else:
            A_tilde = jnp.matmul(G_stack, A_stack)
            xs = x_stack[..., None] if x_stack.ndim == 2 else x_stack
            y_full = jnp.matmul(A_tilde, xs)
            if x_stack.ndim == 2:
                y_full = y_full[..., 0]
        rows = jnp.asarray(np.stack([p.rows_L for p in group]))
        if y_full.ndim == 3:                   # matrix right-hand sides
            return np.asarray(jnp.take_along_axis(
                y_full, rows[..., None], axis=1))
        return np.asarray(jnp.take_along_axis(y_full, rows, axis=1))

    # ----------------------------------------------------------------- run

    def run(self, A_list: Sequence[np.ndarray], x_list: Sequence[np.ndarray],
            dead_workers: Sequence[int] = (),
            ) -> Tuple[List[np.ndarray], ExecutionReport]:
        """Compute A_m x_m for every master through the coded pipeline.

        ``dead_workers`` are 1-based worker columns that never respond
        (fault injection)."""
        sc, plan = self.sc, self.plan
        completion, problems = self._prepare(A_list, x_list, dead_workers)
        results: List[Optional[np.ndarray]] = [None] * sc.M
        ok = np.zeros(sc.M, bool)
        errs = np.zeros(sc.M)

        # group completed masters by problem shape → one stacked decode (and,
        # off-numpy, one stacked encode/product) per group.  The numpy path
        # only needs a common L to share the decode, so it groups coarser.
        groups: Dict[Tuple[int, ...], List[_MasterProblem]] = {}
        for p in problems:
            if p.rows_L is None:
                results[p.m] = np.full(p.L, np.nan)
                continue
            key = ((p.L, p.x.shape[1:]) if self.backend == "numpy"
                   else (p.L, p.L_tilde, p.A.shape[1], p.x.shape[1:]))
            groups.setdefault(key, []).append(p)

        for group in groups.values():
            if self.backend == "numpy":
                y_sel = np.stack([self._encode_products_np(p)
                                  for p in group])
            else:
                y_sel = self._encode_products_dev(group)
            rows = np.stack([p.rows_L for p in group])
            # "prefix" (scatter fast path only, full solve for mixed tasks)
            # keeps the bit-for-bit contract with the legacy _run_loop's
            # per-task mds.decode; the mixed-row substitution path is for
            # the streaming/serving decoders, which verify by tolerance.
            y_hat = decode_batch(
                [p.G for p in group], rows, y_sel, systematic="prefix",
                backend="numpy" if self.backend == "numpy" else "jax")
            for i, p in enumerate(group):
                truth = p.A @ p.x
                results[p.m] = y_hat[i]
                errs[p.m] = float(np.max(np.abs(y_hat[i] - truth)))
                ok[p.m] = errs[p.m] <= self.verify_tol * \
                    (1 + float(np.max(np.abs(truth))))

        report = ExecutionReport(
            completion=completion, used_nodes=[p.prefix for p in problems],
            decode_ok=ok, max_err=errs,
            redundancy=plan.l.sum(axis=1) / sc.L)
        return list(results), report

    # -------------------------------------------------- reference (legacy)

    def _run_loop(self, A_list: Sequence[np.ndarray],
                  x_list: Sequence[np.ndarray],
                  dead_workers: Sequence[int] = (),
                  ) -> Tuple[List[np.ndarray], ExecutionReport]:
        """The original per-master Python loop, kept verbatim as the
        reference implementation: the equivalence tests assert ``run`` (on
        the numpy backend) reproduces it bit-for-bit from the same seed."""
        sc, plan = self.sc, self.plan
        loads = mds.integer_loads(plan.l, 0)
        results: List[np.ndarray] = []
        completion = np.zeros(sc.M)
        used, ok, errs = [], np.zeros(sc.M, bool), np.zeros(sc.M)

        delays = sample_total(self.rng, (), plan.l, plan.k, plan.b,
                              sc.a, sc.u, sc.gamma, local_col0=True)
        for w in dead_workers:
            delays[:, w] = np.inf
        delays = np.where(np.isnan(delays), np.inf, delays)

        for m in range(sc.M):
            A, x = np.asarray(A_list[m]), np.asarray(x_list[m])
            L = A.shape[0]
            lm = loads[m]
            active = np.nonzero(lm > 0)[0]
            L_tilde = int(lm[active].sum())
            G = mds.make_generator(L, max(L_tilde, L),
                                   kind=self.generator_kind,
                                   rng=self.rng, dtype=np.float64)
            slices = mds.split_loads(L_tilde, lm[active])
            A_tilde = mds.encode(G[:L_tilde], A)
            y_parts = {int(n): A_tilde[rows] @ x
                       for n, rows in zip(active, slices)}

            d_act = delays[m, active]
            finite = np.isfinite(d_act)
            order_j = np.argsort(np.where(finite, d_act, np.inf),
                                 kind="stable")
            got_rows: List[np.ndarray] = []
            got_y: List[np.ndarray] = []
            acc = 0
            t_done = np.inf
            prefix = []
            for j in order_j:
                if not finite[j]:
                    break
                n = int(active[j])
                idx = slices[j]
                got_rows.append(idx)
                got_y.append(y_parts[n])
                prefix.append(n)
                acc += idx.size
                if acc >= L:
                    t_done = d_act[j]
                    break
            completion[m] = t_done
            used.append(np.array(prefix))
            if acc >= L:
                rows = np.concatenate(got_rows)[:max(L, 0)]
                ys = np.concatenate(got_y)[:rows.size]
                rows_L, ys_L = rows[:L], ys[:L]
                y_hat = mds.decode(G[:L_tilde], rows_L, ys_L)
                truth = A @ x
                errs[m] = float(np.max(np.abs(y_hat - truth)))
                ok[m] = errs[m] <= 1e-6 * (1 + float(np.max(np.abs(truth))))
                results.append(y_hat)
            else:
                results.append(np.full(L, np.nan))

        report = ExecutionReport(
            completion=completion, used_nodes=used, decode_ok=ok,
            max_err=errs, redundancy=plan.l.sum(axis=1) / sc.L)
        return results, report
