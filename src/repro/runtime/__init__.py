"""Distributed runtime: coded execution, straggler mitigation, train/serve
loops."""
from .coded_exec import CodedExecutor, ExecutionReport  # noqa: F401
from .coded_grads import coded_grad_aggregate, encode_grad_shards  # noqa: F401
from .straggler import BackupTaskPolicy, DeadlinePolicy  # noqa: F401
from .train_loop import TrainLoop, TrainLoopConfig  # noqa: F401
