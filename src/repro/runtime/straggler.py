"""Non-coded straggler-mitigation baselines the paper compares against:
replication / backup tasks ([7], [8]) and deadline-based cancellation
([13]'s cancellation idea).  Used by tests and the ablation benchmark to
show where coding wins.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["DeadlinePolicy", "BackupTaskPolicy"]


@dataclasses.dataclass
class DeadlinePolicy:
    """Launch the task everywhere; cancel once the needed rows arrived (the
    paper's 'cancellation' reference behaviour).  Wasted work = rows still
    running at completion."""
    def completion(self, delays: np.ndarray, loads: np.ndarray,
                   need: float) -> Tuple[float, float]:
        order = np.argsort(delays)
        acc = np.cumsum(loads[order])
        i = int(np.searchsorted(acc, need - 1e-9))
        if i >= len(order):
            return np.inf, 0.0
        t = delays[order[i]]
        wasted = float(loads[order[i + 1:]].sum())
        return float(t), wasted


@dataclasses.dataclass
class BackupTaskPolicy:
    """Redundancy-d replication: each unit task replicated on d workers,
    completion = d-th fastest replica per unit (matches [7]'s model at the
    granularity of whole shards)."""
    d: int = 2

    def completion(self, delays: np.ndarray) -> float:
        """delays: (n_tasks, d) replica delays → overall completion."""
        per_task = delays.min(axis=1)
        return float(per_task.max())
