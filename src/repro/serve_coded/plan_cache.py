"""Persistent step-plan cache: amortise per-step planning across a serve.

At steady state every serving step of a given dispatch width ``m`` asks
the exact same questions: the planner's row is unchanged, so the scaled
shares, the integer shard splits per matmul, the expected-delay row
assignment, the covering-prefix structures, the ragged-shard packing and
the stacked decode factorizations are all pure functions of
``(scenario, plan row, m)``.  Only the *realized* delays differ step to
step — and those are exact under MDS coding for any covering prefix, so
reusing the frozen structures changes no decoded value.

:class:`StepPlanCache` keys frozen :class:`StepPlan` entries by
``(scenario-context bytes, m, k_row, b_row)`` and stamps each with the
cache *epoch*.  Churn and drift replans bump the epoch and clear the
table (``invalidate``), so an in-flight step that dispatched before the
event detects its entry is stale (:meth:`is_current`) and rebuilds its
execution structures from the retimed barrier instead of trusting the
frozen ones.

The tracer counters ``plan_cache_hits`` / ``plan_cache_misses`` /
``plan_cache_invalidations`` make the steady state observable: a
churn-free serve must be all hits after the first step per width.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..obs import current_tracer

__all__ = ["StepPlan", "StepPlanCache"]


@dataclasses.dataclass
class StepPlan:
    """Frozen per-(plan row, m) planning state for a barrier step.

    ``l_ints``/``assign`` are computed at first use (the cache miss) and
    never mutated afterwards — barrier tasks hold row *views* into them.
    ``plans``/``stages`` are filled lazily by whichever execution engine
    runs first and reused by every later step while the entry is current.

    Parity metadata: each frozen ``PrefixPlan`` carries the packed
    threefry counters (``parity_ctrs`` — row index | redraw << 24) of its
    parity rows, stamped at plan time.  That is the *complete* seed
    schedule virtual-parity execution needs — replaying a frozen plan
    re-derives identical parity rows from the counters alone, with no
    encoded-row cache and no dependence on the layer's growth history
    (the counter derivation is what makes these entries safely
    freezable).  :meth:`parity_ctrs` collects them per task.
    """
    keys: List[str]
    l_ints: np.ndarray                 # (T, N+1) int64 shard splits
    assign: np.ndarray                 # (T, N+1) expected-delay row ranks
    epoch: int
    plans: Optional[Dict[str, Any]] = None      # name -> PrefixPlan
    stages: Dict[Tuple[str, ...], Any] = dataclasses.field(
        default_factory=dict)                   # stage key -> PackedStage

    def parity_ctrs(self) -> Dict[str, np.ndarray]:
        """Per-task packed parity-row counters frozen into this entry
        (tasks whose covering prefix used no parity rows are omitted)."""
        if not self.plans:
            return {}
        return {name: p.parity_ctrs for name, p in self.plans.items()
                if getattr(p, "parity_ctrs", None) is not None}


class StepPlanCache:
    """LRU table of :class:`StepPlan` entries, epoch-invalidated.

    The key folds in a caller-provided *context* (the effective-scenario
    bytes): a degrade event changes the closed-form loads without
    necessarily changing the plan row, and a later serve on the same
    bridge resets the scenario — both must miss rather than resurrect a
    stale split.
    """

    def __init__(self, maxsize: int = 32):
        self.maxsize = int(maxsize)
        self._entries: "OrderedDict[bytes, StepPlan]" = OrderedDict()
        self._ctx: bytes = b""
        self.epoch = 0
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.invalidations_by_reason: Dict[str, int] = {}

    def set_context(self, ctx: bytes) -> None:
        """Fold scenario-dependent bytes into every subsequent key."""
        self._ctx = bytes(ctx)

    def _key(self, m: int, k_row: np.ndarray, b_row: np.ndarray) -> bytes:
        return (self._ctx + m.to_bytes(4, "little")
                + k_row.tobytes() + b_row.tobytes())

    def lookup(self, m: int, k_row: np.ndarray,
               b_row: np.ndarray) -> Optional[StepPlan]:
        entry = self._entries.get(self._key(m, k_row, b_row))
        tr = current_tracer()
        if entry is None:
            self.misses += 1
            if tr is not None:
                tr.count("plan_cache_misses")
            return None
        self.hits += 1
        if tr is not None:
            tr.count("plan_cache_hits")
        self._entries.move_to_end(self._key(m, k_row, b_row))
        return entry

    def store(self, m: int, k_row: np.ndarray, b_row: np.ndarray,
              entry: StepPlan) -> StepPlan:
        self._entries[self._key(m, k_row, b_row)] = entry
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return entry

    def invalidate(self, reason: str = "") -> None:
        """Drop every entry and bump the epoch (stale-entry detection)."""
        self._entries.clear()
        self.epoch += 1
        self.invalidations += 1
        r = reason or "manual"
        self.invalidations_by_reason[r] = \
            self.invalidations_by_reason.get(r, 0) + 1
        tr = current_tracer()
        if tr is not None:
            tr.count("plan_cache_invalidations")
            tr.instant(f"plan_cache_invalidate:{reason or 'manual'}",
                       cat="plan")

    def is_current(self, entry: Optional[StepPlan]) -> bool:
        return entry is not None and entry.epoch == self.epoch
