"""MDS-coded execution of an arbitrary row-sharded linear layer.

The serving bridge treats every large matmul ``out = X @ W.T`` — the output
head, the attention q/k/v/o projections, the FFN up/down projections — as
one of the paper's coded tasks: the rows of W (L of them — padded_vocab for
the head, d_ff for the FFN up projection, d_model for the down projection,
…) are encoded with a systematic MDS generator ``G = [I; R]``, split into
per-node contiguous shards sized by the Theorem-1/3 load row (integerised
by :func:`repro.parallel.hetero.coded_row_shards` /
``rescaled_row_shards``), and each *arrived* shard's product is physically
computed as its own matmul — exactly what that worker would return.  The
earliest prefix of shard deliveries covering L rows decodes the exact
output through :func:`repro.stream.backend.decode_batch` (permutation
scatter when only systematic rows arrived, mixed-row substitution
otherwise).

Only the parity block ``R @ W`` needs encoding work; the systematic prefix
*is* W (the same identity-skipping trick the Pallas ``mds_encode`` kernel
uses).  Parity rows are generated lazily in seeded chunks, so each encoded
layer grows with the largest redundancy any plan requests.

Numerics: shard products and the decode run in float64 on the host, so the
decoded output matches the uncoded product to solver precision and greedy
argmax is bit-stable.  ``backend="jax"``/``"pallas"`` route the parity
encode through the device / Pallas kernel path (float32 — verify with the
looser tolerance, as in the streaming engine).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import List, Optional

import numpy as np

from ..core import mds
from ..stream import backend as bk

__all__ = ["CodedLinear", "LinearStep"]


@dataclasses.dataclass
class LinearStep:
    """Result of one coded linear execution."""
    out: np.ndarray             # (B, L) decoded — exact X @ W.T per row of X
    rows: np.ndarray            # (L,) coded-row ids used by the decode
    workers_used: np.ndarray    # node columns whose shards fed the decode
    rows_dispatched: int        # Σ integer shard sizes
    used_solve: bool            # parity rows in the prefix → general solve

    @property
    def logits(self) -> np.ndarray:
        """Head-layer alias: the decoded product *is* the logits batch."""
        return self.out


class CodedLinear:
    """Systematic-MDS-encoded linear layer, executed shard-by-shard.

    W: (L, D) float weight matrix, row-sharded across workers.
    name: label used by the bridge's step log ("head", "blk0.wq", ...).
    seed: parity-generator seed (one layer = one generator stream).
    backend: "numpy" | "jax" | "pallas" for the parity encode + decode
    solve.
    """

    def __init__(self, W: np.ndarray, *, name: str = "linear",
                 seed: int = 0, backend: str = "numpy",
                 parity_chunk: int = 256):
        bk.check_backend(backend)
        if backend != "numpy" and not bk.has_jax():
            backend = "numpy"
        self.W = np.asarray(W, dtype=np.float64)
        self.L, self.D = self.W.shape
        self.name = name
        self.backend = backend
        self.parity_chunk = int(parity_chunk)
        # crc32, not hash(): parity streams must replay across processes
        self._rng = np.random.default_rng((int(seed), 0xC0DE,
                                           zlib.crc32(name.encode())))
        self.R = np.zeros((0, self.L))            # parity generator rows
        self.WR = np.zeros((0, self.D))           # encoded parity shards
        self._G_cache: Optional[np.ndarray] = None

    # -- encoding ------------------------------------------------------------

    def _encode_parity(self, R_new: np.ndarray) -> np.ndarray:
        if self.backend == "numpy":
            return R_new @ self.W
        import jax.numpy as jnp
        if self.backend == "pallas":
            from ..kernels import ops
            G_blk = np.concatenate([np.eye(self.L), R_new]).astype(np.float32)
            full = np.asarray(ops.mds_encode(jnp.asarray(G_blk),
                                             jnp.asarray(self.W, jnp.float32)))
            return full[self.L:].astype(np.float64)
        return np.asarray(jnp.asarray(R_new, jnp.float32)
                          @ jnp.asarray(self.W, jnp.float32),
                          dtype=np.float64)

    def ensure_parity(self, n_parity: int) -> None:
        """Grow the encoded parity block to ≥ ``n_parity`` rows."""
        while self.R.shape[0] < n_parity:
            R_new = self._rng.normal(0.0, 1.0 / np.sqrt(self.L),
                                     size=(self.parity_chunk, self.L))
            self.R = np.concatenate([self.R, R_new])
            self.WR = np.concatenate([self.WR, self._encode_parity(R_new)])
            self._G_cache = None

    def generator(self, L_tilde: int) -> np.ndarray:
        """The systematic generator [I; R] truncated to ``L_tilde`` rows."""
        self.ensure_parity(max(L_tilde - self.L, 0))
        if self._G_cache is None or self._G_cache.shape[0] < L_tilde:
            self._G_cache = np.concatenate([np.eye(self.L), self.R])
        return self._G_cache[:L_tilde]

    def encoded_rows(self, rows: np.ndarray) -> np.ndarray:
        """Gather encoded weight rows (systematic prefix = W itself)."""
        rows = np.asarray(rows)
        out = np.empty((rows.size, self.D))
        sys_m = rows < self.L
        out[sys_m] = self.W[rows[sys_m]]
        out[~sys_m] = self.WR[rows[~sys_m] - self.L]
        return out

    # -- reference -----------------------------------------------------------

    def local(self, X: np.ndarray) -> np.ndarray:
        """The uncoded product X @ W.T (float64) — the verify reference and
        the matmul the ``coded=False`` bridge serves with."""
        return np.asarray(X, dtype=np.float64) @ self.W.T

    # -- one step ------------------------------------------------------------

    def step(self, X: np.ndarray, l_int: np.ndarray, finish: np.ndarray,
             t_complete: float) -> LinearStep:
        """Execute one coded product for an activation batch.

        X:      (B, D) input activations (float64); each row is one token/
                position of the step's batch.
        l_int:  (N+1,) integer shard sizes (Σ ≥ L; contiguous row slices in
                node order, exactly the executor's dispatch layout).
        finish: (N+1,) absolute delivery times (inf = never); the earliest
                prefix covering L by ``t_complete`` feeds the decode.
        """
        X = np.asarray(X, dtype=np.float64)
        l_int = np.asarray(l_int, dtype=np.int64)
        total = int(l_int.sum())
        if total < self.L:
            raise ValueError(f"shards cover {total} < L={self.L} rows")
        self.ensure_parity(total - self.L)
        active = np.nonzero(l_int > 0)[0]
        slices = mds.split_loads(total, l_int[active])
        order = np.argsort(np.where(np.isfinite(finish[active]),
                                    finish[active], np.inf), kind="stable")
        got_rows: List[np.ndarray] = []
        got_y: List[np.ndarray] = []
        used: List[int] = []
        acc = 0
        for j in order:
            if not np.isfinite(finish[active[j]]) or \
                    finish[active[j]] > t_complete + 1e-9:
                continue
            rows_j = slices[j]
            # the per-worker shard execution: this node's encoded rows × X
            got_y.append(self.encoded_rows(rows_j) @ X.T)
            got_rows.append(rows_j)
            used.append(int(active[j]))
            acc += rows_j.size
            if acc >= self.L:
                break
        if acc < self.L:
            raise RuntimeError("deliveries do not cover L by t_complete")
        rows = np.concatenate(got_rows)[:self.L]
        y = np.concatenate(got_y)[:self.L]            # (L, B)
        used_solve = bool((rows >= self.L).any())
        G = self.generator(total)
        z = bk.decode_batch(
            G, rows[None], y[None],
            backend="numpy" if self.backend == "numpy" else "jax")[0]
        return LinearStep(out=z.T, rows=rows,
                          workers_used=np.asarray(used),
                          rows_dispatched=total, used_solve=used_solve)
