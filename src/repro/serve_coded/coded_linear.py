"""MDS-coded execution of an arbitrary row-sharded linear layer.

The serving bridge treats every large matmul ``out = X @ W.T`` — the output
head, the attention q/k/v/o projections, the FFN up/down projections — as
one of the paper's coded tasks: the rows of W (L of them — padded_vocab for
the head, d_ff for the FFN up projection, d_model for the down projection,
…) are encoded with a systematic MDS generator ``G = [I; R]``, split into
per-node contiguous shards sized by the Theorem-1/3 load row (integerised
by :func:`repro.parallel.hetero.coded_row_shards` /
``rescaled_row_shards``), and each *arrived* shard's product is physically
computed as its own matmul — exactly what that worker would return.  The
earliest prefix of shard deliveries covering L rows decodes the exact
output through :func:`repro.stream.backend.decode_batch` (permutation
scatter when only systematic rows arrived, mixed-row substitution
otherwise); *within* that prefix the decoder prefers the received
systematic rows — any L delivered coded rows recover the product, so
picking identity rows first shrinks the parity solve to the coverage
shortfall (see :meth:`CodedLinear.prefix_plan`).

**Counter-derived parity.**  Every parity generator row is a pure
function of ``(seed, name, row index)`` through the threefry counter
derivation in :func:`repro.core.mds.counter_parity_rows`: rows are
derived in fixed ``parity_chunk``-aligned blocks, each block's
conditioning-guard redraw index is itself deterministic, and therefore
row r carries identical bits no matter in what order or granularity the
cache grew — across replans, serves, and processes.  (The historical
implementation drew parity from one *sequential* ``default_rng`` stream,
so a row's values depended on the growth history — a replay bug this
module fixed when virtual storage made the contract load-bearing.)

**Two parity storage modes.**

``parity_storage="materialized"`` (default): the encoded matrix
``[W; WR]`` lives in one packed row-major buffer per layer, grown
*incrementally*: the systematic prefix is W itself (the
identity-skipping trick the Pallas ``mds_encode`` kernel uses), and each
lazily-derived parity block appends ``R_block @ W`` without re-encoding
anything already cached.  Shard execution in both the serial and the
batched engine is a gather from this cache — ``device_rows`` maintains
the float32 device-resident mirror the same incremental way for the
jax/pallas batched kernel path.

``parity_storage="virtual"``: nothing is materialised beyond W itself
plus the per-row seed schedule (packed threefry counters).  Host-side
shard execution derives the few parity rows a covering prefix actually
uses block-by-block on demand (a tiny LRU memo keeps the hot blocks of
a frozen plan resident — bit-identical to the materialised encode, the
same ``R_block @ W`` call on the same block); the device path hands the
packed counters to the generated-parity Pallas kernel
(:func:`repro.kernels.ops.gen_parity_products`), which re-derives each
parity tile inside the grid and contracts it against the resident W —
no ``[W; WR]`` mirror in HBM.  At redundancy 2 this halves
encoded-weight memory (see :meth:`CodedLinear.encoded_cache_bytes`).

**Prefix planning vs execution.**  :meth:`prefix_plan` derives the
earliest covering prefix (which coded rows, from which workers, in
delivery order) from the dispatch timing alone — no activations needed —
so the batched engine plans every matmul of a step barrier up front and
executes the packed products in one pass.  :meth:`step` is the serial
reference: the same plan, executed shard-by-shard.

Numerics: decode-feeding shard products run through
:func:`shard_products` — a float64 ``np.einsum`` contraction whose
per-row bits are independent of how the rows are batched (unlike BLAS
GEMM, whose edge-panel handling changes with the row count), so the
batched engine is bit-identical to the serial loop by construction.
``backend="jax"``/``"pallas"`` route the parity encode and the decode
solve through the device / Pallas kernel path (float32 encode — verify
with the looser tolerance, as in the streaming engine).
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
import zlib
from typing import List, Optional

import numpy as np

from ..core import mds
from ..obs import current_tracer
from ..stream import backend as bk

__all__ = ["CodedLinear", "CodedLMHead", "LinearStep", "HeadStep",
           "PrefixPlan", "shard_products", "prefix_plan_batch",
           "surplus_plan"]

#: the decode solve engine each backend actually runs ("pallas" has encode
#: and product kernels but no solve kernel — its decode runs the jitted
#: jax solve, and benches report that honestly instead of silently
#: relabelling it)
DECODE_ENGINE = {"numpy": "numpy", "jax": "jax", "pallas": "jax"}

#: smallest mixed-row parity solve block (see ``prefix_plan``): blocks
#: below this swap in extra delivered parity rows for the last systematic
#: pins, bounding the inverse-norm tail of tiny Gaussian sub-blocks
MIN_PARITY_BLOCK = 8


def _assemble_prefix(L: int, workers: np.ndarray, starts: np.ndarray,
                     stops_: np.ndarray):
    """Systematic-first row selection within a fixed covering prefix.

    ``workers``/``starts``/``stops_`` describe the delivered shards in
    delivery order (node column, row-range start, row-range stop).  Pick
    the received systematic rows (< L) first and fill the remainder with
    the earliest-delivered parity rows, honouring the MIN_PARITY_BLOCK
    conditioning floor.  The quota arithmetic is vectorised — the old
    sequential per-worker cut/take loop is exactly
    ``clip(quota − cumsum_excl(avail), 0, avail)`` — and only the final
    ``np.arange`` row materialisation loops (short: one pass over the
    prefix's workers).

    Returns ``(rows, slices, used)`` as in :class:`PrefixPlan`.
    """
    sizes = stops_ - starts
    c = np.clip(L - starts, 0, sizes)            # systematic part per shard
    n_sys = int(c.sum())
    par = sizes - c                              # parity rows available
    # parity-fill budget: at least the shortfall; when a solve is needed
    # at all, at least MIN_PARITY_BLOCK rows (a tiny Gaussian block has a
    # fat inverse-norm tail that amplifies the float32 parity-encode error
    # on the jax/pallas backends); never more than L rows total
    budget = L - n_sys
    if budget > 0:
        budget = min(max(budget, MIN_PARITY_BLOCK), int(par.sum()), L)
    sys_quota = L - budget
    cuts = np.clip(sys_quota - (np.cumsum(c) - c), 0, c)
    takes = np.clip(budget - (np.cumsum(par) - par), 0, par)
    slices: List[np.ndarray] = []
    used: List[int] = []
    for w, a, ci, cut, take in zip(workers, starts, c, cuts, takes):
        if cut + take == 0:
            continue
        part = np.arange(a, a + cut) if take == 0 else (
            np.arange(a + ci, a + ci + take) if cut == 0 else
            np.concatenate([np.arange(a, a + cut),
                            np.arange(a + ci, a + ci + take)]))
        slices.append(part)
        used.append(int(w))
    rows = np.concatenate(slices) if len(slices) > 1 else slices[0]
    return rows, slices, np.asarray(used)


def prefix_plan_batch(linears, barrier) -> dict:
    """Covering prefixes for a whole step barrier in one stacked pass.

    Replaces the per-matmul Python planning (~15 ``prefix_plan`` calls
    per trunk step) with one batched selection:
    :meth:`repro.stream.barrier.StepBarrier.covering_selections` computes
    every task's delivered-shard prefix (orders, coverage, row-range
    edges) as stacked array ops, and the per-task remainder is just the
    vectorised quota assembly above.  Bit-identical to calling
    ``prefix_plan`` per task — both run the same selection math and the
    same :func:`_assemble_prefix`.

    ``linears`` maps task name → :class:`CodedLinear`.  Returns
    ``{task.name: PrefixPlan}``.
    """
    plans = {}
    for task, (workers, starts, stops_) in zip(
            barrier.tasks, barrier.covering_selections()):
        lin = linears[task.name]
        total = int(task.l_int.sum())
        if total < lin.L:
            raise ValueError(f"shards cover {total} < L={lin.L} rows")
        lin.ensure_parity(total - lin.L)
        rows, slices, used = _assemble_prefix(lin.L, workers, starts, stops_)
        par = rows[rows >= lin.L] - lin.L
        plans[task.name] = PrefixPlan(
            rows=rows, slices=slices, used=used, total=total,
            used_solve=bool(par.size),
            parity_ctrs=lin.parity_ctrs(par) if par.size else None)
    return plans


def surplus_plan(l_int: np.ndarray, finish: np.ndarray, t_complete: float,
                 plan: PrefixPlan, *, cap: int = 8,
                 assign: Optional[np.ndarray] = None):
    """Delivered coded rows *beyond* a covering prefix — verification fuel.

    MDS redundancy means a dispatch usually delivers more than L rows by
    the barrier completion; the decode uses exactly L of them
    (``plan.rows``) and historically discarded the rest.  The fault
    detector instead spends up to ``cap`` of those surplus rows as parity
    residual checks (each surplus row's product must agree with the
    decoded estimate — see :func:`repro.stream.backend.verify_decode`),
    and the LS tail consumes them for an over-determined solve.

    Same selection math as :meth:`CodedLinear.prefix_plan` (row-range
    layout under ``assign``, delivery cutoff ``t_complete``), earliest
    deliveries first.  Returns ``(rows, row_workers)`` — absolute coded
    row ids and the worker column each came from, aligned.
    """
    l_int = np.asarray(l_int, dtype=np.int64)
    total = int(l_int.sum())
    active = np.nonzero(l_int > 0)[0]
    l_act = l_int[active]
    if assign is None:
        starts_act = np.concatenate([[0], np.cumsum(l_act)[:-1]]).astype(
            np.int64)
    else:
        aorder = np.argsort(np.asarray(assign)[active], kind="stable")
        starts_act = np.empty(active.size, dtype=np.int64)
        starts_act[aorder] = np.concatenate(
            [[0], np.cumsum(l_act[aorder])[:-1]])
    f_act = np.asarray(finish, dtype=np.float64)[active]
    ok = np.isfinite(f_act) & (f_act <= t_complete + 1e-9)
    order = np.argsort(np.where(ok, f_act, np.inf), kind="stable")
    in_prefix = np.zeros(total, dtype=bool)
    in_prefix[plan.rows] = True
    rows_out: List[np.ndarray] = []
    wk_out: List[np.ndarray] = []
    n = 0
    for i in order:
        if not ok[i] or n >= cap:
            break
        r = np.arange(starts_act[i], starts_act[i] + l_act[i])
        keep = r[~in_prefix[r]][:cap - n]
        if keep.size:
            rows_out.append(keep)
            wk_out.append(np.full(keep.size, active[i], dtype=np.int64))
            n += keep.size
    if not rows_out:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
    return np.concatenate(rows_out), np.concatenate(wk_out)


def shard_products(W_rows: np.ndarray, X: np.ndarray) -> np.ndarray:
    """Per-shard products ``W_rows @ X.T`` (rows, B) in float64.

    This is the one product primitive both execution engines share.  It is
    deliberately an ``np.einsum`` contraction, not BLAS ``@``: einsum's
    per-row reduction order depends only on the contraction length D, so
    computing a shard's rows alone, per worker, or packed into a step-wide
    buffer gives bit-identical rows — the property the batched engine's
    exactness tests rely on (BLAS GEMM edge panels break it).
    """
    return np.einsum("ld,bd->lb", W_rows, X)


@dataclasses.dataclass
class PrefixPlan:
    """The earliest covering prefix of one dispatched coded matmul.

    Pure timing — derived from shard sizes and delivery times before any
    activation exists, which is what lets the batched engine pack a whole
    step barrier's gathers and decode structure at dispatch time.
    """
    rows: np.ndarray            # (L,) coded-row ids feeding the decode
    slices: List[np.ndarray]    # per-used-worker row ids, delivery order
    used: np.ndarray            # worker columns, delivery order
    total: int                  # Σ integer shard sizes dispatched
    used_solve: bool            # parity rows in the prefix → general solve
    #: packed threefry counters of the prefix's parity rows (rows ≥ L, in
    #: row order) — the seed/row-block metadata frozen plans carry so
    #: virtual-parity execution needs no encoded-row cache to replay
    parity_ctrs: Optional[np.ndarray] = None

    def row_workers(self) -> np.ndarray:
        """Worker column of every row in ``rows``, aligned — the
        attribution the fault detector localises residual flags with."""
        return np.repeat(self.used,
                         [len(sl) for sl in self.slices]).astype(np.int64)


@dataclasses.dataclass
class LinearStep:
    """Result of one coded linear execution."""
    out: np.ndarray             # (B, L) decoded — exact X @ W.T per row of X
    rows: np.ndarray            # (L,) coded-row ids used by the decode
    workers_used: np.ndarray    # node columns whose shards fed the decode
    rows_dispatched: int        # Σ integer shard sizes
    used_solve: bool            # parity rows in the prefix → general solve
    decode_backend: str = "numpy"   # effective decode-solve engine

    @property
    def logits(self) -> np.ndarray:
        """Head-layer alias: the decoded product *is* the logits batch."""
        return self.out


#: how many derived / encoded parity blocks the virtual mode keeps warm —
#: a frozen steady-state plan touches a handful of parity blocks per step,
#: so a small LRU makes virtual serving gather-speed without growing the
#: footprint toward the materialised cache it exists to avoid
PARITY_BLOCK_MEMO = 4


class CodedLinear:
    """Systematic-MDS-encoded linear layer with a persistent encoded cache.

    W: (L, D) float weight matrix, row-sharded across workers.
    name: label used by the bridge's step log ("head", "blk0.wq", ...).
    seed: parity-generator seed (one layer = one generator stream).
    backend: "numpy" | "jax" | "pallas" for the parity encode + decode
    solve.  If jax is unavailable the layer *warns* and falls back to
    numpy — ``requested_backend`` keeps the ask, ``backend`` the truth.
    parity_storage: "materialized" caches ``[W; WR]`` rows; "virtual"
    derives parity from packed threefry counters on demand (module
    docstring).
    """

    def __init__(self, W: np.ndarray, *, name: str = "linear",
                 seed: int = 0, backend: str = "numpy",
                 parity_chunk: int = 256,
                 parity_storage: str = "materialized"):
        bk.check_backend(backend)
        self.requested_backend = backend
        if backend != "numpy" and not bk.has_jax():
            warnings.warn(
                f"CodedLinear({name!r}): backend {backend!r} requested but "
                "jax is not importable — falling back to backend='numpy' "
                "(float64 encode/decode; slower, tighter numerics)",
                RuntimeWarning, stacklevel=2)
            backend = "numpy"
        if parity_storage not in ("materialized", "virtual"):
            raise ValueError(
                f"parity_storage must be 'materialized' or 'virtual', "
                f"got {parity_storage!r}")
        self.W = np.asarray(W, dtype=np.float64)
        self.L, self.D = self.W.shape
        self.name = name
        self.backend = backend
        self.decode_backend = DECODE_ENGINE[backend]
        self.parity_chunk = int(parity_chunk)
        self.parity_storage = parity_storage
        # crc32, not hash(): parity must replay across processes.  The
        # threefry key is the only per-layer generator state — every
        # parity row is a pure function of (key, packed row counter).
        self.pkey = (zlib.crc32(name.encode()) & 0xFFFFFFFF,
                     (int(seed) ^ 0x9E3779B9) & 0xFFFFFFFF)
        self._block_draws = {}    # block id -> conditioning-guard redraw
        self._block_memo = {}     # block id -> derived R block (LRU)
        self._encb_memo = {}      # block id -> encoded R_b @ W block (LRU)
        self._n_avail = 0         # virtual mode: logical parity rows grown
        if parity_storage == "materialized":
            self._R = np.zeros((0, self.L))       # parity generator rows
            # packed encoded cache [W; WR]: rows [0, L) are W itself (the
            # systematic prefix needs no encode), parity rows append below
            self._enc = np.empty((self.L, self.D))
            self._enc[:] = self.W
            self._n_enc = self.L
        else:
            self._R = None
            self._enc = self.W   # systematic prefix only — a *view*, no copy
            self._n_enc = self.L
        self.parity_redraws = 0                   # conditioning-guard hits
        self._G_cache: Optional[np.ndarray] = None
        self._dplan_memo = None                   # (rows bytes, DecodePlan)
        self._W_dev = None                        # f32 device copy of W
        self._enc_dev = None                      # f32 device [W; WR] mirror
        self._n_dev = 0

    @property
    def R(self) -> np.ndarray:
        """Materialised parity generator rows (use :meth:`parity_rows` for
        storage-agnostic access)."""
        if self._R is None:
            raise RuntimeError(
                f"CodedLinear({self.name!r}): parity_storage='virtual' keeps "
                "no dense R — gather rows via parity_rows(ids)")
        return self._R

    @property
    def WR(self) -> np.ndarray:
        """Encoded parity rows — a view into the packed cache."""
        if self.parity_storage != "materialized":
            raise RuntimeError(
                f"CodedLinear({self.name!r}): parity_storage='virtual' keeps "
                "no [W; WR] cache — gather via gather_encoded(rows)")
        return self._enc[self.L:self._n_enc]

    @property
    def n_parity(self) -> int:
        if self.parity_storage == "virtual":
            return self._n_avail
        return self._n_enc - self.L

    # -- encoding ------------------------------------------------------------

    def _encode_parity(self, R_new: np.ndarray) -> np.ndarray:
        if self.backend == "numpy":
            return R_new @ self.W
        import jax.numpy as jnp
        if self._W_dev is None:
            # uploaded once per matrix; parity chunks reuse it
            self._W_dev = jnp.asarray(self.W, jnp.float32)
        R_dev = jnp.asarray(R_new, jnp.float32)
        if self.backend == "pallas":
            from ..kernels import ops
            return np.asarray(ops.matmul(R_dev, self._W_dev),
                              dtype=np.float64)
        return np.asarray(R_dev @ self._W_dev, dtype=np.float64)

    def _grow_enc(self, n_new: int) -> None:
        need = self._n_enc + n_new
        if need > self._enc.shape[0]:
            cap = max(need, 2 * self._enc.shape[0])
            grown = np.empty((cap, self.D))
            grown[:self._n_enc] = self._enc[:self._n_enc]
            self._enc = grown

    @staticmethod
    def _memo_put(memo: dict, key: int, val: np.ndarray) -> None:
        """Tiny insertion-order LRU (dicts iterate oldest-first)."""
        memo.pop(key, None)
        memo[key] = val
        while len(memo) > PARITY_BLOCK_MEMO:
            memo.pop(next(iter(memo)))

    def _derive_block(self, b: int) -> np.ndarray:
        """Derive parity block ``b`` (``parity_chunk`` rows) from counters.

        Pure function of ``(pkey, b)``: the conditioning-guard redraw index
        is found by bumping the counter's draw byte until the block passes
        :func:`repro.core.mds.parity_cond` — the *same* deterministic walk
        regardless of when, or in what growth order, the block is first
        needed.  That growth-history independence is the replay bug fix:
        the old sequential ``default_rng`` stream gave row r different
        values depending on how the cache had grown before it."""
        blk = self._block_memo.get(b)
        if blk is not None:
            self._memo_put(self._block_memo, b, blk)   # refresh LRU slot
            return blk
        ids = np.arange(b * self.parity_chunk, (b + 1) * self.parity_chunk)
        draw = self._block_draws.get(b)
        if draw is None:
            draw = 0
            blk = mds.counter_parity_rows(
                self.pkey, mds.parity_counters(ids, draw), self.L)
            while mds.parity_cond(blk) > mds.PARITY_COND_LIMIT:
                draw += 1
                self.parity_redraws += 1
                blk = mds.counter_parity_rows(
                    self.pkey, mds.parity_counters(ids, draw), self.L)
            self._block_draws[b] = draw
        else:
            blk = mds.counter_parity_rows(
                self.pkey, mds.parity_counters(ids, draw), self.L)
        self._memo_put(self._block_memo, b, blk)
        return blk

    def _encoded_block(self, b: int) -> np.ndarray:
        """Encoded parity block ``R_b @ W`` (virtual mode, memoised).

        Always encodes the *full* aligned block in one ``_encode_parity``
        call — the identical dgemm the materialised growth path issues for
        the same block, so gathered rows are bit-equal across modes."""
        enc = self._encb_memo.get(b)
        if enc is None:
            enc = self._encode_parity(self._derive_block(b))
        self._memo_put(self._encb_memo, b, enc)
        return enc

    def ensure_parity(self, n_parity: int) -> None:
        """Grow the available parity region to ≥ ``n_parity`` rows.

        Materialised: derive + encode whole ``parity_chunk`` blocks and
        append them to the packed ``[W; WR]`` cache.  Virtual: only the
        logical row count grows — derivation happens lazily per gathered
        block.  Either way each block passes the
        :func:`repro.core.mds.parity_cond` conditioning guard (a collapsed
        singular spectrum is the symptom of every degenerate decode minor)
        via a deterministic redraw-index walk."""
        tr = current_tracer()
        if tr is not None:
            # hit/miss of the persistent encoded cache: a miss pays a
            # parity derivation (+ encode when materialised), a hit is a
            # pure row gather
            tr.count("encode_cache_hits" if self.n_parity >= n_parity
                     else "encode_cache_misses")
        if self.parity_storage == "virtual":
            if n_parity > self._n_avail:
                if tr is not None:
                    tr.count("encode_cache_miss_rows",
                             n_parity - self._n_avail)
                self._n_avail = n_parity
                self._G_cache = None
            return
        while self.n_parity < n_parity:
            R_new = self._derive_block(self.n_parity // self.parity_chunk)
            self._R = np.concatenate([self._R, R_new])
            enc = self._encode_parity(R_new)
            self._grow_enc(enc.shape[0])
            self._enc[self._n_enc:self._n_enc + enc.shape[0]] = enc
            self._n_enc += enc.shape[0]
            self._G_cache = None
            if tr is not None:
                tr.count("encode_cache_miss_rows", enc.shape[0])

    # -- storage-agnostic parity access --------------------------------------

    def parity_rows(self, ids: np.ndarray) -> np.ndarray:
        """Generator parity rows R[ids] (float64), either storage mode.

        ``ids`` are 0-based indices into the parity region (absolute coded
        row minus L).  Materialised mode slices the dense R; virtual mode
        derives the covering blocks (memoised).  Bit-identical between the
        modes — both ultimately come from the same counter derivation."""
        ids = np.asarray(ids, dtype=np.int64)
        if self.parity_storage == "materialized":
            self.ensure_parity(int(ids.max()) + 1 if ids.size else 0)
            return self._R[ids]
        out = np.empty((ids.size, self.L))
        for b in np.unique(ids // self.parity_chunk):
            m = (ids // self.parity_chunk) == b
            out[m] = self._derive_block(int(b))[ids[m] % self.parity_chunk]
        return out

    def parity_ctrs(self, ids: np.ndarray) -> np.ndarray:
        """Packed threefry counters for parity rows ``ids`` — the only
        per-row metadata a frozen plan (or the generated-parity kernel)
        needs.  Deriving them walks the covering blocks' conditioning
        guards, so the redraw byte is already folded in."""
        ids = np.asarray(ids, dtype=np.int64)
        blocks = ids // self.parity_chunk
        for b in np.unique(blocks):
            if int(b) not in self._block_draws:
                self._derive_block(int(b))
        draws = np.asarray([self._block_draws[int(b)] for b in blocks],
                           dtype=np.int64)
        return mds.parity_counters(ids, draws)

    def gather_encoded(self, rows: np.ndarray,
                       out: Optional[np.ndarray] = None) -> np.ndarray:
        """Encoded weight rows ``[W; WR][rows]`` (float64), either mode.

        The one gather primitive both execution engines use.  Materialised:
        a fancy-index into the packed cache.  Virtual: systematic rows come
        straight from W and parity rows from memoised per-block encodes —
        the same full-block dgemm the materialised path ran, so the bits
        match across modes."""
        rows = np.asarray(rows)
        if self.parity_storage == "materialized":
            if out is None:
                return self._enc[:self._n_enc][rows]
            np.take(self._enc[:self._n_enc], rows, axis=0, out=out)
            return out
        if out is None:
            out = np.empty((rows.size, self.D))
        sys_m = rows < self.L
        if sys_m.any():
            out[sys_m] = self.W[rows[sys_m]]
        pids = rows[~sys_m] - self.L
        if pids.size:
            pout = np.empty((pids.size, self.D))
            for b in np.unique(pids // self.parity_chunk):
                m = (pids // self.parity_chunk) == b
                pout[m] = self._encoded_block(int(b))[
                    pids[m] % self.parity_chunk]
            out[~sys_m] = pout
        return out

    def encoded_cache_bytes(self) -> int:
        """Resident encoded-weight bytes (host + device) beyond the model.

        Materialised counts the packed ``[W; WR]`` buffer (full capacity),
        the dense R, and the float32 device mirrors; virtual counts only
        the LRU block memos and the float32 device W — its host systematic
        prefix is a *view* of W, not a copy.  The benchmark gate holds the
        virtual/materialised ratio ≤ 0.55 at redundancy 2."""
        n = 0
        if self.parity_storage == "materialized":
            n += self._enc.nbytes + self._R.nbytes
            if self._enc_dev is not None:
                n += self._n_dev * self.D * 4
        else:
            n += sum(b.nbytes for b in self._block_memo.values())
            n += sum(b.nbytes for b in self._encb_memo.values())
        if self._W_dev is not None:
            n += self.L * self.D * 4
        return n

    def device_W(self):
        """Float32 device-resident W — the operand the generated-parity
        kernel contracts counter-derived tiles against (uploaded once)."""
        import jax.numpy as jnp
        if self._W_dev is None:
            self._W_dev = jnp.asarray(self.W, jnp.float32)
        return self._W_dev

    def generator(self, L_tilde: int) -> np.ndarray:
        """The systematic generator [I; R] truncated to ``L_tilde`` rows.

        Materialises the dense generator — virtual-mode decode planning
        avoids this via :class:`repro.stream.backend.SystematicRows`, but
        the dense form stays available for reference/verify paths."""
        self.ensure_parity(max(L_tilde - self.L, 0))
        if self._G_cache is None or self._G_cache.shape[0] < L_tilde:
            n_par = max(L_tilde - self.L, 0)
            R = (self._R if self.parity_storage == "materialized"
                 else self.parity_rows(np.arange(n_par)))
            self._G_cache = np.concatenate([np.eye(self.L), R])
        return self._G_cache[:L_tilde]

    def encoded_rows(self, rows: np.ndarray) -> np.ndarray:
        """Gather encoded weight rows from the packed cache."""
        return self.gather_encoded(rows)

    def device_rows(self, n_rows: int):
        """Float32 device-resident ``[W; WR]`` prefix of ``n_rows`` rows.

        Uploaded once and grown *incrementally*: only parity rows encoded
        since the last call transfer to the device — the persistent cache
        the batched kernel path gathers its shard tiles from.  Virtual
        storage keeps no such mirror (the generated-parity kernel derives
        parity in-grid against :meth:`device_W`), so this raises there."""
        if self.parity_storage != "materialized":
            raise RuntimeError(
                f"CodedLinear({self.name!r}): parity_storage='virtual' "
                "keeps no device [W; WR] mirror — the batched device path "
                "uses device_W() + parity_ctrs() with the generated-parity "
                "kernel instead")
        import jax.numpy as jnp
        self.ensure_parity(max(n_rows - self.L, 0))
        tr = current_tracer()
        if self._enc_dev is None:
            self._enc_dev = jnp.asarray(self._enc[:self._n_enc], jnp.float32)
            if tr is not None:
                tr.count("device_cache_upload_rows", self._n_enc)
            self._n_dev = self._n_enc
        elif self._n_dev < self._n_enc:
            fresh = jnp.asarray(self._enc[self._n_dev:self._n_enc],
                                jnp.float32)
            self._enc_dev = jnp.concatenate([self._enc_dev, fresh])
            if tr is not None:
                tr.count("device_cache_upload_rows",
                         self._n_enc - self._n_dev)
            self._n_dev = self._n_enc
        else:
            if tr is not None:
                tr.count("device_cache_hits")
        return self._enc_dev[:n_rows]

    # -- reference -----------------------------------------------------------

    def local(self, X: np.ndarray) -> np.ndarray:
        """The uncoded product X @ W.T (float64) — the verify reference and
        the matmul the ``coded=False`` bridge serves with."""
        return np.asarray(X, dtype=np.float64) @ self.W.T

    # -- prefix planning -----------------------------------------------------

    def prefix_plan(self, l_int: np.ndarray, finish: np.ndarray,
                    t_complete: float,
                    order: Optional[np.ndarray] = None,
                    assign: Optional[np.ndarray] = None) -> PrefixPlan:
        """Derive the earliest covering prefix of a dispatch — timing only.

        l_int:  (N+1,) integer shard sizes (Σ ≥ L; contiguous row slices,
                exactly the executor's dispatch layout).
        finish: (N+1,) absolute delivery times (inf = never); the earliest
                prefix covering L by ``t_complete`` feeds the decode.
        order:  optional pre-computed stable argsort of the active nodes'
                finish times (the step barrier computes all tasks' orders
                in one batched call).
        assign: optional (N+1,) sort key fixing which node holds which
                contiguous row range.  ``None`` assigns ranges in node
                order (the historical layout).  The serving bridge passes
                each node's *expected* delay (dispatch-time information
                only — no realized delays), so the systematic prefix sits
                on the statistically fastest nodes: covering prefixes then
                carry mostly identity rows, the decode's parity block
                shrinks, and the pure-scatter fast path fires far more
                often.  Any assignment decodes exactly — this is purely a
                decode-cost optimisation the systematic code enables.
        """
        l_int = np.asarray(l_int, dtype=np.int64)
        total = int(l_int.sum())
        if total < self.L:
            raise ValueError(f"shards cover {total} < L={self.L} rows")
        self.ensure_parity(total - self.L)
        active = np.nonzero(l_int > 0)[0]
        l_act = l_int[active]
        if assign is None:
            edges = np.concatenate([[0], np.cumsum(l_act)])
        else:
            aorder = np.argsort(assign[active], kind="stable")
            starts = np.empty(active.size, dtype=np.int64)
            starts[aorder] = np.concatenate(
                [[0], np.cumsum(l_act[aorder])[:-1]])
            edges = np.concatenate([starts, [total]])  # per-active starts
        f_act = finish[active]
        if order is None:
            order = np.argsort(np.where(np.isfinite(f_act), f_act, np.inf),
                               kind="stable")
        f_ord = f_act[order]
        ok = np.isfinite(f_ord) & (f_ord <= t_complete + 1e-9)
        cum = np.cumsum(np.where(ok, l_act[order], 0))
        stop = int(np.searchsorted(cum, self.L))
        if stop >= cum.size or cum[stop] < self.L:
            raise RuntimeError("deliveries do not cover L by t_complete")
        sel = np.nonzero(ok[:stop + 1])[0]
        picked = order[sel]
        # the covering prefix is fixed (completion semantics untouched);
        # *within* it, decode from the received systematic rows first and
        # fill the remainder with the earliest-delivered parity rows —
        # the decode-free fast path the systematic code exists for.  With
        # the expected-delay assignment above, most prefixes then pin
        # (nearly) every coordinate by scatter and the parity solve block
        # shrinks to the overlap shortfall.
        starts = edges[picked]
        stops_ = starts + l_act[picked]
        rows, slices, used = _assemble_prefix(self.L, active[picked],
                                              starts, stops_)
        par = rows[rows >= self.L] - self.L
        return PrefixPlan(rows=rows, slices=slices, used=used, total=total,
                          used_solve=bool(par.size),
                          parity_ctrs=self.parity_ctrs(par)
                          if par.size else None)

    # -- decode --------------------------------------------------------------

    def decode_plan(self, rows: np.ndarray) -> bk.DecodePlan:
        """X-independent decode structure for one received-rows vector
        (the generator is systematic by construction — the identity-prefix
        scan is skipped).  Memoised on the received-rows vector: at steady
        state every step of a serve decodes the same frozen prefix, so the
        factorization is computed once and replayed."""
        key = rows.tobytes()
        if self._dplan_memo is not None and self._dplan_memo[0] == key:
            return self._dplan_memo[1]
        total = max(int(rows.max()) + 1, self.L)
        if self.parity_storage == "virtual":
            # lazy-row generator adapter: the planner gathers only the
            # parity rows the mixed groups actually solve with — the dense
            # (total, L) G is never formed
            G = bk.SystematicRows(self.L, total, self.parity_rows)
        else:
            G = self.generator(total)
        plan = bk.plan_decode(G, rows[None], identity_prefix=True)
        self._dplan_memo = (key, plan)
        return plan

    # -- one step (the serial reference engine) ------------------------------

    def step(self, X: np.ndarray, l_int: np.ndarray, finish: np.ndarray,
             t_complete: float,
             assign: Optional[np.ndarray] = None,
             plan: Optional[PrefixPlan] = None,
             mutate=None) -> LinearStep:
        """Execute one coded product for an activation batch, shard by
        shard — the serial reference the batched engine is bit-checked
        against.

        X: (B, D) input activations (float64); each row is one token/
        position of the step's batch.  See :meth:`prefix_plan` for the
        timing arguments.  ``plan`` supplies a pre-computed (possibly
        cached) covering prefix; planning is skipped entirely then.
        ``mutate(y, plan)`` is the fault injector's hook, called on the
        freshly assembled (L, B) product block before the decode — the
        serial twin of :meth:`PackedStage.execute`'s ``mutate``.
        """
        X = np.asarray(X, dtype=np.float64)
        tr = current_tracer()
        if plan is None:
            ctx = tr.span(f"plan:{self.name}", cat="plan") \
                if tr is not None else contextlib.nullcontext()
            with ctx:
                plan = self.prefix_plan(l_int, finish, t_complete,
                                        assign=assign)
        # the per-worker shard execution: each node's encoded rows × X
        ctx = tr.span(f"product:{self.name}", cat="kernel",
                      args={"rows": int(plan.rows.size),
                            "workers": int(plan.used.size)}) \
            if tr is not None else contextlib.nullcontext()
        with ctx:
            y = np.concatenate([shard_products(self.gather_encoded(sl), X)
                                for sl in plan.slices])       # (L, B)
        if mutate is not None:
            mutate(y, plan)
        # decode_plan / apply time themselves (repro.stream.backend spans)
        z = self.decode_plan(plan.rows).apply(
            y[None], backend=self.backend)[0]
        return LinearStep(out=z.T, rows=plan.rows,
                          workers_used=plan.used,
                          rows_dispatched=plan.total,
                          used_solve=plan.used_solve,
                          decode_backend=self.decode_backend)


# ---------------------------------------------------------------------------
# The output head — a named CodedLinear
# ---------------------------------------------------------------------------

#: Result of one coded head execution (``.logits`` aliases ``.out``).
HeadStep = LinearStep


class CodedLMHead(CodedLinear):
    """Systematic-MDS-encoded output head, executed shard-by-shard.

    Historically the bridge coded only the output-head matmul and a
    separate module held this implementation; the per-layer
    generalisation is :class:`CodedLinear` and the head is now just the
    instance named ``"head"``: W is ``launch.serve.head_matrix``
    (L = padded vocab) and the step result exposes the decoded product
    as ``.logits``.

    W: (L, D) float weight matrix.
    seed: parity-generator seed (one head = one generator stream).
    backend: "numpy" | "jax" | "pallas" for the parity encode + decode
    solve.
    """

    def __init__(self, W: np.ndarray, *, seed: int = 0,
                 backend: str = "numpy", parity_chunk: int = 256,
                 parity_storage: str = "materialized"):
        super().__init__(W, name="head", seed=seed, backend=backend,
                         parity_chunk=parity_chunk,
                         parity_storage=parity_storage)
