"""repro.serve_coded — coded computation as the inference server's policy.

The bridge (:class:`CodedServingBridge`) serves real prefill/decode token
generation (``repro.launch.serve`` model stack) where the large matmuls of
every token batch are MDS-coded tasks planned by the streaming machinery
(``repro.stream``): the OnlinePlanner's (k, b, l) allocation picks the
worker shards, the SharePool enforces the paper's column-sum ≤ 1 ledger
across tenants' concurrent steps, and a pluggable admission policy
("fifo" | "edf" | "fair") arbitrates which waiting requests join a batch.
``coding_scope`` picks how deep the coding reaches — the output head
("head"), plus the FFN up/down projections ("ffn"), or the whole trunk
including attention q/k/v/o ("trunk") — and decoded outputs are exact:
greedy tokens are bit-identical to the uncoded pipeline at every scope.

See ``src/repro/stream/README.md`` (serving-bridge section) for the
architecture, the coding-scope table and the admission-policy table.
"""
from .bridge import (CODING_SCOPES, EXECUTION_MODES, CodedServingBridge,
                     ServeReport, default_pool)
from .coded_linear import (CodedLinear, CodedLMHead, HeadStep, LinearStep,
                           PrefixPlan, prefix_plan_batch, shard_products)
from .packing import PackedShards, PackedStage, ShardProblem
from .plan_cache import StepPlan, StepPlanCache
from .requests import ServeRequest, synthetic_requests
from .trunk import HostTrunk, trunk_matmul_keys

__all__ = [
    "CodedServingBridge", "ServeReport", "default_pool", "CODING_SCOPES",
    "EXECUTION_MODES",
    "CodedLMHead", "HeadStep", "CodedLinear", "LinearStep", "PrefixPlan",
    "prefix_plan_batch", "shard_products",
    "PackedShards", "PackedStage", "ShardProblem",
    "StepPlan", "StepPlanCache",
    "HostTrunk", "trunk_matmul_keys",
    "ServeRequest", "synthetic_requests",
    "serve_policy_sweep", "print_policy_table", "run_coded_smoke",
    "write_trace_summary",
]


def serve_policy_sweep(bridge: CodedServingBridge, requests, policies,
                       churn=()):
    """Serve the same workload once per admission policy on one bridge.

    The model, jitted step functions and encoded layers are
    policy-independent, so only the admission config swaps between runs —
    the columns of the resulting reports are directly comparable.  With the
    bridge's ``verify`` on (numpy backend), each run is asserted to decode
    every coded matmul to the uncoded product.
    """
    from ..stream.queueing import AdmissionConfig
    reports = {}
    for policy in policies:
        bridge.admission = AdmissionConfig(policy=policy)
        rep = bridge.serve(requests, churn=churn)
        if rep.decode_ok is not None:
            assert rep.decode_ok, (
                f"{policy}: coded decode diverged from the uncoded "
                f"pipeline (max_err={rep.max_err:.2e}, "
                f"match={rep.argmax_match_rate:.3f})")
        assert rep.tokens_generated > 0 and len(rep.steps) > 0
        reports[policy] = rep
    return reports


def print_policy_table(reports) -> None:
    """One row per admission policy: throughput, sojourn tail, misses."""
    print(f"{'policy':<7} {'tok/sim-s':>10} {'p50 ms':>9} {'p99 ms':>9} "
          f"{'miss%':>6} {'waste':>6} {'steps':>6} {'solves':>6} "
          f"{'max_err':>9}")
    for policy, rep in reports.items():
        s = rep.summary()
        print(f"{policy:<7} {s['tokens_per_sim_second']:10.1f} "
              f"{s.get('sojourn_p50', float('nan')):9.1f} "
              f"{s.get('sojourn_p99', float('nan')):9.1f} "
              f"{100.0 * s.get('deadline_miss_rate', 0.0):6.1f} "
              f"{s.get('wasted_fraction', 0.0):6.2f} "
              f"{len(rep.steps):6d} {rep.solve_steps:6d} "
              f"{rep.max_err:9.2e}")


def write_trace_summary(tracer, path, verbose: bool = True) -> None:
    """Write ``tracer``'s Chrome/Perfetto trace to ``path`` and print a
    one-line per-stage wall breakdown (load the file in
    https://ui.perfetto.dev to browse the spans)."""
    tracer.write(path)
    if verbose:
        s = tracer.summary()
        stages = "  ".join(f"{k}={v * 1e3:.1f}ms"
                           for k, v in s["per_stage_wall"].items())
        cov = s["stage_coverage"]
        print(f"[trace] {path}: {s['span_count']} spans, {stages}, "
              f"stage coverage "
              f"{'n/a' if cov is None else format(cov, '.3f')}")


def run_coded_smoke(*, arch: str = "llama3.2-1b", smoke: bool = True,
                    policies=("fifo", "edf", "fair"),
                    n_requests: int = 12, prompt_len: int = 16,
                    gen_len: int = 8, masters: int = 2,
                    slots_per_master: int = 3, rate: float = 0.004,
                    coding_scope: str = "head",
                    steps_per_dispatch: int = 1,
                    execution: str = "batched",
                    backend: str = "numpy", seed: int = 0,
                    trace=None, faults=None, ls_tail: bool = False,
                    verbose: bool = True):
    """Serve one synthetic workload under each admission policy.

    Returns 0 on success (CLI-friendly); asserts that every decoded coded
    matmul matched the uncoded product (numpy backend).  ``trace`` writes
    a Chrome/Perfetto trace of the whole sweep (every policy's serve, as
    sibling "serve" spans) to that path.  ``faults`` (a fault spec string
    or :class:`repro.faults.FaultConfig`) arms the chaos layer —
    injected crash/drop/stale/corrupt faults are detected, localised and
    recovered during the serve, and a per-policy fault summary prints
    after the table.  ``ls_tail`` routes every decode through the
    stacked-LS tail (bit-identical at exactly L rows).
    """
    if isinstance(faults, str):
        from ..faults import parse_fault_spec
        faults = parse_fault_spec(faults)
    tracer = None
    if trace:
        from ..obs import Tracer
        tracer = Tracer(meta={"entry": "run_coded_smoke", "arch": arch,
                              "scope": coding_scope, "backend": backend,
                              "execution": execution})
    from ..stream import AdmissionConfig, StreamConfig
    bridge = CodedServingBridge(
        masters=masters, arch=arch, smoke=smoke, backend=backend,
        config=StreamConfig(admission=AdmissionConfig(policy="edf"),
                            rng=seed),
        slots_per_master=slots_per_master, coding_scope=coding_scope,
        steps_per_dispatch=steps_per_dispatch, execution=execution,
        faults=faults, ls_tail=ls_tail, tracer=tracer)
    bridge._setup_model(prompt_len + gen_len + 8)
    reqs = synthetic_requests(
        n_requests, masters=masters, vocab=bridge._model["cfg"].vocab,
        prompt_len=prompt_len, gen_len=gen_len, rate=rate, seed=seed)
    reports = serve_policy_sweep(bridge, reqs, policies)
    if verbose:
        print(f"[serve_coded] arch={arch} requests={n_requests} "
              f"gen={gen_len} masters={masters} "
              f"slots/master={slots_per_master} scope={coding_scope} "
              f"steps/dispatch={steps_per_dispatch} "
              f"execution={execution} backend={backend}")
        print_policy_table(reports)
        if faults is not None:
            for policy, rep in reports.items():
                f = rep.faults or {}
                print(f"[faults] {policy}: injected={f.get('injected', 0):.0f} "
                      f"detection={f.get('detection_rate', 1.0):.3f} "
                      f"localization={f.get('localization_rate', 1.0):.3f} "
                      f"quarantines={f.get('quarantines', 0):.0f} "
                      f"readmissions={f.get('readmissions', 0):.0f} "
                      f"retries={f.get('retries', 0):.0f} "
                      f"modes={rep.decode_modes}")
        print("[serve_coded] all decoded coded matmuls matched the uncoded "
              "pipeline")
    if tracer is not None:
        write_trace_summary(tracer, trace, verbose)
    return 0
