"""Ragged-shard packing: a step's coded shard products as one pass.

The serial engine executes a coded matmul shard-by-shard: one small host
matmul per worker per matrix, then one decode per matmul — a trunk-scope
step with 15 per-layer tasks pays that Python loop ~75 times per token
(`BENCH_serve.json`'s head-vs-trunk wall gap).  This module is the batched
alternative: the *prefix plans* of all matmuls that share a right-hand
operand (one dependency stage of the forward — q/k/v share the
post-norm hidden states, up/gate share the FFN input) are packed into one
row-gather over the layers' persistent encoded caches, executed as a
single product, and decoded through one stacked
:func:`repro.stream.backend.plan_decode` per row-count group.

Layout.  A :class:`PackedShards` concatenates each problem's prefix rows
(gathered from :attr:`CodedLinear._enc`) into one (P, D) float64 buffer
with per-problem offsets — rows stay in delivery order, so slicing the
packed product at the offsets reproduces the serial per-task results
*bit-identically* (the product primitive is row-stable; see
:func:`repro.serve_coded.coded_linear.shard_products`).  For the device
path the same buffer is padded to ``tile``-aligned row tiles and a
128-aligned contraction width::

    problem 0: rows r00 r01 r02 …   ┐ gather            ┌ tile 0 (128, Dp)
    problem 1: rows r10 r11 …       ├──────▶ (P, D) ──▶ │ tile 1 (128, Dp)
    problem 2: rows r20 …           ┘  pad P→T·128,     └ …   (zero rows)
                                       D→Dp=⌈D/128⌉·128

and :func:`repro.kernels.ops.coded_shard_matmul_batch` runs every tile in
one launch (Pallas grid on TPU, ``vmap`` fallback elsewhere).  The
float32 device products are a verification/offload path — decode-feeding
products stay float64 host-side so greedy tokens remain bit-identical to
the uncoded pipeline on every backend.

X-independence.  Everything here is built from dispatch timing alone
(prefix rows, packed gathers, stacked decode plans), so the bridge packs
a whole :class:`~repro.stream.barrier.StepBarrier` when the step is
dispatched and only the products + solves run inside the token loop —
and a multi-token dispatch (``steps_per_dispatch``) re-uses the packs for
every token.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import current_tracer
from ..stream import backend as bk
from .coded_linear import CodedLinear, shard_products

__all__ = ["ShardProblem", "PackedShards", "PackedStage",
           "pack_shard_problems"]


@dataclasses.dataclass
class ShardProblem:
    """One coded matmul's prefix execution spec inside a packed stage."""
    key: str
    linear: CodedLinear
    rows: np.ndarray            # (L,) coded-row ids, delivery order
    used_solve: bool


class PackedShards:
    """Packed row-gather over the problems' persistent encoded caches.

    ``products(X)`` is the one-pass host execution; ``device_tiles()`` /
    ``products_device(X)`` are the 128-aligned tile layout and the
    one-launch kernel execution for the jax/pallas backends.
    """

    def __init__(self, problems: Sequence[ShardProblem], *, tile: int = 128):
        if not problems:
            raise ValueError("pack needs at least one problem")
        D = {p.linear.D for p in problems}
        if len(D) != 1:
            raise ValueError(f"packed problems must share the contraction "
                             f"width D, got {sorted(D)}")
        self.problems = list(problems)
        self.D = D.pop()
        self.tile = int(tile)
        counts = np.array([p.rows.size for p in self.problems])
        self.offsets = np.concatenate([[0], np.cumsum(counts)])
        self.total = int(self.offsets[-1])
        # the packed host buffer: one storage-agnostic gather per problem
        # (materialised: the packed [W; WR] cache; virtual: W rows + the
        # memoised per-block counter-derived encodes — same bits)
        self.W_packed = np.empty((self.total, self.D))
        for i, p in enumerate(self.problems):
            p.linear.gather_encoded(
                p.rows,
                out=self.W_packed[self.offsets[i]:self.offsets[i + 1]])
        self._tiles = None
        self._gen_specs = None

    # -- host one-pass execution (float64, bit-identical to serial) ---------

    def products(self, X: np.ndarray) -> List[np.ndarray]:
        """All problems' shard products in one contraction → per-problem
        (L_t, B) float64 slices (bit-identical to the serial per-worker
        loop: the primitive is row-stable)."""
        Y = shard_products(self.W_packed, np.asarray(X, dtype=np.float64))
        return [Y[self.offsets[i]:self.offsets[i + 1]]
                for i in range(len(self.problems))]

    # -- device tile layout + one-launch execution (float32) ----------------

    @property
    def n_tiles(self) -> int:
        return -(-self.total // self.tile)

    def gather_index(self) -> Tuple[np.ndarray, np.ndarray]:
        """(T·tile,) per-lane (problem, local-row) gather indices; padding
        lanes carry (-1, -1).  This is the scatter map back from tile
        space to per-problem outputs."""
        lanes = self.n_tiles * self.tile
        prob = np.full(lanes, -1, dtype=np.int64)
        row = np.full(lanes, -1, dtype=np.int64)
        for i, p in enumerate(self.problems):
            o = self.offsets[i]
            prob[o:o + p.rows.size] = i
            row[o:o + p.rows.size] = np.arange(p.rows.size)
        return prob, row

    def device_tiles(self):
        """(T, tile, Dp) float32 device tiles of the packed rows, gathered
        from each layer's incremental device cache (zero rows pad the last
        tile; Dp pads D to the 128-lane MXU width).

        Virtual-parity problems gather only their *systematic* lanes from
        the device-resident W; parity lanes are zeroed here and their
        products written by the generated-parity kernel at execution time
        (:meth:`products_device`) — no ``[W; WR]`` mirror ever exists."""
        import jax.numpy as jnp
        parts = []
        for p in self.problems:
            r = np.asarray(p.rows)
            if p.linear.parity_storage == "virtual":
                sys_m = r < p.linear.L
                gat = jnp.asarray(np.where(sys_m, r, 0))
                part = p.linear.device_W()[gat]
                parts.append(part * jnp.asarray(
                    sys_m[:, None].astype(np.float32)))
            else:
                n = max(int(r.max()) + 1, p.linear.L)
                parts.append(p.linear.device_rows(n)[r])
        packed = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
        lanes = self.n_tiles * self.tile
        Dp = -(-self.D // 128) * 128
        packed = jnp.pad(packed, ((0, lanes - self.total),
                                  (0, Dp - self.D)))
        return packed.reshape(self.n_tiles, self.tile, Dp)

    def products_device(self, X: np.ndarray, *, backend: str = "pallas",
                        interpret: Optional[bool] = None) -> List[np.ndarray]:
        """One-launch device execution of every packed product.

        ``backend="pallas"`` runs the tiles through one
        :func:`~repro.kernels.ops.coded_shard_matmul_batch` Pallas grid;
        ``"jax"`` takes the ``vmap`` fallback.  Float32 — the offload /
        verification path, not the decode-feeding one.
        """
        import jax.numpy as jnp
        from ..kernels import ops
        if self._tiles is None:
            self._tiles = self.device_tiles()
        if self._gen_specs is None:
            # virtual-parity lane specs, frozen once per pack: the flat
            # tile-space lane, its packed threefry counter, and the layer
            # key/W the generated kernel derives the row from
            self._gen_specs = []
            for i, p in enumerate(self.problems):
                if p.linear.parity_storage != "virtual":
                    continue
                r = np.asarray(p.rows)
                par_pos = np.nonzero(r >= p.linear.L)[0]
                if not par_pos.size:
                    continue
                self._gen_specs.append(ops.GeneratedParity(
                    lanes=self.offsets[i] + par_pos,
                    ctrs=p.linear.parity_ctrs(r[par_pos] - p.linear.L),
                    key=p.linear.pkey,
                    w=p.linear.device_W()))
        X = np.asarray(X, dtype=np.float64)
        Dp = self._tiles.shape[-1]
        Xp = jnp.pad(jnp.asarray(X.T, jnp.float32), ((0, Dp - self.D),
                                                     (0, 0)))
        Y = ops.coded_shard_matmul_batch(
            self._tiles, Xp, mode="pallas" if backend == "pallas" else "vmap",
            parity_mode="generated" if self._gen_specs else "materialized",
            parity=self._gen_specs or None,
            interpret=interpret)
        flat = np.asarray(Y, dtype=np.float64).reshape(-1, X.shape[0])
        return [flat[self.offsets[i]:self.offsets[i + 1]]
                for i in range(len(self.problems))]


def pack_shard_problems(problems: Sequence[ShardProblem], *,
                        tile: int = 128) -> PackedShards:
    """Bucket a stage's ragged shard row-slices into one packed gather."""
    return PackedShards(problems, tile=tile)


class _DecodeGroup:
    """Stacked decode structure for one (L, s) group of a stage.

    The same substitution decomposition :func:`repro.stream.backend
    .plan_decode` builds — received systematic rows pin coordinates, the
    (L−s)-sized parity block solves the rest — specialised to the serving
    layout: the systematic generator is ``[I; R]`` by construction, so the
    parity sub-blocks gather straight from each layer's parity rows
    (:meth:`CodedLinear.parity_rows` — dense-R slice or counter
    derivation, no dense generator), and every index set is one
    fancy-index array.  Per-item
    solve inputs are value-identical to the serial engine's, and LAPACK's
    ``gesv`` is deterministic per matrix, so the decoded outputs match the
    serial path bit-for-bit on numpy regardless of how tasks are stacked.
    """

    __slots__ = ("sel", "perm", "rows", "sys_pos", "par_pos", "sys_rows",
                 "unk", "lu", "Gk")

    def __init__(self, sel, problems, rows, s):
        self.sel = sel                          # (gs,) indices into L-group
        L = rows.shape[1]
        if s == L:
            self.perm = True
            self.rows = rows
            return
        self.perm = False
        gs = sel.size
        if gs == 1:                             # the dominant serving case
            r = rows[0]
            m_sys = r < L
            sys_pos = np.nonzero(m_sys)[0]
            par_pos = np.nonzero(~m_sys)[0]
            self.sys_pos = sys_pos[None]
            self.par_pos = par_pos[None]
            sys_rows = r[sys_pos]
            self.sys_rows = sys_rows[None]
            known = np.zeros(L, dtype=bool)
            known[sys_rows] = True
            unk = np.nonzero(~known)[0]
            self.unk = unk[None]
            # parity generator sub-blocks via the storage-agnostic row
            # gather (materialised: a dense-R slice; virtual: the counter
            # derivation) — then the two needed column gathers
            pr = r[par_pos] - L
            Rr = problems[sel[0]].linear.parity_rows(pr)
            # single-axis fancy column gathers come out F-ordered; the
            # serial engine's blocks are C-ordered, and BLAS results are
            # layout-sensitive at the last bit — copy to C for bit-parity
            self.Gk = np.ascontiguousarray(Rr[:, sys_rows])[None]
            self.lu = bk.StackedLU(np.ascontiguousarray(Rr[:, unk])[None])
            return
        m_sys = rows < L
        self.sys_pos = np.nonzero(m_sys)[1].reshape(gs, s)
        self.par_pos = np.nonzero(~m_sys)[1].reshape(gs, L - s)
        self.sys_rows = np.take_along_axis(rows, self.sys_pos, axis=1)
        par_rows = np.take_along_axis(rows, self.par_pos, axis=1)
        known = np.zeros((gs, L), dtype=bool)
        known[np.arange(gs)[:, None], self.sys_rows] = True
        self.unk = np.nonzero(~known)[1].reshape(gs, L - s)
        Rg = [problems[i].linear.parity_rows(par_rows[j] - L)
              for j, i in enumerate(sel)]
        self.Gk = np.stack(
            [Rg[j][:, self.sys_rows[j]]
             for j in range(gs)])                           # (gs, L-s, s)
        self.lu = bk.StackedLU(np.stack(
            [Rg[j][:, self.unk[j]]
             for j in range(gs)]))                          # (gs, L-s, L-s)

    def apply(self, yg: np.ndarray, z: np.ndarray, solve) -> None:
        """Decode this group's slice of the stacked products into ``z``.

        ``solve=None`` runs the numpy path through the group's cached LU
        factors (getrf once per frozen plan, getrs per step); a callable
        (the jitted jax solve) gets the raw stacked systems."""
        if self.perm:
            z[self.sel[:, None], self.rows] = yg[self.sel]
            return
        if self.sel.size == 1:
            # dominant serving case: 1D gathers + a 2D gemm gather the
            # same values as the stacked path below (one dgemm either
            # way), minus the broadcast-index overhead per call
            y0 = yg[self.sel[0]]
            sys_y = y0[self.sys_pos[0]]
            par_y = y0[self.par_pos[0]]
            rhs = (par_y - self.Gk[0] @ sys_y)[None]
            sol = self.lu.solve(rhs) if solve is None \
                else solve(self.lu.A, rhs)
            z0 = z[self.sel[0]]
            z0[self.sys_rows[0]] = sys_y                     # exact pins
            z0[self.unk[0]] = sol[0]
            return
        sel2 = self.sel[:, None]
        ys = yg[self.sel]
        g_ar = np.arange(self.sel.size)[:, None]
        sys_y = ys[g_ar, self.sys_pos]
        par_y = ys[g_ar, self.par_pos]
        rhs = par_y - self.Gk @ sys_y
        sol = self.lu.solve(rhs) if solve is None \
            else solve(self.lu.A, rhs)
        z[sel2, self.sys_rows] = sys_y                       # exact pins
        z[sel2, self.unk] = sol


class PackedStage:
    """One dependency stage of a step: packed products + grouped decode.

    Problems are ordered by matrix height L at pack time, so each height
    group's stacked products are a contiguous *view* of the packed
    product buffer, and each (L, s) straggler group decodes as one
    stacked substitution solve (:class:`_DecodeGroup`) — a stage costs
    one contraction plus one solve launch per group instead of a Python
    loop of per-matmul decodes.
    """

    def __init__(self, problems: Sequence[ShardProblem], *,
                 backend: str = "numpy", tile: int = 128):
        if len(problems) > 1:
            order = sorted(range(len(problems)),
                           key=lambda i: (problems[i].linear.L, i))
            self.problems = [problems[i] for i in order]
        else:
            self.problems = list(problems)
        self.backend = backend
        # the decode-solve engine this stage will actually run (jax falls
        # back to numpy when unavailable) — the bridge logs it per step
        self.solve_backend = "jax" if (backend != "numpy"
                                       and bk.has_jax()) else "numpy"
        self.pack = pack_shard_problems(self.problems, tile=tile)
        # decode groups: (offset problem index, L, member count, subgroups)
        self.groups: List[Tuple[int, int, int, List[_DecodeGroup]]] = []
        if len(self.problems) == 1:
            p = self.problems[0]
            L = p.linear.L
            s = int((p.rows < L).sum())
            self.groups.append(
                (0, L, 1, [_DecodeGroup(np.zeros(1, dtype=np.int64),
                                        self.problems, p.rows[None],
                                        s)]))
            return
        i = 0
        n = len(self.problems)
        while i < n:
            L = self.problems[i].linear.L
            j = i
            while j < n and self.problems[j].linear.L == L:
                j += 1
            members = self.problems[i:j]
            rows = np.stack([p.rows for p in members]) if j - i > 1 \
                else members[0].rows[None]
            s_counts = (rows < L).sum(axis=1)
            subs = [_DecodeGroup(np.nonzero(s_counts == s)[0],
                                 self.problems[i:j], rows[s_counts == s],
                                 int(s))
                    for s in np.unique(s_counts)]
            self.groups.append((i, L, j - i, subs))
            i = j

    def execute(self, X: np.ndarray, *,
                device_products: bool = False,
                mutate=None) -> Dict[str, np.ndarray]:
        """Decode every problem of the stage for one activation batch →
        ``{key: (B, L) exact product}``.

        ``mutate``, when given, is called with the packed product buffer
        ``Y`` (total_rows, B) after the products and before the decode —
        the fault injector's hook for corrupting a worker's returned
        rows exactly where a real Byzantine worker would (the per-problem
        row ranges are ``self.pack.offsets`` / ``self.problems``).  The
        buffer is freshly materialised here, so in-place edits never
        touch the packed weight cache."""
        tr = current_tracer()
        if device_products and self.backend != "numpy":
            # the kernel launch inside products_device times itself
            # (repro.kernels.ops device_span) — no outer kernel span here,
            # stage categories must not double count
            y = self.pack.products_device(X, backend=self.backend)
            Y = np.concatenate(y) if len(y) > 1 else y[0]
        else:
            ctx = tr.span("stage:products", cat="kernel",
                          args={"rows": self.pack.total,
                                "problems": len(self.problems)}) \
                if tr is not None else contextlib.nullcontext()
            with ctx:
                Y = shard_products(self.pack.W_packed,
                                   np.asarray(X, dtype=np.float64))
        if mutate is not None:
            mutate(Y)
        use_jax = self.solve_backend == "jax"
        solve = bk.solve_jax if use_jax else None
        out: Dict[str, np.ndarray] = {}
        B = Y.shape[-1]
        off = self.pack.offsets
        ctx = tr.span("stage:decode", cat="decode",
                      args={"groups": len(self.groups),
                            "solve": self.solve_backend}) \
            if tr is not None else contextlib.nullcontext()
        with ctx:
            for i0, L, g, subs in self.groups:
                yg = Y[off[i0]:off[i0] + g * L].reshape(g, L, B)  # a view
                z = np.empty((g, L, B))
                for sub in subs:
                    sub.apply(yg, z, solve)
                for j in range(g):
                    out[self.problems[i0 + j].key] = z[j].T
        return out
