"""The MDS-coded output head — now a named :class:`CodedLinear`.

Historically the bridge coded only the output-head matmul and this module
held the whole implementation; the per-layer generalisation lives in
:mod:`repro.serve_coded.coded_linear` (``coding_scope`` in the bridge picks
how much of the trunk rides the same machinery).  ``CodedLMHead`` remains
the public name for the head layer: a ``CodedLinear`` whose W is
``launch.serve.head_matrix`` (L = padded vocab) and whose step result
exposes the decoded product as ``.logits``.
"""
from __future__ import annotations

import numpy as np

from .coded_linear import CodedLinear, LinearStep

__all__ = ["CodedLMHead", "HeadStep"]

#: Result of one coded head execution (``.logits`` aliases ``.out``).
HeadStep = LinearStep


class CodedLMHead(CodedLinear):
    """Systematic-MDS-encoded output head, executed shard-by-shard.

    W: (L, D) float weight matrix (``launch.serve.head_matrix``).
    seed: parity-generator seed (one head = one generator stream).
    backend: "numpy" | "jax" | "pallas" for the parity encode + decode
    solve.
    """

    def __init__(self, W: np.ndarray, *, seed: int = 0,
                 backend: str = "numpy", parity_chunk: int = 256):
        super().__init__(W, name="head", seed=seed, backend=backend,
                         parity_chunk=parity_chunk)
