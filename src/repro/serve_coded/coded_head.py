"""Deprecated shim — ``CodedLMHead``/``HeadStep`` live in
:mod:`repro.serve_coded.coded_linear` (the head is just the
``CodedLinear`` named ``"head"``).

Import from ``repro.serve_coded`` (or ``.coded_linear``) instead; this
module is kept for one release and will be removed.
"""
from __future__ import annotations

import warnings

from .coded_linear import CodedLMHead, HeadStep  # noqa: F401

__all__ = ["CodedLMHead", "HeadStep"]

warnings.warn(
    "repro.serve_coded.coded_head is deprecated; import CodedLMHead / "
    "HeadStep from repro.serve_coded (they live in coded_linear now)",
    DeprecationWarning, stacklevel=2)
