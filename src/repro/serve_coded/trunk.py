"""Host-side trunk execution with pluggable (coded) matmul dispatch.

``coding_scope="head"`` serves the jitted model trunk and codes only the
output-head product.  The deeper scopes re-execute the decoder trunk on the
host in float64, routing every large matmul — attention q/k/v/o
projections and FFN up/down projections — through a caller-supplied hook,
so the serving bridge can run each one as a plan-scheduled MDS-coded task
(``coding_scope="trunk"``), or just the FFN block (``"ffn"``), while the
cheap glue (RMSNorm, RoPE, softmax, residuals, cache writes) stays local,
exactly as a master would in the paper's model (the coded workload *is*
the matrix products; everything else is O(d) bookkeeping).

The float64 host pipeline is its own reference: with the hook computing
``X @ W.T`` locally the runner is the *uncoded* server, and because MDS
decode is exact, the coded runner produces bit-identically the same greedy
tokens — the invariant ``tests/test_coded_trunk.py`` enforces across
scopes and backends.  (It also tracks the jitted float32 model to float32
precision, asserted layer-by-layer via ``models.lm``'s ``collect_layers``
threading.)

Supported archs: decoder-only stacks of GQA attention (optionally
sliding-window) + dense FFN (swiglu/gelu/relu2) — the shape of the
llama/gemma/glm/nemotron families.  MoE, MLA, SSM/RWKV mixers and
enc-dec raise ``NotImplementedError`` (their matmul layout needs its own
sharding story; see ROADMAP).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..models.config import ArchConfig, LayerSpec
from ..models.layers import ffn_weight_names

__all__ = ["HostTrunk", "trunk_matmul_keys"]

#: the matmul hook: (key, X (rows, D)) → X @ W_key.T  (rows, L_key)
MatmulFn = Callable[[str, np.ndarray], np.ndarray]

#: the grouped hook: a *dependency stage* of matmuls sharing one right-hand
#: operand — [(key, X), ...] → {key: X @ W_key.T}.  The batched execution
#: engine packs a whole stage's shard gathers into one product; the
#: default adapter just loops the per-matmul hook.
MatmulGroupFn = Callable[[List[Tuple[str, np.ndarray]]],
                         Dict[str, np.ndarray]]

_ATTN_KEYS = ("wq", "wk", "wv", "wo")


def trunk_matmul_keys(cfg: ArchConfig, scope: str) -> List[str]:
    """Ordered keys of the per-layer matmuls coded under ``scope``
    (excluding the head, which every scope codes)."""
    if scope == "head":
        return []
    if scope not in ("ffn", "trunk"):
        raise ValueError(f"unknown coding scope {scope!r}; "
                         f"expected head | ffn | trunk")
    keys: List[str] = []
    specs = list(cfg.prefix) + list(cfg.block) * cfg.n_repeats
    for i, spec in enumerate(specs):
        if scope == "trunk":
            keys.extend(f"blk{i}.{k}" for k in _ATTN_KEYS)
        keys.extend(f"blk{i}.{k}" for k in ffn_weight_names(spec.ffn))
    return keys


def _silu(x: np.ndarray) -> np.ndarray:
    return x / (1.0 + np.exp(-x))


def _gelu_tanh(x: np.ndarray) -> np.ndarray:
    # jax.nn.gelu's default approximate (tanh) form, in float64
    c = np.sqrt(2.0 / np.pi)
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x ** 3)))


def _rms(x: np.ndarray, gain: np.ndarray, eps: float) -> np.ndarray:
    n = x / np.sqrt(np.mean(np.square(x), axis=-1, keepdims=True) + eps)
    return n * gain


_ROPE_TABLES: Dict[Tuple[float, int], Tuple[np.ndarray, np.ndarray]] = {}


def _rope(x: np.ndarray, positions: np.ndarray, base: float) -> np.ndarray:
    """x: (R, T, H, D) even D; positions: (R, T) — mirrors attention.rope.

    cos/sin are table lookups over the integer positions (bit-identical to
    computing them per call: the angle products are the same float64
    values), so the per-token trig cost is one gather."""
    half = x.shape[-1] // 2
    key = (float(base), half)
    P = int(positions.max()) + 1
    tab = _ROPE_TABLES.get(key)
    if tab is None or tab[0].shape[0] < P:
        p = np.arange(max(P, 512), dtype=np.float64)
        freqs = base ** (-np.arange(half, dtype=np.float64) / half)
        ang = p[:, None] * freqs
        tab = (np.cos(ang), np.sin(ang))
        _ROPE_TABLES[key] = tab
    cos = tab[0][positions][:, :, None, :]
    sin = tab[1][positions][:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return np.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


class HostTrunk:
    """Float64 host re-execution of a decoder-only trunk.

    Weight matrices are extracted once from the jitted model's params into
    the (L, D) row-sharded layout ``CodedLinear`` codes (L = output
    features), keyed ``blk{i}.wq`` … ``blk{i}.w_out`` plus ``head``;
    :meth:`forward` replays prefill/decode through a matmul hook.
    """

    def __init__(self, cfg: ArchConfig, params, head_W: np.ndarray):
        if cfg.enc_dec or cfg.mla is not None or cfg.frontend is not None:
            raise NotImplementedError(
                "coding_scope ffn/trunk serves decoder-only dense-attention "
                "archs (enc-dec/MLA/frontend trunks keep scope='head')")
        self.cfg = cfg
        self.specs: List[LayerSpec] = (list(cfg.prefix)
                                       + list(cfg.block) * cfg.n_repeats)
        for spec in self.specs:
            if spec.mixer != "attn" or spec.ffn == "moe":
                raise NotImplementedError(
                    f"coding_scope ffn/trunk supports attn+dense layers; "
                    f"got mixer={spec.mixer!r} ffn={spec.ffn!r}")
        self.n_layers = len(self.specs)
        f64 = lambda a: np.asarray(a, dtype=np.float64)

        self.embed = f64(params["embed"]["tok"])          # (vocab_p, d)
        self.final_norm = f64(params["final_norm"])
        self.norms: List[Tuple[np.ndarray, np.ndarray]] = []
        #: key → (L, D) weight of ``out = X @ W.T``
        self.weights: Dict[str, np.ndarray] = {"head": f64(head_W)}

        def layer_params(i: int):
            n_prefix = len(cfg.prefix)
            if i < n_prefix:
                return params["prefix"][i]
            r, j = divmod(i - n_prefix, len(cfg.block))
            blk = params["blocks"][f"layer{j}"]
            import jax
            return jax.tree.map(lambda a: a[r], blk)

        Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        d = cfg.d_model
        for i, spec in enumerate(self.specs):
            p = layer_params(i)
            self.norms.append((f64(p["norm1"]), f64(p["norm2"])))
            mx = p["mixer"]
            self.weights[f"blk{i}.wq"] = f64(mx["wq"]).reshape(d, Hq * Dh).T
            self.weights[f"blk{i}.wk"] = f64(mx["wk"]).reshape(d, Hkv * Dh).T
            self.weights[f"blk{i}.wv"] = f64(mx["wv"]).reshape(d, Hkv * Dh).T
            self.weights[f"blk{i}.wo"] = f64(mx["wo"]).reshape(Hq * Dh, d).T
            for k in ffn_weight_names(spec.ffn):
                w = f64(p["ffn"][k])
                # w_in/w_gate are (d, d_ff) = W.T; w_out is (d_ff, d) = W.T
                self.weights[f"blk{i}.{k}"] = w.T

    # -- caches --------------------------------------------------------------

    def zero_caches(self, batch: int, max_len: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        shp = (self.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
        return {"k": np.zeros(shp), "v": np.zeros(shp)}

    # -- forward -------------------------------------------------------------

    def local_matmul(self, key: str, X: np.ndarray) -> np.ndarray:
        """The uncoded reference execution of matmul ``key``."""
        return np.asarray(X, dtype=np.float64) @ self.weights[key].T

    def forward(self, tokens: np.ndarray, positions: np.ndarray,
                rows: np.ndarray, caches: Dict[str, np.ndarray],
                mm: Optional[MatmulFn] = None,
                collect: Optional[list] = None,
                mm_group: Optional[MatmulGroupFn] = None) -> np.ndarray:
        """Run ``tokens`` (R, T) at absolute ``positions`` (R, T) through
        the trunk, reading/writing the KV ``caches`` at batch indices
        ``rows`` (R,), with every projection matmul routed through ``mm``
        (None → local uncoded).  Returns the final-norm hidden states
        (R, T, d) — the output head's input.

        Prefill is (R=1, T=prompt); batched decode is (R=slots, T=1);
        positions must be the contiguous continuation of what the cache
        already holds (the serving bridge's slot bookkeeping guarantees
        it).  ``collect`` (a list) receives each layer's post-residual
        hidden state — the mirror of ``models.lm``'s ``collect_layers``
        threading, for layer-by-layer comparison against the jitted
        model.

        ``mm_group`` is the stage-granular hook: each call hands over one
        *dependency stage* — the matmuls that share a right-hand operand
        (q/k/v on the post-norm hiddens, up/gate on the FFN input; o and
        down are single-member stages).  The data dependencies of a
        decoder layer make a stage the largest batchable unit, and the
        batched engine executes each one as a single packed pass.  When
        ``mm_group`` is None the per-matmul ``mm`` hook is looped — the
        serial reference."""
        cfg = self.cfg
        if mm_group is None:
            mm_one = mm or self.local_matmul
            mm_group = lambda items: {k: mm_one(k, X) for k, X in items}
        mmg = mm_group
        tokens = np.asarray(tokens)
        positions = np.asarray(positions)
        rows = np.asarray(rows)
        R, T = tokens.shape
        Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        G = Hq // Hkv
        d = cfg.d_model
        scale = 1.0 / np.sqrt(Dh)
        x = self.embed[tokens]                            # (R, T, d)

        for i, spec in enumerate(self.specs):
            norm1, norm2 = self.norms[i]
            h = _rms(x, norm1, cfg.norm_eps)
            h2d = h.reshape(R * T, d)
            qkv = mmg([(f"blk{i}.wq", h2d), (f"blk{i}.wk", h2d),
                       (f"blk{i}.wv", h2d)])
            q = qkv[f"blk{i}.wq"].reshape(R, T, Hq, Dh)
            k = qkv[f"blk{i}.wk"].reshape(R, T, Hkv, Dh)
            v = qkv[f"blk{i}.wv"].reshape(R, T, Hkv, Dh)
            base = cfg.rope_base_local if spec.sliding_window \
                else cfg.rope_base
            q = _rope(q, positions, base)
            k = _rope(k, positions, base)
            caches["k"][i][rows[:, None], positions] = k
            caches["v"][i][rows[:, None], positions] = v
            K = caches["k"][i][rows]                      # (R, S, Hkv, Dh)
            V = caches["v"][i][rows]
            S = K.shape[1]
            # grouped-query attention without materialising the repeated
            # (R, S, Hq, Dh) K/V: head h reads kv-head h//G, so contracting
            # the (Hkv, G) split against K directly sums the same scalars
            # in the same order as the np.repeat formulation
            qg = q.reshape(R, T, Hkv, G, Dh)
            s = np.einsum("rtkgd,rskd->rkgts", qg,
                          K).reshape(R, Hq, T, S) * scale
            kp = np.arange(K.shape[1])
            valid = kp[None, None, :] <= positions[:, :, None]   # causal
            if spec.sliding_window is not None:
                valid &= kp[None, None, :] > \
                    positions[:, :, None] - spec.sliding_window
            s = np.where(valid[:, None], s, -np.inf)
            s -= s.max(axis=-1, keepdims=True)
            p = np.exp(s)
            p /= p.sum(axis=-1, keepdims=True)
            o = np.einsum("rkgts,rskd->rtkgd",
                          p.reshape(R, Hkv, G, T, S),
                          V).reshape(R, T, Hq, Dh)
            x = x + mmg([(f"blk{i}.wo", o.reshape(R * T, Hq * Dh))
                         ])[f"blk{i}.wo"].reshape(R, T, d)

            h2 = _rms(x, norm2, cfg.norm_eps).reshape(R * T, d)
            up_keys = [(f"blk{i}.w_in", h2)]
            if spec.ffn == "swiglu":
                up_keys.append((f"blk{i}.w_gate", h2))
            ups = mmg(up_keys)
            up = ups[f"blk{i}.w_in"]
            if spec.ffn == "swiglu":
                up = _silu(ups[f"blk{i}.w_gate"]) * up
            elif spec.ffn == "gelu":
                up = _gelu_tanh(up)
            elif spec.ffn == "relu2":
                up = np.square(np.maximum(up, 0.0))
            x = x + mmg([(f"blk{i}.w_out", up)
                         ])[f"blk{i}.w_out"].reshape(R, T, d)
            if collect is not None:
                collect.append(x)

        return _rms(x, self.final_norm, cfg.norm_eps)
