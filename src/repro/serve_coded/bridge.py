"""The coded serving bridge: StreamingExecutor planning as the live
admission/batching policy of the real inference server.

``launch/serve.py`` runs prefill → continuous-batched decode;
``repro.stream`` plans coded matrix products over shared heterogeneous
workers.  This module welds them together: every token batch the server
generates is a set of the paper's coded tasks, scheduled by the *same*
machinery the streaming engine uses —

* the :class:`~repro.stream.replan.OnlinePlanner` supplies the (k, b, l)
  plan for the current pool (churn-aware, SCA-warm-started);
* the :class:`~repro.stream.queueing.SharePool` ledger holds the paper's
  column-sum ≤ 1 constraint across masters' concurrent steps;
* a pluggable :class:`~repro.stream.queueing.AdmissionPolicy`
  ("fifo" | "edf" | "fair") decides which waiting requests join a batch
  when slots free up, and (fair policy) caps a step's admitted shares at
  the max-min fair entitlement;
* :func:`repro.parallel.hetero.coded_row_shards` /
  ``rescaled_row_shards`` turn the fractional plan row into integer
  per-worker shard sizes for each coded weight matrix;
* a :class:`~repro.serve_coded.coded_linear.CodedLinear` per in-scope
  matmul physically executes each arrived shard's product and decodes the
  exact output from the earliest prefix covering its L rows.

**Coding scope.**  ``coding_scope="head"`` (the historical bridge) runs
the jitted trunk locally and codes only the output-head product.
``"ffn"`` re-executes the trunk on the host (:class:`HostTrunk`) and
additionally codes every FFN up/gate/down projection; ``"trunk"`` codes
the attention q/k/v/o projections too — the paper's assumption that the
*entire* matmul workload of a master is MDS-encoded across the shared
workers.  One serving step is then a *multi-task dispatch*: all in-scope
matmuls share one admission (one (k, b) acquisition, one queue cycle) and
complete through a :class:`~repro.stream.barrier.StepBarrier` at the max
of the per-task earliest-prefix times.

**Batched dispatch.**  ``steps_per_dispatch`` generates up to that many
sequential decode tokens per admission: the per-matmul row shards (the
workers' encoded weights) are shipped once and the extra token columns
ride the same deliveries, amortizing encode/queue overhead — the paper's
task is A·x per column; the row allocation (what the delay model loads)
is column-count-free.

**Churn.**  Worker leave/degrade/restore re-times every in-flight step's
per-layer tasks through the stream engine's own re-timing arithmetic
(:func:`~repro.stream.barrier.churn_finish_update`), re-scheduling the
step's completion event under a fresh version (stale completions are
dropped, as in the engine).  A step that can no longer cover some
matrix's rows re-dispatches its *timing* on the post-churn plan — the
already-decoded tokens are provably unchanged (MDS decode is exact for
any covering prefix), only when they land moves.

Time model: request arrivals, worker delays and deadlines live in
*simulation* milliseconds (sampled from the paper's shifted-exponential /
exponential model via the stream backend); the model forwards and shard
matmuls are real computations timed separately in wall-clock seconds.
"""
from __future__ import annotations

import contextlib
import dataclasses
import heapq
import itertools
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..faults import FaultConfig, QuarantineLedger, corrupt_products
from ..obs import STAGE_CATS, Tracer, current_tracer, use_tracer
from ..parallel.hetero import coded_row_shards, rescaled_row_shards
from ..sim.cluster import ClusterProfile, ec2_cluster
from ..stream import backend as bk
from ..stream.barrier import BarrierTask, StepBarrier
from ..stream.events import WorkerEvent
from ..stream.metrics import StreamMetrics, TaskRecord
from ..stream.queueing import (AdmissionConfig, SharePool, fair_demand_rows,
                               make_admission_policy, scale_shares)
from ..stream.config import StreamConfig
from ..stream.replan import OnlinePlanner, ReplanPolicy, scaled_row_loads
from .coded_linear import CodedLMHead
from .coded_linear import (DECODE_ENGINE, CodedLinear, prefix_plan_batch,
                           shard_products, surplus_plan)
from .packing import PackedStage, ShardProblem
from .plan_cache import StepPlan, StepPlanCache
from .requests import ServeRequest
from .trunk import HostTrunk, trunk_matmul_keys

__all__ = ["CodedServingBridge", "ServeReport", "default_pool",
           "CODING_SCOPES", "EXECUTION_MODES"]

_ARRIVE, _CHURN, _STEP, _RETRY = "arrive", "churn", "step", "retry"


def _scenario_ctx(sc) -> bytes:
    """Step-plan-cache context: the bytes the closed-form loads (and hence
    the shard splits and row assignment) depend on besides (m, k, b)."""
    return sc.a.tobytes() + sc.u.tobytes() + sc.gamma.tobytes()

CODING_SCOPES = ("head", "ffn", "trunk")
EXECUTION_MODES = ("serial", "batched")


def _fill_glue(tr, n0: int) -> None:
    """Backfill un-attributed wall time inside a just-closed step span.

    The parent ("step"-cat) span is ``tr.spans[-1]``; its leaves are the
    stage-cat wall spans recorded since index ``n0``.  The gaps between the
    merged leaf intervals, clamped to the parent's extent, become
    ``cat="glue"`` spans — the host forward math and bookkeeping between
    coded stages — so the stage categories tile the step and
    ``stage_coverage`` stays an honest ≈1 instead of silently shrinking as
    more of a step's time hides between instrumented calls."""
    if tr is None or not tr.spans:
        return
    parent = tr.spans[-1]
    ivs = sorted((max(s.t0, parent.t0), min(s.t1, parent.t1))
                 for s in tr.spans[n0:-1]
                 if s.track == "wall" and s.cat in STAGE_CATS)
    cur, n = parent.t0, 0
    for a, b in ivs:
        if b <= a:
            continue
        if a > cur:
            tr.add_span(f"glue:{parent.name}#{n}", cur, a, cat="glue",
                        track="wall", args={"step": parent.name})
            n += 1
        cur = max(cur, b)
    if parent.t1 > cur:
        tr.add_span(f"glue:{parent.name}#{n}", cur, parent.t1, cat="glue",
                    track="wall", args={"step": parent.name})


class _BarrierExecutor:
    """Batched shard-execution engine for one step barrier.

    Built when the step is dispatched: every member task's covering prefix
    is planned up front (one batched delivery-order sort over the barrier,
    :meth:`~repro.stream.barrier.StepBarrier.delivery_orders`), and each
    forward *stage* — the matmuls sharing a right-hand operand — executes
    as one packed product plus one stacked decode per row-count group
    (:class:`~repro.serve_coded.packing.PackedStage`).  Packs and decode
    plans are X-independent and cached, so every token of a multi-token
    dispatch reuses them.

    With a *current* :class:`StepPlanCache` entry the whole structure is
    reused across steps: the first execution for a plan row freezes its
    prefix plans and packed stages into the entry, and every later step of
    the same width replays them — zero planning/packing wall time at
    steady state.  A stale entry (churn bumped the cache epoch after this
    step dispatched) is ignored and the retimed barrier is planned fresh.
    """

    def __init__(self, linears, barrier, *, backend: str,
                 device_products: bool = False, entry=None, cache=None):
        self.linears = linears
        self.backend = backend
        self.device_products = bool(device_products)
        self.used_solve = False
        self.solve_backends: set = set()   # decode engines actually run
        current = cache is not None and cache.is_current(entry)
        if current and entry.plans is not None:
            self.plans = entry.plans
            self._stages = entry.stages
            return
        tr = current_tracer()
        ctx = tr.span("plan:prefixes", cat="plan",
                      args={"tasks": len(barrier.tasks)}) \
            if tr is not None else contextlib.nullcontext()
        with ctx:
            # one stacked covering-selection pass over the whole barrier
            self.plans = prefix_plan_batch(linears, barrier)
        if current:
            entry.plans = self.plans
            self._stages = entry.stages
        else:
            self._stages = {}

    def stage(self, keys):
        kt = tuple(keys)
        memo = self._stages.get(kt)
        if memo is None:
            tr = current_tracer()
            ctx = tr.span("pack:stage", cat="pack",
                          args={"matmuls": len(kt)}) \
                if tr is not None else contextlib.nullcontext()
            with ctx:
                stg = PackedStage(
                    [ShardProblem(key=k, linear=self.linears[k],
                                  rows=self.plans[k].rows,
                                  used_solve=self.plans[k].used_solve)
                     for k in kt], backend=self.backend)
            # the solve flag is a pure function of the frozen plans —
            # memoise it with the stage rather than re-deriving per step
            memo = (stg, any(self.plans[k].used_solve for k in kt))
            self._stages[kt] = memo
        return memo

    def _corruptor(self, stg, marks: Dict[int, str], eps: float):
        """Byzantine-worker hook for :meth:`PackedStage.execute`: corrupt
        the marked workers' delivered rows inside the packed product
        buffer, attributed through the frozen prefix plans (the packed
        row ranges are ``stg.pack.offsets`` in ``stg.problems`` order)."""
        plans = self.plans

        def mutate(Y: np.ndarray) -> None:
            off = stg.pack.offsets
            for i, p in enumerate(stg.problems):
                rw = plans[p.key].row_workers()
                blk = Y[off[i]:off[i] + rw.size]
                for w, kind in marks.items():
                    msk = rw == w
                    if msk.any():
                        blk[msk] = corrupt_products(blk[msk], kind, eps=eps)
        return mutate

    def execute(self, items, *, marks=None,
                eps: float = 1e-3) -> Dict[str, np.ndarray]:
        """One stage: ``[(key, X), ...]`` sharing X → ``{key: out}``."""
        keys = [k for k, _ in items]
        assert all(X is items[0][1] for _, X in items), \
            "a stage's matmuls must share one right-hand operand"
        stg, solve_flag = self.stage(keys)
        outs = stg.execute(
            items[0][1], device_products=self.device_products,
            mutate=self._corruptor(stg, marks, eps) if marks else None)
        self.solve_backends.add(stg.solve_backend)
        self.used_solve |= solve_flag
        return outs


def default_pool(N: int = 8, n_fast: int = 2, seed: int = 0) -> ClusterProfile:
    """The demo pool: EC2-fitted heterogeneous workers, comm-delay aware."""
    return ec2_cluster(N=N, n_fast=n_fast, rng=seed, gamma_over_u=2.0)


@dataclasses.dataclass
class _Slot:
    rid: int
    prompt: np.ndarray
    gen_len: int
    tokens: List[int]
    pos: int = 0
    needs_prefill: bool = True


@dataclasses.dataclass
class _Step:
    k_row: np.ndarray
    b_row: np.ndarray
    barrier: StepBarrier
    t_start: float
    t_acquire: float              # last share acquisition (re-dispatch moves it)
    t_done: float
    version: int
    tok_by_slot: Dict[int, List[int]]
    rows_dispatched: int          # Σ shard rows over all (re-)dispatches
    rows_needed: float            # Σ per-task L over the dispatch's matmuls
    used_solve: bool
    max_err: float
    argmax_ok: int
    redispatches: int = 0
    stalled: bool = False         # lost coverage; holds no shares, retried
    # slots admitted when the step was dispatched — the batched engine
    # executes at barrier completion, and later-admitted slots must wait
    # for the next dispatch (exactly the eager engine's token set)
    planned_slots: frozenset = frozenset()
    executed: bool = False        # tokens generated (eager: at dispatch)
    # per-task decode path (True = parity solve, False = systematic
    # scatter) and the decode-solve engine the step actually ran —
    # recorded by execute_step, logged by step_done
    task_solve: Dict[str, bool] = dataclasses.field(default_factory=dict)
    decode_backend: str = ""
    # the step-plan cache entry this step dispatched from (None with the
    # cache disabled); execution checks it is still current before
    # trusting its frozen prefixes/stages
    entry: Optional[StepPlan] = None
    # -- fault layer ---------------------------------------------------------
    # Byzantine corruption drawn for this dispatch: worker → corruption
    # kind, applied to every product block the worker's rows feed
    fault_marks: Dict[int, str] = dataclasses.field(default_factory=dict)
    decode_mode: str = "exact"    # worst per-task mode: exact < ls < degraded
    faults_detected: int = 0      # tasks whose surplus residuals flagged
    rows_rejected: int = 0        # delivered rows excluded from decodes
    retries: int = 0              # leave-one-worker-out recovery attempts
    corrupt_hit: bool = False     # a marked worker's rows reached a decode
    culprits: List[int] = dataclasses.field(default_factory=list)


class _MasterState:
    def __init__(self, n_slots: int):
        self.caches: Any = None
        self.slots: Dict[int, _Slot] = {}
        self.free: List[int] = list(range(n_slots))
        self.step: Optional[_Step] = None


@dataclasses.dataclass
class ServeReport:
    """Everything a coded serve produced, plus the scheduling metrics."""
    metrics: StreamMetrics
    tokens: Dict[int, List[int]]         # rid → generated token ids
    steps: List[Dict[str, float]]        # per coded-step log
    policy: str
    coding_scope: str
    max_err: float                       # NaN when verification was off
    argmax_match_rate: float
    decode_ok: Optional[bool]            # None when verification was off
    wall_seconds: float
    tokens_generated: int
    solve_steps: int
    execution: str = "batched"           # shard-execution engine
    decode_backend: str = "numpy"        # effective decode-solve engine
    backend: str = "numpy"               # backend as *requested*
    # backend that actually ran: CodedLinear warns and falls back to
    # numpy when jax is unavailable — the report records the truth
    # instead of echoing the request
    backend_effective: str = "numpy"
    parity_storage: str = "materialized"  # "materialized" | "virtual"
    redispatches: int = 0                # in-flight steps re-timed off-plan
    sim_horizon_ms: float = 0.0          # last step/request completion
    # step-plan cache traffic for this serve (all zero when disabled):
    # steady state is hit-only — one miss per (plan row, width), plus one
    # invalidation per churn/replan event
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    plan_cache_invalidations: int = 0
    # tracing (None unless the bridge was built with a recording Tracer):
    # per-stage wall seconds rolled up from the run's spans, and the path
    # the Chrome/Perfetto trace was written to (when serve(trace_path=...))
    per_stage_wall: Optional[Dict[str, float]] = None
    trace_path: Optional[str] = None
    # fault layer (None unless the bridge was built with faults/ls_tail):
    # per-step decode-mode counts and the chaos/detection/recovery totals
    # — "degraded" steps are the explicitly-reported LS fallbacks, never
    # silently wrong logits
    decode_modes: Optional[Dict[str, int]] = None
    faults: Optional[Dict[str, float]] = None

    def summary(self) -> Dict[str, float]:
        out = self.metrics.summary()
        out.update({
            "tokens_generated": float(self.tokens_generated),
            "coded_steps": float(len(self.steps)),
            "solve_steps": float(self.solve_steps),
            "redispatches": float(self.redispatches),
            "tokens_per_sim_second":
                self.tokens_generated / (self.sim_horizon_ms / 1e3)
                if self.sim_horizon_ms > 0 else 0.0,
            "tokens_per_wall_second":
                self.tokens_generated / max(self.wall_seconds, 1e-300),
            "decode_max_err": self.max_err,
            "argmax_match_rate": self.argmax_match_rate,
            "plan_cache_hits": float(self.plan_cache_hits),
            "plan_cache_misses": float(self.plan_cache_misses),
            "plan_cache_invalidations":
                float(self.plan_cache_invalidations),
            "plan_cache_hit_rate": self.plan_cache_hits
                / max(self.plan_cache_hits + self.plan_cache_misses, 1),
        })
        return out


class CodedServingBridge:
    """Serves generation requests with plan-scheduled coded matmuls.

    Parameters
    ----------
    profile:   worker pool (:class:`ClusterProfile`); ``None`` = the demo
               EC2 pool.  The Scenario's L is the model's padded vocab
               (per-layer matrices reuse the plan row rescaled to their
               own height).
    masters:   number of tenants (plan rows); requests carry a master id.
    arch/seed: model selection (smoke-sized) and init seed.
    config:    a stream :class:`~repro.stream.config.StreamConfig` — the
               same unified surface ``StreamingExecutor`` takes.  Supplies
               ``admission``, ``plan_policy`` (its ``policy``), ``replan``
               and the ``seed`` (its ``rng``) in one object; mutually
               exclusive with passing those individually.  (The
               ``BackendConfig`` half does not apply here: the bridge's
               numerics are governed by ``backend``/``verify`` below.)
    admission: stream :class:`AdmissionConfig` — ``policy`` picks the
               waiting-request ordering, ``min_fraction``/``max_queue`` the
               scaling/backpressure rules.
    plan_policy / replan: forwarded to :class:`OnlinePlanner`.
    slots_per_master: continuous-batching capacity per tenant (the
               contended resource the admission policy arbitrates).
    coding_scope: "head" | "ffn" | "trunk" — which matmuls run coded (see
               module docstring).
    steps_per_dispatch: decode tokens generated per admission (≥ 1).
    execution: "batched" (default) plans every matmul of the step barrier
               at dispatch — prefix rows, packed shard gathers, stacked
               decode plans, all X-independent — and generates the step's
               tokens *once, at barrier completion*, each forward stage
               running as one packed pass; "serial" is the shard-by-shard
               reference engine (per-worker host matmuls, one decode per
               matmul, tokens generated eagerly at dispatch).  The two
               engines emit bit-identical greedy tokens; on the numpy
               backend their shard products are bit-identical outright.
    device_products: route the batched engine's packed products through
               the float32 device-resident weight cache and the
               ``coded_shard_matmul_batch`` kernel (jax/pallas backends).
               Off by default: decode-feeding products stay float64
               host-side so tokens match the uncoded pipeline bit-for-bit
               — on-TPU serving flips this on and accepts float32
               verification tolerances.
    backend:   "numpy" | "jax" | "pallas" for the coded encode/decode.
               When jax is missing the layers warn and fall back to
               numpy; ``ServeReport.backend_effective`` records what ran.
    parity_storage: "materialized" keeps each layer's packed ``[W; WR]``
               encoded cache (and its float32 device mirror); "virtual"
               derives parity rows from packed threefry counters on
               demand — host gathers re-encode per block (bit-identical),
               the device path runs the generated-parity kernel against
               resident W, and encoded-weight memory drops to ≈ half at
               redundancy 2.  Decoded values and greedy tokens are
               identical across the modes.
    coded:     False serves the identical pipeline with every in-scope
               matmul computed locally (the *uncoded baseline*: same
               scheduling, same sim timing, no shard execution) — the
               reference the parity tests compare greedy tokens against.
    verify:    compare every decoded matmul against the local uncoded
               product (CI/tests).  Off, the bridge skips the reference
               matmuls — the honest serving configuration, since
               distributing those products is the point.
    tracer:    a :class:`repro.obs.Tracer` to record per-step spans
               (plan/pack/kernel/decode stages, sim-side deliveries,
               cache counters) into.  ``None`` or a disabled tracer keeps
               every hot path on its uninstrumented branch — the serve
               loop then costs one predicate per entry point.
    plan_cache: keep a persistent :class:`StepPlanCache` across steps
               (and serves): shard splits, row assignment, covering
               prefixes, packed stages and decode factorizations are
               computed once per (plan row, width) and replayed while the
               pool is unchanged.  Churn and planner re-solves invalidate
               it.  MDS decode is exact for any covering prefix, so the
               frozen structures change no decoded value; ``False`` runs
               the historical re-plan-every-step path.
    faults:    a :class:`repro.faults.FaultConfig` — deterministic chaos
               (crash/drop/duplicate/stale delivery faults, Byzantine
               product corruption) injected per (dispatch, worker), plus
               the detect/quarantine/retry knobs.  Detection spends
               delivered-beyond-the-prefix rows as parity residual
               checks; a confirmed corrupt or crashed worker is
               quarantined through the churn path (plan-cache epoch bump,
               planner re-solve, backoff readmission) and the step
               recovers by re-decoding from the verified row subset —
               exactly when coverage allows, degraded least-squares
               otherwise, never silently wrong.  The fault draws never
               touch the delay stream: a schedule that fires no fault
               serves bit-identically to ``faults=None``.
    ls_tail:   decode every coded matmul by stacked least squares over
               the covering prefix *plus* the delivered surplus rows
               (``faults.surplus_rows`` cap) instead of discarding them —
               the over-determined solve damps the float32 parity-encode
               noise of the jax/pallas tails.  With no surplus (cap 0)
               the LS plan routes through the same cached LU as the
               square decode, so tokens are identical to ``ls_tail=False``.
    """

    def __init__(self, profile: Optional[ClusterProfile] = None, *,
                 masters: int = 2, arch: str = "llama3.2-1b",
                 smoke: bool = True,
                 config: Optional[StreamConfig] = None,
                 admission: Optional[AdmissionConfig] = None,
                 plan_policy: str = "fractional",
                 replan: Optional[ReplanPolicy] = None,
                 slots_per_master: int = 4,
                 coding_scope: str = "head",
                 steps_per_dispatch: int = 1,
                 execution: str = "batched",
                 device_products: bool = False,
                 backend: str = "numpy",
                 parity_storage: str = "materialized",
                 coded: bool = True,
                 verify: bool = True, seed: int = 0,
                 tracer: Optional[Tracer] = None,
                 plan_cache: bool = True,
                 faults: Optional[FaultConfig] = None,
                 ls_tail: bool = False):
        if coding_scope not in CODING_SCOPES:
            raise ValueError(f"unknown coding_scope {coding_scope!r}; "
                             f"expected one of {CODING_SCOPES}")
        if execution not in EXECUTION_MODES:
            raise ValueError(f"unknown execution {execution!r}; "
                             f"expected one of {EXECUTION_MODES}")
        if steps_per_dispatch < 1:
            raise ValueError("steps_per_dispatch must be >= 1")
        if config is not None:
            if (admission is not None or replan is not None
                    or plan_policy != "fractional"):
                raise TypeError("pass either config=StreamConfig(...) or "
                                "the per-feature admission/plan_policy/"
                                "replan kwargs, not both")
            admission = config.admission
            plan_policy = config.policy
            replan = config.replan
            seed = config.rng
        self.profile = profile or default_pool(seed=seed)
        self.M = int(masters)
        self.arch = arch
        self.smoke = bool(smoke)
        self.admission = admission or AdmissionConfig(policy="edf")
        self.plan_policy = plan_policy
        self.replan = replan
        self.slots_per_master = int(slots_per_master)
        self.coding_scope = coding_scope
        self.steps_per_dispatch = int(steps_per_dispatch)
        self.execution = execution
        self.device_products = bool(device_products)
        self.backend = backend
        if parity_storage not in ("materialized", "virtual"):
            raise ValueError(f"parity_storage must be 'materialized' or "
                             f"'virtual', got {parity_storage!r}")
        self.parity_storage = parity_storage
        self.coded = bool(coded)
        self.verify = bool(verify)
        self.seed = int(seed)
        self.tracer = tracer if (tracer is not None and tracer.enabled) \
            else None
        self.faults = faults
        self.ls_tail = bool(ls_tail)
        # the last serve's quarantine ledger (None before any faulted
        # serve) — tests and the bench introspect offenses/readmissions
        self.ledger: Optional[QuarantineLedger] = None
        self._plan_cache = StepPlanCache() if plan_cache else None
        self._model = None
        self._max_len = 0

    # -- lazy model setup ----------------------------------------------------

    def _setup_model(self, max_len: int):
        if self._model is None:
            from ..launch.serve import build_model, head_matrix, serving_fns
            cfg, params = build_model(self.arch, smoke=self.smoke,
                                      seed=self.seed)
            if cfg.enc_dec:
                raise NotImplementedError("coded bridge serves decoder-only "
                                          "archs (enc-dec prefill needs "
                                          "feats)")
            W = head_matrix(cfg, params)
            self._model = dict(cfg=cfg, params=params, W=W)
            self.sc = self.profile.scenario(self.M, L=float(W.shape[0]))
            self.head = CodedLMHead(W, seed=self.seed, backend=self.backend,
                                    parity_storage=self.parity_storage)
            self._linears: Dict[str, CodedLinear] = {"head": self.head}
            self.runner: Optional[HostTrunk] = None
            if self.coding_scope == "head":
                prefill_fn, decode_fn = serving_fns(cfg, return_hidden=True)
                self._model.update(prefill_fn=prefill_fn, decode_fn=decode_fn)
            else:
                self.runner = HostTrunk(cfg, params, W)
                for key in trunk_matmul_keys(cfg, self.coding_scope):
                    self._linears[key] = CodedLinear(
                        self.runner.weights[key], name=key, seed=self.seed,
                        backend=self.backend,
                        parity_storage=self.parity_storage)
            self._coded_keys = [k for k in self._linears if k != "head"] \
                + ["head"]
        if max_len > self._max_len:
            # caches must cover the longest request this bridge ever saw —
            # a later serve() with longer requests regrows them
            ml = int(max_len)
            cfg = self._model["cfg"]
            if self.coding_scope == "head":
                from ..launch.serve import zero_caches
                self._model["zero_caches"] = lambda b: zero_caches(cfg, b, ml)
            else:
                self._model["zero_caches"] = \
                    lambda b: self.runner.zero_caches(b, ml)
            self._max_len = ml

    @staticmethod
    def _write_slot(big, one, slot: int):
        """Scatter a single-request cache into batch slot ``slot``.

        The batch axis is the first axis where the shapes differ (the
        single-request cache has size 1 there); identical shapes mean a
        one-slot batch — replace wholesale."""
        import jax
        import jax.numpy as jnp

        def w(b, o):
            ax = next((i for i, (bs, os_) in
                       enumerate(zip(b.shape, o.shape)) if bs != os_), None)
            if ax is None:
                return o
            idx = tuple(slot if i == ax else slice(None)
                        for i in range(b.ndim))
            return b.at[idx].set(jnp.take(o, 0, axis=ax))
        return jax.tree.map(w, big, one)

    # -- serve ---------------------------------------------------------------

    def serve(self, requests: Sequence[ServeRequest],
              churn: Sequence[WorkerEvent] = (), *,
              trace_path: Optional[str] = None) -> ServeReport:
        """Serve ``requests`` to completion (see class docstring).

        ``trace_path`` (needs a recording ``tracer``) writes the run's
        Chrome/Perfetto trace JSON there after the event loop drains; the
        report's ``per_stage_wall`` / ``trace_path`` fields are filled in
        either way when a tracer is attached."""
        # re-normalize (callers may assign .tracer after construction):
        # a disabled tracer serves on the identical uninstrumented branch
        tracer = self.tracer \
            if self.tracer is not None and self.tracer.enabled else None
        if tracer is None:
            return self._serve_impl(requests, churn)
        with use_tracer(tracer) as tr:
            with tr.span("serve", cat="run",
                         args={"scope": self.coding_scope,
                               "execution": self.execution,
                               "backend": self.backend,
                               "coded": self.coded,
                               "requests": len(requests)}):
                rep = self._serve_impl(requests, churn)
        rep.per_stage_wall = dict(tr.summary()["per_stage_wall"])
        if trace_path is not None:
            rep.trace_path = str(trace_path)
            tr.write(trace_path)
        return rep

    def _serve_impl(self, requests: Sequence[ServeRequest],
                    churn: Sequence[WorkerEvent] = ()) -> ServeReport:
        t_wall = time.perf_counter()
        reqs = {r.rid: r for r in requests}
        max_len = max(len(r.prompt) + r.gen_len for r in requests) + 8
        self._setup_model(max_len)
        mdl = self._model
        L = self.head.L

        planner = OnlinePlanner(self.sc, policy=self.plan_policy,
                                replan=self.replan, rng=self.seed)
        pool = SharePool(self.sc.N)
        queue = make_admission_policy(self.admission.policy,
                                      self.admission.max_queue)
        metrics = StreamMetrics(self.M, self.sc.N)
        exp = bk.ExponentialBlock(
            np.random.default_rng((self.seed, 0x5E4E)), self.sc.N + 1)
        scale = np.ones(self.sc.N + 1)
        sc_eff = self.sc
        cache = self._plan_cache
        if cache is not None:
            # the cache persists across serves on this bridge; key every
            # lookup on the current effective scenario so a previous
            # serve's entries can only hit when they are still exact
            cache.set_context(_scenario_ctx(sc_eff))
            # a planner re-solve replaces the plan row under the frozen
            # splits' feet — drop everything (first solve does not fire)
            planner.subscribe(lambda: cache.invalidate("replan"))
        cache0 = (cache.hits, cache.misses, cache.invalidations) \
            if cache is not None else (0, 0, 0)
        # per-task covering requirement (each coded matrix's own L) —
        # fixed for the serve, shared by every dispatch's barrier
        needs = np.array([self._linears[key].L
                          for key in self._coded_keys], dtype=np.float64)
        recs: Dict[int, TaskRecord] = {}
        states = [None] * self.M
        for m in range(self.M):
            st = _MasterState(self.slots_per_master)
            st.caches = mdl["zero_caches"](self.slots_per_master)
            states[m] = st
        step_log: List[Dict[str, float]] = []
        tokens_out: Dict[int, List[int]] = {}
        seq = itertools.count()
        version_seq = itertools.count()
        heap: List[Tuple[float, int, str, Any]] = []
        for r in requests:
            heapq.heappush(heap, (r.t_arrive, next(seq), _ARRIVE, r))
        for ev in churn:
            heapq.heappush(heap, (ev.time, next(seq), _CHURN, ev))
        stats = dict(max_err=0.0, match=0, total=0, solves=0, tokens=0,
                     redispatches=0)
        # the decode-solve engine this configuration actually runs: jax and
        # pallas both decode through the jitted solve, but CodedLinear
        # warns and falls back to numpy when jax is unavailable — the
        # report and the per-step log say what really ran, not what was
        # asked (ServeReport.backend_effective carries the same truth)
        eff_decode = ("local" if not self.coded
                      else "numpy" if not bk.has_jax()
                      else DECODE_ENGINE[self.backend])

        # ---- fault layer (chaos + detect/quarantine/retry) ---------------
        faults = self.faults if self.coded else None
        fsched = faults.schedule() \
            if faults is not None and faults.active else None
        fdetect = faults is not None and faults.detect
        faulting = fdetect or self.ls_tail \
            or (faults is not None and faults.active)
        ledger = QuarantineLedger(backoff_base=faults.backoff_base,
                                  backoff_factor=faults.backoff_factor) \
            if faults is not None else None
        self.ledger = ledger
        dispatch_seq = itertools.count()
        surplus_cap = faults.surplus_rows if faults is not None else 0
        eps = faults.corrupt_eps if faults is not None else 1e-3
        # flag threshold: the float32 parity-encode noise of the jax/
        # pallas product tails sits far above the float64 honest-residual
        # floor — detection must not flag its own backend's roundoff
        dtol = faults.residual_tol if faults is not None else 1e-4
        if self.backend != "numpy":
            dtol = max(dtol, 5e-4 if self.coding_scope == "head" else 2e-2)
        decode_modes: Dict[str, int] = {}
        _MODE_RANK = {"exact": 0, "ls": 1, "degraded": 2}
        fstats = dict(injected=0, crashes=0, drops=0, stales=0,
                      duplicates=0, corrupt_steps=0, corrupt_applied=0,
                      detected_steps=0, detected=0, localized=0, retries=0,
                      rows_rejected=0, false_flags=0)

        def _gen(lin, total: int):
            """Generator rows view (``total`` coded rows) for the verify/
            recovery decodes — lazy for virtual parity (no dense G)."""
            if lin.parity_storage == "virtual":
                return bk.SystematicRows(lin.L, max(total, lin.L),
                                         lin.parity_rows)
            return lin.generator(max(total, lin.L))

        # ---- helpers bound to this serve run -----------------------------

        def online() -> np.ndarray:
            return pool.online

        def has_work() -> bool:
            return bool(len(queue)) or any(st.slots for st in states)

        def admit(t: float) -> None:
            while len(queue):
                progressed = False
                for rid in queue.candidates():
                    st = states[reqs[rid].master]
                    if st.free:
                        slot = min(st.free)
                        st.free.remove(slot)
                        queue.remove(rid)
                        queue.note_admitted(reqs[rid].master)
                        recs[rid].t_admit = t
                        r = reqs[rid]
                        st.slots[slot] = _Slot(rid=rid, prompt=r.prompt,
                                               gen_len=r.gen_len, tokens=[])
                        progressed = True
                        break
                    if queue.head_of_line:
                        return
                if not progressed:
                    return

        def fair_cap(m: int, k_req, b_req) -> float:
            # claimants: masters holding step shares, plus masters with
            # queued requests or admitted-but-idle batches (plan-row demand)
            held_rows = {m2: states[m2].step.k_row for m2 in range(self.M)
                         if states[m2].step is not None
                         and not states[m2].step.stalled}
            waiting = queue.waiting_masters() | {
                m2 for m2 in range(self.M)
                if states[m2].slots and states[m2].step is None}
            held, demands = fair_demand_rows(m, planner.plan.k, online(),
                                             waiting, held_rows)
            return queue.fair_fraction(m, k_req, b_req, held=held,
                                       demands=demands)

        def quarantine_worker(w: int, t: float) -> None:
            """Confirmed-fault response: flag the worker in the ledger and
            take it offline through the churn path (synthetic ``crash``
            event — in-flight steps re-time, the plan cache epoch bumps,
            the planner re-solves), with a backoff ``join`` scheduled for
            readmission.  Idempotent while already quarantined."""
            if ledger is None or w <= 0 or not pool.online[w]:
                return
            t_back = ledger.flag(w, t)
            heapq.heappush(heap, (t, next(seq), _CHURN,
                                  WorkerEvent(time=t, worker=w,
                                              kind="crash")))
            heapq.heappush(heap, (t_back, next(seq), _CHURN,
                                  WorkerEvent(time=t_back, worker=w,
                                              kind="join")))

        # ---- hidden-state computation (scope-aware) ----------------------

        def hidden_states_jit(st: _MasterState, slot_ids: List[int]
                              ) -> np.ndarray:
            import jax.numpy as jnp
            cont = [s for s in slot_ids if not st.slots[s].needs_prefill]
            H: Dict[int, np.ndarray] = {}
            if cont:
                B = self.slots_per_master
                toks = np.zeros((B, 1), dtype=np.int32)
                pos = np.zeros((B,), dtype=np.int32)
                for s in cont:
                    toks[s, 0] = st.slots[s].tokens[-1]
                    pos[s] = st.slots[s].pos
                _, st.caches, hid = mdl["decode_fn"](
                    mdl["params"], jnp.asarray(toks), jnp.asarray(pos),
                    st.caches)
                hid = np.asarray(hid, dtype=np.float64)
                for s in cont:
                    H[s] = hid[s, 0]
                    st.slots[s].pos += 1
            for s in slot_ids:
                slot = st.slots[s]
                if not slot.needs_prefill:
                    continue
                batch = {"tokens": jnp.asarray(slot.prompt[None])}
                _, c1, h1 = mdl["prefill_fn"](
                    mdl["params"], batch, mdl["zero_caches"](1))
                st.caches = self._write_slot(st.caches, c1, s)
                slot.pos = len(slot.prompt)
                slot.needs_prefill = False
                H[s] = np.asarray(h1, dtype=np.float64)[0, 0]
            return np.stack([H[s] for s in slot_ids])

        def hidden_states_host(st: _MasterState, slot_ids: List[int],
                               mm, mm_group=None) -> np.ndarray:
            cont = [s for s in slot_ids if not st.slots[s].needs_prefill]
            H: Dict[int, np.ndarray] = {}
            if cont:
                toks = np.array([[st.slots[s].tokens[-1]] for s in cont],
                                dtype=np.int64)
                pos = np.array([[st.slots[s].pos] for s in cont],
                               dtype=np.int64)
                hid = self.runner.forward(toks, pos, np.array(cont),
                                          st.caches, mm, mm_group=mm_group)
                for i, s in enumerate(cont):
                    H[s] = hid[i, 0]
                    st.slots[s].pos += 1
            for s in slot_ids:
                slot = st.slots[s]
                if not slot.needs_prefill:
                    continue
                P = len(slot.prompt)
                hid = self.runner.forward(
                    np.asarray(slot.prompt)[None].astype(np.int64),
                    np.arange(P, dtype=np.int64)[None], np.array([s]),
                    st.caches, mm, mm_group=mm_group)
                slot.pos = P
                slot.needs_prefill = False
                H[s] = hid[0, -1]
            return np.stack([H[s] for s in slot_ids])

        # ---- step timing + dispatch --------------------------------------

        def make_timing(m: int, t: float, relax: bool):
            """Shares + per-matmul delivery schedule, or None if it cannot
            run now.  Draws one ExponentialBlock row per coded matmul."""
            plan = planner.ensure_plan(online(), scale)
            fair_fn = (lambda kq, bq: fair_cap(m, kq, bq)) \
                if queue.uses_fairness and not relax else None
            scaled = scale_shares(
                pool, plan.k[m], plan.b[m], online(),
                allow_scaling=self.admission.allow_scaling,
                floor=1e-6 if relax else self.admission.min_fraction,
                fair_fn=fair_fn)
            if scaled is None:
                return None
            k_row, b_row, _f = scaled
            keys = self._coded_keys
            entry = cache.lookup(m, k_row, b_row) \
                if cache is not None else None
            if entry is None:
                # miss: the splits and the expected-delay assignment are
                # pure functions of (sc_eff, m, k_row, b_row) — compute
                # once, freeze in the cache for every later step
                l_row, _ = scaled_row_loads(sc_eff, m, k_row, b_row)
                if l_row.sum() < L - 1e-6:
                    return None
                l_ints = np.stack(
                    [coded_row_shards(l_row, L) if self._linears[key].L == L
                     else rescaled_row_shards(l_row, L, self._linears[key].L)
                     for key in keys])
                # expected per-node delay (the Exp(1) draws at their mean):
                # the systematic row ranges go to the statistically fastest
                # nodes, so covering prefixes decode mostly by scatter — a
                # dispatch-time decision, blind to the realized delays below
                expect = bk.sample_delays(np.ones_like(l_ints, dtype=float),
                                          np.ones_like(l_ints, dtype=float),
                                          l_ints, k_row, b_row, sc_eff.a[m],
                                          sc_eff.u[m], sc_eff.gamma[m])
                entry = StepPlan(keys=keys, l_ints=l_ints, assign=expect,
                                 epoch=cache.epoch if cache is not None
                                 else 0)
                if cache is not None:
                    cache.store(m, k_row, b_row, entry)
            l_ints = entry.l_ints
            # all of the barrier's delays in one batched draw + transform
            # (drawn hit or miss — the delay stream is cache-independent)
            e = exp.draw_n(len(keys))                   # (T, 2, N+1)
            d = bk.sample_delays(e[:, 0], e[:, 1], l_ints, k_row, b_row,
                                 sc_eff.a[m], sc_eff.u[m], sc_eff.gamma[m])
            finish = np.where(l_ints > 0, t + d, np.inf)
            # fault injection: resolved per (dispatch, loaded worker) from
            # the stateless hash-seeded schedule — the ExponentialBlock
            # stream above is already drawn, so a schedule that fires
            # nothing leaves the timing bit-identical to faults=None
            marks: Dict[int, str] = {}
            if fsched is not None:
                disp = next(dispatch_seq)
                loaded = np.nonzero(l_ints.sum(axis=0)[1:] > 0)[0] + 1
                for w, kind in sorted(
                        fsched.faults_at(disp, loaded).items()):
                    fstats["injected"] += 1
                    if kind == "crash":
                        # dies mid-task: every undelivered shard of this
                        # dispatch is lost and the worker leaves the pool
                        # until its backoff readmission
                        finish[:, w] = np.inf
                        fstats["crashes"] += 1
                        quarantine_worker(w, t)
                    elif kind == "drop":
                        finish[:, w] = np.inf
                        fstats["drops"] += 1
                    elif kind == "stale":
                        finish[:, w] = t + (finish[:, w] - t) \
                            * faults.stale_factor
                        fstats["stales"] += 1
                    elif kind == "duplicate":
                        # receiver-side dedupe: numerically inert
                        fstats["duplicates"] += 1
                    else:                       # Byzantine corruption
                        marks[w] = kind
            tasks = [BarrierTask(name=key, l_int=l_ints[i],
                                 finish=finish[i],
                                 need=needs[i],
                                 assign=entry.assign[i])
                     for i, key in enumerate(keys)]
            barrier = StepBarrier(tasks, F=finish,
                                  l=l_ints.astype(np.float64), need=needs)
            if not np.isfinite(barrier.completion):
                return None
            return k_row, b_row, barrier, entry, marks

        def plan_timing(m: int, t: float, relax: bool):
            """``make_timing`` under a dispatch-step span: plan lookup,
            share scaling and the batched delay draw are real wall work a
            step pays before any shard moves, so they count toward step
            wall time with the planning attributed to the "plan" stage
            (an OnlinePlanner re-solve inside shows up as its own
            cat="replan" child)."""
            tr = current_tracer()
            if tr is None:
                return make_timing(m, t, relax)
            with tr.span(f"dispatch:m{m}", cat="step",
                         args={"master": m, "sim_t": t}) as a:
                with tr.span(f"plan:m{m}", cat="plan",
                             args={"master": m, "relax": relax}):
                    timing = make_timing(m, t, relax)
                a["dispatched"] = timing is not None
            return timing

        def execute_step(m: int, sp: _Step) -> None:
            """Generate the dispatch's tokens through its matmul engine.

            The serial engine runs this eagerly at dispatch (the decoded
            values only depend on *which* prefix covers, not when it
            lands); the batched engine runs it once, at barrier
            completion, with every stage of the forward as one packed
            pass over plans frozen at dispatch."""
            tr = current_tracer()
            if tr is None:
                return _execute_step(m, sp)
            n0 = len(tr.spans)
            with tr.span(f"step:m{m}", cat="step",
                         args={"master": m, "execution": self.execution,
                               "scope": self.coding_scope}) as a:
                _execute_step(m, sp)
                a["tokens"] = sum(len(v) for v in sp.tok_by_slot.values())
                a["used_solve"] = sp.used_solve
            # the wall time between this step's stage spans is measured,
            # not inferred: host forward math + bookkeeping become glue
            _fill_glue(tr, n0)

        def _execute_step(m: int, sp: _Step) -> None:
            st = states[m]
            task_map = {task.name: task for task in sp.barrier.tasks}
            step_stats = dict(max_err=0.0, used_solve=False, argmax_ok=0)
            batched = self.execution == "batched"
            ex = _BarrierExecutor(self._linears, sp.barrier,
                                  backend=self.backend,
                                  device_products=self.device_products,
                                  entry=sp.entry, cache=self._plan_cache) \
                if batched and self.coded else None
            # serial engine: share the same frozen prefixes across steps —
            # the first step per plan row plans the whole barrier in one
            # stacked pass and later steps skip planning entirely, keeping
            # the two engines decode-for-decode identical
            frozen = None
            if (not batched and self.coded and self._plan_cache is not None
                    and self._plan_cache.is_current(sp.entry)):
                if sp.entry.plans is None:
                    tr = current_tracer()
                    ctx = tr.span("plan:prefixes", cat="plan",
                                  args={"tasks": len(sp.barrier.tasks)}) \
                        if tr is not None else contextlib.nullcontext()
                    with ctx:
                        sp.entry.plans = prefix_plan_batch(
                            self._linears, sp.barrier)
                frozen = sp.entry.plans

            # ---- fault verification / recovery ---------------------------
            # one diagnosis per task per step (the fix replays for every
            # token batch of a multi-token dispatch); injection applies to
            # every product block a marked worker's rows feed
            marks = sp.fault_marks
            active_faults = faulting and self.coded
            fixes: Dict[str, tuple] = {}
            plans_memo: Dict[str, Any] = {}

            def corrupt_rows(y: np.ndarray, rw: np.ndarray) -> None:
                """In-place Byzantine injection on one task's product
                block (rows aligned with worker attribution ``rw``)."""
                for w, kind in marks.items():
                    msk = rw == w
                    if msk.any():
                        y[msk] = corrupt_products(y[msk], kind, eps=eps)

            def serial_mutate(y, plan):
                corrupt_rows(y, plan.row_workers())

            def plan_for(key: str):
                if ex is not None:
                    return ex.plans[key]
                if frozen is not None and frozen.get(key) is not None:
                    return frozen[key]
                p = plans_memo.get(key)
                if p is None:
                    task = task_map[key]
                    p = self._linears[key].prefix_plan(
                        task.l_int, task.finish, task.completion,
                        assign=task.assign)
                    plans_memo[key] = p
                return p

            def _diagnose(key, lin, task, plan, out, X):
                """First-token verification of one coded task.

                Residual-check up to ``surplus_cap`` delivered-beyond-the-
                prefix rows against the decoded estimate; on a flag,
                localise by leave-one-worker-out exclusion (retry budget)
                and pick the verified recovery row subset.  Returns the
                per-step fix ``(mode, rows, row_workers, decode_plan)``."""
                sur = swk = np.empty(0, dtype=np.int64)
                if surplus_cap > 0:
                    sur, swk = surplus_plan(task.l_int, task.finish,
                                            task.completion, plan,
                                            cap=surplus_cap,
                                            assign=task.assign)
                g_rows = int(plan.total)
                if fdetect and surplus_cap > 0:
                    # two master-encoded audit rows (worker 0: honest by
                    # construction) always ride along with the delivered
                    # surplus: a *consistent* corruption of every delivered
                    # row — e.g. a sign-flip hitting all used workers —
                    # satisfies its own wrong decode and is undetectable
                    # from worker deliveries alone
                    lin.ensure_parity(g_rows + 2 - lin.L)
                    sur = np.concatenate(
                        [sur, np.arange(g_rows, g_rows + 2, dtype=np.int64)])
                    swk = np.concatenate([swk, np.zeros(2, np.int64)])
                    g_rows += 2
                pw = plan.row_workers()
                if marks and any(
                        w in marks for w in set(plan.used.tolist())
                        | set(swk.tolist())):
                    sp.corrupt_hit = True
                flagged = y_sur = G = None
                if fdetect and sur.size:
                    y_sur = shard_products(lin.gather_encoded(sur), X)
                    if marks:
                        corrupt_rows(y_sur, swk)
                    G = _gen(lin, g_rows)
                    resid = bk.plan_verify(G, sur[None]).residuals(
                        out.T[None], y_sur[None])[0]
                    flagged = resid > dtol
                if flagged is None or not flagged.any():
                    if self.ls_tail:
                        rows_all = np.concatenate([plan.rows, sur])
                        wk_all = np.concatenate([pw, swk])
                        dp = bk.plan_decode_ls(_gen(lin, g_rows),
                                               rows_all[None])
                        return ("ls", rows_all, wk_all, dp)
                    return ("pass", None, None, None)
                # detection: the stacked system is inconsistent — either a
                # flagged surplus row or a row inside the decoded prefix
                sp.faults_detected += 1
                fstats["detected"] += 1
                if not marks:
                    fstats["false_flags"] += 1
                rows_all = np.concatenate([plan.rows, sur])
                wk_all = np.concatenate([pw, swk])
                y_pref = shard_products(lin.gather_encoded(plan.rows), X)
                if marks:
                    corrupt_rows(y_pref, pw)
                y_all = np.concatenate([y_pref, y_sur])
                # candidate order: workers whose surplus rows flagged
                # first, prior offenders next, the rest after.  Each
                # attempt spends one unit of the retry budget and models a
                # *re-dispatch*: the candidate's rows are recomputed
                # honestly (as if shipped to another worker), the decode
                # re-runs on [everyone else's rows; re-dispatched rows]
                # and the remaining deliveries re-check it — the first
                # candidate whose exclusion restores consistency is the
                # culprit and the re-decoded estimate is verified-exact
                flag_wk = list(dict.fromkeys(int(w) for w in swk[flagged]))
                rest = [w for w in dict.fromkeys(int(v) for v in wk_all)
                        if w not in flag_wk]
                if ledger is not None:
                    rest = ledger.suspects_first(rest)
                def attempt(excl: np.ndarray):
                    """Re-dispatch the excluded rows (honest recompute —
                    worker 0, the master's own column, never marked) and
                    re-decode; the remaining deliveries re-check it."""
                    rows_rd = rows_all[excl]
                    y_rd = shard_products(lin.gather_encoded(rows_rd), X)
                    rows_c = np.concatenate([rows_all[~excl], rows_rd])
                    y_c = np.concatenate([y_all[~excl], y_rd])
                    wk_c = np.concatenate(
                        [wk_all[~excl], np.zeros(rows_rd.size, np.int64)])
                    sp.rows_dispatched += int(rows_rd.size)
                    x_hat = bk.plan_decode(G, rows_c[:lin.L][None]).apply(
                        y_c[:lin.L][None], backend=self.backend)[0]
                    resid = bk.plan_verify(
                        G, rows_c[lin.L:][None]).residuals(
                            x_hat[None], y_c[lin.L:][None])[0]
                    return (not (resid > dtol).any()), rows_c, wk_c, x_hat

                budget = max(faults.retry_budget, 0)
                hit, tried = None, 0
                for w in flag_wk + rest:
                    # the final budget unit is reserved for the full
                    # re-dispatch below — it is the one attempt that is
                    # guaranteed to restore consistency
                    if tried >= budget - 1:
                        break
                    tried += 1
                    ok, rows_c, wk_c, x_hat = attempt(wk_all == w)
                    if ok:
                        hit = (rows_c, wk_c, x_hat)
                        break
                if hit is None and flag_wk:
                    # several workers implicated at once (multiple faults,
                    # or a prefix corruption flagging every honest surplus
                    # row): re-dispatch all of them together, then widen
                    # by one extra candidate at a time while budget lasts
                    base = np.isin(wk_all, flag_wk)
                    widen = ([None] + rest) if len(flag_wk) > 1 else rest
                    for w in widen:
                        if tried >= budget - 1:
                            break
                        tried += 1
                        ok, rows_c, wk_c, x_hat = attempt(
                            base if w is None else base | (wk_all == w))
                        if ok:
                            hit = (rows_c, wk_c, x_hat)
                            break
                if hit is None and tried < budget:
                    # last unit of budget: full timeout re-dispatch — the
                    # whole task re-executes on fresh workers (every row
                    # honest by construction), which both recovers exactly
                    # and lets the attribution below name every culprit
                    tried += 1
                    ok, rows_c, wk_c, x_hat = attempt(
                        np.ones(rows_all.size, dtype=bool))
                    if ok:
                        hit = (rows_c, wk_c, x_hat)
                sp.retries += tried
                fstats["retries"] += tried
                if hit is not None:
                    rows_c, wk_c, x_hat = hit
                    # a *verified* estimate in hand, corruption attributes
                    # per delivered row: every worker owning a row whose
                    # residual against x̂ flags is a confirmed culprit
                    row_res = bk.plan_verify(G, rows_all[None]).residuals(
                        x_hat[None], y_all[None])[0]
                    bad_rows = row_res > dtol
                    fstats["localized"] += 1
                    nrej = int(bad_rows.sum())
                    sp.rows_rejected += nrej
                    fstats["rows_rejected"] += nrej
                    for w in sorted(set(int(v)
                                        for v in wk_all[bad_rows])):
                        if w not in sp.culprits:
                            sp.culprits.append(w)
                    sel_r, sel_w = rows_c[:lin.L], wk_c[:lin.L]
                    dp = bk.plan_decode(G, sel_r[None])
                    return ("exact", sel_r, sel_w, dp)
                # no consistent exclusion within budget: reject every row
                # a flagged worker delivered and LS-decode the remainder —
                # explicitly degraded (decode_mode), never silently wrong.
                # Worker 0's audit rows are honest by construction; if they
                # flagged, the fault is elsewhere — always keep them
                bad = np.isin(wk_all, flag_wk) & (wk_all != 0)
                nrej = int(bad.sum())
                sp.rows_rejected += nrej
                fstats["rows_rejected"] += nrej
                sel_r, sel_w = rows_all[~bad], wk_all[~bad]
                dp = bk.plan_decode_ls(G, sel_r[None],
                                       allow_underdetermined=True)
                return ("degraded", sel_r, sel_w, dp)

            def fault_check(key: str, out: np.ndarray,
                            X: np.ndarray) -> np.ndarray:
                """Verify/recover one decoded product (called per token
                batch; the diagnosis is made once and replayed)."""
                lin = self._linears[key]
                fix = fixes.get(key)
                if fix is None:
                    fix = _diagnose(key, lin, task_map[key], plan_for(key),
                                    out, X)
                    fixes[key] = fix
                    mode = fix[0] if fix[0] != "pass" else "exact"
                    if _MODE_RANK[mode] > _MODE_RANK[sp.decode_mode]:
                        sp.decode_mode = mode
                mode, sel_r, sel_w, dp = fix
                if mode == "pass":
                    return out
                y = shard_products(lin.gather_encoded(sel_r), X)
                if marks:
                    corrupt_rows(y, sel_w)
                if mode == "exact":
                    z = dp.apply(y[:lin.L][None], backend=self.backend)[0]
                else:
                    z = dp.apply(y[None], backend=self.backend)[0]
                return z.T

            def verify_coded(key: str, out: np.ndarray, X: np.ndarray):
                lin = self._linears[key]
                ref = lin.local(X) if self.coded else out
                if self.coded:
                    err = float(np.abs(out - ref).max()
                                / (1.0 + np.abs(ref).max()))
                    step_stats["max_err"] = max(step_stats["max_err"], err)
                if key == "head":
                    # reused below for the greedy argmax check — the
                    # head product is the model's largest matmul
                    step_stats["head_ref"] = ref

            def mm(key: str, X: np.ndarray) -> np.ndarray:
                """Serial engine: one shard-by-shard coded task per call."""
                if key not in task_map:             # out-of-scope: local
                    return self.runner.local_matmul(key, X)
                lin = self._linears[key]
                task = task_map[key]
                if self.coded:
                    res = lin.step(X, task.l_int, task.finish,
                                   task.completion, assign=task.assign,
                                   plan=plan_for(key) if active_faults
                                   else (None if frozen is None
                                         else frozen.get(key)),
                                   mutate=serial_mutate if marks else None)
                    out = res.out
                    step_stats["used_solve"] |= res.used_solve
                    sp.task_solve[key] = bool(res.used_solve)
                    sp.decode_backend = res.decode_backend
                    if active_faults:
                        out = fault_check(key, out, X)
                else:
                    out = lin.local(X)
                if self.verify:
                    verify_coded(key, out, X)
                return out

            def mm_group(items) -> Dict[str, np.ndarray]:
                """Batched engine: one dependency stage per call."""
                outs: Dict[str, np.ndarray] = {}
                coded_items = [(k, X) for k, X in items if k in task_map]
                for k, X in items:
                    if k not in task_map:           # out-of-scope: local
                        outs[k] = self.runner.local_matmul(k, X)
                if coded_items:
                    if self.coded:
                        outs.update(ex.execute(coded_items,
                                               marks=marks or None,
                                               eps=eps))
                        step_stats["used_solve"] |= ex.used_solve
                        if active_faults:
                            for k, X in coded_items:
                                outs[k] = fault_check(k, outs[k], X)
                    else:
                        for k, X in coded_items:
                            outs[k] = self._linears[k].local(X)
                    if self.verify:
                        for k, X in coded_items:
                            verify_coded(k, outs[k], X)
                return outs

            tok_by_slot: Dict[int, List[int]] = {}
            for _j in range(self.steps_per_dispatch):
                slot_ids = [s for s in sorted(st.slots)
                            if s in sp.planned_slots
                            and len(st.slots[s].tokens)
                            < st.slots[s].gen_len]
                if not slot_ids:
                    break
                if self.coding_scope == "head":
                    H = hidden_states_jit(st, slot_ids)
                elif batched:
                    H = hidden_states_host(st, slot_ids, None,
                                           mm_group=mm_group)
                else:
                    H = hidden_states_host(st, slot_ids, mm)
                if batched:
                    logits = mm_group([("head", H)])["head"]
                else:
                    logits = mm("head", H)
                tokens = np.argmax(logits, axis=1).astype(np.int64)
                if self.verify:
                    ref = step_stats.pop("head_ref")
                    ok = int((tokens == np.argmax(ref, axis=1)).sum())
                else:
                    ok = len(slot_ids)
                step_stats["argmax_ok"] += ok
                stats["total"] += len(slot_ids)
                for sid, tok in zip(slot_ids, tokens):
                    st.slots[sid].tokens.append(int(tok))
                    tok_by_slot.setdefault(sid, []).append(int(tok))

            stats["max_err"] = max(stats["max_err"], step_stats["max_err"])
            stats["match"] += step_stats["argmax_ok"]
            stats["solves"] += int(step_stats["used_solve"])
            if ex is not None:
                sp.task_solve = {k: bool(p.used_solve)
                                 for k, p in ex.plans.items()}
                if ex.solve_backends:
                    sp.decode_backend = next(iter(ex.solve_backends))
            sp.tok_by_slot = tok_by_slot
            sp.used_solve = step_stats["used_solve"]
            sp.max_err = step_stats["max_err"]
            sp.argmax_ok = step_stats["argmax_ok"]
            sp.executed = True

        def begin_step(m: int, t: float, relax: bool) -> bool:
            st = states[m]
            if not any(len(s.tokens) < s.gen_len
                       for s in st.slots.values()):
                return False
            timing = plan_timing(m, t, relax)
            if timing is None:
                if fsched is not None:
                    # an injected crash/drop can kill this dispatch's
                    # coverage outright; retry on a fresh dispatch id (a
                    # fresh fault draw) instead of deadlocking the master
                    t_tok = float(planner.plan.t_per_master[m])
                    dt = t_tok if math.isfinite(t_tok) and t_tok > 0 \
                        else 1.0
                    heapq.heappush(heap, (t + dt, next(seq), _RETRY, m))
                return False
            k_row, b_row, barrier, entry, marks = timing
            pool.acquire(k_row, b_row)
            sp = _Step(
                k_row=k_row, b_row=b_row, barrier=barrier, t_start=t,
                t_acquire=t, t_done=barrier.completion,
                version=next(version_seq), tok_by_slot={},
                rows_dispatched=barrier.rows_dispatched(),
                rows_needed=float(sum(task.need for task in barrier.tasks)),
                used_solve=False, max_err=0.0, argmax_ok=0,
                planned_slots=frozenset(st.slots), entry=entry,
                fault_marks=marks)
            st.step = sp
            if self.execution == "serial":
                execute_step(m, sp)
            heapq.heappush(heap, (sp.t_done, next(seq), _STEP,
                                  (m, sp.version)))
            return True

        def redispatch_step(m: int, t: float) -> bool:
            """Re-time a coverage-lost in-flight step on the current plan.

            MDS decode is prefix-independent, so the step's greedy tokens
            are the same whichever covering prefix executes: the serial
            engine already decoded them at dispatch and only the *timing*
            is re-dispatched (fresh shards, fresh delays, new completion);
            the batched engine hasn't executed yet and will plan against
            the fresh barrier when the new completion fires.  The caller
            has already released the old shares."""
            st = states[m]
            sp = st.step
            timing = plan_timing(m, t, relax=True)
            sp.version = next(version_seq)
            if timing is None:
                sp.stalled = True
                if fsched is not None:
                    t_tok = float(planner.plan.t_per_master[m])
                    dt = t_tok if math.isfinite(t_tok) and t_tok > 0 \
                        else 1.0
                    heapq.heappush(heap, (t + dt, next(seq), _RETRY, m))
                return False
            k_row, b_row, barrier, entry, marks = timing
            pool.acquire(k_row, b_row)
            sp.k_row, sp.b_row, sp.barrier = k_row, b_row, barrier
            sp.entry = entry
            sp.fault_marks = marks
            sp.t_acquire = t
            sp.t_done = barrier.completion
            sp.rows_dispatched += barrier.rows_dispatched()
            sp.stalled = False
            sp.redispatches += 1
            stats["redispatches"] += 1
            heapq.heappush(heap, (sp.t_done, next(seq), _STEP,
                                  (m, sp.version)))
            return True

        def pump(t: float, relax: bool = False) -> bool:
            started = False
            for m in range(self.M):
                st = states[m]
                if st.step is not None and st.step.stalled:
                    started |= redispatch_step(m, t)
                elif st.step is None and st.slots:
                    started |= begin_step(m, t, relax)
            return started

        def step_done(payload: Tuple[int, int], t: float) -> None:
            m, version = payload
            st = states[m]
            sp = st.step
            if sp is None or sp.version != version:
                return                      # stale (churn re-timed the step)
            if not sp.executed:
                # batched engine: the whole barrier executes now, once, at
                # completion — packed stage products over the frozen plans
                execute_step(m, sp)
            st.step = None
            pool.release(sp.k_row, sp.b_row)
            metrics.record_share_interval(sp.k_row, sp.b_row,
                                          t - sp.t_acquire)
            delivered = sp.barrier.rows_delivered_by(t)
            ntok = sum(len(v) for v in sp.tok_by_slot.values())
            stats["tokens"] += ntok
            # covering-prefix attribution: the step completed at the max of
            # its tasks' earliest covering prefixes — name the task and the
            # worker whose delivery closed that prefix (the straggler the
            # whole barrier waited for)
            crit_task, crit_worker = "", -1
            done_tasks = [task for task in sp.barrier.tasks
                          if np.isfinite(task.completion)]
            if done_tasks:
                ct = max(done_tasks, key=lambda task: task.completion)
                crit_task = ct.name
                eps = 1e-9 * max(1.0, abs(ct.completion))
                hit = np.nonzero((ct.l_int > 0) & np.isfinite(ct.finish)
                                 & (np.abs(ct.finish - ct.completion)
                                    <= eps))[0]
                if hit.size:
                    crit_worker = int(hit[0])
            if crit_worker > 0:
                # repeated-straggler feedback: the planner's suspect
                # signal (shifts load off the worker at suspect_after
                # hits) and the ledger's localisation prior
                planner.note_critical(crit_worker)
                if ledger is not None:
                    ledger.note_critical(crit_worker)
            # confirmed Byzantine culprits: quarantine through the churn
            # path at completion time (same sim behavior for both engines
            # — the serial engine diagnosed eagerly at dispatch)
            for w in sp.culprits:
                quarantine_worker(w, t)
            decode_modes[sp.decode_mode] = \
                decode_modes.get(sp.decode_mode, 0) + 1
            if sp.fault_marks:
                fstats["corrupt_steps"] += 1
                if sp.corrupt_hit:
                    fstats["corrupt_applied"] += 1
                    if sp.faults_detected:
                        fstats["detected_steps"] += 1
            step_log.append({
                "master": m, "scope": self.coding_scope,
                "execution": self.execution,
                "decode_backend": sp.decode_backend or eff_decode,
                "backend": self.head.backend,   # effective, post-fallback
                "parity_storage": self.parity_storage,
                "t_start": sp.t_start, "t_done": t,
                "batch": len(sp.tok_by_slot), "tokens": ntok,
                "n_tasks": len(sp.barrier.tasks),
                "rows_dispatched": sp.rows_dispatched,
                "rows_delivered": delivered, "used_solve": sp.used_solve,
                "redispatches": sp.redispatches, "max_err": sp.max_err,
                "critical_task": crit_task, "critical_worker": crit_worker,
                "decode_mode": sp.decode_mode,
                "faults_detected": sp.faults_detected,
                "rows_rejected": sp.rows_rejected, "retries": sp.retries,
            })
            tr = current_tracer()
            if tr is not None:
                tr.add_span(f"step:m{m}", sp.t_acquire, t, cat="sim_step",
                            track=f"sim:m{m}",
                            args={"master": m, "tokens": ntok,
                                  "batch": len(sp.tok_by_slot),
                                  "redispatches": sp.redispatches,
                                  "critical_task": crit_task,
                                  "critical_worker": crit_worker})
                for task in sp.barrier.tasks:
                    solved = sp.task_solve.get(task.name)
                    if solved is not None:
                        tr.count("decode_parity" if solved
                                 else "decode_systematic", t=t, track="sim")
                    comp = task.completion
                    ok = np.isfinite(comp)
                    eps = 1e-9 * max(1.0, abs(comp)) if ok else 0.0
                    for n in np.nonzero(task.l_int > 0)[0]:
                        fin = float(task.finish[n])
                        if not np.isfinite(fin):
                            continue
                        tr.add_span(
                            f"{task.name}/w{n}", sp.t_acquire, fin,
                            cat="delivery", track=f"sim:worker{n}",
                            args={"worker": int(n), "task": task.name,
                                  "master": m, "rows": int(task.l_int[n]),
                                  "in_prefix": bool(ok and fin
                                                    <= comp + eps),
                                  "critical": bool(ok and abs(fin - comp)
                                                   <= eps)})
            for sid, toks in sp.tok_by_slot.items():
                slot = st.slots[sid]
                tokens_out.setdefault(slot.rid, []).extend(toks)
                rec = recs[slot.rid]
                share = len(toks) / max(ntok, 1)
                rec.rows_needed += sp.rows_needed * share
                rec.rows_total += sp.rows_dispatched * share
                rec.rows_delivered += delivered * share
                if len(slot.tokens) >= slot.gen_len:
                    rec.t_complete = t
                    metrics.record_task(rec)
                    del st.slots[sid]
                    st.free.append(sid)
            admit(t)
            pump(t)

        def on_arrive(r: ServeRequest, t: float) -> None:
            plan = planner.ensure_plan(online(), scale, event=True)
            t_tok = float(plan.t_per_master[r.master])
            deadline = math.inf
            if math.isfinite(r.slack) and math.isfinite(t_tok):
                deadline = t + r.slack * r.gen_len * t_tok
            rec = TaskRecord(tid=r.rid, master=r.master, t_arrive=t,
                             deadline=deadline)
            recs[r.rid] = rec
            if not queue.offer(r.rid, master=r.master, deadline=deadline):
                del recs[r.rid], reqs[r.rid]    # backpressure rejection
                return
            admit(t)
            pump(t)

        def on_churn(ev: WorkerEvent, t: float) -> None:
            nonlocal sc_eff
            undo = scale[ev.worker]
            reason = "churn"
            if ev.kind in ("leave", "crash"):
                pool.set_online(ev.worker, False)
                if ev.kind == "crash" and ledger is not None \
                        and ev.worker in ledger.readmit_at:
                    reason = "quarantine"
            elif ev.kind == "join":
                if ledger is not None and ev.worker in ledger.readmit_at:
                    # backoff readmission of a quarantined worker
                    ledger.readmit(ev.worker)
                    reason = "readmit"
                pool.set_online(ev.worker, True)
            elif ev.kind == "degrade":
                scale[ev.worker] *= ev.factor
            elif ev.kind == "restore":
                scale[ev.worker] = 1.0
            sc_eff = planner.effective_scenario(online(), scale)
            if cache is not None:
                # frozen splits/prefixes derive from the pre-churn pool;
                # in-flight steps detect their entry went stale via the
                # epoch bump and rebuild from their retimed barriers
                cache.invalidate(reason)
                cache.set_context(_scenario_ctx(sc_eff))
            planner.ensure_plan(online(), scale, event=True)
            # re-time in-flight steps' per-layer tasks (the engine's path)
            if ev.kind in ("leave", "crash", "degrade", "restore"):
                for m2 in range(self.M):
                    sp = states[m2].step
                    if sp is None or sp.stalled:
                        continue
                    if not sp.barrier.retime(ev.worker, ev.kind, t,
                                             factor=ev.factor, undo=undo):
                        continue
                    sp.version = next(version_seq)
                    comp = sp.barrier.completion
                    if np.isfinite(comp):
                        sp.t_done = max(comp, t)
                        heapq.heappush(heap, (sp.t_done, next(seq), _STEP,
                                              (m2, sp.version)))
                    else:
                        # coverage lost: release and re-dispatch the timing
                        pool.release(sp.k_row, sp.b_row)
                        metrics.record_share_interval(
                            sp.k_row, sp.b_row, t - sp.t_acquire)
                        redispatch_step(m2, t)
            admit(t)
            pump(t)

        # ---- event loop --------------------------------------------------

        now = 0.0
        while True:
            if not heap:
                # forward-progress fallback: relax fairness/min-fraction so
                # leftover work cannot deadlock against its own reservation
                if has_work() and pump(now, relax=True):
                    continue
                break
            now, _, kind, payload = heapq.heappop(heap)
            if kind == _ARRIVE:
                on_arrive(payload, now)
            elif kind == _CHURN:
                on_churn(payload, now)
            elif kind == _RETRY:
                # fault-killed dispatch: try again (no-op if the master
                # started a step through some other event meanwhile)
                admit(now)
                pump(now)
            else:
                step_done(payload, now)

        metrics.replans = planner.replans
        metrics.rejected = queue.rejected
        metrics.unserved = len(queue) + sum(len(st.slots) for st in states)
        for rid in queue.candidates():
            metrics.record_unserved(recs[rid])
        for st in states:
            for slot in st.slots.values():
                metrics.record_unserved(recs[slot.rid])
        # float64 end to end on numpy; jax/pallas encode the parity block in
        # float32, and the deeper scopes run hundreds of small mixed-row
        # solves per serve whose random Gaussian sub-blocks occasionally
        # draw a small least singular value — the relative error of an
        # exact solve against float32-encoded parity rows then spikes to
        # ~1e-2 on unlucky steps (MIN_PARITY_BLOCK bounds the worst tiny-
        # block cases; the tail of larger blocks is irreducible without a
        # least-squares decode).  Tokens are still bit-checked — argmax
        # parity with the uncoded pipeline is the real invariant.
        if self.backend == "numpy":
            tol = 1e-6
        else:
            tol = 5e-4 if self.coding_scope == "head" else 2e-2
        match_rate = stats["match"] / max(stats["total"], 1)
        verifying = self.verify and self.coded
        fault_report = None
        if faults is not None:
            # headline rates: a corruption "applies" when the marked
            # worker's rows actually reached some decode or surplus check
            # (an unused worker corrupts nothing — nothing to detect)
            fault_report = {k: float(v) for k, v in fstats.items()}
            fault_report.update(
                detection_rate=(fstats["detected_steps"]
                                / fstats["corrupt_applied"])
                if fstats["corrupt_applied"] else 1.0,
                localization_rate=(fstats["localized"]
                                   / fstats["detected"])
                if fstats["detected"] else 1.0,
                quarantines=float(ledger.quarantines),
                readmissions=float(ledger.readmissions),
                degraded_steps=float(decode_modes.get("degraded", 0)),
                suspect_replans=float(planner.suspect_replans),
            )
        return ServeReport(
            metrics=metrics,
            tokens=tokens_out,
            steps=step_log,
            policy=self.admission.policy,
            coding_scope=self.coding_scope,
            max_err=stats["max_err"] if verifying else float("nan"),
            argmax_match_rate=match_rate,
            decode_ok=(stats["max_err"] <= tol and match_rate == 1.0)
            if verifying else None,
            wall_seconds=time.perf_counter() - t_wall,
            tokens_generated=stats["tokens"],
            solve_steps=stats["solves"],
            execution=self.execution,
            decode_backend=eff_decode,
            backend=self.backend,
            backend_effective=self.head.backend,
            parity_storage=self.parity_storage,
            redispatches=stats["redispatches"],
            sim_horizon_ms=max([metrics.t_end]
                               + [s["t_done"] for s in step_log]),
            plan_cache_hits=cache.hits - cache0[0] if cache else 0,
            plan_cache_misses=cache.misses - cache0[1] if cache else 0,
            plan_cache_invalidations=cache.invalidations - cache0[2]
            if cache else 0,
            decode_modes=dict(decode_modes)
            if (faults is not None or self.ls_tail) else None,
            faults=fault_report,
        )
