"""The coded serving bridge: StreamingExecutor planning as the live
admission/batching policy of the real inference server.

``launch/serve.py`` runs prefill → continuous-batched decode;
``repro.stream`` plans coded matrix products over shared heterogeneous
workers.  This module welds them together: every token batch the server
generates is one of the paper's coded tasks, scheduled by the *same*
machinery the streaming engine uses —

* the :class:`~repro.stream.replan.OnlinePlanner` supplies the (k, b, l)
  plan for the current pool (churn-aware, SCA-warm-started);
* the :class:`~repro.stream.queueing.SharePool` ledger holds the paper's
  column-sum ≤ 1 constraint across masters' concurrent steps;
* a pluggable :class:`~repro.stream.queueing.AdmissionPolicy`
  ("fifo" | "edf" | "fair") decides which waiting requests join a batch
  when slots free up, and (fair policy) caps a step's admitted shares at
  the max-min fair entitlement;
* :func:`repro.parallel.hetero.coded_row_shards` turns the fractional plan
  row into integer per-worker shard sizes;
* the :class:`~repro.serve_coded.coded_head.CodedLMHead` physically
  executes each arrived shard's matmul and decodes the exact logits from
  the earliest prefix covering L rows.

Time model: request arrivals, worker delays and deadlines live in
*simulation* milliseconds (sampled from the paper's shifted-exponential /
exponential model via the stream backend); the model forwards and shard
matmuls are real computations timed separately in wall-clock seconds.
In-flight steps are not re-timed by churn (a step is short; churn lands on
the next step's plan) — the streaming engine covers mid-flight re-timing
and speculative re-dispatch for the abstract task model.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..parallel.hetero import coded_row_shards
from ..sim.cluster import ClusterProfile, ec2_cluster
from ..stream import backend as bk
from ..stream.events import WorkerEvent
from ..stream.metrics import StreamMetrics, TaskRecord
from ..stream.queueing import (AdmissionConfig, SharePool, fair_demand_rows,
                               make_admission_policy, scale_shares)
from ..stream.replan import OnlinePlanner, ReplanPolicy, scaled_row_loads
from .coded_head import CodedLMHead
from .requests import ServeRequest

__all__ = ["CodedServingBridge", "ServeReport", "default_pool"]

_ARRIVE, _CHURN, _STEP = "arrive", "churn", "step"


def default_pool(N: int = 8, n_fast: int = 2, seed: int = 0) -> ClusterProfile:
    """The demo pool: EC2-fitted heterogeneous workers, comm-delay aware."""
    return ec2_cluster(N=N, n_fast=n_fast, rng=seed, gamma_over_u=2.0)


@dataclasses.dataclass
class _Slot:
    rid: int
    prompt: np.ndarray
    gen_len: int
    tokens: List[int]
    pos: int = 0
    needs_prefill: bool = True


@dataclasses.dataclass
class _Step:
    k_row: np.ndarray
    b_row: np.ndarray
    l_int: np.ndarray
    finish: np.ndarray
    t_start: float
    t_done: float
    slot_ids: List[int]
    tokens: np.ndarray
    rows_dispatched: int
    used_solve: bool
    max_err: float
    argmax_ok: int


class _MasterState:
    def __init__(self, n_slots: int):
        self.caches: Any = None
        self.slots: Dict[int, _Slot] = {}
        self.free: List[int] = list(range(n_slots))
        self.step: Optional[_Step] = None


@dataclasses.dataclass
class ServeReport:
    """Everything a coded serve produced, plus the scheduling metrics."""
    metrics: StreamMetrics
    tokens: Dict[int, List[int]]         # rid → generated token ids
    steps: List[Dict[str, float]]        # per coded-step log
    policy: str
    max_err: float                       # NaN when verification was off
    argmax_match_rate: float
    decode_ok: Optional[bool]            # None when verification was off
    wall_seconds: float
    tokens_generated: int
    solve_steps: int
    sim_horizon_ms: float = 0.0          # last step/request completion

    def summary(self) -> Dict[str, float]:
        out = self.metrics.summary()
        out.update({
            "tokens_generated": float(self.tokens_generated),
            "coded_steps": float(len(self.steps)),
            "solve_steps": float(self.solve_steps),
            "tokens_per_sim_second":
                self.tokens_generated / (self.sim_horizon_ms / 1e3)
                if self.sim_horizon_ms > 0 else 0.0,
            "tokens_per_wall_second":
                self.tokens_generated / max(self.wall_seconds, 1e-300),
            "decode_max_err": self.max_err,
            "argmax_match_rate": self.argmax_match_rate,
        })
        return out


class CodedServingBridge:
    """Serves generation requests with plan-scheduled coded head matmuls.

    Parameters
    ----------
    profile:   worker pool (:class:`ClusterProfile`); ``None`` = the demo
               EC2 pool.  The Scenario's L is the model's padded vocab.
    masters:   number of tenants (plan rows); requests carry a master id.
    arch/seed: model selection (smoke-sized) and init seed.
    admission: stream :class:`AdmissionConfig` — ``policy`` picks the
               waiting-request ordering, ``min_fraction``/``max_queue`` the
               scaling/backpressure rules.
    plan_policy / replan: forwarded to :class:`OnlinePlanner`.
    slots_per_master: continuous-batching capacity per tenant (the
               contended resource the admission policy arbitrates).
    backend:   "numpy" | "jax" | "pallas" for the head encode/decode.
    verify:    compare every decoded logits batch against the local
               uncoded head product (CI/tests).  Off, the bridge skips the
               (B×L×D) reference matmul per step — the honest serving
               configuration, since distributing that product is the point.
    """

    def __init__(self, profile: Optional[ClusterProfile] = None, *,
                 masters: int = 2, arch: str = "llama3.2-1b",
                 smoke: bool = True,
                 admission: Optional[AdmissionConfig] = None,
                 plan_policy: str = "fractional",
                 replan: Optional[ReplanPolicy] = None,
                 slots_per_master: int = 4, backend: str = "numpy",
                 verify: bool = True, seed: int = 0):
        self.profile = profile or default_pool(seed=seed)
        self.M = int(masters)
        self.arch = arch
        self.smoke = bool(smoke)
        self.admission = admission or AdmissionConfig(policy="edf")
        self.plan_policy = plan_policy
        self.replan = replan
        self.slots_per_master = int(slots_per_master)
        self.backend = backend
        self.verify = bool(verify)
        self.seed = int(seed)
        self._model = None
        self._max_len = 0

    # -- lazy model setup ----------------------------------------------------

    def _setup_model(self, max_len: int):
        if self._model is None:
            from ..launch.serve import build_model, head_matrix, serving_fns
            cfg, params = build_model(self.arch, smoke=self.smoke,
                                      seed=self.seed)
            if cfg.enc_dec:
                raise NotImplementedError("coded bridge serves decoder-only "
                                          "archs (enc-dec prefill needs "
                                          "feats)")
            prefill_fn, decode_fn = serving_fns(cfg, return_hidden=True)
            W = head_matrix(cfg, params)
            self._model = dict(cfg=cfg, params=params, prefill_fn=prefill_fn,
                               decode_fn=decode_fn, W=W)
            self.sc = self.profile.scenario(self.M, L=float(W.shape[0]))
            self.head = CodedLMHead(W, seed=self.seed, backend=self.backend)
        if max_len > self._max_len:
            # caches must cover the longest request this bridge ever saw —
            # a later serve() with longer requests regrows them
            from ..launch.serve import zero_caches
            cfg, ml = self._model["cfg"], int(max_len)
            self._model["zero_caches"] = lambda b: zero_caches(cfg, b, ml)
            self._max_len = ml

    @staticmethod
    def _write_slot(big, one, slot: int):
        """Scatter a single-request cache into batch slot ``slot``.

        The batch axis is the first axis where the shapes differ (the
        single-request cache has size 1 there); identical shapes mean a
        one-slot batch — replace wholesale."""
        import jax
        import jax.numpy as jnp

        def w(b, o):
            ax = next((i for i, (bs, os_) in
                       enumerate(zip(b.shape, o.shape)) if bs != os_), None)
            if ax is None:
                return o
            idx = tuple(slot if i == ax else slice(None)
                        for i in range(b.ndim))
            return b.at[idx].set(jnp.take(o, 0, axis=ax))
        return jax.tree.map(w, big, one)

    # -- serve ---------------------------------------------------------------

    def serve(self, requests: Sequence[ServeRequest],
              churn: Sequence[WorkerEvent] = ()) -> ServeReport:
        t_wall = time.perf_counter()
        reqs = {r.rid: r for r in requests}
        max_len = max(len(r.prompt) + r.gen_len for r in requests) + 8
        self._setup_model(max_len)
        mdl = self._model
        L = self.head.L

        planner = OnlinePlanner(self.sc, policy=self.plan_policy,
                                replan=self.replan, rng=self.seed)
        pool = SharePool(self.sc.N)
        queue = make_admission_policy(self.admission.policy,
                                      self.admission.max_queue)
        metrics = StreamMetrics(self.M, self.sc.N)
        exp = bk.ExponentialBlock(
            np.random.default_rng((self.seed, 0x5E4E)), self.sc.N + 1)
        scale = np.ones(self.sc.N + 1)
        sc_eff = self.sc
        recs: Dict[int, TaskRecord] = {}
        states = [None] * self.M
        for m in range(self.M):
            st = _MasterState(self.slots_per_master)
            st.caches = mdl["zero_caches"](self.slots_per_master)
            states[m] = st
        step_log: List[Dict[str, float]] = []
        tokens_out: Dict[int, List[int]] = {}
        seq = itertools.count()
        heap: List[Tuple[float, int, str, Any]] = []
        for r in requests:
            heapq.heappush(heap, (r.t_arrive, next(seq), _ARRIVE, r))
        for ev in churn:
            heapq.heappush(heap, (ev.time, next(seq), _CHURN, ev))
        stats = dict(max_err=0.0, match=0, total=0, solves=0, tokens=0)

        # ---- helpers bound to this serve run -----------------------------

        def online() -> np.ndarray:
            return pool.online

        def has_work() -> bool:
            return bool(len(queue)) or any(st.slots for st in states)

        def admit(t: float) -> None:
            while len(queue):
                progressed = False
                for rid in queue.candidates():
                    st = states[reqs[rid].master]
                    if st.free:
                        slot = min(st.free)
                        st.free.remove(slot)
                        queue.remove(rid)
                        queue.note_admitted(reqs[rid].master)
                        recs[rid].t_admit = t
                        r = reqs[rid]
                        st.slots[slot] = _Slot(rid=rid, prompt=r.prompt,
                                               gen_len=r.gen_len, tokens=[])
                        progressed = True
                        break
                    if queue.head_of_line:
                        return
                if not progressed:
                    return

        def fair_cap(m: int, k_req, b_req) -> float:
            # claimants: masters holding step shares, plus masters with
            # queued requests or admitted-but-idle batches (plan-row demand)
            held_rows = {m2: states[m2].step.k_row for m2 in range(self.M)
                         if states[m2].step is not None}
            waiting = queue.waiting_masters() | {
                m2 for m2 in range(self.M)
                if states[m2].slots and states[m2].step is None}
            held, demands = fair_demand_rows(m, planner.plan.k, online(),
                                             waiting, held_rows)
            return queue.fair_fraction(m, k_req, b_req, held=held,
                                       demands=demands)

        def hidden_states(m: int, st: _MasterState
                          ) -> Tuple[np.ndarray, List[int]]:
            import jax.numpy as jnp
            slot_ids = sorted(st.slots)
            cont = [s for s in slot_ids if not st.slots[s].needs_prefill]
            H: Dict[int, np.ndarray] = {}
            if cont:
                B = self.slots_per_master
                toks = np.zeros((B, 1), dtype=np.int32)
                pos = np.zeros((B,), dtype=np.int32)
                for s in cont:
                    toks[s, 0] = st.slots[s].tokens[-1]
                    pos[s] = st.slots[s].pos
                _, st.caches, hid = mdl["decode_fn"](
                    mdl["params"], jnp.asarray(toks), jnp.asarray(pos),
                    st.caches)
                hid = np.asarray(hid, dtype=np.float64)
                for s in cont:
                    H[s] = hid[s, 0]
                    st.slots[s].pos += 1
            for s in slot_ids:
                slot = st.slots[s]
                if not slot.needs_prefill:
                    continue
                batch = {"tokens": jnp.asarray(slot.prompt[None])}
                _, c1, h1 = mdl["prefill_fn"](
                    mdl["params"], batch, mdl["zero_caches"](1))
                st.caches = self._write_slot(st.caches, c1, s)
                slot.pos = len(slot.prompt)
                slot.needs_prefill = False
                H[s] = np.asarray(h1, dtype=np.float64)[0, 0]
            return np.stack([H[s] for s in slot_ids]), slot_ids

        def begin_step(m: int, t: float, relax: bool) -> bool:
            st = states[m]
            plan = planner.ensure_plan(online(), scale)
            fair_fn = (lambda kq, bq: fair_cap(m, kq, bq)) \
                if queue.uses_fairness and not relax else None
            scaled = scale_shares(
                pool, plan.k[m], plan.b[m], online(),
                allow_scaling=self.admission.allow_scaling,
                floor=1e-6 if relax else self.admission.min_fraction,
                fair_fn=fair_fn)
            if scaled is None:
                return False
            k_row, b_row, _f = scaled
            l_row, _ = scaled_row_loads(sc_eff, m, k_row, b_row)
            if l_row.sum() < L - 1e-6:
                return False
            l_int = coded_row_shards(l_row, L)
            e = exp.draw()
            d = bk.sample_delays(e[0], e[1], l_int, k_row, b_row,
                                 sc_eff.a[m], sc_eff.u[m], sc_eff.gamma[m])
            finish = np.where(l_int > 0, t + d, np.inf)
            comp = float(bk.completion_times(
                finish[None], l_int[None], np.array([float(L)]))[0])
            if not np.isfinite(comp):
                return False
            pool.acquire(k_row, b_row)
            H, slot_ids = hidden_states(m, st)
            res = self.head.step(H, l_int, finish, comp)
            tokens = np.argmax(res.logits, axis=1).astype(np.int64)
            if self.verify:
                ref = H @ self.head.W.T
                err = float(np.abs(res.logits - ref).max()
                            / (1.0 + np.abs(ref).max()))
                ok = int((tokens == np.argmax(ref, axis=1)).sum())
            else:
                err, ok = 0.0, len(slot_ids)
            stats["max_err"] = max(stats["max_err"], err)
            stats["match"] += ok
            stats["total"] += len(slot_ids)
            stats["solves"] += int(res.used_solve)
            st.step = _Step(k_row=k_row, b_row=b_row, l_int=l_int,
                            finish=finish, t_start=t, t_done=comp,
                            slot_ids=slot_ids, tokens=tokens,
                            rows_dispatched=res.rows_dispatched,
                            used_solve=res.used_solve, max_err=err,
                            argmax_ok=ok)
            heapq.heappush(heap, (comp, next(seq), _STEP, m))
            return True

        def pump(t: float, relax: bool = False) -> bool:
            started = False
            for m in range(self.M):
                if states[m].step is None and states[m].slots:
                    started |= begin_step(m, t, relax)
            return started

        def step_done(m: int, t: float) -> None:
            st = states[m]
            sp = st.step
            st.step = None
            pool.release(sp.k_row, sp.b_row)
            metrics.record_share_interval(sp.k_row, sp.b_row, t - sp.t_start)
            delivered = float(bk.delivered_by(
                sp.finish[None], sp.l_int.astype(np.float64)[None],
                np.array([t]))[0])
            B = len(sp.slot_ids)
            stats["tokens"] += B
            step_log.append({
                "master": m, "t_start": sp.t_start, "t_done": t,
                "batch": B, "rows_dispatched": sp.rows_dispatched,
                "rows_delivered": delivered, "used_solve": sp.used_solve,
                "max_err": sp.max_err,
            })
            for sid, tok in zip(sp.slot_ids, sp.tokens):
                slot = st.slots[sid]
                slot.tokens.append(int(tok))
                tokens_out.setdefault(slot.rid, []).append(int(tok))
                rec = recs[slot.rid]
                rec.rows_needed += L / B
                rec.rows_total += sp.rows_dispatched / B
                rec.rows_delivered += delivered / B
                if len(slot.tokens) >= slot.gen_len:
                    rec.t_complete = t
                    metrics.record_task(rec)
                    del st.slots[sid]
                    st.free.append(sid)
            admit(t)
            pump(t)

        def on_arrive(r: ServeRequest, t: float) -> None:
            plan = planner.ensure_plan(online(), scale, event=True)
            t_tok = float(plan.t_per_master[r.master])
            deadline = math.inf
            if math.isfinite(r.slack) and math.isfinite(t_tok):
                deadline = t + r.slack * r.gen_len * t_tok
            rec = TaskRecord(tid=r.rid, master=r.master, t_arrive=t,
                             deadline=deadline)
            recs[r.rid] = rec
            if not queue.offer(r.rid, master=r.master, deadline=deadline):
                del recs[r.rid], reqs[r.rid]    # backpressure rejection
                return
            admit(t)
            pump(t)

        def on_churn(ev: WorkerEvent, t: float) -> None:
            nonlocal sc_eff
            if ev.kind == "leave":
                pool.set_online(ev.worker, False)
            elif ev.kind == "join":
                pool.set_online(ev.worker, True)
            elif ev.kind == "degrade":
                scale[ev.worker] *= ev.factor
            elif ev.kind == "restore":
                scale[ev.worker] = 1.0
            sc_eff = planner.effective_scenario(online(), scale)
            planner.ensure_plan(online(), scale, event=True)
            admit(t)
            pump(t)

        # ---- event loop --------------------------------------------------

        now = 0.0
        while True:
            if not heap:
                # forward-progress fallback: relax fairness/min-fraction so
                # leftover work cannot deadlock against its own reservation
                if has_work() and pump(now, relax=True):
                    continue
                break
            now, _, kind, payload = heapq.heappop(heap)
            if kind == _ARRIVE:
                on_arrive(payload, now)
            elif kind == _CHURN:
                on_churn(payload, now)
            else:
                step_done(payload, now)

        metrics.replans = planner.replans
        metrics.rejected = queue.rejected
        metrics.unserved = len(queue) + sum(len(st.slots) for st in states)
        for rid in queue.candidates():
            metrics.record_unserved(recs[rid])
        for st in states:
            for slot in st.slots.values():
                metrics.record_unserved(recs[slot.rid])
        tol = 1e-6 if self.backend == "numpy" else 5e-4
        match_rate = stats["match"] / max(stats["total"], 1)
        return ServeReport(
            metrics=metrics,
            tokens=tokens_out,
            steps=step_log,
            policy=self.admission.policy,
            max_err=stats["max_err"] if self.verify else float("nan"),
            argmax_match_rate=match_rate,
            decode_ok=(stats["max_err"] <= tol and match_rate == 1.0)
            if self.verify else None,
            wall_seconds=time.perf_counter() - t_wall,
            tokens_generated=stats["tokens"],
            solve_steps=stats["solves"],
            sim_horizon_ms=max([metrics.t_end]
                               + [s["t_done"] for s in step_log]),
        )
