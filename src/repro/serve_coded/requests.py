"""Request model and synthetic workloads for the coded serving bridge.

A :class:`ServeRequest` is one user generation: a prompt, a target length,
an arrival instant in *simulation* time (milliseconds, the paper's unit)
and a deadline slack.  The slack is relative — the bridge turns it into an
absolute deadline ``t_arrive + slack × gen_len × t*_m`` with ``t*_m`` the
plan-predicted per-token completion of the request's master at arrival, so
"slack 2" means the same urgency on a fast and a slow tenant.

``synthetic_requests`` builds the mixed workload used by the example,
benchmark and CI smoke: per-master Poisson arrivals with a seeded mix of
tight- and loose-deadline requests (the mix is what separates EDF from
FIFO ordering).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["ServeRequest", "synthetic_requests"]


@dataclasses.dataclass
class ServeRequest:
    """One generation request entering the coded server."""
    rid: int
    master: int                 # tenant / master index (plan row)
    prompt: np.ndarray          # (P,) int32 token ids
    gen_len: int                # tokens to generate
    t_arrive: float             # simulation ms
    slack: float = math.inf     # deadline = t_arrive + slack·gen_len·t*_m


def synthetic_requests(n: int, *, masters: int, vocab: int,
                       prompt_len: int = 16, gen_len: int = 8,
                       rate: float = 0.002, seed: int = 0,
                       slack_choices: Optional[Sequence[float]] = (1.5, 4.0),
                       ) -> List[ServeRequest]:
    """``n`` requests with per-master Poisson arrivals (rate per ms).

    Prompts are uniform random tokens of a fixed length (one jit shape).
    ``slack_choices`` draws each request's deadline slack uniformly from
    the given values (None → no deadlines).  Deterministic in ``seed``.
    """
    rng = np.random.default_rng((int(seed), 0x5EB7))
    arrivals: List[Tuple[float, int]] = []
    t = np.zeros(masters)
    per_master = [n // masters + (1 if m < n % masters else 0)
                  for m in range(masters)]
    for m in range(masters):
        for _ in range(per_master[m]):
            t[m] += rng.exponential(1.0 / rate)
            arrivals.append((float(t[m]), m))
    arrivals.sort()
    out: List[ServeRequest] = []
    for rid, (ta, m) in enumerate(arrivals):
        slack = math.inf if slack_choices is None else \
            float(rng.choice(np.asarray(slack_choices, dtype=np.float64)))
        out.append(ServeRequest(
            rid=rid, master=m,
            prompt=rng.integers(0, vocab, size=prompt_len).astype(np.int32),
            gen_len=int(gen_len), t_arrive=ta, slack=slack))
    return out
