"""Architecture configuration schema.

One ``ArchConfig`` describes any of the ten assigned architectures (plus the
paper's own workloads).  A model is a *prefix* of unrolled layers followed by
``n_repeats`` copies of a repeating ``block`` (a tuple of ``LayerSpec``s) —
the repeating unit is what ``jax.lax.scan`` runs over, which keeps the HLO
size independent of depth (61-layer DeepSeek compiles as fast as 16-layer
Llama).

Examples: gemma3's 5 local + 1 global pattern is a 6-layer block; jamba's
1:7 attention:mamba interleave with MoE every other layer is an 8-layer
block; DeepSeek-V3's first-3-dense is a 3-layer prefix + 58 MoE repeats.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Sequence, Tuple

MixerKind = Literal["attn", "mamba", "rwkv"]
FFNKind = Literal["swiglu", "gelu", "relu2", "moe"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert hidden width
    n_shared: int = 0             # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims."""
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating block."""
    mixer: MixerKind = "attn"
    ffn: FFNKind = "swiglu"
    sliding_window: Optional[int] = None     # attention-only; None = global


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    prefix: Tuple[LayerSpec, ...] = ()
    block: Tuple[LayerSpec, ...] = (LayerSpec(),)
    n_repeats: int = 1

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None

    # RWKV-specific
    rwkv_head_size: int = 64

    ffn_act: str = "swiglu"          # activation used by dense FFN layers
    rope_base: float = 10_000.0
    rope_base_local: float = 10_000.0   # gemma3 uses a different local base
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    mtp: bool = False                # DeepSeek multi-token-prediction head

    # encoder-decoder (seamless-m4t)
    enc_dec: bool = False
    n_enc_repeats: int = 0
    enc_block: Tuple[LayerSpec, ...] = ()

    # modality frontend stubs: precomputed embeddings arrive via input_specs
    frontend: Optional[Literal["audio", "vision"]] = None
    frontend_dim: int = 256          # feature dim of the precomputed stubs
    frontend_len: int = 1500         # frames/patches per example

    dtype: str = "bfloat16"

    # long-context capability flag (decides the long_500k dry-run cell)
    subquadratic: bool = False

    @property
    def n_layers(self) -> int:
        return len(self.prefix) + len(self.block) * self.n_repeats

    @property
    def attn_type(self) -> str:
        return "mla" if self.mla is not None else "gqa"

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for reporting
        and for the 6·N·D roofline term."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)

        def layer_params(spec: LayerSpec) -> int:
            p = 2 * d  # two RMSNorm gains
            if spec.mixer == "attn":
                if self.mla is not None:
                    m = self.mla
                    qk = m.nope_dim + m.rope_dim
                    p += d * m.q_lora + m.q_lora * self.n_heads * qk
                    p += d * (m.kv_lora + m.rope_dim)
                    p += m.kv_lora * self.n_heads * (m.nope_dim + m.v_dim)
                    p += self.n_heads * m.v_dim * d
                else:
                    p += d * self.n_heads * self.d_head        # Q
                    p += 2 * d * self.n_kv_heads * self.d_head  # K, V
                    p += self.n_heads * self.d_head * d         # O
            elif spec.mixer == "mamba":
                mc = self.mamba or MambaConfig()
                di = mc.expand * d
                p += 2 * d * di + di * d                      # in/out proj
                p += di * (2 * mc.d_state + 2) + di * mc.d_conv
            elif spec.mixer == "rwkv":
                p += 5 * d * d + 2 * d * 64                   # r,k,v,g,o + decay lora
            if spec.ffn == "moe":
                m = self.moe
                p += d * m.num_experts * m.d_expert * 3
                p += d * m.n_shared * m.d_expert * 3
                p += d * m.num_experts                        # router
            elif spec.mixer == "rwkv":
                p += 2 * d * self.d_ff + d * d   # channel-mix (k, v, r)
            elif spec.ffn == "swiglu":
                p += 3 * d * self.d_ff
            else:
                p += 2 * d * self.d_ff
            return p

        for spec in self.prefix:
            total += layer_params(spec)
        for spec in self.block:
            total += layer_params(spec) * self.n_repeats
        for spec in self.enc_block:
            total += layer_params(spec) * self.n_enc_repeats
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        # subtract the inactive routed experts in every MoE layer
        n_moe_layers = sum(1 for s in self.prefix if s.ffn == "moe")
        n_moe_layers += sum(1 for s in self.block if s.ffn == "moe") * self.n_repeats
        inactive = (m.num_experts - m.top_k) * 3 * self.d_model * m.d_expert
        return full - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)
