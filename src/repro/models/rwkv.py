"""RWKV-6 ("Finch") mixer: data-dependent decay time-mix + channel-mix.

The WKV recurrence runs in the chunk-parallel form (see kernels/wkv6.py for
the TPU Pallas version and the derivation); the model-side implementation
here is the same math in pure jnp with a ``lax.scan`` over chunks, which
keeps the HLO small for the dry-run and is the oracle-consistent fallback on
CPU.  Decode carries (token-shift state, per-head WKV state).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig

__all__ = ["init_rwkv_tmix", "apply_rwkv_tmix", "init_rwkv_cmix",
           "apply_rwkv_cmix", "rwkv_cache_spec"]


def wkv6_chunked(r, k, v, w, u, chunk: int = 64):
    """Chunk-parallel WKV6.  r,k,w: (B,H,T,K), v: (B,H,T,V), u: (H,K).
    Returns (out (B,H,T,V), final state (B,H,K,V))."""
    B, H, T, K = r.shape
    V = v.shape[-1]
    if T % chunk:
        pad = chunk - T % chunk
        r = jnp.pad(r, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad), (0, 0)), constant_values=1.0)
    Tp = r.shape[2]
    nc = Tp // chunk

    def resh(x):
        return x.reshape(B, H, nc, chunk, x.shape[-1]).transpose(2, 0, 1, 3, 4)

    rs, ks, vs, ws = map(resh, (r, k, v, w))      # (nc, B, H, C, ·)

    def per_chunk(S, inp):
        rc, kc, vc, wc = (t.astype(jnp.float32) for t in inp)   # (B,H,C,·)
        lw = jnp.log(jnp.maximum(wc, 1e-12))
        lc = jnp.cumsum(lw, axis=2)
        lc_prev = lc - lw
        r_dec = rc * jnp.exp(lc_prev)
        k_grow = kc * jnp.exp(-lc)
        p = jnp.einsum("bhtk,bhsk->bhts", r_dec, k_grow)
        t_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        s_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        p = jnp.where(t_i > s_i, p, 0.0)
        o = jnp.einsum("bhts,bhsv->bhtv", p, vc)
        bonus = jnp.einsum("bhtk,bhtk->bht", rc * u[None, :, None, :], kc)
        o = o + bonus[..., None] * vc
        o = o + jnp.einsum("bhtk,bhkv->bhtv", r_dec, S)
        lc_last = lc[:, :, -1]                                   # (B,H,K)
        k_carry = kc * jnp.exp(lc_last[:, :, None, :] - lc)
        S_new = (jnp.exp(lc_last)[..., None] * S
                 + jnp.einsum("bhtk,bhtv->bhkv", k_carry, vc))
        return S_new, o

    S0 = jnp.zeros((B, H, K, V), jnp.float32)
    S_fin, outs = jax.lax.scan(per_chunk, S0, (rs, ks, vs, ws))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, Tp, V)[:, :, :T]
    return out.astype(v.dtype), S_fin


def init_rwkv_tmix(rng, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    keys = jax.random.split(rng, 8)
    s = 1.0 / math.sqrt(d)
    lora = 64
    return {
        "mu": jax.random.uniform(keys[0], (5, d), dtype),   # r,k,v,w,g shifts
        "wr": jax.random.normal(keys[1], (d, d), dtype) * s,
        "wk": jax.random.normal(keys[2], (d, d), dtype) * s,
        "wv": jax.random.normal(keys[3], (d, d), dtype) * s,
        "wg": jax.random.normal(keys[4], (d, d), dtype) * s,
        "w0": jnp.full((d,), -2.0, dtype),                  # base decay
        "w_lora_a": jax.random.normal(keys[5], (d, lora), dtype) * s,
        "w_lora_b": jax.random.normal(keys[6], (lora, d), dtype) * 0.01,
        "u": jax.random.normal(keys[7], (H, hs), dtype) * 0.1,
        "wo": jax.random.normal(jax.random.fold_in(rng, 9), (d, d), dtype) * s,
        "ln_g": jnp.ones((d,), dtype),
    }


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray]):
    """Previous-token tensor; ``last`` (B, d) continues across decode steps."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    else:
        last = last[:, None, :]
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def apply_rwkv_tmix(params: dict, x: jnp.ndarray, *, cfg: ArchConfig,
                    cache: Optional[dict] = None,
                    ) -> Tuple[jnp.ndarray, Optional[dict]]:
    B, T, d = x.shape
    hs = cfg.rwkv_head_size
    H = d // hs
    prev = _token_shift(x, cache["shift_t"] if cache is not None else None)
    mu = params["mu"]
    xr, xk, xv, xw, xg = (x + (prev - x) * mu[i] for i in range(5))

    r = xr @ params["wr"]
    k = xk @ params["wk"]
    v = xv @ params["wv"]
    g = jax.nn.silu(xg @ params["wg"])
    w_log = params["w0"] + (jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"])
    w = jnp.exp(-jnp.exp(w_log.astype(jnp.float32)))        # decay ∈ (0,1)

    def heads(t):
        return t.reshape(B, T, H, hs).transpose(0, 2, 1, 3)

    rh, kh, vh, wh = heads(r), heads(k), heads(v), heads(w.astype(x.dtype))

    if cache is None:
        o, _ = wkv6_chunked(rh, kh, vh, wh, params["u"].astype(jnp.float32))
        new_cache = None
    elif T == 1:
        S = cache["wkv"].astype(jnp.float32)                 # (B,H,K,V)
        r1 = rh[:, :, 0].astype(jnp.float32)
        k1 = kh[:, :, 0].astype(jnp.float32)
        v1 = vh[:, :, 0].astype(jnp.float32)
        w1 = wh[:, :, 0].astype(jnp.float32)
        kv = k1[..., None] * v1[..., None, :]
        o1 = jnp.einsum("bhk,bhkv->bhv",
                        r1, S + params["u"].astype(jnp.float32)[None, :, :, None] * kv)
        S = w1[..., None] * S + kv
        o = o1[:, :, None, :].astype(x.dtype)
        new_cache = {"wkv": S.astype(cache["wkv"].dtype), "shift_t": x[:, -1],
                     "shift_c": cache["shift_c"]}
    else:                                                    # prefill
        o, S = wkv6_chunked(rh, kh, vh, wh, params["u"].astype(jnp.float32))
        new_cache = {"wkv": S.astype(cache["wkv"].dtype), "shift_t": x[:, -1],
                     "shift_c": cache["shift_c"]}

    o = o.transpose(0, 2, 1, 3).reshape(B, T, d)
    from .layers import rms_norm
    o = rms_norm(o, params["ln_g"]) * g
    return o @ params["wo"], new_cache


def init_rwkv_cmix(rng, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "mu": jax.random.uniform(k1, (2, d), dtype),
        "wk": jax.random.normal(k2, (d, f), dtype) / math.sqrt(d),
        "wv": jax.random.normal(k3, (f, d), dtype) / math.sqrt(f),
        "wr": jax.random.normal(jax.random.fold_in(k1, 1), (d, d), dtype) / math.sqrt(d),
    }


def apply_rwkv_cmix(params: dict, x: jnp.ndarray, *,
                    cache: Optional[dict] = None,
                    ) -> Tuple[jnp.ndarray, Optional[dict]]:
    prev = _token_shift(x, cache["shift_c"] if cache is not None else None)
    mu = params["mu"]
    xk = x + (prev - x) * mu[0]
    xr = x + (prev - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    out = jax.nn.sigmoid(xr @ params["wr"]) * (k @ params["wv"])
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["shift_c"] = x[:, -1]
    return out, new_cache


def rwkv_cache_spec(cfg: ArchConfig, batch: int, dtype) -> dict:
    hs = cfg.rwkv_head_size
    H = cfg.d_model // hs
    return {
        "wkv": jax.ShapeDtypeStruct((batch, H, hs, hs), jnp.float32),
        "shift_t": jax.ShapeDtypeStruct((batch, cfg.d_model), dtype),
        "shift_c": jax.ShapeDtypeStruct((batch, cfg.d_model), dtype),
    }
