"""Shared building blocks: RMSNorm, dense FFN variants, embeddings."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig

__all__ = ["rms_norm", "init_rms", "init_ffn", "apply_ffn",
           "ffn_weight_names", "init_embedding", "embed", "logits"]


def ffn_weight_names(act: str) -> tuple:
    """The dense-FFN weight matrices of ``act``, in application order.

    This is the layout contract between :func:`init_ffn`/:func:`apply_ffn`
    and consumers that re-execute the matmuls elsewhere (the coded serving
    bridge row-shards each of these across workers under
    ``coding_scope="ffn"``/``"trunk"``)."""
    if act == "swiglu":
        return ("w_in", "w_gate", "w_out")
    if act in ("gelu", "relu2"):
        return ("w_in", "w_out")
    raise ValueError(act)


def init_rms(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def rms_norm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    n = x * jax.lax.rsqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), -1,
                                   keepdims=True) + eps)
    return (n.astype(x.dtype) * gain)


def init_ffn(rng, d: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff)
    p = {"w_in": jax.random.normal(k1, (d, d_ff), dtype) * s_in,
         "w_out": jax.random.normal(k2, (d_ff, d), dtype) * s_out}
    if act == "swiglu":
        p["w_gate"] = jax.random.normal(k3, (d, d_ff), dtype) * s_in
    return p


def apply_ffn(params: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = jnp.einsum("btd,df->btf", x, params["w_in"])
    if act == "swiglu":
        g = jnp.einsum("btd,df->btf", x, params["w_gate"])
        h = jax.nn.silu(g) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":                    # Nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    return jnp.einsum("btf,fd->btd", h, params["w_out"])


def init_embedding(rng, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(rng)
    p = {"tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model), dtype) * 0.02}
    if not cfg.tie_embeddings:
        p["out"] = jax.random.normal(k2, (cfg.d_model, cfg.vocab), dtype) \
            / math.sqrt(cfg.d_model)
    return p


def embed(params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["tok"], tokens, axis=0)


def logits(params: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("btd,vd->btv", x, params["tok"])
    return jnp.einsum("btd,dv->btv", x, params["out"])
