"""Model zoo: composable JAX LM stacks covering the ten assigned archs."""
from .config import (ArchConfig, LayerSpec, MLAConfig, MambaConfig, MoEConfig,
                     SHAPE_CELLS, ShapeCell, shape_cell)  # noqa: F401
from .lm import (ModelCtx, decode_step, init_cache_shapes, init_model,
                 model_fwd, padded_vocab, prefill)  # noqa: F401
