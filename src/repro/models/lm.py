"""Composable LM stack: decoder-only, encoder-decoder, hybrid, SSM.

A model = embeddings + ``prefix`` (unrolled layers) + ``n_repeats`` copies of
the repeating ``block`` run under ``jax.lax.scan`` (stacked params → compact
HLO at any depth) + final norm + output head.  Modality frontends are stub
projections of precomputed features (per the assignment brief).

Three entry points:
  * ``model_fwd``    — full-sequence forward (training / evaluation)
  * ``prefill``      — full-sequence forward that also fills a decode cache
  * ``decode_step``  — one token with cache (serving)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import layers as ly
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .config import ArchConfig, LayerSpec
from ..parallel.ops import sharded_embed

__all__ = ["init_model", "model_fwd", "prefill", "decode_step",
           "init_cache_shapes", "padded_vocab", "ModelCtx"]


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Distribution context threaded through layer application.

    ep_full: shard MoE experts over the *data* axes too (full-mesh expert
    parallelism) — removes the FSDP all-gather of expert weights entirely
    (§Perf hillclimb lever; requires num_experts % dp == 0)."""
    mesh: Optional[jax.sharding.Mesh] = None
    model_axis: str = "model"
    ep_full: bool = False
    remat_policy: str = "full"   # "full" | "dots" (save matmul outputs)
    a2a_fp8: bool = False        # fp8 MoE dispatch payloads


def padded_vocab(cfg: ArchConfig, mult: int = 512) -> int:
    return -(-cfg.vocab // mult) * mult


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(rng, cfg: ArchConfig, spec: LayerSpec, *,
                cross: bool = False) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(rng, 6)
    p: Dict[str, Any] = {"norm1": ly.init_rms(cfg.d_model, dt),
                         "norm2": ly.init_rms(cfg.d_model, dt)}
    if spec.mixer == "attn":
        if cfg.mla is not None:
            p["mixer"] = attn.init_mla(keys[0], cfg, dt)
        else:
            p["mixer"] = attn.init_gqa(keys[0], cfg, dt)
    elif spec.mixer == "mamba":
        p["mixer"] = ssm_mod.init_mamba(keys[0], cfg, dt)
    elif spec.mixer == "rwkv":
        p["mixer"] = rwkv_mod.init_rwkv_tmix(keys[0], cfg, dt)
    if cross:
        p["norm_x"] = ly.init_rms(cfg.d_model, dt)
        p["cross"] = attn.init_cross(keys[1], cfg, dt)
    if spec.ffn == "moe":
        p["ffn"] = moe_mod.init_moe(keys[2], cfg, dt)
    elif spec.mixer == "rwkv":
        p["ffn"] = rwkv_mod.init_rwkv_cmix(keys[2], cfg, dt)
    else:
        p["ffn"] = ly.init_ffn(keys[2], cfg.d_model, cfg.d_ff, spec.ffn, dt)
    return p


def _init_block(rng, cfg: ArchConfig, specs, *, cross: bool = False) -> dict:
    keys = jax.random.split(rng, len(specs))
    return {f"layer{i}": _init_layer(keys[i], cfg, s, cross=cross)
            for i, s in enumerate(specs)}


def init_model(rng, cfg: ArchConfig) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(rng, 8)
    vocab_p = padded_vocab(cfg)
    cfg_p = dataclasses.replace(cfg, vocab=vocab_p)
    params: Dict[str, Any] = {"embed": ly.init_embedding(keys[0], cfg_p, dt)}

    if cfg.prefix:
        params["prefix"] = [
            _init_layer(jax.random.fold_in(keys[1], i), cfg, s)
            for i, s in enumerate(cfg.prefix)]
    block_keys = jax.random.split(keys[2], cfg.n_repeats)
    params["blocks"] = jax.vmap(
        lambda k: _init_block(k, cfg, cfg.block))(block_keys)
    params["final_norm"] = ly.init_rms(cfg.d_model, dt)

    if cfg.enc_dec:
        ekeys = jax.random.split(keys[3], cfg.n_enc_repeats)
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_block(k, cfg, cfg.enc_block))(ekeys)
        params["enc_norm"] = ly.init_rms(cfg.d_model, dt)
        # decoder blocks get cross-attention
        dkeys = jax.random.split(keys[4], cfg.n_repeats)
        params["blocks"] = jax.vmap(
            lambda k: _init_block(k, cfg, cfg.block, cross=True))(dkeys)

    if cfg.frontend is not None:
        params["frontend"] = {
            "proj": jax.random.normal(keys[5], (cfg.frontend_dim, cfg.d_model),
                                      dt) / jnp.sqrt(cfg.frontend_dim)}
    if cfg.mtp:
        params["mtp"] = {
            "norm": ly.init_rms(cfg.d_model, dt),
            "proj": jax.random.normal(keys[6], (2 * cfg.d_model, cfg.d_model),
                                      dt) / jnp.sqrt(2.0 * cfg.d_model)}
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_layer(p: dict, x, *, cfg: ArchConfig, spec: LayerSpec,
                 ctx: ModelCtx, positions=None, cache=None, enc_out=None):
    h = ly.rms_norm(x, p["norm1"], cfg.norm_eps)
    mixer_cache = cache.get("mixer") if cache else None
    if spec.mixer == "attn":
        base = cfg.rope_base_local if spec.sliding_window else cfg.rope_base
        if cfg.mla is not None:
            mo, new_mc = attn.apply_mla(p["mixer"], h, cfg=cfg,
                                        rope_base=base, positions=positions,
                                        cache=mixer_cache)
        else:
            mo, new_mc = attn.apply_gqa(p["mixer"], h, cfg=cfg,
                                        window=spec.sliding_window,
                                        rope_base=base, positions=positions,
                                        cache=mixer_cache)
    elif spec.mixer == "mamba":
        mo, new_mc = ssm_mod.apply_mamba(p["mixer"], h, cfg=cfg,
                                         cache=mixer_cache)
    elif spec.mixer == "rwkv":
        mo, new_mc = rwkv_mod.apply_rwkv_tmix(p["mixer"], h, cfg=cfg,
                                              cache=mixer_cache)
    else:
        raise ValueError(spec.mixer)
    x = x + mo

    if "cross" in p and enc_out is not None:
        hx = ly.rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + attn.apply_cross(p["cross"], hx, enc_out, cfg=cfg)

    h2 = ly.rms_norm(x, p["norm2"], cfg.norm_eps)
    if spec.ffn == "moe":
        fo = moe_mod.apply_moe(p["ffn"], h2, cfg=cfg, mesh=ctx.mesh,
                               model_axis=ctx.model_axis,
                               ep_full=ctx.ep_full, a2a_fp8=ctx.a2a_fp8)
        new_fc = None
    elif spec.mixer == "rwkv":
        fo, new_mc = rwkv_mod.apply_rwkv_cmix(p["ffn"], h2, cache=new_mc)
    else:
        fo = ly.apply_ffn(p["ffn"], h2, spec.ffn)
    x = x + fo
    new_cache = {"mixer": new_mc} if new_mc is not None else {}
    return x, new_cache


def _run_stack(params, x, *, cfg: ArchConfig, specs, stacked, ctx: ModelCtx,
               positions=None, caches=None, enc_out=None,
               collect_layers: bool = False):
    """Run ``prefix`` (list of layer params) or scanned ``blocks``.

    ``collect_layers`` additionally threads each layer's post-residual
    hidden state out of the stack — a list of (B, T, d) arrays for the
    unstacked prefix, a (n_repeats, len(specs), B, T, d) array for the
    scanned blocks (the scan's ``ys`` output) — so callers can compare an
    external re-execution of the trunk layer by layer."""
    if not stacked:
        new_caches = []
        hiddens = []
        for i, (p, spec) in enumerate(zip(params, specs)):
            c = caches[i] if caches is not None else None
            x, nc = _apply_layer(p, x, cfg=cfg, spec=spec, ctx=ctx,
                                 positions=positions, cache=c,
                                 enc_out=enc_out)
            new_caches.append(nc)
            hiddens.append(x)
        if collect_layers:
            return x, new_caches, hiddens
        return x, new_caches

    def body(carry, xs):
        h = carry
        block_params, block_cache = xs
        new_block_cache = {}
        layer_h = []
        for i, spec in enumerate(specs):
            c = block_cache.get(f"layer{i}") if block_cache else None
            h, nc = _apply_layer(block_params[f"layer{i}"], h, cfg=cfg,
                                 spec=spec, ctx=ctx, positions=positions,
                                 cache=c, enc_out=enc_out)
            new_block_cache[f"layer{i}"] = nc
            layer_h.append(h)
        if collect_layers:
            return h, (new_block_cache, jnp.stack(layer_h))
        return h, new_block_cache

    if caches is None:
        if ctx.remat_policy == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(body)
    x, ys = jax.lax.scan(
        body, x, (params, caches if caches is not None else {}))
    if collect_layers:
        new_caches, hiddens = ys
        return x, new_caches, hiddens
    return x, ys


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _encoder(params, feats, *, cfg: ArchConfig, ctx: ModelCtx):
    x = jnp.einsum("btf,fd->btd", feats, params["frontend"]["proj"])
    x, _ = _run_stack(params["enc_blocks"], x, cfg=cfg, specs=cfg.enc_block,
                      stacked=True, ctx=ctx)
    return ly.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _trunk(params, x, *, cfg: ArchConfig, ctx: ModelCtx, positions=None,
           caches=None, enc_out=None, collect_layers: bool = False):
    """``collect_layers`` returns a third output: every layer's
    post-residual hidden state, flattened into one list over prefix +
    repeated-block layers (each entry (B, T, d), *before* the final
    norm)."""
    new_caches = {}
    layer_h = []
    if cfg.prefix:
        out = _run_stack(params["prefix"], x, cfg=cfg, specs=cfg.prefix,
                         stacked=False, positions=positions, ctx=ctx,
                         caches=caches.get("prefix") if caches else None,
                         enc_out=enc_out, collect_layers=collect_layers)
        x, nc = out[0], out[1]
        if collect_layers:
            layer_h.extend(out[2])
        new_caches["prefix"] = nc
    out = _run_stack(params["blocks"], x, cfg=cfg, specs=cfg.block,
                     stacked=True, positions=positions, ctx=ctx,
                     caches=caches.get("blocks") if caches else None,
                     enc_out=enc_out, collect_layers=collect_layers)
    x, nc = out[0], out[1]
    new_caches["blocks"] = nc
    x = ly.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if collect_layers:
        stacked_h = out[2]           # (n_repeats, n_specs, B, T, d)
        for r in range(stacked_h.shape[0]):
            for i in range(stacked_h.shape[1]):
                layer_h.append(stacked_h[r, i])
        return x, new_caches, layer_h
    return x, new_caches


def model_fwd(params, batch: Dict[str, jnp.ndarray], *, cfg: ArchConfig,
              ctx: ModelCtx = ModelCtx()) -> Dict[str, jnp.ndarray]:
    """Full-sequence forward.  Returns {"logits", optional "mtp_logits"}.

    batch: tokens (B, T); audio/enc feats (B, Ts, F) for enc-dec;
    patch feats (B, P, F) for VLM prefix conditioning.
    """
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = sharded_embed(params["embed"]["tok"], tokens, ctx.mesh,
                      ctx.model_axis)
    enc_out = None
    n_prefix_tokens = 0

    if cfg.enc_dec:
        enc_out = _encoder(params, batch["enc_feats"], cfg=cfg, ctx=ctx)
    elif cfg.frontend == "vision":
        pre = jnp.einsum("bpf,fd->bpd", batch["patch_feats"],
                         params["frontend"]["proj"])
        n_prefix_tokens = pre.shape[1]
        x = jnp.concatenate([pre, x], axis=1)

    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (B, x.shape[1]))
    x, _ = _trunk(params, x, cfg=cfg, ctx=ctx, positions=positions,
                  enc_out=enc_out)
    if n_prefix_tokens:
        x = x[:, n_prefix_tokens:]
    out = {"logits": ly.logits(params["embed"], x,
                               dataclasses.replace(cfg, vocab=padded_vocab(cfg)))}
    if cfg.mtp:
        nxt = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)
        h = jnp.concatenate([ly.rms_norm(x, params["mtp"]["norm"],
                                         cfg.norm_eps), nxt], axis=-1)
        h = jnp.einsum("bte,ed->btd", h, params["mtp"]["proj"])
        out["mtp_logits"] = ly.logits(
            params["embed"], h, dataclasses.replace(cfg, vocab=padded_vocab(cfg)))
    return out


def init_cache_shapes(cfg: ArchConfig, batch: int, max_len: int,
                      ) -> Dict[str, Any]:
    """ShapeDtypeStruct cache template (dry-run) — zeros via tree_map for
    real serving."""
    dt = _dtype(cfg)

    def layer_cache(spec: LayerSpec):
        if spec.mixer == "attn":
            if cfg.mla is not None:
                return {"mixer": attn.mla_cache_spec(cfg, batch, max_len, dt)}
            return {"mixer": attn.gqa_cache_spec(cfg, batch, max_len,
                                                 spec.sliding_window, dt)}
        if spec.mixer == "mamba":
            return {"mixer": ssm_mod.mamba_cache_spec(cfg, batch, dt)}
        if spec.mixer == "rwkv":
            return {"mixer": rwkv_mod.rwkv_cache_spec(cfg, batch, dt)}
        return {}

    def stack(tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_repeats,) + s.shape, s.dtype),
            tree)

    caches: Dict[str, Any] = {}
    if cfg.prefix:
        caches["prefix"] = [layer_cache(s) for s in cfg.prefix]
    caches["blocks"] = stack({f"layer{i}": layer_cache(s)
                              for i, s in enumerate(cfg.block)})
    return caches


def prefill(params, batch, caches, *, cfg: ArchConfig,
            ctx: ModelCtx = ModelCtx(), return_hidden: bool = False,
            collect_layers: bool = False):
    """Process the prompt, fill the cache, return last-position logits.

    ``return_hidden`` additionally returns the final-norm hidden state of
    the last position (B, 1, d_model) — the input of the output-head
    matmul, which coded serving executes as a distributed MDS-coded
    product instead of the local ``ly.logits`` contraction.

    ``collect_layers`` appends one more output: the list of *per-layer*
    post-residual hidden states (B, T, d_model) — the activations feeding
    each layer's q/k/v/o and FFN matmuls, which ``coding_scope="trunk"``
    serving distributes too (and which its tests compare layer by
    layer)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = sharded_embed(params["embed"]["tok"], tokens, ctx.mesh,
                      ctx.model_axis)
    enc_out = None
    if cfg.enc_dec:
        enc_out = _encoder(params, batch["enc_feats"], cfg=cfg, ctx=ctx)
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    out = _trunk(params, x, cfg=cfg, ctx=ctx, positions=positions,
                 caches=caches, enc_out=enc_out,
                 collect_layers=collect_layers)
    x, new_caches = out[0], out[1]
    hidden = x[:, -1:]
    logits = ly.logits(params["embed"], hidden,
                       dataclasses.replace(cfg, vocab=padded_vocab(cfg)))
    result = (logits, new_caches)
    if return_hidden:
        result += (hidden,)
    if collect_layers:
        result += (out[2],)
    return result


def decode_step(params, tokens, pos, caches, *, cfg: ArchConfig,
                ctx: ModelCtx = ModelCtx(), enc_out=None,
                return_hidden: bool = False, collect_layers: bool = False):
    """One decode step.  tokens (B, 1), pos (B,) absolute positions.

    ``return_hidden`` additionally returns the final-norm hidden state
    (B, 1, d_model) feeding the output head; ``collect_layers`` the
    per-layer hidden states (see :func:`prefill`)."""
    B = tokens.shape[0]
    x = sharded_embed(params["embed"]["tok"], tokens, ctx.mesh,
                      ctx.model_axis)
    positions = pos[:, None]
    out = _trunk(params, x, cfg=cfg, ctx=ctx, positions=positions,
                 caches=caches, enc_out=enc_out,
                 collect_layers=collect_layers)
    x, new_caches = out[0], out[1]
    logits = ly.logits(params["embed"], x,
                       dataclasses.replace(cfg, vocab=padded_vocab(cfg)))
    result = (logits, new_caches)
    if return_hidden:
        result += (x,)
    if collect_layers:
        result += (out[2],)
    return result
