"""Attention layers: GQA (with sliding-window), MLA (DeepSeek-V3), and
cross-attention — all built on a blockwise "flash" softmax that keeps the
compiled memory footprint bounded (no (T, T) score materialization).

Blocking scheme: the query axis is unrolled into static blocks; for each
query block the KV axis is scanned with a *static* upper bound (causal: only
blocks j ≤ i; sliding window: only the last ⌈W/bk⌉+1 blocks), so the
compiled FLOPs match the true masked work instead of the dense rectangle —
this is the TPU analogue of flash-attention's tile skipping.

Params are plain nested dicts; shapes use (B, T, H, D) layouts internally.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig, MLAConfig

__all__ = [
    "init_gqa", "apply_gqa", "init_mla", "apply_mla",
    "init_cross", "apply_cross", "rope", "flash_attention",
]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, base: float) -> jnp.ndarray:
    """Rotary embedding.  x: (B, T, H, D) with even D; positions: (B, T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash) attention
# ---------------------------------------------------------------------------

def _attn_block(q, k, v, q_pos, k_pos, *, causal, window, kv_valid, scale):
    """One (q-block, kv-block) tile.  q: (B,Hkv,G,bq,D), k/v: (B,Hkv,bk,D).

    Returns the tile's (scores_max, exp_scores @ v, exp_scores sum) pieces
    for online-softmax accumulation.
    """
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    if kv_valid is not None:
        mask &= kp < kv_valid
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    return s


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, kv_valid: Optional[jnp.ndarray] = None,
                    block_q: int = 512, block_k: int = 512,
                    scale: Optional[float] = None) -> jnp.ndarray:
    """Blockwise softmax attention.

    q: (B, Tq, Hq, D); k, v: (B, Tk, Hkv, Dk/Dv).  Hq % Hkv == 0 (GQA).
    ``q_offset`` is the absolute position of q[0] (prefill continuation /
    decode).  ``kv_valid`` masks a padded KV cache (scalar or (B,)).
    Returns (B, Tq, Hq, Dv).
    """
    B, Tq, Hq, D = q.shape
    _, Tk, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    # (B, Hkv, G, T, D) layouts
    qh = q.reshape(B, Tq, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    nq = -(-Tq // bq)
    nk = -(-Tk // bk)
    pad_q = nq * bq - Tq
    pad_k = nk * bk - Tk
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        kv_valid = jnp.minimum(
            jnp.asarray(Tk if kv_valid is None else kv_valid), Tk)

    out_blocks = []
    for i in range(nq):
        q_blk = qh[:, :, :, i * bq:(i + 1) * bq]
        q_pos = q_offset + i * bq + jnp.arange(bq)

        # static kv-block range for this q block (exact masked work)
        if causal:
            j_hi = min(nk, (q_offset + (i + 1) * bq + bk - 1) // bk)
        else:
            j_hi = nk
        if window is not None:
            j_lo = max(0, (q_offset + i * bq - window) // bk)
        else:
            j_lo = 0
        n_steps = max(j_hi - j_lo, 1)

        def step(carry, j):
            m, num, den = carry
            k_blk = jax.lax.dynamic_slice_in_dim(kh, j * bk, bk, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(vh, j * bk, bk, axis=2)
            k_pos = j * bk + jnp.arange(bk)
            s = _attn_block(q_blk, k_blk, v_blk, q_pos, k_pos, causal=causal,
                            window=window, kv_valid=kv_valid, scale=scale)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            num = num * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_blk,
                preferred_element_type=jnp.float32)
            den = den * corr + p.sum(axis=-1)
            return (m_new, num, den), None

        m0 = jnp.full((B, Hkv, G, bq), -jnp.inf, dtype=jnp.float32)
        num0 = jnp.zeros((B, Hkv, G, bq, Dv), dtype=jnp.float32)
        den0 = jnp.zeros((B, Hkv, G, bq), dtype=jnp.float32)
        (m, num, den), _ = jax.lax.scan(
            step, (m0, num0, den0), j_lo + jnp.arange(n_steps))
        out_blocks.append(num / jnp.maximum(den, 1e-30)[..., None])

    out = jnp.concatenate(out_blocks, axis=3) if nq > 1 else out_blocks[0]
    out = out[:, :, :, :Tq]                                  # strip q padding
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, Hq, Dv).astype(q.dtype)


def _decode_attention(q, k_cache, v_cache, kv_valid, *, window=None,
                      scale=None):
    """Single-token attention over a (possibly padded) KV cache.

    q: (B, 1, Hq, D); caches: (B, S, Hkv, D).  kv_valid: (B,) or scalar
    count of valid cache slots (the new token's K/V already written).
    """
    B, _, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qh = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    kp = jnp.arange(S)
    valid = kp[None, :] < jnp.reshape(jnp.asarray(kv_valid), (-1, 1))
    if window is not None:
        valid &= kp[None, :] > jnp.reshape(jnp.asarray(kv_valid), (-1, 1)) - window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------

def init_gqa(rng, cfg: ArchConfig, dtype) -> dict:
    d, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(Hq * Dh)
    return {
        "wq": jax.random.normal(k1, (d, Hq, Dh), dtype) * s,
        "wk": jax.random.normal(k2, (d, Hkv, Dh), dtype) * s,
        "wv": jax.random.normal(k3, (d, Hkv, Dh), dtype) * s,
        "wo": jax.random.normal(k4, (Hq, Dh, d), dtype) * so,
    }


def apply_gqa(params: dict, x: jnp.ndarray, *, cfg: ArchConfig,
              window: Optional[int] = None, rope_base: float = 10_000.0,
              positions: Optional[jnp.ndarray] = None,
              cache: Optional[dict] = None,
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, T, d).  Training/prefill when cache is None or being filled;
    decode (T == 1) when ``cache`` has 'k','v','len'."""
    B, T, d = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q = jnp.einsum("btd,dhx->bthx", x, params["wq"])
    k = jnp.einsum("btd,dhx->bthx", x, params["wk"])
    v = jnp.einsum("btd,dhx->bthx", x, params["wv"])
    q = rope(q, positions, rope_base)
    k = rope(k, positions, rope_base)

    if cache is None:
        o = flash_attention(q, k, v, causal=True, window=window)
        new_cache = None
    elif T > 1:
        # prefill: attend over the fresh K/V, then fill the cache
        o = flash_attention(q, k, v, causal=True, window=window)
        S = cache["k"].shape[1]
        if T >= S:
            # ring smaller than prompt → keep the tail, aligned so that
            # token p sits in slot p % S (decode continues the same ring)
            shift = (T - S) % S
            k_cache = jnp.roll(k[:, -S:], shift, axis=1)
            v_cache = jnp.roll(v[:, -S:], shift, axis=1)
        else:
            k_cache = jax.vmap(lambda c, n: jax.lax.dynamic_update_slice_in_dim(c, n, 0, 0))(
                cache["k"], k)
            v_cache = jax.vmap(lambda c, n: jax.lax.dynamic_update_slice_in_dim(c, n, 0, 0))(
                cache["v"], v)
        kv_valid = jnp.minimum(positions[:, -1] + 1, S)
        new_cache = {"k": k_cache, "v": v_cache, "len": kv_valid}
    else:
        slot = positions[:, 0] % cache["k"].shape[1]   # ring for windowed
        k_cache = jax.vmap(lambda c, kk, s: jax.lax.dynamic_update_slice_in_dim(c, kk, s, 0))(
            cache["k"], k, slot)
        v_cache = jax.vmap(lambda c, vv, s: jax.lax.dynamic_update_slice_in_dim(c, vv, s, 0))(
            cache["v"], v, slot)
        kv_valid = jnp.minimum(positions[:, -1] + 1, k_cache.shape[1])
        o = _decode_attention(q, k_cache, v_cache, kv_valid,
                              window=None)  # window handled by ring size
        new_cache = {"k": k_cache, "v": v_cache, "len": kv_valid}
    out = jnp.einsum("bthx,hxd->btd", o, params["wo"])
    return out, new_cache


def gqa_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                   window: Optional[int], dtype) -> dict:
    """Shape template for a decode cache (ring-buffer sized for windows)."""
    S = min(max_len, window) if window is not None else max_len
    shp = (batch, S, cfg.n_kv_heads, cfg.d_head)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype),
            "len": jax.ShapeDtypeStruct((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(rng, cfg: ArchConfig, dtype) -> dict:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    keys = jax.random.split(rng, 7)
    s = 1.0 / math.sqrt(d)
    sq = 1.0 / math.sqrt(m.q_lora)
    sk = 1.0 / math.sqrt(m.kv_lora)
    return {
        "wq_a": jax.random.normal(keys[0], (d, m.q_lora), dtype) * s,
        "wq_b": jax.random.normal(keys[1], (m.q_lora, H, m.nope_dim + m.rope_dim), dtype) * sq,
        "wkv_a": jax.random.normal(keys[2], (d, m.kv_lora + m.rope_dim), dtype) * s,
        "wk_b": jax.random.normal(keys[3], (m.kv_lora, H, m.nope_dim), dtype) * sk,
        "wv_b": jax.random.normal(keys[4], (m.kv_lora, H, m.v_dim), dtype) * sk,
        "wo": jax.random.normal(keys[5], (H, m.v_dim, d), dtype) / math.sqrt(H * m.v_dim),
        "q_norm": jnp.ones((m.q_lora,), dtype),
        "kv_norm": jnp.ones((m.kv_lora,), dtype),
    }


def _rms(x, g, eps=1e-6):
    n = x * jax.lax.rsqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), -1,
                                   keepdims=True) + eps)
    return (n * g).astype(x.dtype)


def apply_mla(params: dict, x: jnp.ndarray, *, cfg: ArchConfig,
              rope_base: float = 10_000.0,
              positions: Optional[jnp.ndarray] = None,
              cache: Optional[dict] = None,
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """MLA.  Prefill: expand latent → per-head K/V and run flash attention.
    Decode: *absorbed* form — queries are projected into the latent space and
    attention runs over the compressed (kv_lora + rope) cache, which is the
    whole point of MLA's small KV cache."""
    m: MLAConfig = cfg.mla
    B, T, d = x.shape
    H = cfg.n_heads
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    q_lat = _rms(jnp.einsum("btd,dr->btr", x, params["wq_a"]), params["q_norm"])
    q = jnp.einsum("btr,rhx->bthx", q_lat, params["wq_b"])
    q_nope, q_rope = q[..., :m.nope_dim], q[..., m.nope_dim:]
    q_rope = rope(q_rope, positions, rope_base)

    kv = jnp.einsum("btd,dr->btr", x, params["wkv_a"])
    c_kv = _rms(kv[..., :m.kv_lora], params["kv_norm"])   # (B, T, kv_lora)
    k_rope = rope(kv[..., m.kv_lora:][:, :, None, :], positions, rope_base)

    scale = 1.0 / math.sqrt(m.nope_dim + m.rope_dim)

    if cache is None or T > 1:
        k_nope = jnp.einsum("btr,rhx->bthx", c_kv, params["wk_b"])
        v = jnp.einsum("btr,rhx->bthx", c_kv, params["wv_b"])
        k = jnp.concatenate([k_nope,
                             jnp.broadcast_to(k_rope, (B, T, H, m.rope_dim))],
                            axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = flash_attention(qq, k, v, causal=True, scale=scale)
        new_cache = None
        if cache is not None:   # prefill: stash the compressed latents
            c_cache = jax.vmap(lambda c, n: jax.lax.dynamic_update_slice_in_dim(c, n, 0, 0))(
                cache["c"], c_kv)
            r_cache = jax.vmap(lambda c, n: jax.lax.dynamic_update_slice_in_dim(c, n, 0, 0))(
                cache["r"], k_rope[:, :, 0, :])
            new_cache = {"c": c_cache, "r": r_cache,
                         "len": positions[:, -1] + 1}
    else:
        # absorbed decode: q_eff = W_kbᵀ q_nope lives in latent space
        q_lat_abs = jnp.einsum("bthx,rhx->bthr", q_nope, params["wk_b"])
        slot = positions[:, 0]
        c_cache = jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, 0))(
            cache["c"], c_kv, slot)
        r_cache = jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, 0))(
            cache["r"], k_rope[:, :, 0, :], slot)
        kv_valid = positions[:, -1] + 1
        s_lat = jnp.einsum("bthr,bsr->bhts", q_lat_abs.astype(jnp.float32),
                           c_cache.astype(jnp.float32))
        s_rope = jnp.einsum("bthx,bsx->bhts", q_rope.astype(jnp.float32),
                            r_cache.astype(jnp.float32))
        s = (s_lat + s_rope) * scale
        valid = jnp.arange(c_cache.shape[1])[None, :] < kv_valid[:, None]
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhts,bsr->bthr", p, c_cache.astype(jnp.float32))
        o = jnp.einsum("bthr,rhx->bthx", o_lat.astype(x.dtype), params["wv_b"])
        new_cache = {"c": c_cache, "r": r_cache, "len": kv_valid}

    out = jnp.einsum("bthx,hxd->btd", o, params["wo"])
    return out, new_cache


def mla_cache_spec(cfg: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    m = cfg.mla
    return {"c": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora), dtype),
            "r": jax.ShapeDtypeStruct((batch, max_len, m.rope_dim), dtype),
            "len": jax.ShapeDtypeStruct((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec decoder)
# ---------------------------------------------------------------------------

def init_cross(rng, cfg: ArchConfig, dtype) -> dict:
    return init_gqa(rng, cfg, dtype)


def apply_cross(params: dict, x: jnp.ndarray, enc: jnp.ndarray, *,
                cfg: ArchConfig) -> jnp.ndarray:
    """x: (B, Tq, d) decoder states; enc: (B, Tk, d) encoder output."""
    q = jnp.einsum("btd,dhx->bthx", x, params["wq"])
    k = jnp.einsum("btd,dhx->bthx", enc, params["wk"])
    v = jnp.einsum("btd,dhx->bthx", enc, params["wv"])
    o = flash_attention(q, k, v, causal=False)
    return jnp.einsum("bthx,hxd->btd", o, params["wo"])
