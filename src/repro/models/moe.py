"""Mixture-of-Experts layer with capacity-bounded expert-choice dispatch and
an expert-parallel (EP) all-to-all path.

Dispatch: tokens pick their top-k experts (token choice); each expert then
keeps its top-C tokens by router probability (capacity dropping by lowest
affinity, not arrival order — strictly better than Switch-style dropping and
the same scheme DeepSeek's aux-loss-free balancing approximates).

Why this shape: the (T, E) score matrix is tiny compared to a (T, E, C)
one-hot dispatch tensor, and per-expert ``top_k`` + ``take`` lowers to
gathers that the SPMD partitioner handles without materializing anything
token-quadratic.

Paper tie-in (DESIGN.md §2): expert capacity is exactly a Theorem-1 load
allocation — experts are "workers" with unit-delay θ_e and the capacity
vector can be reweighted by ``repro.parallel.hetero`` for heterogeneous
expert shards.

EP path: under ``shard_map`` the expert axis is sharded over the "model"
mesh axis; per-device expert buffers are exchanged with two all-to-alls
(dispatch + return), the canonical MoE collective pattern on TPU pods.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig, MoEConfig

__all__ = ["init_moe", "apply_moe", "moe_capacity"]


def moe_capacity(m: MoEConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * m.top_k / m.num_experts * m.capacity_factor))
    c = max(8, -(-c // 8) * 8)      # pad to a sublane multiple
    return min(c, n_tokens)         # never more slots than tokens


def init_moe(rng, cfg: ArchConfig, dtype) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_expert, m.num_experts
    keys = jax.random.split(rng, 7)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": jax.random.normal(keys[0], (d, E), jnp.float32) * s_in,
        "w_in": jax.random.normal(keys[1], (E, d, f), dtype) * s_in,
        "w_gate": jax.random.normal(keys[2], (E, d, f), dtype) * s_in,
        "w_out": jax.random.normal(keys[3], (E, f, d), dtype) * s_out,
    }
    if m.n_shared:
        p["shared_in"] = jax.random.normal(keys[4], (d, m.n_shared * f), dtype) * s_in
        p["shared_gate"] = jax.random.normal(keys[5], (d, m.n_shared * f), dtype) * s_in
        p["shared_out"] = jax.random.normal(keys[6], (m.n_shared * f, d), dtype) * s_out
    return p


def _expert_ffn(w_in, w_gate, w_out, xs):
    """xs: (E, C, d) → (E, C, d), SwiGLU experts."""
    h = jnp.einsum("ecd,edf->ecf", xs, w_in)
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)


def _dispatch(probs: jnp.ndarray, top_k: int, capacity: int):
    """Expert-choice-of-token-choice dispatch tables.

    probs: (T, E) router probabilities.  Returns (idx, weight):
      idx    (E, C) token index each expert processes,
      weight (E, C) combine weight (0 where the slot is empty/dropped).
    """
    T, E = probs.shape
    topv, topi = jax.lax.top_k(probs, top_k)              # (T, k)
    chosen = jnp.zeros((T, E), probs.dtype)
    chosen = jax.vmap(lambda row, idx, val: row.at[idx].set(val))(
        chosen, topi, topv)                               # (T, E) sparse scores
    score_te = chosen.T                                    # (E, T)
    w, idx = jax.lax.top_k(score_te, capacity)             # (E, C)
    return idx, w


def apply_moe(params: dict, x: jnp.ndarray, *, cfg: ArchConfig,
              mesh: Optional[jax.sharding.Mesh] = None,
              model_axis: str = "model", ep_full: bool = False,
              a2a_fp8: bool = False) -> jnp.ndarray:
    """x: (B, T, d) → (B, T, d).

    With ``mesh`` the dispatch runs under shard_map with the expert axis
    sharded on ``model_axis`` (two all-to-alls); without it, a single-device
    reference path (smoke tests / CPU).

    ``ep_full`` (hillclimb lever): experts sharded over the data axes AND
    their hidden width over the model axis — expert weights become fully
    mesh-sharded (no FSDP all-gather), dispatch all-to-alls run over the
    data axes, and one psum over the model axis reduces the split-f expert
    product.  Requires num_experts % dp == 0 and enough tokens.
    """
    m = cfg.moe
    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    n_tok = B * T

    def local_moe(xt, router, w_in, w_gate, w_out):
        probs = jax.nn.softmax(xt.astype(jnp.float32) @ router, axis=-1)
        cap = moe_capacity(m, xt.shape[0])
        idx, w = _dispatch(probs, m.top_k, cap)            # (E, C)
        xs = jnp.take(xt, idx.reshape(-1), axis=0).reshape(
            m.num_experts, cap, d)
        ys = _expert_ffn(w_in, w_gate, w_out, xs)
        ys = ys * w[..., None].astype(ys.dtype)
        out = jnp.zeros_like(xt).at[idx.reshape(-1)].add(
            ys.reshape(-1, d), mode="drop")
        return out

    if mesh is None or model_axis not in mesh.axis_names:
        out = local_moe(xf, params["router"], params["w_in"],
                        params["w_gate"], params["w_out"])
    else:
        from jax.sharding import PartitionSpec as P
        from ..parallel.ops import shard_map_compat
        import numpy as np
        S = mesh.shape[model_axis]
        Eps = m.num_experts // S
        data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
        dp = int(np.prod([mesh.shape[a] for a in data_axes]))
        tokens_per_shard = n_tok // max(dp, 1)

        def ep_small(xt, router, w_in, w_gate, w_out):
            # Decode-scale token counts: tokens replicated over the model
            # axis, each rank runs its local experts on all of them, psum
            # combines.  One small all-reduce instead of all-to-alls.
            r = jax.lax.axis_index(model_axis)
            probs = jax.nn.softmax(xt.astype(jnp.float32) @ router, -1)
            T_loc = xt.shape[0]
            topv, topi = jax.lax.top_k(probs, m.top_k)
            chosen = jnp.zeros((T_loc, m.num_experts), probs.dtype)
            chosen = jax.vmap(lambda row, i, v: row.at[i].set(v))(
                chosen, topi, topv)
            my = jax.lax.dynamic_slice_in_dim(chosen, r * Eps, Eps, axis=1)
            cap = moe_capacity(m, T_loc)
            w, idx = jax.lax.top_k(my.T, cap)              # (Eps, C)
            xs = jnp.take(xt, idx.reshape(-1), 0).reshape(Eps, cap, d)
            ys = _expert_ffn(w_in, w_gate, w_out, xs)
            ys = ys * w[..., None].astype(ys.dtype)
            out = jnp.zeros_like(xt).at[idx.reshape(-1)].add(
                ys.reshape(-1, d), mode="drop")
            return jax.lax.psum(out, model_axis)

        def ep_moe(xt, router, w_in, w_gate, w_out):
            # xt: (T_loc, d) tokens of this data shard (replicated over model
            # axis entry: we slice our model-rank's token chunk instead).
            r = jax.lax.axis_index(model_axis)
            t_chunk = xt.shape[0] // S
            xt_loc = jax.lax.dynamic_slice_in_dim(xt, r * t_chunk, t_chunk, 0)
            probs = jax.nn.softmax(xt_loc.astype(jnp.float32) @ router, -1)
            cap = moe_capacity(m, t_chunk)
            idx, w = _dispatch(probs, m.top_k, cap)        # (E, C)
            xs = jnp.take(xt_loc, idx.reshape(-1), 0).reshape(
                m.num_experts, cap, d)
            # dispatch all-to-all: (S, Eps, C, d) → experts gather their slice
            xs = xs.reshape(S, Eps, cap, d)
            xs = jax.lax.all_to_all(xs, model_axis, split_axis=0,
                                    concat_axis=0, tiled=False)
            # now (S, Eps, C, d): tokens from every source shard for MY experts
            xs = xs.transpose(1, 0, 2, 3).reshape(Eps, S * cap, d)
            ys = _expert_ffn(w_in, w_gate, w_out, xs)
            ys = ys.reshape(Eps, S, cap, d).transpose(1, 0, 2, 3)
            ys = jax.lax.all_to_all(ys, model_axis, split_axis=0,
                                    concat_axis=0, tiled=False)
            ys = ys.reshape(m.num_experts, cap, d) * w[..., None].astype(ys.dtype)
            out_loc = jnp.zeros_like(xt_loc).at[idx.reshape(-1)].add(
                ys.reshape(-1, d), mode="drop")
            # reassemble the full token block across the model axis
            out = jax.lax.all_gather(out_loc, model_axis, axis=0, tiled=True)
            return out

        def ep_full_body(xt, router, w_in, w_gate, w_out):
            # xt (T_loc, d) identical across model ranks; w_* blocks are
            # (E/dp, d, f/tp).  Dispatch is duplicated across model ranks
            # (cheap); expert matmuls split f over the model axis.
            probs = jax.nn.softmax(xt.astype(jnp.float32) @ router, -1)
            T_loc = xt.shape[0]
            cap = moe_capacity(m, T_loc)
            idx, w = _dispatch(probs, m.top_k, cap)          # (E, C)
            xs = jnp.take(xt, idx.reshape(-1), 0).reshape(
                m.num_experts, cap, d)
            Edp = m.num_experts // dp
            xs = xs.reshape(dp, Edp, cap, d)
            if a2a_fp8:
                # DeepSeek-V3-style fp8 dispatch: halve the dominant
                # all-to-all payload (combine stays bf16 for accuracy)
                xs = xs.astype(jnp.float8_e4m3fn)
            xs = jax.lax.all_to_all(xs, data_axes, split_axis=0,
                                    concat_axis=0, tiled=False)
            xs = xs.astype(x.dtype)
            xs = xs.transpose(1, 0, 2, 3).reshape(Edp, dp * cap, d)
            h = jnp.einsum("ecd,edf->ecf", xs, w_in)
            g = jnp.einsum("ecd,edf->ecf", xs, w_gate)
            ys = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)
            ys = jax.lax.psum(ys, model_axis)                # reduce f shards
            ys = ys.reshape(Edp, dp, cap, d).transpose(1, 0, 2, 3)
            ys = jax.lax.all_to_all(ys, data_axes, split_axis=0,
                                    concat_axis=0, tiled=False)
            ys = ys.reshape(m.num_experts, cap, d) * w[..., None].astype(ys.dtype)
            out = jnp.zeros_like(xt).at[idx.reshape(-1)].add(
                ys.reshape(-1, d), mode="drop")
            return out

        use_full = (ep_full and m.num_experts % dp == 0
                    and tokens_per_shard >= dp and n_tok % dp == 0)
        if use_full:
            body = ep_full_body
            # (E, d, f) in/gate split f on model; (E, f, d) out splits f=dim1
            wspec_in = P(data_axes, None, model_axis)
            wspec_out = P(data_axes, model_axis, None)
        else:
            body = ep_moe if tokens_per_shard >= S else ep_small
            wspec_in = wspec_out = P(model_axis)
        # batch-of-1 decode can't shard the token axis at all: replicate
        xspec = P(data_axes) if (n_tok % dp == 0 and n_tok >= dp) else P()
        out = shard_map_compat(
            body, mesh=mesh,
            in_specs=(xspec, P(), wspec_in, wspec_in, wspec_out),
            out_specs=xspec,
        )(xf, params["router"], params["w_in"], params["w_gate"],
          params["w_out"])

    if m.n_shared:
        h = jnp.einsum("td,df->tf", xf, params["shared_in"])
        g = jnp.einsum("td,df->tf", xf, params["shared_gate"])
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(g) * h,
                               params["shared_out"])
    return out.reshape(B, T, d)
