"""Mamba (selective SSM) mixer for the Jamba hybrid stack.

h_t = exp(Δ_t A) ⊙ h_{t-1} + Δ_t B_t x_t,   y_t = C_tᵀ h_t + D x_t,
with diagonal A (d_inner, d_state), data-dependent (Δ, B, C), causal
depthwise conv front-end, and a SiLU gate — Mamba-1 per Jamba.

Sequence processing uses ``lax.scan`` over time (compact HLO, exact
recurrence); decode carries (h, conv window) through the cache.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig, MambaConfig

__all__ = ["init_mamba", "apply_mamba", "mamba_cache_spec"]


def init_mamba(rng, cfg: ArchConfig, dtype) -> dict:
    mc = cfg.mamba or MambaConfig()
    d = cfg.d_model
    di = mc.expand * d
    N = mc.d_state
    keys = jax.random.split(rng, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "w_in": jax.random.normal(keys[0], (d, 2 * di), dtype) * s,
        "conv": jax.random.normal(keys[1], (mc.d_conv, di), dtype) * 0.2,
        "w_bcdt": jax.random.normal(keys[2], (di, 2 * N + 1), dtype) / math.sqrt(di),
        "dt_bias": jnp.zeros((di,), dtype),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "d_skip": jnp.ones((di,), dtype),
        "w_out": jax.random.normal(keys[3], (di, d), dtype) / math.sqrt(di),
    }


def _conv_causal(x: jnp.ndarray, w: jnp.ndarray,
                 carry: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv.  x: (B, T, di), w: (K, di).
    ``carry``: (B, K-1, di) previous tail for decode continuity."""
    K = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out


def apply_mamba(params: dict, x: jnp.ndarray, *, cfg: ArchConfig,
                cache: Optional[dict] = None,
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, T, d) → (B, T, d).  cache: {'h': (B, di, N), 'conv': (B, K-1, di)}."""
    mc = cfg.mamba or MambaConfig()
    B, T, d = x.shape
    di = mc.expand * d
    N = mc.d_state

    xz = jnp.einsum("btd,de->bte", x, params["w_in"])
    xs, z = xz[..., :di], xz[..., di:]
    conv_carry = cache["conv"] if cache is not None else None
    xs = jax.nn.silu(_conv_causal(xs, params["conv"], conv_carry))

    bcdt = jnp.einsum("bti,ie->bte", xs, params["w_bcdt"])
    Bm, Cm = bcdt[..., :N], bcdt[..., N:2 * N]
    dt = jax.nn.softplus(bcdt[..., -1:] + params["dt_bias"])       # (B,T,di)
    A = -jnp.exp(params["a_log"])                                   # (di, N)

    h0 = (cache["h"].astype(jnp.float32) if cache is not None
          else jnp.zeros((B, di, N), jnp.float32))

    def step(h, inp):
        # α/β are formed per-step inside the body: materializing the full
        # (B, T, di, N) tensors would be ~T·N× the activation budget.
        dt_t, b_t, c_t, x_t = inp
        alpha = jnp.exp(dt_t.astype(jnp.float32)[..., None] * A)    # (B,di,N)
        beta = (dt_t * x_t).astype(jnp.float32)[..., None] \
            * b_t.astype(jnp.float32)[:, None, :]
        h = alpha * h + beta
        y = jnp.einsum("bin,bn->bi", h, c_t.astype(jnp.float32))
        return h, y

    h_last, ys = jax.lax.scan(
        step, h0,
        (dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2),
         Cm.transpose(1, 0, 2), xs.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2).astype(x.dtype)                       # (B,T,di)
    y = y + params["d_skip"] * xs
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bti,id->btd", y, params["w_out"])

    new_cache = None
    if cache is not None:
        K = mc.d_conv
        tail_src = jnp.concatenate([cache["conv"],
                                    xz[..., :di]], axis=1)[:, -(K - 1):]
        new_cache = {"h": h_last.astype(cache["h"].dtype), "conv": tail_src}
    return out, new_cache


def mamba_cache_spec(cfg: ArchConfig, batch: int, dtype) -> dict:
    mc = cfg.mamba or MambaConfig()
    di = mc.expand * cfg.d_model
    return {"h": jax.ShapeDtypeStruct((batch, di, mc.d_state), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, mc.d_conv - 1, di), dtype)}
